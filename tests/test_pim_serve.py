"""PIM linear backends agreement + serving engine behaviour."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs
from repro.models.model import LM
from repro.pim import PimConfig, linear_apply, linear_init, pack_linear
from repro.serve.engine import Request, ServeEngine


@pytest.mark.parametrize("mode", ["ref", "pallas", "popcount"])
@pytest.mark.parametrize("bits", [4, 8])
def test_pim_backends_agree(mode, bits):
    cfg = PimConfig(mode=mode, weight_bits=bits)
    key = jax.random.PRNGKey(0)
    dense = linear_init(key, 128, 64, cfg)
    packed = pack_linear(dense, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 10, 128),
                          jnp.bfloat16)

    y_dense = linear_apply(dense, x, PimConfig(mode="off"))
    y_pim = linear_apply(packed, x, cfg)
    # quantization error bound: W4A8 coarse, W8A8 tight
    err = np.abs(np.asarray(y_pim, np.float32)
                 - np.asarray(y_dense, np.float32))
    ref_mag = np.abs(np.asarray(y_dense, np.float32)).mean()
    tol = 0.15 if bits == 4 else 0.03
    assert err.mean() < tol * max(ref_mag, 1e-3)


def test_pim_ref_equals_pallas_exactly():
    """Same integer arithmetic -> bit-identical accumulators."""
    cfgr = PimConfig(mode="ref", weight_bits=4)
    cfgp = PimConfig(mode="pallas", weight_bits=4)
    key = jax.random.PRNGKey(2)
    dense = linear_init(key, 256, 128, cfgr)
    packed = pack_linear(dense, cfgr)
    x = jax.random.normal(jax.random.PRNGKey(3), (8, 256), jnp.bfloat16)
    yr = linear_apply(packed, x, cfgr)
    yp = linear_apply(packed, x, cfgp)
    np.testing.assert_allclose(np.asarray(yr, np.float32),
                               np.asarray(yp, np.float32), rtol=1e-5)


@pytest.mark.slow     # LM decode loop: ~10-25s compile+run
def test_serve_engine_matches_manual_decode():
    cfg = configs.get_config("llama3.2-1b", smoke=True)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(4))
    prompt = np.asarray([3, 1, 4, 1, 5], np.int32)

    eng = ServeEngine(model, params, batch_slots=2, capacity=32)
    eng.add(Request(rid=0, prompt=prompt, max_new=6))
    done = eng.run()
    assert len(done) == 1 and len(done[0].out) == 6

    # manual greedy decode must match
    toks = jnp.asarray(prompt)[None, :]
    logits, caches = model.prefill(params, tokens=toks, capacity=32)
    cur = int(jnp.argmax(logits[0, -1]))
    outs = [cur]
    pos = prompt.shape[0]
    # replicate across the 2 engine slots to reuse cache shapes
    caches2 = jax.tree.map(
        lambda x: jnp.concatenate([x, x], axis=1)
        if x.ndim >= 2 and x.shape[1] == 1 else x, caches)
    for _ in range(5):
        lg, caches2 = model.decode_step(
            params, caches2, jnp.asarray([[cur], [cur]], jnp.int32),
            jnp.asarray([pos, pos], jnp.int32))
        cur = int(jnp.argmax(lg[0, 0]))
        outs.append(cur)
        pos += 1
    assert outs == done[0].out


@pytest.mark.slow     # LM decode loop: ~10-25s compile+run
def test_serve_engine_continuous_batching():
    cfg = configs.get_config("qwen2-0.5b", smoke=True)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(5))
    eng = ServeEngine(model, params, batch_slots=2, capacity=32)
    rng = np.random.default_rng(0)
    for rid in range(5):                     # more requests than slots
        eng.add(Request(rid=rid,
                        prompt=rng.integers(0, cfg.vocab, 4).astype(np.int32),
                        max_new=4))
    done = eng.run()
    assert sorted(r.rid for r in done) == [0, 1, 2, 3, 4]
    assert all(len(r.out) == 4 for r in done)


def test_serve_engine_hybrid_arch_with_rest_layers():
    """recurrentgemma smoke has unstacked 'rest' layers -- regression
    test for the slot-merge batch-dim handling."""
    cfg = configs.get_config("recurrentgemma-9b", smoke=True)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(6))
    prompt = np.asarray([3, 1, 4, 1, 5], np.int32)

    eng = ServeEngine(model, params, batch_slots=2, capacity=32)
    eng.add(Request(rid=0, prompt=prompt, max_new=5))
    done = eng.run()
    assert len(done) == 1 and len(done[0].out) == 5

    # must match single-request manual decode
    toks = jnp.asarray(prompt)[None, :]
    logits, caches = model.prefill(params, tokens=toks, capacity=32)
    cur = int(jnp.argmax(logits[0, -1]))
    outs = [cur]
    pos = len(prompt)
    caches2 = jax.tree.map(
        lambda x: jnp.concatenate([x, x], axis=1)
        if (x.ndim >= 2 and x.shape[1] == 1) else
        (jnp.concatenate([x, x], axis=0) if x.ndim >= 1 and x.shape[0] == 1
         else x), caches)
    # unit caches: (L, 1, ...) -> dim1; rest caches: (1, ...) -> dim0
    for _ in range(4):
        lg, caches2 = model.decode_step(
            params, caches2, jnp.asarray([[cur], [cur]], jnp.int32),
            jnp.asarray([pos, pos], jnp.int32))
        cur = int(jnp.argmax(lg[0, 0]))
        outs.append(cur)
        pos += 1
    assert outs == done[0].out
