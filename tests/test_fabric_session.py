"""Persistent fabric sessions: cross-program residency, the on-fabric
KV cache, the per-step cost trajectory, and the attention decode block.

The contract under test (docs/fabric.md "Persistent sessions"):

* scheduling through a :class:`FabricSession` carries the resident-tile
  maps ACROSS programs -- a weight tile fetched in decode step 1 emits
  no :class:`TileLoad` in steps 2..N;
* execution stays bit-identical with or without a session, for every
  dtype (residency is accounting, never arithmetic);
* LRU eviction keeps working across program boundaries (an evicted
  tile is refetched), ``reset()`` restores cold behaviour, and ``kv``
  tiles are append-addressed and never LRU-evicted;
* the trajectory splits cold step-1 cost from the steady state.
"""

import numpy as np
import pytest

from repro.core import costmodel
from repro.pim import fabric
from repro.pim.fabric import (FabricConfig, FabricSession, GemmSpec)


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def _grid(n_blocks=8, **kw):
    kw.setdefault("rows", 128)
    kw.setdefault("cols", 8)
    return FabricConfig(n_blocks=n_blocks, **kw)


def _ints(rng, shape, nbits):
    lo = -(1 << (nbits - 1))
    return rng.integers(lo, -lo, shape).astype(np.int64)


def _w_loads(sched):
    return [ld for r in sched.rounds for ld in r.loads if ld.kind == "w"]


# ---------------------------------------------------------------------------
# Cross-program residency
# ---------------------------------------------------------------------------
def test_second_program_emits_zero_weight_loads(rng):
    cfg = _grid(min_compute_blocks=4)
    sess = FabricSession(cfg)
    specs = (GemmSpec("w0", 1, 16, 8),)
    s1 = fabric.schedule_program(specs, 4, cfg=cfg, signed=True,
                                 session=sess)
    assert len(_w_loads(s1)) > 0                      # cold: tiles fetched
    s2 = fabric.schedule_program(specs, 4, cfg=cfg, signed=True,
                                 session=sess)
    assert _w_loads(s2) == []                         # warm: all resident
    # activations are per-program payloads: still fetched every time
    assert any(ld.kind == "x" for r in s2.rounds for ld in r.loads)


def test_fused_qkv_warm_across_steps(rng):
    cfg = FabricConfig(n_blocks=8)
    sess = FabricSession(cfg)
    ws = [_ints(rng, (16, 8), 8) for _ in range(3)]
    for step in range(3):
        sess.begin_step()
        x = _ints(rng, (1, 16), 8)
        fabric.fabric_fused_matmul(x, ws, nbits=8, cfg=cfg, signed=True,
                                   names=("wq", "wk", "wv"), session=sess)
    traj = sess.trajectory()
    assert traj.w_fetches[0] > 0
    assert traj.w_fetches[1] == traj.w_fetches[2] == 0
    assert traj.steady_fetch_reduction > 1.0


def test_cold_session_plans_like_sessionless(rng):
    """The first program of a session with no KV reservations must be
    the sessionless plan exactly (mode map, homes, rounds)."""
    cfg = _grid()
    specs = (GemmSpec("a", 2, 20, 8), GemmSpec("b", 2, 20, 16))
    plain = fabric.schedule_program(specs, 4, cfg=cfg, signed=True)
    warm = fabric.schedule_program(specs, 4, cfg=cfg, signed=True,
                                   session=FabricSession(cfg))
    assert plain.modes == warm.modes
    assert plain.x_home == warm.x_home
    assert plain.w_home == warm.w_home
    assert len(plain.rounds) == len(warm.rounds)
    for rp, rw in zip(plain.rounds, warm.rounds):
        assert rp.tasks == rw.tasks
        assert [(ld.kind, ld.src, ld.dsts, ld.bits) for ld in rp.loads] \
            == [(ld.kind, ld.src, ld.dsts, ld.bits) for ld in rw.loads]


@pytest.mark.parametrize("nbits,dtype", [(4, None), (8, None),
                                         (8, "bf16")])
def test_bit_identity_vs_sessionless(rng, nbits, dtype):
    # default geometry: the bf16 fused-MAC program needs tall blocks
    cfg = FabricConfig(n_blocks=8)
    sess = FabricSession(cfg)
    K, N = 20, 12
    if dtype is None:
        w = _ints(rng, (K, N), nbits)
        xs = [_ints(rng, (2, K), nbits) for _ in range(3)]
    else:
        w = rng.normal(size=(K, N)).astype(np.float32)
        xs = [rng.normal(size=(2, K)).astype(np.float32) for _ in range(3)]
    for x in xs:
        sess.begin_step()
        out = fabric.fabric_matmul(x, w, nbits=nbits, cfg=cfg, signed=True,
                                   dtype=dtype, session=sess).out
        ref = fabric.fabric_matmul(x, w, nbits=nbits, cfg=cfg, signed=True,
                                   dtype=dtype).out
        np.testing.assert_array_equal(out, ref)
    assert sess.trajectory().w_fetches[-1] == 0


def test_lru_eviction_across_program_boundary_refetches(rng):
    """Weights too big to coexist in the resident maps evict each other
    across programs -- the returning weight is REFETCHED, not silently
    reused."""
    # rows=128/cols=8 -> 1024-bit blocks; each int8 weight below spans
    # ~4096 bits of tiles, so A and B cannot both stay resident
    cfg = _grid(min_compute_blocks=2)
    sess = FabricSession(cfg)
    sa = (GemmSpec("wa", 1, 16, 32),)
    sb = (GemmSpec("wb", 1, 16, 32),)
    fabric.schedule_program(sa, 8, cfg=cfg, signed=True, session=sess)
    s2 = fabric.schedule_program(sb, 8, cfg=cfg, signed=True, session=sess)
    assert len(_w_loads(s2)) > 0                     # B displaced A
    s3 = fabric.schedule_program(sa, 8, cfg=cfg, signed=True, session=sess)
    assert len(_w_loads(s3)) > 0                     # A had to come back


def test_session_reset_restores_cold(rng):
    cfg = _grid()
    sess = FabricSession(cfg)
    specs = (GemmSpec("w0", 1, 16, 8),)
    cold = len(_w_loads(fabric.schedule_program(specs, 4, cfg=cfg,
                                                signed=True, session=sess)))
    assert _w_loads(fabric.schedule_program(specs, 4, cfg=cfg, signed=True,
                                            session=sess)) == []
    sess.reset()
    assert sess.programs == 0 and sess.modes is None
    again = len(_w_loads(fabric.schedule_program(specs, 4, cfg=cfg,
                                                 signed=True,
                                                 session=sess)))
    assert again == cold


def test_session_grid_is_pinned():
    sess = FabricSession(_grid())
    fabric.schedule_program((GemmSpec("g", 1, 16, 8),), 4, cfg=_grid(),
                            signed=True, session=sess)
    with pytest.raises(ValueError, match="bound to grid"):
        fabric.schedule_program((GemmSpec("g", 1, 16, 8),), 4,
                                cfg=_grid(n_blocks=16), signed=True,
                                session=sess)


def test_cold_session_adopts_autotuned_grid(rng):
    """A cold, unpinned session may adopt a different cfg (the autotune
    handshake: search picks the split, the session binds to it)."""
    sess = FabricSession(_grid())
    other = _grid(min_compute_blocks=4)
    sched = fabric.schedule_program((GemmSpec("g", 1, 16, 8),), 4,
                                    cfg=other, signed=True, session=sess)
    assert sess.cfg == other and sched.cfg == other


# ---------------------------------------------------------------------------
# KV tiles: append-addressed, session-pinned, never LRU-evicted
# ---------------------------------------------------------------------------
def test_kv_append_delta_loads(rng):
    """A growing KV operand only moves the DELTA each step: holders of
    an earlier prefix fetch bits - seen, history is never refetched."""
    cfg = FabricConfig(n_blocks=8)
    hd, bits, window = 8, 8, 6
    sess = FabricSession(cfg)
    sess.reserve_kv("k", pos_bits=hd * bits, window=window)
    # the first program places the reservation (kv homes are assigned
    # during storage sizing); a warmup GEMM stands in for the QKV step
    fabric.schedule_program((GemmSpec("warmup", 1, 8, 8),), bits,
                            cfg=cfg, signed=True, session=sess)
    kcache = np.zeros((hd, 0), np.int64)
    kv_bits = []
    for t in range(1, window + 1):
        sess.begin_step()
        kcache = np.hstack([kcache, _ints(rng, (hd, 1), bits)])
        sess.kv_append("k")
        q = _ints(rng, (1, hd), bits)
        res = fabric.fabric_fused_matmul(
            q, (kcache,), nbits=bits, cfg=cfg, signed=True,
            specs=(GemmSpec("scores", 1, hd, t, kv="k", kv_axis="n"),),
            session=sess)
        np.testing.assert_array_equal(res.outs[0], q @ kcache)
        kv_bits.append(sess.steps[-1]["kv_fetch_bits"])
    # step 1 fetches one position; every later step only the new column
    assert kv_bits[0] == hd * bits
    assert all(b == hd * bits for b in kv_bits[1:])
    assert sess.kv_len("k") == window
    # the cache tile is pinned: it survives in some compute block's map
    assert any(kk[0] == "kv" for res in sess.resident.values()
               for kk in res)


def test_kv_axis_k_grows_along_contraction(rng):
    """kv_axis='k' (the AV cache): K grows per step, same delta math."""
    cfg = FabricConfig(n_blocks=8)
    hd, bits, window = 8, 8, 5
    sess = FabricSession(cfg)
    sess.reserve_kv("v", pos_bits=hd * bits, window=window)
    fabric.schedule_program((GemmSpec("warmup", 1, 8, 8),), bits,
                            cfg=cfg, signed=True, session=sess)
    vcache = np.zeros((0, hd), np.int64)
    for t in range(1, window + 1):
        sess.begin_step()
        vcache = np.vstack([vcache, _ints(rng, (1, hd), bits)])
        sess.kv_append("v")
        p = _ints(rng, (1, t), bits)
        res = fabric.fabric_fused_matmul(
            p, (vcache,), nbits=bits, cfg=cfg, signed=True,
            specs=(GemmSpec("av", 1, t, hd, kv="v", kv_axis="k"),),
            session=sess)
        np.testing.assert_array_equal(res.outs[0], p @ vcache)
    # steady state moves only the appended row, not the whole history
    assert sess.steps[-1]["kv_fetch_bits"] <= 2 * hd * bits


def test_kv_reservation_rules():
    cfg = FabricConfig(n_blocks=8)
    sess = FabricSession(cfg)
    sess.reserve_kv("k", pos_bits=64, window=4)
    with pytest.raises(ValueError, match="already reserved"):
        sess.reserve_kv("k", pos_bits=64, window=4)
    with pytest.raises(ValueError, match="degenerate"):
        sess.reserve_kv("z", pos_bits=0, window=4)
    with pytest.raises(ValueError, match="not placed"):
        sess.kv_append("k")                     # before the first program
    with pytest.raises(ValueError, match="not .* reserved|not reserved"):
        fabric.schedule_program(
            (GemmSpec("s", 1, 8, 1, kv="nope"),), 8, cfg=cfg,
            signed=True, session=sess)
    fabric.schedule_program((GemmSpec("g", 1, 8, 8),), 8, cfg=cfg,
                            signed=True, session=sess)
    with pytest.raises(ValueError, match="mode map is pinned"):
        sess.reserve_kv("v", pos_bits=64, window=4)
    assert sess.kv["k"]["home"] is not None     # placed by program 1
    for _ in range(4):
        sess.kv_append("k")
    with pytest.raises(ValueError, match="overflows"):
        sess.kv_append("k")


def test_kv_spec_validation():
    with pytest.raises(ValueError, match="kv_axis"):
        fabric.schedule_program(
            (GemmSpec("s", 1, 8, 1, kv="k", kv_axis="m"),), 8,
            cfg=FabricConfig(n_blocks=8), signed=True)


# ---------------------------------------------------------------------------
# Trajectory (core.costmodel.CostTrajectory)
# ---------------------------------------------------------------------------
def test_trajectory_cold_vs_steady(rng):
    cfg = _grid()
    sess = FabricSession(cfg)
    w = _ints(rng, (10, 64), 4)
    for _ in range(4):
        sess.begin_step()
        fabric.fabric_matmul(_ints(rng, (1, 10), 4), w, nbits=4, cfg=cfg,
                             signed=True, session=sess)
    traj = sess.trajectory()
    assert traj.steps == 4
    assert traj.cold_fetches > traj.steady_fetches
    assert traj.steady_fetch_reduction >= 5.0       # the gated shape
    assert traj.cold_energy_pj > traj.steady_energy_pj > 0
    assert traj.cold_overlapped_cycles > traj.steady_overlapped_cycles
    rep = traj.report()
    for key in ("per_step_fetches", "cold_fetches", "steady_fetches",
                "steady_fetch_reduction", "cold_energy_pj",
                "steady_energy_pj"):
        assert key in rep
    assert rep["per_step_fetches"][1:] == [1, 1, 1]


def test_trajectory_single_step_is_neutral():
    traj = costmodel.CostTrajectory(name="t", costs=(None,), fetches=(7,),
                                    fetch_bits=(100.0,), w_fetches=(3,))
    assert traj.steady_fetch_reduction == 1.0
    assert traj.steady_w_fetch_reduction == 1.0


def test_trajectory_zero_steady_weights_stays_finite():
    traj = costmodel.CostTrajectory(
        name="t", costs=(None, None, None), fetches=(9, 1, 1),
        fetch_bits=(0.0, 0.0, 0.0), w_fetches=(8, 0, 0))
    assert traj.steady_w_fetch_reduction == 8.0
    assert traj.steady_fetch_reduction == 9.0


def test_session_stats_shape(rng):
    cfg = _grid()
    sess = FabricSession(cfg)
    sess.begin_step()
    fabric.fabric_matmul(_ints(rng, (1, 10), 4), _ints(rng, (10, 8), 4),
                         nbits=4, cfg=cfg, signed=True, session=sess)
    st = sess.stats()
    assert st["programs"] == 1 and st["steps"] == 1
    assert st["resident_tiles"] > 0
    assert "trajectory" in st


# ---------------------------------------------------------------------------
# Attention block: QKV + scores + AV + out-proj on one session
# ---------------------------------------------------------------------------
def _oracle_decode(blk, xs):
    """Host int replay of FabricAttentionBlock with the SAME fixed
    scales -- the bit-exactness oracle."""
    hd = blk.hd
    kc = np.zeros((hd, 0), np.int64)
    vc = np.zeros((0, hd), np.int64)
    ys = []
    for x in xs:
        x = np.asarray(x, np.float32).reshape(1, -1)
        qx = blk._qfix(x, blk.sx)
        q = blk._qfix(qx @ blk._qwq * (blk.sx * blk.swq), blk.sq)
        k = blk._qfix(qx @ blk._qwk * (blk.sx * blk.swk), blk.sk)
        v = blk._qfix(qx @ blk._qwv * (blk.sx * blk.swv), blk.sv)
        kc = np.hstack([kc, k.T])
        vc = np.vstack([vc, v])
        s = (q @ kc) * (blk.sq * blk.sk * hd ** -0.5)
        e = np.exp(s - s.max(axis=-1, keepdims=True))
        p = blk._qfix(e / e.sum(axis=-1, keepdims=True), blk.sp)
        a = blk._qfix((p @ vc) * (blk.sp * blk.sv), blk.so)
        ys.append((a @ blk._qwo * (blk.so * blk.swo)).astype(np.float32))
    return ys


def test_attention_block_matches_host_oracle(rng):
    d, hd = 16, 8
    cfg = FabricConfig(n_blocks=8)
    wq, wk, wv = (rng.normal(size=(d, hd)).astype(np.float32) * 0.3
                  for _ in range(3))
    wo = rng.normal(size=(hd, d)).astype(np.float32) * 0.3
    blk = fabric.FabricAttentionBlock(wq, wk, wv, wo, cfg=cfg, bits=8,
                                      window=6)
    xs = [rng.normal(size=(d,)).astype(np.float32) for _ in range(4)]
    ys = [blk.decode_step(x)[0] for x in xs]
    # same fixed scales, same int ops -> bit-exact replay
    for y, ref in zip(ys, _oracle_decode(blk, xs)):
        np.testing.assert_array_equal(y, ref)

    traj = blk.session.trajectory()
    # weight-stationary: QKV + wo tiles fetched once, never again
    assert traj.w_fetches[0] > 0
    assert all(wf == 0 for wf in traj.w_fetches[1:])
    # the KV caches live on-fabric and grew in place
    kv = blk.session.stats()["kv"]
    assert kv["k"]["home"] >= 0 and kv["v"]["home"] >= 0
    assert kv["k"]["len"] == kv["v"]["len"] == 4
    assert blk.report()["trajectory"]["steady_fetch_reduction"] > 1.0


def test_attention_block_window_and_shapes(rng):
    d, hd = 8, 4
    wq = rng.normal(size=(d, hd)).astype(np.float32)
    with pytest.raises(ValueError, match="wo"):
        fabric.FabricAttentionBlock(wq, wq, wq, wq,
                                    cfg=FabricConfig(n_blocks=8))
    blk = fabric.FabricAttentionBlock(
        wq, wq, wq, rng.normal(size=(hd, d)).astype(np.float32),
        cfg=FabricConfig(n_blocks=8), window=1)
    blk.decode_step(rng.normal(size=(d,)).astype(np.float32))
    with pytest.raises(ValueError, match="window"):
        blk.decode_step(rng.normal(size=(d,)).astype(np.float32))


# ---------------------------------------------------------------------------
# Probe + PimConfig plumbing
# ---------------------------------------------------------------------------
def test_probe_with_session_bit_identical_and_reports(rng):
    ws = [rng.normal(size=(16, 8)).astype(np.float32) for _ in range(3)]
    cfg = FabricConfig(n_blocks=8)
    ps = fabric.FabricLinearProbe(ws, cfg=cfg, bits=8, max_steps=3,
                                  session=True)
    p0 = fabric.FabricLinearProbe(ws, cfg=cfg, bits=8, max_steps=3)
    for _ in range(3):
        x = rng.normal(size=(2, 16)).astype(np.float32)
        ys = ps.observe(x)
        y0 = p0.observe(x)
        for a, b in zip(ys, y0):
            np.testing.assert_array_equal(a, b)
    rep = ps.report()
    assert rep["session"]["steps"] == 3
    assert rep["session"]["per_step_w_fetches"][1:] == [0, 0]
    assert "session" not in p0.report()


def test_probe_session_survives_varying_batch(rng):
    """Serving with continuous batching recycles slots, so the GEMM's M
    (live-lane count) changes step to step.  Residency is per (tile,
    block): shrinking M or repeating one is free, and GROWING M only
    fetches incrementally -- the blocks the wider task spread newly
    assigns -- never a cold refetch.  Outputs stay bit-identical to a
    sessionless probe at every M."""
    ws = [rng.normal(size=(16, 8)).astype(np.float32) for _ in range(2)]
    cfg = FabricConfig(n_blocks=8)
    ps = fabric.FabricLinearProbe(ws, cfg=cfg, bits=8, max_steps=6,
                                  session=True)
    for m in (2, 1, 2, 3, 3, 1):
        x = rng.normal(size=(m, 16)).astype(np.float32)
        ys = ps.observe(x)
        y0 = fabric.FabricLinearProbe(ws, cfg=cfg, bits=8,
                                      max_steps=1).observe(x)
        for a, b in zip(ys, y0):
            assert a.shape[0] == m
            np.testing.assert_array_equal(a, b)
    rep_full = ps.report()
    assert rep_full["observed_m"] == [2, 1, 2, 3, 3, 1]
    rep = rep_full["session"]
    assert rep["steps"] == 6
    wf = rep["per_step_w_fetches"]
    assert wf[0] > 0                       # cold fetch at M=2
    assert wf[1] == wf[2] == 0             # shrink + repeat: warm
    assert 0 < wf[3] <= wf[0]              # grow to M=3: incremental only
    assert wf[4] == wf[5] == 0             # repeat + shrink: warm again


def test_fused_linear_apply_with_session(rng):
    import jax.numpy as jnp

    from repro.pim.linear import PimConfig, fused_linear_apply, pack_linear

    fcfg = FabricConfig(n_blocks=8)
    sess = FabricSession(fcfg)
    # pack_linear bit-plane packs along K: needs a multiple of 32
    packed = [pack_linear({"w": jnp.asarray(
        rng.normal(size=(32, 8)).astype(np.float32))},
        PimConfig(weight_bits=8)) for _ in range(2)]
    cfg_s = PimConfig(mode="fabric", weight_bits=8, act_bits=8,
                      fabric=fcfg, fabric_session=sess)
    cfg_0 = PimConfig(mode="fabric", weight_bits=8, act_bits=8, fabric=fcfg)
    assert hash(cfg_s) is not None              # frozen config stays usable
    for _ in range(2):
        sess.begin_step()
        x = jnp.asarray(rng.normal(size=(1, 32)).astype(np.float32))
        ys = fused_linear_apply(packed, x, cfg_s)
        y0 = fused_linear_apply(packed, x, cfg_0)
        for a, b in zip(ys, y0):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert sess.trajectory().w_fetches[1] == 0
