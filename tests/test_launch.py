"""Launcher machinery: HLO analysis parsing, sharding rules, and a
real (subprocess) dry-run cell."""

import json
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.launch import analysis
from repro.models.common import resolve_spec


HLO = """HloModule test, is_scheduled=true

%add (a: f32[], b: f32[]) -> f32[] {
  ROOT %r = f32[] add(f32[] %a, f32[] %b)
}

%body (p: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %ar = f32[128,256]{1,0} all-reduce(%x), replica_groups=[4,2]<=[8], to_apply=%add
  %ag = f32[256,256]{1,0} all-gather(f32[128,256] %ar), dimensions={0}
}

%cond (p: (s32[], f32[128,256])) -> pred[] {
  %c = s32[] constant(24)
  %cmp = pred[] compare(s32[] %i, s32[] %c), direction=LT
}

ENTRY %main (a: f32[128,256]) -> f32[128,256] {
  %w = (s32[], f32[128,256]) while(%init), condition=%cond, body=%body
  %ar2 = f32[64]{0} all-reduce(f32[64] %y), to_apply=%add
}
"""


def test_hlo_collective_parsing():
    comps = analysis.parse_computations(HLO)
    assert {"add", "body", "cond", "main"} <= set(comps)
    trips = analysis.while_trip_counts(HLO, comps)
    assert trips == {"body": 24}
    st = analysis.collective_bytes(HLO)
    # body: all-reduce 128*256*4 x2 factor x24 trips
    #       all-gather 256*256*4 x24
    # main: all-reduce 64*4 x2
    want = 128 * 256 * 4 * 2 * 24 + 256 * 256 * 4 * 24 + 64 * 4 * 2
    assert st.total_bytes == want, (st.total_bytes, want)
    assert analysis.scan_trip_multiplier(HLO) == 24


def test_roofline_terms():
    r = analysis.roofline(197e12 * 256, 819e9 * 256, 0.0, 256)
    assert abs(r["t_compute_s"] - 1.0) < 1e-9
    assert abs(r["t_memory_s"] - 1.0) < 1e-9
    assert r["dominant"] in ("compute", "memory")


def test_resolve_spec_divisibility_guard():
    mesh = jax.make_mesh((1,), ("model",))
    spec = resolve_spec(mesh, (14, 64), ("model", None))
    assert spec == P("model", None)   # 14 % 1 == 0
    # "batch" expands to present axes only; absent axes drop
    spec = resolve_spec(mesh, (8, 16), ("batch", "data"))
    assert spec == P(None, None)


@pytest.mark.slow     # spawns a subprocess that jit-compiles a model
def test_dryrun_cell_subprocess(tmp_path):
    """End-to-end dry-run of one real cell on the 256-chip mesh."""
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", "qwen2-0.5b", "--shape", "decode_32k",
           "--out", str(tmp_path)]
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(
        (tmp_path / "qwen2-0.5b__decode_32k__single.json").read_text())
    assert out["status"] == "ok"
    assert out["chips"] == 256
    assert out["collective_bytes"] >= 0
    assert out["memory_analysis"]["temp_size_in_bytes"] > 0
