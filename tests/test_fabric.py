"""Differential test harness for the fabric scheduler (docs/fabric.md).

Oracle layering, cheapest-to-richest:

1. **numpy** -- ground-truth integer arithmetic (``x @ w`` in int64);
2. **cram_matmul** -- the single-shot per-tile primitive (one program per
   tile, no grid, no residency);
3. **fabric** -- the scheduled block grid (mode allocation + rounds);
4. **pallas popcount** -- the TPU-native bit-plane kernel.

Every layer must produce the *same integers*: the arithmetic is exact at
every level, so any mismatch is a scheduling/packing bug, not tolerance.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import costmodel as cm
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.pim import cram, fabric
from repro.pim.fabric import FabricConfig

# small block geometry: tiny programs, shared compile cache across tests
ROWS, COLS = 128, 8


def _grid(n_blocks):
    return FabricConfig(n_blocks=n_blocks, rows=ROWS, cols=COLS)


def _signed_operands(rng, nbits, m, k, n):
    lo, hi = -(1 << (nbits - 1)), 1 << (nbits - 1)
    x = rng.integers(lo, hi, (m, k)).astype(np.int64)
    w = rng.integers(lo, hi, (k, n)).astype(np.int64)
    return x, w


# ---------------------------------------------------------------------------
# Differential matrix: fabric vs numpy across precision / shape / grid
# ---------------------------------------------------------------------------
_MATRIX = [
    # (nbits, n_blocks, (M, K, N)) -- K/N/M deliberately not tile multiples
    (4, 1, (3, 10, 11)),
    (4, 4, (3, 10, 11)),
    (4, 4, (1, 1, 1)),
    (4, 4, (2, 20, 16)),       # exact tile multiples (kt=10, cols=8)
    (4, 64, (5, 23, 17)),
    (8, 1, (2, 7, 5)),
    (8, 4, (2, 23, 5)),
    (8, 64, (3, 9, 10)),
]


@pytest.mark.parametrize("nbits,blocks,shape", _MATRIX,
                         ids=[f"int{n}-{b}blk-{'x'.join(map(str, s))}"
                              for n, b, s in _MATRIX])
def test_fabric_matches_numpy_signed(rng, nbits, blocks, shape):
    m, k, n = shape
    x, w = _signed_operands(rng, nbits, m, k, n)
    res = fabric.fabric_matmul(x, w, nbits=nbits, cfg=_grid(blocks),
                               signed=True)
    np.testing.assert_array_equal(res.out, x @ w)
    # the cost report is derived from the same executed IR
    assert res.cost.ops == m * k * n
    assert res.cost.energy_pj > 0 and res.cost.time_us > 0


@pytest.mark.parametrize("nbits", [4, 8])
def test_fabric_matches_numpy_unsigned_ragged(rng, nbits):
    x = rng.integers(0, 1 << nbits, (3, 13)).astype(np.uint64)
    w = rng.integers(0, 1 << nbits, (13, 11)).astype(np.uint64)
    res = fabric.fabric_matmul(x, w, nbits=nbits, cfg=_grid(4))
    np.testing.assert_array_equal(res.out, x.astype(np.int64)
                                  @ w.astype(np.int64))


def test_fabric_matches_cram_single_shot(rng):
    """Scheduled grid == the single-shot per-tile primitive."""
    x, w = _signed_operands(rng, 4, 3, 23, 11)
    via_cram = cram.cram_matmul(x, w, n=4, rows=ROWS, cols=COLS,
                                signed=True)
    via_fabric = fabric.fabric_matmul(x, w, nbits=4, cfg=_grid(4),
                                      signed=True).out
    np.testing.assert_array_equal(via_cram, via_fabric)


@pytest.mark.parametrize("nbits", [4, 8])
def test_fabric_matches_pallas_popcount(rng, nbits):
    """Fabric vs the Pallas bit-plane popcount kernel (K % 32 == 0)."""
    m, k, n = 4, 32, 8
    x, w = _signed_operands(rng, nbits, m, k, n)
    ap = kref.pack_bitplanes(jnp.asarray(x, jnp.int32), nbits, axis=1)
    wp = kref.pack_bitplanes(jnp.asarray(w, jnp.int32), nbits, axis=0)
    via_pallas = np.asarray(kops.popcount_matmul(ap, wp))
    via_fabric = fabric.fabric_matmul(x, w, nbits=nbits, cfg=_grid(4),
                                      signed=True).out
    np.testing.assert_array_equal(via_fabric, via_pallas)


# ---------------------------------------------------------------------------
# Schedule IR invariants
# ---------------------------------------------------------------------------
def test_schedule_structure_and_residency():
    sched = fabric.schedule_gemm(5, 23, 17, 4, cfg=_grid(8), signed=True)
    cfg = sched.cfg
    assert len(sched.modes) == cfg.n_blocks
    assert sched.n_compute >= cfg.min_compute_blocks
    assert sched.n_compute + sched.n_storage == cfg.n_blocks

    # storage capacity is never oversubscribed; homes are storage blocks
    used = {b: 0 for b, mode in enumerate(sched.modes) if mode == "storage"}
    for (g, ki, ni), home in sched.w_home.items():
        kw = min(23, (ki + 1) * sched.kt) - ki * sched.kt
        nw = min(17, (ni + 1) * cfg.cols) - ni * cfg.cols
        if home >= 0:
            assert sched.modes[home] == "storage"
            used[home] += kw * nw * sched.nbits
    for m, home in enumerate(sched.x_home):
        if home >= 0:
            assert sched.modes[home] == "storage"
            used[home] += 23 * sched.nbits
    assert all(u <= cfg.block_bits for u in used.values())

    # every (m, k-tile, n-tile) unit appears exactly once, on a compute slot
    seen = set()
    for rnd in sched.rounds:
        assert len(rnd.tasks) <= sched.n_compute
        for t in rnd.tasks:
            assert sched.modes[t.block] == "compute"
            assert (t.m, t.k0, t.n0) not in seen
            seen.add((t.m, t.k0, t.n0))
    import math
    assert len(seen) == 5 * math.ceil(23 / sched.kt) * math.ceil(17 / 8)


def test_schedule_single_block_grid_spills_everything():
    sched = fabric.schedule_gemm(2, 7, 5, 8, cfg=_grid(1))
    assert sched.n_storage == 0 and sched.n_compute == 1
    assert all(h == -1 for h in sched.x_home)
    assert all(h == -1 for h in sched.w_home.values())
    cost = fabric.schedule_cost(sched)
    assert cost.spill_bits_moved > 0 and cost.fabric_bits_moved > 0


def test_fabric_rejects_bad_operands(rng):
    x = np.full((2, 3), 8, np.int64)          # out of int4 signed range
    w = np.zeros((3, 2), np.int64)
    with pytest.raises(ValueError, match="signed operands"):
        fabric.fabric_matmul(x, w, nbits=4, cfg=_grid(2), signed=True)
    sched = fabric.schedule_gemm(2, 3, 2, 4, cfg=_grid(2))
    with pytest.raises(ValueError, match="do not match"):
        fabric.execute_schedule(sched, np.zeros((9, 9), np.uint64),
                                np.zeros((9, 9), np.uint64))


# ---------------------------------------------------------------------------
# Attention through the scheduler (acceptance criterion: score matmul
# end-to-end with a costmodel-derived report)
# ---------------------------------------------------------------------------
def test_attention_scores_end_to_end(rng):
    B, Sq, Sk, H, hd = 1, 5, 7, 2, 16
    q = rng.normal(size=(B, Sq, H, hd)).astype(np.float32)
    k = rng.normal(size=(B, Sk, H, hd)).astype(np.float32)
    scores, int_scores, costs = fabric.fabric_attention_scores(
        q, k, cfg=_grid(4), bits=8)

    # integer scores are bit-exact vs numpy on the quantized operands
    qq, sq = fabric._quantize_sym(q, 8)
    qk, sk = fabric._quantize_sym(k, 8)
    want = np.einsum("bqhd,bchd->bqhc", qq, qk)
    np.testing.assert_array_equal(int_scores, want)

    # float scores approximate the fp32 attention scores (int8 quant)
    ref = np.einsum("bqhd,bchd->bqhc", q, k) * hd ** -0.5
    assert np.abs(scores - ref).max() < 0.05 * max(np.abs(ref).max(), 1)

    # cost report: energy pJ / time us roll up through core.costmodel
    total = fabric.combine_costs("attn_scores", costs)
    assert total.ops == B * H * Sq * Sk * hd
    rep = total.report()
    assert rep["energy_pj"] > 0 and rep["time_us"] > 0
    assert rep["energy_pj"] == pytest.approx(
        rep["energy_compute_pj"] + rep["energy_storage_pj"]
        + rep["energy_wire_pj"], rel=1e-6)


def test_attention_value_matmul_through_fabric(rng):
    """The second attention GEMM (probs @ V) also runs on the grid:
    probs are unsigned (softmax output), V is signed."""
    Sq, Sk, hd = 4, 6, 8
    p = rng.random((Sq, Sk)).astype(np.float32)
    p /= p.sum(axis=-1, keepdims=True)
    v = rng.normal(size=(Sk, hd)).astype(np.float32)
    qp = np.clip(np.round(p * 255), 0, 255).astype(np.int64)   # uint8 probs
    qv, sv = fabric._quantize_sym(v, 8)
    # both operands must share the idot geometry: run unsigned with the
    # signed V biased through the schedule's zero-point algebra
    res = fabric.fabric_matmul(qp, qv, nbits=9, cfg=_grid(4), signed=True)
    np.testing.assert_array_equal(res.out, qp @ qv)


# ---------------------------------------------------------------------------
# PIM linear backend + serve probe
# ---------------------------------------------------------------------------
def test_linear_fabric_backend_equals_ref():
    import jax

    from repro.pim import PimConfig, linear_apply, linear_init, pack_linear

    cfgf = PimConfig(mode="fabric", weight_bits=4, fabric=_grid(6))
    cfgr = PimConfig(mode="ref", weight_bits=4)
    dense = linear_init(jax.random.PRNGKey(0), 32, 8, cfgr)
    packed = pack_linear(dense, cfgr)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 32), jnp.bfloat16)
    yr = linear_apply(packed, x, cfgr)
    yf = linear_apply(packed, x, cfgf)
    np.testing.assert_array_equal(np.asarray(yr, np.float32),
                                  np.asarray(yf, np.float32))


class _StubModel:
    """Minimal model exposing the ServeEngine surface (fast probe test)."""

    def __init__(self, vocab=11, d=16):
        rng = np.random.default_rng(0)
        self.embed = rng.normal(size=(vocab, d)).astype(np.float32)

    def init_cache(self, b, cap):
        return {"n": jnp.zeros((b,), jnp.int32)}

    def _embed(self, params, tokens):
        return jnp.asarray(self.embed)[tokens]

    def prefill(self, params, tokens, capacity=None):
        b, s = tokens.shape
        logits = jnp.ones((b, s, self.embed.shape[0]))
        return logits, {"n": jnp.zeros((1,), jnp.int32)}

    def decode_step(self, params, caches, tokens, pos):
        b = tokens.shape[0]
        logits = jnp.ones((b, 1, self.embed.shape[0]))
        return logits, caches


def test_serve_engine_fabric_probe(rng):
    from repro.pim.fabric import FabricLinearProbe
    from repro.serve.engine import Request, ServeEngine

    model = _StubModel()
    w = rng.normal(size=(16, 6)).astype(np.float32)
    probe = FabricLinearProbe(w, cfg=_grid(4), bits=8, max_steps=2)
    eng = ServeEngine(model, params={}, batch_slots=2, capacity=8,
                      fabric_probe=probe)
    eng.add(Request(rid=0, prompt=np.asarray([1, 2, 3], np.int32),
                    max_new=4))
    done = eng.run()
    assert len(done) == 1

    rep = eng.fabric_report()
    assert rep is not None and rep["energy_pj"] > 0
    assert len(probe.costs) == 2                     # capped at max_steps
    # probe output == quantized matmul of the live embeddings; with one
    # request in a 2-slot engine the probe sees M=1 -- only ACTIVE
    # lanes, never the idle slot's stale token
    y = probe.outputs[0]
    assert y.shape == (1, 6) and np.isfinite(y).all()
    assert rep["observed_m"] == [1, 1]


def test_serve_engine_without_probe_reports_none():
    from repro.serve.engine import ServeEngine

    eng = ServeEngine(_StubModel(), params={}, batch_slots=1, capacity=8)
    assert eng.fabric_report() is None


# ---------------------------------------------------------------------------
# cram boundary behaviour, example-based (the same edges the hypothesis
# suite in test_fabric_property.py fuzzes -- these run even without
# hypothesis installed)
# ---------------------------------------------------------------------------
def test_cram_signed_regression(rng):
    """Regression for the signed two's-complement offset path: exact over
    the full signed range, including the asymmetric minimum."""
    for n in (4, 8):
        lo, hi = -(1 << (n - 1)), 1 << (n - 1)
        x = rng.integers(lo, hi, (3, 9)).astype(np.int64)
        w = rng.integers(lo, hi, (9, 5)).astype(np.int64)
        x.flat[0] = lo                              # asymmetric extreme
        w.flat[0] = hi - 1
        got = cram.cram_matmul(x, w, n=n, rows=ROWS, cols=COLS, signed=True)
        np.testing.assert_array_equal(got, x @ w)
        d = cram.cram_dot(w, w, n, rows=ROWS, signed=True)
        np.testing.assert_array_equal(d, (w * w).sum(axis=0))


def test_cram_wide_precision_acc_clamp(rng):
    """int16 regression: idot's capacity exceeds what the 32-bit
    accumulator can hold exactly, so the K-tiling must clamp
    (cram.idot_tile) -- full-capacity max operands used to wrap."""
    assert cram.idot_tile(16, 512) < cram.idot_geometry(16, 512)
    T = cram.idot_geometry(16, ROWS)          # unclamped capacity
    a = np.full((T, 2), (1 << 16) - 1, np.uint64)
    got = cram.cram_dot(a, a, 16, rows=ROWS)
    np.testing.assert_array_equal(got, (a * a).sum(axis=0))


def test_cram_dot_capacity_edges(rng):
    """K at exact idot tuple capacity -1 / exact / +1 (the +1 case tiles
    into a second program launch), with operands at 2^n - 1."""
    for n in (4, 8):
        cap = cram.idot_geometry(n, ROWS)
        for T in (cap - 1, cap, cap + 1):
            a = rng.integers(0, 1 << n, (T, 3)).astype(np.uint64)
            b = rng.integers(0, 1 << n, (T, 3)).astype(np.uint64)
            a[0] = b[0] = (1 << n) - 1
            got = cram.cram_dot(a, b, n, rows=ROWS)
            np.testing.assert_array_equal(got, (a * b).sum(axis=0))
