"""Paged-KV serve engine: page lifecycle, admission policy, chunked
prefill, preemption/resume, and the PR-10 bugfix regressions.

Stub model: logits are a pure function of the *input token and its
position* (``next == (7*t + 3 + 2*pos) % vocab``), so slot mixups,
position drift after a resume, and chunked-prefill indexing errors all
change visible tokens instead of hiding in argmax-of-ones.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.serve.engine import Request, ServeEngine
from repro.serve.kv import PagedKV
from repro.serve.scheduler import Scheduler, SchedulerConfig

VOCAB = 32


def _f(t, p):
    return (7 * t + 3 + 2 * p) % VOCAB


def _chain(seq, max_new):
    """Reference greedy chain for :class:`_CountModel`."""
    L = len(seq)
    out = [_f(int(seq[-1]), L - 1)]
    while len(out) < max_new:
        out.append(_f(out[-1], L + len(out) - 1))
    return out


class _CountModel:
    """Next-token logits = one-hot of ``_f(input token, position)``.

    Position-dependence makes chunked prefill and preemption-resume
    *observable*: a lane resumed at the wrong position, or a streamed
    prompt fed at a shifted index, produces a different token chain.
    """

    def __init__(self, vocab=VOCAB, d=8):
        self.vocab = vocab
        rng = np.random.default_rng(0)
        self.embed = rng.normal(size=(vocab, d)).astype(np.float32)

    def init_cache(self, b, cap):
        return {"n": jnp.zeros((b,), jnp.int32)}

    def _embed(self, params, tokens):
        return jnp.asarray(self.embed)[tokens]

    def prefill(self, params, tokens, capacity=None):
        b, s = tokens.shape
        posn = jnp.arange(s, dtype=jnp.int32)[None, :]
        logits = jax.nn.one_hot((7 * tokens + 3 + 2 * posn) % self.vocab,
                                self.vocab)
        return logits, {"n": jnp.full((b,), s, jnp.int32)}

    def decode_step(self, params, caches, tokens, pos):
        logits = jax.nn.one_hot(
            (7 * tokens + 3 + 2 * pos[:, None]) % self.vocab, self.vocab)
        return logits, caches


def _engine(**kw):
    return ServeEngine(_CountModel(), params={},
                       batch_slots=kw.pop("B", 2),
                       capacity=kw.pop("capacity", 32), **kw)


def _req(rid, plen, max_new=2, **kw):
    prompt = ((np.arange(plen) * 5 + rid) % VOCAB).astype(np.int32)
    return Request(rid=rid, prompt=prompt, max_new=max_new, **kw)


# ---------------------------------------------------------------------------
# PagedKV unit behaviour
# ---------------------------------------------------------------------------
def test_kv_pages_for_and_capacity():
    kv = PagedKV(num_pages=4, page_size=4)
    assert [kv.pages_for(n) for n in (1, 4, 5, 8, 9)] == [1, 1, 2, 2, 3]
    assert kv.capacity_tokens == 16
    assert kv.can_ever_fit(16) and not kv.can_ever_fit(17)


def test_kv_alloc_append_free_lifecycle():
    kv = PagedKV(num_pages=3, page_size=4)
    assert kv.alloc(0, 4)                     # 1 page
    assert kv.free_pages == 2
    assert kv.append(0)                       # 5 tokens -> 2 pages
    assert kv.used_pages == 2
    assert kv.alloc(1, 3)                     # 3rd page
    assert kv.free_pages == 0
    assert kv.append(1)                       # 4 tokens: same page
    assert kv.used_pages == 3
    assert not kv.append(1)                   # 5 tokens: pool dry
    assert kv.lens[1] == 4                    # failed append changed nothing
    assert kv.stats["failed_appends"] == 1
    assert kv.free(0) == 2
    assert kv.append(1)                       # retries succeed after free
    kv.free(1)
    kv.assert_empty()
    assert kv.stats["allocs"] == kv.stats["frees"] == 2


def test_kv_failed_alloc_leaves_state_clean():
    kv = PagedKV(num_pages=2, page_size=4)
    assert kv.alloc(7, 8)                     # both pages
    assert not kv.alloc(8, 1)
    assert 8 not in kv.tables and kv.free_pages == 0
    with pytest.raises(KeyError):
        kv.alloc(7, 1)                        # double admit is a bug
    kv.free(7)
    kv.assert_empty()


def test_kv_leak_is_loud():
    kv = PagedKV(num_pages=2, page_size=4)
    kv.alloc(3, 4)
    with pytest.raises(AssertionError, match="leaked"):
        kv.assert_empty()


# ---------------------------------------------------------------------------
# Bugfix regression 1: long prompts reject instead of crashing
# ---------------------------------------------------------------------------
def test_prompt_longer_than_capacity_is_rejected_not_crashed():
    """The old engine raised a numpy broadcast ValueError at
    ``padded[:plen]`` for plen = capacity + 1."""
    eng = _engine(B=1, capacity=16)
    eng.add(_req(0, plen=17, max_new=1))      # capacity + 1
    done = eng.run()                          # must not raise
    assert done == []
    assert eng.stats["rejected"] == 1
    assert len(eng.rejected) == 1
    assert eng.rejected[0].status == "rejected"
    assert eng.rejected[0].out == []
    eng.kv.assert_empty()


def test_prompt_plus_budget_beyond_capacity_is_rejected():
    """Admission is strict: prompt + max_new must fit the slot (no
    silent ring wraparound)."""
    eng = _engine(B=1, capacity=16)
    eng.add(_req(0, plen=12, max_new=8))      # 20 > 16
    assert eng.run() == [] and eng.stats["rejected"] == 1
    # the boundary case fits
    eng.add(_req(1, plen=12, max_new=4))      # 16 == 16
    done = eng.run()
    assert len(done) == 1 and len(done[0].out) == 4


def test_rejected_requests_do_not_block_the_queue():
    eng = _engine(B=1, capacity=16)
    eng.add(_req(0, plen=17, max_new=1))
    eng.add(_req(1, plen=4, max_new=2))
    done = eng.run()
    assert [r.rid for r in done] == [1]
    assert done[0].out == _chain(done[0].prompt, 2)
    assert eng.stats["rejected"] == 1


def test_long_prompt_truncate_policy():
    eng = _engine(B=1, capacity=16, long_prompt="truncate")
    req = _req(0, plen=20, max_new=2)
    full_prompt = req.prompt.copy()
    eng.add(req)
    done = eng.run()
    assert len(done) == 1 and done[0].truncated
    assert eng.stats["truncated"] == 1 and eng.stats["rejected"] == 0
    limit = 16 - 2                            # capacity - max_new
    assert len(done[0].prompt) == limit
    assert done[0].out == _chain(full_prompt[:limit], 2)


# ---------------------------------------------------------------------------
# Bugfix regression 2: freed slots backfill in the same step
# ---------------------------------------------------------------------------
def test_freed_slot_serves_in_the_same_step():
    """With a full queue, the active-slot count never drops while work
    remains: retirement backfills before the step returns."""
    eng = _engine(B=2)
    for rid in range(6):
        eng.add(_req(rid, plen=2, max_new=2))
    while eng.queue or any(s is not None for s in eng.slots):
        eng.step()
        work_remains = bool(eng.queue)
        active = sum(1 for s in eng.slots if s is not None)
        if work_remains:
            assert active == eng.B, \
                f"slot sat idle with {len(eng.queue)} queued"
    assert eng.stats["admitted"] == 6


def test_backfill_keeps_fifo_order_and_chains():
    eng = _engine(B=2)
    for rid in range(5):
        eng.add(_req(rid, plen=3, max_new=2))
    done = eng.run()
    assert [r.rid for r in done] == [0, 1, 2, 3, 4]
    for r in done:
        assert r.out == _chain(r.prompt, 2)


# ---------------------------------------------------------------------------
# Bugfix regression 3: unified accounting epilogue
# ---------------------------------------------------------------------------
def test_admit_only_step_counts_accounting():
    """A step whose only work was a prefill (max_new=1: no decode ever
    runs) still counts the step, advances the sampling counter, and
    checks the deadline -- the old early return skipped all three."""
    eng = _engine(B=1, step_deadline_ms=0.0)
    eng.add(_req(0, plen=4, max_new=1))
    done = eng.step()
    assert len(done) == 1 and done[0].out == _chain(done[0].prompt, 1)
    assert eng.stats["steps"] == 1
    assert eng.stats["deadline_misses"] == 1
    assert eng._step_count == 1


def test_idle_step_still_counts_nothing():
    eng = _engine(B=1)
    assert eng.step() == []
    assert eng.stats["steps"] == 0 and eng._step_count == 0


# ---------------------------------------------------------------------------
# Bugfix regression 3b: the probe sees only ACTIVE lanes
# ---------------------------------------------------------------------------
class _ShapeProbe:
    done = False
    faults = None
    escaped_outputs = 0

    def __init__(self):
        self.shapes = []

    def observe(self, x):
        self.shapes.append(tuple(np.asarray(x).shape))

    def observe_ref(self, x):                 # pragma: no cover
        return None

    def report(self):                         # pragma: no cover
        return {}


def test_probe_observes_only_active_lanes():
    """B=4 engine with 2 requests: the probe must see M=2 activations,
    never the stale token embeddings of the 2 empty slots."""
    probe = _ShapeProbe()
    eng = _engine(B=4, fabric_probe=probe)
    eng.add(_req(0, plen=2, max_new=3))
    eng.add(_req(1, plen=2, max_new=3))
    eng.run()
    assert probe.shapes, "probe never observed"
    assert all(s[0] == 2 for s in probe.shapes), probe.shapes


def test_probe_lane_count_tracks_retirement():
    """As requests finish, the observed M shrinks with the live batch."""
    probe = _ShapeProbe()
    eng = _engine(B=2, fabric_probe=probe)
    eng.add(_req(0, plen=2, max_new=4))
    eng.add(_req(1, plen=2, max_new=2))
    eng.run()
    ms = [s[0] for s in probe.shapes]
    assert ms[0] == 2 and ms[-1] == 1         # r1 retires first


# ---------------------------------------------------------------------------
# Page lifecycle through the engine
# ---------------------------------------------------------------------------
def test_no_leaked_pages_after_run():
    eng = _engine(B=2, capacity=16, page_size=4)
    for rid in range(7):
        eng.add(_req(rid, plen=3 + rid % 5, max_new=1 + rid % 3))
    done = eng.run()
    assert len(done) == 7
    eng.kv.assert_empty()
    rep = eng.kv_report()
    assert rep["allocs"] == rep["frees"] == 7
    assert rep["pages_alloc"] == rep["pages_freed"]
    assert rep["high_water_pages"] <= rep["num_pages"]


# ---------------------------------------------------------------------------
# Preemption + resume
# ---------------------------------------------------------------------------
def _preemption_engine():
    # pool of 4x4-token pages shared by 2 slots: two 4-prompt/8-new
    # requests need 3 pages each at peak (6 > 4) -> preemption
    return _engine(B=2, capacity=16, page_size=4, num_pages=4)


def test_preemption_resume_token_bit_identity():
    reqs = [_req(0, plen=4, max_new=8), _req(1, plen=4, max_new=8)]
    baseline = {r.rid: _chain(r.prompt, 8) for r in reqs}

    eng = _preemption_engine()
    for r in reqs:
        eng.add(r)
    done = eng.run()
    assert eng.stats["preemptions"] >= 1
    assert eng.stats["resumes"] >= 1
    assert len(done) == 2
    for r in done:
        assert r.out == baseline[r.rid], \
            f"rid {r.rid} diverged after preemption"
    pre = [r for r in done if r.preemptions]
    assert pre and all(r.t_done is not None for r in done)
    eng.kv.assert_empty()


def test_preemption_victim_is_last_admitted():
    reqs = [_req(0, plen=4, max_new=8), _req(1, plen=4, max_new=8)]
    eng = _preemption_engine()
    for r in reqs:
        eng.add(r)
    done = eng.run()
    by_rid = {r.rid: r for r in done}
    assert by_rid[1].preemptions >= 1         # FIFO: last admitted
    assert by_rid[0].preemptions == 0


def test_unpreempted_run_matches_roomy_pool():
    """Same workload with a roomy pool: no preemptions, same chains."""
    reqs = [_req(0, plen=4, max_new=8), _req(1, plen=4, max_new=8)]
    eng = _engine(B=2, capacity=16, page_size=4, num_pages=8)
    for r in reqs:
        eng.add(r)
    done = eng.run()
    assert eng.stats["preemptions"] == 0
    for r in done:
        assert r.out == _chain(r.prompt, 8)


# ---------------------------------------------------------------------------
# Chunked prefill
# ---------------------------------------------------------------------------
def test_chunked_prefill_token_identity():
    """Chunk-streamed prefill must generate the same chain as a whole
    prefill -- including the first token, produced from the logits of
    the REAL last prompt token."""
    whole = _engine(B=1)
    chunked = _engine(B=1, prefill_chunk=4)
    for eng in (whole, chunked):
        eng.add(_req(0, plen=12, max_new=4))
    dw, dc = whole.run(), chunked.run()
    assert dw[0].out == dc[0].out == _chain(dw[0].prompt, 4)
    assert chunked.stats["stream_prefill_tokens"] == 8   # 12 - chunk 4
    assert whole.stats["stream_prefill_tokens"] == 0


def test_chunked_prefill_interleaves_with_decode():
    """A long streaming prompt must not stall the other lane's decode:
    the short request makes one token of progress every step."""
    eng = _engine(B=2, prefill_chunk=2)
    long_req = _req(0, plen=10, max_new=2)
    short_req = _req(1, plen=2, max_new=8)
    eng.add(long_req)
    eng.add(short_req)
    eng.step()                                # both admitted
    while long_req.status == "prefill":
        before = len(short_req.out)
        eng.step()
        if short_req.status != "done":
            assert len(short_req.out) == before + 1, \
                "decode lane stalled behind a streaming prefill"
    done = eng.run()
    assert {r.rid for r in done} | {0, 1} == {0, 1}
    assert long_req.out == _chain(long_req.prompt, 2)
    assert short_req.out == _chain(short_req.prompt, 8)


def test_chunked_prefill_pages_grow_with_the_stream():
    eng = _engine(B=1, capacity=32, page_size=4, prefill_chunk=4)
    req = _req(0, plen=12, max_new=2)
    eng.add(req)
    # the admitting step also streams one token (admit-then-decode in
    # the same step): 4 prefilled + 1 streamed = 5 tokens -> 2 pages
    eng.step()
    assert eng.kv.lens[0] == 5 and eng.kv.used_pages == 2
    growth = [eng.kv.used_pages]
    while not req.done:
        eng.step()
        if eng.kv.held(0):
            growth.append(eng.kv.used_pages)
    assert growth == sorted(growth)           # pages only ever grow
    # peak residency: 12 prompt tokens + 1 generated-token KV write
    # (the final token is sampled but never written back)
    assert eng.kv.stats["high_water_pages"] == eng.kv.pages_for(13)
    eng.kv.assert_empty()


# ---------------------------------------------------------------------------
# Deadline-aware admission
# ---------------------------------------------------------------------------
def test_deadline_admission_orders_by_slo():
    eng = _engine(B=1, admission="deadline")
    eng.add(_req(0, plen=2, max_new=2))                      # no SLO
    eng.add(_req(1, plen=2, max_new=2, deadline_ms=500.0))
    eng.add(_req(2, plen=2, max_new=2, deadline_ms=10.0))
    done = eng.run()
    assert [r.rid for r in done] == [2, 1, 0]


def test_fifo_admission_ignores_deadlines():
    eng = _engine(B=1, admission="fifo")
    eng.add(_req(0, plen=2, max_new=2))
    eng.add(_req(1, plen=2, max_new=2, deadline_ms=1.0))
    done = eng.run()
    assert [r.rid for r in done] == [0, 1]


def test_deadline_scheduler_victim_is_latest_deadline():
    kv = PagedKV(num_pages=8, page_size=4)
    sched = Scheduler(SchedulerConfig(admission="deadline"), kv, 64)
    a = _req(0, plen=2, deadline_ms=10.0)
    b = _req(1, plen=2, deadline_ms=900.0)
    c = _req(2, plen=2)                       # no SLO = latest
    for seq, r in enumerate((a, b, c)):
        r._admit_seq = seq
    assert sched.pick_victim([a, b, c]) is c
    assert sched.pick_victim([a, b]) is b
    assert sched.pick_victim([a, b], protect=b) is a


# ---------------------------------------------------------------------------
# Latency accounting
# ---------------------------------------------------------------------------
def test_request_timestamps_are_monotone():
    eng = _engine(B=1)
    eng.add(_req(0, plen=4, max_new=3))
    done = eng.run()
    r = done[0]
    assert r.t_enqueue <= r.t_admit <= r.t_first <= r.t_done
    assert r.queue_ms() >= 0 and r.ttft_ms() > 0
    assert r.ms_per_token() is not None and r.ms_per_token() >= 0


# ---------------------------------------------------------------------------
# Real-model chunked prefill (slow tier)
# ---------------------------------------------------------------------------
@pytest.mark.slow     # LM prefill+decode compile: ~20s
def test_chunked_prefill_matches_whole_on_real_model():
    from repro import configs
    from repro.models.model import LM

    cfg = configs.get_config("qwen2-0.5b", smoke=True)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = np.asarray(
        np.random.default_rng(3).integers(0, cfg.vocab, 12), np.int32)

    outs = []
    for chunk in (None, 8):
        eng = ServeEngine(model, params, batch_slots=2, capacity=32,
                          prefill_chunk=chunk)
        eng.add(Request(rid=0, prompt=prompt.copy(), max_new=5))
        done = eng.run()
        outs.append(done[0].out)
    assert outs[0] == outs[1], \
        "chunk-streamed prefill diverged from whole prefill"
