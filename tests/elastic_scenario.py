"""Elastic-restart scenario, run under 4 fake devices by
test_train.py::test_elastic_restart_subprocess.

Phase 1: train 6 steps on a (data=2, model=2) mesh, checkpoint.
Phase 2: "lose" half the data-parallel groups -> rebuild on (1, 2),
restore, continue to step 10.  The global batch and RNG counters are
unchanged, so the post-restart loss sequence must equal a reference run
that never failed.
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ["JAX_PLATFORMS"] = "cpu"

import tempfile

import numpy as np

import jax

from repro import configs
from repro.launch.mesh import make_mesh
from repro.launch.sharding import batch_sharding, params_sharding
from repro.models.model import LM
from repro.train import checkpoint as ckpt
from repro.train import data as data_mod
from repro.train import optimizer as opt_mod
from repro.train.step import make_train_step


def run_steps(mesh, model, params, opt_state, pipe, opt_cfg, lo, hi):
    step_fn = make_train_step(model, opt_cfg)
    losses = []
    with mesh:
        p_shard = params_sharding(params, mesh)
        params = jax.device_put(params, p_shard)
        jitted = jax.jit(step_fn)
        for s in range(lo, hi):
            batch = pipe.batch(s)
            batch = jax.device_put(batch, batch_sharding(batch, mesh))
            params, opt_state, m = jitted(params, opt_state, batch)
            losses.append(float(m["loss"]))
    return params, opt_state, losses


def main():
    cfg = configs.get_config("qwen2-0.5b", smoke=True)
    model = LM(cfg)
    opt_cfg = opt_mod.OptConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    pipe = data_mod.Pipeline(data_mod.DataConfig(
        global_batch=4, seq_len=16, vocab=cfg.vocab))

    params0 = model.init(jax.random.PRNGKey(0))
    opt0 = opt_mod.init(params0, opt_cfg)

    # reference: 10 uninterrupted steps on the big mesh
    _, _, ref_losses = run_steps(make_mesh(2, 2), model, params0, opt0,
                                 pipe, opt_cfg, 0, 10)

    # phase 1: 6 steps on (2, 2), checkpoint
    tmp = tempfile.mkdtemp(prefix="elastic_")
    params, opt_state, l1 = run_steps(make_mesh(2, 2), model, params0,
                                      opt0, pipe, opt_cfg, 0, 6)
    ckpt.save(tmp, 6, {"params": params, "opt": opt_state})

    # phase 2: node loss -> (1, 2) mesh, restore, continue
    like = {"params": params0, "opt": opt0}
    tree, meta = ckpt.restore(tmp, like)
    assert meta["step"] == 6
    _, _, l2 = run_steps(make_mesh(1, 2), model, tree["params"],
                         tree["opt"], pipe, opt_cfg, 6, 10)

    got = l1 + l2
    err = max(abs(a - b) for a, b in zip(got, ref_losses))
    assert err < 2e-2, (got, ref_losses)
    print(f"ELASTIC_OK max_loss_delta={err:.5f}")


if __name__ == "__main__":
    main()
