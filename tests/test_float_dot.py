"""Float fused-MAC (float_dot) golden tier.

The bf16 column of the paper's dot-product evaluation: a differential
matrix of the ``float_dot`` engine program against the numpy FTZ+RTZ
fused-MAC reference (``repro.core.ref.float_dot``) across bf16 / fp16 /
fp8, K at capacity boundaries, adversarial operand classes, and
executor bit-identity -- plus golden cycle/footprint pins, lane-plan
assertions for the compiler extension (complementary-predication
coverage + copy/fill-run batching), and example-based bodies of the
rounding-edge properties fuzzed in ``test_fabric_property.py`` (they
run here even without hypothesis).

Semantics under test (docs/engine.md "float MAC microcode"): per tuple
the product is rounded to fmt exactly as ``float_mul``, widened by
``ACC_GUARD`` zero guard bits, and added to a running accumulator with
the ``float_add`` pipeline at the widened format; the final
normalize/round RTZ-truncates the guard bits and flushes a zero
exponent.  NOT IEEE-754: no round-to-nearest, no subnormals, no
inf/nan, and accumulation order matters.
"""

import numpy as np
import pytest

from repro.core import compiler, engine, floatprog, harness, isa, ref
from repro.core.floatprog import ACC_GUARD, BF16, FP16, FP8_E4M3
from repro.pim import cram

FMTS = {"bf16": BF16, "fp16": FP16, "fp8": FP8_E4M3}
COLS = 8


def _bits(rng, fmt, shape, elo=None, ehi=None, zero_p=0.15):
    """Random fmt bit patterns in a (default mid-range) exponent band."""
    eb, m = fmt.ebits, fmt.mbits
    emax = (1 << eb) - 1
    elo = max(1, emax // 3) if elo is None else elo
    ehi = (2 * emax // 3) if ehi is None else ehi
    s = rng.integers(0, 2, shape).astype(np.uint32)
    e = rng.integers(elo, max(elo + 1, ehi), shape).astype(np.uint32)
    mm = rng.integers(0, 1 << m, shape).astype(np.uint32)
    bits = (s << (eb + m)) | (e << m) | mm
    return np.where(rng.random(shape) < zero_p, 0, bits).astype(np.uint64)


def _run_fdot(fmt, a, b, executor="scan", rows=512):
    prog, lay = floatprog.float_dot(fmt, rows=rows, tuples=a.shape[0])
    arr = harness.run_program(prog, lay, {"a": a, "b": b}, a.shape[1],
                              executor=executor)
    return floatprog.fdot_result(arr, fmt), floatprog.fdot_acc(arr, fmt)


# ---------------------------------------------------------------------------
# Differential matrix: program == numpy FTZ+RTZ reference, bit for bit
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", sorted(FMTS))
def test_float_dot_matches_reference(rng, name):
    fmt = FMTS[name]
    prog, lay = floatprog.float_dot(fmt, rows=512)
    a = _bits(rng, fmt, (lay.tuples, COLS))
    b = _bits(rng, fmt, (lay.tuples, COLS))
    got, got_acc = _run_fdot(fmt, a, b)
    want, want_acc = ref.float_dot_acc(a, b, fmt.ebits, fmt.mbits)
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(got_acc, want_acc)


@pytest.mark.parametrize("name", sorted(FMTS))
@pytest.mark.parametrize("kcase", ["one", "cap-1", "cap", "cap+1"])
def test_float_dot_capacity_boundaries(rng, name, kcase):
    """K at 1 / capacity-1 / capacity / capacity+1: the +1 case K-tiles
    into a second launch with the wide accumulator image chained, so the
    result must stay bit-identical to one sequential reference pass."""
    fmt = FMTS[name]
    cap = cram.fdot_geometry(fmt, 512)
    K = {"one": 1, "cap-1": max(1, cap - 1), "cap": cap,
         "cap+1": cap + 1}[kcase]
    a = _bits(rng, fmt, (K, COLS))
    b = _bits(rng, fmt, (K, COLS))
    got = cram.cram_fdot(a, b, fmt, executor="scan")
    np.testing.assert_array_equal(
        got, ref.float_dot(a, b, fmt.ebits, fmt.mbits))


@pytest.mark.parametrize("name", sorted(FMTS))
def test_float_dot_signed_operands(rng, name):
    """Dense mixed signs: effective subtraction + cancellation paths."""
    fmt = FMTS[name]
    K = min(4, cram.fdot_geometry(fmt, 512))
    a = _bits(rng, fmt, (K, COLS), zero_p=0.0)
    b = _bits(rng, fmt, (K, COLS), zero_p=0.0)
    sbit = np.uint64(1) << np.uint64(fmt.width - 1)
    a[0] |= sbit                          # force negatives in row 0
    b[1] |= sbit
    got, _ = _run_fdot(fmt, a, b)
    np.testing.assert_array_equal(
        got, ref.float_dot(a, b, fmt.ebits, fmt.mbits))


@pytest.mark.parametrize("name", sorted(FMTS))
def test_float_dot_denormal_inputs_ftz(rng, name):
    """Denormal bit patterns (exp == 0, mantissa != 0) are flushed on
    load: the result equals both the reference on the raw patterns and
    the reference on explicitly-zeroed ones."""
    fmt = FMTS[name]
    K = min(3, cram.fdot_geometry(fmt, 512))
    a = _bits(rng, fmt, (K, COLS))
    b = _bits(rng, fmt, (K, COLS))
    mmask = np.uint64((1 << fmt.mbits) - 1)
    a[0] &= mmask                         # exp=0, mantissa junk: denormal
    a[0] |= np.uint64(1)
    got, _ = _run_fdot(fmt, a, b)
    want = ref.float_dot(a, b, fmt.ebits, fmt.mbits)
    np.testing.assert_array_equal(got, want)
    flushed = a.copy()
    flushed[0] = 0
    np.testing.assert_array_equal(
        want, ref.float_dot(flushed, b, fmt.ebits, fmt.mbits))


@pytest.mark.parametrize("name", sorted(FMTS))
def test_float_dot_overflow_region(rng, name):
    """Near-emax exponents: finite-only semantics wrap the exponent the
    same way in program and reference (documented deviation)."""
    fmt = FMTS[name]
    emax = (1 << fmt.ebits) - 1
    K = min(3, cram.fdot_geometry(fmt, 512))
    a = _bits(rng, fmt, (K, COLS), elo=emax - 2, ehi=emax, zero_p=0.0)
    b = _bits(rng, fmt, (K, COLS), elo=emax - 2, ehi=emax, zero_p=0.0)
    got, _ = _run_fdot(fmt, a, b)
    np.testing.assert_array_equal(
        got, ref.float_dot(a, b, fmt.ebits, fmt.mbits))


# ---------------------------------------------------------------------------
# Accuracy vs a float32-accumulate reference (tolerance, not bit-exact:
# RTZ at every step loses up to ~2^-mbits per product plus guard-bit
# truncation in the accumulator)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name,rtol", [("bf16", 0.05), ("fp16", 0.01),
                                       ("fp8", 0.5)])
def test_float_dot_close_to_float32_accumulate(rng, name, rtol):
    fmt = FMTS[name]
    prog, lay = floatprog.float_dot(fmt, rows=512)
    a = _bits(rng, fmt, (lay.tuples, COLS))
    b = _bits(rng, fmt, (lay.tuples, COLS))
    got, _ = _run_fdot(fmt, a, b)
    gotf = ref.from_bits(got, fmt.ebits, fmt.mbits)
    truef = (ref.from_bits(a, fmt.ebits, fmt.mbits).astype(np.float64)
             * ref.from_bits(b, fmt.ebits, fmt.mbits)).sum(axis=0)
    scale = np.abs(ref.from_bits(a, fmt.ebits, fmt.mbits)
                   * ref.from_bits(b, fmt.ebits, fmt.mbits)).sum(axis=0)
    err = np.abs(gotf.astype(np.float64) - truef)
    assert np.all(err <= rtol * np.maximum(scale, 1e-6)), \
        (err, rtol * scale)


# ---------------------------------------------------------------------------
# Executor bit-identity (full state: array + carry + tag)
# ---------------------------------------------------------------------------
def _states_equal(a, b):
    return all(np.array_equal(np.asarray(getattr(a, f)),
                              np.asarray(getattr(b, f)))
               for f in ("array", "carry", "tag"))


def test_float_dot_executors_bit_identical(rng):
    """unroll == scan == compiled on the bf16 fused MAC, including the
    final carry/tag latches and every scratch row."""
    fmt = BF16
    prog, lay = floatprog.float_dot(fmt, rows=512, tuples=2)
    a = _bits(rng, fmt, (2, COLS))
    b = _bits(rng, fmt, (2, COLS))
    state = harness.make_jax_state(
        harness.pack_state(lay, {"a": a, "b": b}, COLS))
    un = engine.execute(prog, state)
    sc = engine.execute_scan(prog, state)
    co = engine.execute_compiled(prog, state)
    assert _states_equal(un, sc)
    assert _states_equal(un, co)
    np.testing.assert_array_equal(
        floatprog.fdot_result(np.asarray(co.array), fmt),
        ref.float_dot(a, b, fmt.ebits, fmt.mbits))


def test_float_dot_chaining_bit_identical_across_launches(rng):
    """A K-tiled reduction chained through fdot_set_acc equals one
    sequential pass: the tiling is invisible in the bits."""
    fmt = FP8_E4M3
    cap = cram.fdot_geometry(fmt, 512)
    K = cap + 3
    a = _bits(rng, fmt, (K, COLS))
    b = _bits(rng, fmt, (K, COLS))
    # manual two-launch chain
    prog1, lay1 = floatprog.float_dot(fmt, rows=512, tuples=cap)
    img = harness.pack_state(lay1, {"a": a[:cap], "b": b[:cap]}, COLS)
    arr = np.asarray(engine.run(prog1, harness.make_jax_state(img),
                                executor="scan").array)
    acc = floatprog.fdot_acc(arr, fmt)
    prog2, lay2 = floatprog.float_dot(fmt, rows=512, tuples=K - cap)
    img2 = harness.pack_state(lay2, {"a": a[cap:], "b": b[cap:]}, COLS)
    floatprog.fdot_set_acc(img2, fmt, acc)
    arr2 = np.asarray(engine.run(prog2, harness.make_jax_state(img2),
                                 executor="scan").array)
    got = floatprog.fdot_result(arr2, fmt)
    want, want_acc = ref.float_dot_acc(a, b, fmt.ebits, fmt.mbits)
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(floatprog.fdot_acc(arr2, fmt), want_acc)
    # the oracle chains identically through its acc parameter
    mid = ref.float_dot_acc(a[:cap], b[:cap], fmt.ebits, fmt.mbits)[1]
    np.testing.assert_array_equal(
        ref.float_dot_acc(a[cap:], b[cap:], fmt.ebits, fmt.mbits,
                          acc=mid)[1], want_acc)


# ---------------------------------------------------------------------------
# Example-based bodies of the hypothesis rounding-edge properties
# (test_fabric_property.py fuzzes these; here they run without hypothesis)
# ---------------------------------------------------------------------------
def test_float_dot_single_product_equals_float_mul(rng):
    """K=1 dot == float_mul exactly: acc starts at +0, one product is
    added losslessly (guard bits are zeros), final round drops them."""
    for name, fmt in FMTS.items():
        a = _bits(rng, fmt, (1, COLS))
        b = _bits(rng, fmt, (1, COLS))
        got = cram.cram_fdot(a, b, fmt, executor="scan")
        np.testing.assert_array_equal(
            got, ref.float_mul(a[0], b[0], fmt.ebits, fmt.mbits))


def test_float_dot_catastrophic_cancellation_is_exact_zero(rng):
    """x*y + x*(-y): the products negate exactly (sign-XOR), equal
    magnitudes subtract to zero mantissa, and the flush yields +0 --
    the documented FTZ behavior, not a tiny residual."""
    fmt = BF16
    x = _bits(rng, fmt, (1, COLS), zero_p=0.0)[0]
    y = _bits(rng, fmt, (1, COLS), zero_p=0.0)[0]
    sbit = np.uint64(1) << np.uint64(fmt.width - 1)
    a = np.stack([x, x])
    b = np.stack([y, y ^ sbit])
    got, got_acc = _run_fdot(fmt, a, b)
    assert (got == 0).all()
    assert (got_acc == 0).all()


def test_float_dot_exponent_extremes(rng):
    """Smallest-normal x smallest-normal underflows to +0 (FTZ); the
    reference agrees bit for bit at both exponent-field extremes."""
    fmt = BF16
    eb, m = fmt.ebits, fmt.mbits
    lo = _bits(rng, fmt, (2, COLS), elo=1, ehi=2, zero_p=0.0)
    got, _ = _run_fdot(fmt, lo, lo)
    want = ref.float_dot(lo, lo, eb, m)
    np.testing.assert_array_equal(got, want)
    assert (want == 0).all()              # product exps underflow: FTZ
    hi = _bits(rng, fmt, (2, COLS), elo=(1 << eb) - 2, ehi=(1 << eb) - 1,
               zero_p=0.0)
    got_hi, _ = _run_fdot(fmt, hi, hi)
    np.testing.assert_array_equal(got_hi, ref.float_dot(hi, hi, eb, m))


# ---------------------------------------------------------------------------
# Golden pins: cycles, footprint, capacity (program-generator level;
# an executor can never change these)
# ---------------------------------------------------------------------------
def test_float_dot_golden_cycles_and_footprints():
    golden = {
        # fmt: (cycles, imem slots, tuples @ 512 rows)
        "bf16": (5001, 439, 5),
        "fp16": (5620, 463, 5),
        "fp8": (11663, 382, 18),
    }
    for name, (cycles, slots, tuples) in golden.items():
        prog, lay = floatprog.float_dot(FMTS[name], rows=512)
        assert prog.cycles() == cycles, name
        assert prog.footprint() == slots, name
        assert lay.tuples == tuples, name
        # the fused MAC is the documented 2-image program (docs/engine.md)
        assert prog.imem_images() == 2, name


def test_fdot_geometry_capacity():
    assert cram.fdot_geometry(BF16, 512) == 5
    assert cram.fdot_geometry(FP8_E4M3, 512) == 18
    assert cram.fdot_geometry(BF16, 256) == 0        # scratch alone > rows
    with pytest.raises(ValueError, match="cannot host"):
        cram.cram_fdot(np.zeros((1, 2), np.uint64),
                       np.zeros((1, 2), np.uint64), BF16, rows=256)
    with pytest.raises(ValueError, match="float_dot"):
        floatprog.float_dot(BF16, rows=512, tuples=99)


def test_cram_fmatmul_matches_reference(rng):
    fmt = FP8_E4M3
    cap = cram.fdot_geometry(fmt, 512)
    x = _bits(rng, fmt, (3, cap + 2))
    w = _bits(rng, fmt, (cap + 2, 10))
    got = cram.cram_fmatmul(x, w, fmt, cols=COLS, executor="scan")
    np.testing.assert_array_equal(
        got, ref.float_matmul(x, w, fmt.ebits, fmt.mbits))


# ---------------------------------------------------------------------------
# to_bits / from_bits conversion contract
# ---------------------------------------------------------------------------
def test_to_bits_bf16_is_truncating_float32_conversion(rng):
    x = rng.normal(scale=10.0, size=64).astype(np.float32)
    got = ref.to_bits(x, 8, 7)
    want = (x.view(np.uint32) >> 16).astype(np.uint32)
    np.testing.assert_array_equal(got, want)


def test_to_bits_ftz_and_clamp():
    x = np.array([0.0, 1e-45, -1e-42, np.inf, -np.inf, 1e38, 65504.0],
                 np.float32)
    b16 = ref.to_bits(x, 5, 10)                      # fp16
    assert b16[0] == 0 and b16[1] == 0 and b16[2] == 0   # FTZ
    maxfin = ((1 << 5) - 1) << 10 | ((1 << 10) - 1)
    assert b16[3] == maxfin                          # +inf clamps
    assert b16[4] == maxfin | (1 << 15)              # -inf clamps signed
    # round trip of exactly-representable values is lossless
    exact = np.array([1.0, -2.5, 0.15625, 40.0], np.float32)
    np.testing.assert_array_equal(
        ref.from_bits(ref.to_bits(exact, 8, 7), 8, 7), exact)


# ---------------------------------------------------------------------------
# Compiler: the lane plan engages on the float tuple loops
# ---------------------------------------------------------------------------
def test_float_dot_lane_plan_engaged():
    """analyze() must produce a plan (no flat-lowering fallback) with a
    substantive vectorized prefix: load + FTZ + multiply + product-widen
    run as lanes; only the accumulate is loop-carried."""
    for name, fmt in FMTS.items():
        prog, lay = floatprog.float_dot(fmt, rows=512)
        plan = compiler.analyze(prog)
        assert plan is not None, f"{name}: flat-lowering fallback"
        assert plan.lanes == lay.tuples
        assert plan.serial_start >= len(plan.body) // 4, \
            f"{name}: prefix too small ({plan.serial_start})"
        assert plan.serial_start < len(plan.body)    # accumulate serial


def test_float_mul_now_fully_vectorizes():
    """The complementary-predication coverage upgrade: float_mul's only
    red rows were the TROW/TNROW-pair normalize writes; the whole tuple
    body now runs as lanes (no serial suffix)."""
    prog, _ = floatprog.float_mul(BF16, rows=512)
    plan = compiler.analyze(prog)
    assert plan is not None
    assert plan.serial_start == len(plan.body)


def test_coverage_kills_complementary_pair():
    """_coverage_kills: a trow g / tnrow g predicated pair covers; a
    read between the halves, or a guard rewrite, spoils it."""
    from repro.core.isa import Instr
    O = isa
    pair = [
        Instr(O.OP_TROW, a=9),
        Instr(O.OP_COPY, dst=5, a=1, pred=True),
        Instr(O.OP_TNROW, a=9),
        Instr(O.OP_COPY, dst=5, a=2, pred=True),
    ]
    assert 5 in compiler._coverage_kills(pair)
    spoiled_read = [pair[0], pair[1],
                    Instr(O.OP_XOR, dst=6, a=5, b=1),      # exposed read
                    pair[2], pair[3]]
    assert 5 not in compiler._coverage_kills(spoiled_read)
    spoiled_guard = [pair[0], pair[1],
                     Instr(O.OP_W1, dst=9),                # guard rewritten
                     pair[2], pair[3]]
    assert 5 not in compiler._coverage_kills(spoiled_guard)
    # unpredicated and t1-predicated writes cover immediately
    direct = [Instr(O.OP_W0, dst=7),
              Instr(O.OP_T1), Instr(O.OP_W1, dst=8, pred=True)]
    cov = compiler._coverage_kills(direct)
    assert {7, 8} <= cov


def test_segment_folds_copy_and_fill_runs():
    """The simple-op batcher: uniform-stride COPY runs and predicated
    W0/W1 runs fold into single integer-domain items."""
    from repro.core.isa import Instr
    O = isa
    stream = [Instr(O.OP_COPY, dst=("k", 10 + i), a=("k", 20 + i))
              for i in range(6)]
    stream += [Instr(O.OP_W0, dst=("k", 30 + i), pred=True)
               for i in range(5)]
    items = compiler._segment(stream)
    kinds = [k for k, _ in items]
    assert kinds == ["copyrun", "fillrun"]
    # a stride break splits the run
    broken = stream[:3] + [Instr(O.OP_COPY, dst=("k", 99), a=("k", 0))]
    kinds2 = [k for k, _ in compiler._segment(broken)]
    assert "copyrun" not in kinds2


def test_run_batcher_bit_exact_on_crafted_program(rng):
    """Descending predicated copy runs (the normalize shift idiom) and
    fill runs execute bit-exactly through the compiled path."""
    from repro.core.isa import Instr, Loop, Program, R, SetReg
    O = isa
    nodes = [
        SetReg(1, 16), SetReg(2, 0),
        Loop(6, [Instr(O.OP_COPY, R(1), R(2), inc=((1, 1), (2, 1)))]),
        Instr(O.OP_TROW, a=40),
        SetReg(1, 38), SetReg(2, 33),
        Loop(5, [Instr(O.OP_COPY, R(1), R(2), pred=True,
                       inc=((1, -1), (2, -1)))]),
        SetReg(1, 48),
        Loop(5, [Instr(O.OP_W1, R(1), pred=True, inc=((1, 1),))]),
    ]
    prog = Program("crafted_runs", nodes)
    import jax.numpy as jnp
    state = engine.CRState(
        array=jnp.asarray(rng.integers(0, 2, (64, COLS)).astype(bool)),
        carry=jnp.asarray(rng.integers(0, 2, COLS).astype(bool)),
        tag=jnp.asarray(rng.integers(0, 2, COLS).astype(bool)))
    un = engine.execute(prog, state)
    co = engine.execute_compiled(prog, state)
    assert _states_equal(un, co)
