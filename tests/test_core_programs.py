"""Bit-exact validation of Compute RAM programs against numpy oracles."""

import numpy as np
import pytest

from repro.core import harness, isa, programs, ref


def _run(program, layout, data, cols=8, executor="compiled"):
    return harness.run_program(program, layout, data, cols,
                               executor=executor)


def _rand(rng, n, shape):
    return rng.integers(0, 1 << n, size=shape, dtype=np.uint64)


@pytest.mark.parametrize("n", [4, 8])
@pytest.mark.parametrize("sub", [False, True])
def test_int_addsub(n, sub):
    rng = np.random.default_rng(0)
    prog, lay = programs.iadd(n, rows=128, sub=sub)
    assert lay.tuples >= 3
    a = _rand(rng, n, (lay.tuples, 8))
    b = _rand(rng, n, (lay.tuples, 8))
    arr = _run(prog, lay, {"a": a, "b": b})
    got = harness.unpack_field(arr, lay, "d")
    want = ref.isub(a, b, n) if sub else ref.iadd(a, b, n)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("n", [4, 8])
def test_int_mul(n):
    rng = np.random.default_rng(1)
    prog, lay = programs.imul(n, rows=256)
    a = _rand(rng, n, (lay.tuples, 8))
    b = _rand(rng, n, (lay.tuples, 8))
    arr = _run(prog, lay, {"a": a, "b": b})
    got = harness.unpack_field(arr, lay, "d")
    np.testing.assert_array_equal(got, ref.imul(a, b, n))


@pytest.mark.parametrize("n,rows", [(4, 128), (8, 256)])
def test_int_dot(n, rows):
    rng = np.random.default_rng(2)
    prog, lay = programs.idot(n, rows=rows)
    a = _rand(rng, n, (lay.tuples, 8))
    b = _rand(rng, n, (lay.tuples, 8))
    arr = _run(prog, lay, {"a": a, "b": b})
    got = harness.unpack_acc(arr, lay)
    np.testing.assert_array_equal(got, ref.idot(a, b))


def test_scan_executor_matches_unrolled():
    rng = np.random.default_rng(3)
    prog, lay = programs.iadd(4, rows=64)
    a = _rand(rng, 4, (lay.tuples, 8))
    b = _rand(rng, 4, (lay.tuples, 8))
    arr1 = _run(prog, lay, {"a": a, "b": b}, executor="unroll")
    arr2 = _run(prog, lay, {"a": a, "b": b}, executor="scan")
    np.testing.assert_array_equal(arr1, arr2)


def test_executors_match_unrolled_bf16():
    """All executors cover every opcode class used by the float
    programs (predication, tag chains, CSTORE, W0/W1, XOR...)."""
    rng = np.random.default_rng(5)
    prog, lay = programs.bf16_add(rows=512, tuples=2)
    a = _bf16_bits(rng, (2, 8))
    b = _bf16_bits(rng, (2, 8))
    arr1 = _run(prog, lay, {"a": a, "b": b}, cols=8, executor="unroll")
    arr2 = _run(prog, lay, {"a": a, "b": b}, cols=8, executor="scan")
    arr3 = _run(prog, lay, {"a": a, "b": b}, cols=8, executor="compiled")
    np.testing.assert_array_equal(arr1, arr2)
    np.testing.assert_array_equal(arr1, arr3)


def _bf16_bits(rng, shape, emin=100, emax=150, with_zero=True):
    s = rng.integers(0, 2, shape).astype(np.uint32)
    e = rng.integers(emin, emax, shape).astype(np.uint32)
    m = rng.integers(0, 128, shape).astype(np.uint32)
    bits = (s << 15) | (e << 7) | m
    if with_zero:
        bits = np.where(rng.random(shape) < 0.1, 0, bits)
    return bits.astype(np.uint16)


@pytest.mark.parametrize("op", ["add", "mul"])
def test_bf16(op):
    rng = np.random.default_rng(4)
    gen = programs.bf16_add if op == "add" else programs.bf16_mul
    oracle = ref.bf16_add if op == "add" else ref.bf16_mul
    prog, lay = gen(rows=512, tuples=3)
    a = _bf16_bits(rng, (lay.tuples, 16))
    b = _bf16_bits(rng, (lay.tuples, 16))
    arr = _run(prog, lay, {"a": a, "b": b}, cols=16)
    got = harness.unpack_field(arr, lay, "d").astype(np.uint16)
    want = oracle(a, b)
    np.testing.assert_array_equal(got, want)


def test_bf16_add_special_values():
    """0+x, x+x, x-x, equal-exponent subtract, big exponent gap."""
    def f2b(x):
        return np.asarray(x, ">f4").astype(np.float32).view(np.uint32) >> 16

    cases_a = np.array([0.0, 1.5, 2.0, 1.0, 1e10, -3.25, 0.0], np.float32)
    cases_b = np.array([2.5, 1.5, -2.0, -1.0078125, 1.0, 3.25, 0.0],
                       np.float32)
    a = (cases_a.view(np.uint32) >> 16).astype(np.uint16)
    b = (cases_b.view(np.uint32) >> 16).astype(np.uint16)

    prog, lay = programs.bf16_add(rows=512, tuples=1)
    arr = _run(prog, lay, {"a": a[None], "b": b[None]}, cols=len(a))
    got = harness.unpack_field(arr, lay, "d").astype(np.uint16)[0]
    want = ref.bf16_add(a, b)
    np.testing.assert_array_equal(got, want)
    # sanity: the oracle itself is close to true bf16 arithmetic
    gotf = (got.astype(np.uint32) << 16).view(np.float32)
    truef = cases_a + cases_b
    np.testing.assert_allclose(gotf, truef, rtol=0.02, atol=1e-7)


def test_programs_fit_instruction_memory():
    """Paper §III-A2: every common operation fits the 256-slot imem.

    The fused float MAC (``float_dot``) is the one documented
    exception: multiply + widened-accumulator add in one sequence
    exceeds a single 4 Kb image and is streamed as two imem loads
    (``Program.imem_images``; see docs/engine.md deviation notes).
    """
    for (op, prec), gen in programs.GENERATORS.items():
        prog, _ = gen(rows=512)
        budget = 2 if (op, prec[0]) in (("dot", "b"), ("dot", "f")) else 1
        assert prog.imem_images() <= budget, \
            f"{op}/{prec}: {prog.footprint()} > {budget * isa.IMEM_SLOTS}"
        words = isa.encode(prog)
        assert all(0 <= w <= 0xFFFF for w in words)


def test_cycle_counts_match_table2_throughput():
    """Steady-state cycles/op consistent with paper Table II GOPS."""
    # int4 add: 5 cycles/op -> 40 lanes * 609.1 MHz / 5 = 4.87 GOPS (4.8)
    prog, lay = programs.iadd(4, rows=512)
    per_op = prog.cycles() / lay.tuples
    assert 4.5 <= per_op <= 5.5, per_op
    # int8 add: 9 cycles/op -> 2.71 GOPS (2.7)
    prog, lay = programs.iadd(8, rows=512)
    per_op = prog.cycles() / lay.tuples
    assert 8.5 <= per_op <= 9.5, per_op


@pytest.mark.parametrize("fmt_name,ebits,mbits", [
    ("fp16", 5, 10), ("fp8", 4, 3), ("bf16", 8, 7)])
@pytest.mark.parametrize("op", ["add", "mul"])
def test_parameterized_float_formats(fmt_name, ebits, mbits, op):
    """The paper's 'any custom precision' claim: one parameterized
    instruction-sequence generator covers bf16 / IEEE half / fp8-e4m3,
    each validated bit-exactly against the generalized oracle."""
    from repro.core import floatprog
    fmt = floatprog.FloatFormat(ebits, mbits, fmt_name)
    gen = floatprog.float_add if op == "add" else floatprog.float_mul
    oracle = ref.float_add if op == "add" else ref.float_mul
    prog, lay = gen(fmt, rows=512, tuples=3)
    assert prog.footprint() <= isa.IMEM_SLOTS

    rng = np.random.default_rng(ebits * 100 + mbits + ord(op[0]))
    emax = (1 << ebits) - 1
    lo, hi = max(1, emax // 3), min(emax - 1, 2 * emax // 3 + 2)
    def mk(shape):
        s = rng.integers(0, 2, shape).astype(np.uint32)
        e = rng.integers(lo, hi, shape).astype(np.uint32)
        m = rng.integers(0, 1 << mbits, shape).astype(np.uint32)
        bits = (s << (ebits + mbits)) | (e << mbits) | m
        return np.where(rng.random(shape) < 0.1, 0, bits).astype(np.uint64)
    a, b = mk((lay.tuples, 12)), mk((lay.tuples, 12))
    arr = _run(prog, lay, {"a": a, "b": b}, cols=12)
    got = harness.unpack_field(arr, lay, "d")
    want = oracle(a, b, ebits, mbits)
    np.testing.assert_array_equal(got, want.astype(np.uint64))


@pytest.mark.parametrize("n", [4, 8])
def test_vsearch_cam(n):
    """CAM-style equality search (Jeloka TCAM/BCAM mode, paper §II-B)."""
    rng = np.random.default_rng(11)
    prog, lay = programs.vsearch(n, rows=128)
    a = _rand(rng, n, (lay.tuples, 10))
    q = _rand(rng, n, (lay.tuples, 10))
    # force some matches
    q[:, :4] = a[:, :4]
    arr = _run(prog, lay, {"a": a, "q": q}, cols=10)
    got = harness.unpack_field(arr, lay, "m")
    np.testing.assert_array_equal(got.astype(bool), a == q)


@pytest.mark.parametrize("n", [4, 8])
def test_vcmp_gt(n):
    rng = np.random.default_rng(12)
    prog, lay = programs.vcmp_gt(n, rows=128)
    a = _rand(rng, n, (lay.tuples, 10))
    b = _rand(rng, n, (lay.tuples, 10))
    arr = _run(prog, lay, {"a": a, "b": b}, cols=10)
    got = harness.unpack_field(arr, lay, "m")
    np.testing.assert_array_equal(got.astype(bool), a > b)
