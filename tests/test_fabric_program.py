"""FabricProgram (PR 4): cross-round residency, multi-GEMM fusion, and
topology-aware placement.

Differential lines held here:

* residency-enabled replay is **bit-identical** to the PR 3
  reload-every-round path (``FabricConfig(residency=False)``) across
  int4/int8 x ragged shapes x 1/4/64-block grids -- residency changes
  the load stage and the cost model, never the arithmetic;
* a fused QKV :class:`FabricProgram` is **bit-identical** to three
  independent ``schedule_gemm`` runs (the acceptance criterion);
* a weight tile reused across R rounds is fetched exactly once, and the
  activation-sharing schedule cuts total fetches by >= 2x (the bench
  gate, pinned here at test scale).
"""

import numpy as np
import pytest

from repro.core import costmodel as cm, ref
from repro.pim import fabric
from repro.pim.fabric import FabricConfig, GemmSpec

ROWS, COLS = 128, 8
# float programs need the full-depth geometry (wide-accumulator scratch
# alone exceeds 128 rows) and use the scan executor: fabric-shaped
# wide-block float compiles are a bench concern, not a tier-1 one
FROWS = 512


def _grid(n_blocks, **kw):
    return FabricConfig(n_blocks=n_blocks, rows=ROWS, cols=COLS, **kw)


def _signed_operands(rng, nbits, m, k, n):
    lo, hi = -(1 << (nbits - 1)), 1 << (nbits - 1)
    x = rng.integers(lo, hi, (m, k)).astype(np.int64)
    w = rng.integers(lo, hi, (k, n)).astype(np.int64)
    return x, w


# ---------------------------------------------------------------------------
# Residency differential matrix: residency on == residency off == numpy
# ---------------------------------------------------------------------------
_MATRIX = [
    (4, 1, (3, 10, 11)),
    (4, 4, (3, 10, 11)),
    (4, 4, (2, 20, 16)),
    (4, 64, (5, 23, 17)),
    (8, 1, (2, 7, 5)),
    (8, 4, (2, 23, 5)),
    (8, 64, (3, 9, 10)),
]
_IDS = [f"int{n}-{b}blk-{'x'.join(map(str, s))}" for n, b, s in _MATRIX]


@pytest.mark.parametrize("nbits,blocks,shape", _MATRIX, ids=_IDS)
def test_residency_replay_bit_identical(rng, nbits, blocks, shape):
    m, k, n = shape
    x, w = _signed_operands(rng, nbits, m, k, n)
    res_on = fabric.fabric_matmul(x, w, nbits=nbits, cfg=_grid(blocks),
                                  signed=True)
    res_off = fabric.fabric_matmul(
        x, w, nbits=nbits, cfg=_grid(blocks, residency=False), signed=True)
    np.testing.assert_array_equal(res_on.out, x @ w)
    np.testing.assert_array_equal(res_off.out, res_on.out)
    # residency never *adds* fetches, and off means reload-every-round
    st_on = fabric.residency_stats(res_on.schedule)
    st_off = fabric.residency_stats(res_off.schedule)
    assert st_on["fetches"] <= st_off["fetches"]
    assert st_off["fetch_reduction"] == 1.0
    assert st_on["reads"] == st_off["reads"]
    # residency only shrinks the modeled load stage, never grows it
    assert res_on.cost.energy_wire_pj <= res_off.cost.energy_wire_pj + 1e-9
    assert res_on.cost.overlapped_cycles_ <= \
        res_off.cost.overlapped_cycles_ + 1e-9


def test_weight_tile_fetched_exactly_once_across_rounds():
    """Weight-stationary GEMM: one weight tile, >= 8 rounds -- the tile
    crosses the fabric ONCE (the paper's data-movement headline)."""
    sched = fabric.schedule_gemm(32, 10, 8, 4, cfg=_grid(4), signed=True)
    assert len(sched.rounds) >= 8
    assert len(sched.w_home) == 1                    # single weight tile
    w_loads = [ld for rnd in sched.rounds for ld in rnd.loads
               if ld.kind == "w"]
    assert len(w_loads) == 1, "resident weight tile must be fetched once"
    # the reload-every-round baseline fetches it every round
    off = fabric.schedule_gemm(32, 10, 8, 4,
                               cfg=_grid(4, residency=False), signed=True)
    w_reloads = [ld for rnd in off.rounds for ld in rnd.loads
                 if ld.kind == "w"]
    assert len(w_reloads) == len(off.rounds)


def test_residency_fetch_reduction_two_x():
    """The bench-gated claim at test scale: activation slices reused
    across n-tiles + broadcast weight tiles cut total fetch count 2x+.
    M aligned to the compute-block count keeps every activation slice
    returning to the block that already holds it."""
    sched = fabric.schedule_gemm(8, 10, 64, 4,
                                 cfg=_grid(8, min_compute_blocks=8),
                                 signed=True)
    assert len(sched.rounds) >= 8
    st = fabric.residency_stats(sched)
    assert st["fetch_reduction"] >= 2.0, st


def test_residency_eviction_refetches():
    """A compute block's resident set is bounded by its bit capacity:
    thrashing working sets evict (LRU) and later reuses re-fetch --
    fetch count sits strictly between all-hit and reload-every-round."""
    # one compute block, 8 weight tiles + 12 activation slices streaming
    # through a 1024-bit block (w tile = 320 bits, x slice = 40): the
    # working set 12*40 + 2*320 > 1024 forces LRU eviction
    sched = fabric.schedule_gemm(12, 10, 64, 4, cfg=_grid(2), signed=True)
    st = fabric.residency_stats(sched)
    distinct = len({(ld.kind, tuple(ld.key))
                    for rnd in sched.rounds for ld in rnd.loads})
    assert st["fetches"] > distinct, "capacity pressure must re-fetch"
    assert st["fetches"] < st["reload_fetches"]
    # still exact, of course
    rng = np.random.default_rng(0)
    x, w = _signed_operands(rng, 4, 12, 10, 64)
    res = fabric.fabric_matmul(x, w, nbits=4, signed=True, schedule=sched)
    np.testing.assert_array_equal(res.out, x @ w)


# ---------------------------------------------------------------------------
# bf16 rows of the residency matrix + mixed-precision fusion
# ---------------------------------------------------------------------------
def _fgrid(n_blocks, **kw):
    return FabricConfig(n_blocks=n_blocks, rows=FROWS, cols=COLS,
                        executor="scan", **kw)


_BF16_MATRIX = [
    (1, (2, 7, 5)),
    (4, (3, 11, 10)),          # ragged everything, K > one fdot tile
    (4, (2, 4, 9)),            # N > block columns
]
_BF16_IDS = [f"bf16-{b}blk-{'x'.join(map(str, s))}"
             for b, s in _BF16_MATRIX]


@pytest.mark.parametrize("blocks,shape", _BF16_MATRIX, ids=_BF16_IDS)
def test_bf16_residency_replay_bit_identical(rng, blocks, shape):
    """The bf16 row of the residency on/off matrix: float GEMMs are
    bit-exact vs the FTZ+RTZ fused-MAC reference (ref.float_matmul),
    independent of grid size, residency, and K-tiling (the wide
    accumulator image chains across k-stages)."""
    import jax.numpy as jnp

    m, k, n = shape
    x = rng.normal(scale=3.0, size=(m, k)).astype(np.float32)
    w = rng.normal(scale=2.0, size=(k, n)).astype(np.float32)
    want = ref.float_matmul(ref.to_bits(x, 8, 7), ref.to_bits(w, 8, 7))
    res_on = fabric.fabric_matmul(x, w, cfg=_fgrid(blocks),
                                  dtype=jnp.bfloat16)
    res_off = fabric.fabric_matmul(
        x, w, cfg=_fgrid(blocks, residency=False), dtype="bf16")
    np.testing.assert_array_equal(res_on.out_bits, want)
    np.testing.assert_array_equal(res_off.out_bits, want)
    np.testing.assert_array_equal(res_on.out,
                                  ref.from_bits(want, 8, 7))
    # residency discipline holds for float rounds too: never more
    # fetches or fetched bits (drain *positions* may shift -- the
    # residency-first assignment moves tasks between sites)
    st_on = fabric.residency_stats(res_on.schedule)
    st_off = fabric.residency_stats(res_off.schedule)
    assert st_on["fetches"] <= st_off["fetches"]
    assert st_on["fetch_bits"] <= st_off["fetch_bits"]
    assert st_on["reads"] == st_off["reads"]


def test_mixed_precision_fused_program_bit_identical(rng):
    """int8 QKV + a bf16 output projection in ONE FabricProgram
    (asymmetric per-GEMM precision): every output bit-identical to the
    independent single-GEMM runs, in one grid allocation."""
    import jax.numpy as jnp

    M, K = 3, 9
    x = rng.integers(-8, 8, (M, K)).astype(np.int64)
    wq, wk, wv = (rng.integers(-100, 100, (K, n)).astype(np.int64)
                  for n in (6, 6, 5))
    wo = rng.normal(scale=1.5, size=(K, 7)).astype(np.float32)
    cfg = _fgrid(6)
    fused = fabric.fabric_fused_matmul(
        x, (wq, wk, wv, wo), nbits=8, cfg=cfg, signed=True,
        dtypes=(None, None, "int8", jnp.bfloat16),
        names=("q", "k", "v", "o"))
    # int projections: exact int64 ground truth
    for out, w in zip(fused.outs[:3], (wq, wk, wv)):
        np.testing.assert_array_equal(out, x @ w)
    # bf16 projection: the float reference over the bf16-encoded x
    xb = ref.to_bits(x.astype(np.float32), 8, 7)
    want_o = ref.float_matmul(xb, ref.to_bits(wo, 8, 7))
    np.testing.assert_array_equal(fused.bits[3], want_o)
    # ... and bit-identical to the independent single-GEMM runs
    solo_int = fabric.fabric_matmul(x, wq, nbits=8, cfg=cfg, signed=True)
    np.testing.assert_array_equal(fused.outs[0], solo_int.out)
    solo_f = fabric.fabric_matmul(x.astype(np.float32), wo, cfg=cfg,
                                  dtype="bf16")
    np.testing.assert_array_equal(fused.bits[3], solo_f.out_bits)
    # one program: both dtype classes present, rounds never mix them
    sched = fused.schedule
    assert sched.classes == ("int8", "bf16") and sched.multi
    infos = sched.infos()
    for rnd in sched.rounds:
        kinds = {infos[t.gemm].name for t in rnd.tasks}
        assert len(kinds) == 1 and rnd.dtype in kinds
    # mixed programs key activations per dtype class (distinct payloads)
    xkeys = {ld.key[0] for rnd in sched.rounds for ld in rnd.loads
             if ld.kind == "x"}
    assert xkeys == {"int8", "bf16"}
    # the cost walk prices each class at its own program's cycles
    assert "int8+bf16" in fused.cost.name


def test_mixed_program_reuse_and_dtype_mismatch(rng):
    x = rng.integers(-8, 8, (2, 6)).astype(np.int64)
    w = rng.integers(-8, 8, (6, 4)).astype(np.int64)
    wf = rng.normal(size=(6, 4)).astype(np.float32)
    cfg = _fgrid(4)
    res = fabric.fabric_fused_matmul(x, (w, wf), nbits=4, cfg=cfg,
                                     signed=True, dtypes=(None, "bf16"))
    again = fabric.fabric_fused_matmul(x, (w, wf), nbits=4, cfg=cfg,
                                       signed=True, dtypes=(None, "bf16"),
                                       program=res.schedule)
    np.testing.assert_array_equal(res.outs[0], again.outs[0])
    np.testing.assert_array_equal(res.bits[1], again.bits[1])
    with pytest.raises(ValueError, match="does not match"):
        fabric.fabric_fused_matmul(x, (w, wf), nbits=4, cfg=cfg,
                                   signed=True, dtypes=(None, "fp16"),
                                   program=res.schedule)


def test_bf16_schedule_guard_on_small_geometry():
    """The dtype-aware infeasible-geometry guard (the bugfix): a bf16
    GEMM on a too-shallow grid fails at schedule time with the same
    clear error shape as the int guard, not a downstream layout error."""
    small = FabricConfig(n_blocks=2, rows=ROWS, cols=COLS)
    with pytest.raises(ValueError, match="cannot host a float_dot"):
        fabric.schedule_program((GemmSpec("g", 2, 4, 4, dtype="bf16"),),
                                8, cfg=small)
    # the int guard still reads the same way
    tiny = FabricConfig(n_blocks=2, rows=16, cols=COLS)
    with pytest.raises(ValueError, match="cannot host an idot"):
        fabric.schedule_gemm(2, 4, 4, 8, cfg=tiny)
    # and the search simply skips infeasible float candidates
    sr = fabric.search_program(
        (GemmSpec("g", 2, 6, 4, dtype="bf16"),), 8, base=_fgrid(4),
        geometries=((ROWS, COLS), (FROWS, COLS)))
    assert sr.config.rows == FROWS


# ---------------------------------------------------------------------------
# Multi-GEMM fusion (the QKV case)
# ---------------------------------------------------------------------------
def test_fused_qkv_bit_identical_to_three_runs(rng):
    """Acceptance: one fused QKV FabricProgram == three independent
    schedule_gemm executions == numpy, and the fused program shares
    activation fetches across the GEMMs."""
    M, K = 5, 23
    x = rng.integers(-8, 8, (M, K)).astype(np.int64)
    ws = [rng.integers(-8, 8, (K, n)).astype(np.int64) for n in (11, 9, 17)]
    cfg = _grid(8)
    fused = fabric.fabric_fused_matmul(x, ws, nbits=4, cfg=cfg, signed=True,
                                       names=("q", "k", "v"))
    assert len(fused.outs) == 3
    for out, w in zip(fused.outs, ws):
        np.testing.assert_array_equal(out, x @ w)       # ground truth
        single = fabric.fabric_matmul(x, w, nbits=4, cfg=cfg, signed=True)
        np.testing.assert_array_equal(out, single.out)  # three runs
    # shared activation residency: the fused program fetches x fewer
    # times than the three independent programs combined
    fused_x = sum(1 for rnd in fused.schedule.rounds for ld in rnd.loads
                  if ld.kind == "x")
    separate_x = sum(
        1 for w in ws
        for rnd in fabric.schedule_gemm(M, K, w.shape[1], 4, cfg=cfg,
                                        signed=True).rounds
        for ld in rnd.loads if ld.kind == "x")
    assert fused_x < separate_x
    # one grid allocation, one cost roll-up covering all three GEMMs
    assert fused.cost.ops == sum(M * K * w.shape[1] for w in ws)
    assert fused.schedule.gemms[0].name == "q"


def test_fused_unsigned_and_program_reuse(rng):
    x = rng.integers(0, 16, (3, 13)).astype(np.uint64)
    ws = [rng.integers(0, 16, (13, n)).astype(np.uint64) for n in (5, 8)]
    res = fabric.fabric_fused_matmul(x, ws, nbits=4, cfg=_grid(4))
    for out, w in zip(res.outs, ws):
        np.testing.assert_array_equal(
            out, x.astype(np.int64) @ w.astype(np.int64))
    # reuse the plan; mismatched operands are rejected
    again = fabric.fabric_fused_matmul(x, ws, nbits=4, cfg=_grid(4),
                                       program=res.schedule)
    for a, b in zip(again.outs, res.outs):
        np.testing.assert_array_equal(a, b)
    with pytest.raises(ValueError, match="does not match"):
        fabric.fabric_fused_matmul(x, ws[:1], nbits=4, cfg=_grid(4),
                                   program=res.schedule)


def test_schedule_program_rejects_mismatched_activations():
    with pytest.raises(ValueError, match="share activations"):
        fabric.schedule_program(
            (GemmSpec("a", 2, 8, 4), GemmSpec("b", 3, 8, 4)), 4,
            cfg=_grid(2))
    with pytest.raises(ValueError, match="at least one"):
        fabric.schedule_program((), 4, cfg=_grid(2))


def test_single_gemm_program_keeps_legacy_surface():
    sched = fabric.schedule_gemm(2, 7, 5, 8, cfg=_grid(2))
    assert (sched.M, sched.K, sched.N) == (2, 7, 5)
    assert isinstance(sched, fabric.Schedule)        # migration alias
    fused = fabric.schedule_program(
        (GemmSpec("q", 2, 7, 5), GemmSpec("k", 2, 7, 5)), 8, cfg=_grid(2))
    with pytest.raises(ValueError, match="ambiguous"):
        _ = fused.N
    with pytest.raises(ValueError, match="single-GEMM"):
        fabric.execute_schedule(fused, np.zeros((2, 7), np.uint64),
                                np.zeros((7, 5), np.uint64))


def test_fused_linear_apply_matches_per_layer():
    import jax
    import jax.numpy as jnp

    from repro.pim import (PimConfig, fused_linear_apply, linear_apply,
                           linear_init, pack_linear)

    cfgr = PimConfig(mode="ref", weight_bits=4)
    cfgf = PimConfig(mode="fabric", weight_bits=4, fabric=_grid(6))
    packed = [pack_linear(linear_init(jax.random.PRNGKey(i), 32, 8, cfgr),
                          cfgr) for i in range(3)]
    x = jax.random.normal(jax.random.PRNGKey(9), (3, 32), jnp.bfloat16)
    want = [linear_apply(p, x, cfgr) for p in packed]
    got_ref = fused_linear_apply(packed, x, cfgr)
    got_fab = fused_linear_apply(packed, x, cfgf)
    for w_, r_, f_ in zip(want, got_ref, got_fab):
        np.testing.assert_array_equal(np.asarray(w_, np.float32),
                                      np.asarray(r_, np.float32))
        np.testing.assert_array_equal(np.asarray(w_, np.float32),
                                      np.asarray(f_, np.float32))


def test_fused_linear_apply_autotuned_matches():
    import jax
    import jax.numpy as jnp

    from repro.pim import PimConfig, fused_linear_apply, linear_init, \
        pack_linear

    cfgr = PimConfig(mode="ref", weight_bits=4)
    cfga = PimConfig(mode="fabric", weight_bits=4, fabric=_grid(6),
                     fabric_autotune=True)
    packed = [pack_linear(linear_init(jax.random.PRNGKey(i), 32, 8, cfgr),
                          cfgr) for i in range(2)]
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 32), jnp.bfloat16)
    want = fused_linear_apply(packed, x, cfgr)
    got = fused_linear_apply(packed, x, cfga)
    for w_, g_ in zip(want, got):
        np.testing.assert_array_equal(np.asarray(w_, np.float32),
                                      np.asarray(g_, np.float32))


# ---------------------------------------------------------------------------
# Topology: sites, placement, hop-priced wires
# ---------------------------------------------------------------------------
def test_grid_sites_and_hops():
    cfg = _grid(6)                                   # 3x2... near-square
    assert cfg.grid_rows * cfg.grid_cols >= cfg.n_blocks
    assert cfg.site(0) == (0, 0)
    assert cfg.hops(0, 0) == 0
    assert cfg.hops(0, cfg.n_blocks - 1) == cfg.grid_diameter
    assert cfg.edge_hops(0) == 1
    # hops are symmetric and obey the triangle inequality vs the edge
    for b in range(cfg.n_blocks):
        assert cfg.hops(0, b) == cfg.hops(b, 0)
        assert cfg.edge_hops(b) <= cfg.edge_hops(0) + cfg.hops(0, b)


def test_wire_energy_monotone_in_grid_diameter():
    """The same payload priced across the grid diameter costs strictly
    more wire energy as the grid grows (acceptance criterion)."""
    energies = []
    for n_blocks in (4, 16, 64):
        cfg = _grid(n_blocks)
        mm = fabric._broadcast_net_mm(cfg, 0, (cfg.n_blocks - 1,))
        energies.append(cm.wire_energy_fj(1024, mm))
    assert energies[0] < energies[1] < energies[2]
    # spill fetches also grow with distance from the host edge
    spills = [fabric._spill_net_mm(_grid(n), (n - 1,)) for n in (4, 16, 64)]
    assert spills[0] < spills[1] < spills[2]


def test_placement_affects_wire_energy_not_results(rng):
    """Interleaving storage among compute blocks changes hop distances
    (and therefore wire energy) but never the integers."""
    x, w = _signed_operands(rng, 4, 5, 23, 17)
    costs = {}
    for placement in fabric.PLACEMENT_CHOICES:
        cfg = _grid(16, placement=placement)
        res = fabric.fabric_matmul(x, w, nbits=4, cfg=cfg, signed=True)
        np.testing.assert_array_equal(res.out, x @ w)
        sched = res.schedule
        assert sched.n_compute + sched.n_storage == 16
        costs[placement] = res.cost
    assert costs["interleaved"].energy_wire_pj != \
        costs["contiguous"].energy_wire_pj
    # identical event counts: placement only moves bits, never adds them
    assert costs["interleaved"].fabric_bits_moved == \
        costs["contiguous"].fabric_bits_moved


def test_schedule_cost_uses_hop_pricing():
    sched = fabric.schedule_gemm(5, 23, 17, 4, cfg=_grid(8), signed=True)
    cost = fabric.schedule_cost(sched)
    assert cost.fabric_bit_mm > 0
    rep = cost.report()
    assert rep["fabric_bit_mm"] > 0 and rep["avg_hop_mm"] > 0
    # the wire split is exactly the hop-priced totals
    want = (cm.wire_energy_bit_mm_fj(cost.fabric_bit_mm)
            + cm.wire_energy_bit_mm_fj(cost.spill_bit_mm)) / 1e3
    assert cost.energy_wire_pj == pytest.approx(want)


# ---------------------------------------------------------------------------
# Search: placement dimension, dedup, explainable candidates
# ---------------------------------------------------------------------------
def test_search_candidates_deduped_and_explainable():
    sr = fabric.search_schedule(8, 64, 32, 4, base=_grid(8),
                                geometries=((128, 8), (256, 16)))
    sigs = [(c["rows"], c["cols"], c["placement"], c["n_compute"])
            for c in sr.candidates]
    assert len(sigs) == len(set(sigs)), "geometry-equivalent dupes"
    for c in sr.candidates:
        assert c["placement"] in fabric.PLACEMENT_CHOICES
        assert 0.0 <= c["hit_rate"] <= 1.0
        assert c["fetches"] > 0 and c["fetch_reduction"] >= 1.0
    assert {c["placement"] for c in sr.candidates} == \
        set(fabric.PLACEMENT_CHOICES)
    # the argmin row is in the table
    best = min(c["objective"] for c in sr.candidates)
    assert sr.cost.overlapped_cycles_ == pytest.approx(best, rel=1e-6)
    assert "placement" in sr.describe() or sr.config.placement in \
        sr.describe()
    table = sr.candidate_table()
    assert "hit_rate" in table and "placement" in table


def test_search_program_fused_argmin_executes(rng):
    M, K = 4, 20
    x = rng.integers(-8, 8, (M, K)).astype(np.int64)
    ws = [rng.integers(-8, 8, (K, n)).astype(np.int64) for n in (8, 6)]
    specs = tuple(GemmSpec(f"p{i}", M, K, w.shape[1])
                  for i, w in enumerate(ws))
    sr = fabric.search_program(specs, 4, base=_grid(8), signed=True,
                               geometries=((ROWS, COLS),))
    res = fabric.fabric_fused_matmul(x, ws, nbits=4, signed=True,
                                     program=sr.schedule)
    for out, w in zip(res.outs, ws):
        np.testing.assert_array_equal(out, x @ w)


# ---------------------------------------------------------------------------
# Fused serving probe
# ---------------------------------------------------------------------------
def test_probe_fused_projections(rng):
    from repro.pim.fabric import FabricLinearProbe

    ws = [rng.normal(size=(16, n)).astype(np.float32) for n in (6, 4, 5)]
    probe = FabricLinearProbe(ws, cfg=_grid(4), bits=8, max_steps=1)
    x = rng.normal(size=(2, 16)).astype(np.float32)
    ys = probe.observe(x)
    assert isinstance(ys, tuple) and len(ys) == 3
    assert [y.shape for y in ys] == [(2, 6), (2, 4), (2, 5)]
    rep = probe.report()
    assert rep["projections"] == 3 and rep["energy_pj"] > 0
    # fused probe output == three single-weight probes, bit for bit
    for wi, yi in zip(ws, ys):
        single = FabricLinearProbe(wi, cfg=_grid(4), bits=8, max_steps=1)
        np.testing.assert_array_equal(single.observe(x), yi)


def test_probe_fused_autotune_and_engine(rng):
    from repro.pim.fabric import FabricLinearProbe
    from repro.serve.engine import Request, ServeEngine
    from tests.test_fabric import _StubModel

    ws = [rng.normal(size=(16, n)).astype(np.float32) for n in (6, 4)]
    probe = FabricLinearProbe(ws, cfg=_grid(4), bits=8, max_steps=2,
                              autotune=True)
    eng = ServeEngine(_StubModel(), params={}, batch_slots=2, capacity=8,
                      fabric_probe=probe)
    eng.add(Request(rid=0, prompt=np.asarray([1, 2, 3], np.int32),
                    max_new=4))
    done = eng.run()
    assert len(done) == 1
    rep = eng.fabric_report()
    assert rep is not None and rep["autotuned"] and rep["projections"] == 2
    assert probe.search is not None
    assert len(probe.search.schedule.gemms) == 2
