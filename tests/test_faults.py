"""Fault tolerance: injection, parity scrub, spare repair, degradation.

Layered like the stack itself (docs/faults.md):

* the :class:`~repro.core.faults.FaultModel` process -- seeded
  determinism, inert-by-default, RNG discipline (scrub on/off replay
  the same flips);
* 2-D parity math -- odd flips detected, the 4-flip rectangle blind
  spot pinned as *documented* behaviour;
* the protected engine paths (``execute_blocks``/``run_chain``) --
  scrub-on bit-exact vs the clean run, scrub-off escapes;
* the fabric -- scrub-on exactness with priced overhead, scrub-off
  escapes, dead-block spare remap, spare-less degraded reschedule,
  and the ``FabricFaultError`` terminal case;
* the probe oracle + serve fallback seam;
* the fuzzer fault family and its committed two-sided corpus pin.
"""

import dataclasses
import pathlib

import numpy as np
import pytest

from repro.core import costmodel, engine, fuzz
from repro.core import faults as fc
from repro.core.faults import FabricFaultError, FaultModel
from repro.pim import fabric
from repro.pim.fabric import FabricConfig

CORPUS = pathlib.Path(__file__).parent / "corpus"


def _grid(n_blocks=8, **kw):
    return FabricConfig(n_blocks=n_blocks, rows=128, cols=16, **kw)


def _gemm(rng, m=6, k=40, n=5):
    x = rng.integers(-8, 8, (m, k)).astype(np.int64)
    w = rng.integers(-8, 8, (k, n)).astype(np.int64)
    return x, w


# ---------------------------------------------------------------------------
# FaultModel process
# ---------------------------------------------------------------------------
def test_fault_model_inert_by_default():
    fm = FaultModel()
    assert not fm.active
    # bit_rate 0: the flip mask is empty but the event still counts
    mask = fm.flip_mask((2, 4, 4))
    assert not mask.any() and fm.injection_events == 1
    assert fm.injected_flips == 0


def test_fault_model_validation():
    with pytest.raises(ValueError):
        FaultModel(bit_rate=1.5)
    with pytest.raises(ValueError):
        FaultModel(bit_rate=-0.1)
    with pytest.raises(ValueError):
        FaultModel(scrub_every=0)
    # dead block ids are deduped + sorted
    assert FaultModel(dead_blocks=(3, 1, 3)).dead_blocks == (1, 3)


def test_fault_model_seed_determinism_and_reset():
    a = FaultModel(bit_rate=0.05, seed=7)
    b = FaultModel(bit_rate=0.05, seed=7)
    m1, m2 = a.flip_mask((3, 16, 8)), b.flip_mask((3, 16, 8))
    assert np.array_equal(m1, m2) and m1.any()
    a.reset()
    assert a.injection_events == 0
    assert np.array_equal(a.flip_mask((3, 16, 8)), m2)


def test_rng_advances_identically_with_scrub_on_or_off():
    """Scrub must not perturb the draw sequence: same seed, different
    scrub settings, identical flips -- the two-sided replay property."""
    on = FaultModel(bit_rate=0.03, seed=3, scrub=True)
    off = FaultModel(bit_rate=0.03, seed=3, scrub=False)
    for _ in range(4):
        assert np.array_equal(on.flip_mask((2, 8, 8)),
                              off.flip_mask((2, 8, 8)))


def test_heal_after_stops_injection_but_advances_rng():
    fm = FaultModel(bit_rate=0.5, seed=0, heal_after=2)
    assert fm.flip_mask((1, 8, 8)).any()
    assert fm.flip_mask((1, 8, 8)).any()
    assert fm.healed
    assert not fm.flip_mask((1, 8, 8)).any()      # healed: no flips
    assert fm.injection_events == 3               # ...but still counted


def test_scrub_cadence():
    fm = FaultModel(bit_rate=0.1, scrub_every=3)
    assert [fm.should_scrub(p) for p in range(6)] == \
        [True, False, False, True, False, False]
    assert not FaultModel(bit_rate=0.1, scrub=False).should_scrub(0)


# ---------------------------------------------------------------------------
# Parity math
# ---------------------------------------------------------------------------
def test_parity_detects_odd_flip_patterns(rng):
    base = rng.integers(0, 2, (4, 16, 8)).astype(bool)
    sig = fc.parity_signature(base)
    assert not fc.dirty_blocks(base, sig).any()
    for nflips in (1, 2, 3, 5):
        cur = base.copy()
        rows = rng.choice(16, nflips, replace=False)
        cols = rng.choice(8, nflips, replace=False)
        for r, c in zip(rows, cols):       # distinct rows AND cols: odd
            cur[1, r, c] ^= True           # parity in every touched line
        assert list(fc.dirty_blocks(cur, sig)) == [False, True, False,
                                                   False]


def test_parity_rectangle_blind_spot_is_documented():
    """The 4-flip rectangle is the smallest undetectable pattern --
    pinned so a silent parity upgrade (or regression) shows up here."""
    base = np.zeros((1, 16, 8), bool)
    sig = fc.parity_signature(base)
    cur = base.copy()
    for r, c in ((2, 1), (2, 5), (9, 1), (9, 5)):
        cur[0, r, c] ^= True
    assert not fc.dirty_blocks(cur, sig).any()


def test_scrub_restores_and_charges(rng):
    pristine = rng.integers(0, 2, (3, 16, 8)).astype(bool)
    sig = fc.parity_signature(pristine)
    cur = pristine.copy()
    cur[2, 5, 3] ^= True
    fm = FaultModel(bit_rate=0.1)
    out = fc.scrub_states(cur, pristine, sig, fm)
    assert np.array_equal(out, pristine)
    assert fm.detected == fm.repaired == 1
    assert fm.refetch_bits == 16 * 8          # one dirty block re-fetched
    assert fm.scrub_rows == 3 * 16            # ...but every row verified


def test_inject_dead_block_reads_garbage_not_zeros(rng):
    arrays = rng.integers(0, 2, (3, 32, 8)).astype(bool)
    fm = FaultModel(dead_blocks=(1,), seed=0)
    out = fc.inject(arrays.copy(), fm)
    assert np.array_equal(out[0], arrays[0])
    assert np.array_equal(out[2], arrays[2])
    assert not np.array_equal(out[1], arrays[1])
    assert 0 < out[1].sum() < out[1].size     # garbage, not all-0/all-1
    # the fabric convention: dead ids are grid positions, not batch
    # slots -- an explicit empty dead_slots leaves the batch alone
    out2 = fc.inject(arrays.copy(), FaultModel(dead_blocks=(1,), seed=0),
                     dead_slots=())
    assert np.array_equal(out2, arrays)


# ---------------------------------------------------------------------------
# Protected engine paths
# ---------------------------------------------------------------------------
def _fuzz_case(seed=3):
    fp = fuzz.gen_program(seed, fuzz.FuzzConfig())
    states = fuzz.gen_state(seed, fp.cfg, blocks=fp.cfg.blocks)
    return fp.program, states


def test_execute_blocks_scrub_on_is_bit_exact():
    prog, states = _fuzz_case()
    want = engine.execute_blocks(prog, states, "compiled")
    fm = FaultModel(bit_rate=3e-3, seed=1)
    got = engine.execute_blocks(prog, states, "compiled", faults=fm)
    assert fm.injected_flips > 0 and fm.detected > 0
    assert fm.repaired == fm.detected
    for f in ("array", "carry", "tag"):
        assert np.array_equal(np.asarray(getattr(got, f)),
                              np.asarray(getattr(want, f))), f


def test_execute_blocks_scrub_off_escapes():
    prog, states = _fuzz_case()
    want = engine.execute_blocks(prog, states, "compiled")
    fm = FaultModel(bit_rate=3e-3, seed=1, scrub=False)
    got = engine.execute_blocks(prog, states, "compiled", faults=fm)
    assert fm.injected_flips > 0 and fm.repaired == 0
    assert not np.array_equal(np.asarray(got.array),
                              np.asarray(want.array))


def test_run_chain_injects_between_programs():
    prog, _ = _fuzz_case()
    state = fuzz.gen_state(3, fuzz.FuzzConfig())
    want = engine.run_chain([prog, prog], state)
    fm = FaultModel(bit_rate=2e-3, seed=4)
    got = engine.run_chain([prog, prog], state, faults=fm)
    assert fm.injection_events == 2           # one point per chained leg
    for f in ("array", "carry", "tag"):
        assert np.array_equal(np.asarray(getattr(got, f)),
                              np.asarray(getattr(want, f))), f


# ---------------------------------------------------------------------------
# Fabric: scrub, spares, degraded grid
# ---------------------------------------------------------------------------
def test_fabric_scrub_on_exact_and_priced(rng):
    x, w = _gemm(rng)
    clean = fabric.fabric_matmul(x, w, nbits=4, signed=True, cfg=_grid())
    fm = FaultModel(bit_rate=2e-3, seed=0)
    res = fabric.fabric_matmul(x, w, nbits=4, signed=True, cfg=_grid(),
                               faults=fm)
    assert np.array_equal(np.asarray(res.out, np.int64), x @ w)
    assert fm.injected_flips > 0 and fm.escaped == 0
    # the scrub/parity/re-fetch overhead is priced, not free
    assert res.cost.energy_pj > clean.cost.energy_pj
    assert "+faults" in res.cost.name


def test_fabric_scrub_off_escapes(rng):
    x, w = _gemm(rng)
    fm = FaultModel(bit_rate=2e-3, seed=0, scrub=False)
    res = fabric.fabric_matmul(x, w, nbits=4, signed=True, cfg=_grid(),
                               faults=fm)
    assert fm.injected_flips > 0
    assert not np.array_equal(np.asarray(res.out, np.int64), x @ w)


def test_fabric_spare_remap_is_bit_exact(rng):
    x, w = _gemm(rng)
    cfg = _grid(8, spare_blocks=2)
    assert cfg.spare_ids == (6, 7) and cfg.usable_blocks == 6
    fm = FaultModel(dead_blocks=(2,), seed=0)
    res = fabric.fabric_matmul(x, w, nbits=4, signed=True, cfg=cfg,
                               faults=fm)
    assert np.array_equal(np.asarray(res.out, np.int64), x @ w)
    assert fm.remaps == 1
    assert res.schedule.modes[2] == "dead"
    # exactly one spare took over, inheriting a live mode
    taken = [b for b in cfg.spare_ids
             if res.schedule.modes[b] != "spare"]
    assert len(taken) == 1
    assert res.schedule.modes[taken[0]] in ("compute", "storage")
    assert "dead" in res.schedule.describe()


def test_fabric_degraded_reschedule_without_spares(rng):
    x, w = _gemm(rng)
    fm = FaultModel(dead_blocks=(1, 3), seed=0)
    res = fabric.fabric_matmul(x, w, nbits=4, signed=True, cfg=_grid(8),
                               faults=fm)
    assert np.array_equal(np.asarray(res.out, np.int64), x @ w)
    assert res.schedule.cfg.n_blocks == 6     # re-planned on survivors
    assert fm.remaps == 2


def test_fabric_all_dead_raises(rng):
    x, w = _gemm(rng)
    fm = FaultModel(dead_blocks=(0, 1), seed=0)
    with pytest.raises(FabricFaultError):
        fabric.fabric_matmul(x, w, nbits=4, signed=True, cfg=_grid(2),
                             faults=fm)


def test_unrepaired_dead_block_refuses_to_launch(rng):
    """execute_program must not silently launch a grid whose schedule
    still uses a block the fault model says is dead."""
    x = rng.integers(0, 16, (6, 40)).astype(np.uint64)
    w = rng.integers(0, 16, (40, 5)).astype(np.uint64)
    sched = fabric.schedule_program(
        (fabric.GemmSpec("g", 6, 40, 5),), nbits=4, cfg=_grid(4))
    used = [b for b in range(4) if sched.modes[b] in ("compute", "storage")]
    fm = FaultModel(dead_blocks=(used[0],), seed=0)
    with pytest.raises(FabricFaultError):
        fabric.execute_program(sched, x, (w,), faults=fm)


def test_spare_blocks_config_validation():
    with pytest.raises(ValueError):
        FabricConfig(n_blocks=4, spare_blocks=-1)
    with pytest.raises(ValueError):
        # reserving every block leaves nothing to compute on
        FabricConfig(n_blocks=4, spare_blocks=4)
    cfg = FabricConfig(n_blocks=4, spare_blocks=0)
    assert cfg.spare_ids == () and cfg.usable_blocks == 4


# ---------------------------------------------------------------------------
# Fault repairs vs persistent sessions (docs/fabric.md + docs/faults.md):
# a repair that rewrites or restores a block must never leave a stale
# resident-tile entry behind -- stale residency is silent wrong reuse
# in the cost model on the NEXT decode step.
# ---------------------------------------------------------------------------
def test_spare_remap_invalidates_session_residency(rng):
    x, w = _gemm(rng)
    cfg = _grid(8, spare_blocks=2)
    sess = fabric.FabricSession(cfg)
    sess.begin_step()
    fabric.fabric_matmul(x, w, nbits=4, signed=True, cfg=cfg, session=sess)
    dead = next(b for b, r in sess.resident.items() if r)
    fm = FaultModel(dead_blocks=(dead,), seed=0)
    sess.begin_step()
    res = fabric.fabric_matmul(x, w, nbits=4, signed=True, cfg=cfg,
                               faults=fm, session=sess)
    assert np.array_equal(np.asarray(res.out, np.int64), x @ w)
    assert fm.remaps == 1
    # the dead block's map is gone; its spare starts COLD (it inherited
    # the mode and the tasks, not the tiles)
    assert dead not in sess.resident
    spare = next(s for s in cfg.spare_ids if sess.modes[s] != "spare")
    assert sess.resident.get(spare) == {}
    assert sess.modes[dead] == "dead"
    # no surviving home pointer may still name the dead block
    assert dead not in sess.w_homes.values()
    assert all(b != dead for b, _ in sess._x_alloc)


def test_scrub_restore_invalidates_session_residency(rng):
    """A pristine-image scrub restore refetches ONLY that launch's
    packed operands -- everything else the block's resident map claimed
    must be dropped, so the next step refetches instead of reusing."""
    x, w = _gemm(rng)
    cfg = _grid()
    sess = fabric.FabricSession(cfg)
    for _ in range(2):
        sess.begin_step()
        fabric.fabric_matmul(x, w, nbits=4, signed=True, cfg=cfg,
                             session=sess)
    assert sess.steps[-1]["w_fetches"] == 0        # warm before the fault
    fm = FaultModel(bit_rate=2e-2, seed=0)
    sess.begin_step()
    res = fabric.fabric_matmul(x, w, nbits=4, signed=True, cfg=cfg,
                               faults=fm, session=sess)
    assert np.array_equal(np.asarray(res.out, np.int64), x @ w)
    assert fm.injected_flips > 0 and fm.escaped == 0
    sess.begin_step()
    fabric.fabric_matmul(x, w, nbits=4, signed=True, cfg=cfg, session=sess)
    assert sess.steps[-1]["w_fetches"] > 0         # scrubbed -> refetch


def test_degraded_reschedule_resets_session(rng):
    """Not enough spares: the dense renumbering of the degraded grid
    invalidates every home and resident entry, so the whole session
    goes back to cold (and re-warms on the next program)."""
    x, w = _gemm(rng)
    cfg = _grid(8)
    sess = fabric.FabricSession(cfg)
    sess.begin_step()
    fabric.fabric_matmul(x, w, nbits=4, signed=True, cfg=cfg, session=sess)
    assert sess.programs == 1
    fm = FaultModel(dead_blocks=(1, 3), seed=0)
    sess.begin_step()
    res = fabric.fabric_matmul(x, w, nbits=4, signed=True, cfg=cfg,
                               faults=fm, session=sess)
    assert np.array_equal(np.asarray(res.out, np.int64), x @ w)
    assert res.schedule.cfg.n_blocks == 6
    # the degraded replan ran sessionless: the session is fully cold
    assert sess.modes is None and sess.programs == 0
    assert not sess.resident and not sess.w_homes


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------
def test_fault_cost_pins():
    c = costmodel.fault_cost("t", n_blocks=8, cols=32, parity_bits=160,
                             scrub_rows=100, refetch_bits=512,
                             edge_hops=2.0)
    # rows: 100 scrubbed + ceil(160/32) parity + ceil(512/32) re-fetch
    assert c.storage_rows_touched == 100 + 5 + 16
    assert c.fabric_bits_moved == 160 + 512
    assert c.ops == 0 and c.energy_pj > 0
    zero = costmodel.fault_cost("z", n_blocks=8, cols=32, parity_bits=0,
                                scrub_rows=0, refetch_bits=0)
    assert zero.energy_pj == 0.0


# ---------------------------------------------------------------------------
# Probe oracle + serve fallback seam
# ---------------------------------------------------------------------------
def test_probe_escape_raises_and_ref_path_serves(rng):
    w = rng.normal(size=(16, 6)).astype(np.float32)
    x = rng.normal(size=(2, 16)).astype(np.float32)
    fm = FaultModel(bit_rate=0.05, seed=0, scrub=False)
    probe = fabric.FabricLinearProbe(w, cfg=_grid(4), bits=8, faults=fm)
    with pytest.raises(FabricFaultError):
        probe.observe(x)
    assert probe.escaped_outputs == 1 and fm.escaped == 1
    # the fallback path is the host quantized matmul, probe-exact
    clean = fabric.FabricLinearProbe(w, cfg=_grid(4), bits=8)
    assert np.allclose(probe.observe_ref(x), clean.observe(x))
    assert fm.stats()["escaped"] == 1


def test_probe_scrub_on_observes_clean(rng):
    w = rng.normal(size=(16, 6)).astype(np.float32)
    x = rng.normal(size=(2, 16)).astype(np.float32)
    fm = FaultModel(bit_rate=2e-3, seed=0, scrub=True)
    probe = fabric.FabricLinearProbe(w, cfg=_grid(4), bits=8, faults=fm)
    clean = fabric.FabricLinearProbe(w, cfg=_grid(4), bits=8)
    assert np.allclose(probe.observe(x), clean.observe(x))
    assert probe.escaped_outputs == 0


# ---------------------------------------------------------------------------
# Fuzzer fault family + committed two-sided corpus pin
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", [0, 5, 11])
def test_fuzz_faults_variant_clean(seed):
    rep = fuzz.replay(fuzz.gen_program(seed, fuzz.FuzzConfig()),
                      variants=("faults",))
    assert rep.ok, [f"{m.variant}/{m.field}: {m.detail}"
                    for m in rep.mismatches]


def test_fuzz_faults_variant_catches_forced_escape():
    cfg = fuzz.FuzzConfig(fault_rate=5e-3, fault_scrub=False)
    stats = fuzz.run_budget(5, seed=0, cfg=cfg, corpus_dir=None,
                            do_shrink=False)
    assert stats["mismatch"] is not None
    assert any(m.variant == "faults"
               for m in stats["mismatch"].mismatches)


def test_fault_corpus_two_sided():
    """The committed repro: bit-exact as-committed (scrub on), escaping
    with the *identical* flip sequence once the scrub is off."""
    fp, pins = fuzz.load_corpus(CORPUS / "fuzz_faults.txt")
    assert fp.cfg.fault_scrub and fp.cfg.fault_rate > 0
    assert fp.program.cycles() == pins["cycles"]
    assert fuzz.replay(fp, variants=("faults",)).ok
    off = fp.with_groups(
        fp.groups, cfg=dataclasses.replace(fp.cfg, fault_scrub=False))
    rep = fuzz.replay(off, variants=("faults",))
    assert not rep.ok
    assert all(m.variant == "faults" for m in rep.mismatches)


def test_fault_knobs_roundtrip_through_corpus_text():
    fp = fuzz.gen_program(2, fuzz.FuzzConfig(fault_rate=0.25,
                                             fault_seed=99,
                                             fault_scrub=False))
    fp2, _pins = fuzz.program_from_text(fuzz.program_to_text(fp))
    assert fp2.cfg.fault_rate == 0.25
    assert fp2.cfg.fault_seed == 99
    assert fp2.cfg.fault_scrub is False
