"""Multi-block execution: ragged block counts, canonical compile
budgets, and the packed-resident replay paths.

`engine.execute_blocks` simulates B blocks as ONE wide block of B*C
columns, rounds B up to a canonical budget (zero-padding the batch) so a
single compiled fn serves a whole range of ragged counts, and -- since
the packed-by-default policy -- runs the interior on uint32 bit planes.
These tests pin all of that bit-exactly against the unroll oracle, pin
the cache behaviour the budgets exist for, and pin the packed-resident
forms (`pack_block_states` / `compile_packed` / `run_chain`) that keep
state packed across chained launches.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import engine, floatprog, harness, programs, ref
from repro.core.floatprog import FP8_E4M3


def _states_equal(a, b):
    return all(np.array_equal(np.asarray(getattr(a, f)),
                              np.asarray(getattr(b, f)))
               for f in ("array", "carry", "tag"))


def _rand_block_states(rng, blocks, rows, cols):
    return engine.CRState(
        array=jnp.asarray(rng.integers(0, 2, (blocks, rows, cols))
                          .astype(bool)),
        carry=jnp.asarray(rng.integers(0, 2, (blocks, cols)).astype(bool)),
        tag=jnp.asarray(rng.integers(0, 2, (blocks, cols)).astype(bool)))


# ---------------------------------------------------------------------------
# Ragged block counts x executors x packed, bit-identical
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("blocks", [1, 3, 17, 65])
def test_ragged_blocks_bit_identity(rng, blocks):
    """blocks in {1, 3, 17, 65} hit four different canonical budgets
    (1, 4, 32, 128): every one must be bit-identical to the vmapped
    unroll oracle through scan and both compiled representations, with
    the zero-padded tail sliced away (65 -> budget 128 exercises a pad
    bigger than the batch itself)."""
    prog, _ = programs.idot(4, rows=128)
    states = _rand_block_states(rng, blocks, 128, 8)
    ref_out = engine.execute_blocks(prog, states, "unroll")
    assert ref_out.array.shape == states.array.shape
    scan = engine.execute_blocks(prog, states, "scan")
    assert _states_equal(ref_out, scan)
    for packed in (False, True, None):        # None = policy default
        comp = engine.execute_blocks(prog, states, "compiled",
                                     packed=packed)
        assert comp.array.shape == states.array.shape
        assert _states_equal(ref_out, comp), f"packed={packed}"


def test_canonical_block_budget_values():
    assert [engine.canonical_block_budget(b) for b in
            (1, 2, 3, 4, 5, 17, 64, 65, 512)] \
        == [1, 2, 4, 4, 8, 32, 64, 128, 512]
    # above the largest budget the count passes through unchanged (the
    # fabric chunks its batches at MAX_BATCH_BLOCKS=512 already)
    assert engine.canonical_block_budget(513) == 513


def test_blocks_budget_cache_reuse(rng):
    """Ragged counts within one budget share ONE compiled fn: replaying
    blocks 5..8 after a cold 5-block launch may compile once (budget 8)
    and must then be pure cache hits -- the per-distinct-count
    recompiles the budgets eliminate."""
    prog, _ = programs.iadd(8, rows=64)
    rows, cols = 64, 8
    engine.execute_blocks(prog, _rand_block_states(rng, 5, rows, cols))
    s0 = engine.compile_cache_stats()
    for blocks in (6, 7, 8, 5):
        out = engine.execute_blocks(
            prog, _rand_block_states(rng, blocks, rows, cols))
        assert out.array.shape == (blocks, rows, cols)
    s1 = engine.compile_cache_stats()
    assert s1["misses"] == s0["misses"], \
        "block counts 5-8 must reuse the budget-8 compiled fn"
    assert s1["hits"] >= s0["hits"] + 4


def test_default_packed_policy():
    """Small programs resolve packed=None to the uint32 interior; the
    big flat float sequences stay on the bool interior (their plane-
    domain chains compile pathologically on CPU XLA)."""
    assert engine.default_packed(programs.iadd(8)[0])
    assert engine.default_packed(programs.idot(4)[0])
    assert not engine.default_packed(programs.bf16_dot(rows=512)[0])
    assert not engine.default_packed(programs.fp8_dot(rows=512)[0])


# ---------------------------------------------------------------------------
# Packed-resident replay: pack once, launch N times, unpack once
# ---------------------------------------------------------------------------
def test_pack_block_states_roundtrip(rng):
    states = _rand_block_states(rng, 5, 32, 8)
    wide = engine.pack_block_states(states)
    assert wide.array.dtype == jnp.uint32
    back = engine.unpack_block_states(wide, 5, 8)
    assert _states_equal(states, back)


def test_packed_resident_replay_bit_identity(rng):
    """Three chained launches on packed-resident state == three
    sequential unroll launches on the bool batch."""
    prog, _ = programs.idot(4, rows=128)
    blocks, rows, cols = 5, 128, 8
    states = _rand_block_states(rng, blocks, rows, cols)
    fn = engine.compile_packed(prog, rows, blocks * cols)
    wide = engine.pack_block_states(states)
    for _ in range(3):
        wide = fn(wide)
    got = engine.unpack_block_states(wide, blocks, cols)
    want = states
    for _ in range(3):
        want = engine.execute_blocks(prog, want, "unroll")
    assert _states_equal(got, want)


def test_run_chain_matches_sequential(rng):
    """A fused packed chain of distinct small programs == running them
    one launch at a time through the unroll oracle (satellite: small-
    program replay keeps state packed across chained launches)."""
    chain = [programs.iadd(8, rows=128)[0],
             programs.imul(4, rows=128)[0],
             programs.idot(4, rows=128)[0],
             programs.iadd(8, rows=128)[0]]   # repeat: same body reused
    state = engine.CRState(
        array=jnp.asarray(rng.integers(0, 2, (128, 8)).astype(bool)),
        carry=jnp.asarray(rng.integers(0, 2, 8).astype(bool)),
        tag=jnp.asarray(rng.integers(0, 2, 8).astype(bool)))
    got = engine.run_chain(chain, state)
    want = state
    for p in chain:
        want = engine.run(p, want, "unroll")
    assert _states_equal(got, want)
    assert engine.run_chain([], state) is state


def test_run_chain_is_cached(rng):
    state = engine.make_state(64, 8)
    chain = [programs.iadd(4, rows=64)[0], programs.iadd(4, rows=64)[0]]
    engine.run_chain(chain, state)
    s0 = engine.compile_cache_stats()
    engine.run_chain(chain, state)
    s1 = engine.compile_cache_stats()
    assert s1["misses"] == s0["misses"] and s1["hits"] == s0["hits"] + 1


# ---------------------------------------------------------------------------
# float_dot wide-accumulator chaining across ragged compiled launches
# ---------------------------------------------------------------------------
def _bits(rng, fmt, shape):
    s = rng.integers(0, 2, shape).astype(np.uint64)
    e = rng.integers(1, (1 << fmt.ebits) - 1, shape).astype(np.uint64)
    m = rng.integers(0, 1 << fmt.mbits, shape).astype(np.uint64)
    return (s << np.uint64(fmt.ebits + fmt.mbits)) \
        | (e << np.uint64(fmt.mbits)) | m


def test_float_dot_wide_acc_chain_ragged_blocks(rng):
    """A K-tiled float dot chained through fdot_set_acc across TWO
    ragged compiled execute_blocks launches (3 blocks -> budget 4,
    zero-padded) matches the float reference oracle per block."""
    fmt = FP8_E4M3
    cap, K, blocks, cols = 3, 5, 3, 8
    a = _bits(rng, fmt, (blocks, K, cols))
    b = _bits(rng, fmt, (blocks, K, cols))

    def launch(tuples, a_t, b_t, accs):
        prog, lay = floatprog.float_dot(fmt, rows=512, tuples=tuples)
        imgs = []
        for i in range(blocks):
            img = harness.pack_state(
                lay, {"a": a_t[i], "b": b_t[i]}, cols)
            if accs is not None:
                floatprog.fdot_set_acc(img, fmt, accs[i])
            imgs.append(img)
        states = engine.CRState(
            array=jnp.asarray(np.stack(imgs)),
            carry=jnp.zeros((blocks, cols), bool),
            tag=jnp.ones((blocks, cols), bool))
        out = engine.execute_blocks(prog, states, "compiled")
        assert _states_equal(out,
                             engine.execute_blocks(prog, states, "scan"))
        return np.asarray(out.array)

    arr1 = launch(cap, a[:, :cap], b[:, :cap], None)
    accs = [floatprog.fdot_acc(arr1[i], fmt) for i in range(blocks)]
    arr2 = launch(K - cap, a[:, cap:], b[:, cap:], accs)
    for i in range(blocks):
        got = floatprog.fdot_result(arr2[i], fmt)
        want, _ = ref.float_dot_acc(a[i], b[i], fmt.ebits, fmt.mbits)
        np.testing.assert_array_equal(got, want)


@pytest.mark.slow
def test_run_chain_bf16_gemmspec_packed_resident(rng):
    """TWO launches of a bf16 GemmSpec's class program chained through
    packed-resident state (the PR 6 surface) == the sequential unpacked
    unroll path, bit-for-bit.

    Until now only int programs were pinned through run_chain /
    pack_block_states; the float class programs take the packed_io
    lowering through an entirely different plane-domain path, and their
    packed compiles are slow -- hence the slow marker, and rows=384:
    the shallowest bf16 fdot geometry, whose capacity-1 program
    (~1k cycles) is what the GemmSpec schedule resolves to there.
    """
    from repro.pim import fabric
    from repro.pim.fabric import FabricConfig, GemmSpec

    cfg = FabricConfig(n_blocks=2, rows=384, cols=8, executor="scan")
    sched = fabric.schedule_program(
        (GemmSpec("o", 2, 1, 3, "bf16"),), 8, cfg=cfg)
    prog, _lay = sched.class_program("bf16")
    assert sched.class_kt("bf16") == 1        # keeps the compile bounded

    blocks, rows, cols = 2, cfg.rows, cfg.cols
    states = _rand_block_states(rng, blocks, rows, cols)

    # the fused block batch as ONE wide block (pack_block_states'
    # transform, pre-packing) -- run_chain packs it once, replays both
    # launches on uint32 words, unpacks once
    wide = engine.CRState(
        array=jnp.moveaxis(states.array, 0, 1).reshape(rows,
                                                       blocks * cols),
        carry=states.carry.reshape(blocks * cols),
        tag=states.tag.reshape(blocks * cols))
    out = engine.run_chain([prog, prog], wide)
    got = engine.CRState(
        array=jnp.moveaxis(out.array.reshape(rows, blocks, cols), 1, 0),
        carry=out.carry.reshape(blocks, cols),
        tag=out.tag.reshape(blocks, cols))

    # sequential unpacked oracle
    want = states
    for _ in range(2):
        want = engine.execute_blocks(prog, want, "unroll")
    assert _states_equal(got, want)
