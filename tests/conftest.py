"""Shared test configuration.

Environment is pinned BEFORE jax is imported anywhere: tests always run
on CPU with 4 virtual host devices (so sharding/mesh tests see a multi-
device topology deterministically, even on GPU build hosts).
"""

import os
import random

# must happen before `import jax` in any test module -- conftest is
# imported by pytest before collection of the test modules themselves
os.environ.setdefault("JAX_PLATFORMS", "cpu")
if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=4").strip()

import numpy as np
import pytest


@pytest.fixture(autouse=True)
def _seed_global_rngs():
    """Every test starts from the same global RNG state."""
    random.seed(0)
    np.random.seed(0)


@pytest.fixture
def rng():
    """Seeded numpy Generator for tests that want local randomness."""
    return np.random.default_rng(0)


@pytest.fixture
def jax_key():
    import jax

    return jax.random.PRNGKey(0)
