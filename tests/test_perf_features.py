"""Beyond-paper perf features: int8 KV cache, hierarchical MoE dispatch,
storage-mode quantized weights (EXPERIMENTS.md §Perf)."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import qweight
from repro.models.model import LM


@pytest.mark.parametrize("bits,tol", [(8, 0.05), (4, 0.25)])
def test_kv_quant_decode_matches_prefill(bits, tol):
    cfg = configs.get_config("llama3.2-1b", smoke=True)
    cfg = dataclasses.replace(cfg, kv_quant_bits=bits)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(2))
    b, s = 2, 8
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s + 1)), jnp.int32)

    full, _ = model.apply(params, tokens=toks)
    _, caches = model.prefill(params, tokens=toks[:, :s], capacity=s + 1)
    step_logits, _ = model.decode_step(params, caches, toks[:, s:s + 1],
                                       jnp.full((b,), s, jnp.int32))
    got = np.asarray(step_logits[:, 0], np.float32)
    want = np.asarray(full[:, s], np.float32)
    err = np.abs(got - want).mean() / (np.abs(want).mean() + 1e-6)
    assert err < tol
    # packed cache really is smaller
    kv_bytes = sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(caches))
    cfg_full = dataclasses.replace(cfg, kv_quant_bits=None)
    _, caches_f = LM(cfg_full).prefill(
        params, tokens=toks[:, :s], capacity=s + 1)
    kv_full = sum(x.size * x.dtype.itemsize
                  for x in jax.tree.leaves(caches_f))
    assert kv_bytes < kv_full * (0.65 if bits == 8 else 0.45)


def test_moe_chunked_dispatch_equivalent():
    """With no-drop capacity, hierarchical dispatch == global dispatch."""
    cfg = configs.get_config("granite-moe-3b-a800m", smoke=True)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32)

    out1, _ = model.apply(params, tokens=toks)

    cfg4 = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, dispatch_chunks=4))
    out2, _ = LM(cfg4).apply(params, tokens=toks)
    np.testing.assert_allclose(np.asarray(out1, np.float32),
                               np.asarray(out2, np.float32),
                               rtol=0.02, atol=0.02)


@pytest.mark.parametrize("bits", [8, 4])
def test_quantized_weight_forward(bits):
    cfg = configs.get_config("llama3.2-1b", smoke=True)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(5))
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 12)), jnp.int32)

    ref, _ = model.apply(params, tokens=toks)
    qp = qweight.quantize_tree(params, bits=bits)
    # storage shrinks ~2x (w8) / ~4x (w4) for the weight-dominated tree
    ratio = qweight.tree_bytes(params) / qweight.tree_bytes(qp)
    assert ratio > (1.7 if bits == 8 else 2.8), ratio
    got, _ = model.apply(qp, tokens=toks)
    r = np.asarray(ref, np.float32)
    g = np.asarray(got, np.float32)
    rel = np.abs(g - r).mean() / (np.abs(r).mean() + 1e-6)
    # w4 uses per-(layer, out-channel) scales; tiny random-init models
    # inflate the relative logit error (production W4 adds group-wise
    # scales -- noted in DESIGN.md as future work)
    assert rel < (0.05 if bits == 8 else 0.5), rel


def test_packed_weight_exact_roundtrip():
    # values on the exact int4 grid: amax = 7*s  =>  scale == s
    rng = np.random.default_rng(0)
    ints = rng.integers(-7, 8, (64, 32))
    ints[0, 0] = 7                         # pin amax
    w = jnp.asarray(ints, jnp.float32) * 0.01
    q = qweight._quantize_leaf(w, 4)
    assert isinstance(q, qweight.PackedWeight)
    back = qweight.dq(q, jnp.float32)
    np.testing.assert_allclose(np.asarray(back), np.asarray(w), atol=1e-6)
