"""Pallas kernels vs pure-jnp oracles (interpret mode; shape/dtype sweeps)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand_int(rng, bits, shape):
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1))
    return jnp.asarray(rng.integers(lo, hi, shape), jnp.int8)


@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("axis", [0, 1])
def test_pack_unpack_roundtrip(bits, axis):
    rng = np.random.default_rng(0)
    x = _rand_int(rng, bits, (64, 32))
    planes = ref.pack_bitplanes(x, bits, axis=axis)
    back = ref.unpack_bitplanes(planes, axis=axis, signed=True)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x, np.int32))


@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("mnk", [(16, 128, 64), (32, 256, 128),
                                 (128, 128, 512)])
def test_quant_matmul_vs_oracle(bits, mnk):
    m, n, k = mnk
    rng = np.random.default_rng(1)
    a = _rand_int(rng, 8, (m, k))
    w = _rand_int(rng, bits, (k, n))
    scale = jnp.asarray(rng.uniform(0.001, 0.1, n), jnp.float32)
    wp = ref.pack_bitplanes(w, bits, axis=0)
    got = ops.quant_matmul(a, wp, scale, bits=bits, interpret=True,
                           block_m=16, block_n=64, block_k=64)
    want = ref.quant_matmul(a, wp, scale, bits=bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6)
    # and against plain integer matmul (exactness of the decomposition)
    exact = (np.asarray(a, np.int64) @ np.asarray(w, np.int64)
             ).astype(np.float32) * np.asarray(scale)[None, :]
    np.testing.assert_allclose(np.asarray(got), exact, rtol=1e-6)


@pytest.mark.parametrize("ba,bw", [(4, 4), (8, 4), (4, 8)])
def test_popcount_matmul_vs_oracle(ba, bw):
    m, n, k = 16, 64, 128
    rng = np.random.default_rng(2)
    a = _rand_int(rng, ba, (m, k))
    w = _rand_int(rng, bw, (k, n))
    ap = ref.pack_bitplanes(a, ba, axis=1)
    wp = ref.pack_bitplanes(w, bw, axis=0)
    got = ops.popcount_matmul(ap, wp, interpret=True,
                              block_m=8, block_n=32, block_k=64)
    want = np.asarray(a, np.int64) @ np.asarray(w, np.int64)
    np.testing.assert_array_equal(np.asarray(got), want)
    oracle = ref.popcount_matmul(ap, wp, a_signed=True, w_signed=True)
    np.testing.assert_array_equal(np.asarray(oracle), want)


def test_popcount_matches_engine_semantics():
    """Cross-layer: Pallas popcount path == Compute RAM engine idot.

    Both implement sum_t a_t*b_t by bit-level AND/add -- verify they
    agree end-to-end (unsigned int4, one output column per CR column).
    """
    from repro.core import harness, programs
    from repro.core import ref as cref
    rng = np.random.default_rng(3)
    prog, lay = programs.idot(4, rows=128)
    cols = 8
    a = rng.integers(0, 16, (lay.tuples, cols), dtype=np.uint64)
    b = rng.integers(0, 16, (lay.tuples, cols), dtype=np.uint64)
    got_engine = harness.unpack_acc(
        harness.run_program(prog, lay, {"a": a, "b": b}, cols), lay)

    # same dot products via the packed kernel: per column c,
    # acc[c] = a[:, c] . b[:, c]
    K = ((lay.tuples + 31) // 32) * 32
    a_pad = np.zeros((cols, K), np.int8)
    b_pad = np.zeros((K, cols), np.int8)
    a_pad[:, :lay.tuples] = a.T
    b_pad[:lay.tuples, :] = b
    ap = ref.pack_bitplanes(jnp.asarray(a_pad), 4, axis=1)
    wp = ref.pack_bitplanes(jnp.asarray(b_pad), 4, axis=0)
    out = ops.popcount_matmul(ap, wp, a_signed=False, w_signed=False,
                              interpret=True, block_m=8, block_n=8,
                              block_k=32)
    np.testing.assert_array_equal(np.diag(np.asarray(out)), got_engine)


def test_quantize_roundtrip_error():
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(0, 1, (128, 64)), jnp.float32)
    q, s = ops.quantize(x, bits=8, axis=1)
    err = np.abs(np.asarray(q, np.float32) * np.asarray(s)[None, :] -
                 np.asarray(x))
    assert err.max() < np.abs(np.asarray(x)).max() / 100


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("shape", [(2, 128, 32), (4, 256, 64)])
def test_flash_attention_vs_oracle(causal, shape):
    from repro.kernels.flash_attention import attention_ref, flash_attention
    bh, s, hd = shape
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(0, 1, (bh, s, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (bh, s, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (bh, s, hd)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                          interpret=True)
    want = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_matches_model_chunked_path():
    """Pallas kernel == the model zoo's chunked-jnp attention."""
    from repro.kernels.flash_attention import flash_attention
    from repro.models.attention import chunked_attention
    b, s, h, hd = 2, 128, 4, 32
    rng = np.random.default_rng(8)
    q = jnp.asarray(rng.normal(0, 1, (b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (b, s, h, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (b, s, h, hd)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    want = chunked_attention(q, k, v, pos, pos, causal=True, chunk=64)
    qf = jnp.moveaxis(q, 2, 1).reshape(b * h, s, hd)
    kf = jnp.moveaxis(k, 2, 1).reshape(b * h, s, hd)
    vf = jnp.moveaxis(v, 2, 1).reshape(b * h, s, hd)
    got = flash_attention(qf, kf, vf, causal=True, block_q=64, block_k=64,
                          interpret=True)
    got = jnp.moveaxis(got.reshape(b, h, s, hd), 1, 2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Bit-plane backends (repro.kernels.bitplane_ops): the ripple add and
# the lane-axis popcount fold behind the packed compiled executor
# ---------------------------------------------------------------------------
def test_planes_add_none_elision_oracle():
    """planes_add with None (known-zero) planes == dense add/sub.

    Exhausts every None/dense pattern over 4-bit operands with and
    without a carry-in; subtraction is the asymmetric case (a-0 vs 0-b
    elide differently), so both orders are covered by construction.
    """
    from itertools import product

    from repro.kernels import bitplane_ops as bp

    rng = np.random.default_rng(0)
    w = 4
    av = rng.integers(0, 2, (w, 8)).astype(np.uint32)
    bv = rng.integers(0, 2, (w, 8)).astype(np.uint32)
    cv = rng.integers(0, 2, (8,)).astype(np.uint32)
    for mask_a, mask_b, cin, sub in product(
            range(1 << w), range(1 << w), (False, True), (False, True)):
        a = [jnp.asarray(av[i]) if mask_a >> i & 1 else None
             for i in range(w)]
        b = [jnp.asarray(bv[i]) if mask_b >> i & 1 else None
             for i in range(w)]
        ad = [jnp.zeros(8, jnp.uint32) if p is None else p for p in a]
        bd = [jnp.zeros(8, jnp.uint32) if p is None else p for p in b]
        ci = jnp.asarray(cv) if cin else None
        cd = jnp.asarray(cv) if cin else jnp.zeros(8, jnp.uint32)
        got, gc = bp.planes_add(a, b, ci, sub=sub)
        want, wc = bp.planes_add(ad, bd, cd, sub=sub)
        for g, x in zip(got, want):
            gd = jnp.zeros(8, jnp.uint32) if g is None else g
            np.testing.assert_array_equal(np.asarray(gd & 1),
                                          np.asarray(x & 1))
        gcd = jnp.zeros(8, jnp.uint32) if gc is None else gc
        np.testing.assert_array_equal(np.asarray(gcd & 1),
                                      np.asarray(wc & 1))


def test_planes_add_matches_integer_arithmetic():
    """Dense planes_add == uint add/sub mod 2^w with exact carry-out."""
    from repro.kernels import bitplane_ops as bp

    rng = np.random.default_rng(1)
    w, n = 6, 64
    a = rng.integers(0, 1 << w, n)
    b = rng.integers(0, 1 << w, n)
    c = rng.integers(0, 2, n)
    for sub in (False, True):
        ap = [jnp.asarray((a >> i & 1).astype(np.uint32)) for i in range(w)]
        bpl = [jnp.asarray((b >> i & 1).astype(np.uint32)) for i in range(w)]
        out, cout = bp.planes_add(ap, bpl, jnp.asarray(c.astype(np.uint32)),
                                  sub=sub)
        got = sum(np.asarray(p & 1).astype(np.int64) << i
                  for i, p in enumerate(out))
        full = a - b - c if sub else a + b + c
        np.testing.assert_array_equal(got, full % (1 << w))
        np.testing.assert_array_equal(np.asarray(cout & 1).astype(bool),
                                      (full < 0) if sub
                                      else (full >> w).astype(bool))


@pytest.mark.parametrize("lanes,words,width", [(3, 4, 5), (8, 16, 8),
                                               (17, 33, 12)])
def test_lane_fold_pallas_matches_jnp(lanes, words, width):
    """The Pallas positional-popcount fold (interpret mode) == the jnp
    carry-save tree == per-bit integer summation, on ragged lane/word
    counts that exercise the grid padding."""
    from repro.kernels import bitplane_ops as bp

    rng = np.random.default_rng(2)
    m = min(width, 4)
    x = jnp.asarray(rng.integers(0, 1 << 32, (m, lanes, words),
                                 dtype=np.uint64).astype(np.uint32))
    got = bp.lane_fold_pallas(x, width, block_w=16, interpret=True)
    want = bp.lane_fold_jnp([x[i] for i in range(m)], width)
    for i in range(width):
        w = np.zeros(words, np.uint32) if want[i] is None \
            else np.asarray(want[i])
        np.testing.assert_array_equal(np.asarray(got[i]), w)
    # integer oracle: the column at (word wi, bit) holds, per lane, the
    # integer sum_i(plane_i_bit << i); the fold sums lanes mod 2^width
    xs = np.asarray(x, np.uint64)
    folded = np.asarray(got, np.uint64)
    for wi in range(0, words, max(1, words // 5)):
        for bit in (0, 31):
            tot = sum(sum((int(xs[i][t, wi]) >> bit & 1) << i
                          for i in range(m))
                      for t in range(lanes))
            have = sum((int(folded[i, wi]) >> bit & 1) << i
                       for i in range(width))
            assert have == tot % (1 << width), (wi, bit)


def test_use_pallas_fold_selection_rule(monkeypatch):
    """Auto mode: Pallas only for packed folds on a TPU backend above
    the column threshold; env var force-overrides either way."""
    from repro.kernels import bitplane_ops as bp

    monkeypatch.delenv(bp._ENV, raising=False)
    big = bp.PALLAS_FOLD_MIN_COLS // 32
    monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
    assert not bp.use_pallas_fold(8, big, True)
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    assert bp.use_pallas_fold(8, big, True)
    assert not bp.use_pallas_fold(1, 1, True)      # below threshold
    assert not bp.use_pallas_fold(8, big, False)   # never unpacked
    monkeypatch.setenv(bp._ENV, "jnp")
    assert not bp.use_pallas_fold(8, big, True)
    monkeypatch.setenv(bp._ENV, "pallas")
    monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
    assert bp.use_pallas_fold(1, 1, True)


def test_lane_fold_dispatch_env_override(monkeypatch):
    """lane_fold under REPRO_BITPLANE_BACKEND=pallas (interpret) is
    bit-identical to the jnp tree on packed planes."""
    from repro.kernels import bitplane_ops as bp

    rng = np.random.default_rng(3)
    width, lanes, words = 6, 5, 7
    planes = [None if i == 2 else
              jnp.asarray(rng.integers(0, 1 << 32, (lanes, words),
                                       dtype=np.uint64).astype(np.uint32))
              for i in range(width)]
    want = bp.lane_fold_jnp(planes, width)
    monkeypatch.setenv(bp._ENV, "pallas")
    got = bp.lane_fold(planes, width, packed=True, interpret=True)
    for g, w in zip(got, want):
        gd = np.zeros(words, np.uint32) if g is None else np.asarray(g)
        wd = np.zeros(words, np.uint32) if w is None else np.asarray(w)
        np.testing.assert_array_equal(gd, wd)
