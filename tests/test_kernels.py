"""Pallas kernels vs pure-jnp oracles (interpret mode; shape/dtype sweeps)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand_int(rng, bits, shape):
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1))
    return jnp.asarray(rng.integers(lo, hi, shape), jnp.int8)


@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("axis", [0, 1])
def test_pack_unpack_roundtrip(bits, axis):
    rng = np.random.default_rng(0)
    x = _rand_int(rng, bits, (64, 32))
    planes = ref.pack_bitplanes(x, bits, axis=axis)
    back = ref.unpack_bitplanes(planes, axis=axis, signed=True)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x, np.int32))


@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("mnk", [(16, 128, 64), (32, 256, 128),
                                 (128, 128, 512)])
def test_quant_matmul_vs_oracle(bits, mnk):
    m, n, k = mnk
    rng = np.random.default_rng(1)
    a = _rand_int(rng, 8, (m, k))
    w = _rand_int(rng, bits, (k, n))
    scale = jnp.asarray(rng.uniform(0.001, 0.1, n), jnp.float32)
    wp = ref.pack_bitplanes(w, bits, axis=0)
    got = ops.quant_matmul(a, wp, scale, bits=bits, interpret=True,
                           block_m=16, block_n=64, block_k=64)
    want = ref.quant_matmul(a, wp, scale, bits=bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6)
    # and against plain integer matmul (exactness of the decomposition)
    exact = (np.asarray(a, np.int64) @ np.asarray(w, np.int64)
             ).astype(np.float32) * np.asarray(scale)[None, :]
    np.testing.assert_allclose(np.asarray(got), exact, rtol=1e-6)


@pytest.mark.parametrize("ba,bw", [(4, 4), (8, 4), (4, 8)])
def test_popcount_matmul_vs_oracle(ba, bw):
    m, n, k = 16, 64, 128
    rng = np.random.default_rng(2)
    a = _rand_int(rng, ba, (m, k))
    w = _rand_int(rng, bw, (k, n))
    ap = ref.pack_bitplanes(a, ba, axis=1)
    wp = ref.pack_bitplanes(w, bw, axis=0)
    got = ops.popcount_matmul(ap, wp, interpret=True,
                              block_m=8, block_n=32, block_k=64)
    want = np.asarray(a, np.int64) @ np.asarray(w, np.int64)
    np.testing.assert_array_equal(np.asarray(got), want)
    oracle = ref.popcount_matmul(ap, wp, a_signed=True, w_signed=True)
    np.testing.assert_array_equal(np.asarray(oracle), want)


def test_popcount_matches_engine_semantics():
    """Cross-layer: Pallas popcount path == Compute RAM engine idot.

    Both implement sum_t a_t*b_t by bit-level AND/add -- verify they
    agree end-to-end (unsigned int4, one output column per CR column).
    """
    from repro.core import harness, programs
    from repro.core import ref as cref
    rng = np.random.default_rng(3)
    prog, lay = programs.idot(4, rows=128)
    cols = 8
    a = rng.integers(0, 16, (lay.tuples, cols), dtype=np.uint64)
    b = rng.integers(0, 16, (lay.tuples, cols), dtype=np.uint64)
    got_engine = harness.unpack_acc(
        harness.run_program(prog, lay, {"a": a, "b": b}, cols), lay)

    # same dot products via the packed kernel: per column c,
    # acc[c] = a[:, c] . b[:, c]
    K = ((lay.tuples + 31) // 32) * 32
    a_pad = np.zeros((cols, K), np.int8)
    b_pad = np.zeros((K, cols), np.int8)
    a_pad[:, :lay.tuples] = a.T
    b_pad[:lay.tuples, :] = b
    ap = ref.pack_bitplanes(jnp.asarray(a_pad), 4, axis=1)
    wp = ref.pack_bitplanes(jnp.asarray(b_pad), 4, axis=0)
    out = ops.popcount_matmul(ap, wp, a_signed=False, w_signed=False,
                              interpret=True, block_m=8, block_n=8,
                              block_k=32)
    np.testing.assert_array_equal(np.diag(np.asarray(out)), got_engine)


def test_quantize_roundtrip_error():
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(0, 1, (128, 64)), jnp.float32)
    q, s = ops.quantize(x, bits=8, axis=1)
    err = np.abs(np.asarray(q, np.float32) * np.asarray(s)[None, :] -
                 np.asarray(x))
    assert err.max() < np.abs(np.asarray(x)).max() / 100


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("shape", [(2, 128, 32), (4, 256, 64)])
def test_flash_attention_vs_oracle(causal, shape):
    from repro.kernels.flash_attention import attention_ref, flash_attention
    bh, s, hd = shape
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(0, 1, (bh, s, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (bh, s, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (bh, s, hd)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                          interpret=True)
    want = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_matches_model_chunked_path():
    """Pallas kernel == the model zoo's chunked-jnp attention."""
    from repro.kernels.flash_attention import flash_attention
    from repro.models.attention import chunked_attention
    b, s, h, hd = 2, 128, 4, 32
    rng = np.random.default_rng(8)
    q = jnp.asarray(rng.normal(0, 1, (b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (b, s, h, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (b, s, h, hd)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    want = chunked_attention(q, k, v, pos, pos, causal=True, chunk=64)
    qf = jnp.moveaxis(q, 2, 1).reshape(b * h, s, hd)
    kf = jnp.moveaxis(k, 2, 1).reshape(b * h, s, hd)
    vf = jnp.moveaxis(v, 2, 1).reshape(b * h, s, hd)
    got = flash_attention(qf, kf, vf, causal=True, block_q=64, block_k=64,
                          interpret=True)
    got = jnp.moveaxis(got.reshape(b, h, s, hd), 1, 2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
