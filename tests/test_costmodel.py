"""Paper-claim validation of the cost model (Table II, Figs 4-6)."""

import pytest

from repro.core import costmodel as cm


def test_table2_area():
    assert abs(cm.AREA_CR_UM2 - 11072.5) < 1.0
    # "~33% more area compared to a BRAM"
    assert 0.30 <= cm.AREA_CR_UM2 / cm.AREA_BRAM_UM2 - 1 <= 0.36
    # "A DSP Slice has ~12% more area than a Compute RAM"
    assert 0.09 <= cm.AREA_DSP_UM2 / cm.AREA_CR_UM2 - 1 <= 0.15


def test_table2_frequency():
    assert abs(cm.FREQ_CR_MHZ - 609.1) < 0.5
    # "~37% slower than BRAMs"
    assert 0.32 <= 1 - cm.FREQ_CR_MHZ / cm.FREQ_BRAM_MHZ <= 0.37
    # "~43% faster than DSPs in fixed-point, ~67% in floating-point"
    assert cm.FREQ_CR_MHZ / cm.FREQ_DSP_FIXED_MHZ > 1.40
    assert cm.FREQ_CR_MHZ / cm.FREQ_DSP_FLOAT_MHZ > 1.60


def test_table2_throughput_from_programs():
    """CR GOPS computed from executing our instruction sequences."""
    assert abs(cm.cr_throughput_gops("add", "int4") - 4.8) < 0.2
    assert abs(cm.cr_throughput_gops("add", "int8") - 2.7) < 0.2
    # CR beats every other block at int4/int8 (paper: highest throughput)
    for prec in ("int4", "int8"):
        cr = cm.cr_throughput_gops("add", prec)
        assert cr > cm.GOPS_DSP[prec] and cr > cm.GOPS_LB[prec]


@pytest.mark.parametrize("prec", ["int4", "int8"])
def test_fig4_addition_claims(prec):
    r = cm.compare("add", prec)
    # energy ~20% of baseline (avg 80% savings)
    assert r["energy_ratio"] < 0.35
    # execution time improvement 20%-80%
    assert 0.1 <= r["time_ratio"] <= 0.8
    # circuit frequency 60-65% higher
    assert 0.55 <= r["freq_gain"] <= 0.70
    # area reduced
    assert r["area_ratio"] < 1.0


def test_fig5_multiplication_claims():
    r = cm.compare("mul", "int4")
    # paper: ~12% shorter total time; ours lands close (cycle counts are
    # from our own sequences)
    assert r["time_ratio"] < 1.1
    assert r["area_ratio"] < 1.0
    assert r["energy_ratio"] < 1.0


def test_fig6_dot_product_claims():
    r40 = cm.compare("dot", "int4", cr_cols=40)
    r72 = cm.compare("dot", "int4", cr_cols=72)
    # paper: CR at 40 columns takes MORE time than baseline
    assert r40["time_ratio"] > 1.0
    # widening the array increases parallelism -> time strictly improves
    assert r72["time_ratio"] < r40["time_ratio"] * 0.75
    # area impact of widening is minor
    assert r72["compute_ram"].area_um2 / r40["compute_ram"].area_um2 < 1.1


def test_energy_average_savings():
    """Paper headline: 'average savings of 80% in energy' -- holds for the
    ops whose cycle counts match the paper's (int add); our from-scratch
    mul/dot sequences are within ~2x of paper cycles and documented."""
    r4 = cm.compare("add", "int4")["energy_ratio"]
    r8 = cm.compare("add", "int8")["energy_ratio"]
    assert (r4 + r8) / 2 < 0.30
