"""Paper-claim validation of the cost model (Table II, Figs 4-6)."""

import pytest

from repro.core import costmodel as cm


def test_table2_area():
    assert abs(cm.AREA_CR_UM2 - 11072.5) < 1.0
    # "~33% more area compared to a BRAM"
    assert 0.30 <= cm.AREA_CR_UM2 / cm.AREA_BRAM_UM2 - 1 <= 0.36
    # "A DSP Slice has ~12% more area than a Compute RAM"
    assert 0.09 <= cm.AREA_DSP_UM2 / cm.AREA_CR_UM2 - 1 <= 0.15


def test_table2_frequency():
    assert abs(cm.FREQ_CR_MHZ - 609.1) < 0.5
    # "~37% slower than BRAMs"
    assert 0.32 <= 1 - cm.FREQ_CR_MHZ / cm.FREQ_BRAM_MHZ <= 0.37
    # "~43% faster than DSPs in fixed-point, ~67% in floating-point"
    assert cm.FREQ_CR_MHZ / cm.FREQ_DSP_FIXED_MHZ > 1.40
    assert cm.FREQ_CR_MHZ / cm.FREQ_DSP_FLOAT_MHZ > 1.60


def test_table2_throughput_from_programs():
    """CR GOPS computed from executing our instruction sequences."""
    assert abs(cm.cr_throughput_gops("add", "int4") - 4.8) < 0.2
    assert abs(cm.cr_throughput_gops("add", "int8") - 2.7) < 0.2
    # CR beats every other block at int4/int8 (paper: highest throughput)
    for prec in ("int4", "int8"):
        cr = cm.cr_throughput_gops("add", prec)
        assert cr > cm.GOPS_DSP[prec] and cr > cm.GOPS_LB[prec]


@pytest.mark.parametrize("prec", ["int4", "int8"])
def test_fig4_addition_claims(prec):
    r = cm.compare("add", prec)
    # energy ~20% of baseline (avg 80% savings)
    assert r["energy_ratio"] < 0.35
    # execution time improvement 20%-80%
    assert 0.1 <= r["time_ratio"] <= 0.8
    # circuit frequency 60-65% higher
    assert 0.55 <= r["freq_gain"] <= 0.70
    # area reduced
    assert r["area_ratio"] < 1.0


def test_fig5_multiplication_claims():
    r = cm.compare("mul", "int4")
    # paper: ~12% shorter total time; ours lands close (cycle counts are
    # from our own sequences)
    assert r["time_ratio"] < 1.1
    assert r["area_ratio"] < 1.0
    assert r["energy_ratio"] < 1.0


def test_fig6_dot_product_claims():
    r40 = cm.compare("dot", "int4", cr_cols=40)
    r72 = cm.compare("dot", "int4", cr_cols=72)
    # paper: CR at 40 columns takes MORE time than baseline
    assert r40["time_ratio"] > 1.0
    # widening the array increases parallelism -> time strictly improves
    assert r72["time_ratio"] < r40["time_ratio"] * 0.75
    # area impact of widening is minor
    assert r72["compute_ram"].area_um2 / r40["compute_ram"].area_um2 < 1.1


# ---------------------------------------------------------------------------
# Entry-point pins: compare / cr_throughput_gops / wire_energy_fj
# (paper Table III/IV methodology encoded as constants -- any drift in
# the derivation chain moves these numbers)
# ---------------------------------------------------------------------------
def test_wire_energy_pinned():
    """Keckler-style wire energy: bits x mm x 34 fJ/bit/mm x 4 (FPGA
    switch factor).  One 40-bit BRAM row over the baseline average net
    = 3264 fJ; the CR control nets are ~75x cheaper."""
    assert cm.wire_energy_fj(40, cm.NET_LENGTH_BASE_MM) == \
        pytest.approx(3264.0)
    assert cm.wire_energy_fj(4, cm.NET_LENGTH_CR_MM) == \
        pytest.approx(43.52)
    assert cm.wire_energy_fj(0, cm.NET_LENGTH_BASE_MM) == 0.0
    # fabric hop is strictly cheaper than the spill path per bit
    assert cm.wire_energy_fj(1, cm.NET_LENGTH_FABRIC_MM) < \
        cm.wire_energy_fj(1, cm.NET_LENGTH_SPILL_MM)


def test_hop_net_length_pinned():
    """Topology-aware wire model: one Manhattan hop = 0.15 mm, nets are
    monotone (non-decreasing) in hop count and never shorter than one
    hop; two hops equal the legacy flat fabric net, so the hop model and
    the flat model agree on a typical short hop and diverge with grid
    diameter."""
    assert cm.NET_LENGTH_HOP_MM == pytest.approx(0.15)
    assert cm.hop_net_length_mm(0) == pytest.approx(cm.NET_LENGTH_HOP_MM)
    assert cm.hop_net_length_mm(1) == pytest.approx(cm.NET_LENGTH_HOP_MM)
    assert cm.hop_net_length_mm(2) == pytest.approx(cm.NET_LENGTH_FABRIC_MM)
    lengths = [cm.hop_net_length_mm(h) for h in range(10)]
    assert lengths == sorted(lengths)
    # wire energy over the hop-priced length is monotone in the hop
    # count for a fixed payload (grid-diameter monotonicity)
    energies = [cm.wire_energy_fj(40, cm.hop_net_length_mm(h))
                for h in (1, 2, 6, 14)]
    assert all(a < b for a, b in zip(energies, energies[1:]))


def test_wire_energy_bit_mm_matches_flat_pricing():
    """bits x mm pricing is the same Keckler constants as the flat
    model: pricing N bits over one flat net length must agree."""
    assert cm.wire_energy_bit_mm_fj(100 * cm.NET_LENGTH_FABRIC_MM) == \
        pytest.approx(cm.wire_energy_fj(100, cm.NET_LENGTH_FABRIC_MM))
    assert cm.wire_energy_bit_mm_fj(0.0) == 0.0


def test_schedule_rollup_hop_priced_wire():
    """When the schedule walk supplies hop-priced bit*mm totals, the
    wire split is derived from them (not the flat net lengths), and the
    totals round-trip through the report."""
    c = _rollup(fabric_bits_moved=100.0, spill_bits_moved=50.0,
                fabric_bit_mm=45.0, spill_bit_mm=90.0)
    want = (cm.wire_energy_bit_mm_fj(45.0)
            + cm.wire_energy_bit_mm_fj(90.0)) / 1e3
    assert c.energy_wire_pj == pytest.approx(want)
    rep = c.report()
    assert rep["fabric_bit_mm"] == pytest.approx(45.0)
    assert rep["spill_bit_mm"] == pytest.approx(90.0)
    assert rep["avg_hop_mm"] == pytest.approx(0.45)


def test_cr_throughput_gops_dot_pinned():
    """Dot-product throughput from *executed* instruction sequences at
    the compute-mode frequency (paper §V-D operating point)."""
    assert cm.cr_throughput_gops("dot", "int4") == pytest.approx(0.501,
                                                                 abs=0.02)
    assert cm.cr_throughput_gops("dot", "int8") == pytest.approx(0.210,
                                                                 abs=0.02)
    assert cm.cr_throughput_gops("mul", "int4") == pytest.approx(0.811,
                                                                 abs=0.03)
    # wider geometry (§V-D, 72 cols) scales throughput ~linearly
    r40 = cm.cr_throughput_gops("dot", "int4", cols=40, rows=512)
    r72 = cm.cr_throughput_gops("dot", "int4", cols=72,
                                rows=512 * 40 // 72)
    assert r72 > r40


def test_compare_record_is_self_consistent():
    """compare() must expose both CircuitCosts and ratios derived from
    them -- for every shipped baseline composition."""
    for (op, prec) in cm.BASELINES:
        r = cm.compare(op, prec)
        base, cr = r["baseline"], r["compute_ram"]
        assert r["area_ratio"] == pytest.approx(cr.area_um2 / base.area_um2)
        assert r["energy_ratio"] == pytest.approx(
            cr.energy_per_op_pj / base.energy_per_op_pj)
        assert r["time_ratio"] == pytest.approx(
            cr.time_per_op_ns / base.time_per_op_ns)
        assert base.energy_pj > 0 and cr.energy_pj > 0
        assert base.ops > 0 and cr.ops > 0


# ---------------------------------------------------------------------------
# Schedule-level roll-up (fabric scheduler accounting)
# ---------------------------------------------------------------------------
def _rollup(**kw):
    base = dict(n_blocks=4, n_compute=2, n_storage=2, rounds=2,
                compute_block_cycles=0.0, round_cycles=0.0,
                storage_rows_touched=0.0, fabric_bits_moved=0.0,
                spill_bits_moved=0.0, ops=100)
    base.update(kw)
    return cm.schedule_cost_rollup("t", **base)


def test_schedule_rollup_compute_energy_pinned():
    """1000 compute-mode block-cycles = 4429 pJ (compute activity 2.5x,
    75% SRAM-fraction CR block -- same constants as ComputeRamDesign)."""
    c = _rollup(compute_block_cycles=1000.0)
    assert c.energy_compute_pj == pytest.approx(4429.0, rel=1e-3)
    assert c.energy_storage_pj == 0 and c.energy_wire_pj == 0
    assert c.energy_pj == pytest.approx(c.energy_compute_pj)


def test_schedule_rollup_storage_and_wire():
    c = _rollup(storage_rows_touched=1000.0, fabric_bits_moved=100.0,
                spill_bits_moved=100.0)
    # storage-mode row access at activity 0.1, 90% SRAM fraction
    assert c.energy_storage_pj == pytest.approx(2037.3, rel=1e-3)
    # 100 bits on each path; spill nets are NET_LENGTH_SPILL/FABRIC x
    want_wire = (cm.wire_energy_fj(100, cm.NET_LENGTH_FABRIC_MM)
                 + cm.wire_energy_fj(100, cm.NET_LENGTH_SPILL_MM)) / 1e3
    assert c.energy_wire_pj == pytest.approx(want_wire)


def test_schedule_rollup_time_model():
    """Rounds serialize at the CR circuit frequency; storage traffic
    overlaps row-by-row at the (faster) BRAM frequency."""
    c = _rollup(round_cycles=1212.0, storage_rows_touched=922.9)
    assert c.time_us == pytest.approx(1212.0 / cm.FREQ_CIRCUIT_CR_MHZ
                                      + 1.0)
    assert c.gops == pytest.approx(c.ops / c.time_us / 1e3)


def test_schedule_rollup_report_roundtrip():
    rep = _rollup(compute_block_cycles=10, storage_rows_touched=5,
                  fabric_bits_moved=64).report()
    assert rep["blocks"] == 4 and rep["rounds"] == 2 and rep["ops"] == 100
    assert rep["energy_pj"] == pytest.approx(
        rep["energy_compute_pj"] + rep["energy_storage_pj"]
        + rep["energy_wire_pj"], abs=0.01)


def test_schedule_rollup_overlap_defaults_fall_back_to_serial():
    """Roll-ups without per-round structure expose serial == overlapped
    (no modeled overlap), derived from the legacy time model."""
    c = _rollup(round_cycles=1212.0, storage_rows_touched=500.0)
    want = 1212.0 + 500.0 * cm.STORAGE_ROW_CR_CYCLES
    assert c.serial_cycles == 0.0 and c.overlapped_cycles == 0.0
    assert c.serial_cycles_ == pytest.approx(want)
    assert c.overlapped_cycles_ == pytest.approx(want)
    assert c.overlap_speedup == pytest.approx(1.0)
    # one cycle unit: serial_cycles_ at the CR frequency IS time_us
    assert c.serial_cycles_ / cm.FREQ_CIRCUIT_CR_MHZ == \
        pytest.approx(c.time_us)


def test_schedule_rollup_explicit_overlap_pinned():
    c = _rollup(round_cycles=1000.0, serial_cycles=3000.0,
                overlapped_cycles=1800.0)
    assert c.serial_cycles_ == 3000.0
    assert c.overlapped_cycles_ == 1800.0
    assert c.overlap_speedup == pytest.approx(3000.0 / 1800.0)
    assert c.time_us_overlapped == pytest.approx(
        1800.0 / cm.FREQ_CIRCUIT_CR_MHZ)
    rep = c.report()
    assert rep["serial_cycles"] == 3000.0
    assert rep["overlapped_cycles"] == 1800.0
    assert rep["overlap_speedup"] == pytest.approx(1.667, abs=1e-3)


def test_storage_row_cycle_conversion_pinned():
    """One storage row at BRAM frequency, in CR-circuit cycle units."""
    assert cm.STORAGE_ROW_CR_CYCLES == pytest.approx(
        cm.FREQ_CIRCUIT_CR_MHZ / cm.FREQ_BRAM_MHZ)
    assert 0.6 < cm.STORAGE_ROW_CR_CYCLES < 0.7    # BRAM is faster


def test_energy_average_savings():
    """Paper headline: 'average savings of 80% in energy' -- holds for the
    ops whose cycle counts match the paper's (int add); our from-scratch
    mul/dot sequences are within ~2x of paper cycles and documented."""
    r4 = cm.compare("add", "int4")["energy_ratio"]
    r8 = cm.compare("add", "int8")["energy_ratio"]
    assert (r4 + r8) / 2 < 0.30
