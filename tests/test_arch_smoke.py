"""Per-architecture smoke tests: reduced configs, one forward + one
train-ish step on CPU, asserting output shapes and no NaNs; plus a
decode-vs-prefill consistency check per family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models.model import LM

ARCHS = configs.list_archs()


def _batch(cfg, b=2, s=16, key=0):
    rng = np.random.default_rng(key)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (b, s)), jnp.int32)}
    if cfg.is_encdec:
        batch["src_embeds"] = jnp.asarray(
            rng.normal(0, 1, (b, s, cfg.d_model)), jnp.bfloat16)
    return batch


@pytest.mark.slow     # full-model jit per arch: minutes on CPU
@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = configs.get_config(arch, smoke=True)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    enc_out = enc_pos = None
    if cfg.is_encdec:
        b, s = batch["src_embeds"].shape[:2]
        enc_pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        enc_out = model.encode(params, batch["src_embeds"], enc_pos)
        assert enc_out.shape == (b, s, cfg.d_model)
    logits, aux = jax.jit(model.apply)(params, tokens=batch["tokens"],
                                       enc_out=enc_out, enc_pos=enc_pos)
    assert logits.shape == (2, 16, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.slow     # full-model jit per arch: minutes on CPU
@pytest.mark.parametrize("arch", ARCHS)
def test_loss_and_grad_step(arch):
    cfg = configs.get_config(arch, smoke=True)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(1))
    batch = _batch(cfg, key=1)

    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat)
    # SGD step changes the loss
    new_params = jax.tree.map(lambda p, g: p - 0.5 * g.astype(p.dtype),
                              params, grads)
    loss2 = jax.jit(model.loss)(new_params, batch)
    assert float(loss2) != float(loss)


@pytest.mark.slow     # full-model jit per arch: minutes on CPU
@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill(arch):
    """Prefill s tokens, then decode token s; compare against a full
    forward over s+1 tokens (the KV/state caches must be consistent)."""
    cfg = configs.get_config(arch, smoke=True)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(2))
    b, s = 2, 8
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s + 1)), jnp.int32)

    enc_out = enc_pos = None
    if cfg.is_encdec:
        src = jnp.asarray(rng.normal(0, 1, (b, s, cfg.d_model)),
                          jnp.bfloat16)
        enc_pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        enc_out = model.encode(params, src, enc_pos)

    full, _ = model.apply(params, tokens=toks, enc_out=enc_out,
                          enc_pos=enc_pos)
    _, caches = model.prefill(params, tokens=toks[:, :s], capacity=s + 1,
                              enc_out=enc_out, enc_pos=enc_pos)
    step_logits, _ = model.decode_step(
        params, caches, toks[:, s:s + 1],
        jnp.full((b,), s, jnp.int32), enc_out=enc_out, enc_pos=enc_pos)

    got = np.asarray(step_logits[:, 0], np.float32)
    want = np.asarray(full[:, s], np.float32)
    np.testing.assert_allclose(got, want, rtol=0.05, atol=0.05)


def test_param_counts_plausible():
    """Full configs must be in the advertised parameter range."""
    expect = {
        "falcon-mamba-7b": (6e9, 9e9),
        "granite-20b": (18e9, 24e9),
        "llama3.2-1b": (1.0e9, 1.8e9),
        "qwen2-0.5b": (0.4e9, 0.7e9),
        "h2o-danube-1.8b": (1.5e9, 2.2e9),
        "granite-moe-3b-a800m": (2.5e9, 4e9),
        "mixtral-8x7b": (42e9, 50e9),
        "recurrentgemma-9b": (7e9, 11e9),
        "chameleon-34b": (30e9, 38e9),
        "seamless-m4t-large-v2": (1.2e9, 3e9),
    }
    for arch, (lo, hi) in expect.items():
        cfg = configs.get_config(arch)
        n = cfg.param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"
