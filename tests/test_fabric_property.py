"""Hypothesis property tests: cram_matmul / cram_dot boundary behaviour
and the float fused-MAC rounding edges.

Fuzzes the edges the fabric scheduler leans on: operands at ``2^n - 1``,
K at exact ``idot_geometry`` capacity +/- 1, N at the paper's 40 block
columns, and the full signed range (asymmetric two's-complement minimum
included).  The float properties pin the *documented FTZ+RTZ fused-MAC
semantics* -- exponent-field extremes, FTZ inputs, catastrophic
cancellation -- against the oracle, not exact IEEE.  Example-based pins
of the same edges live in ``test_fabric.py`` / ``test_float_dot.py`` so
they run even without hypothesis installed.
"""

import os

import numpy as np
import pytest

# CI exports REQUIRE_HYPOTHESIS=1 after installing requirements-dev.txt:
# there a missing hypothesis is a hard failure (the tier silently
# skipping is exactly the drift this guards against); locally it stays
# a clean skip.
if os.environ.get("REQUIRE_HYPOTHESIS"):
    import hypothesis
else:
    hypothesis = pytest.importorskip(
        "hypothesis", reason="property tests need hypothesis "
        "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import ref  # noqa: E402
from repro.core.floatprog import BF16, FP16, FP8_E4M3  # noqa: E402
from repro.pim import cram, fabric  # noqa: E402
from repro.pim.fabric import FabricConfig  # noqa: E402

ROWS, COLS = 128, 8
_FMTS = [BF16, FP16, FP8_E4M3]


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([4, 8]),
       st.sampled_from([-1, 0, 1]))
def test_prop_cram_dot_capacity_edge(seed, n, delta):
    """K at exact idot tuple capacity -1 / exact / +1 (the +1 case tiles
    into a second program launch) stays exact, including max operands."""
    rng = np.random.default_rng(seed)
    cap = cram.idot_geometry(n, ROWS)
    T = max(1, cap + delta)
    a = rng.integers(0, 1 << n, (T, 3)).astype(np.uint64)
    b = rng.integers(0, 1 << n, (T, 3)).astype(np.uint64)
    a[0] = b[0] = (1 << n) - 1                    # operands at 2^n - 1
    got = cram.cram_dot(a, b, n, rows=ROWS)
    np.testing.assert_array_equal(got, (a * b).sum(axis=0))


@settings(max_examples=4, deadline=None)
@given(st.sampled_from([4, 8]))
def test_prop_cram_dot_all_max_operands(n):
    """Worst-case accumulation: every operand at 2^n - 1 for a full
    capacity tile -- the bounded carry-ripple proof obligation."""
    cap = cram.idot_geometry(n, ROWS)
    a = np.full((cap, 2), (1 << n) - 1, np.uint64)
    got = cram.cram_dot(a, a, n, rows=ROWS)
    np.testing.assert_array_equal(got, (a * a).sum(axis=0))


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([39, 40, 41]))
def test_prop_cram_matmul_block_width_edge(seed, n_out):
    """N at exactly the paper's 40 block columns, one short, and one past
    (forces a second ragged N tile)."""
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 16, (2, 5)).astype(np.uint64)
    w = rng.integers(0, 16, (5, n_out)).astype(np.uint64)
    got = cram.cram_matmul(x, w, n=4, rows=ROWS, cols=40)
    np.testing.assert_array_equal(got, x @ w)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([4, 8]))
def test_prop_cram_matmul_signed(seed, n):
    """Signed path is exact over the full two's-complement range."""
    rng = np.random.default_rng(seed)
    lo, hi = -(1 << (n - 1)), 1 << (n - 1)
    m, k, nn = (int(v) for v in rng.integers(1, 6, 3))
    x = rng.integers(lo, hi, (m, k)).astype(np.int64)
    w = rng.integers(lo, hi, (k, nn)).astype(np.int64)
    x.flat[0] = lo                                  # asymmetric extreme
    w.flat[0] = hi - 1
    got = cram.cram_matmul(x, w, n=n, rows=ROWS, cols=COLS, signed=True)
    np.testing.assert_array_equal(got, x @ w)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 8), st.integers(1, 12),
       st.integers(1, 12))
def test_prop_fabric_gemm_exact_any_shape(seed, m, k, n):
    """The scheduled fabric GEMM is exact for arbitrary ragged shapes."""
    rng = np.random.default_rng(seed)
    x = rng.integers(-8, 8, (m, k)).astype(np.int64)
    w = rng.integers(-8, 8, (k, n)).astype(np.int64)
    cfg = FabricConfig(n_blocks=4, rows=ROWS, cols=COLS)
    res = fabric.fabric_matmul(x, w, nbits=4, cfg=cfg, signed=True)
    np.testing.assert_array_equal(res.out, x @ w)


# ---------------------------------------------------------------------------
# Float fused-MAC rounding edges (documented FTZ+RTZ semantics, not
# IEEE).  The engine program is pinned bit-exact against ref.float_dot
# in test_float_dot.py, so these fuzz the *semantics* on the oracle and
# spot-check the engine through cram_fdot on the bf16 examples.
# ---------------------------------------------------------------------------
def _fmt_bits(rng, fmt, shape, elo, ehi, zero_p=0.0):
    eb, m = fmt.ebits, fmt.mbits
    s = rng.integers(0, 2, shape).astype(np.uint32)
    e = rng.integers(elo, max(elo + 1, ehi), shape).astype(np.uint32)
    mm = rng.integers(0, 1 << m, shape).astype(np.uint32)
    bits = (s << (eb + m)) | (e << m) | mm
    return np.where(rng.random(shape) < zero_p, 0, bits).astype(np.uint64)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([0, 1, 2]),
       st.booleans())
def test_prop_float_dot_exponent_extremes(seed, fi, low):
    """Operands at the exponent-field extremes: smallest normals
    underflow to +0 (FTZ, never a subnormal residual); largest wrap
    finite-only -- in both cases program == oracle bit-exactly."""
    fmt = _FMTS[fi]
    rng = np.random.default_rng(seed)
    emax = (1 << fmt.ebits) - 1
    elo, ehi = (1, 2) if low else (emax - 1, emax)
    a = _fmt_bits(rng, fmt, (2, 3), elo, ehi)
    b = _fmt_bits(rng, fmt, (2, 3), elo, ehi)
    want = ref.float_dot(a, b, fmt.ebits, fmt.mbits)
    if low:
        # product exponents underflow below the smallest normal: FTZ
        assert (want == 0).all()
    got = cram.cram_fdot(a, b, fmt, executor="scan")
    np.testing.assert_array_equal(got, want)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([0, 1, 2]))
def test_prop_float_dot_ftz_inputs(seed, fi):
    """Denormal input patterns (exp == 0, mantissa != 0) behave exactly
    like +0: flushing them by hand never changes the result."""
    fmt = _FMTS[fi]
    rng = np.random.default_rng(seed)
    emax = (1 << fmt.ebits) - 1
    a = _fmt_bits(rng, fmt, (3, 3), 1, emax - 1)
    b = _fmt_bits(rng, fmt, (3, 3), 1, emax - 1)
    mmask = np.uint64((1 << fmt.mbits) - 1)
    a[0] &= mmask                       # denormal patterns in row 0
    flushed = a.copy()
    flushed[0] = 0
    np.testing.assert_array_equal(
        ref.float_dot(a, b, fmt.ebits, fmt.mbits),
        ref.float_dot(flushed, b, fmt.ebits, fmt.mbits))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([0, 1, 2]))
def test_prop_float_dot_catastrophic_cancellation(seed, fi):
    """x*y + x*(-y) == +0 exactly: negation is a sign-bit XOR, equal
    magnitudes cancel to a zero mantissa, and the flush produces +0 --
    the documented behavior (no sticky/guard residual to round)."""
    fmt = _FMTS[fi]
    rng = np.random.default_rng(seed)
    emax = (1 << fmt.ebits) - 1
    x = _fmt_bits(rng, fmt, (3,), 1, emax - 1)
    y = _fmt_bits(rng, fmt, (3,), 1, emax - 1)
    sbit = np.uint64(1 << (fmt.width - 1))
    a = np.stack([x, x])
    b = np.stack([y, y ^ sbit])
    assert (ref.float_dot(a, b, fmt.ebits, fmt.mbits) == 0).all()


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_prop_float_dot_tiling_invariance(seed):
    """The wide-accumulator chain makes the K-tiling invisible: any
    split point gives the same bits as one sequential pass."""
    fmt = FP8_E4M3
    rng = np.random.default_rng(seed)
    emax = (1 << fmt.ebits) - 1
    K = int(rng.integers(2, 8))
    cut = int(rng.integers(1, K))
    a = _fmt_bits(rng, fmt, (K, 3), 1, emax - 1, zero_p=0.2)
    b = _fmt_bits(rng, fmt, (K, 3), 1, emax - 1, zero_p=0.2)
    one, acc_one = ref.float_dot_acc(a, b, fmt.ebits, fmt.mbits)
    mid = ref.float_dot_acc(a[:cut], b[:cut], fmt.ebits, fmt.mbits)[1]
    two, acc_two = ref.float_dot_acc(a[cut:], b[cut:], fmt.ebits,
                                     fmt.mbits, acc=mid)
    np.testing.assert_array_equal(one, two)
    np.testing.assert_array_equal(acc_one, acc_two)
