"""Hypothesis property tests: cram_matmul / cram_dot boundary behaviour.

Fuzzes the edges the fabric scheduler leans on: operands at ``2^n - 1``,
K at exact ``idot_geometry`` capacity +/- 1, N at the paper's 40 block
columns, and the full signed range (asymmetric two's-complement minimum
included).  Example-based pins of the same edges live in
``test_fabric.py`` so they run even without hypothesis installed.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.pim import cram, fabric  # noqa: E402
from repro.pim.fabric import FabricConfig  # noqa: E402

ROWS, COLS = 128, 8


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([4, 8]),
       st.sampled_from([-1, 0, 1]))
def test_prop_cram_dot_capacity_edge(seed, n, delta):
    """K at exact idot tuple capacity -1 / exact / +1 (the +1 case tiles
    into a second program launch) stays exact, including max operands."""
    rng = np.random.default_rng(seed)
    cap = cram.idot_geometry(n, ROWS)
    T = max(1, cap + delta)
    a = rng.integers(0, 1 << n, (T, 3)).astype(np.uint64)
    b = rng.integers(0, 1 << n, (T, 3)).astype(np.uint64)
    a[0] = b[0] = (1 << n) - 1                    # operands at 2^n - 1
    got = cram.cram_dot(a, b, n, rows=ROWS)
    np.testing.assert_array_equal(got, (a * b).sum(axis=0))


@settings(max_examples=4, deadline=None)
@given(st.sampled_from([4, 8]))
def test_prop_cram_dot_all_max_operands(n):
    """Worst-case accumulation: every operand at 2^n - 1 for a full
    capacity tile -- the bounded carry-ripple proof obligation."""
    cap = cram.idot_geometry(n, ROWS)
    a = np.full((cap, 2), (1 << n) - 1, np.uint64)
    got = cram.cram_dot(a, a, n, rows=ROWS)
    np.testing.assert_array_equal(got, (a * a).sum(axis=0))


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([39, 40, 41]))
def test_prop_cram_matmul_block_width_edge(seed, n_out):
    """N at exactly the paper's 40 block columns, one short, and one past
    (forces a second ragged N tile)."""
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 16, (2, 5)).astype(np.uint64)
    w = rng.integers(0, 16, (5, n_out)).astype(np.uint64)
    got = cram.cram_matmul(x, w, n=4, rows=ROWS, cols=40)
    np.testing.assert_array_equal(got, x @ w)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([4, 8]))
def test_prop_cram_matmul_signed(seed, n):
    """Signed path is exact over the full two's-complement range."""
    rng = np.random.default_rng(seed)
    lo, hi = -(1 << (n - 1)), 1 << (n - 1)
    m, k, nn = (int(v) for v in rng.integers(1, 6, 3))
    x = rng.integers(lo, hi, (m, k)).astype(np.int64)
    w = rng.integers(lo, hi, (k, nn)).astype(np.int64)
    x.flat[0] = lo                                  # asymmetric extreme
    w.flat[0] = hi - 1
    got = cram.cram_matmul(x, w, n=n, rows=ROWS, cols=COLS, signed=True)
    np.testing.assert_array_equal(got, x @ w)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 8), st.integers(1, 12),
       st.integers(1, 12))
def test_prop_fabric_gemm_exact_any_shape(seed, m, k, n):
    """The scheduled fabric GEMM is exact for arbitrary ragged shapes."""
    rng = np.random.default_rng(seed)
    x = rng.integers(-8, 8, (m, k)).astype(np.int64)
    w = rng.integers(-8, 8, (k, n)).astype(np.int64)
    cfg = FabricConfig(n_blocks=4, rows=ROWS, cols=COLS)
    res = fabric.fabric_matmul(x, w, nbits=4, cfg=cfg, signed=True)
    np.testing.assert_array_equal(res.out, x @ w)
