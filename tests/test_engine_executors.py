"""Executor equivalence matrix + compiled-program cache behaviour.

The compiled executor (`engine.compile_program`) re-derives program
semantics through a real compiler pipeline (lane vectorization, ripple-
chain folding, integer provenance), so these tests pin it bit-exactly
against the two reference executors on every opcode and every shipped
instruction-sequence generator, plus golden cycle/footprint numbers.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import compiler, engine, harness, isa, programs
from repro.core.isa import Instr, Loop, Program, R, SetReg


def _states_equal(a, b):
    return all(np.array_equal(np.asarray(getattr(a, f)),
                              np.asarray(getattr(b, f)))
               for f in ("array", "carry", "tag"))


def _rand_state(rng, rows, cols):
    return engine.CRState(
        array=jnp.asarray(rng.integers(0, 2, (rows, cols)).astype(bool)),
        carry=jnp.asarray(rng.integers(0, 2, cols).astype(bool)),
        tag=jnp.asarray(rng.integers(0, 2, cols).astype(bool)))


def _assert_all_executors_agree(prog, state, packed_variants=(False, True)):
    ref = engine.execute(prog, state)
    scan = engine.execute_scan(prog, state)
    assert _states_equal(ref, scan), f"{prog.name}: scan != unroll"
    for packed in packed_variants:
        comp = engine.execute_compiled(prog, state, packed=packed)
        assert _states_equal(ref, comp), \
            f"{prog.name}: compiled(packed={packed}) != unroll"


# ---------------------------------------------------------------------------
# Every opcode, predicated and not, through all three executors
# ---------------------------------------------------------------------------
_ROW_OPS = sorted(isa._WRITES_ROW)
_LATCH_OPS = sorted(set(range(isa.N_ARRAY_OPS)) - isa._WRITES_ROW)


@pytest.mark.parametrize("pred", [False, True])
def test_every_opcode_bit_exact(rng, pred):
    rows, cols = 16, 8
    nodes = []
    for i, op in enumerate(_ROW_OPS + _LATCH_OPS):
        nodes.append(Instr(op, dst=(3 + i) % rows, a=(5 + 2 * i) % rows,
                           b=(1 + 3 * i) % rows, pred=pred))
        nodes.append(Instr(isa.OP_TROW, a=(7 * i) % rows))   # vary tag
    prog = Program(f"allops_pred{pred}", nodes)
    assert set(isa.stream_meta(prog.expand()).op_histogram) \
        .issuperset((op, 1) for op in _ROW_OPS)
    _assert_all_executors_agree(prog, _rand_state(rng, rows, cols))


def test_chain_idioms_bit_exact(rng):
    """Ripple chains / partial-product runs (the folded fast paths)."""
    rows, cols = 64, 8
    nodes = [
        Instr(isa.OP_C0),
        SetReg(1, 16), SetReg(2, 0), SetReg(3, 8),
        Loop(8, [Instr(isa.OP_FA, R(1), R(2), R(3),
                       inc=((1, 1), (2, 1), (3, 1)))]),
        # in-place predicated subtract chain
        Instr(isa.OP_TROW, a=40),
        Instr(isa.OP_C0),
        SetReg(1, 16), SetReg(2, 0),
        Loop(8, [Instr(isa.OP_FS, R(1), R(1), R(2),
                       inc=((1, 1), (2, 1)))]),
        Instr(isa.OP_CSTORE, 30),
        # AND run against one shared row (partial-product idiom)
        SetReg(1, 48), SetReg(2, 8),
        Loop(6, [Instr(isa.OP_AND, R(1), R(2), 41,
                       inc=((1, 1), (2, 1)))]),
    ]
    prog = Program("chains", nodes)
    _assert_all_executors_agree(prog, _rand_state(rng, rows, cols))


# ---------------------------------------------------------------------------
# Every shipped program generator, all executors, bit-exact
# ---------------------------------------------------------------------------
def _operand_data(rng, lay, cols):
    w = lay.fields["a"][1]
    names = [n for n in lay.fields if n in ("a", "b", "q")]
    out = {}
    for n in names:
        v = rng.integers(0, 1 << min(w, 16), (lay.tuples, cols),
                         dtype=np.uint64)
        out[n] = np.where(rng.random((lay.tuples, cols)) < 0.1, 0, v)
    return out


_GEN_CASES = [
    ("add_int4", lambda: programs.iadd(4, rows=128)),
    ("add_int8", lambda: programs.iadd(8, rows=128)),
    ("sub_int8", lambda: programs.isub(8, rows=128)),
    ("add_int16", lambda: programs.iadd(16, rows=128)),
    ("mul_int4", lambda: programs.imul(4, rows=128)),
    ("mul_int8", lambda: programs.imul(8, rows=256)),
    ("mul_int16", lambda: programs.imul(16, rows=256, tuples=2)),
    ("dot_int4", lambda: programs.idot(4, rows=128)),
    ("dot_int8", lambda: programs.idot(8, rows=256)),
    ("dot_int16", lambda: programs.idot(16, rows=256, tuples=2)),
    ("add_bf16", lambda: programs.bf16_add(rows=512, tuples=2)),
    ("mul_bf16", lambda: programs.bf16_mul(rows=512, tuples=2)),
    ("add_fp16", lambda: programs.fp16_add(rows=512, tuples=2)),
    ("mul_fp16", lambda: programs.fp16_mul(rows=512, tuples=2)),
    ("add_fp8", lambda: programs.fp8_add(rows=512, tuples=2)),
    ("mul_fp8", lambda: programs.fp8_mul(rows=512, tuples=2)),
    ("dot_bf16", lambda: programs.bf16_dot(rows=512, tuples=2)),
    ("dot_fp8", lambda: programs.fp8_dot(rows=512, tuples=3)),
    ("vsearch8", lambda: programs.vsearch(8, rows=128)),
    ("vcmp_gt4", lambda: programs.vcmp_gt(4, rows=128)),
]


@pytest.mark.parametrize("name,gen", _GEN_CASES,
                         ids=[c[0] for c in _GEN_CASES])
def test_program_executor_matrix(rng, name, gen):
    prog, lay = gen()
    cols = 8
    state = harness.make_jax_state(
        harness.pack_state(lay, _operand_data(rng, lay, cols), cols))
    # packed=True covered on the cheap programs; the float programs use
    # the default representation (same lowering, 10x the compile time)
    packed_variants = (False, True) if "int" in name or "v" in name \
        else (False,)
    _assert_all_executors_agree(prog, state, packed_variants)


def test_golden_cycles_and_footprints():
    """Cycle/footprint goldens for the paper geometry (rows=512).

    These pin the *program generators*: an executor can never change
    them, so a diff here means the ISA-level cost model moved.
    """
    golden = {
        ("add", "int4"): (211, 6),
        ("add", "int8"): (190, 6),
        ("mul", "int4"): (931, 16),
        ("mul", "int8"): (1351, 16),
        ("dot", "int4"): (2820, 28),
        ("dot", "int8"): (3256, 28),
    }
    for key, (cycles, slots) in golden.items():
        prog, _ = programs.GENERATORS[key](rows=512)
        assert prog.cycles() == cycles, key
        assert prog.footprint() == slots, key


# ---------------------------------------------------------------------------
# Multi-block execution
# ---------------------------------------------------------------------------
def test_execute_blocks_compiled_matches_scan(rng):
    prog, lay = programs.idot(4, rows=128)
    blocks, rows, cols = 4, 128, 8
    states = engine.CRState(
        array=jnp.asarray(
            rng.integers(0, 2, (blocks, rows, cols)).astype(bool)),
        carry=jnp.zeros((blocks, cols), bool),
        tag=jnp.ones((blocks, cols), bool))
    out_scan = engine.execute_blocks(prog, states, executor="scan")
    out_comp = engine.execute_blocks(prog, states, executor="compiled")
    assert _states_equal(out_scan, out_comp)


def test_run_dispatch_rejects_unknown_executor():
    prog, _ = programs.iadd(4, rows=64)
    state = engine.make_state(64, 8)
    with pytest.raises(ValueError, match="unknown executor"):
        engine.run(prog, state, executor="warp")


def test_compile_rejects_too_small_geometry():
    prog, _ = programs.iadd(8, rows=512)
    with pytest.raises(ValueError, match="rows"):
        engine.compile_program(prog, rows=16, cols=8)


# ---------------------------------------------------------------------------
# Compiled-program cache
# ---------------------------------------------------------------------------
def test_cache_hits_same_program_and_geometry():
    engine.clear_compile_cache()
    prog1, _ = programs.iadd(4, rows=64)
    prog2, _ = programs.iadd(4, rows=64)       # fresh but identical object
    assert prog1.fingerprint() == prog2.fingerprint()
    f1 = engine.compile_program(prog1, 64, 8)
    assert len(engine._COMPILE_CACHE) == 1
    f2 = engine.compile_program(prog2, 64, 8)
    assert f1 is f2, "identical (program, geometry) must hit the cache"


def test_cache_misses_on_geometry_change():
    engine.clear_compile_cache()
    prog, _ = programs.iadd(4, rows=64)
    f1 = engine.compile_program(prog, 64, 8)
    f2 = engine.compile_program(prog, 128, 8)
    f3 = engine.compile_program(prog, 64, 16)
    assert f1 is not f2 and f1 is not f3
    assert len(engine._COMPILE_CACHE) == 3


def test_cache_no_cross_contamination_same_name(rng):
    """Two same-named programs with different nodes: the 16-bit encoded
    words are identical (absolute rows live in registers), so the
    fingerprint must hash the expanded stream too."""
    p1 = Program("twin", [Instr(isa.OP_W1, dst=3)])
    p2 = Program("twin", [Instr(isa.OP_W1, dst=5)])
    assert isa.encode(p1) == isa.encode(p2)
    assert p1.fingerprint() != p2.fingerprint()

    state = engine.make_state(16, 8)
    out1 = engine.execute_compiled(p1, state)
    out2 = engine.execute_compiled(p2, state)
    assert np.asarray(out1.array)[3].all() and not \
        np.asarray(out1.array)[5].any()
    assert np.asarray(out2.array)[5].all() and not \
        np.asarray(out2.array)[3].any()


def test_cache_keys_include_packed_and_blocks():
    engine.clear_compile_cache()
    prog, _ = programs.iadd(4, rows=64)
    engine.compile_program(prog, 64, 8, packed=False)
    engine.compile_program(prog, 64, 8, packed=True)
    assert len(engine._COMPILE_CACHE) == 2


def test_cache_lru_eviction_and_recompile(rng):
    """The compile cache is a bounded LRU: exceeding the limit evicts
    the least-recently-used entry, and an evicted program recompiles to
    a bit-identical executable (eviction is perf-only, never
    correctness)."""
    old_limit = engine._COMPILE_CACHE.limit
    engine.clear_compile_cache()
    try:
        engine.set_compile_cache_limit(2)
        prog, lay = programs.iadd(4, rows=64)
        state = engine.CRState(
            array=jnp.asarray(rng.integers(0, 2, (64, 8)).astype(bool)),
            carry=jnp.zeros((8,), bool), tag=jnp.ones((8,), bool))
        f1 = engine.compile_program(prog, 64, 8)
        before = np.asarray(f1(state).array)
        engine.compile_program(prog, 64, 16)
        f1b = engine.compile_program(prog, 64, 8)      # touch: now MRU
        assert f1b is f1
        engine.compile_program(prog, 128, 8)           # evicts the 64x16
        assert len(engine._COMPILE_CACHE) == 2
        assert engine.compile_cache_stats()["evictions"] >= 1
        f1c = engine.compile_program(prog, 64, 8)      # still cached
        assert f1c is f1
        engine.compile_program(prog, 64, 16)           # evicts 128x8 ...
        engine.compile_program(prog, 128, 8)           # ... evicts 64x8
        f1d = engine.compile_program(prog, 64, 8)      # recompile
        assert f1d is not f1
        np.testing.assert_array_equal(before, np.asarray(f1d(state).array))
    finally:
        engine.set_compile_cache_limit(old_limit)
        engine.clear_compile_cache()


def test_cache_limit_validation():
    with pytest.raises(ValueError, match="limit"):
        engine.set_compile_cache_limit(0)


def test_cse_pass_bit_identical_and_smaller(rng):
    """compile_program(cse=True) routes through the jaxpr-level CSE
    pass: never more equations, identical results, and a distinct cache
    key from the un-CSE'd variant."""
    engine.clear_compile_cache()
    prog, lay = programs.idot(8, rows=128)
    a = rng.integers(0, 256, (lay.tuples, 8), dtype=np.uint64)
    b = rng.integers(0, 256, (lay.tuples, 8), dtype=np.uint64)
    state = harness.make_jax_state(
        harness.pack_state(lay, {"a": a, "b": b}, 8))
    f_raw = engine.compile_program(prog, 128, 8, cse=False)
    f_cse = engine.compile_program(prog, 128, 8, cse=True)
    assert f_raw is not f_cse          # resolved flag is in the cache key
    assert len(engine._COMPILE_CACHE) == 2
    stats = engine.last_cse_stats
    assert stats is not None
    assert 0 < stats["eqns_after"] <= stats["eqns_before"]
    np.testing.assert_array_equal(np.asarray(f_raw(state).array),
                                  np.asarray(f_cse(state).array))
    acc = harness.unpack_acc(np.asarray(f_cse(state).array), lay)
    np.testing.assert_array_equal(acc, (a * b).sum(axis=0))


def test_cse_auto_threshold():
    """cse=None resolves by expanded-stream size against CSE_MIN_CYCLES."""
    small, _ = programs.iadd(4, rows=64)
    assert engine._use_cse(small, None) is False
    assert engine._use_cse(small, True) is True
    big, _ = programs.bf16_add(rows=512)
    assert len(big.expand()) >= engine.CSE_MIN_CYCLES
    assert engine._use_cse(big, None) is True
    assert engine._use_cse(big, False) is False


def test_cse_jaxpr_pass_direct():
    """The raw pass: duplicate pure computations collapse; evaluation of
    the CSE'd jaxpr matches the original function exactly."""
    import jax

    from repro.core import compiler

    def f(x):
        a = (x + 1.0) * 2.0
        b = (x + 1.0) * 2.0          # duplicate of a
        return a + b, a - b

    example = jax.ShapeDtypeStruct((8,), jnp.float32)
    g = compiler.apply_cse(f, example)
    assert g._cse_stats["removed"] >= 2
    x = jnp.arange(8, dtype=jnp.float32)
    ga, gd = g(x)
    fa, fd = f(x)
    np.testing.assert_array_equal(np.asarray(ga), np.asarray(fa))
    np.testing.assert_array_equal(np.asarray(gd), np.asarray(fd))


# ---------------------------------------------------------------------------
# CRAM-backed matmul (pim <-> engine cross-layer)
# ---------------------------------------------------------------------------
def test_cram_matmul_exact(rng):
    from repro.pim import cram_dot, cram_matmul
    a = rng.integers(0, 16, (12, 8), dtype=np.uint64)
    b = rng.integers(0, 16, (12, 8), dtype=np.uint64)
    np.testing.assert_array_equal(cram_dot(a, b, 4, rows=256),
                                  (a * b).sum(axis=0))
    # tiles across both K (idot capacity) and N (block columns)
    x = rng.integers(0, 16, (2, 24), dtype=np.uint64)
    w = rng.integers(0, 16, (24, 12), dtype=np.uint64)
    np.testing.assert_array_equal(
        cram_matmul(x, w, n=4, rows=128, cols=8), x @ w)


# ---------------------------------------------------------------------------
# Cache-key collisions on the packed / multi-loop lowering paths
# (extends the PR 1 no-collision pins above: those only covered the
# bool-interior single-loop path)
# ---------------------------------------------------------------------------
def test_cache_no_collision_predication_packed(rng):
    """Fingerprint-adjacent programs -- same shape, same encoded words
    except one predication bit -- must NOT share a packed compile-cache
    entry: each compiles its own fn (two misses, zero hits), and the
    predicated twin really behaves differently."""
    def twin(pred):
        return Program("twin_pred", [
            Instr(isa.OP_TNROW, a=0),           # tag <- ~row0
            Instr(isa.OP_W1, dst=3, pred=pred),
            Instr(isa.OP_XOR, dst=5, a=3, b=1, pred=pred),
        ])

    p1, p2 = twin(False), twin(True)
    assert p1.footprint() == p2.footprint()
    assert p1.cycles() == p2.cycles()
    assert p1.fingerprint() != p2.fingerprint()

    engine.clear_compile_cache()
    base = engine.compile_cache_stats()
    state = _rand_state(rng, 16, 8)
    out1 = engine.execute_compiled(p1, state, packed=True)
    out2 = engine.execute_compiled(p2, state, packed=True)
    st = engine.compile_cache_stats()
    assert st["misses"] - base["misses"] == 2, \
        "pred-differing twins must each miss (no key collision)"
    assert st["hits"] == base["hits"]
    # the unpredicated twin unconditionally writes rows 3/5; the
    # predicated one only where tag (= ~row0) is set
    assert not _states_equal(out1, out2)
    np.testing.assert_array_equal(np.asarray(out1.array[3]),
                                  np.ones(8, bool))
    tag = ~np.asarray(state.array[0])
    np.testing.assert_array_equal(
        np.asarray(out2.array[3]),
        np.where(tag, True, np.asarray(state.array[3])))
    # replaying either twin is a pure hit -- nothing recompiles
    engine.execute_compiled(p1, state, packed=True)
    st2 = engine.compile_cache_stats()
    assert st2["misses"] == st["misses"] and st2["hits"] == st["hits"] + 1


def test_cache_miss_behavior_multiloop_and_blocks_paths(rng):
    """One fuzz-generated multi-loop program through the three compiled
    lowerings: bool, packed, and the wide-block path each key their own
    entry (distinct misses), and packed=None keys as its resolved
    default rather than a fourth entry."""
    from repro.core import fuzz

    cfg = fuzz.FuzzConfig(weights=tuple(
        (n, 1.0 if n == "multiloop" else 0.0) for n in fuzz.SEQUENCES))
    prog = fuzz.gen_program(0, cfg).program
    assert sum(isinstance(nd, Loop) for nd in prog.nodes) >= 2

    engine.clear_compile_cache()
    base = engine.compile_cache_stats()
    state = _rand_state(rng, cfg.rows, cfg.cols)
    blocks = engine.CRState(
        array=jnp.stack([state.array] * 3),
        carry=jnp.stack([state.carry] * 3),
        tag=jnp.stack([state.tag] * 3))

    outs = [engine.execute_compiled(prog, state, packed=False),
            engine.execute_compiled(prog, state, packed=True)]
    engine.execute_blocks(prog, blocks, "compiled", packed=True)
    st = engine.compile_cache_stats()
    assert st["misses"] - base["misses"] == 3, \
        "bool / packed / wide-block lowerings must not share keys"
    # packed=None resolves to default_packed(prog) -- a HIT on the
    # matching packed entry, not a new compile
    assert engine.default_packed(prog)
    engine.execute_compiled(prog, state, packed=None)
    st2 = engine.compile_cache_stats()
    assert st2["misses"] == st["misses"]
    assert st2["hits"] == st["hits"] + 1
    # all lowerings agree with the unroll oracle, of course
    want = engine.execute(prog, state)
    for out in outs:
        assert _states_equal(out, want)
