"""PR 3 fabric fast paths: overlapped rounds, weight-tile broadcast,
batched multi-round replay, and the schedule autotuner.

Everything here holds the same line as the PR 2 differential harness:
the *fast* paths (batched replay, broadcast-coalesced loads, autotuned
schedules) must stay bit-identical to the serial per-round execution --
they are optimizations of the launch structure and the cost model, never
of the arithmetic.
"""

import numpy as np
import pytest

from repro.core import costmodel as cm
from repro.pim import fabric
from repro.pim.fabric import FabricConfig

ROWS, COLS = 128, 8


def _grid(n_blocks, **kw):
    return FabricConfig(n_blocks=n_blocks, rows=ROWS, cols=COLS, **kw)


def _signed_operands(rng, nbits, m, k, n):
    lo, hi = -(1 << (nbits - 1)), 1 << (nbits - 1)
    x = rng.integers(lo, hi, (m, k)).astype(np.int64)
    w = rng.integers(lo, hi, (k, n)).astype(np.int64)
    return x, w


# int4/int8 x ragged shapes x 1/4/64-block grids (PR 2 matrix)
_MATRIX = [
    (4, 1, (3, 10, 11)),
    (4, 4, (3, 10, 11)),
    (4, 4, (2, 20, 16)),
    (4, 64, (5, 23, 17)),
    (8, 1, (2, 7, 5)),
    (8, 4, (2, 23, 5)),
    (8, 64, (3, 9, 10)),
]
_IDS = [f"int{n}-{b}blk-{'x'.join(map(str, s))}" for n, b, s in _MATRIX]


# ---------------------------------------------------------------------------
# Differential: batched replay == serial per-round == numpy
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("nbits,blocks,shape", _MATRIX, ids=_IDS)
def test_batched_replay_bit_identical(rng, nbits, blocks, shape):
    m, k, n = shape
    x, w = _signed_operands(rng, nbits, m, k, n)
    sched = fabric.schedule_gemm(m, k, n, nbits, cfg=_grid(blocks),
                                 signed=True)
    xu, _ = fabric.cram._bias_signed(x, nbits)
    wu, _ = fabric.cram._bias_signed(w, nbits)
    raw_serial = fabric.execute_schedule(sched, xu, wu, batch_rounds=False)
    raw_batch = fabric.execute_schedule(sched, xu, wu, batch_rounds=True)
    np.testing.assert_array_equal(raw_serial, raw_batch)
    # and the full signed path lands on numpy ground truth
    res = fabric.fabric_matmul(x, w, nbits=nbits, cfg=_grid(blocks),
                               signed=True)
    np.testing.assert_array_equal(res.out, x @ w)


def test_batched_replay_chunked(rng):
    """Tiny max_batch_blocks forces multiple padded chunks; still exact."""
    x, w = _signed_operands(rng, 4, 5, 23, 17)
    sched = fabric.schedule_gemm(5, 23, 17, 4, cfg=_grid(4), signed=True)
    assert len(sched.rounds) > 2
    xu, _ = fabric.cram._bias_signed(x, 4)
    wu, _ = fabric.cram._bias_signed(w, 4)
    ref = fabric.execute_schedule(sched, xu, wu, batch_rounds=False)
    for cap in (1, sched.n_compute, 2 * sched.n_compute + 1):
        got = fabric.execute_schedule(sched, xu, wu, batch_rounds=True,
                                      max_batch_blocks=cap)
        np.testing.assert_array_equal(ref, got)


def test_autotuned_schedule_bit_identical(rng):
    """The search argmin executes to the same integers as ground truth."""
    m, k, n, nbits = 5, 23, 17, 4
    x, w = _signed_operands(rng, nbits, m, k, n)
    sr = fabric.search_schedule(m, k, n, nbits, base=_grid(8), signed=True,
                                geometries=((ROWS, COLS),))
    res = fabric.fabric_matmul(x, w, nbits=nbits, signed=True,
                               schedule=sr.schedule)
    np.testing.assert_array_equal(res.out, x @ w)


def test_fabric_matmul_rejects_mismatched_schedule(rng):
    sched = fabric.schedule_gemm(2, 7, 5, 8, cfg=_grid(2), signed=True)
    x, w = _signed_operands(rng, 8, 3, 7, 5)           # wrong M
    with pytest.raises(ValueError, match="does not match"):
        fabric.fabric_matmul(x, w, nbits=8, signed=True, schedule=sched)


# ---------------------------------------------------------------------------
# IR invariants: the load stage
# ---------------------------------------------------------------------------
def _loads_by_key(rnd):
    d = {}
    for ld in rnd.loads:
        d.setdefault((ld.kind,) + tuple(ld.key), []).append(ld)
    return d


def test_every_task_operand_loaded_or_resident():
    """No round reads a tile whose load hasn't retired: every operand a
    task touches is covered by a load of the SAME round destined to the
    task's block, or was fetched into that block by an earlier round
    (the resident-tile map)."""
    sched = fabric.schedule_gemm(5, 23, 17, 4, cfg=_grid(8), signed=True)
    resident = {b: set() for b in sched.compute_blocks}
    for rnd in sched.rounds:
        by_key = _loads_by_key(rnd)
        for t in rnd.tasks:
            for kind, key, src in (("x", (t.m, t.k0), t.x_src),
                                   ("w", (t.gemm, t.k0, t.n0), t.w_src)):
                loads = by_key.get((kind,) + key)
                fetched = loads is not None and \
                    any(t.block in ld.dsts for ld in loads)
                assert fetched or (kind,) + key in resident[t.block], \
                    f"{kind}{key} neither loaded nor resident"
                assert loads is None or all(ld.src == src for ld in loads)
        for ld in rnd.loads:
            for d in ld.dsts:
                resident[d].add((ld.kind,) + tuple(ld.key))


def test_broadcast_coalesced_and_residency_skips_reloads():
    """A round's tasks sharing a weight tile join ONE broadcast load;
    later rounds reusing the (now resident) tile issue NO load at all."""
    # M > n_compute so every round's tasks share one (ki, ni) tile and
    # the same tile recurs across rounds
    sched = fabric.schedule_gemm(6, 10, 8, 4, cfg=_grid(4), signed=True)
    assert len(sched.rounds) >= 2
    first = sched.rounds[0]
    w_loads = [ld for ld in first.loads if ld.kind == "w"]
    assert len(w_loads) == 1                        # one tile, one fetch
    ld = w_loads[0]
    assert tuple(ld.key) == (0, 0, 0)               # (gemm, k0, n0)
    assert set(ld.dsts) == {t.block for t in first.tasks}   # broadcast
    assert len(ld.dsts) > 1
    assert len({t.w_src for t in first.tasks}) == 1          # share w_src
    assert ld.src == first.tasks[0].w_src
    # every later round reads the same weight tile from residency
    for rnd in sched.rounds[1:]:
        assert all(l_.kind != "w" for l_ in rnd.loads), \
            "resident weight tile must not be re-fetched"
    st = fabric.residency_stats(sched)
    assert st["hits"] > 0 and st["fetch_reduction"] > 1.0


def test_residency_disabled_reloads_every_round():
    """cfg.residency=False restores the PR 3 reload-every-round load
    stage: one fetch per distinct tile per round, zero hits."""
    cfg = _grid(4, residency=False)
    sched = fabric.schedule_gemm(6, 10, 8, 4, cfg=cfg, signed=True)
    for rnd in sched.rounds:
        assert any(ld.kind == "w" for ld in rnd.loads)
    st = fabric.residency_stats(sched)
    assert st["hits"] == 0 and st["fetch_reduction"] == 1.0
    assert st["fetches"] == st["reload_fetches"]


def test_x_loads_keyed_per_k_slice():
    """Distinct K-slices of one activation row are distinct payloads:
    they must NOT coalesce into one load (regression: keying x loads on
    m alone modeled a round's worth of x traffic as a single fetch)."""
    # M=1: a round's tasks all read row 0 but across several K-tiles
    sched = fabric.schedule_gemm(1, 40, 8, 4,
                                 cfg=_grid(4, min_compute_blocks=4),
                                 signed=True)
    kt = sched.kt
    for rnd in sched.rounds:
        x_loads = [ld for ld in rnd.loads if ld.kind == "x"]
        k0s = {t.k0 for t in rnd.tasks}
        assert {tuple(ld.key) for ld in x_loads} == {(0, k0) for k0 in k0s}
        for ld in x_loads:
            kw = min(40, ld.key[1] + kt) - ld.key[1]
            assert ld.bits == kw * sched.nbits
    # total modeled x bits = every (m, k-slice) pair once per round
    total_x = sum(ld.bits for rnd in sched.rounds for ld in rnd.loads
                  if ld.kind == "x")
    want = sum((t.k1 - t.k0) * sched.nbits
               for rnd in sched.rounds
               for t in {(tt.m, tt.k0): tt for tt in rnd.tasks}.values())
    assert total_x == want


def test_broadcast_moves_fewer_bits_than_unicast():
    """The wire-energy split prices a broadcast once: coalesced loads
    move strictly fewer fabric bits than per-task unicast would."""
    sched = fabric.schedule_gemm(6, 10, 8, 4, cfg=_grid(4), signed=True)
    per_task_bits = sum(
        (t.k1 - t.k0) * sched.nbits + (t.k1 - t.k0) * (t.n1 - t.n0)
        * sched.nbits
        for rnd in sched.rounds for t in rnd.tasks if t.w_src >= 0
        or t.x_src >= 0)
    load_bits = sum(ld.bits for rnd in sched.rounds for ld in rnd.loads
                    if ld.src >= 0)
    assert load_bits < per_task_bits


# ---------------------------------------------------------------------------
# Overlap latency model
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("nbits,blocks,shape", _MATRIX, ids=_IDS)
def test_overlapped_strictly_below_serial(nbits, blocks, shape):
    m, k, n = shape
    sched = fabric.schedule_gemm(m, k, n, nbits, cfg=_grid(blocks),
                                 signed=True)
    cost = fabric.schedule_cost(sched)
    assert cost.serial_cycles_ > 0 and cost.overlapped_cycles_ > 0
    if len(sched.rounds) >= 2:
        assert cost.overlapped_cycles_ < cost.serial_cycles_
    else:
        assert cost.overlapped_cycles_ == pytest.approx(cost.serial_cycles_)
    # the serial model and the legacy time roll-up are ONE model
    assert cost.serial_cycles_ / cm.FREQ_CIRCUIT_CR_MHZ == \
        pytest.approx(cost.time_us, rel=1e-9)
    assert cost.time_us_overlapped <= cost.time_us + 1e-9
    assert cost.overlap_speedup >= 1.0


def test_overlap_reported_and_combined():
    sched = fabric.schedule_gemm(5, 23, 17, 4, cfg=_grid(4), signed=True)
    cost = fabric.schedule_cost(sched)
    rep = cost.report()
    for key in ("serial_cycles", "overlapped_cycles", "time_us_overlapped",
                "overlap_speedup"):
        assert key in rep
    total = fabric.combine_costs("two", [cost, cost])
    assert total.serial_cycles == pytest.approx(2 * cost.serial_cycles_)
    assert total.overlapped_cycles == pytest.approx(
        2 * cost.overlapped_cycles_)


# ---------------------------------------------------------------------------
# Schedule autotuner
# ---------------------------------------------------------------------------
def test_search_schedule_returns_argmin():
    sr = fabric.search_schedule(8, 64, 32, 4, base=_grid(8),
                                geometries=((128, 8), (256, 16), (512, 40)))
    assert sr.candidates, "search must price at least one candidate"
    best = min(c["objective"] for c in sr.candidates)
    got = sr.cost.overlapped_cycles_
    assert got == pytest.approx(best, rel=1e-6)
    # the returned schedule really is a plan for the requested GEMM
    s = sr.schedule
    assert (s.M, s.K, s.N) == (8, 64, 32)
    assert s.cfg.n_blocks == 8


def test_search_schedule_memoized_and_validated():
    a = fabric.search_schedule(4, 20, 8, 4, base=_grid(4),
                               geometries=((128, 8),))
    b = fabric.search_schedule(4, 20, 8, 4, base=_grid(4),
                               geometries=((128, 8),))
    assert a is b                                  # LRU memo hit
    with pytest.raises(ValueError, match="objective"):
        fabric.search_schedule(4, 20, 8, 4, base=_grid(4),
                               objective="nope")


def test_search_skips_impossible_geometries():
    """A geometry too small to host the idot program is skipped, not
    fatal -- as long as one candidate remains."""
    sr = fabric.search_schedule(2, 8, 4, 8, base=_grid(2),
                                geometries=((40, 8), (128, 8)))
    assert all(c["rows"] == 128 for c in sr.candidates)
    with pytest.raises(ValueError, match="no candidate"):
        fabric.search_schedule(2, 8, 4, 8, base=_grid(2),
                               geometries=((40, 8),))


def test_linear_fabric_autotune_equals_ref():
    import jax
    import jax.numpy as jnp

    from repro.pim import PimConfig, linear_apply, linear_init, pack_linear

    cfga = PimConfig(mode="fabric", weight_bits=4, fabric=_grid(6),
                     fabric_autotune=True)
    cfgr = PimConfig(mode="ref", weight_bits=4)
    dense = linear_init(jax.random.PRNGKey(0), 32, 8, cfgr)
    packed = pack_linear(dense, cfgr)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 32), jnp.bfloat16)
    yr = linear_apply(packed, x, cfgr)
    ya = linear_apply(packed, x, cfga)
    np.testing.assert_array_equal(np.asarray(yr, np.float32),
                                  np.asarray(ya, np.float32))


def test_probe_autotune_reports_grid(rng):
    from repro.pim.fabric import FabricLinearProbe

    w = rng.normal(size=(16, 6)).astype(np.float32)
    probe = FabricLinearProbe(w, cfg=_grid(4), bits=8, max_steps=1,
                              autotune=True)
    x = rng.normal(size=(2, 16)).astype(np.float32)
    y_tuned = probe.observe(x)
    assert probe.search is not None
    rep = probe.report()
    assert rep["autotuned"] and rep["geometry"] == f"{ROWS}x{COLS}"
    # tuned output == untuned output (same arithmetic, different split)
    ref = FabricLinearProbe(w, cfg=_grid(4), bits=8, max_steps=1)
    y_ref = ref.observe(x)
    np.testing.assert_array_equal(y_tuned, y_ref)
    assert ref.report()["autotuned"] is False
