"""Constrained-random fuzzer: tier-1 budget, shrinking, corpus replay.

Four layers:

* generator invariants -- seed determinism (fingerprint/cycles/
  footprint), validity-by-construction for every sequence family, text
  serialization roundtrips;
* a small bounded differential budget (the big 200-program budget runs
  as its own CI step via ``benchmarks/fuzz_run.py``);
* the mismatch pipeline, driven by a known-bad mutation hook: the
  forced bug must be caught, delta-debug shrunk to a <= 10-op repro,
  written to a corpus file, and that file must replay;
* the committed corpus under ``tests/corpus/`` -- every file is a
  permanent regression: recorded cycles/footprint must not drift and
  the replay matrix must stay bit-identical.
"""

import pathlib

import pytest

from repro.core import engine, fuzz, isa
from repro.core.isa import Instr, Loop, Program, SetReg, R

CORPUS_DIR = pathlib.Path(__file__).parent / "corpus"
CFG = fuzz.FuzzConfig()


# ---------------------------------------------------------------------------
# Generator invariants
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", [0, 7, 123, 9999])
def test_gen_seed_deterministic(seed):
    a = fuzz.gen_program(seed, CFG)
    b = fuzz.gen_program(seed, CFG)
    assert a.program.fingerprint() == b.program.fingerprint()
    assert a.program.cycles() == b.program.cycles()
    assert a.program.footprint() == b.program.footprint()
    assert [n for n, _ in a.groups] == [n for n, _ in b.groups]


def test_gen_valid_by_construction():
    for seed in range(40):
        fp = fuzz.gen_program(seed, CFG)
        assert isa.validate_program(fp.program, CFG.rows) == []
        assert fp.program.cycles() > 0
        assert fp.program.fits_imem()


@pytest.mark.parametrize("name", sorted(fuzz.SEQUENCES))
def test_each_sequence_wellformed(name):
    """Every sequence family, in isolation, emits only valid programs."""
    cfg = fuzz.FuzzConfig(weights=tuple(
        (n, 1.0 if n == name else 0.0) for n in fuzz.SEQUENCES))
    for seed in range(25):
        fp = fuzz.gen_program(seed, cfg)
        assert all(n == name for n, _ in fp.groups)
        assert isa.validate_program(fp.program, cfg.rows) == []


def test_multiloop_sequence_has_two_loops():
    cfg = fuzz.FuzzConfig(weights=tuple(
        (n, 1.0 if n == "multiloop" else 0.0) for n in fuzz.SEQUENCES))
    fp = fuzz.gen_program(0, cfg)
    top_loops = sum(isinstance(nd, Loop) for nd in fp.program.nodes)
    assert top_loops >= 2


def test_text_roundtrip():
    for seed in (0, 3, 11, 29):
        fp = fuzz.gen_program(seed, CFG)
        fp2, pins = fuzz.program_from_text(fuzz.program_to_text(fp))
        assert fp2.program.expand() == fp.program.expand()
        assert pins["cycles"] == fp.program.cycles()
        assert pins["footprint"] == fp.program.footprint()
        assert fp2.seed == fp.seed
        assert fp2.cfg.rows == fp.cfg.rows


def test_validate_program_catches_bad_rows():
    prog = Program("bad", [Instr(isa.OP_COPY, dst=99, a=0)])
    assert fuzz and isa.validate_program(prog, rows=48)
    prog2 = Program("bad2", [SetReg(1, 40),
                             Loop(20, [Instr(isa.OP_W1, R(1),
                                             inc=((1, 1),))])])
    assert isa.validate_program(prog2, rows=48)
    ok = Program("ok", [Instr(isa.OP_COPY, dst=1, a=0)])
    assert isa.validate_program(ok, rows=48) == []


# ---------------------------------------------------------------------------
# Bounded differential budget (tier-1's in-suite slice)
# ---------------------------------------------------------------------------
def test_bounded_budget_clean():
    stats = fuzz.run_budget(10, seed=0, cfg=CFG, corpus_dir=None)
    assert stats["programs"] == 10
    assert stats["mismatch"] is None, stats["mismatch"].mismatches
    assert stats["ops"] > 0
    assert stats["seq_histogram"]


# ---------------------------------------------------------------------------
# The mismatch -> shrink -> corpus pipeline, via the known-bad mutation
# ---------------------------------------------------------------------------
def test_forced_mutation_shrinks_to_minimal_repro(tmp_path):
    mut = fuzz.MUTATIONS["fa-flip"]
    stats = fuzz.run_budget(30, seed=0, cfg=CFG, mutate=mut,
                            corpus_dir=tmp_path)
    assert stats["mismatch"] is not None, \
        "fa-flip mutation was never caught"
    assert any(m.variant == "compiled:packed=True"
               for m in stats["mismatch"].mismatches)
    # the issue's acceptance bar: shrinks to a <= 10-op repro
    assert stats["shrunk_ops"] is not None and stats["shrunk_ops"] <= 10
    repro = pathlib.Path(stats["repro_path"])
    assert repro.exists() and repro.parent == tmp_path
    # the corpus file replays: still failing under the mutation, clean
    # without it (the engine itself is correct)
    fp, pins = fuzz.load_corpus(repro)
    assert pins["cycles"] == fp.program.cycles()
    assert not fuzz.replay(fp, mutate=mut).ok
    assert fuzz.replay(fp).ok


def test_shrink_is_greedy_minimal():
    """Pure-python shrink check (no replays): a predicate that only
    needs one FA instruction must strip everything else."""
    fp = fuzz.gen_program(1, CFG)   # seed 1 contains an FA (ripple seq)

    def fails(cand):
        return any(i.op == isa.OP_FA for i in cand.program.expand())

    assert fails(fp)
    small = fuzz.shrink(fp, fails)
    stream = small.program.expand()
    assert len(stream) == 1 and stream[0].op == isa.OP_FA
    assert small.shrunk


def test_replay_pins_cycles_and_footprint():
    """replay() re-derives the program from its seed and cross-checks
    fingerprint/cycles/footprint -- the seed-discipline assertion."""
    fp = fuzz.gen_program(5, CFG)
    rep = fuzz.replay(fp, variants=("compiled:packed=False",))
    assert rep.ok
    assert rep.cycles == fp.program.cycles()
    assert rep.footprint == fp.program.footprint()


# ---------------------------------------------------------------------------
# Committed corpus: permanent regressions
# ---------------------------------------------------------------------------
_corpus_files = sorted(CORPUS_DIR.glob("fuzz_*.txt"))


def test_corpus_is_committed():
    assert _corpus_files, f"no corpus files under {CORPUS_DIR}"


@pytest.mark.parametrize("path", _corpus_files,
                         ids=[p.stem for p in _corpus_files])
def test_corpus_replays_bit_identical(path):
    fp, pins = fuzz.load_corpus(path)
    # accounting must not drift from what was recorded at capture time
    assert fp.program.cycles() == pins["cycles"], \
        f"{path.name}: cycle accounting drifted"
    assert fp.program.footprint() == pins["footprint"], \
        f"{path.name}: imem footprint drifted"
    assert isa.validate_program(fp.program, fp.cfg.rows) == []
    rep = fuzz.replay(fp)
    assert rep.ok, [f"{m.variant}/{m.field}: {m.detail}"
                    for m in rep.mismatches]


def test_cache_stats_move_during_replay():
    """The replay matrix actually exercises the compile cache."""
    engine.clear_compile_cache()
    before = engine.compile_cache_stats()["misses"]
    fuzz.replay(fuzz.gen_program(2, CFG))
    after = engine.compile_cache_stats()["misses"]
    assert after > before
