"""Hypothesis property tests on system invariants."""

import os

import numpy as np
import pytest

# CI exports REQUIRE_HYPOTHESIS=1 after installing requirements-dev.txt:
# there a missing hypothesis is a hard failure (the tier silently
# skipping is exactly the drift this guards against); locally it stays
# a clean skip.
if os.environ.get("REQUIRE_HYPOTHESIS"):
    import hypothesis
else:
    hypothesis = pytest.importorskip(
        "hypothesis", reason="property tests need hypothesis "
        "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import engine, harness, programs
from repro.core import ref as cref
from repro.kernels import ref as kref


@st.composite
def _int_vectors(draw, nbits):
    t = draw(st.integers(2, 6))
    cols = draw(st.integers(1, 6))
    lo, hi = 0, (1 << nbits)
    a = draw(st.lists(st.integers(lo, hi - 1), min_size=t * cols,
                      max_size=t * cols))
    b = draw(st.lists(st.integers(lo, hi - 1), min_size=t * cols,
                      max_size=t * cols))
    return (np.array(a, np.uint64).reshape(t, cols),
            np.array(b, np.uint64).reshape(t, cols))


def _run(prog, lay, data, cols):
    return harness.run_program(prog, lay, data, cols)


@settings(max_examples=15, deadline=None)
@given(_int_vectors(4))
def test_prop_iadd4(ab):
    a, b = ab
    prog, lay = programs.iadd(4, rows=128, tuples=a.shape[0])
    out = _run(prog, lay, {"a": a, "b": b}, a.shape[1])
    got = harness.unpack_field(out, lay, "d")
    np.testing.assert_array_equal(got, cref.iadd(a, b, 4))


@settings(max_examples=15, deadline=None)
@given(_int_vectors(4))
def test_prop_imul4(ab):
    a, b = ab
    prog, lay = programs.imul(4, rows=256, tuples=a.shape[0])
    out = _run(prog, lay, {"a": a, "b": b}, a.shape[1])
    got = harness.unpack_field(out, lay, "d")
    np.testing.assert_array_equal(got, cref.imul(a, b, 4))


@settings(max_examples=15, deadline=None)
@given(_int_vectors(4))
def test_prop_idot4(ab):
    a, b = ab
    prog, lay = programs.idot(4, rows=128, tuples=a.shape[0])
    out = _run(prog, lay, {"a": a, "b": b}, a.shape[1])
    np.testing.assert_array_equal(harness.unpack_acc(out, lay),
                                  cref.idot(a, b))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 4))
def test_prop_bf16_add_matches_oracle(seed, tuples):
    rng = np.random.default_rng(seed)
    s = rng.integers(0, 2, (tuples, 8)).astype(np.uint32)
    e = rng.integers(90, 160, (tuples, 8)).astype(np.uint32)
    m = rng.integers(0, 128, (tuples, 8)).astype(np.uint32)
    a = ((s << 15) | (e << 7) | m).astype(np.uint16)
    s2 = rng.integers(0, 2, (tuples, 8)).astype(np.uint32)
    e2 = rng.integers(90, 160, (tuples, 8)).astype(np.uint32)
    m2 = rng.integers(0, 128, (tuples, 8)).astype(np.uint32)
    b = ((s2 << 15) | (e2 << 7) | m2).astype(np.uint16)
    prog, lay = programs.bf16_add(rows=512, tuples=tuples)
    out = _run(prog, lay, {"a": a, "b": b}, 8)
    got = harness.unpack_field(out, lay, "d").astype(np.uint16)
    np.testing.assert_array_equal(got, cref.bf16_add(a, b))


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([4, 8]),
       st.sampled_from([32, 64, 96]))
def test_prop_bitplane_matmul_exact(seed, bits, k):
    """Bit-plane decomposition is EXACT integer arithmetic for any
    shape/bit-width: pack -> popcount matmul == plain int matmul."""
    rng = np.random.default_rng(seed)
    m, n = int(rng.integers(1, 8)), int(rng.integers(8, 32))
    lo, hi = -(1 << (bits - 1)), 1 << (bits - 1)
    a = rng.integers(lo, hi, (m, k)).astype(np.int8)
    w = rng.integers(lo, hi, (k, n)).astype(np.int8)
    ap = kref.pack_bitplanes(jnp.asarray(a), bits, axis=1)
    wp = kref.pack_bitplanes(jnp.asarray(w), bits, axis=0)
    got = np.asarray(kref.popcount_matmul(ap, wp, True, True))
    want = a.astype(np.int64) @ w.astype(np.int64)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_prop_checkpoint_roundtrip(seed):
    import tempfile
    from repro.train import checkpoint as ckpt
    rng = np.random.default_rng(seed)
    tmp = tempfile.mkdtemp(prefix=f"ck{seed % 1000}_")
    tree = {"a": jnp.asarray(rng.normal(size=(3, 5)), jnp.float32),
            "b": [jnp.asarray(rng.normal(size=(4,)), jnp.bfloat16)],
            "n": int(rng.integers(0, 100))}
    ckpt.save(tmp, 1, tree)
    back, _ = ckpt.restore(tmp, tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_prop_predication_isolation(seed):
    """Columns with tag=0 are never modified by predicated row writes --
    the per-column predication invariant of the logic peripherals."""
    from repro.core.isa import Instr, Program, OP_FA, OP_TROW, OP_W1
    rng = np.random.default_rng(seed)
    rows, cols = 16, 8
    arr = rng.integers(0, 2, (rows, cols)).astype(bool)
    arr[0] = rng.integers(0, 2, cols).astype(bool)   # tag source row
    prog = Program("p", [
        Instr(OP_TROW, a=0),
        Instr(OP_W1, 3, pred=True),
        Instr(OP_FA, 5, 6, 7, pred=True),
    ])
    st_ = engine.CRState(jnp.asarray(arr), jnp.zeros((cols,), bool),
                         jnp.ones((cols,), bool))
    out = np.asarray(engine.execute(prog, st_).array)
    masked = ~arr[0]
    np.testing.assert_array_equal(out[:, masked], arr[:, masked])


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_prop_storage_mode_isolation(seed):
    """Compute programs only write their layout's scratch/result rows:
    the dual-mode claim -- operand storage is preserved bit-exactly."""
    rng = np.random.default_rng(seed)
    prog, lay = programs.iadd(8, rows=128)
    a = rng.integers(0, 256, (lay.tuples, 8)).astype(np.uint64)
    b = rng.integers(0, 256, (lay.tuples, 8)).astype(np.uint64)
    arr = harness.pack_state(lay, {"a": a, "b": b}, 8)
    st_ = engine.CRState(jnp.asarray(arr), jnp.zeros((8,), bool),
                         jnp.ones((8,), bool))
    out = np.asarray(engine.execute(prog, st_).array)
    # operands unchanged after compute mode
    np.testing.assert_array_equal(harness.unpack_field(out, lay, "a"), a)
    np.testing.assert_array_equal(harness.unpack_field(out, lay, "b"), b)
