"""Training substrate: optimizer, data determinism, checkpoint
round-trip, fault-tolerant runner (fault injection + restore)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs
from repro.models.model import LM
from repro.train import checkpoint as ckpt
from repro.train import data as data_mod
from repro.train import optimizer as opt_mod
from repro.train.runner import RunnerConfig, Trainer, elastic_remesh
from repro.train.step import jit_train_step


def test_optimizer_converges_quadratic():
    cfg = opt_mod.OptConfig(lr=0.1, warmup_steps=5, total_steps=200,
                            weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt_mod.init(params, cfg)
    loss = lambda p: jnp.sum(jnp.square(p["w"] - 1.0))
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, state, _ = opt_mod.apply(params, g, state, cfg)
    assert float(loss(params)) < 1e-2


def test_data_pipeline_deterministic_and_shardable():
    cfg = data_mod.DataConfig(seed=7, global_batch=8, seq_len=16, vocab=100)
    p0 = data_mod.Pipeline(cfg)
    a = np.asarray(p0.batch(3)["tokens"])
    b = np.asarray(p0.batch(3)["tokens"])
    np.testing.assert_array_equal(a, b)           # counter-based: pure
    assert (np.asarray(p0.batch(4)["tokens"]) != a).any()
    # 2-host sharding covers the same global batch, disjointly
    h0 = data_mod.Pipeline(cfg, host_id=0, n_hosts=2)
    h1 = data_mod.Pipeline(cfg, host_id=1, n_hosts=2)
    gb = np.concatenate([np.asarray(h0.batch(3)["tokens"]),
                         np.asarray(h1.batch(3)["tokens"])])
    np.testing.assert_array_equal(gb, a)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)},
            "s": jnp.asarray(5, jnp.int32)}
    ckpt.save(tmp_path, 10, tree)
    ckpt.save(tmp_path, 20, tree)
    assert ckpt.latest_step(tmp_path) == 20
    back, meta = ckpt.restore(tmp_path, tree)
    assert meta["step"] == 20
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))
        assert x.dtype == y.dtype


def test_checkpoint_retention(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(tmp_path, s, tree, keep=2)
    assert ckpt.all_steps(tmp_path) == [4, 5]


@pytest.mark.slow     # multi-step training loop + restarts
def test_runner_end_to_end_with_fault_injection(tmp_path):
    cfg = configs.get_config("qwen2-0.5b", smoke=True)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = opt_mod.OptConfig(lr=1e-3, warmup_steps=2, total_steps=30)
    opt_state = opt_mod.init(params, opt_cfg)
    pipe = data_mod.Pipeline(data_mod.DataConfig(
        global_batch=2, seq_len=16, vocab=cfg.vocab))
    step_fn = jit_train_step(model, opt_cfg, donate=False)

    failures = {"armed": True}

    def fail_hook(step):
        if step == 12 and failures["armed"]:
            failures["armed"] = False
            raise RuntimeError("simulated node failure")

    tr = Trainer(RunnerConfig(total_steps=15, ckpt_every=5,
                              ckpt_dir=str(tmp_path), log_every=100),
                 step_fn, params, opt_state, pipe,
                 fail_hook=fail_hook, log=lambda *a: None)
    end_step, metrics = tr.run()
    assert end_step == 15
    assert tr.restarts == 1                      # failed once, recovered
    assert np.isfinite(metrics["loss"])
    assert ckpt.latest_step(tmp_path) == 15


def test_elastic_remesh_resizing():
    assert elastic_remesh(256, 16, 8) == 32      # lose half the pod
    with pytest.raises(AssertionError):
        elastic_remesh(256, 16, 7)               # non-divisible topology


@pytest.mark.slow     # 30-step training run
def test_loss_decreases_over_short_run(tmp_path):
    """End-to-end sanity: 30 steps of a tiny model on synthetic data."""
    cfg = configs.get_config("llama3.2-1b", smoke=True)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(1))
    opt_cfg = opt_mod.OptConfig(lr=3e-3, warmup_steps=5, total_steps=30)
    opt_state = opt_mod.init(params, opt_cfg)
    pipe = data_mod.Pipeline(data_mod.DataConfig(
        global_batch=4, seq_len=32, vocab=cfg.vocab))
    step_fn = jit_train_step(model, opt_cfg, donate=False)
    losses = []
    for s in range(30):
        params, opt_state, m = step_fn(params, opt_state, pipe.batch(s))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2


def test_async_checkpoint_saver(tmp_path):
    from repro.train.checkpoint import AsyncSaver
    s = AsyncSaver()
    tree = {"w": jnp.arange(10, dtype=jnp.float32)}
    s.submit(tmp_path, 5, tree)
    s.submit(tmp_path, 6, tree)     # joins the first automatically
    s.wait()
    assert ckpt.all_steps(tmp_path) == [5, 6]
    back, meta = ckpt.restore(tmp_path, tree)
    assert meta["step"] == 6
    np.testing.assert_array_equal(np.asarray(back["w"]),
                                  np.arange(10, dtype=np.float32))


def test_compressed_gradient_allreduce():
    """int8-compressed DP gradient psum ~= exact psum (bounded error)."""
    import os
    from repro.train.compress import make_compressed_grad_fn
    from repro.launch.mesh import make_mesh

    if jax.device_count() < 2:
        pytest.skip("needs >= 2 devices (run under dryrun env for full)")

    mesh = make_mesh(2, 1)
    with mesh:
        def loss_fn(params, batch):
            pred = batch["x"] @ params["w"]
            return jnp.mean((pred - batch["y"]) ** 2)

        rng = np.random.default_rng(0)
        params = {"w": jnp.asarray(rng.normal(0, 1, (8, 4)), jnp.float32)}
        batch = {"x": jnp.asarray(rng.normal(0, 1, (16, 8)), jnp.float32),
                 "y": jnp.asarray(rng.normal(0, 1, (16, 4)), jnp.float32)}

        fn = make_compressed_grad_fn(loss_fn, mesh, bits=8)
        loss_c, grads_c = jax.jit(fn)(params, batch)
        loss_e, grads_e = jax.value_and_grad(loss_fn)(params, batch)

        assert abs(float(loss_c) - float(loss_e)) < 1e-4
        ge = np.asarray(grads_e["w"])
        gc = np.asarray(grads_c["w"])
        # error bounded by quantization step ~ max|g|/127 per shard
        assert np.abs(gc - ge).max() < np.abs(ge).max() / 40


@pytest.mark.slow     # pmap compile across 4 host devices
def test_compressed_gradient_allreduce_multidevice():
    """Run the compressed-psum test on 4 fake devices via subprocess
    (the in-process test skips on single-device environments)."""
    import subprocess
    import sys
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-q",
         "tests/test_train.py::test_compressed_gradient_allreduce"],
        capture_output=True, text=True, timeout=300,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
             "JAX_PLATFORMS": "cpu",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=4"})
    assert r.returncode == 0, r.stdout[-1500:] + r.stderr[-1500:]
    assert "1 passed" in r.stdout


@pytest.mark.slow     # subprocess training restart
def test_elastic_restart_subprocess():
    """Full elastic scenario: train on (2,2), checkpoint, lose half the
    data axis, restore on (1,2), continue -- losses match an
    uninterrupted reference run (see tests/elastic_scenario.py)."""
    import subprocess
    import sys
    r = subprocess.run(
        [sys.executable, "tests/elastic_scenario.py"],
        capture_output=True, text=True, timeout=500,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"})
    assert r.returncode == 0, r.stdout[-1500:] + r.stderr[-1500:]
    assert "ELASTIC_OK" in r.stdout


def test_file_backed_data_pipeline(tmp_path):
    """memmap token-file source: deterministic, in-vocab, resumable."""
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 1000, 4096).astype(np.uint16)
    fp = tmp_path / "tokens.bin"
    toks.tofile(fp)
    cfg = data_mod.DataConfig(seed=3, global_batch=4, seq_len=32,
                              vocab=1000, path=str(fp))
    pipe = data_mod.Pipeline(cfg)
    b1 = np.asarray(pipe.batch(7)["tokens"])
    b2 = np.asarray(pipe.batch(7)["tokens"])
    np.testing.assert_array_equal(b1, b2)
    assert b1.shape == (4, 32)
    assert (b1 >= 0).all() and (b1 < 1000).all()
    # windows really come from the file
    flat = b1[0]
    starts = [i for i in range(len(toks) - 32)
              if (toks[i:i + 32] == flat).all()]
    assert starts, "batch window not found in source file"
