"""ServeEngine edge cases: slot recycling, prompt buckets, sampling
keys, deadlines, and fault-driven degradation.

Uses a stub model whose logits are a pure function of the *input*
token (``next == (7*t + 3) % vocab``), so slot bookkeeping mistakes,
padded-prefill indexing errors, and cache mixups change visible
tokens instead of hiding in argmax-of-ones.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.faults import FaultModel
from repro.pim.fabric import FabricConfig, FabricLinearProbe
from repro.serve.engine import Request, ServeEngine, _bucket

VOCAB = 32


def _f(t):
    return (7 * t + 3) % VOCAB


class _EchoModel:
    """Next-token logits = one-hot of ``_f(input token)``."""

    def __init__(self, vocab=VOCAB, d=16):
        self.vocab = vocab
        rng = np.random.default_rng(0)
        self.embed = rng.normal(size=(vocab, d)).astype(np.float32)

    def init_cache(self, b, cap):
        return {"n": jnp.zeros((b,), jnp.int32)}

    def _embed(self, params, tokens):
        return jnp.asarray(self.embed)[tokens]

    def prefill(self, params, tokens, capacity=None):
        logits = jax.nn.one_hot((tokens * 7 + 3) % self.vocab, self.vocab)
        return logits, {"n": jnp.zeros((1,), jnp.int32)}

    def decode_step(self, params, caches, tokens, pos):
        logits = jax.nn.one_hot((tokens * 7 + 3) % self.vocab, self.vocab)
        return logits, caches


def _engine(**kw):
    return ServeEngine(_EchoModel(), params={}, batch_slots=kw.pop("B", 2),
                       capacity=kw.pop("capacity", 16), **kw)


# ---------------------------------------------------------------------------
# Prompt buckets
# ---------------------------------------------------------------------------
def test_bucket_function():
    assert [_bucket(n) for n in (1, 2, 3, 4, 5, 8, 9)] == \
        [1, 2, 4, 4, 8, 8, 16]


def test_ragged_prompts_share_one_prefill_compile():
    eng = _engine(B=4)
    for rid, n in enumerate((5, 6, 7, 8)):      # all bucket to 8
        eng.add(Request(rid=rid,
                        prompt=np.arange(1, n + 1).astype(np.int32),
                        max_new=2))
    eng.run()
    assert eng.stats["prefill_compiles"] == 1
    assert eng.fault_report()["prefill_bucket_shapes"] == [8]
    # a shorter prompt opens a second (smaller) bucket
    eng.add(Request(rid=9, prompt=np.asarray([4, 5], np.int32), max_new=2))
    eng.run()
    assert eng.stats["prefill_compiles"] == 2
    assert eng.fault_report()["prefill_bucket_shapes"] == [2, 8]


def test_pad_unsafe_model_prefills_at_exact_lengths():
    """A model with recurrent state (``prefill_pad_safe`` False) folds
    pad tokens into its cache, so the engine must not pad its prompts --
    every distinct length is its own 'bucket'."""
    eng = _engine(B=4)
    eng.model.prefill_pad_safe = False
    eng._pad_safe = False
    for rid, n in enumerate((5, 6, 7, 5)):
        eng.add(Request(rid=rid,
                        prompt=np.arange(1, n + 1).astype(np.int32),
                        max_new=2))
    eng.run()
    assert eng.stats["prefill_compiles"] == 3   # lengths 5, 6, 7
    assert eng.fault_report()["prefill_bucket_shapes"] == [5, 6, 7]


def test_bucket_clamped_to_capacity():
    eng = _engine(B=1, capacity=8)
    eng.add(Request(rid=0, prompt=np.arange(1, 8).astype(np.int32),
                    max_new=1))                 # len 7 -> bucket 8 == cap
    eng.run()
    assert eng.fault_report()["prefill_bucket_shapes"] == [8]


def test_padded_prefill_reads_the_real_last_token():
    """The first generated token must come from logits at position
    ``plen - 1``, not the padded tail (a -1 index would read pad)."""
    eng = _engine(B=1)
    prompt = np.asarray([9, 2, 6], np.int32)    # len 3 -> bucket 4
    eng.add(Request(rid=0, prompt=prompt, max_new=3))
    done = eng.run()
    want = [_f(6)]                              # from the REAL last token
    for _ in range(2):
        want.append(_f(want[-1]))
    assert done[0].out == want


# ---------------------------------------------------------------------------
# Slot lifecycle
# ---------------------------------------------------------------------------
def test_max_new_one_yields_exactly_one_token():
    eng = _engine(B=2)
    eng.add(Request(rid=0, prompt=np.asarray([5], np.int32), max_new=1))
    done = eng.run()
    assert len(done) == 1 and done[0].done
    assert done[0].out == [_f(5)]               # prefill token only


def test_more_requests_than_slots_recycles_in_order():
    eng = _engine(B=2)
    for rid in range(5):
        eng.add(Request(rid=rid, prompt=np.asarray([rid + 1], np.int32),
                        max_new=2))
    done = eng.run()
    # slots free in pairs, the queue drains FIFO, finish order is stable
    assert [r.rid for r in done] == [0, 1, 2, 3, 4]
    assert all(len(r.out) == 2 for r in done)
    # every request decoded its OWN chain, not a neighbour slot's
    for r in done:
        assert r.out == [_f(r.rid + 1), _f(_f(r.rid + 1))]


def test_step_with_empty_queue_and_active_slots_decodes():
    eng = _engine(B=2)
    eng.add(Request(rid=0, prompt=np.asarray([3], np.int32), max_new=3))
    assert eng.step() == []                     # admitted + 1 decode
    assert not eng.queue                        # queue already empty
    done = eng.step()                           # keeps decoding
    assert [r.rid for r in done] == [0] and len(done[0].out) == 3


def test_step_on_idle_engine_is_a_noop():
    eng = _engine()
    assert eng.step() == []
    assert eng.stats["steps"] == 0


# ---------------------------------------------------------------------------
# Sampling keys
# ---------------------------------------------------------------------------
def _sampled(seed, temperature=1.0):
    eng = _engine(B=2, temperature=temperature, seed=seed)
    for rid in range(2):
        eng.add(Request(rid=rid, prompt=np.asarray([rid + 1], np.int32),
                        max_new=8))
    return tuple(tuple(r.out) for r in eng.run())


def test_temperature_sampling_is_seed_deterministic():
    assert _sampled(seed=1) == _sampled(seed=1)


def test_temperature_sampling_varies_across_seeds_and_steps():
    a, b = _sampled(seed=1), _sampled(seed=2)
    assert a != b                               # fold_in(base, step) keys
    # within one run the per-step keys differ too: a greedy chain would
    # be _f-deterministic, sampled chains at temp 1 must not all be
    greedy = _sampled(seed=1, temperature=0.0)
    assert a != greedy


def test_greedy_ignores_seed():
    assert _sampled(seed=1, temperature=0.0) == \
        _sampled(seed=2, temperature=0.0)


# ---------------------------------------------------------------------------
# Deadlines + degradation
# ---------------------------------------------------------------------------
def test_step_deadline_miss_counter():
    eng = _engine(B=1, step_deadline_ms=0.0)    # every step misses
    eng.add(Request(rid=0, prompt=np.asarray([1], np.int32), max_new=3))
    eng.run()
    assert eng.stats["steps"] > 0
    assert eng.stats["deadline_misses"] == eng.stats["steps"]


def test_fault_report_on_probeless_engine():
    eng = _engine(B=1)
    eng.add(Request(rid=0, prompt=np.asarray([1], np.int32), max_new=2))
    eng.run()
    rep = eng.fault_report()
    assert rep["steps"] > 0 and not rep["probe_fallback_active"]
    assert "probe_escaped_outputs" not in rep and "faults" not in rep


def _probe(fm, d=16, n=6):
    w = np.linspace(-1, 1, d * n).reshape(d, n).astype(np.float32)
    cfg = FabricConfig(n_blocks=4, rows=128, cols=16)
    return FabricLinearProbe(w, cfg=cfg, bits=8, max_steps=8, faults=fm)


def test_probe_retry_heals_and_serving_continues():
    fm = FaultModel(bit_rate=0.05, seed=0, scrub=False, heal_after=1)
    eng = _engine(B=1, fabric_probe=_probe(fm), probe_retries=2)
    eng.add(Request(rid=0, prompt=np.asarray([2], np.int32), max_new=2))
    done = eng.run()
    assert len(done) == 1 and len(done[0].out) == 2
    rep = eng.fault_report()
    assert rep["probe_retries"] == 1            # one faulted launch
    assert rep["probe_fallbacks"] == 0          # ...healed on retry
    assert not rep["probe_fallback_active"]
    assert rep["faults"]["escaped"] == 1


def test_probe_exhausted_retries_fall_back_permanently():
    fm = FaultModel(bit_rate=0.05, seed=0, scrub=False)   # never heals
    eng = _engine(B=1, fabric_probe=_probe(fm), probe_retries=1)
    eng.add(Request(rid=0, prompt=np.asarray([2], np.int32), max_new=3))
    done = eng.run()
    # degraded, not down: every token still produced
    assert len(done) == 1 and len(done[0].out) == 3
    rep = eng.fault_report()
    assert rep["probe_fallbacks"] == 1 and rep["probe_fallback_active"]
    assert rep["probe_retries"] == 1
    # after the fallback the fabric is never launched again
    events_at_fallback = fm.injection_events
    assert eng.stats["steps"] >= 2
    assert fm.injection_events == events_at_fallback