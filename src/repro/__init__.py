"""repro: Compute RAMs (Asilomar 2021) as a multi-pod JAX framework."""
