"""Continuous-batching serve engine: paged KV, scheduled admission,
chunked prefill, preemption, and per-request latency accounting.

The engine decodes a fixed batch of ``batch_slots`` lanes through ONE
jitted ``decode_step`` and keeps those lanes full from a queue
(continuous batching).  PR 10 rebuilt the loop around three real
serving subsystems:

* :class:`repro.serve.kv.PagedKV` -- a fixed-size-page KV pool with
  per-request page tables.  Admission is capacity-aware (a prompt that
  can never fit is **rejected** with accounting instead of crashing),
  decode appends allocate pages on demand, and a dry pool **preempts**
  the least-committed request (requeued with its tokens; it resumes by
  re-prefilling ``prompt + out`` -- bit-identical under greedy
  decoding).
* :class:`repro.serve.scheduler.Scheduler` -- admission order (FIFO or
  earliest-deadline-first), long-prompt policy (reject | truncate),
  chunked prefill, and victim selection.
* **chunked prefill** -- a prompt longer than ``prefill_chunk`` enters
  with one bounded prefill call and streams its tail through the shared
  decode step, one token per engine step, *interleaved* with the other
  lanes' decode -- a long prompt never stalls the batch.  The streamed
  cache writes are bit-identical to a whole prefill (same projections
  at the same positions), so the first generated token matches.

Scheduling invariants the tests pin:

* a slot freed by a finishing request is **re-admitted in the same
  step** (retire-then-backfill): with work queued, the active-lane
  count never dips between steps;
* every step that did any work (prefill, decode, or retirement) runs
  one accounting epilogue -- ``stats["steps"]``, the per-step deadline
  check, and the sampling-key counter advance together on every path;
* the fabric probe only ever observes **active** lanes' token
  embeddings -- finished slots' stale tokens are never fed to the grid.

An optional ``fabric_probe`` (:class:`repro.pim.fabric.FabricLinearProbe`)
routes linear projections of the live decode step through the simulated
Compute RAM block grid -- the paper's fabric executing a slice of real
serving traffic, with per-step energy/time accounting.  A probe built
with several weights (the Q/K/V/... projections of one layer) runs the
whole decode step's projections as ONE fused
:class:`repro.pim.fabric.FabricProgram`; with ``session=True`` the
probe's weights stay resident across steps even as slots recycle and
the active-lane count (the GEMM's M) changes step to step.

Graceful degradation (docs/faults.md): a probe whose fault model lets a
corruption escape raises
:class:`repro.core.faults.FabricFaultError`; the engine retries the
launch with exponential backoff up to ``probe_retries`` times, then
permanently falls back to the probe's host ``ref`` path
(``observe_ref``) -- serving keeps producing tokens either way.
``step_deadline_ms`` tracks per-step wall-clock deadline misses, and
``fault_report()`` aggregates the health counters."""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.faults import FabricFaultError

from .kv import PagedKV
from .scheduler import Scheduler, SchedulerConfig


# eq=False: identity semantics -- requests live in queues and slots, and
# field-wise dataclass equality would compare numpy prompts (ambiguous
# truth value) the moment list.remove() ran
@dataclasses.dataclass(eq=False)
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    # SLO: relative per-request deadline (drives deadline-aware
    # admission ordering and the latency report; not a kill switch)
    deadline_ms: Optional[float] = None
    # lifecycle: queued -> prefill (streaming a long prompt) -> decode
    #            -> done | rejected; preemption goes back to queued
    status: str = "queued"
    preemptions: int = 0
    truncated: bool = False
    # latency timestamps (time.perf_counter seconds; None = not reached)
    t_enqueue: Optional[float] = None
    t_admit: Optional[float] = None   # first admission
    t_first: Optional[float] = None   # first generated token
    t_done: Optional[float] = None
    # scheduler bookkeeping (internal)
    _arrival_seq: int = -1
    _admit_seq: int = -1
    _ptr: int = 0                     # next seq index to stream-feed
    _seq: Optional[np.ndarray] = None  # prompt + out at last admission

    # -- latency metrics ----------------------------------------------------
    def queue_ms(self) -> Optional[float]:
        if self.t_enqueue is None or self.t_admit is None:
            return None
        return (self.t_admit - self.t_enqueue) * 1e3

    def ttft_ms(self) -> Optional[float]:
        """Time to first token (enqueue -> first generated token)."""
        if self.t_enqueue is None or self.t_first is None:
            return None
        return (self.t_first - self.t_enqueue) * 1e3

    def ms_per_token(self) -> Optional[float]:
        """Steady-state decode latency: first token -> done, per token.
        A one-token request reports its TTFT-after-admission instead."""
        if self.t_done is None or not self.out:
            return None
        if len(self.out) > 1:
            return (self.t_done - self.t_first) * 1e3 / (len(self.out) - 1)
        return (self.t_done - self.t_admit) * 1e3


def _bucket(n: int) -> int:
    """Smallest power of two >= n (n >= 1)."""
    return 1 << max(0, int(n - 1).bit_length())


class ServeEngine:
    """Paged continuous-batching decode over fixed jit shapes.

    All slots share one jitted decode_step; finished slots are refilled
    from the scheduler's queue in the same step they free up.

    New serving knobs (defaults reproduce the pre-paging engine on
    in-capacity workloads):

    * ``page_size`` / ``num_pages`` -- the :class:`PagedKV` pool.  The
      default pool exactly covers ``batch_slots`` dense slots; a
      smaller pool creates admission pressure and preemption.
    * ``prefill_chunk`` -- enable chunked prefill (tokens per prefill
      call; the tail streams through the decode step).
    * ``admission`` -- ``"fifo"`` | ``"deadline"`` ordering.
    * ``long_prompt`` -- ``"reject"`` | ``"truncate"`` for prompts that
      can never fit (longer than ``min(capacity, pool) - max_new``).
    """

    def __init__(self, model, params, batch_slots: int = 4,
                 capacity: int = 256, temperature: float = 0.0,
                 fabric_probe=None, seed: int = 0,
                 step_deadline_ms: Optional[float] = None,
                 probe_retries: int = 2, probe_backoff_s: float = 0.0,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 admission: str = "fifo", long_prompt: str = "reject"):
        self.model = model
        self.params = params
        self.B = batch_slots
        self.capacity = capacity
        self.temperature = temperature
        self.fabric_probe = fabric_probe
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self.pos = np.zeros((batch_slots,), np.int32)
        self.tokens = np.zeros((batch_slots, 1), np.int32)
        self.caches = model.init_cache(batch_slots, capacity)
        self._decode = jax.jit(model.decode_step)
        self._prefill_one = jax.jit(
            lambda p, t: model.prefill(p, tokens=t, capacity=capacity))
        # paged KV pool: default exactly covers the dense per-slot
        # caches (batch_slots x capacity tokens), so in-capacity
        # workloads never feel it; shrink it to model real memory
        # pressure (admission waits, preemption).
        if num_pages is None:
            num_pages = batch_slots * max(1, -(-capacity // page_size))
        self.kv = PagedKV(num_pages, page_size)
        self.sched = Scheduler(
            SchedulerConfig(admission=admission,
                            prefill_chunk=prefill_chunk,
                            long_prompt=long_prompt),
            self.kv, capacity)
        self.rejected: List[Request] = []
        # sampling: one base key per engine; each step folds in a
        # monotonic counter, so no two steps can share a key (the old
        # PRNGKey(pos.sum()) repeated whenever the pos-sum repeated --
        # correlated samples across steps)
        self.seed = seed
        self._base_key = jax.random.PRNGKey(seed)
        self._step_count = 0       # worked steps (sampling-key counter)
        self._decode_count = 0     # decode launches (cold/warm split)
        self._admit_count = 0
        # prompt-length bucketing: _prefill_one compiles once per padded
        # shape, so tracking the distinct buckets counts its compiles.
        # Models with recurrent state (ssm/rec layers) fold pad tokens
        # into their cache, so they prefill at exact lengths instead.
        self._pad_safe = bool(getattr(model, "prefill_pad_safe", True))
        self._prefill_buckets: set = set()
        # graceful degradation knobs + health counters
        self.step_deadline_ms = step_deadline_ms
        self.probe_retries = probe_retries
        self.probe_backoff_s = probe_backoff_s
        self.probe_fallback = False
        self.stats = {"steps": 0, "deadline_misses": 0,
                      "probe_retries": 0, "probe_fallbacks": 0,
                      "prefill_compiles": 0,
                      # scheduler accounting
                      "admitted": 0, "rejected": 0, "truncated": 0,
                      "preemptions": 0, "resumes": 0,
                      "stream_prefill_tokens": 0,
                      # phase timing split (serve_bench artifact): total
                      # prefill wall-clock + prompt tokens pushed through
                      # it, and decode wall-clock split cold (first decode
                      # step: compiles + fabric-session warm-up) vs warm
                      # (steady state)
                      "prefill_s": 0.0, "prefill_tokens": 0,
                      "decode_s": 0.0, "decode_tokens": 0,
                      "decode_cold_s": 0.0, "decode_warm_s": 0.0,
                      "decode_warm_steps": 0}

    # -- queue --------------------------------------------------------------
    @property
    def queue(self) -> List[Request]:
        return self.sched.queue

    def add(self, req: Request):
        if req.t_enqueue is None:
            req.t_enqueue = time.perf_counter()
        self.sched.add(req)

    # -- admission ----------------------------------------------------------
    def _next_admissible(self) -> Optional[Request]:
        """Pop the next admissible request per policy; handles
        reject/truncate verdicts inline.  None = nothing can start now
        (empty queue or the policy head is waiting for pages)."""
        while True:
            req = self.sched.peek()
            if req is None:
                return None
            v = self.sched.verdict(req)
            if v == "too_long":
                limit = self.sched.max_admissible_tokens(req.max_new)
                if self.sched.cfg.long_prompt == "truncate" and limit >= 1:
                    # clip in place and re-run the verdict: the
                    # truncated prompt may still have to WAIT for pages
                    req.prompt = np.asarray(req.prompt[:limit], np.int32)
                    if not req.truncated:
                        req.truncated = True
                        self.stats["truncated"] += 1
                    continue
                self.sched.pop(req)
                req.status = "rejected"
                req.t_done = time.perf_counter()
                self.stats["rejected"] += 1
                self.rejected.append(req)
                continue
            if v == "wait":
                # head-of-line: admission stalls until pages free up
                # (skipping past the policy head would starve it)
                return None
            self.sched.pop(req)
            return req

    def _admit(self) -> int:
        """Fill free slots from the queue; returns admissions made."""
        admitted = 0
        for i in range(self.B):
            if self.slots[i] is not None:
                continue
            req = self._next_admissible()
            if req is None:
                break
            self._prefill_into(i, req)
            admitted += 1
        return admitted

    def _prefill_into(self, i: int, req: Request):
        """Admit ``req`` into slot ``i``: bounded prefill call, paged KV
        allocation, and (for long prompts) arming the streamed tail."""
        tp0 = time.perf_counter()
        resume = bool(req.out)
        # a resumed request re-prefills prompt + generated tokens: the
        # recompute preemption policy (greedy chains continue bit-
        # identically; see docs/serve.md)
        seq = (np.concatenate([np.asarray(req.prompt, np.int32),
                               np.asarray(req.out, np.int32)])
               if resume else np.asarray(req.prompt, np.int32))
        seq_len = len(seq)
        chunk = self.sched.first_chunk(seq_len)
        if not self.kv.alloc(req.rid, chunk):
            raise RuntimeError("admission verdict said pages were free")
        # pad the prefill to a power-of-two bucket: ragged arrival
        # traffic hits a handful of compiled prefill shapes instead of
        # one per distinct length.  Pad tokens sit at positions >= the
        # real length, which decode either masks (cache position >
        # current pos) or overwrites before ever attending --
        # bit-identical logits at the real last token.
        bucket = (min(_bucket(chunk), self.capacity)
                  if self._pad_safe else chunk)
        padded = np.zeros((bucket,), np.int32)
        padded[:chunk] = seq[:chunk]
        if bucket not in self._prefill_buckets:
            self._prefill_buckets.add(bucket)
            self.stats["prefill_compiles"] += 1
        logits, cache = self._prefill_one(
            self.params, jnp.asarray(padded)[None, :])

        # merge this request's cache into slot i: the batch dim is
        # dim 1 for scanned-stack ("unit") caches, dim 0 for
        # unstacked ("rest") layer caches.
        def merge(path, full, one):
            keys = [getattr(q, "key", str(q)) for q in path
                    if hasattr(q, "key")]
            bdim = 1 if "unit" in keys else 0
            idx = (slice(None),) * bdim + (i,)
            src = one[(slice(None),) * bdim + (0,)]
            return full.at[idx].set(src)

        self.caches = jax.tree_util.tree_map_with_path(
            merge, self.caches, cache)

        now = time.perf_counter()
        if req.t_admit is None:
            req.t_admit = now
        req._admit_seq = self._admit_count
        self._admit_count += 1
        req._seq = seq
        self.slots[i] = req
        self.pos[i] = chunk
        self.stats["admitted"] += 1
        if resume:
            self.stats["resumes"] += 1
        if chunk < seq_len:
            # long prompt: the tail streams through the shared decode
            # step, one token per engine step, interleaved with the
            # other lanes' decode
            req.status = "prefill"
            req._ptr = chunk
            self.tokens[i, 0] = seq[chunk]
        else:
            req.status = "decode"
            nxt = int(jnp.argmax(logits[0, chunk - 1]))
            req.out.append(nxt)
            if req.t_first is None:
                req.t_first = now
            self.tokens[i, 0] = nxt
        self.stats["prefill_s"] += time.perf_counter() - tp0
        self.stats["prefill_tokens"] += chunk

    # -- retirement / preemption --------------------------------------------
    def _finish(self, i: int, req: Request):
        req.done = True
        req.status = "done"
        req.t_done = time.perf_counter()
        self.slots[i] = None
        if self.kv.held(req.rid):
            self.kv.free(req.rid)

    def _retire_satisfied(self) -> List[Request]:
        """Finish slots whose budget the prefill token already covered
        (max_new=1 admits) -- decoding them would overshoot."""
        finished = []
        for i, req in enumerate(self.slots):
            if req is not None and len(req.out) >= req.max_new:
                self._finish(i, req)
                finished.append(req)
        return finished

    def _preempt(self, i: int, req: Request):
        """Evict ``req`` from slot ``i`` back to the queue, pages freed,
        generated tokens kept (resume re-prefills prompt + out)."""
        self.kv.free(req.rid)
        self.slots[i] = None
        req.status = "queued"
        req.preemptions += 1
        req._ptr = 0
        req._seq = None
        self.stats["preemptions"] += 1
        self.sched.add(req)

    def _append_kv(self, active: List[int]):
        """Charge one KV token per active lane for this decode step,
        preempting victims while the pool is dry."""
        for i in active:
            req = self.slots[i]
            if req is None:          # already preempted as a victim
                continue
            while not self.kv.append(req.rid):
                others = [r for r in self.slots
                          if r is not None and r is not req]
                victim = self.sched.pick_victim(others)
                if victim is None:
                    raise RuntimeError(
                        "KV pool dry with a single active request -- "
                        "admission should have rejected it")
                vslot = next(j for j, r in enumerate(self.slots)
                             if r is victim)
                self._preempt(vslot, victim)

    # -- probe --------------------------------------------------------------
    def _observe_guarded(self, x):
        """Probe observe with bounded retry-with-backoff, then fallback.

        A :class:`FabricFaultError` (escaped corruption, or a dead grid
        that can no longer be repaired) is retried up to
        ``probe_retries`` times with exponential backoff; if the fabric
        still faults, the engine falls back permanently to the probe's
        host ``ref`` path -- degraded accounting, correct tokens.
        """
        delay = self.probe_backoff_s
        for attempt in range(self.probe_retries + 1):
            try:
                return self.fabric_probe.observe(x)
            except FabricFaultError:
                if attempt < self.probe_retries:
                    self.stats["probe_retries"] += 1
                    if delay > 0:
                        time.sleep(delay)
                        delay *= 2
        self.probe_fallback = True
        self.stats["probe_fallbacks"] += 1
        return self.fabric_probe.observe_ref(x)

    # -- the step -----------------------------------------------------------
    def step(self) -> List[Request]:
        """One scheduling step: retire, admit, decode every active lane,
        retire again, and backfill freed slots -- so with work queued
        the batch never runs a lane short.  Returns finished requests."""
        t0 = time.perf_counter()
        finished = self._retire_satisfied()
        admitted = self._admit()
        # a fresh admit whose prefill token covered its whole budget
        # (max_new=1) finishes before it ever decodes
        finished += self._retire_satisfied()

        active = [i for i, r in enumerate(self.slots) if r is not None]
        decode_ran = False
        if active:
            td0 = time.perf_counter()
            # paged-KV accounting for the token each lane writes this
            # step; a dry pool preempts the least-committed lane(s)
            self._append_kv(active)
            active = [i for i, r in enumerate(self.slots) if r is not None]
            streaming = [i for i in active
                         if self.slots[i].status == "prefill"]
            if self.fabric_probe is not None and not self.fabric_probe.done \
                    and not self.probe_fallback:
                # this step's real activations -- the token embeddings
                # of the ACTIVE lanes only (a finished slot's stale
                # token never reaches the grid; the fused program's M
                # tracks the live batch)
                x = self.model._embed(
                    self.params, jnp.asarray(self.tokens[active]))
                self._observe_guarded(np.asarray(x, np.float32)[:, 0, :])
            logits, self.caches = self._decode(
                self.params, self.caches, jnp.asarray(self.tokens),
                jnp.asarray(self.pos))
            if self.temperature > 0:
                key = jax.random.fold_in(self._base_key, self._step_count)
                nxt = jax.random.categorical(
                    key, logits[:, 0] / self.temperature, axis=-1)
            else:
                nxt = jnp.argmax(logits[:, 0], axis=-1)
            nxt = np.asarray(nxt, np.int32)

            now = time.perf_counter()
            produced = 0
            for i in active:
                req = self.slots[i]
                self.pos[i] += 1
                if req.status == "prefill":
                    # streamed a prompt token into the cache this step
                    req._ptr += 1
                    self.stats["stream_prefill_tokens"] += 1
                    if req._ptr < len(req._seq):
                        self.tokens[i, 0] = req._seq[req._ptr]
                        continue
                    # last prompt token consumed: this step's logits
                    # ARE the first-token logits
                    req.status = "decode"
                req.out.append(int(nxt[i]))
                if req.t_first is None:
                    req.t_first = now
                produced += 1
                self.tokens[i, 0] = nxt[i]
                if len(req.out) >= req.max_new:
                    self._finish(i, req)
                    finished.append(req)
            # decode phase split: the FIRST decode launch pays the
            # one-time costs (decode_step jit compile, fabric-session
            # weight warm-up); later launches are the steady state
            dt = time.perf_counter() - td0
            self.stats["decode_s"] += dt
            self.stats["decode_tokens"] += produced
            if self._decode_count == 0:
                self.stats["decode_cold_s"] += dt
            else:
                self.stats["decode_warm_s"] += dt
                self.stats["decode_warm_steps"] += 1
            self._decode_count += 1
            decode_ran = True

        # retire-then-backfill: a slot freed THIS step serves the queue
        # THIS step (its prefill runs now; it decodes next step)
        admitted += self._admit()

        # unified accounting epilogue: every path that did work -- a
        # prefill-only turn, a retire-only turn, or a full decode --
        # counts the step and checks the deadline (the old early return
        # skipped all of it)
        if decode_ran or admitted or finished:
            self._step_count += 1
            self.stats["steps"] += 1
            if self.step_deadline_ms is not None:
                if (time.perf_counter() - t0) * 1e3 > self.step_deadline_ms:
                    self.stats["deadline_misses"] += 1
        return finished

    def run(self) -> List[Request]:
        done = []
        while self.queue or any(s is not None for s in self.slots):
            done.extend(self.step())
        return done

    # -- reports ------------------------------------------------------------
    def fabric_report(self):
        """Combined cost report of the fabric probe (None if unused).

        Includes the probe's ``config_summary()`` -- the block geometry
        and storage/compute split actually served from, and whether the
        schedule autotuner picked it."""
        if self.fabric_probe is None:
            return None
        return self.fabric_probe.report()

    def kv_report(self) -> dict:
        """The paged pool's allocation accounting (docs/serve.md)."""
        return self.kv.report()

    def fault_report(self) -> dict:
        """Serving health: fault + degradation accounting (docs/faults.md).

        Always available (zeros on a fault-free engine): step and
        deadline counters, probe retries/fallbacks, the probe's
        escaped-output count, and -- when the probe carries a
        :class:`repro.core.faults.FaultModel` -- its full
        injected/detected/repaired/escaped tally."""
        rep = dict(self.stats)
        rep["prefill_bucket_shapes"] = sorted(self._prefill_buckets)
        rep["probe_fallback_active"] = self.probe_fallback
        if self.fabric_probe is not None:
            rep["probe_escaped_outputs"] = getattr(
                self.fabric_probe, "escaped_outputs", 0)
            fm = getattr(self.fabric_probe, "faults", None)
            if fm is not None:
                rep["faults"] = fm.stats()
        return rep
