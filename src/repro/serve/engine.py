"""Batched serving engine: prefill + decode with slot-based continuous
batching (vLLM-style lite) and greedy/temperature sampling.

An optional ``fabric_probe`` (:class:`repro.pim.fabric.FabricLinearProbe`)
routes linear projections of the live decode step through the simulated
Compute RAM block grid -- the paper's fabric executing a slice of real
serving traffic, with per-step energy/time accounting.  A probe built
with several weights (the Q/K/V/... projections of one layer) runs the
whole decode step's projections as ONE fused
:class:`repro.pim.fabric.FabricProgram`: one grid allocation, shared
activation residency, one batched launch.  A probe constructed with
``autotune=True`` picks its grid split and placement via the fabric
program search on the first observed shape, so serving selects the best
geometry automatically; ``fabric_report()`` names the grid served
from.

Graceful degradation (docs/faults.md): a probe whose fault model lets a
corruption escape raises
:class:`repro.core.faults.FabricFaultError`; the engine retries the
launch with exponential backoff up to ``probe_retries`` times, then
permanently falls back to the probe's host ``ref`` path
(``observe_ref``) -- serving keeps producing tokens either way.
``step_deadline_ms`` tracks per-step wall-clock deadline misses, and
``fault_report()`` aggregates the health counters (retries, fallbacks,
deadline misses, the fault model's injected/detected/repaired/escaped
tallies)."""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.faults import FabricFaultError


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


def _bucket(n: int) -> int:
    """Smallest power of two >= n (n >= 1)."""
    return 1 << max(0, int(n - 1).bit_length())


class ServeEngine:
    """Fixed-slot batch decode.  All slots share one jitted decode_step;
    finished slots are refilled from the queue (continuous batching)."""

    def __init__(self, model, params, batch_slots: int = 4,
                 capacity: int = 256, temperature: float = 0.0,
                 fabric_probe=None, seed: int = 0,
                 step_deadline_ms: Optional[float] = None,
                 probe_retries: int = 2, probe_backoff_s: float = 0.0):
        self.model = model
        self.params = params
        self.B = batch_slots
        self.capacity = capacity
        self.temperature = temperature
        self.fabric_probe = fabric_probe
        self.queue: List[Request] = []
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self.pos = np.zeros((batch_slots,), np.int32)
        self.tokens = np.zeros((batch_slots, 1), np.int32)
        self.caches = model.init_cache(batch_slots, capacity)
        self._decode = jax.jit(model.decode_step)
        self._prefill_one = jax.jit(
            lambda p, t: model.prefill(p, tokens=t, capacity=capacity))
        # sampling: one base key per engine; each step folds in a
        # monotonic counter, so no two steps can share a key (the old
        # PRNGKey(pos.sum()) repeated whenever the pos-sum repeated --
        # correlated samples across steps)
        self.seed = seed
        self._base_key = jax.random.PRNGKey(seed)
        self._step_count = 0
        # prompt-length bucketing: _prefill_one compiles once per padded
        # shape, so tracking the distinct buckets counts its compiles.
        # Models with recurrent state (ssm/rec layers) fold pad tokens
        # into their cache, so they prefill at exact lengths instead.
        self._pad_safe = bool(getattr(model, "prefill_pad_safe", True))
        self._prefill_buckets: set = set()
        # graceful degradation knobs + health counters
        self.step_deadline_ms = step_deadline_ms
        self.probe_retries = probe_retries
        self.probe_backoff_s = probe_backoff_s
        self.probe_fallback = False
        self.stats = {"steps": 0, "deadline_misses": 0,
                      "probe_retries": 0, "probe_fallbacks": 0,
                      "prefill_compiles": 0,
                      # phase timing split (serve_bench artifact): total
                      # prefill wall-clock + prompt tokens pushed through
                      # it, and decode wall-clock split cold (first decode
                      # step: compiles + fabric-session warm-up) vs warm
                      # (steady state)
                      "prefill_s": 0.0, "prefill_tokens": 0,
                      "decode_s": 0.0, "decode_tokens": 0,
                      "decode_cold_s": 0.0, "decode_warm_s": 0.0,
                      "decode_warm_steps": 0}

    def add(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i in range(self.B):
            if self.slots[i] is None and self.queue:
                tp0 = time.perf_counter()
                req = self.queue.pop(0)
                # pad the prompt to a power-of-two bucket: ragged arrival
                # traffic hits a handful of compiled prefill shapes
                # instead of one per distinct length.  Pad tokens sit at
                # positions >= the real length, which decode either
                # masks (cache position > current pos) or overwrites
                # before ever attending -- bit-identical logits at the
                # real last token.
                plen = len(req.prompt)
                bucket = (min(_bucket(plen), self.capacity)
                          if self._pad_safe else plen)
                padded = np.zeros((bucket,), np.int32)
                padded[:plen] = req.prompt
                if bucket not in self._prefill_buckets:
                    self._prefill_buckets.add(bucket)
                    self.stats["prefill_compiles"] += 1
                logits, cache = self._prefill_one(
                    self.params, jnp.asarray(padded)[None, :])

                # merge this request's cache into slot i: the batch dim is
                # dim 1 for scanned-stack ("unit") caches, dim 0 for
                # unstacked ("rest") layer caches.
                def merge(path, full, one):
                    keys = [getattr(q, "key", str(q)) for q in path
                            if hasattr(q, "key")]
                    bdim = 1 if "unit" in keys else 0
                    idx = (slice(None),) * bdim + (i,)
                    src = one[(slice(None),) * bdim + (0,)]
                    return full.at[idx].set(src)

                self.caches = jax.tree_util.tree_map_with_path(
                    merge, self.caches, cache)
                nxt = int(jnp.argmax(logits[0, plen - 1]))
                req.out.append(nxt)
                self.slots[i] = req
                self.pos[i] = plen
                self.tokens[i, 0] = nxt
                self.stats["prefill_s"] += time.perf_counter() - tp0
                self.stats["prefill_tokens"] += plen

    def _observe_guarded(self, x):
        """Probe observe with bounded retry-with-backoff, then fallback.

        A :class:`FabricFaultError` (escaped corruption, or a dead grid
        that can no longer be repaired) is retried up to
        ``probe_retries`` times with exponential backoff; if the fabric
        still faults, the engine falls back permanently to the probe's
        host ``ref`` path -- degraded accounting, correct tokens.
        """
        delay = self.probe_backoff_s
        for attempt in range(self.probe_retries + 1):
            try:
                return self.fabric_probe.observe(x)
            except FabricFaultError:
                if attempt < self.probe_retries:
                    self.stats["probe_retries"] += 1
                    if delay > 0:
                        time.sleep(delay)
                        delay *= 2
        self.probe_fallback = True
        self.stats["probe_fallbacks"] += 1
        return self.fabric_probe.observe_ref(x)

    def step(self) -> List[Request]:
        """One decode step for all active slots; returns finished reqs."""
        t0 = time.perf_counter()
        self._admit()
        # a request whose budget the prefill token already satisfied
        # (max_new=1) finishes here -- decoding would overshoot it
        finished = []
        for i, req in enumerate(self.slots):
            if req is not None and len(req.out) >= req.max_new:
                req.done = True
                finished.append(req)
                self.slots[i] = None
        if all(s is None for s in self.slots):
            return finished
        td0 = time.perf_counter()
        active = sum(1 for s in self.slots if s is not None)
        if self.fabric_probe is not None and not self.fabric_probe.done \
                and not self.probe_fallback:
            # this step's real activations (token embeddings of the
            # batch) through the simulated Compute RAM fabric
            x = self.model._embed(self.params, jnp.asarray(self.tokens))
            self._observe_guarded(np.asarray(x, np.float32)[:, 0, :])
        logits, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(self.tokens),
            jnp.asarray(self.pos))
        if self.temperature > 0:
            key = jax.random.fold_in(self._base_key, self._step_count)
            nxt = jax.random.categorical(
                key, logits[:, 0] / self.temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits[:, 0], axis=-1)
        nxt = np.asarray(nxt, np.int32)

        for i, req in enumerate(self.slots):
            if req is None:
                continue
            req.out.append(int(nxt[i]))
            self.pos[i] += 1
            self.tokens[i, 0] = nxt[i]
            if len(req.out) >= req.max_new:
                req.done = True
                finished.append(req)
                self.slots[i] = None
        # decode phase split: the FIRST decode step pays the one-time
        # costs (decode_step jit compile, fabric-session weight warm-up);
        # later steps are the steady state the session keeps warm
        dt = time.perf_counter() - td0
        self.stats["decode_s"] += dt
        self.stats["decode_tokens"] += active
        if self._step_count == 0:
            self.stats["decode_cold_s"] += dt
        else:
            self.stats["decode_warm_s"] += dt
            self.stats["decode_warm_steps"] += 1
        self._step_count += 1
        self.stats["steps"] += 1
        if self.step_deadline_ms is not None:
            if (time.perf_counter() - t0) * 1e3 > self.step_deadline_ms:
                self.stats["deadline_misses"] += 1
        return finished

    def run(self) -> List[Request]:
        done = []
        while self.queue or any(s is not None for s in self.slots):
            done.extend(self.step())
        return done

    def fabric_report(self):
        """Combined cost report of the fabric probe (None if unused).

        Includes the probe's ``config_summary()`` -- the block geometry
        and storage/compute split actually served from, and whether the
        schedule autotuner picked it."""
        if self.fabric_probe is None:
            return None
        return self.fabric_probe.report()

    def fault_report(self) -> dict:
        """Serving health: fault + degradation accounting (docs/faults.md).

        Always available (zeros on a fault-free engine): step and
        deadline counters, probe retries/fallbacks, the probe's
        escaped-output count, and -- when the probe carries a
        :class:`repro.core.faults.FaultModel` -- its full
        injected/detected/repaired/escaped tally."""
        rep = dict(self.stats)
        rep["prefill_bucket_shapes"] = sorted(self._prefill_buckets)
        rep["probe_fallback_active"] = self.probe_fallback
        if self.fabric_probe is not None:
            rep["probe_escaped_outputs"] = getattr(
                self.fabric_probe, "escaped_outputs", 0)
            fm = getattr(self.fabric_probe, "faults", None)
            if fm is not None:
                rep["faults"] = fm.stats()
        return rep
