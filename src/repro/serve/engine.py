"""Batched serving engine: prefill + decode with slot-based continuous
batching (vLLM-style lite) and greedy/temperature sampling.

An optional ``fabric_probe`` (:class:`repro.pim.fabric.FabricLinearProbe`)
routes linear projections of the live decode step through the simulated
Compute RAM block grid -- the paper's fabric executing a slice of real
serving traffic, with per-step energy/time accounting.  A probe built
with several weights (the Q/K/V/... projections of one layer) runs the
whole decode step's projections as ONE fused
:class:`repro.pim.fabric.FabricProgram`: one grid allocation, shared
activation residency, one batched launch.  A probe constructed with
``autotune=True`` picks its grid split and placement via the fabric
program search on the first observed shape, so serving selects the best
geometry automatically; ``fabric_report()`` names the grid served
from."""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Fixed-slot batch decode.  All slots share one jitted decode_step;
    finished slots are refilled from the queue (continuous batching)."""

    def __init__(self, model, params, batch_slots: int = 4,
                 capacity: int = 256, temperature: float = 0.0,
                 fabric_probe=None):
        self.model = model
        self.params = params
        self.B = batch_slots
        self.capacity = capacity
        self.temperature = temperature
        self.fabric_probe = fabric_probe
        self.queue: List[Request] = []
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self.pos = np.zeros((batch_slots,), np.int32)
        self.tokens = np.zeros((batch_slots, 1), np.int32)
        self.caches = model.init_cache(batch_slots, capacity)
        self._decode = jax.jit(model.decode_step)
        self._prefill_one = jax.jit(
            lambda p, t: model.prefill(p, tokens=t, capacity=capacity))

    def add(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i in range(self.B):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                logits, cache = self._prefill_one(
                    self.params, jnp.asarray(req.prompt)[None, :])

                # merge this request's cache into slot i: the batch dim is
                # dim 1 for scanned-stack ("unit") caches, dim 0 for
                # unstacked ("rest") layer caches.
                def merge(path, full, one):
                    keys = [getattr(q, "key", str(q)) for q in path
                            if hasattr(q, "key")]
                    bdim = 1 if "unit" in keys else 0
                    idx = (slice(None),) * bdim + (i,)
                    src = one[(slice(None),) * bdim + (0,)]
                    return full.at[idx].set(src)

                self.caches = jax.tree_util.tree_map_with_path(
                    merge, self.caches, cache)
                nxt = int(jnp.argmax(logits[0, -1]))
                req.out.append(nxt)
                self.slots[i] = req
                self.pos[i] = len(req.prompt)
                self.tokens[i, 0] = nxt

    def step(self) -> List[Request]:
        """One decode step for all active slots; returns finished reqs."""
        self._admit()
        if all(s is None for s in self.slots):
            return []
        if self.fabric_probe is not None and not self.fabric_probe.done:
            # this step's real activations (token embeddings of the
            # batch) through the simulated Compute RAM fabric
            x = self.model._embed(self.params, jnp.asarray(self.tokens))
            self.fabric_probe.observe(np.asarray(x, np.float32)[:, 0, :])
        logits, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(self.tokens),
            jnp.asarray(self.pos))
        if self.temperature > 0:
            key = jax.random.PRNGKey(int(self.pos.sum()))
            nxt = jax.random.categorical(
                key, logits[:, 0] / self.temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits[:, 0], axis=-1)
        nxt = np.asarray(nxt, np.int32)

        finished = []
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            req.out.append(int(nxt[i]))
            self.pos[i] += 1
            self.tokens[i, 0] = nxt[i]
            if len(req.out) >= req.max_new:
                req.done = True
                finished.append(req)
                self.slots[i] = None
        return finished

    def run(self) -> List[Request]:
        done = []
        while self.queue or any(s is not None for s in self.slots):
            done.extend(self.step())
        return done

    def fabric_report(self):
        """Combined cost report of the fabric probe (None if unused).

        Includes the probe's ``config_summary()`` -- the block geometry
        and storage/compute split actually served from, and whether the
        schedule autotuner picked it."""
        if self.fabric_probe is None:
            return None
        return self.fabric_probe.report()
