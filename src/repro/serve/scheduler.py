"""Admission, ordering, and preemption policy for the serve engine.

The scheduler owns the *which-request-when* decisions and nothing
else -- it never touches model state, so policies are unit-testable
without a model:

* **admission order** -- ``fifo`` (arrival order) or ``deadline``
  (earliest-deadline-first among requests that carry a ``deadline_ms``
  SLO, FIFO among the rest; a deadline always outranks no deadline);
* **admission verdicts** -- a prompt that can *never* fit (longer than
  the per-slot capacity budget or the whole page pool) is rejected or
  truncated up front instead of crashing mid-prefill; a prompt that
  merely has to wait for pages stays queued;
* **chunked prefill** -- prompts longer than ``prefill_chunk`` enter in
  a bounded prefill call and stream their tail through the shared
  decode step, one token per engine step, so a long prompt never stalls
  the decode batch behind a monolithic prefill;
* **preemption victims** -- when a decode step needs a KV page and the
  pool is dry, the victim is the *least-committed* active request: the
  last one admitted under FIFO, the latest-deadline one under
  ``deadline`` (no deadline counts as infinitely late).  Victims are
  requeued with their generated tokens intact and resume by
  re-prefilling ``prompt + out`` (recompute-style preemption -- greedy
  decoding makes the resumed chain bit-identical).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

from .kv import PagedKV

_INF = float("inf")


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    admission: str = "fifo"              # fifo | deadline
    prefill_chunk: Optional[int] = None  # max tokens per prefill call
    long_prompt: str = "reject"          # reject | truncate

    def __post_init__(self):
        if self.admission not in ("fifo", "deadline"):
            raise ValueError(f"admission={self.admission!r}")
        if self.long_prompt not in ("reject", "truncate"):
            raise ValueError(f"long_prompt={self.long_prompt!r}")
        if self.prefill_chunk is not None and self.prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")


class Scheduler:
    """Queue + policy.  Requests are the engine's ``Request`` objects;
    the scheduler reads their ``deadline_ms`` / sequencing fields and
    writes nothing but queue membership."""

    def __init__(self, cfg: SchedulerConfig, kv: PagedKV, capacity: int):
        self.cfg = cfg
        self.kv = kv
        self.capacity = int(capacity)
        self.queue: List = []
        self._arrivals = 0

    # -- queue --------------------------------------------------------------
    def add(self, req) -> None:
        if req._arrival_seq < 0:          # first arrival; resumes keep it
            req._arrival_seq = self._arrivals
            self._arrivals += 1
        self.queue.append(req)

    def _order_key(self, req):
        if self.cfg.admission == "deadline":
            d = req.deadline_ms if req.deadline_ms is not None else _INF
            return (d, req._arrival_seq)
        return (req._arrival_seq,)

    def peek(self):
        """The next request admission would consider (policy order)."""
        if not self.queue:
            return None
        return min(self.queue, key=self._order_key)

    def pop(self, req) -> None:
        self.queue.remove(req)

    # -- admission verdicts -------------------------------------------------
    def max_admissible_tokens(self, max_new: int) -> int:
        """Longest prompt admissible with a ``max_new`` decode budget:
        the whole sequence must fit BOTH the per-slot capacity and the
        page pool (strict -- no silent ring-buffer wraparound)."""
        return min(self.capacity, self.kv.capacity_tokens) - int(max_new)

    def verdict(self, req) -> str:
        """``admit`` | ``wait`` | ``too_long`` for the request's
        *current* sequence (prompt plus any tokens generated before a
        preemption)."""
        seq_len = len(req.prompt) + len(req.out)
        if len(req.prompt) > self.max_admissible_tokens(req.max_new):
            return "too_long"
        first = self.first_chunk(seq_len)
        return "admit" if self.kv.can_admit(first) else "wait"

    def first_chunk(self, seq_len: int) -> int:
        """Tokens covered by the initial prefill call; the rest streams
        through the decode step."""
        if self.cfg.prefill_chunk is None:
            return seq_len
        return min(seq_len, self.cfg.prefill_chunk)

    # -- preemption ---------------------------------------------------------
    def pick_victim(self, active: List, protect=None):
        """Least-committed active request to evict (None if no
        candidate).  ``protect`` is never chosen -- the request whose
        append triggered the preemption must make progress."""
        cands = [r for r in active if r is not protect]
        if not cands:
            return None
        if self.cfg.admission == "deadline":
            return max(cands, key=lambda r: (
                r.deadline_ms if r.deadline_ms is not None else _INF,
                r._admit_seq))
        return max(cands, key=lambda r: r._admit_seq)
