"""Serving engine: paged KV, scheduled continuous batching, load gen.

Public surface:

* :class:`repro.serve.engine.ServeEngine` / ``Request`` -- the engine
* :class:`repro.serve.kv.PagedKV` -- paged KV-cache accounting
* :class:`repro.serve.scheduler.Scheduler` / ``SchedulerConfig``
* :mod:`repro.serve.loadgen` -- seeded arrivals + latency rollups
"""

from .engine import Request, ServeEngine          # noqa: F401
from .kv import PagedKV                           # noqa: F401
from .scheduler import Scheduler, SchedulerConfig  # noqa: F401
