"""Paged KV-cache accounting: fixed-size pages, per-request page tables.

A production serving engine never gives a request a dense
``(capacity,)`` KV slab up front -- it would strand memory on short
requests and crash on long ones (the old ``ServeEngine`` did exactly
that: ``padded[:plen]`` raised once ``plen`` outgrew ``capacity``).
Instead the physical KV store is a pool of fixed-size **pages**; each
request owns a **page table** that grows one page at a time as its
sequence extends, admission is gated on free pages, and retirement
returns every page to the pool (vLLM's PagedAttention memory model).

In this repo the *numerics* still live in the model's per-slot ring
caches (and, for the fabric leg, in the on-fabric KV reservations of
:class:`repro.pim.fabric.FabricSession`); :class:`PagedKV` is the
shared **capacity model** layered on top.  It is accounting, not
arithmetic -- but the policies it drives are real: a prompt that can
never fit is rejected instead of crashing, a decode step that needs a
page from an empty pool preempts a victim, and a leak (a retired
request whose pages were never freed) is a hard error that
:meth:`assert_empty` turns into a test failure.
"""

from __future__ import annotations

from typing import Dict, List


class PagedKV:
    """Fixed-size-page KV pool with per-request page tables.

    ``num_pages`` pages of ``page_size`` token slots each.  Pages are
    handed out LIFO (a freed page is reused first -- locality), and a
    request's table only ever grows until :meth:`free` returns it
    wholesale.
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages <= 0 or page_size <= 0:
            raise ValueError(
                f"need positive pool: num_pages={num_pages} "
                f"page_size={page_size}")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self.tables: Dict[int, List[int]] = {}   # rid -> [page_id, ...]
        self.lens: Dict[int, int] = {}           # rid -> tokens held
        self.stats = {"allocs": 0, "frees": 0, "pages_alloc": 0,
                      "pages_freed": 0, "failed_appends": 0,
                      "high_water_pages": 0}

    # -- capacity queries ---------------------------------------------------
    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` (at least one)."""
        return max(1, -(-int(n_tokens) // self.page_size))

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.num_pages - len(self._free)

    @property
    def capacity_tokens(self) -> int:
        """Total token slots the pool can ever hold."""
        return self.num_pages * self.page_size

    def can_admit(self, n_tokens: int) -> bool:
        """Enough *free* pages to hold ``n_tokens`` right now?"""
        return self.pages_for(n_tokens) <= self.free_pages

    def can_ever_fit(self, n_tokens: int) -> bool:
        """Could ``n_tokens`` fit in an *empty* pool?  (admission's
        reject-vs-wait distinction: False means reject forever)."""
        return self.pages_for(n_tokens) <= self.num_pages

    # -- lifecycle ----------------------------------------------------------
    def alloc(self, rid: int, n_tokens: int) -> bool:
        """Admit ``rid`` holding ``n_tokens``; False if pages run short
        (no partial allocation is left behind)."""
        if rid in self.tables:
            raise KeyError(f"rid {rid} already holds pages")
        need = self.pages_for(n_tokens)
        if need > self.free_pages:
            return False
        self.tables[rid] = [self._free.pop() for _ in range(need)]
        self.lens[rid] = int(n_tokens)
        self.stats["allocs"] += 1
        self.stats["pages_alloc"] += need
        self.stats["high_water_pages"] = max(
            self.stats["high_water_pages"], self.used_pages)
        return True

    def append(self, rid: int) -> bool:
        """Extend ``rid`` by one token; allocates a page on a boundary
        crossing.  False (state unchanged) when the pool is dry -- the
        caller's cue to preempt a victim and retry."""
        table = self.tables[rid]
        new_len = self.lens[rid] + 1
        if new_len > len(table) * self.page_size:
            if not self._free:
                self.stats["failed_appends"] += 1
                return False
            table.append(self._free.pop())
            self.stats["pages_alloc"] += 1
            self.stats["high_water_pages"] = max(
                self.stats["high_water_pages"], self.used_pages)
        self.lens[rid] = new_len
        return True

    def free(self, rid: int) -> int:
        """Return every page ``rid`` holds; returns the page count."""
        table = self.tables.pop(rid)
        del self.lens[rid]
        self._free.extend(reversed(table))
        self.stats["frees"] += 1
        self.stats["pages_freed"] += len(table)
        return len(table)

    def held(self, rid: int) -> bool:
        return rid in self.tables

    # -- audits -------------------------------------------------------------
    def assert_empty(self) -> None:
        """Raise if any request leaked pages (post-``run()`` audit)."""
        if self.tables:
            raise AssertionError(
                f"leaked KV pages: rids {sorted(self.tables)} still hold "
                f"{self.used_pages} pages")
        if self.free_pages != self.num_pages:
            raise AssertionError(
                f"pool accounting drift: {self.free_pages} free != "
                f"{self.num_pages} total")

    def report(self) -> dict:
        return {
            "num_pages": self.num_pages,
            "page_size": self.page_size,
            "used_pages": self.used_pages,
            "free_pages": self.free_pages,
            "active_requests": len(self.tables),
            **self.stats,
        }
