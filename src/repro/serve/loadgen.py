"""Seeded load generation + latency rollups for the serve engine.

Arrival times are measured in **engine steps**, not wall-clock: the
engine is step-driven, so gating arrivals on the step index makes a
whole load sweep deterministic end-to-end -- same seed, same arrival
interleaving, same admissions, same token streams, on any machine.
Wall-clock enters only through the latency *measurements* (the
``Request`` timestamps the engine stamps as it serves).

Distributions:

* ``poisson`` -- exponential inter-arrival gaps at ``rate`` requests
  per step (the classic open-loop server model);
* ``bursty``  -- ``burst``-sized request clumps every ``burst_gap``
  steps (flash-crowd traffic; stresses admission + page pressure);
* ``all_at_once`` -- everything queued at step 0 (the closed-loop
  reference: maximum batching opportunity, zero arrival noise).

:func:`latency_report` rolls per-request timestamps into the serving
SLO quantities CI gates: p50/p99 decode ms-per-token, p50/p99 time to
first token, queue wait, and aggregate tokens/sec.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Tuple

import numpy as np

from .engine import Request, ServeEngine


@dataclasses.dataclass(frozen=True)
class LoadConfig:
    n_requests: int = 100
    seed: int = 0
    arrival: str = "poisson"        # poisson | bursty | all_at_once
    rate: float = 2.0               # poisson: mean arrivals per step
    burst: int = 8                  # bursty: requests per burst
    burst_gap: int = 6              # bursty: steps between bursts
    prompt_len: Tuple[int, int] = (4, 16)   # uniform inclusive range
    max_new: Tuple[int, int] = (2, 8)
    vocab: int = 256
    # fraction of requests carrying a deadline_ms SLO (uniform range)
    deadline_frac: float = 0.0
    deadline_ms: Tuple[float, float] = (50.0, 500.0)
    # fraction of deliberately oversize prompts (admission-rejection
    # traffic); their length is set by the driver via `oversize_len`
    oversize_frac: float = 0.0
    oversize_len: int = 0

    def __post_init__(self):
        if self.arrival not in ("poisson", "bursty", "all_at_once"):
            raise ValueError(f"arrival={self.arrival!r}")


def generate(cfg: LoadConfig) -> List[Tuple[float, Request]]:
    """Seeded ``[(arrival_step, Request), ...]`` sorted by arrival."""
    rng = np.random.default_rng([cfg.seed, 0xC0DE])
    n = cfg.n_requests
    if cfg.arrival == "poisson":
        gaps = rng.exponential(scale=1.0 / max(cfg.rate, 1e-9), size=n)
        at = np.cumsum(gaps)
    elif cfg.arrival == "bursty":
        at = np.asarray([(i // cfg.burst) * cfg.burst_gap
                         for i in range(n)], np.float64)
    else:                            # all_at_once
        at = np.zeros((n,), np.float64)

    out = []
    for rid in range(n):
        oversize = (cfg.oversize_frac > 0
                    and rng.random() < cfg.oversize_frac)
        plen = (cfg.oversize_len if oversize else
                int(rng.integers(cfg.prompt_len[0], cfg.prompt_len[1] + 1)))
        req = Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab, plen).astype(np.int32),
            max_new=int(rng.integers(cfg.max_new[0], cfg.max_new[1] + 1)))
        if cfg.deadline_frac > 0 and rng.random() < cfg.deadline_frac:
            req.deadline_ms = float(rng.uniform(*cfg.deadline_ms))
        out.append((float(at[rid]), req))
    return out


def clone_requests(arrivals) -> List[Tuple[float, Request]]:
    """Fresh Request objects over the same rids/prompts/budgets --
    one load set can drive several engine legs independently."""
    return [(at, Request(rid=r.rid, prompt=np.asarray(r.prompt, np.int32),
                         max_new=r.max_new, deadline_ms=r.deadline_ms))
            for at, r in arrivals]


def drive(engine: ServeEngine, arrivals,
          max_steps: Optional[int] = None) -> dict:
    """Feed ``arrivals`` into the engine as its step index passes each
    arrival time; run to drain.  Returns the run record (done list,
    wall seconds, step count)."""
    pending = sorted(arrivals, key=lambda p: (p[0], p[1].rid))
    done: List[Request] = []
    i = 0
    step_idx = 0
    t0 = time.perf_counter()
    while (i < len(pending) or engine.queue
           or any(s is not None for s in engine.slots)):
        while i < len(pending) and pending[i][0] <= step_idx:
            engine.add(pending[i][1])
            i += 1
        done.extend(engine.step())
        step_idx += 1
        if max_steps is not None and step_idx >= max_steps:
            break
    wall_s = time.perf_counter() - t0
    return {"done": done, "wall_s": wall_s, "steps": step_idx}


def _pct(vals, q):
    return float(np.percentile(np.asarray(vals, np.float64), q))


def latency_report(done: List[Request], wall_s: float,
                   engine: ServeEngine) -> dict:
    """Per-request timestamps -> SLO quantities.

    ``p50_ms``/``p99_ms`` are the decode ms-per-token percentiles over
    completed requests (a request's own steady-state token cadence);
    ``tokens_per_s`` is aggregate generated-token throughput over the
    whole sweep wall-clock (queue time included -- the honest serving
    number)."""
    per_tok = [r.ms_per_token() for r in done
               if r.ms_per_token() is not None]
    ttft = [r.ttft_ms() for r in done if r.ttft_ms() is not None]
    queue = [r.queue_ms() for r in done if r.queue_ms() is not None]
    tokens = sum(len(r.out) for r in done)
    rep = {
        "requests_done": len(done),
        "tokens": tokens,
        "wall_s": round(wall_s, 3),
        "tokens_per_s": round(tokens / max(wall_s, 1e-9), 1),
        "rejected": engine.stats["rejected"],
        "truncated": engine.stats["truncated"],
        "preemptions": engine.stats["preemptions"],
        "resumes": engine.stats["resumes"],
        "steps": engine.stats["steps"],
    }
    for name, vals in (("ms_per_token", per_tok), ("ttft_ms", ttft),
                       ("queue_ms", queue)):
        if vals:
            rep[f"{name}_p50"] = round(_pct(vals, 50), 3)
            rep[f"{name}_p99"] = round(_pct(vals, 99), 3)
    # the gate-facing aliases (CI validates these exact keys)
    rep["p50_ms"] = rep.get("ms_per_token_p50", 0.0)
    rep["p99_ms"] = rep.get("ms_per_token_p99", 0.0)
    return rep
