"""Deterministic, shardable, checkpointable data pipeline.

Production shape: every host draws the *same* global batch definition
from a counter-based RNG (stateless: ``(seed, step)`` fully determines
the batch), then slices its per-host shard.  Restart-from-checkpoint
resumes at the recorded step with zero drift; elastic re-sharding only
changes the slice boundaries, not the stream.

Two sources:

* ``SyntheticLM`` -- zipf-ish token stream (benchmarks, dry-runs, tests)
* ``FileLM``      -- memory-mapped uint16/uint32 token file (real runs)
"""

from __future__ import annotations

import dataclasses
import pathlib

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    global_batch: int = 8
    seq_len: int = 128
    vocab: int = 256
    path: str | None = None          # None -> synthetic
    src_len: int | None = None       # enc-dec source length
    d_model: int | None = None       # for frontend-stub embeds


class Pipeline:
    """state = just the step counter; batch(step) is a pure function."""

    def __init__(self, cfg: DataConfig, host_id: int = 0, n_hosts: int = 1):
        self.cfg = cfg
        self.host_id = host_id
        self.n_hosts = n_hosts
        assert cfg.global_batch % n_hosts == 0
        self._mm = None
        if cfg.path is not None:
            self._mm = np.memmap(pathlib.Path(cfg.path), dtype=np.uint16,
                                 mode="r")

    def _host_slice(self):
        per = self.cfg.global_batch // self.n_hosts
        return self.host_id * per, per

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        start, per = self._host_slice()
        if self._mm is not None:
            # deterministic offsets from a counter-based hash
            rs = np.random.Generator(np.random.Philox(
                key=cfg.seed, counter=step))
            max_start = len(self._mm) - cfg.seq_len - 1
            offs = rs.integers(0, max_start, cfg.global_batch)
            offs = offs[start:start + per]
            toks = np.stack([self._mm[o:o + cfg.seq_len] for o in offs])
            out = {"tokens": jnp.asarray(toks.astype(np.int32))}
        else:
            rs = np.random.Generator(np.random.Philox(
                key=cfg.seed, counter=step))
            # zipf-ish synthetic distribution over the real vocab
            u = rs.random((cfg.global_batch, cfg.seq_len))
            toks = np.minimum((u ** 3 * cfg.vocab).astype(np.int32),
                              cfg.vocab - 1)
            out = {"tokens": jnp.asarray(toks[start:start + per])}
        if cfg.src_len and cfg.d_model:
            rs2 = np.random.Generator(np.random.Philox(
                key=cfg.seed + 1, counter=step))
            emb = rs2.normal(0, 1, (per, cfg.src_len, cfg.d_model))
            out["src_embeds"] = jnp.asarray(emb, jnp.bfloat16)
        return out

    # checkpointable state ---------------------------------------------------
    def state_dict(self, step: int) -> dict:
        return {"step": step, "seed": self.cfg.seed,
                "global_batch": self.cfg.global_batch}

    @staticmethod
    def resume_step(state: dict) -> int:
        return int(state["step"])
