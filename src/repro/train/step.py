"""jit-able train step: loss -> grads -> AdamW, with microbatch
gradient accumulation (lax.scan) for large global batches."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import optimizer as opt_mod


def make_train_step(model, opt_cfg, accum: int = 1):
    """Returns train_step(params, opt_state, batch) -> (p, s, metrics).

    ``accum`` > 1 splits the per-device batch into microbatches scanned
    sequentially (activation memory / batch size decoupling).
    """

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def train_step(params, opt_state, batch):
        if accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum)
                                    + x.shape[1:]), batch)

            def body(carry, mb):
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return (carry[0] + l,
                        jax.tree.map(jnp.add, carry[1], g)), None

            zero = (jnp.zeros((), jnp.float32),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params))
            (loss, grads), _ = jax.lax.scan(body, zero, micro)
            loss = loss / accum
            grads = jax.tree.map(lambda g: g / accum, grads)

        params, opt_state, metrics = opt_mod.apply(
            params, grads, opt_state, opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def jit_train_step(model, opt_cfg, accum: int = 1, donate: bool = True):
    fn = make_train_step(model, opt_cfg, accum)
    return jax.jit(fn, donate_argnums=(0, 1) if donate else ())
