"""Fault-tolerant checkpointing: atomic, keep-N, resumable.

Layout:  <dir>/step_<n>/arrays.npz + meta.json, written to a temp dir and
atomically renamed (a crash mid-write never corrupts the latest valid
checkpoint).  ``latest_step`` scans for complete checkpoints only.

On a multi-host cluster each host writes its process-local shards under
``host_<i>`` (here: single host).  bfloat16 leaves are stored as uint16
views (npz has no bf16).
"""

from __future__ import annotations

import json
import os
import pathlib
import shutil
import time

import jax
import jax.numpy as jnp
import numpy as np

_BF16_TAG = "__bf16__"


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(ckpt_dir, step: int, tree, extra_meta: dict | None = None,
         keep: int = 3) -> str:
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}_{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, treedef = _flatten(tree)
    arrays = {}
    for i, leaf in enumerate(leaves):
        a = np.asarray(leaf)
        if a.dtype == jnp.bfloat16:
            arrays[f"{_BF16_TAG}{i}"] = a.view(np.uint16)
        else:
            arrays[f"a{i}"] = a
    np.savez(tmp / "arrays.npz", **arrays)
    meta = {"step": step, "n_leaves": len(leaves),
            "treedef": str(treedef), "time": time.time(),
            "extra": extra_meta or {}}
    (tmp / "meta.json").write_text(json.dumps(meta))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)                     # atomic publish

    # retention
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(ckpt_dir / f"step_{s:08d}", ignore_errors=True)
    return str(final)


class AsyncSaver:
    """Overlap checkpoint IO with training (one in-flight save).

    ``submit`` snapshots device arrays to host (blocking only on the
    device->host copy), then serializes + atomically publishes on a
    background thread.  ``wait`` joins the in-flight save (call before
    shutdown or before restoring).
    """

    def __init__(self):
        self._thread = None
        self._error = None

    def submit(self, ckpt_dir, step, tree, extra_meta=None, keep=3):
        import threading
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)   # snapshot

        def run():
            try:
                save(ckpt_dir, step, host_tree, extra_meta, keep)
            except Exception as e:                    # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err


def all_steps(ckpt_dir) -> list:
    ckpt_dir = pathlib.Path(ckpt_dir)
    out = []
    if not ckpt_dir.exists():
        return out
    for p in ckpt_dir.iterdir():
        if p.name.startswith("step_") and (p / "meta.json").exists() \
                and (p / "arrays.npz").exists():
            out.append(int(p.name.split("_")[1]))
    return sorted(out)


def latest_step(ckpt_dir):
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir, like, step: int | None = None):
    """Restore into the structure (and dtypes) of ``like``.

    Returns (tree, meta).  ``like`` may be ShapeDtypeStructs or arrays.
    """
    ckpt_dir = pathlib.Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    meta = json.loads((d / "meta.json").read_text())
    data = np.load(d / "arrays.npz")

    leaves, treedef = _flatten(like)
    out = []
    for i, leaf in enumerate(leaves):
        if f"{_BF16_TAG}{i}" in data:
            a = jnp.asarray(data[f"{_BF16_TAG}{i}"]).view(jnp.bfloat16)
        else:
            a = jnp.asarray(data[f"a{i}"])
        if isinstance(leaf, (int, float)):       # python scalars (metadata)
            out.append(type(leaf)(a))
            continue
        assert a.shape == leaf.shape, (a.shape, leaf.shape)
        out.append(a.astype(leaf.dtype))
    return jax.tree.unflatten(treedef, out), meta
