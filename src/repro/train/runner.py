"""Fault-tolerant training runner.

Production posture for 1000+ nodes (see README §fault-tolerance):

* checkpoint/restart -- atomic keep-N checkpoints (params + optimizer +
  data-pipeline state) every ``ckpt_every`` steps; on *any* step failure
  the runner restores the latest checkpoint and replays.  The data
  pipeline is counter-based, so replayed batches are bit-identical.
* node failure -- surfaces as a failed step (collective error); restart
  from checkpoint on the surviving topology via ``elastic_remesh``:
  batches are re-sliced over the new data-parallel extent, the model
  axis stays fixed (re-lowering handled by the caller's mesh rebuild).
* straggler mitigation -- a step-time watchdog tracks a running median;
  steps slower than ``straggler_factor`` x median are logged and counted
  so the scheduler can evict the slow host.  (In synchronous SPMD the
  step itself cannot be skipped.)
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from . import checkpoint as ckpt_mod


@dataclasses.dataclass
class RunnerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    max_restarts: int = 3
    straggler_factor: float = 3.0
    log_every: int = 10
    async_ckpt: bool = True      # overlap checkpoint IO with training


class Trainer:
    def __init__(self, cfg: RunnerConfig, train_step: Callable,
                 params, opt_state, pipeline,
                 fail_hook: Optional[Callable] = None,
                 log: Callable = print):
        self.cfg = cfg
        self.train_step = train_step
        self.params = params
        self.opt_state = opt_state
        self.pipeline = pipeline
        self.fail_hook = fail_hook          # test hook: raise to simulate
        self.log = log
        self.step_times: list = []
        self.straggler_events = 0
        self.restarts = 0
        self._saver = ckpt_mod.AsyncSaver()

    # -- checkpoint glue -----------------------------------------------------
    def _save(self, step, final=False):
        tree = {"params": self.params, "opt": self.opt_state,
                "data": self.pipeline.state_dict(step)}
        if self.cfg.async_ckpt and not final:
            self._saver.submit(self.cfg.ckpt_dir, step, tree,
                               keep=self.cfg.keep)
            self.log(f"[ckpt] step {step} (async)")
        else:
            self._saver.wait()
            path = ckpt_mod.save(self.cfg.ckpt_dir, step, tree,
                                 keep=self.cfg.keep)
            self.log(f"[ckpt] step {step} -> {path}")

    def _restore(self):
        self._saver.wait()
        like = {"params": self.params, "opt": self.opt_state,
                "data": self.pipeline.state_dict(0)}
        tree, meta = ckpt_mod.restore(self.cfg.ckpt_dir, like)
        self.params = tree["params"]
        self.opt_state = tree["opt"]
        step = int(meta["step"])
        self.log(f"[ckpt] restored step {step}")
        return step

    # -- main loop -------------------------------------------------------------
    def run(self, start_step: int = 0):
        step = start_step
        last_metrics = {}
        while step < self.cfg.total_steps:
            try:
                batch = self.pipeline.batch(step)
                t0 = time.perf_counter()
                if self.fail_hook is not None:
                    self.fail_hook(step)
                self.params, self.opt_state, metrics = self.train_step(
                    self.params, self.opt_state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0

                # straggler watchdog
                self.step_times.append(dt)
                med = float(np.median(self.step_times[-50:]))
                if len(self.step_times) > 5 and \
                        dt > self.cfg.straggler_factor * med:
                    self.straggler_events += 1
                    self.log(f"[straggler] step {step}: {dt:.3f}s "
                             f"(median {med:.3f}s)")

                step += 1
                last_metrics = {k: float(v) for k, v in metrics.items()}
                if step % self.cfg.log_every == 0:
                    self.log(f"[train] step {step} "
                             f"loss {last_metrics['loss']:.4f} "
                             f"({dt*1e3:.0f} ms)")
                if step % self.cfg.ckpt_every == 0:
                    self._save(step)
            except KeyboardInterrupt:
                raise
            except Exception as e:                      # noqa: BLE001
                self.restarts += 1
                self.log(f"[fault] step {step}: {type(e).__name__}: {e}")
                if self.restarts > self.cfg.max_restarts:
                    raise
                if ckpt_mod.latest_step(self.cfg.ckpt_dir) is not None:
                    step = self._restore()
                else:
                    self.log("[fault] no checkpoint; restarting from 0")
                    step = start_step
        self._save(step, final=True)
        return step, last_metrics


def elastic_remesh(global_batch: int, n_data_old: int, n_data_new: int):
    """Re-slice the global batch over a changed data-parallel extent.

    Returns the new per-shard batch.  The synchronous semantics (same
    global batch, same RNG counters) are preserved exactly, which is why
    shrink/grow needs no optimizer adjustments.
    """
    assert global_batch % n_data_new == 0, \
        f"global_batch {global_batch} must divide data axis {n_data_new}"
    return global_batch // n_data_new
