"""AdamW with cosine schedule + global-norm clipping (no optax needed)."""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


def init(params, cfg: OptConfig) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(step=jnp.zeros((), jnp.int32),
                    mu=jax.tree.map(zeros, params),
                    nu=jax.tree.map(zeros, params))


def schedule(step, cfg: OptConfig):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 \
        * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def apply(params, grads, state: OptState, cfg: OptConfig):
    """Returns (new_params, new_state, metrics)."""
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    step = state.step + 1
    lr = schedule(step, cfg)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return new_params, OptState(step, new_mu, new_nu), \
        {"grad_norm": gnorm, "lr": lr}
