"""Gradient compression for data-parallel all-reduce.

At 1000+ nodes the gradient all-reduce over the (pod, data) axes is the
dominant cross-pod traffic.  ``compressed_psum`` reduces it ~4x by
summing int8-quantized values (+ one f32 scale per leaf) instead of f32:

    g_q = round(g / s),  s = max|g| / 127        (per leaf, per shard)
    sum = psum(g_q * s_local)  ->  communicated as int-scaled payloads

The quantization error is unbiased per step (symmetric rounding) and
bounded by ``max|g| / 127``; error feedback (residual carry-over) can be
layered on top by the caller.  Used inside ``shard_map`` over the data
axes; model-parallel leaves pass through untouched.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def quantize_leaf(g, bits: int = 8):
    qmax = (1 << (bits - 1)) - 1
    amax = jnp.max(jnp.abs(g.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / qmax
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale),
                 -qmax - 1, qmax).astype(jnp.int8)
    return q, scale


def dequantize_leaf(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(tree, axis_names, bits: int = 8):
    """psum a pytree of per-shard gradients with int8 on-wire payloads.

    Must run inside ``shard_map`` (axis names bound).  Communicates
    int8 values widened to int32 for the reduction (wire format on real
    interconnects stays 1 B/elt with a ring of int8 partial sums; XLA's
    int32 psum here is the portable stand-in) plus one f32 scale per
    leaf and shard.
    """
    def one(g):
        q, scale = quantize_leaf(g, bits)
        # all shards must agree on a scale: use the max via psum-max
        smax = jax.lax.pmax(scale, axis_names)
        q = jnp.clip(jnp.round(g.astype(jnp.float32) / smax),
                     -(1 << (bits - 1)) + 0, (1 << (bits - 1)) - 1
                     ).astype(jnp.int8)
        total = jax.lax.psum(q.astype(jnp.int32), axis_names)
        return (total.astype(jnp.float32) * smax).astype(g.dtype)
    return jax.tree.map(one, tree)


def make_compressed_grad_fn(loss_fn, mesh, bits: int = 8):
    """value_and_grad with int8-compressed data-parallel reduction.

    ``loss_fn(params, batch) -> scalar``; params replicated over the
    data axes (model sharding handled outside).  Returns a function
    (params, batch) -> (mean_loss, summed_grads / n_shards).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n = 1
    for a in axes:
        n *= mesh.shape[a]

    def local(params, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads = compressed_psum(grads, axes, bits)
        loss = jax.lax.psum(loss, axes)
        return loss / n, jax.tree.map(lambda g: g / n, grads)

    batch_spec = P(axes if len(axes) > 1 else (axes[0] if axes else None))
    return shard_map(local, mesh=mesh,
                     in_specs=(P(), batch_spec),
                     out_specs=(P(), P()),
                     check_rep=False)
