"""falcon-mamba-7b: Mamba-1 SSM, attention-free [arXiv:2410.05355]."""

from .base import ModelConfig, MoESpec, SSMSpec, RGLRUSpec  # noqa


def config() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b",
        family="ssm",
        n_layers=64,
        d_model=4096,
        n_heads=1,
        n_kv_heads=1,
        d_ff=0,
        vocab=65024,
        ssm=SSMSpec(state_dim=16, conv_width=4, expand=2),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b-smoke",
        family="ssm",
        n_layers=2,
        d_model=64,
        n_heads=1,
        n_kv_heads=1,
        d_ff=0,
        vocab=256,
        ssm=SSMSpec(state_dim=4, conv_width=4, expand=2),
    )
