"""granite-moe-3b-a800m: fine-grained MoE, 40 experts top-8, d_ff=512 [hf:ibm-granite].  The assignment lists both '40e top-8' and '32 experts'; we follow the explicit MoE field (40 experts)."""

from .base import ModelConfig, MoESpec, SSMSpec, RGLRUSpec  # noqa


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m",
        family="moe",
        n_layers=32,
        d_model=1536,
        n_heads=24,
        n_kv_heads=8,
        d_ff=512,
        vocab=49155,
        moe=MoESpec(num_experts=40, top_k=8, d_ff=512),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        moe=MoESpec(num_experts=8, top_k=2, d_ff=128, capacity_factor=4.0),
    )
