"""mixtral-8x7b: 8 experts top-2, sliding-window attention [arXiv:2401.04088]."""

from .base import ModelConfig, MoESpec, SSMSpec, RGLRUSpec  # noqa


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b",
        family="moe",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab=32000,
        sliding_window=4096,
        moe=MoESpec(num_experts=8, top_k=2, d_ff=14336),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        sliding_window=32,
        moe=MoESpec(num_experts=4, top_k=2, d_ff=128, capacity_factor=2.0),
    )
