"""Model/architecture configuration schema for the 10-arch zoo."""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoESpec:
    num_experts: int
    top_k: int
    d_ff: int                    # per-expert hidden size
    capacity_factor: float = 1.25
    # 1 = global sort-based dispatch (replicated expert buffer -> big
    # all-reduce).  >1 = hierarchical dispatch: tokens dispatched within
    # data-parallel chunks into per-chunk expert buffers; the buffer's
    # chunk dim lands on the data axes and its expert dim on the model
    # axis, so only an all-to-all-sized reshard remains (EXPERIMENTS.md
    # §Perf iteration 1).
    dispatch_chunks: int = 1


@dataclasses.dataclass(frozen=True)
class SSMSpec:                   # Mamba-1 selective SSM
    state_dim: int = 16
    conv_width: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None   # None -> ceil(d_model / 16)


@dataclasses.dataclass(frozen=True)
class RGLRUSpec:                 # RecurrentGemma / Griffin
    lru_width: Optional[int] = None   # None -> d_model
    conv_width: int = 4
    window: int = 2048           # local-attention window in the 1:2 mix


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    sliding_window: Optional[int] = None   # SWA (mistral-style)
    rope_theta: float = 10_000.0
    moe: Optional[MoESpec] = None
    ssm: Optional[SSMSpec] = None
    rglru: Optional[RGLRUSpec] = None
    encoder_layers: int = 0      # > 0 => encoder-decoder
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    mlp_variant: str = "swiglu"          # swiglu (3 mats) | gelu (2 mats)
    kv_quant_bits: Optional[int] = None  # 8 => int8 KV cache (PIM storage)
    remat_policy: str = "full"           # full | dots | none (train remat)
    # modality frontend stub: inputs arrive as precomputed embeddings
    # ("frames"/"patches") concatenated with token embeddings.
    frontend_stub: Optional[str] = None    # None | "patch" | "frame"

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    # ---- layer plan for scan-over-layers ---------------------------------
    def layer_types(self) -> List[str]:
        if self.ssm is not None:
            return ["ssm"] * self.n_layers
        if self.rglru is not None:
            # Griffin pattern: (rec, rec, local attn) repeating
            pattern = ["rec", "rec", "attn"]
            return [pattern[i % 3] for i in range(self.n_layers)]
        return ["attn"] * self.n_layers

    def scan_plan(self) -> Tuple[List[str], int, List[str]]:
        """(repeating unit, repeat count, remainder) for lax.scan."""
        types = self.layer_types()
        if self.rglru is not None:
            unit = ["rec", "rec", "attn"]
            n = len(types) // 3
            return unit, n, types[3 * n:]
        return [types[0]], len(types), []

    @property
    def subquadratic(self) -> bool:
        """Can this arch decode at 500k context with bounded state?"""
        return (self.ssm is not None or self.rglru is not None
                or self.sliding_window is not None)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    # approximate parameter count (for 6ND roofline bookkeeping)
    def param_count(self) -> int:
        d, hd = self.d_model, self.hd
        n_attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        nmat = 3 if self.mlp_variant == "swiglu" else 2
        if self.moe:
            n_ffn = self.moe.num_experts * 3 * d * self.moe.d_ff \
                + d * self.moe.num_experts
        else:
            n_ffn = nmat * d * self.d_ff
        per_layer = {"attn": n_attn + n_ffn, "ssm": 0, "rec": 0}
        if self.ssm:
            di = self.ssm.expand * d
            dtr = self.ssm.dt_rank or -(-d // 16)
            per_layer["ssm"] = (d * 2 * di + di * self.ssm.conv_width
                                + di * (dtr + 2 * self.ssm.state_dim)
                                + dtr * di + di * self.ssm.state_dim
                                + di * d + n_ffn)
        if self.rglru:
            w = self.rglru.lru_width or d
            per_layer["rec"] = (2 * d * w + w * self.rglru.conv_width
                                + 2 * w * w // 1 + w * d + n_ffn)
        total = sum(per_layer[t] for t in self.layer_types())
        total += self.vocab * d * (1 if self.tie_embeddings else 2)
        total += self.encoder_layers * (n_attn * 2 + n_ffn)
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if not self.moe:
            return self.param_count()
        full = self.param_count()
        moe_all = self.n_layers * self.moe.num_experts * 3 * self.d_model \
            * self.moe.d_ff
        moe_active = self.n_layers * self.moe.top_k * 3 * self.d_model \
            * self.moe.d_ff
        return int(full - moe_all + moe_active)
