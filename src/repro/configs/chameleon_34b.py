"""chameleon-34b: early-fusion VLM; VQ image tokens share the vocab so the backbone is a plain decoder [arXiv:2405.09818].  The image tokenizer frontend is a stub (tokens arrive pre-quantized)."""

from .base import ModelConfig, MoESpec, SSMSpec, RGLRUSpec  # noqa


def config() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b",
        family="vlm",
        n_layers=48,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=22016,
        vocab=65536,
        frontend_stub="patch",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b-smoke",
        family="vlm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=256,
        vocab=256,
        frontend_stub="patch",
    )
