"""recurrentgemma-9b: Griffin hybrid, RG-LRU + local attention 1:2, MQA [arXiv:2402.19427]."""

from .base import ModelConfig, MoESpec, SSMSpec, RGLRUSpec  # noqa


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        n_layers=38,
        d_model=4096,
        n_heads=16,
        n_kv_heads=1,
        d_ff=12288,
        vocab=256000,
        rglru=RGLRUSpec(lru_width=4096, conv_width=4, window=2048),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b-smoke",
        family="hybrid",
        n_layers=5,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        d_ff=256,
        vocab=256,
        rglru=RGLRUSpec(lru_width=64, conv_width=4, window=32),
    )
