"""llama3.2-1b: small llama3, GQA kv=8, 500k rope theta [hf:meta-llama]."""

from .base import ModelConfig, MoESpec, SSMSpec, RGLRUSpec  # noqa


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-1b",
        family="dense",
        n_layers=16,
        d_model=2048,
        n_heads=32,
        n_kv_heads=8,
        d_ff=8192,
        vocab=128256,
        rope_theta=500000.0,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-1b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=256,
        vocab=256,
        rope_theta=500000.0,
        tie_embeddings=True,
    )
