"""seamless-m4t-large-v2: encoder-decoder multimodal backbone [arXiv:2308.11596].  Speech frontend is a stub: the encoder consumes precomputed frame embeddings (B, S, d); 24 encoder + 24 decoder layers."""

from .base import ModelConfig, MoESpec, SSMSpec, RGLRUSpec  # noqa


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2",
        family="audio",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=8192,
        vocab=256206,
        mlp_variant="gelu",
        encoder_layers=24,
        frontend_stub="frame",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2-smoke",
        family="audio",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=256,
        vocab=256,
        mlp_variant="gelu",
        encoder_layers=2,
        frontend_stub="frame",
    )
