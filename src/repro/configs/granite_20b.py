"""granite-20b code model: llama-arch dense, MQA (kv=1) [arXiv:2405.04324]."""

from .base import ModelConfig, MoESpec, SSMSpec, RGLRUSpec  # noqa


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-20b",
        family="dense",
        n_layers=52,
        d_model=6144,
        n_heads=48,
        n_kv_heads=1,
        d_ff=24576,
        vocab=49152,
        mlp_variant="gelu",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-20b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        d_ff=256,
        vocab=256,
        mlp_variant="gelu",
    )
