"""h2o-danube-1.8b: llama+mistral mix with sliding-window attention [arXiv:2401.16818]."""

from .base import ModelConfig, MoESpec, SSMSpec, RGLRUSpec  # noqa


def config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-1.8b",
        family="dense",
        n_layers=24,
        d_model=2560,
        n_heads=32,
        n_kv_heads=8,
        d_ff=6912,
        vocab=32000,
        sliding_window=4096,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-1.8b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=256,
        vocab=256,
        sliding_window=32,
    )
