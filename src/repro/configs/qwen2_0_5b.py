"""qwen2-0.5b: GQA kv=2 with QKV bias [arXiv:2407.10671]."""

from .base import ModelConfig, MoESpec, SSMSpec, RGLRUSpec  # noqa


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-0.5b",
        family="dense",
        n_layers=24,
        d_model=896,
        n_heads=14,
        n_kv_heads=2,
        d_ff=4864,
        vocab=151936,
        qkv_bias=True,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-0.5b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=256,
        vocab=256,
        qkv_bias=True,
        tie_embeddings=True,
    )
