"""Architecture registry: ``--arch <id>`` resolution."""

import importlib

from .base import ModelConfig, MoESpec, RGLRUSpec, SSMSpec  # noqa

ARCHS = {
    "falcon-mamba-7b": "falcon_mamba_7b",
    "granite-20b": "granite_20b",
    "llama3.2-1b": "llama3_2_1b",
    "qwen2-0.5b": "qwen2_0_5b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "mixtral-8x7b": "mixtral_8x7b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "chameleon-34b": "chameleon_34b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    return mod.smoke_config() if smoke else mod.config()


def list_archs():
    return sorted(ARCHS)
