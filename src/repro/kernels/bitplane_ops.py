"""Bit-plane arithmetic backends: ripple adds and lane-axis popcount folds.

The compiled executor's packed interior (``core/compiler.py``) represents
per-column integers as *bit planes*: plane ``i`` is one main-array row's
repr value -- ``(cols,)`` bool, or ``(W,)`` uint32 words with 32 columns
per word.  Arithmetic on such integers is pure bitwise logic (the same
full-adder the carry chain of paper fig. 5 implements), which XLA fuses
into a handful of memory passes instead of the gather/weighted-sum
ladders of an int32 interior.

Two hot loops live here so they can be backend-dispatched:

* :func:`planes_add` -- an m-bit ripple-carry add/sub over plane lists
  (5 bitwise ops per bit).  Always jnp: chains are small and fuse.
* :func:`lane_fold` -- the reduction ``sum_t x_t mod 2^width`` over the
  lane (tuple) axis of lane-shaped planes.  This is a *positional
  popcount* (count/accumulate bits per column position across T lanes),
  computed as a log-depth carry-save ripple-fold tree.  It is the inner
  loop of every dot-product accumulator on the fabric, and the only
  piece big enough to pay for a Pallas kernel: above a column-count
  threshold on TPU the fold runs as one VMEM kernel
  (:func:`lane_fold_pallas`, built on the ``bitserial_matmul`` idioms);
  everywhere else the jax.numpy tree is the fallback.

Both paths are exact (mod ``2**width``) and bit-identical; tests verify
the Pallas kernel in ``interpret=True`` mode like the other kernels.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

__all__ = [
    "planes_add", "lane_fold", "lane_fold_jnp", "lane_fold_pallas",
    "use_pallas_fold", "PALLAS_FOLD_MIN_COLS",
]

#: lane_fold switches to the Pallas kernel when the fold covers at least
#: this many columns (lanes x packed words x 32) AND the default backend
#: is a TPU.  The jnp tree is always the fallback.
PALLAS_FOLD_MIN_COLS = 1 << 16

_ENV = "REPRO_BITPLANE_BACKEND"          # "auto" (default) | "jnp" | "pallas"


def _fa(a, b, c):
    """Bitwise full adder on mask arrays: returns (sum, carry_out)."""
    axb = a ^ b
    return axb ^ c, (a & b) | (c & axb)


def _fs(a, b, c):
    """Bitwise full subtractor (a - b - borrow): (diff, borrow_out)."""
    axb = a ^ b
    return axb ^ c, (~a & b) | (c & ~axb)


def _add1(a, b, c, sub: bool):
    """One ripple step where any of a/b/c may be None (known zero).

    Subtraction is NOT commutative in (a, b): the zero-elision cases are
    handled per side (0 - b borrows where b|c; a - 0 borrows where ~a&c).
    """
    if a is None and b is None:           # 0 op 0 op c
        return c, (c if sub else None)
    if a is None:                         # 0 op b
        if sub:
            # 0 - b - c: diff = b ^ c, borrow = b | c
            if c is None:
                return b, b
            return b ^ c, b | c
        if c is None:
            return b, None
        return b ^ c, b & c
    if b is None:                         # a op 0
        if c is None:
            return a, None
        if sub:
            # a - 0 - c: diff = a ^ c, borrow = ~a & c
            return a ^ c, ~a & c
        return a ^ c, a & c
    if c is None:
        if sub:
            return a ^ b, ~a & b
        return a ^ b, a & b
    return (_fs if sub else _fa)(a, b, c)


def planes_add(a, b, cin=None, *, sub: bool = False, width=None):
    """Ripple add/sub of two bit-plane lists.

    ``a`` and ``b`` are sequences of same-dtype mask arrays (bool planes
    or packed uint32 words), least-significant first; ``None`` entries
    (and a ``None`` ``cin``) are known-zero planes and cost no ops.
    Shorter inputs are zero-extended.  Returns ``(planes, carry_out)``
    of length ``width`` (default ``max(len(a), len(b))``); both the
    planes and the carry may be ``None`` (known zero).  For ``sub`` the
    carry is the borrow.  Exact mod ``2**width`` with the exact final
    carry/borrow -- the same contract as the engine's OP_FA/OP_FS chain.
    """
    m = max(len(a), len(b)) if width is None else width
    out = []
    c = cin
    for i in range(m):
        ai = a[i] if i < len(a) else None
        bi = b[i] if i < len(b) else None
        s, c = _add1(ai, bi, c, sub)
        out.append(s)
    return out, c


def _tree_fold(planes, width: int):
    """Pairwise carry-save ripple-fold over the leading lane axis.

    ``planes``: list of ``(T, ...)`` mask arrays (entries may be None).
    Returns a list of ``width`` base-shaped planes == the mod-2**width
    sum over lanes.  Associativity of modular addition makes any
    pairing order exact, so the tree halves T each level.
    """
    planes = list(planes[:width])
    planes += [None] * (width - len(planes))
    T = next(p.shape[0] for p in planes if p is not None)
    while T > 1:
        h = T // 2
        a = [None if p is None else p[:h] for p in planes]
        b = [None if p is None else p[h:2 * h] for p in planes]
        s, _ = planes_add(a, b, width=width)
        if T % 2:                      # odd lane rides along to next level
            def cat(si, ti):
                if si is None and ti is None:
                    return None
                ref = si if si is not None else ti
                left = (jnp.zeros((h,) + ref.shape[1:], ref.dtype)
                        if si is None else si)
                right = (jnp.zeros((1,) + ref.shape[1:], ref.dtype)
                         if ti is None else ti)
                return jnp.concatenate([left, right])
            tail = [None if p is None else p[2 * h:] for p in planes]
            planes, T = [cat(si, ti) for si, ti in zip(s, tail)], h + 1
        else:
            planes, T = s, h
    return [None if p is None else p[0] for p in planes]


def lane_fold_jnp(planes, width: int):
    """jax.numpy backend of :func:`lane_fold` (works on bool or uint32)."""
    return _tree_fold(planes, width)


# ---------------------------------------------------------------------------
# Pallas backend: the whole fold as one VMEM kernel over packed words
# ---------------------------------------------------------------------------
def _lane_fold_kernel(x_ref, o_ref, *, lanes: int, m: int, width: int):
    """Positional-popcount fold of one word-column tile.

    ``x_ref``: (m, lanes, bw) uint32 planes; ``o_ref``: (width, bw).
    The reduction runs entirely in VMEM as the same carry-save tree the
    jnp path uses -- on the VPU every step is an elementwise op.
    """
    x = x_ref[...]
    planes = [x[i] for i in range(m)] + [None] * (width - m)
    out = _tree_fold(planes, width)
    zero = jnp.zeros(o_ref.shape[1:], jnp.uint32)
    o_ref[...] = jnp.stack([zero if p is None else p for p in out])


@functools.partial(jax.jit, static_argnames=("width", "block_w", "interpret"))
def lane_fold_pallas(x, width: int, *, block_w: int = 512,
                     interpret: bool = False):
    """Pallas TPU fold: ``x`` is (m, T, W) uint32, result (width, W).

    Grid over word-column tiles; each program folds its tile's T lanes
    in VMEM.  Validated against :func:`lane_fold_jnp` in interpret mode.
    """
    from jax.experimental import pallas as pl

    m, lanes, w = x.shape
    bw = min(block_w, w)
    pad = (-w) % bw
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad)))
    wp = w + pad
    out = pl.pallas_call(
        functools.partial(_lane_fold_kernel, lanes=lanes, m=m, width=width),
        grid=(wp // bw,),
        in_specs=[pl.BlockSpec((m, lanes, bw), lambda j: (0, 0, j))],
        out_specs=pl.BlockSpec((width, bw), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((width, wp), jnp.uint32),
        interpret=interpret,
    )(x)
    return out[:, :w] if pad else out


def _backend() -> str:
    v = os.environ.get(_ENV, "auto").lower()
    return v if v in ("auto", "jnp", "pallas") else "auto"


def use_pallas_fold(lanes: int, words: int, packed: bool) -> bool:
    """Selection rule: Pallas only for packed planes, on a TPU backend,
    when the fold covers >= :data:`PALLAS_FOLD_MIN_COLS` columns.  The
    ``REPRO_BITPLANE_BACKEND`` env var forces either backend."""
    be = _backend()
    if be == "jnp" or not packed:
        return False
    if be == "pallas":
        return True
    return (jax.default_backend() == "tpu"
            and lanes * words * 32 >= PALLAS_FOLD_MIN_COLS)


def lane_fold(planes, width: int, *, packed: bool, interpret: bool = False):
    """Fold lane-shaped planes down the lane axis, mod ``2**width``.

    Dispatches to the Pallas kernel per :func:`use_pallas_fold`, falling
    back to the fused jnp tree.  ``planes`` entries may be None (known
    zero); the result list may contain None entries likewise.
    """
    live = [p for p in planes[:width] if p is not None]
    if not live:
        return [None] * width
    lanes, words = live[0].shape[0], live[0].shape[-1]
    if (use_pallas_fold(lanes, words, packed)
            and all(p is None or p.ndim == 2 for p in planes[:width])):
        zero = jnp.zeros_like(live[0])
        x = jnp.stack([zero if p is None else p for p in planes[:width]])
        out = lane_fold_pallas(x, width, interpret=interpret)
        return [out[i] for i in range(width)]
    return lane_fold_jnp(planes, width)
