"""Public jit'd API over the Pallas kernels (with CPU interpret fallback).

``interpret`` defaults to True off-TPU so the whole framework runs (and
is tested) on CPU; on TPU the kernels compile to Mosaic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import bitserial_matmul as _bsm
from . import ref as kref

pack_bitplanes = kref.pack_bitplanes
unpack_bitplanes = kref.unpack_bitplanes
plane_coefs = kref.plane_coefs


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def quant_matmul(a, w_packed, scale_w, *, bits: int, interpret=None, **kw):
    """Performance path: packed-weight matmul (see bitserial_matmul.py)."""
    if interpret is None:
        interpret = _default_interpret()
    return _bsm.quant_matmul(a, w_packed, scale_w, bits=bits,
                             interpret=interpret, **kw)


def popcount_matmul(a_packed, w_packed, *, interpret=None, **kw):
    """PIM-faithful path: AND+popcount bit-serial matmul."""
    if interpret is None:
        interpret = _default_interpret()
    return _bsm.popcount_matmul(a_packed, w_packed, interpret=interpret,
                                **kw)


@functools.partial(jax.jit, static_argnames=("bits", "axis"))
def quantize(x, *, bits: int, axis: int = 0):
    """Symmetric per-channel quantization to signed ``bits`` integers.

    Returns (q int8, scale f32) with ``x ~= q * scale`` and scales taken
    along every axis except ``axis`` (i.e. one scale per slice of
    ``axis``... reduced over the other axes).
    """
    reduce_axes = tuple(i for i in range(x.ndim) if i != axis)
    amax = jnp.max(jnp.abs(x), axis=reduce_axes, keepdims=True)
    qmax = (1 << (bits - 1)) - 1
    scale = jnp.maximum(amax, 1e-8) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax).astype(jnp.int8)
    return q, scale.reshape(x.shape[axis]).astype(jnp.float32)
