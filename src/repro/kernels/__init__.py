"""Pallas TPU kernels: bit-plane-decomposed matmul (performance +
PIM-faithful popcount paths) and fused flash attention, each with
pure-jnp oracles and interpret-mode validation."""
