"""Pallas TPU kernels: bit-plane-decomposed ("bit-serial") matmul.

TPU adaptation of the Compute RAM idea (DESIGN.md §2).  The FPGA block
keeps operands in SRAM and computes across bit-lines; the TPU-native
equivalent keeps operands **bit-plane packed in HBM** (the "storage
mode" buffer) and computes on them **inside VMEM** without ever
materializing the expanded tensor in HBM (the "compute mode"):

* :func:`unpack_matmul_kernel` -- the performance path.  Weight tiles
  arrive as packed ``uint32`` bit planes (``bits/32`` of the bf16
  footprint), are expanded to int8 *inside VMEM*, and hit the MXU as a
  regular int32-accumulating matmul.  HBM traffic for weights drops by
  ``16/bits`` vs bf16 (4x for int4), which is precisely the "don't move
  the data to the DSP" energy/bandwidth argument of the paper, restated
  for the HBM<->VMEM hierarchy.

* :func:`popcount_matmul_kernel` -- the PIM-faithful path.  Both
  operands stay as bit planes and partial products are formed as
  ``popcount(AND)`` per plane pair with power-of-two recombination --
  the exact arithmetic the in-array engine performs (AND on the
  bit-line, add via the carry chain), vectorized over the VPU.

Both are validated in ``interpret=True`` mode against ``ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import ref

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x/0.5.x;
# support both so the kernels import on whichever the image bakes in.
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")


def _pick_block(dim: int, target: int, mult: int) -> int:
    """Largest divisor of ``dim`` that is <= target and a multiple of
    ``mult`` (so odd model dims like 896 or 4864 still tile cleanly)."""
    best = None
    for d in range(min(target, dim), 0, -1):
        if dim % d == 0 and d % mult == 0:
            best = d
            break
    if best is None:
        raise ValueError(f"no block for dim={dim} target={target} mult={mult}")
    return best


# ---------------------------------------------------------------------------
# Performance path: packed weights -> VMEM unpack -> MXU matmul
# ---------------------------------------------------------------------------
def _unpack_matmul_kernel(a_ref, w_ref, s_ref, o_ref, acc_ref, *,
                          bits: int, block_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.int8)                       # (bm, bk)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    coefs = ref.plane_coefs(bits, signed=True)

    bn = w_ref.shape[-1]
    w = jnp.zeros((block_k, bn), jnp.int32)
    for b in range(bits):
        wp = w_ref[b]                                     # (bk//32, bn) u32
        bitv = (wp[:, None, :] >> shifts[None, :, None]) & jnp.uint32(1)
        w = w + coefs[b] * bitv.reshape(block_k, bn).astype(jnp.int32)

    acc_ref[...] += jax.lax.dot_general(
        a.astype(jnp.int32), w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        o_ref[...] = (acc_ref[...].astype(jnp.float32)
                      * s_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "block_m", "block_n",
                                             "block_k", "interpret",
                                             "out_dtype"))
def quant_matmul(a, w_packed, scale_w, *, bits: int,
                 block_m: int = 128, block_n: int = 128, block_k: int = 512,
                 interpret: bool = False, out_dtype=jnp.float32):
    """C = (A @ unpack(W_packed)) * scale_w.

    a: (M, K) int8;  w_packed: (bits, K//32, N) uint32;  scale_w: (N,) f32.
    M/N/K must divide by the block shapes (callers pad; model dims are
    MXU-aligned anyway).
    """
    m, k = a.shape
    n = w_packed.shape[-1]
    assert w_packed.shape == (bits, k // 32, n), w_packed.shape
    block_m = _pick_block(m, block_m, 1)
    block_n = _pick_block(n, block_n, 1)
    block_k = _pick_block(k, block_k, 32)

    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        functools.partial(_unpack_matmul_kernel, bits=bits, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, t: (i, t)),
            pl.BlockSpec((bits, block_k // 32, block_n),
                         lambda i, j, t: (0, t, j)),
            pl.BlockSpec((1, block_n), lambda i, j, t: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, w_packed, scale_w.reshape(1, n).astype(jnp.float32))


# ---------------------------------------------------------------------------
# PIM-faithful path: AND + popcount over bit-plane pairs
# ---------------------------------------------------------------------------
def _popcount_kernel(ap_ref, wp_ref, o_ref, acc_ref, *, ca, cw):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    for i, ci in enumerate(ca):
        a = ap_ref[i]                                     # (bm, bkw) u32
        for j, cj in enumerate(cw):
            w = wp_ref[j]                                 # (bkw, bn) u32
            anded = a[:, :, None] & w[None, :, :]         # (bm, bkw, bn)
            pc = jax.lax.population_count(anded).astype(jnp.int32)
            acc_ref[...] += (ci * cj) * jnp.sum(pc, axis=1)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("a_signed", "w_signed",
                                             "block_m", "block_n", "block_k",
                                             "interpret"))
def popcount_matmul(a_packed, w_packed, *, a_signed: bool = True,
                    w_signed: bool = True, block_m: int = 32,
                    block_n: int = 128, block_k: int = 256,
                    interpret: bool = False):
    """(M, N) int32 = bit-serial matmul of packed planes (exact).

    a_packed: (Ba, M, K//32) uint32;  w_packed: (Bw, K//32, N) uint32.
    """
    ba, m, kw = a_packed.shape
    bw, kw2, n = w_packed.shape
    assert kw == kw2, (kw, kw2)
    k = kw * 32
    block_m = _pick_block(m, block_m, 1)
    block_n = _pick_block(n, block_n, 1)
    block_k = _pick_block(k, block_k, 32)

    ca = ref.plane_coefs(ba, a_signed)
    cw = ref.plane_coefs(bw, w_signed)
    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        functools.partial(_popcount_kernel, ca=ca, cw=cw),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ba, block_m, block_k // 32),
                         lambda i, j, t: (0, i, t)),
            pl.BlockSpec((bw, block_k // 32, block_n),
                         lambda i, j, t: (0, t, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a_packed, w_packed)
