"""Pure-jnp oracles for the Pallas kernels.

The bit-plane identity underlying everything (paper §II-B, adapted):

    x = -2^{B-1} * b_{B-1} + sum_{i<B-1} 2^i * b_i      (two's complement)
    A @ W = sum_{i,j} coef_i * coef_j * (A_i @ W_j)     (A_i, W_j in {0,1})

so a bit-plane-decomposed matmul is *exactly* the integer matmul; no
approximation is involved.  These oracles compute the same quantities
with ordinary jnp ops.
"""

from __future__ import annotations

import jax.numpy as jnp


def plane_coefs(bits: int, signed: bool) -> list:
    """Weight of each bit plane (MSB negative for two's complement)."""
    coefs = [1 << i for i in range(bits)]
    if signed:
        coefs[-1] = -coefs[-1]
    return coefs


def pack_bitplanes(x: jnp.ndarray, bits: int, axis: int) -> jnp.ndarray:
    """Pack integer tensor into bit planes along ``axis``.

    Returns uint32 with a new leading plane dimension and ``axis``
    shrunk 32x: plane ``b``, word ``w`` packs bits ``b`` of elements
    ``32w .. 32w+31``.  ``axis`` length must be a multiple of 32.
    """
    x = jnp.asarray(x)
    k = x.shape[axis]
    assert k % 32 == 0, f"pack axis must be multiple of 32, got {k}"
    u = x.astype(jnp.int32) & ((1 << bits) - 1)      # two's complement view
    u = jnp.moveaxis(u, axis, -1).astype(jnp.uint32)
    u = u.reshape(u.shape[:-1] + (k // 32, 32))
    shifts = jnp.arange(32, dtype=jnp.uint32)
    planes = []
    for b in range(bits):
        bit = (u >> jnp.uint32(b)) & jnp.uint32(1)
        word = jnp.sum(bit << shifts, axis=-1, dtype=jnp.uint32)
        planes.append(jnp.moveaxis(word, -1, axis))
    return jnp.stack(planes, axis=0)


def unpack_bitplanes(planes: jnp.ndarray, axis: int, signed: bool,
                     dtype=jnp.int32) -> jnp.ndarray:
    """Inverse of :func:`pack_bitplanes` (axis in the *unpacked* tensor)."""
    bits = planes.shape[0]
    coefs = plane_coefs(bits, signed)
    out = None
    for b in range(bits):
        p = jnp.moveaxis(planes[b], axis, -1)
        w = p[..., :, None]
        bitvals = ((w >> jnp.arange(32, dtype=jnp.uint32)) & jnp.uint32(1))
        v = bitvals.reshape(p.shape[:-1] + (-1,)).astype(dtype) * coefs[b]
        out = v if out is None else out + v
    return jnp.moveaxis(out, -1, axis)


def quant_matmul(a: jnp.ndarray, w_packed: jnp.ndarray, scale_w: jnp.ndarray,
                 bits: int, out_dtype=jnp.float32) -> jnp.ndarray:
    """Oracle: C = (A @ unpack(W)) * scale_w, int32 accumulation.

    a: (M, K) int8;  w_packed: (bits, K//32, N) uint32;
    scale_w: (N,) per-output-channel dequant scale.
    """
    w = unpack_bitplanes(w_packed, axis=0, signed=True)      # (K, N) int32
    acc = jnp.dot(a.astype(jnp.int32), w,
                  preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * scale_w[None, :]).astype(out_dtype)


def popcount_matmul(a_packed: jnp.ndarray, w_packed: jnp.ndarray,
                    a_signed: bool, w_signed: bool) -> jnp.ndarray:
    """Oracle for the PIM-faithful popcount path.

    a_packed: (Ba, M, K//32); w_packed: (Bw, K//32, N) -> (M, N) int32.
    """
    ca = plane_coefs(a_packed.shape[0], a_signed)
    cw = plane_coefs(w_packed.shape[0], w_signed)
    shifts = jnp.arange(32, dtype=jnp.uint32)

    def bits_of(p):   # (..., W) uint32 -> (..., W*32) int32 in {0,1}
        b = (p[..., None] >> shifts) & jnp.uint32(1)
        return b.reshape(p.shape[:-1] + (-1,)).astype(jnp.int32)

    out = 0
    for i, ci in enumerate(ca):
        ai = bits_of(a_packed[i])                       # (M, K)
        for j, cj in enumerate(cw):
            wj = bits_of(jnp.moveaxis(w_packed[j], 0, -1))   # (N, K)
            out = out + ci * cj * (ai @ wj.T)
    return out
