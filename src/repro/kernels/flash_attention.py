"""Pallas TPU flash-attention kernel (causal, online softmax).

The chunked-jnp attention in ``models/attention.py`` is the portable
path; this kernel is the fused VMEM-resident version for the serving /
single-shard hot spot: q/k/v tiles stream HBM->VMEM once, scores and the
online-softmax state (m, l, acc) never leave VMEM, and fully-masked
key blocks are skipped structurally by the causal grid bound.

On a TPU pod this slots in per-shard under ``shard_map`` (heads on the
model axis); the dry-run meshes use the jnp path, which lowers to the
same blockwise schedule.  Validated in interpret mode vs ``ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x/0.5.x;
# support both so the kernels import on whichever the image bakes in.
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  block_q: int, block_k: int, causal: bool):
    i = pl.program_id(1)        # query block
    t = pl.program_id(2)        # key block

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                    # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                    # (bk, hd)
    v = v_ref[0].astype(jnp.float32)
    s = q @ k.T * (q.shape[-1] ** -0.5)                 # (bq, bk)

    if causal:
        rows = i * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        cols = t * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        s = jnp.where(cols <= rows, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + p @ v
    m_ref[...] = m_new

    @pl.when(t == pl.num_programs(2) - 1)
    def _done():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q, k, v: (BH, S, hd) -> (BH, S, hd).  Heads folded into the batch
    dim (callers reshape (B, S, H, hd) -> (B*H, S, hd))."""
    bh, s, hd = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)

    grid = (bh, s // block_q, s // block_k)
    return pl.pallas_call(
        functools.partial(_flash_kernel, block_q=block_q, block_k=block_k,
                          causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, t: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, t: (b, t, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, t: (b, t, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, t: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)


def attention_ref(q, k, v, causal=True):
    """Naive oracle."""
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (q.shape[-1] ** -0.5)
    if causal:
        sq, sk = s.shape[-2:]
        mask = jnp.tril(jnp.ones((sq, sk), bool))
        s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
