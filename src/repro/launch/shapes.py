"""Assigned input shapes and ShapeDtypeStruct stand-ins for every model
input (no device allocation -- dry-run only)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

SHAPES = {
    "train_4k": {"kind": "train", "seq": 4096, "batch": 256},
    "prefill_32k": {"kind": "prefill", "seq": 32768, "batch": 32},
    "decode_32k": {"kind": "decode", "seq": 32768, "batch": 128},
    "long_500k": {"kind": "decode", "seq": 524288, "batch": 1},
}

ENC_SRC_LEN = 4096      # encoder source length for enc-dec decode shapes


def applicable(cfg, shape_name: str) -> bool:
    """long_500k needs sub-quadratic attention (DESIGN.md §5)."""
    if shape_name == "long_500k":
        return cfg.subquadratic
    return True


def skip_reason(cfg, shape_name: str) -> str:
    if shape_name == "long_500k" and not cfg.subquadratic:
        return ("full quadratic attention at 524k context: KV cache + "
                "attention do not fit; noted in DESIGN.md §5")
    return ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg, shape_name: str) -> dict:
    """ShapeDtypeStructs for the step function of this (arch, shape)."""
    sh = SHAPES[shape_name]
    b, s = sh["batch"], sh["seq"]
    kind = sh["kind"]

    if kind == "train":
        batch = {"tokens": _sds((b, s), jnp.int32)}
        if cfg.is_encdec:
            batch["src_embeds"] = _sds((b, s, cfg.d_model), jnp.bfloat16)
        return {"batch": batch}

    if kind == "prefill":
        out = {"tokens": _sds((b, s), jnp.int32)}
        if cfg.is_encdec:
            out["enc_out"] = _sds((b, ENC_SRC_LEN, cfg.d_model),
                                  jnp.bfloat16)
            out["enc_pos"] = _sds((b, ENC_SRC_LEN), jnp.int32)
        return out

    if kind == "decode":
        out = {"tokens": _sds((b, 1), jnp.int32),
               "pos": _sds((b,), jnp.int32)}
        if cfg.is_encdec:
            out["enc_out"] = _sds((b, ENC_SRC_LEN, cfg.d_model),
                                  jnp.bfloat16)
            out["enc_pos"] = _sds((b, ENC_SRC_LEN), jnp.int32)
        return out

    raise ValueError(kind)
