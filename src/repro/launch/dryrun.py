import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: sharding
mismatches, compile-time OOM sizing, and unsupported collectives all
surface here.  Results (memory analysis, cost analysis, collective
schedule, roofline terms) are written as JSON for EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  python -m repro.launch.dryrun --all --out results/
  python -m repro.launch.dryrun --arch ... --shape ... --multi-pod
"""

import argparse
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs
from repro.launch import analysis, shapes as shp
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import (batch_sharding, cache_sharding,
                                   opt_sharding, params_sharding)
from repro.models.model import LM
from repro.train import optimizer as opt_mod
from repro.train.step import make_train_step
from jax.sharding import NamedSharding, PartitionSpec as P


def _aval(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def apply_overrides(cfg, overrides: dict):
    """dataclasses.replace with dotted keys ("moe.dispatch_chunks")."""
    import dataclasses
    flat, nested = {}, {}
    for key, v in (overrides or {}).items():
        if "." in key:
            head, tail = key.split(".", 1)
            nested.setdefault(head, {})[tail] = v
        else:
            flat[key] = v
    for head, sub in nested.items():
        flat[head] = dataclasses.replace(getattr(cfg, head), **sub)
    return dataclasses.replace(cfg, **flat)


def lower_cell(arch: str, shape_name: str, multi_pod: bool = False,
               opt_overrides: dict | None = None,
               mesh_shape: tuple | None = None) -> dict:
    opt_overrides = dict(opt_overrides or {})
    wq_bits = opt_overrides.pop("wq_bits", None)
    cfg = configs.get_config(arch)
    if opt_overrides:
        cfg = apply_overrides(cfg, opt_overrides)
    if not shp.applicable(cfg, shape_name):
        return {"arch": arch, "shape": shape_name,
                "multi_pod": multi_pod, "status": "skipped",
                "reason": shp.skip_reason(cfg, shape_name)}

    if mesh_shape is not None:
        from repro.launch.mesh import make_mesh
        mesh = make_mesh(*mesh_shape)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    model = LM(cfg)
    spec = shp.input_specs(cfg, shape_name)
    kind = shp.SHAPES[shape_name]["kind"]
    t0 = time.time()

    with mesh:
        if wq_bits:
            from repro.models.qweight import quantize_tree
            params_avals = jax.eval_shape(
                lambda k: quantize_tree(model.init(k), bits=wq_bits),
                jax.random.PRNGKey(0))
        else:
            params_avals = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        p_shard = params_sharding(params_avals, mesh)
        rep = NamedSharding(mesh, P())

        if kind == "train":
            opt_cfg = opt_mod.OptConfig()
            opt_avals = jax.eval_shape(
                lambda p: opt_mod.init(p, opt_cfg), params_avals)
            o_shard = opt_sharding(opt_avals, p_shard, mesh)
            b_shard = batch_sharding(spec["batch"], mesh)
            step = make_train_step(model, opt_cfg)
            jitted = jax.jit(step,
                             in_shardings=(p_shard, o_shard, b_shard),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_avals, opt_avals, spec["batch"])

        elif kind == "prefill":
            def prefill_step(params, tokens, enc_out=None, enc_pos=None):
                return model.prefill(params, tokens=tokens,
                                     enc_out=enc_out, enc_pos=enc_pos)
            args = [params_avals, spec["tokens"]]
            in_sh = [p_shard, batch_sharding(spec["tokens"], mesh)]
            if cfg.is_encdec:
                args += [spec["enc_out"], spec["enc_pos"]]
                in_sh += [batch_sharding(spec["enc_out"], mesh),
                          batch_sharding(spec["enc_pos"], mesh)]
            jitted = jax.jit(prefill_step, in_shardings=tuple(in_sh))
            lowered = jitted.lower(*args)

        else:  # decode
            seq = shp.SHAPES[shape_name]["seq"]
            b = shp.SHAPES[shape_name]["batch"]
            cache_avals = jax.eval_shape(
                lambda: model.init_cache(b, seq))
            c_shard = cache_sharding(cache_avals, mesh)

            def serve_step(params, caches, tokens, pos,
                           enc_out=None, enc_pos=None):
                return model.decode_step(params, caches, tokens, pos,
                                         enc_out=enc_out, enc_pos=enc_pos)
            args = [params_avals, cache_avals, spec["tokens"], spec["pos"]]
            in_sh = [p_shard, c_shard,
                     batch_sharding(spec["tokens"], mesh),
                     batch_sharding(spec["pos"], mesh)]
            if cfg.is_encdec:
                args += [spec["enc_out"], spec["enc_pos"]]
                in_sh += [batch_sharding(spec["enc_out"], mesh),
                          batch_sharding(spec["enc_pos"], mesh)]
            jitted = jax.jit(serve_step, in_shardings=tuple(in_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(*args)

        compiled = lowered.compile()

    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    cost = dict(cost) if cost else {}
    text = compiled.as_text()
    coll = analysis.collective_bytes(text)
    scan_mult = analysis.scan_trip_multiplier(text)
    chips = mesh.devices.size

    mem_d = {}
    if mem is not None:
        for k in ("temp_size_in_bytes", "argument_size_in_bytes",
                  "output_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                mem_d[k] = int(v)

    res = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "status": "ok", "chips": int(chips),
        "compile_s": round(t_compile, 1),
        "params_b": int(cfg.param_count()),
        "active_params_b": int(cfg.active_param_count()),
        "hlo_flops": float(cost.get("flops", -1)),
        "hlo_bytes": float(cost.get("bytes accessed", -1)),
        "scan_trip_multiplier": float(scan_mult),
        "collective_bytes": coll.total_bytes,
        "collective_by_kind": coll.bytes_by_kind,
        "collective_ops": coll.count,
        "memory_analysis": mem_d,
    }
    res.update(analysis.analytic_terms(cfg, shape_name, chips))
    if wq_bits:
        # params move at 1 B/elt (w8) or 0.5 B/elt (w4 planes) vs bf16
        n_total = cfg.param_count()
        res["analytic_bytes"] -= 2.0 * n_total \
            - (n_total if wq_bits == 8 else n_total / 2)
        res["wq_bits"] = wq_bits
    return res


ALL_CELLS = [(a, s) for a in configs.list_archs() for s in shp.SHAPES]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--degraded", action="store_true",
                    help="elastic re-mesh after node loss: (data=8, model=16)"
                         " = half a pod; proves the re-lowered topology"
                         " compiles")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    cells = ALL_CELLS if args.all else [(args.arch, args.shape)]
    meshes = [False, True] if (args.both_meshes or args.all) \
        else [args.multi_pod]

    mesh_shape = (8, 16) if args.degraded else None
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}__{shape}__" + (
                "degraded" if args.degraded else
                ("multi" if mp else "single"))
            fp = out / f"{tag}.json"
            if fp.exists():
                print(f"[skip] {tag} (exists)")
                continue
            print(f"[dryrun] {tag} ...", flush=True)
            try:
                res = lower_cell(arch, shape, mp, mesh_shape=mesh_shape)
            except Exception as e:                    # noqa: BLE001
                res = {"arch": arch, "shape": shape, "multi_pod": mp,
                       "status": "error", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-3000:]}
            fp.write_text(json.dumps(res, indent=1))
            print(f"[done] {tag}: {res['status']}", flush=True)


if __name__ == "__main__":
    main()
