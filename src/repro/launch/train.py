"""Production training driver.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
      --data 4 --model 2 --steps 100 --batch 8 --seq 256

On a real cluster the same entry point runs under ``jax.distributed``
(one process per host); the mesh axes and sharding rules are identical.
``--smoke`` uses the reduced config.  Fault tolerance: restarts from the
latest checkpoint in --ckpt-dir automatically.
"""

from __future__ import annotations

import argparse

import jax

from repro import configs
from repro.launch.mesh import make_mesh
from repro.launch.sharding import batch_sharding, opt_sharding, \
    params_sharding
from repro.models.model import LM
from repro.train import checkpoint as ckpt_mod
from repro.train import data as data_mod
from repro.train import optimizer as opt_mod
from repro.train.runner import RunnerConfig, Trainer
from repro.train.step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b",
                    choices=configs.list_archs())
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--pod", type=int, default=1)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data-path", default=None)
    args = ap.parse_args()

    cfg = configs.get_config(args.arch, smoke=args.smoke)
    model = LM(cfg)
    mesh = make_mesh(args.data, args.model, args.pod)
    opt_cfg = opt_mod.OptConfig(lr=args.lr, warmup_steps=10,
                                total_steps=args.steps)
    dcfg = data_mod.DataConfig(
        global_batch=args.batch, seq_len=args.seq, vocab=cfg.vocab,
        path=args.data_path,
        src_len=args.seq if cfg.is_encdec else None,
        d_model=cfg.d_model if cfg.is_encdec else None)
    pipe = data_mod.Pipeline(dcfg, host_id=jax.process_index(),
                             n_hosts=jax.process_count())

    with mesh:
        params = model.init(jax.random.PRNGKey(0))
        p_shard = params_sharding(params, mesh)
        params = jax.device_put(params, p_shard)
        opt_state = opt_mod.init(params, opt_cfg)
        opt_state = jax.device_put(
            opt_state, opt_sharding(opt_state, p_shard, mesh))

        # params/opt_state are committed to their shardings by device_put;
        # batches get an explicit sharding so host arrays scatter correctly.
        step = make_train_step(model, opt_cfg, accum=args.accum)
        sample = pipe.batch(0)

        def jitted(p, o, b):
            b = jax.device_put(b, batch_sharding(b, mesh))
            return _inner(p, o, b)

        _inner = jax.jit(step, donate_argnums=(0, 1))

        start = 0
        latest = ckpt_mod.latest_step(args.ckpt_dir)
        trainer = Trainer(
            RunnerConfig(total_steps=args.steps,
                         ckpt_every=args.ckpt_every,
                         ckpt_dir=args.ckpt_dir),
            jitted, params, opt_state, pipe)
        if latest is not None:
            start = trainer._restore()
            print(f"resuming from step {start}")
        end, metrics = trainer.run(start)
        print(f"finished at step {end}: {metrics}")


if __name__ == "__main__":
    main()
