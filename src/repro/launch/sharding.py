"""Parameter / input / cache sharding rules (GSPMD via NamedSharding).

Rules are path+shape based; scanned stacks (leading layer dim) get a
leading ``None``.  Anything whose dimension doesn't divide the mesh axis
stays replicated on that dim (``resolve_spec`` guard) -- e.g. qwen2's 14
heads on a 16-way model axis.
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.common import resolve_spec

# logical specs by parameter name; "+L" variants handled by rank check
_RULES = {
    # name: (ndim-without-stack, spec)
    "embed": (2, ("model", None)),
    "head": (2, (None, "model")),
    "wq": (3, (None, "model", None)),
    "wk": (3, (None, "model", None)),
    "wv": (3, (None, "model", None)),
    "wo": (3, ("model", None, None)),
    "bq": (2, ("model", None)),
    "bk": (2, ("model", None)),
    "bv": (2, ("model", None)),
    "w_gate": (2, (None, "model")),
    "w_up": (2, (None, "model")),
    "w_down": (2, ("model", None)),
    "router": (2, (None, None)),
    "in_proj": (2, (None, "model")),
    "x_proj": (2, ("model", None)),
    "dt_w": (2, (None, "model")),
    "dt_b": (1, ("model",)),
    "A_log": (2, ("model", None)),
    "D": (1, ("model",)),
    "out_proj": (2, ("model", None)),
    "conv_w": (2, (None, "model")),
    "conv_b": (1, ("model",)),
    "wx": (2, (None, "model")),
    "wy": (2, (None, "model")),
    "wi": (2, (None, "model")),
    "wr": (2, (None, "model")),
    "lambda_p": (1, ("model",)),
    "out": (2, ("model", None)),
}
# MoE expert-stacked weights: experts on the model axis (EP)
_MOE_RULES = {
    "w_gate": (3, ("model", None, None)),
    "w_up": (3, ("model", None, None)),
    "w_down": (3, ("model", None, None)),
}


def _spec_for_path(path, leaf):
    keys = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
    name = keys[-1]
    # storage-mode quantized weights: {"q","scale"} / PackedWeight planes
    if name == "q" and len(keys) >= 2:
        name = keys[-2]
    elif name == "planes":           # (.., K//32, N): K folds the TP axis
        return (None,) * (leaf.ndim - 2) + ("model", None)
    elif name == "scale":
        return (None,) * leaf.ndim
    in_moe = "moe" in keys
    rules = _MOE_RULES if (in_moe and name in _MOE_RULES) else _RULES
    if name not in rules:
        return (None,) * leaf.ndim
    nd, spec = rules[name]
    if leaf.ndim == nd + 1:          # scanned stack
        return (None,) + tuple(spec)
    if leaf.ndim == nd:
        return tuple(spec)
    return (None,) * leaf.ndim


def params_sharding(params, mesh):
    """NamedSharding pytree for a params (or grads/opt moment) pytree."""
    def one(path, leaf):
        spec = _spec_for_path(path, leaf)
        return NamedSharding(mesh, resolve_spec(mesh, leaf.shape, spec))
    return jax.tree_util.tree_map_with_path(one, params)


def batch_sharding(batch, mesh):
    def one(leaf):
        spec = ("batch",) + (None,) * (leaf.ndim - 1)
        return NamedSharding(mesh, resolve_spec(mesh, leaf.shape, spec))
    return jax.tree.map(one, batch)


def cache_sharding(cache, mesh):
    """Decode caches: (stack, B, ...) -> batch on dim 1, heads/features on
    the model axis where divisible."""
    def one(path, leaf):
        keys = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
        name = keys[-1]
        stack = (None,) if "unit" in keys else ()   # scanned stacks only
        if name in ("k", "v"):       # (B, cap, KV, hd)
            spec = stack + ("batch", None, "model", None)
        elif name in ("k_s", "v_s"):  # (B, cap, KV) int8-cache scales
            spec = stack + ("batch", None, "model")
        elif name == "pos":          # (B, cap)
            spec = stack + ("batch", None)
        elif name == "h":            # ssm (B, di, st) | rglru (B, w)
            spec = stack + (("batch", "model", None)
                            if leaf.ndim - len(stack) == 3
                            else ("batch", "model"))
        elif name == "conv":         # (B, cw-1, di)
            spec = stack + ("batch", None, "model")
        else:
            spec = (None,) * leaf.ndim
        assert len(spec) == leaf.ndim, (keys, leaf.shape, spec)
        return NamedSharding(mesh, resolve_spec(mesh, leaf.shape, spec))
    return jax.tree_util.tree_map_with_path(one, cache)


def opt_sharding(opt_state, params_shardings, mesh):
    """Optimizer state mirrors parameter shardings; step is replicated."""
    from repro.train.optimizer import OptState
    rep = NamedSharding(mesh, P())
    return OptState(step=rep, mu=params_shardings, nu=params_shardings)
