import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: lower a cell with optimization overrides and
record roofline terms per iteration (EXPERIMENTS.md §Perf).

Usage: python -m repro.launch.perf [--cell granite_moe_train] [--all]
"""

import argparse
import json
import pathlib
import traceback

# iteration plans: (cell tag, arch, shape, [(iter name, overrides), ...])
PLANS = {
    "granite_moe_train": (
        "granite-moe-3b-a800m", "train_4k", [
            ("it0_baseline", {}),
            ("it1_chunked_dispatch", {"moe.dispatch_chunks": 32}),
            ("it2_chunked_cf1", {"moe.dispatch_chunks": 32,
                                 "moe.capacity_factor": 1.0}),
        ]),
    "granite_moe_decode": (
        "granite-moe-3b-a800m", "decode_32k", [
            ("it0_baseline", {}),
            ("it1_kv_int8", {"kv_quant_bits": 8}),
            ("it2_kv_int8_w8", {"kv_quant_bits": 8, "wq_bits": 8}),
            ("it3_kv_int8_w8_chunked", {"kv_quant_bits": 8, "wq_bits": 8,
                                        "moe.dispatch_chunks": 8}),
        ]),
    "chameleon_decode": (
        "chameleon-34b", "decode_32k", [
            ("it0_baseline", {}),
            ("it1_kv_int8", {"kv_quant_bits": 8}),
            ("it2_kv_int8_w8", {"kv_quant_bits": 8, "wq_bits": 8}),
            ("it3_kv_int8_w4planes", {"kv_quant_bits": 8, "wq_bits": 4}),
            ("it4_kv_int4_w4planes", {"kv_quant_bits": 4, "wq_bits": 4}),
        ]),
    # compute-bound cell: remat-policy trade (recompute FLOPs vs memory)
    "chameleon_train": (
        "chameleon-34b", "train_4k", [
            ("it0_baseline_full_remat", {}),
            ("it1_dots_remat", {"remat_policy": "dots"}),
            ("it2_no_remat", {"remat_policy": "none"}),
        ]),
    # bonus: same dispatch fix on the other MoE cell
    "mixtral_prefill": (
        "mixtral-8x7b", "prefill_32k", [
            ("it0_baseline", {}),
            ("it1_chunked_dispatch", {"moe.dispatch_chunks": 32}),
        ]),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=sorted(PLANS), default=None)
    ap.add_argument("--out", default="results/perf")
    args = ap.parse_args()

    from repro.launch.dryrun import lower_cell
    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    cells = [args.cell] if args.cell else sorted(PLANS)
    for cell in cells:
        arch, shape, iters = PLANS[cell]
        for name, overrides in iters:
            fp = out / f"{cell}__{name}.json"
            if fp.exists():
                print(f"[skip] {cell}/{name}")
                continue
            print(f"[perf] {cell}/{name} ...", flush=True)
            try:
                res = lower_cell(arch, shape, multi_pod=False,
                                 opt_overrides=overrides)
                res["iteration"] = name
                res["overrides"] = overrides
            except Exception as e:                     # noqa: BLE001
                res = {"iteration": name, "status": "error",
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-3000:]}
            fp.write_text(json.dumps(res, indent=1))
            print(f"[done] {cell}/{name}: {res['status']}", flush=True)


if __name__ == "__main__":
    main()
