"""Roofline-term extraction from compiled dry-run artifacts.

compute term    = HLO_FLOPs / (chips * 197e12)          [bf16 MXU peak]
memory term     = HLO_bytes / (chips * 819e9)           [HBM]
collective term = collective_bytes / (chips * 100e9)    [2 ICI links/axis]

``cost_analysis()`` on the CPU backend reports flops/bytes for the whole
(global) program with while-loop bodies counted once, so we scale by the
while trip counts recovered from the optimized HLO text (the loop
condition compares the induction variable against a constant).  The same
scaling applies to collective bytes parsed from ``compiled.as_text()``.
"""

from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12          # bf16 per chip (TPU v5e-class)
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 100e9               # bytes/s effective per chip (2 x ~50GB/s links)

_DTYPE_BYTES = {"pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
                "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
                "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "tf32": 4}

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+|pred)\[([0-9,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _line_max_bytes(line: str) -> int:
    return max((_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(line)),
               default=0)


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    total_bytes: float
    count: int


def parse_computations(hlo: str):
    """Split optimized HLO text into {name: [lines]} computations."""
    comps = {}
    cur = None
    for line in hlo.splitlines():
        # computation defs start at column 0:  %name (params...) -> ty {
        m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$", line)
        if m:
            cur = m.group(1)
            comps[cur] = []
        elif cur is not None:
            comps[cur].append(line)
    return comps


def while_trip_counts(hlo: str, comps: dict) -> dict:
    """body-computation name -> trip count (best effort)."""
    # find while ops: ... while(...), condition=%cond, body=%body
    trips = {}
    for line in hlo.splitlines():
        if " while(" not in line:
            continue
        mb = re.search(r"body=%?([\w\.\-]+)", line)
        mc = re.search(r"condition=%?([\w\.\-]+)", line)
        if not mb or not mc:
            continue
        body, cond = mb.group(1), mc.group(1)
        count = None
        for cl in comps.get(cond, []):
            m = re.search(r"constant\((\d+)\)", cl)
            if m:
                count = int(m.group(1))
        trips[body] = count if count else 1
    return trips


def _call_multipliers(comps: dict, trips: dict) -> dict:
    """computation -> product of enclosing while trip counts."""
    # build edges: computation -> called computations
    call_re = re.compile(
        r"(?:calls=|to_apply=|body=|condition=|branch_computations=\{)"
        r"%?([\w\.\-]+)")
    edges = {c: set() for c in comps}
    for c, lines in comps.items():
        for line in lines:
            for callee in call_re.findall(line):
                if callee in comps:
                    edges[c].add(callee)

    mult = {c: 1 for c in comps}
    # propagate from entry: iterate to fixpoint (call graph is a DAG)
    for _ in range(len(comps)):
        changed = False
        for c in comps:
            for callee in edges[c]:
                m = mult[c] * trips.get(callee, 1)
                if m > mult[callee]:
                    mult[callee] = m
                    changed = True
        if not changed:
            break
    return mult


def collective_bytes(hlo: str) -> CollectiveStats:
    comps = parse_computations(hlo)
    trips = while_trip_counts(hlo, comps)
    mult = _call_multipliers(comps, trips)

    by_kind = {k: 0.0 for k in _COLLECTIVES}
    count = 0
    for cname, lines in comps.items():
        scale = mult.get(cname, 1)
        for line in lines:
            for kind in _COLLECTIVES:
                if f" {kind}(" in line or f"{kind}-start(" in line:
                    b = _line_max_bytes(line)
                    factor = 2.0 if kind == "all-reduce" else 1.0
                    by_kind[kind] += b * factor * scale
                    count += 1
                    break
    total = sum(by_kind.values())
    return CollectiveStats(by_kind, total, count)


def scan_trip_multiplier(hlo: str) -> float:
    """Largest while trip count (≈ the layer scan) -- used to scale
    cost_analysis flops, which count while bodies once."""
    comps = parse_computations(hlo)
    trips = while_trip_counts(hlo, comps)
    return max(trips.values(), default=1)


def analytic_terms(cfg, shape_name: str, chips: int) -> dict:
    """Closed-form FLOP/byte estimates (MODEL_FLOPS = 6ND etc.).

    Used alongside cost_analysis(): the CPU backend counts while-loop
    bodies once, so the analytic numbers are the trustworthy absolute
    scale while the parsed numbers validate structure.
    """
    from repro.launch.shapes import SHAPES
    sh = SHAPES[shape_name]
    b, s = sh["batch"], sh["seq"]
    kind = sh["kind"]
    n_active = cfg.active_param_count()
    n_total = cfg.param_count()
    L, H, hd = cfg.n_layers, cfg.n_heads, cfg.hd

    attn_ctx = min(s, cfg.sliding_window or s)
    if cfg.rglru is not None:
        attn_layers = sum(1 for t in cfg.layer_types() if t == "attn")
        attn_ctx = min(s, cfg.rglru.window)
    elif cfg.ssm is not None:
        attn_layers = 0
    else:
        attn_layers = L

    if kind == "train":
        tokens = b * s
        flops = 6.0 * n_active * tokens \
            + 12.0 * attn_layers * b * s * attn_ctx * H * hd / 2
        # params+opt traffic (fwd read, bwd read, update rw) + activations
        bytes_ = (2 * n_total * 3) + (8.0 * n_total * 2) \
            + 4.0 * L * tokens * cfg.d_model * 2
    elif kind == "prefill":
        tokens = b * s
        flops = 2.0 * n_active * tokens \
            + 4.0 * attn_layers * b * s * attn_ctx * H * hd / 2
        bytes_ = 2.0 * n_total + 2.0 * L * tokens * cfg.d_model * 2
    else:  # decode: one token per sequence, full context in cache
        tokens = b
        ctx = attn_ctx
        flops = 2.0 * n_active * tokens \
            + 4.0 * attn_layers * b * ctx * H * hd
        kv_elt = {None: 2, 8: 1, 4: 0.5}[cfg.kv_quant_bits]
        kv_bytes = 2 * attn_layers * b * ctx * cfg.n_kv_heads * hd * kv_elt
        bytes_ = 2.0 * n_total + kv_bytes
    return {
        "analytic_flops": float(flops),
        "analytic_bytes": float(bytes_),
        "model_flops_6nd": float(6.0 * n_active * b * s) if kind == "train"
        else float(2.0 * n_active * (b * s if kind == "prefill" else b)),
    }


def roofline(flops: float, hbm_bytes: float, coll_bytes: float,
             chips: int) -> dict:
    t_comp = flops / (chips * PEAK_FLOPS)
    t_mem = hbm_bytes / (chips * HBM_BW)
    t_coll = coll_bytes / (chips * ICI_BW)
    dominant = max((t_comp, "compute"), (t_mem, "memory"),
                   (t_coll, "collective"))[1]
    bound = max(t_comp, t_mem, t_coll)
    return {
        "t_compute_s": t_comp, "t_memory_s": t_mem,
        "t_collective_s": t_coll, "dominant": dominant,
        "roofline_s": bound,
        "roofline_frac_compute": t_comp / bound if bound else 0.0,
    }
