"""Production mesh construction (TPU v5e pods).

Single pod = 16x16 = 256 chips, axes (data, model).
Multi-pod  = 2 pods = 512 chips, axes (pod, data, model); the "pod" axis
carries only data parallelism (gradient all-reduce over DCN/ICI), the
"model" axis never crosses pods.

Defined as functions so importing this module never touches jax device
state (dryrun.py sets XLA_FLAGS *before* any jax import).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(data: int, model: int, pod: int = 1):
    """Arbitrary mesh (tests, elastic re-mesh after node loss)."""
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def data_parallel_size(mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n
