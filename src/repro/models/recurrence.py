"""Shared recurrence machinery: causal conv + chunked linear scans.

Both Mamba's selective SSM and RecurrentGemma's RG-LRU are linear
recurrences  h_t = a_t * h_{t-1} + b_t  (elementwise).  We evaluate them
with an outer ``lax.scan`` over fixed-size time chunks (bounded
working-set -- required at 32k+ sequence lengths) and an associative
scan inside each chunk.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def causal_conv(x, w, b, state=None):
    """Depthwise causal conv.  x: (B, S, D); w: (CW, D); b: (D,).

    ``state``: (B, CW-1, D) trailing inputs from the previous step (decode);
    returns (y, new_state).
    """
    cw = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], cw - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)          # (B, S+CW-1, D)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(cw))
    new_state = xp[:, -(cw - 1):, :] if cw > 1 else state
    return y + b, new_state


def _assoc(e1, e2):
    a1, b1 = e1
    a2, b2 = e2
    return a1 * a2, a2 * b1 + b2


def chunked_linear_scan(a, b, h0, chunk: int = 256):
    """h_t = a_t * h_{t-1} + b_t  over axis 1 (time).

    a, b: (B, S, ...) same shape; h0: (B, ...).  Returns (h_all, h_last)
    with h_all: (B, S, ...).  Peak memory ~ (B, chunk, ...) per step.
    """
    B, S = a.shape[0], a.shape[1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    n = S // chunk
    ar = jnp.moveaxis(a.reshape((B, n, chunk) + a.shape[2:]), 1, 0)
    br = jnp.moveaxis(b.reshape((B, n, chunk) + b.shape[2:]), 1, 0)

    def outer(h, xs):
        ac, bc = xs                                   # (B, chunk, ...)
        pa, pb = jax.lax.associative_scan(_assoc, (ac, bc), axis=1)
        hs = pa * h[:, None] + pb                     # (B, chunk, ...)
        return hs[:, -1], hs

    h_last, hs = jax.lax.scan(outer, h0, (ar, br))
    h_all = jnp.moveaxis(hs, 0, 1).reshape((B, S) + a.shape[2:])
    return h_all, h_last


def linear_scan_step(a, b, h):
    """Single decode step of the same recurrence."""
    return a * h + b
