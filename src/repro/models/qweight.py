"""Storage-mode quantized weights for serving (the Compute RAM dual-mode
idea applied at model scale).

``quantize_tree`` converts selected weight leaves into compact storage:

* ``bits=8``: ``{"q": int8, "scale": f32[out]}``  (2x HBM reduction)
* ``bits=4``: ``{"planes": uint32[4, in//32, out], "scale": f32[out]}``
  -- true bit-plane packing, the same buffer format the Pallas
  bit-serial kernels consume (4x HBM reduction vs bf16).

``dq(leaf)`` transparently expands either form (or passes raw arrays
through) at the point of use; XLA fuses the dequant into the consuming
matmul so no expanded copy lives in HBM.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref as kref

# weights worth quantizing (2D+ matmul operands)
_QUANT_NAMES = {"wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
                "in_proj", "out_proj", "x_proj", "dt_w", "wx", "wy",
                "wi", "wr", "out", "embed", "head"}


@jax.tree_util.register_pytree_node_class
class PackedWeight:
    """Bit-plane packed weight: planes uint32 (bits, K//32, N) + scale."""

    def __init__(self, planes, scale, shape):
        self.planes = planes
        self.scale = scale
        self.shape = tuple(shape)

    def tree_flatten(self):
        return (self.planes, self.scale), self.shape

    @classmethod
    def tree_unflatten(cls, shape, leaves):
        return cls(leaves[0], leaves[1], shape)


def _quantize_leaf(w, bits: int, stacked: bool = False):
    """``stacked``: leading dim is the scan-layer axis -- every produced
    leaf keeps it so lax.scan can slice per layer."""
    wf = w.astype(jnp.float32)
    qmax = (1 << (bits - 1)) - 1
    if stacked:
        flat = wf.reshape(wf.shape[0], -1, wf.shape[-1])    # (L, K, N)
        amax = jnp.maximum(jnp.max(jnp.abs(flat), axis=1), 1e-8)
        scale = (amax / qmax).astype(jnp.float32)           # (L, N)
        q = jnp.clip(jnp.round(flat / scale[:, None, :]), -qmax - 1, qmax)
        if bits == 4 and flat.shape[1] % 32 == 0:
            planes = jax.vmap(
                lambda qq: kref.pack_bitplanes(qq.astype(jnp.int8), 4,
                                               axis=0))(q)  # (L,4,K/32,N)
            return PackedWeight(planes, scale, w.shape[1:])
        return {"q": q.astype(jnp.int8).reshape(w.shape), "scale": scale}
    flat = wf.reshape(-1, wf.shape[-1])
    amax = jnp.maximum(jnp.max(jnp.abs(flat), axis=0), 1e-8)
    scale = (amax / qmax).astype(jnp.float32)
    q = jnp.clip(jnp.round(flat / scale), -qmax - 1, qmax)
    if bits == 4 and flat.shape[0] % 32 == 0:
        planes = kref.pack_bitplanes(q.astype(jnp.int8), 4, axis=0)
        return PackedWeight(planes, scale, w.shape)
    return {"q": q.astype(jnp.int8).reshape(w.shape), "scale": scale}


def dq(leaf, dtype=jnp.bfloat16):
    """Dequantize a (possibly) quantized weight leaf."""
    if isinstance(leaf, PackedWeight):
        w = kref.unpack_bitplanes(leaf.planes, axis=0, signed=True)
        w = w.astype(jnp.float32) * leaf.scale
        return w.reshape(leaf.shape).astype(dtype)
    if isinstance(leaf, dict) and "q" in leaf:
        return (leaf["q"].astype(jnp.float32)
                * leaf["scale"]).astype(dtype)
    return leaf


def quantize_tree(params, bits: int = 8, names=None):
    """Quantize matching 2D+ weight leaves of a params pytree.

    Leaves under a scanned "unit" stack keep their leading layer axis.
    """
    names = names or _QUANT_NAMES

    def walk(tree, stacked=False):
        if isinstance(tree, dict):
            out = {}
            for k, v in tree.items():
                min_nd = 3 if stacked else 2
                if k in names and hasattr(v, "ndim") and v.ndim >= min_nd:
                    out[k] = _quantize_leaf(v, bits, stacked)
                else:
                    out[k] = walk(v, stacked or k == "unit")
            return out
        if isinstance(tree, list):
            return [walk(v, stacked) for v in tree]
        return tree

    return walk(params)


def tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree.leaves(tree) if hasattr(x, "size"))
