"""LM assembly: embeds -> scanned layer groups -> norm -> logits.

Layers are stacked per repeating unit and executed with ``jax.lax.scan``
so HLO size (and compile time) is independent of depth -- essential for
the 80-cell multi-pod dry-run.  Three execution modes:

* ``apply``       -- full-sequence forward (training, encoder)
* ``prefill``     -- full-sequence forward that also emits decode caches
* ``decode_step`` -- one token with ring-buffer KV / recurrent state

Linear layers can be routed through the PIM backend (``repro.pim``) for
quantized serving -- the paper's Compute RAM as a framework feature.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import attention as attn
from . import common, moe as moe_mod, rglru as rg, ssm as ssm_mod
from .common import dense_init, rmsnorm, shard
from .qweight import dq


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------
def mlp_init(key, cfg):
    ks = common.split_keys(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    p = {"w_up": dense_init(ks[1], (d, f)),
         "w_down": dense_init(ks[2], (f, d))}
    if cfg.mlp_variant == "swiglu":
        p["w_gate"] = dense_init(ks[0], (d, f))
    return p


def mlp_apply(params, x):
    if "w_gate" in params:
        h = jax.nn.silu(x @ dq(params["w_gate"])) * (x @ dq(params["w_up"]))
    else:
        h = jax.nn.gelu(x @ dq(params["w_up"]))
    h = shard(h, "batch", None, "model")
    return shard(h @ dq(params["w_down"]), "batch", None, None)


# ---------------------------------------------------------------------------
# Blocks (one per layer type)
# ---------------------------------------------------------------------------
def _block_init(key, cfg: ModelConfig, btype: str):
    d = cfg.d_model
    ks = common.split_keys(key, 4)
    p = {"ln1": jnp.zeros((d,), jnp.float32)}
    if btype == "attn":
        p["attn"] = attn.attn_init(ks[0], cfg)
        p["ln2"] = jnp.zeros((d,), jnp.float32)
        if cfg.moe is not None:
            p["moe"] = moe_mod.moe_init(ks[1], cfg)
        elif cfg.d_ff > 0:
            p["mlp"] = mlp_init(ks[1], cfg)
    elif btype == "xattn":       # decoder layer of an encoder-decoder
        p["attn"] = attn.attn_init(ks[0], cfg)
        p["lnx"] = jnp.zeros((d,), jnp.float32)
        p["xattn"] = attn.attn_init(ks[1], cfg, cross=True)
        p["ln2"] = jnp.zeros((d,), jnp.float32)
        p["mlp"] = mlp_init(ks[2], cfg)
    elif btype == "ssm":
        p["ssm"] = ssm_mod.ssm_init(ks[0], cfg)
    elif btype == "rec":
        p["rec"] = rg.rglru_init(ks[0], cfg)
        p["ln2"] = jnp.zeros((d,), jnp.float32)
        p["mlp"] = mlp_init(ks[1], cfg)
    else:
        raise ValueError(btype)
    return p


def _window_for(cfg, btype):
    if cfg.rglru is not None and btype == "attn":
        return cfg.rglru.window
    return cfg.sliding_window


def _ffn(params, cfg, x):
    if "moe" in params:
        y, aux = moe_mod.moe_apply(params["moe"], x, cfg)
        return y, aux
    if "mlp" in params:
        return mlp_apply(params["mlp"], x), 0.0
    return None, 0.0


def _block_apply(params, h, cfg, btype, positions, mode, cache,
                 enc_out=None, enc_pos=None, causal=True):
    """Returns (h, new_cache, aux)."""
    new_cache = {}
    aux = 0.0
    x = rmsnorm(h, params["ln1"], cfg.norm_eps)

    if btype in ("attn", "xattn"):
        window = _window_for(cfg, btype)
        if mode == "decode":
            pos = positions[:, 0]
            y, new_cache["kv"] = attn.attn_decode(
                params["attn"], x, cache["kv"], cfg, pos, window=window)
        else:
            y = attn.attn_apply(params["attn"], x, cfg, positions,
                                causal=causal, window=window)
            if mode == "prefill":
                cap = cache["kv"]["k"].shape[1]
                new_cache["kv"] = attn.prefill_kv_cache(
                    params["attn"], x, cfg, positions, cap, window=window)
        h = h + y
        if btype == "xattn":
            xx = rmsnorm(h, params["lnx"], cfg.norm_eps)
            y = attn.attn_apply(params["xattn"], xx, cfg, positions,
                                causal=False, kv_src=enc_out,
                                kv_positions=enc_pos)
            h = h + y
        f = rmsnorm(h, params["ln2"], cfg.norm_eps)
        y, aux = _ffn(params, cfg, f)
        if y is not None:
            h = h + y

    elif btype == "ssm":
        y, c = ssm_mod.ssm_apply(params["ssm"], x, cfg,
                                 cache=cache.get("ssm") if cache else None)
        if mode != "train":
            new_cache["ssm"] = c
        h = h + y

    elif btype == "rec":
        y, c = rg.rglru_apply(params["rec"], x, cfg,
                              cache=cache.get("rec") if cache else None)
        if mode != "train":
            new_cache["rec"] = c
        h = h + y
        f = rmsnorm(h, params["ln2"], cfg.norm_eps)
        y, _ = _ffn(params, cfg, f)
        if y is not None:
            h = h + y

    return h, new_cache, aux


def _block_cache(cfg, btype, batch, capacity):
    if btype in ("attn", "xattn"):
        window = _window_for(cfg, btype)
        return {"kv": attn.init_kv_cache(cfg, batch, capacity, window)}
    if btype == "ssm":
        return {"ssm": ssm_mod.ssm_init_cache(cfg, batch)}
    if btype == "rec":
        return {"rec": rg.rglru_init_cache(cfg, batch)}
    raise ValueError(btype)


# ---------------------------------------------------------------------------
# The model
# ---------------------------------------------------------------------------
class LM:
    """Decoder-only LM (also hosts the encoder stack for enc-dec)."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.unit, self.n_units, self.rest = cfg.scan_plan()
        if cfg.is_encdec:
            # decoder layers are xattn; encoder handled separately
            self.unit, self.n_units, self.rest = ["xattn"], cfg.n_layers, []

    # -- init ---------------------------------------------------------------
    def init(self, key):
        cfg = self.cfg
        k_embed, k_units, k_rest, k_head, k_enc = jax.random.split(key, 5)

        def unit_init(k):
            kk = common.split_keys(k, len(self.unit))
            return {f"b{i}": _block_init(kk[i], cfg, t)
                    for i, t in enumerate(self.unit)}

        params = {
            "embed": dense_init(k_embed, (cfg.vocab, cfg.d_model)),
            "unit": jax.vmap(unit_init)(
                jax.random.split(k_units, self.n_units)),
            "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
        }
        if self.rest:
            kk = common.split_keys(k_rest, len(self.rest))
            params["rest"] = [
                _block_init(kk[i], cfg, t) for i, t in enumerate(self.rest)]
        if not cfg.tie_embeddings:
            params["head"] = dense_init(k_head, (cfg.d_model, cfg.vocab))
        if cfg.is_encdec:
            def enc_init(k):
                return {"b0": _block_init(k, cfg, "attn")}
            params["encoder"] = {
                "unit": jax.vmap(enc_init)(
                    jax.random.split(k_enc, cfg.encoder_layers)),
                "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
            }
        return params

    # -- scanned group execution ---------------------------------------------
    def _run_unit(self, stacked, h, positions, mode, caches, unit=None,
                  enc_out=None, enc_pos=None, causal=True):
        cfg = self.cfg
        unit = unit or self.unit

        def body(carry, xs):
            hh = carry
            lp, lc = xs
            new_lc = {}
            aux = 0.0
            for i, t in enumerate(unit):
                c_i = lc[f"b{i}"] if lc is not None else None
                hh, nc, a = _block_apply(lp[f"b{i}"], hh, cfg, t, positions,
                                         mode, c_i, enc_out, enc_pos, causal)
                new_lc[f"b{i}"] = nc
                aux = aux + a
            return hh, (new_lc, aux)

        if mode == "train" and cfg.remat_policy != "none":
            # remat each scanned layer: activation memory stays O(L * B*S*d)
            # carries.  "full" recomputes everything in backward; "dots"
            # saves matmul outputs (less recompute FLOPs, more memory).
            if cfg.remat_policy == "dots":
                body = jax.checkpoint(
                    body, policy=jax.checkpoint_policies
                    .dots_with_no_batch_dims_saveable)
            else:
                body = jax.checkpoint(body)
        xs = (stacked, caches)
        h, (new_caches, auxs) = jax.lax.scan(body, h, xs)
        return h, new_caches, jnp.sum(auxs)

    def _embed(self, params, tokens=None, embeds=None):
        if embeds is not None:
            return embeds
        e = jnp.take(dq(params["embed"]), tokens, axis=0).astype(jnp.bfloat16)
        return shard(e, "batch", None, None)

    def _head(self, params, h):
        h = rmsnorm(h, params["final_norm"], self.cfg.norm_eps)
        w = (dq(params["embed"]).T if self.cfg.tie_embeddings
             else dq(params["head"]))
        logits = h @ w.astype(h.dtype)
        return shard(logits, "batch", None, "model")

    def encode(self, params, embeds, positions):
        """Bidirectional encoder stack (enc-dec archs)."""
        enc = params["encoder"]
        h = embeds

        def body(hh, lp):
            hh, _, _ = _block_apply(lp["b0"], hh, self.cfg, "attn",
                                    positions, "train", None, causal=False)
            return hh, None

        h, _ = jax.lax.scan(jax.checkpoint(body), h, enc["unit"])
        return rmsnorm(h, enc["final_norm"], self.cfg.norm_eps)

    # -- public entry points --------------------------------------------------
    def _forward(self, params, tokens, embeds, positions, mode, caches,
                 enc_out=None, enc_pos=None):
        cfg = self.cfg
        b = (tokens if tokens is not None else embeds).shape[0]
        s = (tokens if tokens is not None else embeds).shape[1]
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32),
                                         (b, s))
        h = self._embed(params, tokens, embeds)
        unit_caches = caches["unit"] if caches is not None else None
        h, new_unit_caches, aux = self._run_unit(
            params["unit"], h, positions, mode, unit_caches,
            enc_out=enc_out, enc_pos=enc_pos)
        new_rest = []
        if self.rest:
            for i, t in enumerate(self.rest):
                c_i = caches["rest"][i] if caches is not None else None
                h, nc, a = _block_apply(params["rest"][i], h, cfg, t,
                                        positions, mode, c_i,
                                        enc_out, enc_pos)
                new_rest.append(nc)
                aux = aux + a
        logits = self._head(params, h)
        new_caches = ({"unit": new_unit_caches, "rest": new_rest}
                      if mode != "train" else None)
        return logits, new_caches, aux

    def apply(self, params, tokens=None, embeds=None, positions=None,
              enc_out=None, enc_pos=None):
        logits, _, aux = self._forward(params, tokens, embeds, positions,
                                       "train", None, enc_out, enc_pos)
        return logits, aux

    @property
    def prefill_pad_safe(self) -> bool:
        """True when tail-padding a prompt cannot perturb the post-prefill
        cache.  Positional KV caches only hold pad entries at positions
        the decoder overwrites (or masks) before attending, but ``ssm`` /
        ``rec`` layers fold every pad token into their recurrent state --
        the serve engine's power-of-two prompt bucketing checks this
        before padding."""
        return not any(t in ("ssm", "rec")
                       for t in (*self.unit, *self.rest))

    def init_cache(self, batch: int, capacity: int):
        cfg = self.cfg

        def one_unit(_):
            return {f"b{i}": _block_cache(cfg, t, batch, capacity)
                    for i, t in enumerate(self.unit)}

        unit_cache = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (self.n_units,) + x.shape).copy()
            if self.n_units > 1 else x[None],
            one_unit(None))
        rest = [ _block_cache(cfg, t, batch, capacity) for t in self.rest ]
        return {"unit": unit_cache, "rest": rest}

    def prefill(self, params, tokens=None, embeds=None, capacity=None,
                enc_out=None, enc_pos=None):
        s = (tokens if tokens is not None else embeds).shape[1]
        b = (tokens if tokens is not None else embeds).shape[0]
        caches = self.init_cache(b, capacity or s)
        logits, caches, _ = self._forward(params, tokens, embeds, None,
                                          "prefill", caches,
                                          enc_out, enc_pos)
        return logits, caches

    def decode_step(self, params, caches, tokens, pos,
                    enc_out=None, enc_pos=None):
        """tokens: (B, 1); pos: (B,) int32."""
        positions = pos[:, None]
        logits, new_caches, _ = self._forward(
            params, tokens, None, positions, "decode", caches,
            enc_out, enc_pos)
        return logits, new_caches

    # -- loss -----------------------------------------------------------------
    def loss(self, params, batch):
        """Next-token cross entropy (+ MoE aux)."""
        tokens = batch["tokens"]
        enc_out = enc_pos = None
        if self.cfg.is_encdec:
            b, ss = batch["src_embeds"].shape[:2]
            enc_pos = jnp.broadcast_to(
                jnp.arange(ss, dtype=jnp.int32), (b, ss))
            enc_out = self.encode(params, batch["src_embeds"], enc_pos)
        embeds = batch.get("embeds")
        logits, aux = self.apply(params, tokens=tokens, embeds=embeds,
                                 enc_out=enc_out, enc_pos=enc_pos)
        targets = tokens[:, 1:]
        lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), -1)
        nll = -jnp.take_along_axis(lp, targets[..., None], -1)[..., 0]
        return jnp.mean(nll) + 0.01 * aux
