"""RG-LRU recurrent block (RecurrentGemma / Griffin)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common
from .common import dense_init, shard
from .qweight import dq
from .recurrence import causal_conv, chunked_linear_scan, linear_scan_step

_C = 8.0   # Griffin's fixed scaling constant in a_t = exp(-c*softplus(L)*r)


def rglru_init(key, cfg) -> dict:
    d = cfg.d_model
    w = cfg.rglru.lru_width or d
    cw = cfg.rglru.conv_width
    ks = common.split_keys(key, 6)
    return {
        "wx": dense_init(ks[0], (d, w)),
        "wy": dense_init(ks[1], (d, w)),
        "conv_w": dense_init(ks[2], (cw, w), dtype=jnp.bfloat16),
        "conv_b": jnp.zeros((w,), jnp.bfloat16),
        "wi": dense_init(ks[3], (w, w)),
        "wr": dense_init(ks[4], (w, w)),
        "lambda_p": jnp.full((w,), 1.0, jnp.float32),
        "out": dense_init(ks[5], (w, d)),
    }


def rglru_apply(params, x, cfg, *, cache=None, chunk: int = 256):
    """x: (B, S, d); cache: {"conv": (B,CW-1,w), "h": (B,w)} or None."""
    xb = x @ dq(params["wx"])
    xb = shard(xb, "batch", None, "model")
    conv_state = cache["conv"] if cache else None
    xb, new_conv = causal_conv(xb, params["conv_w"], params["conv_b"],
                               conv_state)

    i_g = jax.nn.sigmoid(xb @ dq(params["wi"])).astype(jnp.float32)
    r_g = jax.nn.sigmoid(xb @ dq(params["wr"])).astype(jnp.float32)
    log_a = -_C * jax.nn.softplus(params["lambda_p"]) * r_g
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) \
        * (i_g * xb.astype(jnp.float32))

    h0 = cache["h"] if cache else jnp.zeros(
        (x.shape[0], xb.shape[-1]), jnp.float32)
    if x.shape[1] == 1:                                  # decode
        h = linear_scan_step(a[:, 0], gated[:, 0], h0)
        hs = h[:, None]
    else:
        hs, h = chunked_linear_scan(a, gated, h0, chunk=chunk)

    y = hs.astype(x.dtype) * jax.nn.gelu(x @ dq(params["wy"]))
    out = y @ dq(params["out"])
    out = shard(out, "batch", None, None)
    return out, {"conv": new_conv, "h": h}


def rglru_init_cache(cfg, batch: int) -> dict:
    w = cfg.rglru.lru_width or cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.rglru.conv_width - 1, w), jnp.bfloat16),
        "h": jnp.zeros((batch, w), jnp.float32),
    }
