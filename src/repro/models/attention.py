"""Attention: GQA / MQA / sliding-window / cross, with chunked
online-softmax (memory-safe at 32k+ contexts) and ring-buffer KV caches.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common
from .common import dense_init, rope, shard
from .qweight import dq

NEG_INF = -1e30


def attn_init(key, cfg, cross: bool = False) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = common.split_keys(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, H, hd)),
        "wk": dense_init(ks[1], (d, KV, hd)),
        "wv": dense_init(ks[2], (d, KV, hd)),
        "wo": dense_init(ks[3], (H, hd, d), in_axis=0),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((H, hd), jnp.bfloat16)
        p["bk"] = jnp.zeros((KV, hd), jnp.bfloat16)
        p["bv"] = jnp.zeros((KV, hd), jnp.bfloat16)
    return p


def _qkv(params, x, kv_src, cfg, positions, kv_positions, use_rope=True):
    q = jnp.einsum("bsd,dhk->bshk", x, dq(params["wq"]))
    k = jnp.einsum("bsd,dhk->bshk", kv_src, dq(params["wk"]))
    v = jnp.einsum("bsd,dhk->bshk", kv_src, dq(params["wv"]))
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, kv_positions, cfg.rope_theta)
    return q, k, v


def _repeat_kv(k, n_heads):
    """(B, S, KV, hd) -> (B, S, H, hd) by repeating each group."""
    g = n_heads // k.shape[2]
    return jnp.repeat(k, g, axis=2) if g > 1 else k


def chunked_attention(q, k, v, pos_q, pos_k, *, causal: bool,
                      window=None, chunk: int = 1024):
    """Online-softmax attention, scanning over KV chunks.

    q: (B, Sq, H, hd);  k, v: (B, Sk, H, hd) (KV already repeated);
    pos_q: (B, Sq), pos_k: (B, Sk) int32 (-1 = invalid key slot).
    Working set per step is O(Sq * chunk), never O(Sk^2).
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    chunk = min(chunk, sk)
    assert sk % chunk == 0, (sk, chunk)
    n = sk // chunk
    scale = hd ** -0.5

    qf = q.astype(jnp.float32) * scale
    ks = jnp.moveaxis(k.reshape(b, n, chunk, h, hd), 1, 0)
    vs = jnp.moveaxis(v.reshape(b, n, chunk, h, hd), 1, 0)
    ps = jnp.moveaxis(pos_k.reshape(b, n, chunk), 1, 0)

    def body(carry, xs):
        m, l, acc = carry
        kc, vc, pc = xs
        s = jnp.einsum("bqhd,bchd->bqhc", qf, kc.astype(jnp.float32))
        valid = (pc >= 0)[:, None, :]
        if causal:
            valid = valid & (pc[:, None, :] <= pos_q[:, :, None])
        if window is not None:
            valid = valid & (pc[:, None, :] > pos_q[:, :, None] - window)
        s = jnp.where(valid[:, :, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bqhc,bchd->bqhd", p, vc.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, sq, h), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, h), jnp.float32)
    a0 = jnp.zeros((b, sq, h, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (ks, vs, ps))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out


def attn_apply(params, x, cfg, positions, *, causal=True, window=None,
               kv_src=None, kv_positions=None, chunk=1024):
    """Full-sequence attention (training / prefill / encoder / cross)."""
    b, s, d = x.shape
    cross = kv_src is not None
    src = kv_src if cross else x
    kpos = kv_positions if cross else positions
    q, k, v = _qkv(params, x, src, cfg, positions, kpos,
                   use_rope=not cross)
    q = shard(q, "batch", None, "model", None)
    k = _repeat_kv(k, cfg.n_heads)
    v = _repeat_kv(v, cfg.n_heads)
    k = shard(k, "batch", None, "model", None)
    v = shard(v, "batch", None, "model", None)
    out = chunked_attention(q, k, v, positions, kpos,
                            causal=causal and not cross,
                            window=window, chunk=chunk)
    out = out.astype(x.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, dq(params["wo"]))
    return shard(y, "batch", None, None)


# ---------------------------------------------------------------------------
# Decode path: ring-buffer KV cache (optionally int8-quantized "storage
# mode", the Compute RAM dual-mode idea applied to the cache: halves the
# dominant HBM term of decode -- see EXPERIMENTS.md §Perf)
# ---------------------------------------------------------------------------
def init_kv_cache(cfg, batch: int, capacity: int, window=None) -> dict:
    cap = capacity if window is None else min(capacity, window)
    shape = (batch, cap, cfg.n_kv_heads, cfg.hd)
    if cfg.kv_quant_bits == 4:
        # two nibbles per byte along hd: 4x smaller than bf16
        assert cfg.hd % 2 == 0
        pshape = shape[:3] + (cfg.hd // 2,)
        return {
            "k": jnp.zeros(pshape, jnp.uint8),
            "v": jnp.zeros(pshape, jnp.uint8),
            "k_s": jnp.zeros(shape[:3], jnp.bfloat16),
            "v_s": jnp.zeros(shape[:3], jnp.bfloat16),
            "pos": jnp.full((batch, cap), -1, jnp.int32),
        }
    if cfg.kv_quant_bits:
        return {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_s": jnp.zeros(shape[:3], jnp.bfloat16),
            "v_s": jnp.zeros(shape[:3], jnp.bfloat16),
            "pos": jnp.full((batch, cap), -1, jnp.int32),
        }
    return {
        "k": jnp.zeros(shape, jnp.bfloat16),
        "v": jnp.zeros(shape, jnp.bfloat16),
        "pos": jnp.full((batch, cap), -1, jnp.int32),
    }


def _kv_quantize(x, bits: int):
    """x: (..., hd) -> (int8 / nibble-packed uint8 values, bf16 scale)."""
    qmax = (1 << (bits - 1)) - 1
    amax = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1),
                       1e-6)
    scale = amax / qmax
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -qmax - 1, qmax).astype(jnp.int32)
    if bits == 4:
        u = (q & 0xF).astype(jnp.uint8)                 # two's complement
        lo, hi = u[..., 0::2], u[..., 1::2]
        return (lo | (hi << 4)).astype(jnp.uint8), scale.astype(jnp.bfloat16)
    return q.astype(jnp.int8), scale.astype(jnp.bfloat16)


def _nib_signed(u):
    s = u.astype(jnp.int32)
    return jnp.where(s >= 8, s - 16, s)


def _kv_read(cache, name):
    x = cache[name]
    if x.dtype == jnp.uint8:                            # 4-bit packed
        lo = _nib_signed(x & 0xF)
        hi = _nib_signed(x >> 4)
        vals = jnp.stack([lo, hi], axis=-1).reshape(x.shape[:-1] +
                                                    (x.shape[-1] * 2,))
        return vals.astype(jnp.float32) \
            * cache[name + "_s"].astype(jnp.float32)[..., None]
    if x.dtype == jnp.int8:
        return x.astype(jnp.float32) \
            * cache[name + "_s"].astype(jnp.float32)[..., None]
    return x.astype(jnp.float32)


def attn_decode(params, x, cache, cfg, pos, *, window=None):
    """One-token decode.  x: (B, 1, d); pos: (B,) int32 current position."""
    b, s, d = x.shape
    assert s == 1
    positions = pos[:, None]
    q, k, v = _qkv(params, x, x, cfg, positions, positions)

    cap = cache["k"].shape[1]
    slot = pos % cap                                   # ring buffer
    bidx = jnp.arange(b)
    if cfg.kv_quant_bits:
        kq, ks_ = _kv_quantize(k[:, 0], cfg.kv_quant_bits)
        vq, vs_ = _kv_quantize(v[:, 0], cfg.kv_quant_bits)
        new_cache = {
            "k": cache["k"].at[bidx, slot].set(kq),
            "v": cache["v"].at[bidx, slot].set(vq),
            "k_s": cache["k_s"].at[bidx, slot].set(ks_),
            "v_s": cache["v_s"].at[bidx, slot].set(vs_),
            "pos": cache["pos"].at[bidx, slot].set(pos),
        }
    else:
        new_cache = {
            "k": cache["k"].at[bidx, slot].set(k[:, 0].astype(jnp.bfloat16)),
            "v": cache["v"].at[bidx, slot].set(v[:, 0].astype(jnp.bfloat16)),
            "pos": cache["pos"].at[bidx, slot].set(pos),
        }
    ck = _kv_read(new_cache, "k")
    cv = _kv_read(new_cache, "v")
    cp = new_cache["pos"]

    scale = cfg.hd ** -0.5
    qh = shard(q.astype(jnp.float32) * scale, "batch", None, "model", None)
    kh = _repeat_kv(ck, cfg.n_heads)
    vh = _repeat_kv(cv, cfg.n_heads)
    kh = shard(kh, "batch", None, "model", None)
    vh = shard(vh, "batch", None, "model", None)
    s_ = jnp.einsum("bqhd,bchd->bqhc", qh, kh)
    valid = (cp >= 0)[:, None, :] & (cp[:, None, :] <= positions[:, :, None])
    if window is not None:
        valid = valid & (cp[:, None, :] > positions[:, :, None] - window)
    s_ = jnp.where(valid[:, :, None, :], s_, NEG_INF)
    p = jax.nn.softmax(s_, axis=-1)
    out = jnp.einsum("bqhc,bchd->bqhd", p, vh).astype(x.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, dq(params["wo"]))
    return y, new_cache


def prefill_kv_cache(params, x, cfg, positions, capacity, window=None):
    """Build a cache from a prefilled sequence (keys of the last `cap`)."""
    b, s, d = x.shape
    _, k, v = _qkv(params, x, x, cfg, positions, positions)
    cap = capacity if window is None else min(capacity, window)
    if s >= cap:
        ks, vs, ps = k[:, -cap:], v[:, -cap:], positions[:, -cap:]
    else:
        pad = cap - s
        ks = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        ps = jnp.pad(positions, ((0, 0), (0, pad)), constant_values=-1)
    # ring-consistent placement: slot = pos % cap
    slot = jnp.where(ps >= 0, ps % cap, jnp.arange(cap)[None, :] % cap)
    bidx = jnp.arange(b)[:, None]
    cache = init_kv_cache(cfg, b, cap)
    if cfg.kv_quant_bits:
        kq, ks_ = _kv_quantize(ks, cfg.kv_quant_bits)
        vq, vs_ = _kv_quantize(vs, cfg.kv_quant_bits)
        return {
            "k": cache["k"].at[bidx, slot].set(kq),
            "v": cache["v"].at[bidx, slot].set(vq),
            "k_s": cache["k_s"].at[bidx, slot].set(ks_),
            "v_s": cache["v_s"].at[bidx, slot].set(vs_),
            "pos": cache["pos"].at[bidx, slot].set(ps),
        }
    return {
        "k": cache["k"].at[bidx, slot].set(ks.astype(jnp.bfloat16)),
        "v": cache["v"].at[bidx, slot].set(vs.astype(jnp.bfloat16)),
        "pos": cache["pos"].at[bidx, slot].set(ps),
    }
