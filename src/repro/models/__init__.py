"""10-architecture model zoo (dense / GQA / SWA / MoE / Mamba /
RG-LRU / enc-dec) with scan-over-layers and storage-mode quantization."""
