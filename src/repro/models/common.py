"""Shared model utilities: sharding constraints, norms, rope, init."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# Sharding: specs are written with logical axes; `shard()` silently drops
# axes the active mesh doesn't have ("pod" on single-pod runs) and is a
# no-op outside a mesh context (unit tests on one device).
# ---------------------------------------------------------------------------
def _active_mesh():
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        if m.empty:
            return None
        return m
    except Exception:
        return None


def batch_axes(mesh=None):
    mesh = mesh if mesh is not None else _active_mesh()
    if mesh is None:
        return ("data",)
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh, s):
    if s is None:
        return 1
    if isinstance(s, tuple):
        out = 1
        for a in s:
            out *= mesh.shape[a]
        return out
    return mesh.shape[s]


def resolve_spec(mesh, shape, spec):
    """Resolve a logical spec against a mesh *and* a shape: logical axes
    missing from the mesh or not dividing the dimension are dropped."""
    names = set(mesh.axis_names)

    def fix(s, dim):
        if s == "batch":
            s = tuple(a for a in ("pod", "data") if a in names)
            if not s:
                return None
            s = s if len(s) > 1 else s[0]
        elif isinstance(s, str):
            s = s if s in names else None
        elif isinstance(s, tuple):
            t = tuple(a for a in s if a in names)
            s = t if t else None
        if s is None:
            return None
        if dim is not None and dim % _axis_size(mesh, s) != 0:
            return None                      # uneven: leave replicated
        return s

    dims = list(shape) + [None] * (len(spec) - len(shape))
    return P(*[fix(s, d) for s, d in zip(spec, dims)])


def shard(x, *spec):
    """with_sharding_constraint with mesh/shape-aware axis filtering.

    spec entries: None, "model", "batch" (expands to present pod/data axes),
    or explicit axis names / tuples.  Axes that don't divide the dimension
    (e.g. 14 heads on a 16-way model axis) are silently dropped.
    """
    mesh = _active_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, resolve_spec(mesh, x.shape, spec))


def spec_for(mesh, *spec) -> P:
    """Resolve a logical spec to a concrete PartitionSpec for ``mesh``."""
    names = set(mesh.axis_names)

    def fix(s):
        if s == "batch":
            ax = tuple(a for a in ("pod", "data") if a in names)
            return ax if len(ax) > 1 else (ax[0] if ax else None)
        if isinstance(s, str):
            return s if s in names else None
        if isinstance(s, tuple):
            t = tuple(a for a in s if a in names)
            return t if t else None
        return s

    return P(*[fix(s) for s in spec])


# ---------------------------------------------------------------------------
# Layers
# ---------------------------------------------------------------------------
def rmsnorm(x, w, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    normed = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (normed * (1.0 + w)).astype(x.dtype)


def rope(q, positions, theta):
    """Rotary embedding.  q: (..., S, H, hd); positions: (..., S)."""
    hd = q.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.log(theta) * jnp.arange(half, dtype=jnp.float32)
                    / half)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..,S,half)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    q1, q2 = q[..., :half], q[..., half:]
    out = jnp.concatenate([q1 * cos - q2 * sin, q2 * cos + q1 * sin], -1)
    return out.astype(q.dtype)


def dense_init(key, shape, in_axis=0, dtype=jnp.bfloat16):
    fan_in = shape[in_axis]
    return (jax.random.normal(key, shape, jnp.float32) * fan_in ** -0.5
            ).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))
