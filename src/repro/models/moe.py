"""Mixture-of-Experts FFN with sort-based capacity dispatch.

Expert-parallel friendly: the expert buffer ``(E, C, d)`` is sharded on
the "model" axis; the token->expert resharding lowers to all-to-all-like
collectives under pjit.  Dispatch is sort-based (argsort by expert id +
within-expert rank via an exclusive running count), which avoids the
O(T*E*C) one-hot dispatch tensors of the Switch formulation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common
from .common import dense_init, shard
from .qweight import dq


def moe_init(key, cfg) -> dict:
    spec = cfg.moe
    d, e, f = cfg.d_model, spec.num_experts, spec.d_ff
    ks = common.split_keys(key, 4)
    return {
        "router": dense_init(ks[0], (d, e), dtype=jnp.float32),
        "w_gate": dense_init(ks[1], (e, d, f)),
        "w_up": dense_init(ks[2], (e, d, f)),
        "w_down": dense_init(ks[3], (e, f, d), in_axis=1),
    }


def _capacity(tokens: int, spec) -> int:
    c = int(tokens * spec.top_k * spec.capacity_factor / spec.num_experts)
    return max(spec.top_k, -(-c // 8) * 8)


def _chunks_for(t: int, requested: int) -> int:
    c = max(1, min(requested, t))
    while t % c:
        c -= 1
    return c


def moe_apply(params, x, cfg):
    """x: (B, S, d) -> (B, S, d); load-balance aux loss returned too."""
    spec = cfg.moe
    b, s, d = x.shape
    t = b * s
    e, k = spec.num_experts, spec.top_k
    X = _chunks_for(t, spec.dispatch_chunks)
    tc = t // X
    cap = _capacity(tc, spec)
    xf = x.reshape(X, tc, d)
    xf = shard(xf, "batch", None, None)

    logits = xf.astype(jnp.float32) @ dq(params["router"], jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                     # (X, Tc, E)
    gate, eidx = jax.lax.top_k(probs, k)                        # (X, Tc, k)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    # aux load-balancing loss (Switch-style, over all tokens)
    density = jnp.mean(jax.nn.one_hot(eidx[..., 0], e, dtype=jnp.float32),
                       (0, 1))
    density_prob = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(density * density_prob)

    # ---- per-chunk sort-based dispatch (local capacity) -------------------
    def dispatch(xc, gate_c, eidx_c):
        fe = eidx_c.reshape(-1)                                 # (Tc*k,)
        fg = gate_c.reshape(-1)
        tok = jnp.repeat(jnp.arange(tc), k)
        order = jnp.argsort(fe)
        se, stok = fe[order], tok[order]
        counts = jnp.bincount(fe, length=e)
        starts = jnp.cumsum(counts) - counts
        rank = jnp.arange(tc * k) - starts[se]
        keep = rank < cap
        slot = se * cap + jnp.where(keep, rank, 0)
        buf = jnp.zeros((e * cap, d), x.dtype)
        buf = buf.at[slot].add(jnp.where(keep[:, None], xc[stok], 0))
        return buf.reshape(e, cap, d), (order, stok, keep, slot, fg)

    buf, meta = jax.vmap(dispatch)(xf, gate, eidx)   # (X, E, C, d)
    buf = shard(buf, "batch", "model", None, None)

    # ---- expert FFN (chunks on data axes, experts on model axis) ----------
    h = jax.nn.silu(jnp.einsum("xecd,edf->xecf", buf, dq(params["w_gate"]))) \
        * jnp.einsum("xecd,edf->xecf", buf, dq(params["w_up"]))
    y = jnp.einsum("xecf,efd->xecd", h, dq(params["w_down"]))
    y = shard(y, "batch", "model", None, None)

    # ---- per-chunk combine -------------------------------------------------
    def combine(y_c, m):
        order, stok, keep, slot, fg = m
        ye = y_c.reshape(e * cap, d)[slot]
        contrib = jnp.where(keep[:, None],
                            ye * fg[order][:, None].astype(x.dtype), 0)
        return jnp.zeros((tc, d), x.dtype).at[stok].add(contrib)

    out = jax.vmap(combine)(y, meta)
    out = shard(out, "batch", None, None)
    return out.reshape(b, s, d), aux
