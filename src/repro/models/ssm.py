"""Mamba-1 selective SSM block (falcon-mamba style, attention-free)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common
from .common import dense_init, shard
from .qweight import dq
from .recurrence import causal_conv, chunked_linear_scan, linear_scan_step


def _dims(cfg):
    di = cfg.ssm.expand * cfg.d_model
    dtr = cfg.ssm.dt_rank or -(-cfg.d_model // 16)
    return di, dtr, cfg.ssm.state_dim


def ssm_init(key, cfg) -> dict:
    d = cfg.d_model
    di, dtr, st = _dims(cfg)
    cw = cfg.ssm.conv_width
    ks = common.split_keys(key, 6)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di)),
        "conv_w": dense_init(ks[1], (cw, di), dtype=jnp.bfloat16),
        "conv_b": jnp.zeros((di,), jnp.bfloat16),
        "x_proj": dense_init(ks[2], (di, dtr + 2 * st)),
        "dt_w": dense_init(ks[3], (dtr, di)),
        "dt_b": jnp.full((di,), -4.6, jnp.float32),   # softplus^-1(0.01)
        "A_log": jnp.log(jnp.tile(
            jnp.arange(1, st + 1, dtype=jnp.float32)[None, :], (di, 1))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], (di, d)),
    }


def _ssm_inner(params, xi, dt_r, Bm, Cm, h0, chunk):
    """Selective-SSM recurrence.  xi: (B,S,di) post-conv/silu."""
    di, st = params["A_log"].shape
    dt = jax.nn.softplus(dt_r.astype(jnp.float32)
                         @ dq(params["dt_w"], jnp.float32) + params["dt_b"])  # (B,S,di)
    A = -jnp.exp(params["A_log"])                                # (di,st)
    decay = jnp.exp(dt[..., None] * A)                           # (B,S,di,st)
    bx = (dt * xi.astype(jnp.float32))[..., None] * Bm[:, :, None, :]
    if xi.shape[1] == 1:                                         # decode
        h = linear_scan_step(decay[:, 0], bx[:, 0], h0)
        hs = h[:, None]
    else:
        hs, h = chunked_linear_scan(decay, bx, h0, chunk=chunk)
    y = jnp.einsum("bsdn,bsn->bsd", hs, Cm)                      # (B,S,di)
    y = y + params["D"] * xi.astype(jnp.float32)
    return y, h


def ssm_apply(params, x, cfg, *, cache=None, chunk: int = 256):
    """x: (B, S, d).  cache: {"conv": (B,CW-1,di), "h": (B,di,st)} or None."""
    di, dtr, st = _dims(cfg)
    u = x @ dq(params["in_proj"])
    xi, z = jnp.split(u, 2, axis=-1)
    xi = shard(xi, "batch", None, "model")
    conv_state = cache["conv"] if cache else None
    xi, new_conv = causal_conv(xi, params["conv_w"], params["conv_b"],
                               conv_state)
    xi = jax.nn.silu(xi)

    dbc = xi @ dq(params["x_proj"])
    dt_r = dbc[..., :dtr]
    Bm = dbc[..., dtr:dtr + st].astype(jnp.float32)
    Cm = dbc[..., dtr + st:].astype(jnp.float32)

    h0 = cache["h"] if cache else jnp.zeros(
        (x.shape[0], di, st), jnp.float32)
    y, h = _ssm_inner(params, xi, dt_r, Bm, Cm, h0, chunk)

    out = (y.astype(x.dtype) * jax.nn.silu(z)) @ dq(params["out_proj"])
    out = shard(out, "batch", None, None)
    new_cache = {"conv": new_conv, "h": h}
    return out, new_cache


def ssm_init_cache(cfg, batch: int) -> dict:
    di, dtr, st = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm.conv_width - 1, di), jnp.bfloat16),
        "h": jnp.zeros((batch, di, st), jnp.float32),
    }
