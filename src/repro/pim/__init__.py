from .linear import PimConfig, linear_init, linear_apply, pack_linear  # noqa
from .cram import cram_dot, cram_matmul  # noqa
