from .linear import (PimConfig, linear_init, linear_apply,  # noqa
                     fused_linear_apply, pack_linear)
from .cram import cram_dot, cram_matmul, idot_geometry  # noqa
from .fabric import (FabricConfig, FabricLinearProbe, FabricProgram,  # noqa
                     GemmSpec, Schedule, SearchResult, TileLoad,
                     fabric_attention_scores, fabric_fused_matmul,
                     fabric_matmul, residency_stats, schedule_gemm,
                     schedule_program, search_program, search_schedule)
