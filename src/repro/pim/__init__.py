from .linear import PimConfig, linear_init, linear_apply, pack_linear  # noqa
from .cram import cram_dot, cram_matmul, idot_geometry  # noqa
from .fabric import (FabricConfig, FabricLinearProbe, Schedule,  # noqa
                     SearchResult, TileLoad, fabric_attention_scores,
                     fabric_matmul, schedule_gemm, search_schedule)
