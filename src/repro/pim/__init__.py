from .linear import PimConfig, linear_init, linear_apply, pack_linear  # noqa
