from .linear import (PimConfig, linear_init, linear_apply,  # noqa
                     fused_linear_apply, pack_linear)
from .cram import (DTYPES, DType, cram_dot, cram_fdot, cram_fmatmul,  # noqa
                   cram_matmul, fdot_geometry, idot_geometry,
                   resolve_dtype)
from .fabric import (FabricConfig, FabricLinearProbe, FabricProgram,  # noqa
                     GemmSpec, Schedule, SearchResult, TileLoad,
                     fabric_attention_scores, fabric_fused_matmul,
                     fabric_matmul, residency_stats, schedule_gemm,
                     schedule_program, search_program, search_schedule)
