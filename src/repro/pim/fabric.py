"""Fabric scheduler: tile GEMM/attention across a Compute RAM block grid.

The paper's fabric-level claim (§IV, §V): an FPGA carries hundreds of
Compute RAM sites, each *dynamically* allocated to storage mode (a plain
BRAM holding operands) or compute mode (executing an instruction
sequence), and a DL workload is tiled across the grid.  This module is
that layer for the simulator: it turns "one block runs one program"
(:mod:`repro.pim.cram`) into "a simulated FPGA runs a matmul".

Pipeline
--------
1. :func:`schedule_gemm` builds an explicit :class:`Schedule` IR:

   * **mode map** -- each of the grid's ``n_blocks`` blocks is assigned
     ``storage`` (operand residency) or ``compute`` (paper §II dual-mode
     allocation).  Storage demand is sized from the operand footprint;
     whatever does not fit on-fabric is marked *spilled* (off-fabric
     memory, longer wires).
   * **tiling** -- K is tiled to the ``idot`` tuple capacity of the
     block geometry (:func:`repro.pim.cram.idot_geometry`, clamped so
     the int32 accumulator provably cannot overflow), N to the block's
     columns, and each output row ``m`` is one tile task.  Ragged edge
     tiles are zero-padded to the fixed tile geometry so **every round
     replays one compiled program**.
   * **rounds** -- tile tasks are packed ``n_compute`` at a time into
     :class:`Round`\\ s; one round is one ``engine.execute_blocks``
     launch.  Blocks without a task in a partial round are *not
     started* (each block has its own start line from the host FSM, so
     idle blocks burn no compute energy); the simulator still steps
     them on zeros purely as a wide-batch convenience, and their
     results are discarded.
   * **loads** -- each round carries an explicit operand-load stage
     (:class:`TileLoad`): the tiles its tasks read, where they live,
     and which blocks they fan out to.  Contiguous tasks sharing a
     weight tile coalesce into ONE broadcast load (single
     multi-destination net).  The load/compute dependency is what the
     cost model's double-buffered ``overlapped_cycles`` pipeline hides.

2. :func:`execute_schedule` runs the rounds **exactly** on the block
   simulator and accumulates per-tile accumulators into the output.  By
   default all rounds are *batched* into one compiled wide-block launch
   (rounds become extra block-columns) -- the simulator-side wall-clock
   fast path, bit-identical to the per-round loop.

3. :func:`schedule_cost` walks the same IR and prices it with
   :mod:`repro.core.costmodel` (compute-mode cycles, storage-mode row
   traffic, and block-to-block / spill wire energy for every operand
   move), returning a :class:`repro.core.costmodel.ScheduleCost` whose
   ``serial_cycles`` / ``overlapped_cycles`` pin the overlap win.

4. :func:`search_schedule` autotunes: it enumerates ``FabricConfig``
   geometries x storage/compute splits, prices every candidate through
   the same roll-up (no execution), and returns the argmin schedule --
   wired into ``PimConfig(mode="fabric", fabric_autotune=True)`` and
   the serving fabric probe.

Signed operands use the same zero-point offset algebra as
:func:`repro.pim.cram.cram_matmul` (the blocks are unsigned-only
hardware); corrections are host-side sums.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core import costmodel, engine, harness, programs
from repro.pim import cram

ACC_BITS = 32


# ---------------------------------------------------------------------------
# Config + IR
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FabricConfig:
    """A grid of Compute RAM blocks (one simulated FPGA)."""
    n_blocks: int = 8
    rows: int = 512
    cols: int = 40
    executor: str = "compiled"
    min_compute_blocks: int = 1    # never storage-starve the grid

    @property
    def block_bits(self) -> int:
        return self.rows * self.cols

    def __post_init__(self):
        if self.n_blocks < 1:
            raise ValueError("fabric needs at least one block")
        if not 1 <= self.min_compute_blocks <= self.n_blocks:
            raise ValueError("min_compute_blocks out of range")


@dataclasses.dataclass(frozen=True)
class TileTask:
    """One (output-row, K-tile, N-tile) unit of work on one compute block."""
    block: int                 # compute-block slot executing this tile
    m: int                     # output row
    k0: int
    k1: int
    n0: int
    n1: int
    x_src: int                 # storage block holding x[m, :] (-1 = spill)
    w_src: int                 # storage block holding w tile (-1 = spill)


@dataclasses.dataclass(frozen=True)
class TileLoad:
    """One operand fetch that must retire before its round's compute.

    The load stage is explicit in the IR so the cost model can price
    round *i+1*'s loads as double-buffered against round *i*'s compute
    (``ScheduleCost.overlapped_cycles``), and so consecutive tasks
    sharing a weight tile coalesce into ONE fetch broadcast to several
    destination blocks (``len(dsts) > 1``): a single multi-destination
    net, priced once in the wire-energy split.
    """
    kind: str                  # "x" (activation slice) | "w" (weight tile)
    key: Tuple[int, ...]       # ("x": (m, k0)) | ("w": (k0, n0))
    src: int                   # storage block holding the payload (-1 = spill)
    dsts: Tuple[int, ...]      # destination compute blocks (broadcast if >1)
    bits: int                  # payload bits of ONE copy


@dataclasses.dataclass(frozen=True)
class Round:
    """One lockstep ``execute_blocks`` launch over the compute blocks.

    ``loads`` is the round's operand-load stage: every tile a task reads
    is covered by exactly one load of the same round (the dependency the
    overlap model pipelines).  Broadcast groups are contiguous task runs
    sharing a weight tile.
    """
    tasks: Tuple[TileTask, ...]
    loads: Tuple[TileLoad, ...] = ()


@dataclasses.dataclass(frozen=True)
class Schedule:
    """Explicit fabric schedule for one quantized GEMM (the IR every
    later scaling PR -- sharding, async rounds, multi-backend -- builds
    on)."""
    cfg: FabricConfig
    nbits: int
    signed: bool
    M: int
    K: int
    N: int
    kt: int                              # K-tile (idot tuples per launch)
    modes: Tuple[str, ...]               # per block: "compute" | "storage"
    x_home: Tuple[int, ...]              # per output row m -> block | -1
    w_home: Dict[Tuple[int, int], int]   # (k-tile, n-tile) -> block | -1
    rounds: Tuple[Round, ...]

    @property
    def n_compute(self) -> int:
        return self.modes.count("compute")

    @property
    def n_storage(self) -> int:
        return self.modes.count("storage")

    @property
    def program(self):
        """The single idot program every round replays."""
        prog, _ = programs.idot(self.nbits, rows=self.cfg.rows,
                                tuples=self.kt)
        return prog

    @property
    def ops(self) -> int:
        """Useful MACs (zero-padding excluded)."""
        return sum((t.k1 - t.k0) * (t.n1 - t.n0)
                   for r in self.rounds for t in r.tasks)

    def describe(self) -> str:
        lines = [
            f"Schedule {self.M}x{self.K}@{self.K}x{self.N} "
            f"int{self.nbits}{'s' if self.signed else 'u'} on "
            f"{self.cfg.n_blocks} blocks "
            f"({self.n_compute} compute / {self.n_storage} storage)",
            f"  K-tile={self.kt} tuples, N-tile={self.cfg.cols} cols, "
            f"{len(self.rounds)} round(s), "
            f"{sum(len(r.tasks) for r in self.rounds)} tile task(s)",
        ]
        spills = sum(1 for t_ in self.w_home.values() if t_ < 0) \
            + sum(1 for t_ in self.x_home if t_ < 0)
        if spills:
            lines.append(f"  {spills} operand(s) spilled off-fabric")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Scheduling
# ---------------------------------------------------------------------------
def schedule_gemm(M: int, K: int, N: int, nbits: int,
                  cfg: FabricConfig = FabricConfig(),
                  signed: bool = False) -> Schedule:
    """Plan ``(M, K) @ (K, N)`` onto the block grid (no execution)."""
    if min(M, K, N) < 1:
        raise ValueError(f"degenerate GEMM {M}x{K}x{N}")
    if cram.idot_geometry(nbits, cfg.rows, ACC_BITS) < 1:
        # idot_tile clamps to >= 1, which would silently plan a program
        # that does not fit the array (accumulator + scratch + 1 tuple
        # exceed the rows); fail at schedule time instead of compile time
        raise ValueError(
            f"geometry {cfg.rows}x{cfg.cols} cannot host an idot{nbits} "
            f"program (too few rows)")
    kt = cram.idot_tile(nbits, cfg.rows, ACC_BITS)
    k_tiles = math.ceil(K / kt)
    n_tiles = math.ceil(N / cfg.cols)

    # --- mode map: size storage demand, keep >= min_compute_blocks ----------
    w_tile_bits = {}
    for ki in range(k_tiles):
        for ni in range(n_tiles):
            kw = min(K, (ki + 1) * kt) - ki * kt
            nw = min(N, (ni + 1) * cfg.cols) - ni * cfg.cols
            w_tile_bits[(ki, ni)] = kw * nw * nbits
    x_row_bits = K * nbits
    total_bits = sum(w_tile_bits.values()) + M * x_row_bits
    n_storage = min(math.ceil(total_bits / cfg.block_bits),
                    cfg.n_blocks - cfg.min_compute_blocks)
    n_storage = max(n_storage, 0)
    n_compute = cfg.n_blocks - n_storage
    modes = tuple(["storage"] * n_storage + ["compute"] * n_compute)

    # --- operand residency: first-fit into the storage blocks ---------------
    free = [cfg.block_bits] * n_storage

    def place(bits: int) -> int:
        for b in range(n_storage):
            if free[b] >= bits:
                free[b] -= bits
                return b
        return -1                                  # spill off-fabric

    w_home = {key: place(bits) for key, bits in sorted(w_tile_bits.items())}
    x_home = tuple(place(x_row_bits) for _ in range(M))

    # --- tile tasks -> lockstep rounds of n_compute ------------------------
    # (ki, ni, m) order: consecutive tasks share a weight tile, so the
    # load builder below coalesces their fetches into one broadcast.
    units = [(m, ki, ni) for ki in range(k_tiles) for ni in range(n_tiles)
             for m in range(M)]
    rounds = []
    for r0 in range(0, len(units), n_compute):
        tasks = []
        for slot, (m, ki, ni) in enumerate(units[r0:r0 + n_compute]):
            tasks.append(TileTask(
                block=n_storage + slot, m=m,
                k0=ki * kt, k1=min(K, (ki + 1) * kt),
                n0=ni * cfg.cols, n1=min(N, (ni + 1) * cfg.cols),
                x_src=x_home[m], w_src=w_home[(ki, ni)]))
        rounds.append(Round(tasks=tuple(tasks),
                            loads=_round_loads(tasks, nbits)))

    return Schedule(cfg=cfg, nbits=nbits, signed=signed, M=M, K=K, N=N,
                    kt=kt, modes=modes, x_home=x_home, w_home=w_home,
                    rounds=tuple(rounds))


def _round_loads(tasks, nbits: int) -> Tuple[TileLoad, ...]:
    """Build one round's load stage, coalescing broadcastable fetches.

    A *contiguous* run of tasks reading the same weight tile (the
    (ki, ni, m) unit order makes sharers adjacent) becomes one
    :class:`TileLoad` with several destinations -- the payload crosses
    the fabric once on a multi-destination net.  Activation slices get
    the same treatment, keyed ``(m, k0)`` -- the K-slice matters: two
    tasks reading different K-ranges of one row fetch different
    payloads.  Runs coalesce mainly at ``M == 1`` (one slice feeding
    several n-tiles); elsewhere ``m`` varies fastest, so runs are
    singletons.
    """
    loads: list = []
    last = {}                      # kind -> index of most recent load
    for t in tasks:
        kw = t.k1 - t.k0
        for kind, key, src, bits in (
                ("x", (t.m, t.k0), t.x_src, kw * nbits),
                ("w", (t.k0, t.n0), t.w_src, kw * (t.n1 - t.n0) * nbits)):
            i = last.get(kind)
            if i is not None and loads[i].key == key:
                loads[i] = dataclasses.replace(
                    loads[i], dsts=loads[i].dsts + (t.block,))
            else:
                last[kind] = len(loads)
                loads.append(TileLoad(kind=kind, key=key, src=src,
                                      dsts=(t.block,), bits=bits))
    return tuple(loads)


# ---------------------------------------------------------------------------
# Exact execution on the block simulator
# ---------------------------------------------------------------------------
# Cap on blocks per batched launch: bounds host memory for huge
# schedules (rounds are chunked; the final chunk is zero-padded so one
# compiled wide fn serves every chunk of a schedule).
MAX_BATCH_BLOCKS = 512


def execute_schedule(sched: Schedule, x_u: np.ndarray, w_u: np.ndarray,
                     executor: Optional[str] = None,
                     batch_rounds: Optional[bool] = None,
                     max_batch_blocks: int = MAX_BATCH_BLOCKS) -> np.ndarray:
    """Run the schedule's rounds exactly; operands already unsigned.

    x_u ``(M, K)``, w_u ``(K, N)`` unsigned ``< 2^nbits``.  Returns the
    raw uint64 accumulator image ``(M, N)`` (callers apply the signed
    zero-point correction; see :func:`fabric_matmul`).

    ``batch_rounds`` (default: on for the compiled executor) replays ALL
    rounds as one ``engine.execute_blocks`` launch: every round replays
    the same compiled program, and the compiled wide-block path treats
    blocks as extra columns, so R rounds of B blocks are exactly one
    launch of R*B blocks.  One dispatch instead of R -- bit-identical to
    the per-round loop (blocks never interact), and the wall-clock win
    the fabric benchmark gates on.  Launches are chunked at
    ``max_batch_blocks`` blocks (last chunk zero-padded to the chunk
    shape so a single compiled fn serves all chunks).
    """
    import jax.numpy as jnp

    cfg = sched.cfg
    executor = executor or cfg.executor
    if batch_rounds is None:
        batch_rounds = executor == "compiled" and len(sched.rounds) > 1
    x_u = np.asarray(x_u, np.uint64)
    w_u = np.asarray(w_u, np.uint64)
    if x_u.shape != (sched.M, sched.K) or w_u.shape != (sched.K, sched.N):
        raise ValueError(f"operands {x_u.shape} @ {w_u.shape} do not match "
                         f"schedule {sched.M}x{sched.K}x{sched.N}")
    if np.any(x_u >= (1 << sched.nbits)) or np.any(w_u >= (1 << sched.nbits)):
        raise ValueError(f"operands must be < 2^{sched.nbits}")

    prog, lay = programs.idot(sched.nbits, rows=cfg.rows, tuples=sched.kt)
    n_compute = sched.n_compute
    out = np.zeros((sched.M, sched.N), np.uint64)

    def pack_blocks(tasks_slots, n_slots: int) -> np.ndarray:
        """Vectorized pack: all (task, block-slot) pairs of one launch.

        Bit-plane transposition runs once per bit over every block at
        once (numpy broadcasting) instead of once per task -- identical
        images to ``harness.pack_state`` per block, but the host-side
        cost no longer scales with task count.
        """
        a_vals = np.zeros((n_slots, sched.kt, cfg.cols), np.uint64)
        b_vals = np.zeros((n_slots, sched.kt, cfg.cols), np.uint64)
        for t, slot in tasks_slots:
            kw, nw = t.k1 - t.k0, t.n1 - t.n0
            a_vals[slot, :kw, :] = x_u[t.m, t.k0:t.k1][:, None]  # -> cols
            b_vals[slot, :kw, :nw] = w_u[t.k0:t.k1, t.n0:t.n1]
        arrs = np.zeros((n_slots, cfg.rows, cfg.cols), bool)
        bases = np.array([lay.base(i) for i in range(sched.kt)])
        for name, vals in (("a", a_vals), ("b", b_vals)):
            off, width = lay.fields[name]
            for i in range(width):
                arrs[:, bases + off + i, :] = \
                    ((vals >> np.uint64(i)) & np.uint64(1)).astype(bool)
        return arrs

    def unpack_accs(res: np.ndarray) -> np.ndarray:
        """(blocks, rows, cols) result image -> (blocks, cols) accs."""
        acc = np.zeros((res.shape[0], res.shape[2]), np.uint64)
        for i in range(lay.acc_bits):
            acc |= res[:, i, :].astype(np.uint64) << np.uint64(i)
        return acc

    def launch(arrs: np.ndarray) -> np.ndarray:
        blocks = arrs.shape[0]
        states = engine.CRState(
            array=jnp.asarray(arrs),
            carry=jnp.zeros((blocks, cfg.cols), bool),
            tag=jnp.ones((blocks, cfg.cols), bool))
        res = np.asarray(
            engine.execute_blocks(prog, states, executor=executor).array)
        return unpack_accs(res)

    if not batch_rounds:
        for rnd in sched.rounds:
            slots = [(t, t.block - sched.n_storage) for t in rnd.tasks]
            acc = launch(pack_blocks(slots, n_compute))
            for t, slot in slots:
                out[t.m, t.n0:t.n1] += acc[slot, : t.n1 - t.n0]
        return out

    # batched replay: rounds become extra block-columns of one launch;
    # the last chunk stays zero-padded to the chunk shape so ONE
    # compiled wide fn serves every chunk
    R = len(sched.rounds)
    chunk_r = max(1, min(R, max(max_batch_blocks, n_compute) // n_compute))
    for c0 in range(0, R, chunk_r):
        chunk = sched.rounds[c0:c0 + chunk_r]
        slots = [(t, ri * n_compute + t.block - sched.n_storage)
                 for ri, rnd in enumerate(chunk) for t in rnd.tasks]
        acc = launch(pack_blocks(slots, chunk_r * n_compute))
        for t, slot in slots:
            out[t.m, t.n0:t.n1] += acc[slot, : t.n1 - t.n0]
    return out


@dataclasses.dataclass(frozen=True)
class FabricResult:
    out: np.ndarray
    schedule: Schedule
    cost: costmodel.ScheduleCost


def fabric_matmul(x, w, nbits: int = 4,
                  cfg: FabricConfig = FabricConfig(),
                  signed: bool = False, *,
                  schedule: Optional[Schedule] = None,
                  batch_rounds: Optional[bool] = None) -> FabricResult:
    """Schedule, execute, and account ``(M, K) @ (K, N)`` on the fabric.

    Bit-exact vs ``x @ w`` in int64 for any operand in range; the cost
    report prices the *executed* schedule (same IR), so correctness and
    accounting can never drift apart.

    ``schedule`` reuses a pre-built plan (e.g. the
    :func:`search_schedule` argmin) instead of re-planning; its shape /
    precision must match the operands.  ``batch_rounds`` is forwarded to
    :func:`execute_schedule`.
    """
    x = np.asarray(x)
    w = np.asarray(w)
    if schedule is None:
        sched = schedule_gemm(x.shape[0], x.shape[1], w.shape[1], nbits,
                              cfg=cfg, signed=signed)
    else:
        sched = schedule
        if (sched.M, sched.K, sched.N) != (x.shape[0], x.shape[1],
                                           w.shape[1]) \
                or sched.nbits != nbits or sched.signed != signed:
            raise ValueError(
                f"schedule {sched.M}x{sched.K}x{sched.N}/int{sched.nbits}"
                f"{'s' if sched.signed else 'u'} does not match operands "
                f"{x.shape} @ {w.shape} int{nbits}{'s' if signed else 'u'}")
    if signed:
        cram._check_range((x, w), nbits, signed=True)
        xu, off = cram._bias_signed(x, nbits)
        wu, _ = cram._bias_signed(w, nbits)
        raw = execute_schedule(sched, xu, wu, batch_rounds=batch_rounds)
        out = cram._unbias(raw, off,
                           xu.sum(axis=1, dtype=np.int64)[:, None],
                           wu.sum(axis=0, dtype=np.int64)[None, :],
                           x.shape[1])
    else:
        out = execute_schedule(sched, x, w, batch_rounds=batch_rounds)
    return FabricResult(out=out, schedule=sched, cost=schedule_cost(sched))


# ---------------------------------------------------------------------------
# Cost accounting (walks the IR, prices with core.costmodel)
# ---------------------------------------------------------------------------
def schedule_cost(sched: Schedule) -> costmodel.ScheduleCost:
    """Roll one schedule up into energy (pJ) / time (us).

    Event counts per round (transposed bit-serial layout):

    * operand load: each :class:`TileLoad` moves its payload bits ONCE,
      regardless of how many destinations the broadcast fans out to --
      the fetch is a single multi-destination net (fabric hop when the
      home is a storage-mode block, the spill path when off-fabric) and
      one read stream at the source.
    * storage-mode traffic: source rows read (``ceil(bits / row width)``
      at the home block, once per load) plus destination rows written
      per task (the tile spans ``kt * 2n`` rows of the compute block
      while it is still in storage mode), plus ``ACC_BITS`` accumulator
      rows read back per task (the drain stage).
    * compute: every *started* block burns ``program.cycles()``
      compute-mode cycles; idle blocks in a partial round are never
      started (per-block start lines) and burn nothing.  Rounds
      serialize (lockstep launches), so the critical path still spans
      every round regardless of occupancy.

    Latency (CR-cycle units, storage rows converted at the BRAM/CR
    frequency ratio): ``serial_cycles`` lays every round's load ->
    compute -> drain end to end.  ``overlapped_cycles`` double-buffers:
    round *i+1*'s loads and round *i*'s drain run during round *i*'s
    compute, so each pipeline stage costs ``max(compute, next_load +
    drain)`` -- strictly less than serial for any schedule with >= 2
    rounds (the hidden work is positive), identical for 1 round.
    """
    cfg = sched.cfg
    cycles = sched.program.cycles()
    row_bits = cfg.cols

    n_active = sum(len(r.tasks) for r in sched.rounds)
    fabric_bits = 0.0
    spill_bits = 0.0
    load_rows = []                 # per round: src reads + dst writes
    drain_rows = []                # per round: accumulator readback
    for rnd in sched.rounds:
        lr = 0.0
        for ld in rnd.loads:
            if ld.src >= 0:
                fabric_bits += ld.bits
                lr += math.ceil(ld.bits / row_bits)        # src reads, once
            else:
                spill_bits += ld.bits
        for t in rnd.tasks:
            # result readback always crosses the fabric to the host edge
            fabric_bits += ACC_BITS * (t.n1 - t.n0)
            # dst writes while the compute block is still in storage mode
            lr += sched.kt * 2 * sched.nbits
        load_rows.append(lr)
        drain_rows.append(float(len(rnd.tasks) * ACC_BITS))
    rows_touched = sum(load_rows) + sum(drain_rows)

    ratio = costmodel.STORAGE_ROW_CR_CYCLES
    R = len(sched.rounds)
    serial = sum(load_rows[r] * ratio + cycles + drain_rows[r] * ratio
                 for r in range(R))
    overlapped = load_rows[0] * ratio
    for r in range(R - 1):
        overlapped += max(float(cycles),
                          (load_rows[r + 1] + drain_rows[r]) * ratio)
    overlapped += cycles + drain_rows[R - 1] * ratio

    return costmodel.schedule_cost_rollup(
        f"fabric/gemm{sched.M}x{sched.K}x{sched.N}/int{sched.nbits}",
        n_blocks=cfg.n_blocks, n_compute=sched.n_compute,
        n_storage=sched.n_storage, rounds=R,
        compute_block_cycles=float(n_active * cycles),
        round_cycles=float(R * cycles),
        storage_rows_touched=rows_touched,
        fabric_bits_moved=fabric_bits, spill_bits_moved=spill_bits,
        ops=sched.ops, serial_cycles=serial, overlapped_cycles=overlapped)


# ---------------------------------------------------------------------------
# Schedule autotuner: enumerate FabricConfig geometries x storage/compute
# splits, price each candidate with the (cheap, pure-Python) costmodel
# roll-up -- NO execution -- and return the argmin schedule.
# ---------------------------------------------------------------------------
#: Paper §V-D block geometries (same 20 Kb capacity, different aspect).
GEOMETRY_CHOICES: Tuple[Tuple[int, int], ...] = tuple(
    sorted(costmodel.GEOMETRIES))

#: Objectives the search can minimize -> ScheduleCost accessor.
OBJECTIVES = {
    "overlapped_cycles": "overlapped_cycles_",
    "serial_cycles": "serial_cycles_",
    "time_us": "time_us",
    "energy_pj": "energy_pj",
    "energy_per_op_pj": "energy_per_op_pj",
}

# bounded memo (shared LRU implementation with the compile cache)
_SEARCH_MEMO = engine._LRUCache(128)


@dataclasses.dataclass(frozen=True)
class SearchResult:
    """Argmin of a schedule search plus the full priced candidate table."""
    schedule: Schedule
    cost: costmodel.ScheduleCost
    objective: str
    candidates: Tuple[dict, ...]     # one row per priced candidate

    @property
    def config(self) -> FabricConfig:
        return self.schedule.cfg

    def describe(self) -> str:
        c = self.schedule.cfg
        return (f"search[{self.objective}]: {len(self.candidates)} "
                f"candidate(s) -> {c.rows}x{c.cols} "
                f"min_compute={c.min_compute_blocks} "
                f"({getattr(self.cost, OBJECTIVES[self.objective]):.0f})")


def _split_choices(n_blocks: int) -> Tuple[int, ...]:
    """min_compute_blocks candidates: sweep the storage/compute split."""
    raw = {1, n_blocks // 4, n_blocks // 2, (3 * n_blocks) // 4, n_blocks}
    return tuple(sorted(x for x in raw if 1 <= x <= n_blocks))


def search_schedule(M: int, K: int, N: int, nbits: int, *,
                    base: FabricConfig = FabricConfig(),
                    signed: bool = False,
                    geometries: Optional[Tuple[Tuple[int, int], ...]] = None,
                    splits: Optional[Tuple[int, ...]] = None,
                    objective: str = "overlapped_cycles") -> SearchResult:
    """Search ``FabricConfig`` geometries x tiling splits for one GEMM.

    Every candidate is planned with :func:`schedule_gemm` and priced
    with :func:`schedule_cost` -- pure Python on the IR, no simulator
    execution -- so the search is cheap enough to run per serving shape.
    The argmin schedule is returned ready for :func:`fabric_matmul`
    (``schedule=``).

    ``geometries`` defaults to the base grid's geometry plus the paper
    §V-D choices (:data:`GEOMETRY_CHOICES`).  Callers that will
    *execute* the winner on the simulator may want to pin ``geometries``
    to the base geometry only: each new (nbits, rows, kt) shape compiles
    a fresh program (seconds), whereas split-only tuning reuses compiled
    programs.  ``splits`` defaults to a sweep of
    ``min_compute_blocks`` over the grid (:func:`_split_choices`).

    Results are memoized (bounded LRU) -- serving calls the search once
    per (shape, grid), not once per token.
    """
    if objective not in OBJECTIVES:
        raise ValueError(f"unknown objective {objective!r}; "
                         f"expected one of {sorted(OBJECTIVES)}")
    geometries = tuple(geometries) if geometries is not None else \
        tuple(dict.fromkeys(((base.rows, base.cols),) + GEOMETRY_CHOICES))
    splits = tuple(splits) if splits is not None else \
        _split_choices(base.n_blocks)

    key = (M, K, N, nbits, signed, base.n_blocks, base.executor,
           geometries, splits, objective)
    hit = _SEARCH_MEMO.get(key)
    if hit is not None:
        return hit

    attr = OBJECTIVES[objective]
    best = None
    best_val = None
    rows_out = []
    for rows, cols in geometries:
        for mcb in splits:
            if mcb > base.n_blocks:
                continue
            cfg = FabricConfig(n_blocks=base.n_blocks, rows=rows, cols=cols,
                               executor=base.executor,
                               min_compute_blocks=mcb)
            try:
                sched = schedule_gemm(M, K, N, nbits, cfg=cfg, signed=signed)
            except ValueError:
                continue               # geometry can't host the program
            cost = schedule_cost(sched)
            val = float(getattr(cost, attr))
            rows_out.append({
                "rows": rows, "cols": cols, "min_compute": mcb,
                "n_compute": sched.n_compute, "n_storage": sched.n_storage,
                "rounds": len(sched.rounds), "kt": sched.kt,
                "objective": round(val, 3),
                "serial_cycles": round(cost.serial_cycles_, 1),
                "overlapped_cycles": round(cost.overlapped_cycles_, 1),
                "energy_pj": round(cost.energy_pj, 3),
            })
            if best_val is None or val < best_val:
                best, best_val = (sched, cost), val
    if best is None:
        raise ValueError(
            f"no candidate geometry can schedule {M}x{K}x{N} int{nbits}")
    return _SEARCH_MEMO.put(key, SearchResult(
        schedule=best[0], cost=best[1], objective=objective,
        candidates=tuple(rows_out)))


# ---------------------------------------------------------------------------
# Attention on the fabric (the paper's DL workload, via models/attention
# shapes: q/k are (B, S, H, hd) exactly as produced by ``_qkv``)
# ---------------------------------------------------------------------------
def _quantize_sym(x: np.ndarray, bits: int):
    """Symmetric per-tensor quantization to signed ``bits`` ints."""
    qmax = (1 << (bits - 1)) - 1
    amax = max(float(np.abs(x).max()), 1e-8)
    scale = amax / qmax
    q = np.clip(np.round(x / scale), -qmax - 1, qmax).astype(np.int64)
    return q, scale


def fabric_attention_scores(q: np.ndarray, k: np.ndarray,
                            cfg: FabricConfig = FabricConfig(),
                            bits: int = 8):
    """Attention score matmul ``q @ k^T`` per (batch, head) on the fabric.

    q: ``(B, Sq, H, hd)``, k: ``(B, Sk, H, hd)`` floats (the
    ``models.attention._qkv`` layout).  Each (batch, head) score tile is
    one fabric GEMM of the *quantized* operands; scores come back
    dequantized and pre-scaled by ``hd ** -0.5`` -- ready for the
    softmax of :func:`repro.models.attention.chunked_attention`.

    Returns ``(scores (B, Sq, H, Sk) float32, int_scores int64,
    costs list[ScheduleCost])``.
    """
    q = np.asarray(q, np.float32)
    k = np.asarray(k, np.float32)
    B, Sq, H, hd = q.shape
    Bk, Sk, Hk, hdk = k.shape
    if (B, H, hd) != (Bk, Hk, hdk):
        raise ValueError(f"q {q.shape} vs k {k.shape}")

    qq, sq = _quantize_sym(q, bits)
    qk, sk = _quantize_sym(k, bits)
    scores = np.zeros((B, Sq, H, Sk), np.float32)
    int_scores = np.zeros((B, Sq, H, Sk), np.int64)
    costs = []
    for b in range(B):
        for h in range(H):
            res = fabric_matmul(qq[b, :, h, :], qk[b, :, h, :].T,
                                nbits=bits, cfg=cfg, signed=True)
            int_scores[b, :, h, :] = res.out
            scores[b, :, h, :] = res.out * (sq * sk * hd ** -0.5)
            costs.append(res.cost)
    return scores, int_scores, costs


class FabricLinearProbe:
    """Run one decode step's linear projection on the simulated fabric.

    Attached to :class:`repro.serve.engine.ServeEngine`, the probe takes
    the engine's *live* per-step activations (the token embeddings of
    the batch being decoded), quantizes activation and weight to
    ``bits``, and runs the projection as a fabric-scheduled GEMM --
    i.e. a small slice of a real decode step executes on the
    cycle-accurate block grid, with a cost report per step.

    The fabric simulator is an oracle, not a serving fast path, so the
    probe only samples the first ``max_steps`` decode steps.

    ``autotune=True`` runs :func:`search_schedule` on the first observed
    activation shape and serves every sampled step from the argmin
    schedule -- serving picks its grid split automatically.  The search
    is restricted to the probe's own block geometry by default (split
    sweep only: executing a new geometry would compile a new program
    mid-serve); pass ``search_geometries`` to widen it.
    """

    def __init__(self, w, cfg: FabricConfig = FabricConfig(),
                 bits: int = 8, max_steps: int = 1,
                 autotune: bool = False,
                 search_geometries: Optional[tuple] = None):
        self.w = np.asarray(w, np.float32)       # (d_in, d_out)
        if self.w.ndim != 2:
            raise ValueError(f"probe weight must be 2-D, got {self.w.shape}")
        self.cfg = cfg
        self.bits = bits
        self.max_steps = max_steps
        self.autotune = autotune
        self.search_geometries = search_geometries
        self.search: Optional[SearchResult] = None
        self.costs: list = []
        self.outputs: list = []

    @property
    def done(self) -> bool:
        return len(self.costs) >= self.max_steps

    def _schedule_for(self, M: int, K: int, N: int) -> Optional[Schedule]:
        if not self.autotune:
            return None
        if self.search is None or \
                (self.search.schedule.M, self.search.schedule.K,
                 self.search.schedule.N) != (M, K, N):
            geoms = self.search_geometries if self.search_geometries \
                is not None else ((self.cfg.rows, self.cfg.cols),)
            self.search = search_schedule(M, K, N, self.bits, base=self.cfg,
                                          signed=True, geometries=geoms)
        return self.search.schedule

    def observe(self, x) -> Optional[np.ndarray]:
        """x: (B, d_in) float activation of the current decode step."""
        if self.done:
            return None
        x = np.asarray(x, np.float32)
        qx, sx = _quantize_sym(x, self.bits)
        qw, sw = _quantize_sym(self.w, self.bits)
        sched = self._schedule_for(qx.shape[0], qx.shape[1], qw.shape[1])
        res = fabric_matmul(qx, qw, nbits=self.bits, cfg=self.cfg,
                            signed=True, schedule=sched)
        y = res.out.astype(np.float32) * (sx * sw)
        self.costs.append(res.cost)
        self.outputs.append(y)
        return y

    def config_summary(self) -> dict:
        """The grid the probe actually serves from (autotuned or not)."""
        cfg = self.search.schedule.cfg if self.search is not None else self.cfg
        return {
            "geometry": f"{cfg.rows}x{cfg.cols}",
            "n_blocks": cfg.n_blocks,
            "min_compute": cfg.min_compute_blocks,
            "autotuned": self.search is not None,
        }

    def report(self) -> Optional[dict]:
        if not self.costs:
            return None
        rep = combine_costs("fabric/decode_linear", self.costs).report()
        rep.update(self.config_summary())
        return rep


def combine_costs(name: str, costs) -> costmodel.ScheduleCost:
    """Sum a list of :class:`ScheduleCost` (sequential launches)."""
    if not costs:
        raise ValueError("no costs to combine")
    c0 = costs[0]
    return costmodel.ScheduleCost(
        name=name, n_blocks=c0.n_blocks,
        n_compute=max(c.n_compute for c in costs),
        n_storage=max(c.n_storage for c in costs),
        rounds=sum(c.rounds for c in costs),
        compute_block_cycles=sum(c.compute_block_cycles for c in costs),
        round_cycles=sum(c.round_cycles for c in costs),
        storage_rows_touched=sum(c.storage_rows_touched for c in costs),
        fabric_bits_moved=sum(c.fabric_bits_moved for c in costs),
        spill_bits_moved=sum(c.spill_bits_moved for c in costs),
        ops=sum(c.ops for c in costs),
        energy_compute_pj=sum(c.energy_compute_pj for c in costs),
        energy_storage_pj=sum(c.energy_storage_pj for c in costs),
        energy_wire_pj=sum(c.energy_wire_pj for c in costs),
        # sequential launches: serial latencies add; overlap only exists
        # within each schedule, so the pipelined latencies add too
        serial_cycles=sum(c.serial_cycles_ for c in costs),
        overlapped_cycles=sum(c.overlapped_cycles_ for c in costs))
