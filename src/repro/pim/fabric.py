"""Fabric scheduler: tile GEMMs across a Compute RAM block grid.

The paper's fabric-level claim (§IV, §V): an FPGA carries hundreds of
Compute RAM sites, each *dynamically* allocated to storage mode (a plain
BRAM holding operands) or compute mode (executing an instruction
sequence), and a DL workload is tiled across the grid.  This module is
that layer for the simulator: it turns "one block runs one program"
(:mod:`repro.pim.cram`) into "a simulated FPGA runs a matmul" -- and,
since the :class:`FabricProgram` refactor, "a simulated FPGA runs a
*decode step*": several GEMMs sharing activations fused into one grid
allocation.

Pipeline
--------
1. :func:`schedule_program` builds an explicit :class:`FabricProgram`
   IR for one or more GEMMs that share their activation operand (the
   fused-QKV case; :func:`schedule_gemm` is the single-GEMM wrapper):

   * **mode map + placement** -- each of the grid's ``n_blocks`` blocks
     sits at a ``(row, col)`` site (:meth:`FabricConfig.site`) and is
     assigned ``storage`` (operand residency) or ``compute`` mode
     (paper §II dual-mode allocation).  ``FabricConfig.placement``
     decides *where* the storage blocks go: ``contiguous`` packs them
     at one grid corner, ``interleaved`` spreads them among the compute
     blocks (shorter operand hops).  Storage demand is sized from the
     operand footprint; whatever does not fit on-fabric is marked
     *spilled* (off-fabric memory, longer wires).
   * **tiling** -- K is tiled to the ``idot`` tuple capacity of the
     block geometry (:func:`repro.pim.cram.idot_geometry`, clamped so
     the int32 accumulator provably cannot overflow), each GEMM's N to
     the block's columns, and each output row ``m`` is one tile task.
     Ragged edge tiles are zero-padded to the fixed tile geometry so
     **every round replays one compiled program** across every fused
     GEMM.
   * **rounds** -- tile tasks are packed ``n_compute`` at a time into
     :class:`Round`\\ s; one round is one ``engine.execute_blocks``
     launch.  Blocks without a task in a partial round are *not
     started* (each block has its own start line from the host FSM, so
     idle blocks burn no compute energy); the simulator still steps
     them on zeros purely as a wide-batch convenience, and their
     results are discarded.
   * **residency-aware loads** -- each round carries an explicit
     operand-load stage (:class:`TileLoad`).  Loads are *cache fills*
     against a per-compute-block resident-tile map: a tile fetched for
     round *i* stays pinned in its block for later rounds that reuse
     it, so repeated weight tiles are fetched ONCE instead of once per
     round (LRU eviction when the block's bits run out).  Within one
     round, every block needing a tile that is not already resident
     joins one multi-destination broadcast fetch.  Tasks are assigned
     to blocks residency-first (a task prefers a block that already
     holds its weight tile, then its activation slice), which is what
     converts cross-round reuse in the IR into actual fetch savings.

2. :func:`execute_program` runs the rounds **exactly** on the block
   simulator and accumulates per-tile accumulators into each GEMM's
   output.  By default all rounds are *batched* into one compiled
   wide-block launch (rounds become extra block-columns) -- the
   simulator-side wall-clock fast path, bit-identical to the per-round
   loop.

3. :func:`schedule_cost` walks the same IR and prices it with
   :mod:`repro.core.costmodel`: compute-mode cycles, storage-mode row
   traffic, and **hop-priced** wire energy -- every load/broadcast/
   drain is billed by the Manhattan distance between the actual block
   sites involved (``costmodel.hop_net_length_mm``), not one average
   fabric net length, so the cost model finally *sees* both residency
   (fewer fetches) and placement (shorter fetches), the paper's
   headline data-movement savings.

4. :func:`search_program` / :func:`search_schedule` autotune: they
   enumerate ``FabricConfig`` geometries x storage/compute splits x
   placements, price every candidate through the same roll-up (no
   execution), deduplicate geometry-equivalent candidates, and return
   the argmin program -- wired into ``PimConfig(mode="fabric",
   fabric_autotune=True)`` and the serving fabric probe.

Signed operands use the same zero-point offset algebra as
:func:`repro.pim.cram.cram_matmul` (the blocks are unsigned-only
hardware); corrections are host-side sums.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import costmodel, engine, floatprog, programs, ref
from repro.core import faults as faults_core
from repro.pim import cram

FabricFaultError = faults_core.FabricFaultError

ACC_BITS = 32

#: Storage-block placement strategies (the autotuner sweeps these).
PLACEMENT_CHOICES: Tuple[str, ...] = ("contiguous", "interleaved")


def _dtype_info(name) -> cram.DType:
    """Resolve a dtype spec, synthesizing intN widths not in DTYPES."""
    if name is None:
        raise ValueError("dtype name must be resolved before lookup")
    if isinstance(name, cram.DType):
        return name
    if isinstance(name, str) and name.startswith("int") \
            and name not in cram.DTYPES:
        return cram.DType(name, "int", int(name[3:]))
    return cram.resolve_dtype(name)


def _wide_drain_bits(info: cram.DType) -> int:
    """Rows a float task drains: the wide accumulator image (chaining
    means the *wide* value leaves the block, not just the rounded fmt
    result)."""
    return floatprog.wide_format(info.fmt).width


# ---------------------------------------------------------------------------
# Config + IR
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FabricConfig:
    """A grid of Compute RAM blocks (one simulated FPGA).

    Blocks are laid out row-major on a near-square ``grid_rows x
    grid_cols`` grid of sites; the host/IO interface sits just off site
    ``(0, 0)``, so :meth:`edge_hops` is the Manhattan distance a spill
    fetch or an accumulator drain crosses.  ``placement`` picks where
    storage-mode blocks sit (``contiguous`` corner vs ``interleaved``
    among the compute blocks); ``residency`` enables the cross-round
    resident-tile map (off = the PR 3 reload-every-round load stage,
    kept for differential tests and as the pricing baseline).
    """
    n_blocks: int = 8
    rows: int = 512
    cols: int = 40
    executor: str = "compiled"
    min_compute_blocks: int = 1    # never storage-starve the grid
    placement: str = "contiguous"  # where storage blocks sit on the grid
    residency: bool = True         # cross-round resident-tile map
    # blocks held in reserve for fault repair: the LAST ``spare_blocks``
    # grid sites are never assigned storage or compute mode by the
    # scheduler; ``repair_program`` remaps a dead block onto the nearest
    # live spare (docs/faults.md).  0 = the pre-fault grid, bit-exact.
    spare_blocks: int = 0

    @property
    def block_bits(self) -> int:
        return self.rows * self.cols

    @property
    def grid_cols(self) -> int:
        return int(math.ceil(math.sqrt(self.n_blocks)))

    @property
    def grid_rows(self) -> int:
        return int(math.ceil(self.n_blocks / self.grid_cols))

    @property
    def grid_diameter(self) -> int:
        """Manhattan distance between the two farthest sites."""
        return (self.grid_rows - 1) + (self.grid_cols - 1)

    def site(self, block: int) -> Tuple[int, int]:
        """(row, col) site of one block on the grid."""
        return block // self.grid_cols, block % self.grid_cols

    def hops(self, a: int, b: int) -> int:
        """Manhattan hop distance between two blocks' sites."""
        (ra, ca), (rb, cb) = self.site(a), self.site(b)
        return abs(ra - rb) + abs(ca - cb)

    def edge_hops(self, block: int) -> int:
        """Hops from a block to the host/IO interface (just off (0,0))."""
        r, c = self.site(block)
        return r + c + 1

    @property
    def spare_ids(self) -> Tuple[int, ...]:
        """Grid sites reserved as repair spares (the last N blocks)."""
        return tuple(range(self.n_blocks - self.spare_blocks,
                           self.n_blocks))

    @property
    def usable_blocks(self) -> int:
        """Blocks the scheduler may assign (grid minus spares)."""
        return self.n_blocks - self.spare_blocks

    def __post_init__(self):
        if self.n_blocks < 1:
            raise ValueError("fabric needs at least one block")
        if self.spare_blocks < 0:
            raise ValueError("spare_blocks must be >= 0")
        if not 1 <= self.min_compute_blocks <= self.n_blocks - \
                self.spare_blocks:
            raise ValueError("min_compute_blocks out of range (grid minus "
                             "spares must still fit the compute floor)")
        if self.placement not in PLACEMENT_CHOICES:
            raise ValueError(f"placement {self.placement!r} not in "
                             f"{PLACEMENT_CHOICES}")


@dataclasses.dataclass(frozen=True)
class GemmSpec:
    """One GEMM of a fabric program: ``(M, K) @ (K, N)``.

    Fused GEMMs of one :class:`FabricProgram` share ``M``/``K`` (and the
    activation operand); ``N`` is per GEMM (the QKV projections).

    ``dtype`` picks the GEMM's element type (a ``repro.pim.cram.DTYPES``
    key, or anything :func:`repro.pim.cram.resolve_dtype` accepts, e.g.
    ``jnp.bfloat16``); ``None`` defaults to the program-level
    ``int{nbits}``.  Fused GEMMs may mix dtypes -- int4/int8/bf16
    coexisting in ONE program (asymmetric per-GEMM precision): each
    dtype class gets its own tile geometry, instruction sequence, and
    activation encoding, while sharing the grid allocation and the
    residency machinery.

    ``kv`` names a :class:`FabricSession` KV cache that backs this
    GEMM's weight operand -- the new ``kv`` tile class of the Schedule
    IR.  KV tiles are session-pinned (never LRU-evicted within the
    sequence window), live at the cache's reserved home block, and load
    *append-addressed*: a compute block that already holds an earlier
    prefix of a growing tile fetches only the delta bits
    (:meth:`FabricSession.kv_append` grows the cache between programs).
    ``kv_axis`` records which GEMM dimension the appended positions tile
    along -- ``"n"`` for the K^T scores operand (``(hd, t)``), ``"k"``
    for the V operand (``(t, hd)``); the scheduler's growing-tile delta
    machinery covers both, the axis is a declaration checked at
    schedule time.
    """
    name: str
    M: int
    K: int
    N: int
    dtype: Optional[str] = None
    kv: Optional[str] = None
    kv_axis: str = "n"


@dataclasses.dataclass(frozen=True)
class TileTask:
    """One (gemm, output-row, K-tile, N-tile) unit of work on one block."""
    block: int                 # compute block executing this tile
    m: int                     # output row
    k0: int
    k1: int
    n0: int
    n1: int
    x_src: int                 # storage block holding x[m, :] (-1 = spill)
    w_src: int                 # storage block holding w tile (-1 = spill)
    gemm: int = 0              # index into FabricProgram.gemms


@dataclasses.dataclass(frozen=True)
class TileLoad:
    """One operand *cache fill* that must retire before its round's compute.

    The load stage is explicit in the IR so the cost model can price
    round *i+1*'s loads as double-buffered against round *i*'s compute
    (``ScheduleCost.overlapped_cycles``).  ``dsts`` lists only the
    compute blocks where the tile is NOT already resident: blocks that
    fetched it in an earlier round (and have not evicted it) are served
    from their resident-tile map and appear in no load at all.  Several
    missing destinations coalesce into ONE multi-destination broadcast
    net, priced once in the wire-energy split by the Manhattan span of
    the sites it touches.
    """
    kind: str                  # "x" (activation slice) | "w" (weight tile)
    key: Tuple[int, ...]       # ("x": (m, k0)) | ("w": (gemm, k0, n0))
    src: int                   # storage block holding the payload (-1 = spill)
    dsts: Tuple[int, ...]      # destination compute blocks (broadcast if >1)
    bits: int                  # payload bits of ONE copy


@dataclasses.dataclass(frozen=True)
class Round:
    """One lockstep ``execute_blocks`` launch over the compute blocks.

    ``loads`` is the round's operand-load stage: every tile a task reads
    is either covered by a load of the same round or already resident in
    the task's block from an earlier fetch (the cache-fill semantics the
    overlap model pipelines and ``residency_stats`` audits).
    """
    tasks: Tuple[TileTask, ...]
    loads: Tuple[TileLoad, ...] = ()
    # element-type class of every task in this round (a round is ONE
    # lockstep program launch, so it can never mix dtypes); None means
    # the program's default int class (single-dtype legacy programs).
    dtype: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class FabricProgram:
    """Explicit fabric schedule for one or more fused quantized GEMMs.

    The multi-GEMM, residency-aware successor of the single-GEMM
    ``Schedule`` IR (which remains as an alias): every fused GEMM shares
    the activation operand and the grid allocation, and all rounds
    replay ONE compiled idot program.  Single-GEMM programs keep the
    legacy accessors (``M``/``K``/``N``).
    """
    cfg: FabricConfig
    nbits: int
    signed: bool
    gemms: Tuple[GemmSpec, ...]
    kt: int                              # K-tile of gemm 0 (legacy accessor)
    modes: Tuple[str, ...]               # per block: "compute" | "storage"
    x_home: Tuple[int, ...]              # per output row m -> block | -1
    #                                      (primary dtype class's copy)
    w_home: Dict[Tuple[int, int, int], int]  # (gemm, k-tile, n-tile) -> block
    rounds: Tuple[Round, ...]
    # per-GEMM resolved dtype names + K-tiles (empty tuples on programs
    # built before the dtype refactor -> int{nbits} / kt fallbacks)
    dtypes: Tuple[str, ...] = ()
    kts: Tuple[int, ...] = ()
    # non-primary dtype classes' activation homes: (dtype, m) -> block
    x_home_ext: Dict[Tuple[str, int], int] = \
        dataclasses.field(default_factory=dict)

    @property
    def M(self) -> int:
        return self.gemms[0].M           # shared across fused GEMMs

    @property
    def K(self) -> int:
        return self.gemms[0].K           # shared across fused GEMMs

    # -- dtype plumbing -----------------------------------------------------
    def dtype_of(self, g: int) -> str:
        return self.dtypes[g] if self.dtypes else f"int{self.nbits}"

    def kt_of(self, g: int) -> int:
        return self.kts[g] if self.kts else self.kt

    def infos(self) -> Tuple[cram.DType, ...]:
        """Resolved :class:`repro.pim.cram.DType` per fused GEMM."""
        return tuple(_dtype_info(self.dtype_of(g))
                     for g in range(len(self.gemms)))

    @property
    def classes(self) -> Tuple[str, ...]:
        """Distinct dtype classes, in first-appearance order."""
        return tuple(dict.fromkeys(self.dtype_of(g)
                                   for g in range(len(self.gemms))))

    @property
    def multi(self) -> bool:
        """Mixed-precision program (>= 2 dtype classes)?"""
        return len(self.classes) > 1

    def class_kt(self, name: str) -> int:
        for g in range(len(self.gemms)):
            if self.dtype_of(g) == name:
                return self.kt_of(g)
        raise KeyError(name)

    def class_program(self, name: str):
        """(program, layout) every round of dtype class ``name`` replays."""
        info = _dtype_info(name)
        if info.is_float:
            return floatprog.float_dot(info.fmt, rows=self.cfg.rows,
                                       tuples=self.class_kt(name))
        return programs.idot(info.bits, rows=self.cfg.rows,
                             tuples=self.class_kt(name))

    @property
    def N(self) -> int:
        if len(self.gemms) != 1:
            raise ValueError(
                f"N is ambiguous for a {len(self.gemms)}-GEMM program; "
                f"use .gemms")
        return self.gemms[0].N

    @property
    def n_compute(self) -> int:
        return self.modes.count("compute")

    @property
    def n_storage(self) -> int:
        return self.modes.count("storage")

    @property
    def compute_blocks(self) -> Tuple[int, ...]:
        return tuple(b for b, m in enumerate(self.modes) if m == "compute")

    @property
    def program(self):
        """The program the primary dtype class's rounds replay."""
        prog, _ = self.class_program(self.dtype_of(0))
        return prog

    @property
    def ops(self) -> int:
        """Useful MACs (zero-padding excluded), across all fused GEMMs."""
        return sum((t.k1 - t.k0) * (t.n1 - t.n0)
                   for r in self.rounds for t in r.tasks)

    def describe(self) -> str:
        cfg = self.cfg
        sig = "s" if self.signed else "u"
        shapes = " + ".join(
            f"{g.name}[{self.dtype_of(i)}]:{g.M}x{g.K}@{g.K}x{g.N}"
            for i, g in enumerate(self.gemms))
        prec = "+".join(self.classes) if self.dtypes \
            else f"int{self.nbits}"
        kts = ", ".join(f"{c}:{self.class_kt(c)}" for c in self.classes) \
            if self.multi else str(self.kt)
        lines = [
            f"FabricProgram [{shapes}] {prec}{sig} on "
            f"{cfg.n_blocks} blocks "
            f"({cfg.grid_rows}x{cfg.grid_cols} grid, "
            f"{self.n_compute} compute / {self.n_storage} storage, "
            f"{cfg.placement})",
            f"  K-tile={kts} tuples, N-tile={cfg.cols} cols, "
            f"{len(self.rounds)} round(s), "
            f"{sum(len(r.tasks) for r in self.rounds)} tile task(s)",
        ]
        if cfg.residency:
            st = residency_stats(self)
            lines.append(
                f"  residency: {st['fetches']} fetch(es) for "
                f"{st['reads']} tile read(s) "
                f"(hit rate {st['hit_rate']:.0%}, "
                f"{st['fetch_reduction']:.2f}x fewer than reload)")
        spares = self.modes.count("spare")
        dead = self.modes.count("dead")
        if spares or dead:
            lines.append(f"  {spares} spare block(s) in reserve"
                         + (f", {dead} dead block(s) remapped" if dead
                            else ""))
        spills = sum(1 for t_ in self.w_home.values() if t_ < 0) \
            + sum(1 for t_ in self.x_home if t_ < 0) \
            + sum(1 for t_ in self.x_home_ext.values() if t_ < 0)
        if spills:
            lines.append(f"  {spills} operand(s) spilled off-fabric")
        return "\n".join(lines)


#: Migration alias: PR 2/3 named the single-GEMM IR ``Schedule``.
Schedule = FabricProgram


# ---------------------------------------------------------------------------
# Persistent sessions: residency across programs (weight-stationary decode)
# ---------------------------------------------------------------------------
class FabricSession:
    """Grid state that persists across sequential fabric programs.

    Every :func:`schedule_program` call normally starts from a cold
    resident-tile map, so a weight-stationary serve loop refetches every
    weight tile on every decode step.  A session owns the state that
    should outlive one program:

    * the **mode map** (storage/compute allocation), pinned by the
      session's first program so later programs schedule onto the same
      grid split;
    * the per-compute-block **resident-tile maps**, keyed *globally*
      (weight tiles by ``(gemm name, dtype, k0, n0)``), so a tile
      fetched in decode step 1 emits **no load** in steps 2..N -- the
      caller contract is that a stable GEMM name means a stationary
      weight (a renamed or mutated weight only mis-models cost, never
      correctness: execution always packs the actual operands passed);
    * the storage blocks' **free space + operand homes**, so a warm tile
      is also not re-placed (activations are per-program: their homes
      recycle and their resident entries drop at each program boundary
      -- a decode step's activations are new payloads every step);
    * **KV caches** (:meth:`reserve_kv` / :meth:`kv_append`): reserved
      storage-block regions that grow in place, the on-fabric KV cache
      (see :class:`GemmSpec.kv`);
    * a per-decode-step **cost/fetch trajectory**
      (:meth:`begin_step` / :meth:`trajectory`) -- the cold step-1 vs
      steady-state split in :class:`repro.core.costmodel.CostTrajectory`.

    Lifecycle: create -> warm (schedule/execute programs through it) ->
    invalidated on fault repair (:meth:`invalidate_blocks` /
    :meth:`apply_remap`, wired into ``execute_program`` scrubs and
    :func:`repair_program`) -> :meth:`reset` back to cold.

    Residency remains an IR/cost-model concept: :func:`execute_program`
    re-packs every operand host-side each launch, so outputs are
    bit-identical with or without a session -- the session changes what
    the schedule *charges for moving*, never what the blocks compute.
    Not thread-safe; one session serves one sequential serve loop.
    """

    def __init__(self, cfg: Optional[FabricConfig] = None):
        self._cfg0 = cfg
        self.reset()

    # NOTE: no __eq__/__hash__ overrides -- identity hashing keeps a
    # session embeddable in frozen configs (repro.pim.linear.PimConfig).

    def reset(self) -> None:
        """Back to cold: drop residency, homes, KV caches, trajectory."""
        self.cfg: Optional[FabricConfig] = self._cfg0
        self.modes: Optional[Tuple[str, ...]] = None
        self.storage_free: Dict[int, int] = {}
        self.resident: Dict[int, dict] = {}    # block -> {key: [bits, last]}
        self.w_homes: Dict[tuple, int] = {}    # global weight key -> block
        self.clock = 0                         # global LRU round counter
        self.epoch = 0                         # program counter (x scoping)
        self.programs = 0
        self.kv: Dict[str, dict] = {}
        self.steps: List[dict] = []
        self._x_alloc: List[Tuple[int, int]] = []

    # -- grid binding (internal: schedule_program) --------------------------
    def _bind(self, cfg: FabricConfig) -> None:
        if self.cfg is not None and self.cfg != cfg:
            if self.programs == 0 and self.modes is None:
                self.cfg = cfg        # cold: adopt (e.g. an autotuned split)
                return
            raise ValueError(
                f"session is bound to grid {self.cfg}; got {cfg} -- "
                f"reset() before switching grids")
        self.cfg = cfg

    def _begin_program(self) -> None:
        """Per-program state turnover: activations never warm across
        programs (a decode step's activations are new payloads), so
        their storage allocations recycle and their resident entries
        drop; weights and KV tiles persist."""
        self.epoch += 1
        self.programs += 1
        for b, bits in self._x_alloc:
            if b >= 0:
                self.storage_free[b] = self.storage_free.get(b, 0) + bits
        self._x_alloc = []
        for res in self.resident.values():
            for kk in [k for k in res if k[0] == "x"]:
                del res[kk]
        self._step()["programs"] += 1

    # -- decode-step trajectory ----------------------------------------------
    def begin_step(self) -> dict:
        """Open a new per-decode-step accounting bucket."""
        self.steps.append({"programs": 0, "fetches": 0, "fetch_bits": 0.0,
                           "w_fetches": 0, "kv_fetch_bits": 0.0,
                           "kv_appends": 0, "kv_append_bits": 0,
                           "costs": []})
        return self.steps[-1]

    def _step(self) -> dict:
        return self.steps[-1] if self.steps else self.begin_step()

    def record_cost(self, cost: costmodel.ScheduleCost) -> None:
        self._step()["costs"].append(cost)

    def trajectory(self) -> costmodel.CostTrajectory:
        """The session's per-step cost/fetch trajectory so far."""
        costs = tuple(combine_costs("fabric/session_step", s["costs"])
                      if s["costs"] else None for s in self.steps)
        return costmodel.CostTrajectory(
            name="fabric/session",
            costs=costs,
            fetches=tuple(s["fetches"] for s in self.steps),
            fetch_bits=tuple(s["fetch_bits"] for s in self.steps),
            w_fetches=tuple(s["w_fetches"] for s in self.steps),
            kv_fetch_bits=tuple(s["kv_fetch_bits"] for s in self.steps))

    def stats(self) -> dict:
        rep = {
            "programs": self.programs,
            "steps": len(self.steps),
            "resident_tiles": sum(len(r) for r in self.resident.values()),
            "resident_bits": sum(bits for r in self.resident.values()
                                 for bits, _ in r.values()),
            "kv": {k: {"len": m["len"], "window": m["window"],
                       "home": m["home"]} for k, m in self.kv.items()},
        }
        if self.steps:
            rep["trajectory"] = self.trajectory().report()
        return rep

    # -- on-fabric KV caches -------------------------------------------------
    def reserve_kv(self, kv_id: str, pos_bits: int, window: int) -> None:
        """Reserve a growing KV cache of up to ``window`` positions of
        ``pos_bits`` bits each.  Must happen before the session's first
        program: reservations join the storage-demand sizing and are
        placed FIRST (before any weight tile), so the cache lives
        on-fabric whenever it fits one storage block."""
        if self.modes is not None:
            raise ValueError(
                "reserve_kv after the session's first program: the mode "
                "map is pinned; reset() to re-plan")
        if kv_id in self.kv:
            raise ValueError(f"KV cache {kv_id!r} already reserved")
        if pos_bits < 1 or window < 1:
            raise ValueError(f"degenerate KV reservation {kv_id!r}: "
                             f"{window} x {pos_bits} bits")
        self.kv[kv_id] = {"pos_bits": int(pos_bits), "window": int(window),
                          "len": 0, "home": None}

    def kv_len(self, kv_id: str) -> int:
        return self.kv[kv_id]["len"]

    def kv_append(self, kv_id: str, n_new: int = 1) -> None:
        """Append ``n_new`` positions to a KV cache (the decode step's
        new K/V row): the cache grows *in place* at its home block --
        history already on the grid is never refetched.  Charges the
        append write to the current step's trajectory."""
        meta = self.kv[kv_id]
        if meta["home"] is None:
            raise ValueError(
                f"KV cache {kv_id!r} not placed yet: run the session's "
                f"first program before appending")
        if meta["len"] + n_new > meta["window"]:
            raise ValueError(
                f"KV cache {kv_id!r} overflows its window: "
                f"{meta['len']} + {n_new} > {meta['window']}")
        meta["len"] += n_new
        bits = n_new * meta["pos_bits"]
        step = self._step()
        step["kv_appends"] += n_new
        step["kv_append_bits"] += bits
        cfg = self.cfg
        if cfg is not None:
            home = meta["home"]
            step["costs"].append(costmodel.kv_append_cost(
                f"fabric/kv_append/{kv_id}", n_blocks=cfg.n_blocks,
                cols=cfg.cols, bits=bits,
                edge_hops=(cfg.edge_hops(home) if home >= 0
                           else cfg.grid_diameter),
                spilled=home < 0))

    # -- fault hooks -----------------------------------------------------------
    def invalidate_blocks(self, blocks) -> None:
        """Drop every resident-tile entry of the given grid blocks.

        Called when a scrub restores a block from its pristine image
        (:func:`execute_program`'s fault path): the pristine refetch
        restores only *that launch's* packed operands, so any other
        tile the block's resident map claims to hold can no longer be
        trusted -- a stale map after repair would be silent wrong
        reuse in the cost model.  The next program refetches."""
        for b in blocks:
            if b in self.resident:
                self.resident[b].clear()

    def apply_remap(self, mapping: Dict[int, int]) -> None:
        """Mirror a :func:`repair_program` spare remap into the session.

        A dead compute block's resident map is DROPPED (the spare
        starts cold -- it holds nothing yet, silent reuse would be
        wrong); a dead storage block's homes and free space move to its
        spare, and every home pointer is rewritten."""
        if self.modes is None or not mapping:
            return
        modes = list(self.modes)
        for b, s in mapping.items():
            modes[s] = modes[b]
            modes[b] = "dead"
            if b in self.resident:
                self.resident.pop(b)
                self.resident[s] = {}
            if b in self.storage_free:
                self.storage_free[s] = self.storage_free.pop(b)
        self.modes = tuple(modes)

        def remap(v: int) -> int:
            return mapping.get(v, v) if v >= 0 else v

        self.w_homes = {k: remap(v) for k, v in self.w_homes.items()}
        self._x_alloc = [(remap(b), bits) for b, bits in self._x_alloc]
        for meta in self.kv.values():
            if meta["home"] is not None:
                meta["home"] = remap(meta["home"])


# ---------------------------------------------------------------------------
# Scheduling
# ---------------------------------------------------------------------------
def _task_operands(t: TileTask, gemms: Sequence[GemmSpec],
                   infos: Sequence[cram.DType], multi: bool):
    """The (kind, key, src, bits) operand reads of one tile task.

    Activation slices are keyed ``(m, k0)`` -- shared across fused GEMMs
    (all of them read the same activations); weight tiles are keyed
    ``(gemm, k0, n0)``.  The K-slice matters: two tasks reading
    different K-ranges of one row fetch different payloads.  In a
    mixed-precision program every dtype class stores its *own encoding*
    of the activations (a quantized int8 row and a bf16 row are
    different payloads even for the same ``(m, k0)``), so activation
    keys grow a leading dtype component: ``(dtype, m, k0)``.

    A GEMM backed by a session KV cache (``GemmSpec.kv``) reads its
    weight-side operand as a ``kv`` tile instead, keyed
    ``(kv_id, k0, n0)`` -- the key is already program-independent, so a
    session can track the growing tile across decode steps.
    """
    info = infos[t.gemm]
    kw = t.k1 - t.k0
    xkey = (info.name, t.m, t.k0) if multi else (t.m, t.k0)
    yield "x", xkey, t.x_src, kw * info.bits
    wbits = kw * (t.n1 - t.n0) * info.bits
    kv = getattr(gemms[t.gemm], "kv", None)
    if kv:
        yield "kv", (kv, t.k0, t.n0), t.w_src, wbits
    else:
        yield "w", (t.gemm, t.k0, t.n0), t.w_src, wbits


def _storage_block_ids(n_blocks: int, n_storage: int,
                       placement: str) -> Tuple[int, ...]:
    """Which grid sites hold operands (the placement dimension)."""
    if placement == "interleaved" and n_storage > 0:
        return tuple(int(i * n_blocks / n_storage) for i in range(n_storage))
    return tuple(range(n_storage))


def _assign_slots(chunk, compute_blocks, resident, x_keys, w_keys):
    """Residency-affinity task placement within one round.

    Each unit prefers a free compute block that already holds its weight
    tile (the big payload), then one holding its activation slice;
    leftovers fill the remaining blocks in grid order.  Deterministic:
    units are visited in schedule order.
    """
    free = list(compute_blocks)
    assign = {}
    deferred = []
    for u in chunk:
        b = next((b for b in free if w_keys[u] in resident[b]), None)
        if b is None:
            b = next((b for b in free if x_keys[u] in resident[b]), None)
        if b is None:
            deferred.append(u)
        else:
            assign[u] = b
            free.remove(b)
    for u in deferred:
        assign[u] = free.pop(0)
    return assign


def _evict_lru(res: dict, capacity: int, pinned: set):
    """Evict least-recently-used resident tiles until under capacity.

    Tiles read by the current round (``pinned``) are never evicted, and
    neither are ``kv`` tiles -- the session's KV cache is pinned for the
    whole sequence window (evicting appended history would turn every
    later decode step's delta load back into a full refetch); the
    idot layout guarantees one x slice + one w tile always fit a block.
    """
    while sum(bits for bits, _ in res.values()) > capacity:
        victims = [(last, kk) for kk, (_, last) in res.items()
                   if kk not in pinned and kk[0] != "kv"]
        if not victims:
            break
        res.pop(min(victims)[1])


def schedule_program(specs: Sequence[GemmSpec], nbits: int,
                     cfg: FabricConfig = FabricConfig(),
                     signed: bool = False,
                     session: Optional[FabricSession] = None
                     ) -> FabricProgram:
    """Plan one or more activation-sharing GEMMs onto the block grid.

    All specs must share ``M`` and ``K`` (they read the same activation
    operand -- the fused-QKV contract); each spec brings its own ``N``
    and weight matrix.  No execution happens here; the returned
    :class:`FabricProgram` feeds :func:`execute_program`,
    :func:`schedule_cost`, and the search.

    With a :class:`FabricSession`, the plan is made against the
    session's *warm* state: the mode map is pinned by the session's
    first program, weight tiles already resident in a compute block emit
    no load (keyed globally by GEMM name + dtype + tile coordinates, so
    the reuse carries across programs), weight homes persist, and
    ``GemmSpec.kv`` GEMMs read their weight operand from the session's
    reserved KV cache with append-addressed delta loads.  A *cold*
    session (no KV reservations) plans the first program identically to
    the sessionless path.  Scheduling through a session mutates it (the
    plan IS the intent to run) -- never pass a live session to a search.
    """
    specs = tuple(specs)
    if not specs:
        raise ValueError("fabric program needs at least one GEMM")
    M, K = specs[0].M, specs[0].K
    for g in specs:
        if min(g.M, g.K, g.N) < 1:
            raise ValueError(f"degenerate GEMM {g.name}: {g.M}x{g.K}x{g.N}")
        if (g.M, g.K) != (M, K):
            raise ValueError(
                f"fused GEMMs must share activations: {g.name} is "
                f"{g.M}x{g.K}, expected {M}x{K}")
        if g.kv_axis not in ("n", "k"):
            raise ValueError(f"GEMM {g.name}: kv_axis {g.kv_axis!r} "
                             f"not in ('n', 'k')")
        if g.kv and session is not None and g.kv not in session.kv:
            raise ValueError(f"GEMM {g.name}: KV cache {g.kv!r} not "
                             f"reserved on the session (reserve_kv first)")
    if session is not None:
        session._bind(cfg)
        session._begin_program()

    # --- resolve per-GEMM dtypes + per-class K-tiles -----------------------
    infos = tuple(cram.resolve_dtype(g.dtype) or _dtype_info(f"int{nbits}")
                  for g in specs)
    class_kt: Dict[str, int] = {}
    for info in infos:
        if info.name in class_kt:
            continue
        # the dtype-aware infeasible-geometry guard: idot_tile /
        # float_dot would otherwise clamp or fail much later with an
        # opaque layout error -- fail at schedule time with the
        # geometry named, for ints and floats alike
        if info.is_float:
            kt_c = cram.fdot_geometry(info.fmt, cfg.rows)
            if kt_c < 1:
                raise ValueError(
                    f"geometry {cfg.rows}x{cfg.cols} cannot host a "
                    f"float_dot[{info.name}] program (too few rows)")
        else:
            if cram.idot_geometry(info.bits, cfg.rows, ACC_BITS) < 1:
                raise ValueError(
                    f"geometry {cfg.rows}x{cfg.cols} cannot host an "
                    f"idot{info.bits} program (too few rows)")
            kt_c = cram.idot_tile(info.bits, cfg.rows, ACC_BITS)
        class_kt[info.name] = kt_c
    kts = tuple(class_kt[i.name] for i in infos)
    classes = tuple(dict.fromkeys(i.name for i in infos))
    by_class = {c: [g for g in range(len(specs)) if infos[g].name == c]
                for c in classes}
    multi = len(classes) > 1
    k_tiles = [math.ceil(K / kts[g]) for g in range(len(specs))]
    n_tiles = [math.ceil(g.N / cfg.cols) for g in specs]

    # --- mode map + placement: size storage demand, place the blocks -------
    # session KV-backed GEMMs read the reserved cache instead of placed
    # weight tiles: they join neither the storage sizing nor first-fit
    w_tile_bits = {}
    for g, spec in enumerate(specs):
        if spec.kv and session is not None:
            continue
        for ki in range(k_tiles[g]):
            for ni in range(n_tiles[g]):
                kw = min(K, (ki + 1) * kts[g]) - ki * kts[g]
                nw = min(spec.N, (ni + 1) * cfg.cols) - ni * cfg.cols
                w_tile_bits[(g, ki, ni)] = kw * nw * infos[g].bits
    x_row_bits = {c: K * _dtype_info(c).bits for c in classes}
    pinned_modes = session is not None and session.modes is not None
    if pinned_modes:
        modes = session.modes
        storage_ids = tuple(b for b, m in enumerate(modes)
                            if m == "storage")
        free = session.storage_free
    else:
        total_bits = sum(w_tile_bits.values()) \
            + M * sum(x_row_bits[c] for c in classes)
        if session is not None:
            total_bits += sum(m_["window"] * m_["pos_bits"]
                              for m_ in session.kv.values())
        usable = cfg.usable_blocks      # spares are never scheduled onto
        n_storage = min(math.ceil(total_bits / cfg.block_bits),
                        usable - cfg.min_compute_blocks)
        n_storage = max(n_storage, 0)
        storage_ids = _storage_block_ids(usable, n_storage, cfg.placement)
        spare_ids = set(cfg.spare_ids)
        modes = tuple("spare" if b in spare_ids
                      else "storage" if b in set(storage_ids) else "compute"
                      for b in range(cfg.n_blocks))
        free = {b: cfg.block_bits for b in storage_ids}
    compute_blocks = tuple(b for b, m in enumerate(modes) if m == "compute")
    n_compute = len(compute_blocks)
    if n_compute < 1:
        raise ValueError("session mode map has no compute blocks left")

    # --- operand residency: first-fit into the storage blocks ---------------
    def place(bits: int) -> int:
        for b in storage_ids:
            if free[b] >= bits:
                free[b] -= bits
                return b
        return -1                                  # spill off-fabric

    if session is not None and not pinned_modes:
        # pin the mode map and place KV reservations FIRST, so the
        # cache lives on-fabric whenever it fits a storage block
        session.modes = modes
        session.storage_free = free
        for meta in session.kv.values():
            meta["home"] = place(meta["window"] * meta["pos_bits"])

    def w_gkey(g: int, ki: int, ni: int) -> tuple:
        return ("w", specs[g].name, infos[g].name,
                ki * kts[g], ni * cfg.cols)

    w_home = {}
    for key, bits in sorted(w_tile_bits.items()):
        if session is not None:
            gk = w_gkey(*key)
            if gk not in session.w_homes:
                session.w_homes[gk] = place(bits)
            w_home[key] = session.w_homes[gk]
        else:
            w_home[key] = place(bits)
    for g, spec in enumerate(specs):       # KV GEMMs: home = the cache
        if spec.kv and session is not None:
            home = session.kv[spec.kv]["home"]
            for ki in range(k_tiles[g]):
                for ni in range(n_tiles[g]):
                    w_home[(g, ki, ni)] = home
    x_homes = {(c, m): place(x_row_bits[c])
               for c in classes for m in range(M)}
    if session is not None:
        session._x_alloc = [(x_homes[(c, m)], x_row_bits[c])
                            for c in classes for m in range(M)]
    x_home = tuple(x_homes[(classes[0], m)] for m in range(M))

    # --- tile units -> lockstep rounds of n_compute ------------------------
    # (ki, g, ni, m) order: consecutive units share a weight tile (so a
    # round's sharers join one broadcast), and for fused GEMMs every
    # activation slice (m, k-slice) recurs across g/ni -- the reuse the
    # resident-tile map converts into skipped fetches.  Single-GEMM
    # programs reduce to the PR 3 (ki, ni, m) order exactly.
    #
    # A round is ONE lockstep program launch, so tasks of different
    # dtype classes can never share one: units are built per class
    # *segment* (single-int-class programs get one segment -- the exact
    # legacy order).  Float classes additionally segment per k-tile:
    # a float output tile's k-tiles CHAIN through the wide accumulator
    # (float addition does not associate, unlike the host-summed int
    # partials), so two k-tiles of one output must sit in different,
    # ordered rounds.
    def class_units(c: str, ki_range) -> list:
        return [(g, m, ki, ni)
                for ki in ki_range
                for g in by_class[c]
                for ni in range(n_tiles[g])
                for m in range(M)]

    segments: List[Tuple[str, list]] = []
    for c in classes:
        g0 = by_class[c][0]
        if _dtype_info(c).is_float:
            for ki in range(k_tiles[g0]):
                segments.append((c, class_units(c, (ki,))))
        else:
            segments.append((c, class_units(c, range(k_tiles[g0]))))

    def unit_task(u, block: int) -> TileTask:
        g, m, ki, ni = u
        return TileTask(
            block=block, m=m, gemm=g,
            k0=ki * kts[g], k1=min(K, (ki + 1) * kts[g]),
            n0=ni * cfg.cols, n1=min(specs[g].N, (ni + 1) * cfg.cols),
            x_src=x_homes[(infos[g].name, m)], w_src=w_home[(g, ki, ni)])

    def canon(kind: str, key: tuple) -> tuple:
        """Bookkeeping key for the resident-tile maps: local (kind, key)
        without a session; program-independent *global* keys with one --
        weights by (name, dtype, tile), activations scoped to this
        program's epoch (never warm across programs), kv keys already
        global."""
        if session is None:
            return (kind, key)
        if kind == "w":
            g, k0, n0 = key
            return ("w", specs[g].name, infos[g].name, k0, n0)
        if kind == "kv":
            return ("kv",) + tuple(key)
        if multi:
            d, m, k0 = key
        else:
            m, k0 = key
            d = infos[0].name
        return ("x", session.epoch, d, m, k0)

    def unit_keys(u):
        g, m, ki, ni = u
        k0, n0 = ki * kts[g], ni * cfg.cols
        xkey = (infos[g].name, m, k0) if multi else (m, k0)
        if specs[g].kv:
            wkk = canon("kv", (specs[g].kv, k0, n0))
        else:
            wkk = canon("w", (g, k0, n0))
        return canon("x", xkey), wkk

    if session is not None:
        resident = session.resident
        for b in compute_blocks:
            resident.setdefault(b, {})
    else:
        resident = {b: {} for b in compute_blocks}
    rbase = session.clock if session is not None else 0
    rounds: List[Round] = []
    for c, units in segments:
        x_keys = {u: unit_keys(u)[0] for u in units}
        w_keys = {u: unit_keys(u)[1] for u in units}
        for r0 in range(0, len(units), n_compute):
            chunk = units[r0:r0 + n_compute]
            if cfg.residency:
                assign = _assign_slots(chunk, compute_blocks, resident,
                                       x_keys, w_keys)
            else:
                assign = {u: compute_blocks[i] for i, u in enumerate(chunk)}
            tasks = tuple(unit_task(u, assign[u]) for u in chunk)

            # load stage: group this round's tile reads by (kind, key);
            # each group is ONE fetch broadcast to the blocks that miss
            order: List[tuple] = []
            needs: Dict[tuple, list] = {}
            pinned: Dict[int, set] = {b: set() for b in compute_blocks}
            for t in tasks:
                for kind, key, src, bits in _task_operands(t, specs, infos,
                                                           multi):
                    kk = canon(kind, key)
                    if kk not in needs:
                        needs[kk] = [kind, key, src, bits, []]
                        order.append(kk)
                    if t.block not in needs[kk][4]:
                        needs[kk][4].append(t.block)
                    pinned[t.block].add(kk)

            rindex = rbase + len(rounds)
            loads = []
            for kk in order:
                kind, lkey, src, bits, dsts = needs[kk]
                if cfg.residency and kind == "kv" and session is not None:
                    # append-addressed growing tile: a holder of an
                    # earlier prefix fetches only the delta; holders of
                    # distinct prefixes split into separate delta nets
                    groups: Dict[int, list] = {}
                    for d in dsts:
                        seen = resident[d][kk][0] if kk in resident[d] else 0
                        if seen >= bits:
                            resident[d][kk][1] = rindex    # full hit
                        else:
                            groups.setdefault(seen, []).append(d)
                    for seen in sorted(groups):
                        loads.append(TileLoad(
                            kind="kv", key=lkey, src=src,
                            dsts=tuple(groups[seen]), bits=bits - seen))
                        for d in groups[seen]:
                            resident[d][kk] = [bits, rindex]
                            _evict_lru(resident[d], cfg.block_bits,
                                       pinned[d])
                    continue
                if cfg.residency:
                    missing = [d for d in dsts if kk not in resident[d]]
                    for d in dsts:
                        if kk in resident[d]:
                            resident[d][kk][1] = rindex    # LRU touch
                else:
                    missing = dsts
                if not missing:
                    continue                               # all-hit: no net
                loads.append(TileLoad(kind=kind, key=lkey, src=src,
                                      dsts=tuple(missing), bits=bits))
                if cfg.residency:
                    for d in missing:
                        resident[d][kk] = [bits, rindex]
                        _evict_lru(resident[d], cfg.block_bits, pinned[d])
            rounds.append(Round(tasks=tasks, loads=tuple(loads), dtype=c))

    if session is not None:
        session.clock = rbase + len(rounds)
        step = session._step()
        for rnd in rounds:
            for ld in rnd.loads:
                step["fetches"] += 1
                step["fetch_bits"] += ld.bits
                if ld.kind == "w":
                    step["w_fetches"] += 1
                elif ld.kind == "kv":
                    step["kv_fetch_bits"] += ld.bits

    return FabricProgram(cfg=cfg, nbits=nbits, signed=signed, gemms=specs,
                         kt=kts[0], modes=modes, x_home=x_home,
                         w_home=w_home, rounds=tuple(rounds),
                         dtypes=tuple(i.name for i in infos), kts=kts,
                         x_home_ext={k: v for k, v in x_homes.items()
                                     if k[0] != classes[0]})


def schedule_gemm(M: int, K: int, N: int, nbits: int,
                  cfg: FabricConfig = FabricConfig(),
                  signed: bool = False) -> FabricProgram:
    """Plan ``(M, K) @ (K, N)`` onto the block grid (no execution)."""
    return schedule_program((GemmSpec("gemm", M, K, N),), nbits,
                            cfg=cfg, signed=signed)


def residency_stats(sched: FabricProgram) -> dict:
    """Audit the load stage: fetches vs resident hits, from the IR alone.

    ``reads`` counts every (task, operand) pair; ``fetches`` counts
    :class:`TileLoad` nets (a broadcast is ONE fetch); a pair not
    covered by a same-round load destination was served by the block's
    resident-tile map (``hits``).  ``reload_fetches`` is what the PR 3
    reload-every-round load stage would have issued (one net per
    distinct tile per round) -- ``fetch_reduction`` is the headline
    residency win the fabric benchmark gates on.
    """
    reads = fetch_pairs = fetches = reload_fetches = 0
    fetch_bits = reload_bits = 0.0
    infos = sched.infos()
    multi = sched.multi
    for rnd in sched.rounds:
        loaded = {}
        for ld in rnd.loads:
            fetches += 1
            fetch_bits += ld.bits
            # kv delta loads of one growing tile may split into several
            # nets (per distinct resident prefix): union the coverage
            loaded.setdefault((ld.kind, tuple(ld.key)), set()).update(
                ld.dsts)
        round_keys = {}
        for t in rnd.tasks:
            for kind, key, _src, bits in _task_operands(t, sched.gemms,
                                                        infos, multi):
                kk = (kind, key)
                reads += 1
                round_keys[kk] = bits
                if t.block in loaded.get(kk, ()):
                    fetch_pairs += 1
        reload_fetches += len(round_keys)
        reload_bits += sum(round_keys.values())
    hits = reads - fetch_pairs
    return {
        "reads": reads,
        "fetches": fetches,
        "fetch_bits": fetch_bits,
        "hits": hits,
        "hit_rate": hits / max(reads, 1),
        "reload_fetches": reload_fetches,
        "reload_fetch_bits": reload_bits,
        "fetch_reduction": reload_fetches / max(fetches, 1),
    }


# ---------------------------------------------------------------------------
# Fault repair: remap dead blocks onto spares, or reschedule degraded
# ---------------------------------------------------------------------------
def repair_program(sched: FabricProgram, dead,
                   fm: Optional[faults_core.FaultModel] = None,
                   session: Optional[FabricSession] = None
                   ) -> FabricProgram:
    """Remap dead blocks out of a fabric program (docs/faults.md).

    ``dead`` is a collection of grid block ids diagnosed dead (a hard
    whole-block fault).  Repair is tiered:

    1. a dead block the schedule never used (an idle spare, or already
       marked dead) costs nothing -- the program is returned unchanged;
    2. each dead *used* block is remapped onto the nearest live spare by
       Manhattan hops (ties broken by lower id, deterministic): the
       spare inherits the dead block's mode and every task, operand
       home, and load net is rewritten to the new site.  Bit-exact --
       only the wire distances (and thus the cost roll-up) change;
    3. with too few spares, the program is **rescheduled on a degraded
       grid** of the surviving block count (sites renumbered densely) --
       still exact, but the schedule shape may change (fewer rounds'
       worth of parallelism);
    4. if even the degraded grid cannot host the program,
       :class:`repro.core.faults.FabricFaultError` is raised -- the
       serve layer's cue to retry elsewhere or fall back to the ref
       path.

    ``fm`` (optional :class:`repro.core.faults.FaultModel`) receives the
    remap count for the health report.

    ``session`` (optional :class:`FabricSession`) is kept consistent
    with the repair: a spare remap moves the dead block's storage homes
    onto the spare and DROPS a dead compute block's resident-tile map
    (the spare starts cold -- reusing the dead block's map on the spare
    would be silent wrong reuse); a degraded-grid reschedule resets the
    session entirely (the dense renumbering invalidates every home and
    resident entry), so the next program re-warms from cold.
    """
    cfg = sched.cfg
    dead = {int(b) for b in dead if 0 <= int(b) < cfg.n_blocks}
    used = {b for b, m in enumerate(sched.modes)
            if m in ("compute", "storage")}
    dead_used = sorted(dead & used)
    if not dead_used:
        return sched
    spares = [b for b, m in enumerate(sched.modes)
              if m == "spare" and b not in dead]
    if len(spares) >= len(dead_used):
        mapping = {}
        avail = list(spares)
        for b in dead_used:
            s = min(avail, key=lambda sp: (cfg.hops(b, sp), sp))
            avail.remove(s)
            mapping[b] = s
        if fm is not None:
            fm.remaps += len(mapping)
        if session is not None:
            session.apply_remap(mapping)

        def remap(b: int) -> int:
            return mapping.get(b, b) if b >= 0 else b

        modes = list(sched.modes)
        for b, s in mapping.items():
            modes[s] = modes[b]
            modes[b] = "dead"
        rounds = tuple(
            Round(tasks=tuple(
                      dataclasses.replace(t, block=remap(t.block),
                                          x_src=remap(t.x_src),
                                          w_src=remap(t.w_src))
                      for t in r.tasks),
                  loads=tuple(
                      dataclasses.replace(ld, src=remap(ld.src),
                                          dsts=tuple(remap(d)
                                                     for d in ld.dsts))
                      for ld in r.loads),
                  dtype=r.dtype)
            for r in sched.rounds)
        return dataclasses.replace(
            sched, modes=tuple(modes),
            x_home=tuple(remap(b) for b in sched.x_home),
            w_home={k: remap(v) for k, v in sched.w_home.items()},
            x_home_ext={k: remap(v) for k, v in sched.x_home_ext.items()},
            rounds=rounds)

    # not enough spares: degraded-grid reschedule on the survivors
    alive = cfg.n_blocks - len(dead)
    if alive < 1:
        raise FabricFaultError(
            f"all {cfg.n_blocks} blocks dead; nothing to reschedule onto")
    if fm is not None:
        fm.remaps += len(dead_used)
    if session is not None:
        session.reset()               # dense renumbering: nothing survives
    degraded = dataclasses.replace(
        cfg, n_blocks=alive, spare_blocks=0,
        min_compute_blocks=min(cfg.min_compute_blocks, alive))
    try:
        return schedule_program(sched.gemms, sched.nbits, cfg=degraded,
                                signed=sched.signed)
    except ValueError as e:
        raise FabricFaultError(
            f"degraded grid of {alive} block(s) cannot host the "
            f"program: {e}") from e


# ---------------------------------------------------------------------------
# Exact execution on the block simulator
# ---------------------------------------------------------------------------
# Cap on blocks per batched launch: bounds host memory for huge
# schedules (rounds are chunked; the final chunk is zero-padded so one
# compiled wide fn serves every chunk of a schedule).
MAX_BATCH_BLOCKS = 512


def execute_program(sched: FabricProgram, x_u: np.ndarray,
                    w_us: Sequence[np.ndarray],
                    executor: Optional[str] = None,
                    batch_rounds: Optional[bool] = None,
                    max_batch_blocks: int = MAX_BATCH_BLOCKS,
                    x_alt: Optional[Dict[str, np.ndarray]] = None,
                    packed: Optional[bool] = None,
                    faults: Optional[faults_core.FaultModel] = None,
                    dead_repaired: bool = False,
                    session: Optional[FabricSession] = None
                    ) -> List[np.ndarray]:
    """Run the program's rounds exactly; operands already encoded.

    x_u ``(M, K)`` is the shared activation in the *primary* dtype
    class's encoding (unsigned ``< 2^bits`` for ints -- signed callers
    bias first -- and fmt bit patterns for floats); ``w_us[g]`` is GEMM
    *g*'s ``(K, N_g)`` weight in its own dtype's encoding.  For
    mixed-precision programs ``x_alt`` maps every non-primary dtype
    class name to its activation encoding.  Returns one raw ``(M, N_g)``
    uint64 image per fused GEMM: the accumulator for int GEMMs (callers
    apply the signed zero-point correction; see :func:`fabric_matmul`)
    and the rounded fmt bit pattern for float GEMMs.

    ``batch_rounds`` (default: on for the compiled executor) batches
    rounds into wide ``engine.execute_blocks`` launches (rounds = extra
    block-columns), chunked at ``max_batch_blocks``.  Rounds batch only
    with neighbours replaying the SAME program on independent data: a
    dtype-class boundary splits the batch, and float rounds batch
    per K-stage -- a float output tile's k-tiles chain through the wide
    accumulator image, which the host carries between stages, so the
    result is bit-identical to the per-round loop *and* independent of
    the K-tiling.

    ``packed`` selects the compiled interior representation and is
    forwarded to ``engine.execute_blocks``: the default ``None``
    resolves per program via ``engine.default_packed`` -- the int
    dot/mul round programs go through the uint32 bit-plane interior
    (where the wide-block scaling win lives) while the big float
    sequences keep the bool interior and its fast compiles.  Either
    setting is bit-identical.

    An active ``faults`` model (:class:`repro.core.faults.FaultModel`)
    injects seeded bit flips into every launch's packed block images
    and parity-scrubs on the model's cadence *before* the blocks
    execute: a dirty slot is restored from its pristine image (the
    re-pack from the backing operands -- the re-fetch the cost model
    prices).  Dead blocks must have been remapped away first
    (:func:`repair_program`); an unrepaired dead block that the
    schedule still uses raises
    :class:`repro.core.faults.FabricFaultError`.

    ``session`` (optional :class:`FabricSession`) is consulted only by
    the fault path: a parity scrub that restores a block from its
    pristine image re-packed *this launch's* operands only, so any
    session resident-tile entries for that physical block -- which may
    describe tiles of OTHER programs scheduled against warm state -- can
    no longer be trusted and are invalidated
    (:meth:`FabricSession.invalidate_blocks`); the next program through
    the session refetches them.  Residency itself was already consumed
    at schedule time, so execution is unaffected.
    """
    import jax.numpy as jnp

    cfg = sched.cfg
    executor = executor or cfg.executor
    fm = faults if (faults is not None and faults.active) else None
    # ``dead_repaired`` (set by fabric_fused_matmul after repair_program)
    # suppresses this guard: a degraded-grid reschedule renumbers block
    # ids densely, so the model's physical dead ids may coincide with
    # live logical ids of the repaired schedule.
    if fm is not None and fm.dead_blocks and not fm.healed \
            and not dead_repaired:
        unrepaired = sorted(
            set(fm.dead_blocks)
            & {b for b, m in enumerate(sched.modes)
               if m in ("compute", "storage")})
        if unrepaired:
            raise FabricFaultError(
                f"dead block(s) {unrepaired} still mapped by the "
                f"schedule; run repair_program first")
    if batch_rounds is None:
        batch_rounds = executor == "compiled" and len(sched.rounds) > 1
    infos = sched.infos()
    classes = sched.classes
    primary = classes[0]
    x_encs = {primary: np.asarray(x_u, np.uint64)}
    for name, enc in (x_alt or {}).items():
        x_encs[name] = np.asarray(enc, np.uint64)
    missing = [c for c in classes if c not in x_encs]
    if missing:
        raise ValueError(
            f"missing activation encoding(s) for dtype class(es) "
            f"{missing} (pass x_alt)")
    w_us = [np.asarray(w, np.uint64) for w in w_us]
    if len(w_us) != len(sched.gemms):
        raise ValueError(f"{len(w_us)} weight operand(s) for a "
                         f"{len(sched.gemms)}-GEMM program")
    M, K = sched.M, sched.K
    for g, (spec, w_u) in enumerate(zip(sched.gemms, w_us)):
        info = infos[g]
        width = info.fmt.width if info.is_float else info.bits
        x_enc = x_encs[info.name]
        if x_enc.shape != (M, K) or w_u.shape != (K, spec.N):
            raise ValueError(
                f"operands {x_enc.shape} @ {w_u.shape} do not match "
                f"schedule {M}x{K}x{spec.N} (gemm {spec.name})")
        if np.any(w_u >= (1 << width)) or np.any(x_enc >= (1 << width)):
            raise ValueError(f"operands must be < 2^{width} "
                             f"({info.name} gemm {spec.name})")

    progs = {c: sched.class_program(c) for c in classes}
    class_info = {c: _dtype_info(c) for c in classes}
    compute_blocks = sched.compute_blocks
    slot_of = {b: i for i, b in enumerate(compute_blocks)}
    n_compute = len(compute_blocks)
    outs = [np.zeros((M, spec.N), np.uint64) for spec in sched.gemms]
    # float chaining state: (gemm, m, n0) -> (cols,) wide acc image
    accs: Dict[Tuple[int, int, int], np.ndarray] = {}

    def pack_blocks(c: str, tasks_slots, n_slots: int) -> np.ndarray:
        """Vectorized pack: all (task, block-slot) pairs of one launch.

        Bit-plane transposition runs once per bit over every block at
        once (numpy broadcasting) instead of once per task -- identical
        images to ``harness.pack_state`` per block, but the host-side
        cost no longer scales with task count.
        """
        _, lay = progs[c]
        kt = sched.class_kt(c)
        a_vals = np.zeros((n_slots, kt, cfg.cols), np.uint64)
        b_vals = np.zeros((n_slots, kt, cfg.cols), np.uint64)
        for t, slot in tasks_slots:
            kw, nw = t.k1 - t.k0, t.n1 - t.n0
            a_vals[slot, :kw, :] = \
                x_encs[c][t.m, t.k0:t.k1][:, None]           # -> cols
            b_vals[slot, :kw, :nw] = w_us[t.gemm][t.k0:t.k1, t.n0:t.n1]
        arrs = np.zeros((n_slots, cfg.rows, cfg.cols), bool)
        bases = np.array([lay.base(i) for i in range(kt)])
        for name, vals in (("a", a_vals), ("b", b_vals)):
            off, width = lay.fields[name]
            for i in range(width):
                arrs[:, bases + off + i, :] = \
                    ((vals >> np.uint64(i)) & np.uint64(1)).astype(bool)
        if class_info[c].is_float:
            fmt = class_info[c].fmt
            for t, slot in tasks_slots:
                if t.k0 == 0:
                    continue          # fresh accumulator (+0 image)
                acc = accs[(t.gemm, t.m, t.n0)]
                floatprog.fdot_set_acc(arrs[slot], fmt, acc)
        return arrs

    def unpack_int(c: str, res: np.ndarray) -> np.ndarray:
        """(blocks, rows, cols) result image -> (blocks, cols) accs."""
        _, lay = progs[c]
        acc = np.zeros((res.shape[0], res.shape[2]), np.uint64)
        for i in range(lay.acc_bits):
            acc |= res[:, i, :].astype(np.uint64) << np.uint64(i)
        return acc

    launch_idx = [0]                   # scrub cadence counts launches

    def faulted(arrs: np.ndarray) -> np.ndarray:
        """Inject + (on cadence) parity-scrub one launch's block images."""
        pristine = arrs
        blocks, rows_, cols_ = arrs.shape
        fm.parity_bits = max(fm.parity_bits,
                             blocks * faults_core.parity_bits(rows_, cols_))
        sig = faults_core.parity_signature(pristine)
        out = faults_core.inject(pristine.copy(), fm, dead_slots=())
        if fm.scrub and launch_idx[0] % fm.scrub_every == 0:
            if session is not None:
                # a scrubbed slot's restored image holds only THIS
                # launch's operands -- drop the physical block's warm
                # residency so later programs refetch instead of
                # silently reusing a state the scrub rewrote
                dirty = faults_core.dirty_blocks(out, sig)
                if dirty.any():
                    session.invalidate_blocks(
                        compute_blocks[s % n_compute]
                        for s in np.nonzero(dirty)[0])
            out = faults_core.scrub_states(out, pristine, sig, fm)
        launch_idx[0] += 1
        return out

    def launch(c: str, arrs: np.ndarray) -> np.ndarray:
        if fm is not None:
            arrs = faulted(arrs)
        blocks = arrs.shape[0]
        states = engine.CRState(
            array=jnp.asarray(arrs),
            carry=jnp.zeros((blocks, cfg.cols), bool),
            tag=jnp.ones((blocks, cfg.cols), bool))
        return np.asarray(engine.execute_blocks(
            progs[c][0], states, executor=executor, packed=packed).array)

    def consume(c: str, slots, res: np.ndarray) -> None:
        info = class_info[c]
        if not info.is_float:
            acc = unpack_int(c, res)
            for t, slot in slots:
                outs[t.gemm][t.m, t.n0:t.n1] += acc[slot, : t.n1 - t.n0]
            return
        fmt = info.fmt
        for t, slot in slots:
            nw = t.n1 - t.n0
            accs[(t.gemm, t.m, t.n0)] = \
                floatprog.fdot_acc(res[slot], fmt)
            if t.k1 == K:             # final K-stage: rounded result
                outs[t.gemm][t.m, t.n0:t.n1] = \
                    floatprog.fdot_result(res[slot], fmt)[:nw]

    def round_stage(rnd: Round):
        """Batch key: rounds batch only within (class, float K-stage)."""
        c = rnd.dtype or primary
        if class_info[c].is_float and rnd.tasks:
            return c, rnd.tasks[0].k0
        return c, None

    # group consecutive batchable rounds, then chunk each group
    groups: List[Tuple[str, List[Round]]] = []
    for rnd in sched.rounds:
        key = round_stage(rnd)
        if batch_rounds and groups and groups[-1][0] == key:
            groups[-1][1].append(rnd)
        else:
            groups.append((key, [rnd]))

    for (c, _stage), rlist in groups:
        R = len(rlist)
        chunk_r = max(1, min(R, max(max_batch_blocks, n_compute)
                             // n_compute))
        for c0 in range(0, R, chunk_r):
            chunk = rlist[c0:c0 + chunk_r]
            slots = [(t, ri * n_compute + slot_of[t.block])
                     for ri, rnd in enumerate(chunk) for t in rnd.tasks]
            # the last chunk stays zero-padded to the chunk shape so ONE
            # compiled wide fn serves every chunk of the group
            consume(c, slots, launch(
                c, pack_blocks(c, slots, chunk_r * n_compute)))
    return outs


def execute_schedule(sched: FabricProgram, x_u: np.ndarray, w_u: np.ndarray,
                     executor: Optional[str] = None,
                     batch_rounds: Optional[bool] = None,
                     max_batch_blocks: int = MAX_BATCH_BLOCKS,
                     packed: Optional[bool] = None) -> np.ndarray:
    """Single-GEMM wrapper of :func:`execute_program` (legacy surface)."""
    if len(sched.gemms) != 1:
        raise ValueError("execute_schedule is single-GEMM; use "
                         "execute_program for fused programs")
    return execute_program(sched, x_u, (w_u,), executor=executor,
                           batch_rounds=batch_rounds,
                           max_batch_blocks=max_batch_blocks,
                           packed=packed)[0]


@dataclasses.dataclass(frozen=True)
class FabricResult:
    out: np.ndarray
    schedule: FabricProgram
    cost: costmodel.ScheduleCost
    #: float GEMMs also surface the raw fmt bit patterns (``out`` is
    #: their exact float32 value); None for integer GEMMs.
    out_bits: Optional[np.ndarray] = None


@dataclasses.dataclass(frozen=True)
class FusedResult:
    """Outputs of one fused multi-GEMM fabric program (one per GEMM)."""
    outs: Tuple[np.ndarray, ...]
    schedule: FabricProgram
    cost: costmodel.ScheduleCost
    #: per-GEMM raw fmt bit patterns for float GEMMs (None for ints)
    bits: Tuple[Optional[np.ndarray], ...] = ()


def _encode_float_operand(arr: np.ndarray, fmt) -> np.ndarray:
    """Float array -> fmt bit patterns; unsigned ints pass through as
    already-packed bit patterns."""
    if np.issubdtype(arr.dtype, np.unsignedinteger):
        return arr.astype(np.uint64)
    return ref.to_bits(np.asarray(arr, np.float32),
                       fmt.ebits, fmt.mbits).astype(np.uint64)


def fabric_matmul(x, w, nbits: int = 4,
                  cfg: FabricConfig = FabricConfig(),
                  signed: bool = False, *,
                  dtype=None,
                  schedule: Optional[FabricProgram] = None,
                  batch_rounds: Optional[bool] = None,
                  faults: Optional[faults_core.FaultModel] = None,
                  session: Optional[FabricSession] = None
                  ) -> FabricResult:
    """Schedule, execute, and account ``(M, K) @ (K, N)`` on the fabric.

    Integer GEMMs (``dtype=None`` / ``"int4"`` / ...) are bit-exact vs
    ``x @ w`` in int64 for any operand in range.  Float GEMMs
    (``dtype=jnp.bfloat16`` / ``"bf16"`` / ``"fp16"`` / ``"fp8"``) take
    float arrays (converted by :func:`repro.core.ref.to_bits`, FTZ+RTZ)
    or pre-packed unsigned bit patterns, and are bit-exact vs the
    FTZ+RTZ fused-MAC reference :func:`repro.core.ref.float_matmul` --
    independent of grid size and K-tiling, because the wide accumulator
    image chains across K-tiles.  The cost report prices the *executed*
    schedule (same IR), so correctness and accounting never drift apart.

    ``schedule`` reuses a pre-built plan (e.g. the
    :func:`search_schedule` argmin) instead of re-planning; its shape /
    precision must match the operands.  ``batch_rounds`` is forwarded to
    :func:`execute_schedule`.  ``session`` threads a
    :class:`FabricSession` through scheduling so sequential calls reuse
    warm resident tiles (see :func:`fabric_fused_matmul`).
    """
    res = fabric_fused_matmul(x, (w,), nbits=nbits, cfg=cfg, signed=signed,
                              dtypes=(dtype,), program=schedule,
                              batch_rounds=batch_rounds, faults=faults,
                              session=session)
    return FabricResult(out=res.outs[0], schedule=res.schedule,
                        cost=res.cost,
                        out_bits=res.bits[0] if res.bits else None)


def fabric_fused_matmul(x, ws: Sequence, nbits: int = 4,
                        cfg: FabricConfig = FabricConfig(),
                        signed: bool = False, *,
                        names: Optional[Sequence[str]] = None,
                        dtypes: Optional[Sequence] = None,
                        program: Optional[FabricProgram] = None,
                        batch_rounds: Optional[bool] = None,
                        faults: Optional[faults_core.FaultModel] = None,
                        specs: Optional[Sequence[GemmSpec]] = None,
                        session: Optional[FabricSession] = None
                        ) -> FusedResult:
    """Run several GEMMs sharing activations as ONE fabric program.

    ``x (M, K) @ ws[g] (K, N_g)`` for every g -- the fused-QKV case: one
    grid allocation, shared activation residency, one batched wide-block
    launch.  Bit-exact per GEMM vs ``x @ ws[g]`` in int64 (int GEMMs) /
    vs :func:`repro.core.ref.float_matmul` (float GEMMs).

    ``dtypes`` assigns a per-GEMM element type (None entries = the
    int{nbits} default), enabling **asymmetric precision**: int4, int8
    and bf16 GEMMs coexisting in one program (e.g. int8 QKV + a bf16
    output projection).  Every float GEMM reads the shared activation
    through its own encoding (``ref.to_bits`` of ``x`` as float32 --
    exact whenever x holds small integers); int GEMMs require an
    integer-valued ``x`` in range, exactly as before.

    ``program`` reuses a pre-built plan (e.g. the :func:`search_program`
    argmin); its shapes / precision / dtypes must match the operands.

    ``faults`` (:class:`repro.core.faults.FaultModel`, default None =
    pristine SRAM) enables the fault path: dead blocks are repaired out
    of the schedule first (:func:`repair_program` -- spare remap or
    degraded reschedule), bit flips are injected + parity-scrubbed per
    launch inside :func:`execute_program`, and the returned cost adds
    the honest fault overhead (parity storage, scrub reads, re-fetch
    traffic via :func:`repro.core.costmodel.fault_cost`).

    ``specs`` overrides the auto-built :class:`GemmSpec` tuple -- the
    way to declare ``kv=`` cache tiles or custom stable names while
    still letting this call schedule; shapes must match the operands.
    Ignored when ``program`` is given (the program carries its specs).

    ``session`` threads a :class:`FabricSession` through scheduling:
    sequential calls against the same session schedule WARM -- weight
    tiles resident from earlier programs emit no :class:`TileLoad`, and
    the session's trajectory records the per-call cost.  With both
    ``program`` and ``session``, the program acts as the plan template
    (its specs / cfg / precision) and is re-scheduled against the
    session's current residency -- a pre-tuned plan stays pre-tuned
    while later steps still get the warm-state savings.  Outputs are
    bit-identical with or without a session: execution always re-packs
    from the host-side operands; residency is a cost/IR concept.
    """
    x = np.asarray(x)
    ws = [np.asarray(w) for w in ws]
    if names is None:
        names = [f"gemm{g}" for g in range(len(ws))]
    if dtypes is None:
        dtypes = (None,) * len(ws)
    if len(dtypes) != len(ws):
        raise ValueError(f"{len(dtypes)} dtype(s) for {len(ws)} GEMM(s)")
    rinfos = tuple(cram.resolve_dtype(d) or _dtype_info(f"int{nbits}")
                   for d in dtypes)
    if program is None:
        if specs is None:
            specs = tuple(GemmSpec(str(names[g]), x.shape[0], x.shape[1],
                                   ws[g].shape[1],
                                   dtype=(rinfos[g].name
                                          if dtypes[g] is not None
                                          else None))
                          for g in range(len(ws)))
        else:
            specs = tuple(specs)
            if len(specs) != len(ws):
                raise ValueError(
                    f"{len(specs)} spec(s) for {len(ws)} GEMM(s)")
            rinfos = tuple(cram.resolve_dtype(s.dtype)
                           or _dtype_info(f"int{nbits}") for s in specs)
        sched = schedule_program(specs, nbits, cfg=cfg, signed=signed,
                                 session=session)
    else:
        sched = program
        shapes = tuple((g.M, g.K, g.N) for g in sched.gemms)
        want = tuple((x.shape[0], x.shape[1], w.shape[1]) for w in ws)
        have_dt = tuple(sched.dtype_of(g) for g in range(len(sched.gemms)))
        want_dt = tuple(i.name for i in rinfos)
        if shapes != want or sched.nbits != nbits \
                or sched.signed != signed or have_dt != want_dt:
            raise ValueError(
                f"program {shapes}/int{sched.nbits}"
                f"{'s' if sched.signed else 'u'}/{have_dt} does not match "
                f"operands {want} int{nbits}{'s' if signed else 'u'}"
                f"/{want_dt}")
        if session is not None:
            # the program is the plan template; re-schedule its specs on
            # its cfg against the session's warm residency so a tuned
            # plan keeps its geometry AND gets the cross-call savings
            sched = schedule_program(sched.gemms, sched.nbits,
                                     cfg=sched.cfg, signed=sched.signed,
                                     session=session)
    infos = sched.infos()

    # encode the shared activation once per dtype class, weights per GEMM
    int_off: Dict[str, np.int64] = {}
    x_encs: Dict[str, np.ndarray] = {}
    for info in infos:
        if info.name in x_encs:
            continue
        if info.is_float:
            x_encs[info.name] = _encode_float_operand(x, info.fmt)
        elif signed:
            cram._check_range([x], info.bits, signed=True)
            xu, off = cram._bias_signed(x, info.bits)
            x_encs[info.name] = xu
            int_off[info.name] = off
        else:
            cram._check_range([x], info.bits, signed=False)
            x_encs[info.name] = np.asarray(x, np.uint64)
    w_encs = []
    for info, w in zip(infos, ws):
        if info.is_float:
            w_encs.append(_encode_float_operand(w, info.fmt))
        elif signed:
            cram._check_range([w], info.bits, signed=True)
            w_encs.append(cram._bias_signed(w, info.bits)[0])
        else:
            cram._check_range([w], info.bits, signed=False)
            w_encs.append(np.asarray(w, np.uint64))

    fm = faults if (faults is not None and faults.active) else None
    repaired = False
    if fm is not None and fm.dead_blocks and not fm.healed:
        sched = repair_program(sched, fm.dead_blocks, fm=fm,
                               session=session)
        repaired = True

    primary = sched.classes[0]
    x_alt = {c: enc for c, enc in x_encs.items() if c != primary}
    scrub0, refetch0 = ((fm.scrub_rows, fm.refetch_bits) if fm is not None
                        else (0, 0))
    raws = execute_program(sched, x_encs[primary], w_encs,
                           batch_rounds=batch_rounds,
                           x_alt=x_alt or None, faults=fm,
                           dead_repaired=repaired, session=session)

    outs, bits = [], []
    for info, raw, wu in zip(infos, raws, w_encs):
        if info.is_float:
            bits.append(raw.astype(np.uint32))
            outs.append(ref.from_bits(raw, info.fmt.ebits, info.fmt.mbits))
        elif signed:
            off = int_off[info.name]
            a_sums = x_encs[info.name].sum(axis=1, dtype=np.int64)[:, None]
            outs.append(cram._unbias(
                raw, off, a_sums, wu.sum(axis=0, dtype=np.int64)[None, :],
                x.shape[1]))
            bits.append(None)
        else:
            outs.append(raw)
            bits.append(None)
    cost = schedule_cost(sched)
    if fm is not None:
        fcost = costmodel.fault_cost(
            "fabric/fault_overhead", n_blocks=sched.cfg.n_blocks,
            cols=sched.cfg.cols, parity_bits=fm.parity_bits,
            scrub_rows=fm.scrub_rows - scrub0,
            refetch_bits=fm.refetch_bits - refetch0,
            edge_hops=sched.cfg.grid_diameter)
        cost = combine_costs(cost.name + "+faults", [cost, fcost])
    if session is not None:
        session.record_cost(cost)
    return FusedResult(outs=tuple(outs), schedule=sched,
                       cost=cost, bits=tuple(bits))


# ---------------------------------------------------------------------------
# Cost accounting (walks the IR, prices with core.costmodel)
# ---------------------------------------------------------------------------
def _broadcast_net_mm(cfg: FabricConfig, src: int,
                      dsts: Tuple[int, ...]) -> float:
    """Wire length of one multi-destination fabric net, by placement.

    The net spans the bounding box of the source and destination sites
    (a Steiner-tree approximation): its length is the Manhattan span in
    hops times the per-hop wire length -- so a broadcast to neighbours
    is short and one across the grid diameter is long.
    """
    sites = [cfg.site(src)] + [cfg.site(d) for d in dsts]
    rows_ = [s[0] for s in sites]
    cols_ = [s[1] for s in sites]
    span = (max(rows_) - min(rows_)) + (max(cols_) - min(cols_))
    return costmodel.hop_net_length_mm(span)


def _spill_net_mm(cfg: FabricConfig, dsts: Tuple[int, ...]) -> float:
    """Off-fabric fetch: the long I/O column plus the on-fabric hops
    from the host edge to the farthest destination block."""
    edge = max(cfg.edge_hops(d) for d in dsts)
    return costmodel.NET_LENGTH_SPILL_MM + costmodel.hop_net_length_mm(edge)


def schedule_cost(sched: FabricProgram) -> costmodel.ScheduleCost:
    """Roll one fabric program up into energy (pJ) / time (us).

    Event counts per round (transposed bit-serial layout):

    * operand load: each :class:`TileLoad` moves its payload bits ONCE,
      regardless of how many destinations the broadcast fans out to --
      the fetch is a single multi-destination net priced by the
      Manhattan span of the sites it touches (:func:`_broadcast_net_mm`;
      the spill path adds the off-fabric I/O column), and one read
      stream at the source.  Tiles served from a block's resident-tile
      map appear in NO load: residency savings are wire and storage
      savings the cost model sees directly.
    * storage-mode traffic: source rows read (``ceil(bits / row width)``
      at the home block, once per load) plus destination rows written
      per *fetched* copy (the tile spans ``kt * nbits`` rows of the
      compute block while it is still in storage mode; resident hits
      write nothing), plus ``ACC_BITS`` accumulator rows read back per
      task (the drain stage).
    * compute: every *started* block burns ``program.cycles()``
      compute-mode cycles; idle blocks in a partial round are never
      started (per-block start lines) and burn nothing.  Rounds
      serialize (lockstep launches), so the critical path still spans
      every round regardless of occupancy.

    Latency (CR-cycle units, storage rows converted at the BRAM/CR
    frequency ratio): ``serial_cycles`` lays every round's load ->
    compute -> drain end to end.  ``overlapped_cycles`` double-buffers:
    round *i+1*'s loads and round *i*'s drain run during round *i*'s
    compute, so each pipeline stage costs ``max(compute, next_load +
    drain)`` -- strictly less than serial for any schedule with >= 2
    rounds (the hidden work is positive), identical for 1 round.
    Residency shrinks the load stage of later rounds, so the pipeline
    model credits reuse with real cycles, not just energy.
    """
    cfg = sched.cfg
    infos = sched.infos()
    primary = sched.classes[0]
    cycles_of = {c: sched.class_program(c)[0].cycles()
                 for c in sched.classes}
    # per-task drain width: int tasks read back the 32-bit accumulator;
    # float tasks drain the *wide* accumulator image (K-tile chaining
    # moves the wide value, not just the rounded fmt result)
    drain_of = {g: (_wide_drain_bits(infos[g]) if infos[g].is_float
                    else ACC_BITS) for g in range(len(infos))}
    by_name = {infos[g].name: g for g in range(len(infos))}
    row_bits = cfg.cols

    n_active_cycles = 0.0
    round_cycles = 0.0
    fabric_bits = 0.0
    spill_bits = 0.0
    fabric_bit_mm = 0.0
    spill_bit_mm = 0.0
    load_rows = []                 # per round: src reads + dst writes
    drain_rows = []                # per round: accumulator readback
    cycles_rows = []               # per round: compute cycles
    for rnd in sched.rounds:
        cyc = cycles_of[rnd.dtype or primary]
        n_active_cycles += len(rnd.tasks) * cyc
        round_cycles += cyc
        cycles_rows.append(float(cyc))
        lr = 0.0
        for ld in rnd.loads:
            if ld.src >= 0:
                fabric_bits += ld.bits
                fabric_bit_mm += ld.bits * _broadcast_net_mm(cfg, ld.src,
                                                             ld.dsts)
                lr += math.ceil(ld.bits / row_bits)        # src reads, once
            else:
                spill_bits += ld.bits
                spill_bit_mm += ld.bits * _spill_net_mm(cfg, ld.dsts)
            # dst writes while the compute block is still in storage
            # mode -- one copy per destination that actually fetched;
            # the tile spans the load's class K-tile x element width
            if ld.kind == "w":
                g = ld.key[0]
                lr += len(ld.dsts) * sched.kt_of(g) * infos[g].bits
            elif ld.kind == "kv":
                # append-addressed cache tile: only the DELTA bits since
                # the destination last saw this tile land in new rows --
                # history already sits in place and is never rewritten
                lr += len(ld.dsts) * math.ceil(ld.bits / row_bits)
            else:
                g = by_name[ld.key[0]] if sched.multi else by_name[primary]
                lr += len(ld.dsts) * sched.kt_of(g) * infos[g].bits
        dr = 0.0
        for t in rnd.tasks:
            # result readback crosses the fabric to the host edge: hops
            # from the task's site to the I/O interface
            bits = drain_of[t.gemm] * (t.n1 - t.n0)
            fabric_bits += bits
            fabric_bit_mm += bits * costmodel.hop_net_length_mm(
                cfg.edge_hops(t.block))
            dr += drain_of[t.gemm]
        load_rows.append(lr)
        drain_rows.append(dr)
    rows_touched = sum(load_rows) + sum(drain_rows)

    ratio = costmodel.STORAGE_ROW_CR_CYCLES
    R = len(sched.rounds)
    serial = sum(load_rows[r] * ratio + cycles_rows[r]
                 + drain_rows[r] * ratio for r in range(R))
    overlapped = load_rows[0] * ratio
    for r in range(R - 1):
        overlapped += max(cycles_rows[r],
                          (load_rows[r + 1] + drain_rows[r]) * ratio)
    overlapped += cycles_rows[R - 1] + drain_rows[R - 1] * ratio

    shapes = "+".join(f"{g.M}x{g.K}x{g.N}" for g in sched.gemms)
    prec = "+".join(sched.classes) if sched.dtypes else f"int{sched.nbits}"
    return costmodel.schedule_cost_rollup(
        f"fabric/gemm{shapes}/{prec}",
        n_blocks=cfg.n_blocks, n_compute=sched.n_compute,
        n_storage=sched.n_storage, rounds=R,
        compute_block_cycles=float(n_active_cycles),
        round_cycles=float(round_cycles),
        storage_rows_touched=rows_touched,
        fabric_bits_moved=fabric_bits, spill_bits_moved=spill_bits,
        ops=sched.ops, serial_cycles=serial, overlapped_cycles=overlapped,
        fabric_bit_mm=fabric_bit_mm, spill_bit_mm=spill_bit_mm)


# ---------------------------------------------------------------------------
# Schedule autotuner: enumerate FabricConfig geometries x storage/compute
# splits x placements, price each candidate with the (cheap, pure-Python)
# costmodel roll-up -- NO execution -- and return the argmin program.
# ---------------------------------------------------------------------------
#: Paper §V-D block geometries (same 20 Kb capacity, different aspect).
GEOMETRY_CHOICES: Tuple[Tuple[int, int], ...] = tuple(
    sorted(costmodel.GEOMETRIES))

#: Objectives the search can minimize -> ScheduleCost accessor.
OBJECTIVES = {
    "overlapped_cycles": "overlapped_cycles_",
    "serial_cycles": "serial_cycles_",
    "time_us": "time_us",
    "energy_pj": "energy_pj",
    "energy_per_op_pj": "energy_per_op_pj",
}

# bounded memo (shared LRU implementation with the compile cache)
_SEARCH_MEMO = engine._LRUCache(128)


@dataclasses.dataclass(frozen=True)
class SearchResult:
    """Argmin of a schedule search plus the full priced candidate table.

    ``candidates`` holds one row per *distinct* schedule: geometry-
    equivalent configs (e.g. two ``min_compute_blocks`` values clamping
    to the same storage/compute split) are deduplicated before pricing,
    and every row carries the residency hit-rate/fetch columns so an
    autotune pick is explainable from the table alone.
    """
    schedule: FabricProgram
    cost: costmodel.ScheduleCost
    objective: str
    candidates: Tuple[dict, ...]     # one row per priced candidate

    @property
    def config(self) -> FabricConfig:
        return self.schedule.cfg

    def describe(self) -> str:
        c = self.schedule.cfg
        return (f"search[{self.objective}]: {len(self.candidates)} "
                f"candidate(s) -> {c.rows}x{c.cols} "
                f"min_compute={c.min_compute_blocks} {c.placement} "
                f"({getattr(self.cost, OBJECTIVES[self.objective]):.0f})")

    def candidate_table(self) -> str:
        """The priced candidate table, one aligned text row each."""
        cols = ("rows", "cols", "placement", "n_compute", "n_storage",
                "rounds", "hit_rate", "fetches", "objective",
                "energy_pj")
        head = " ".join(f"{c:>10}" for c in cols)
        body = [" ".join(f"{r[c]:>10}" for c in cols)
                for r in self.candidates]
        return "\n".join([head] + body)


def _split_choices(n_blocks: int) -> Tuple[int, ...]:
    """min_compute_blocks candidates: sweep the storage/compute split."""
    raw = {1, n_blocks // 4, n_blocks // 2, (3 * n_blocks) // 4, n_blocks}
    return tuple(sorted(x for x in raw if 1 <= x <= n_blocks))


def search_program(specs: Sequence[GemmSpec], nbits: int, *,
                   base: FabricConfig = FabricConfig(),
                   signed: bool = False,
                   geometries: Optional[Tuple[Tuple[int, int], ...]] = None,
                   splits: Optional[Tuple[int, ...]] = None,
                   placements: Optional[Tuple[str, ...]] = None,
                   objective: str = "overlapped_cycles") -> SearchResult:
    """Search geometries x splits x placements for one fabric program.

    Every candidate is planned with :func:`schedule_program` and priced
    with :func:`schedule_cost` -- pure Python on the IR, no simulator
    execution -- so the search is cheap enough to run per serving shape.
    The argmin program is returned ready for :func:`fabric_fused_matmul`
    (``program=``) / :func:`fabric_matmul` (``schedule=``).

    ``geometries`` defaults to the base grid's geometry plus the paper
    §V-D choices (:data:`GEOMETRY_CHOICES`).  Callers that will
    *execute* the winner on the simulator may want to pin ``geometries``
    to the base geometry only: each new (nbits, rows, kt) shape compiles
    a fresh program (seconds), whereas split/placement tuning reuses
    compiled programs.  ``splits`` defaults to a sweep of
    ``min_compute_blocks`` over the grid (:func:`_split_choices`);
    ``placements`` to :data:`PLACEMENT_CHOICES` (where the storage
    blocks sit -- the dimension the hop-priced wire model makes
    meaningful).

    Candidates that plan to an identical schedule (same geometry,
    placement, and resulting storage/compute split) are priced once.
    Results are memoized (bounded LRU) -- serving calls the search once
    per (shape, grid), not once per token.
    """
    if objective not in OBJECTIVES:
        raise ValueError(f"unknown objective {objective!r}; "
                         f"expected one of {sorted(OBJECTIVES)}")
    specs = tuple(specs)
    geometries = tuple(geometries) if geometries is not None else \
        tuple(dict.fromkeys(((base.rows, base.cols),) + GEOMETRY_CHOICES))
    splits = tuple(splits) if splits is not None else \
        _split_choices(base.n_blocks)
    placements = tuple(placements) if placements is not None else \
        PLACEMENT_CHOICES

    key = (specs, nbits, signed, base.n_blocks, base.executor,
           base.residency, geometries, splits, placements, objective)
    hit = _SEARCH_MEMO.get(key)
    if hit is not None:
        return hit

    attr = OBJECTIVES[objective]
    best = None
    best_val = None
    rows_out = []
    seen = set()
    for rows, cols in geometries:
        for placement in placements:
            for mcb in splits:
                if mcb > base.n_blocks:
                    continue
                cfg = FabricConfig(n_blocks=base.n_blocks, rows=rows,
                                   cols=cols, executor=base.executor,
                                   min_compute_blocks=mcb,
                                   placement=placement,
                                   residency=base.residency)
                try:
                    sched = schedule_program(specs, nbits, cfg=cfg,
                                             signed=signed)
                except ValueError:
                    continue           # geometry can't host the program
                sig = (rows, cols, placement, sched.n_compute)
                if sig in seen:        # geometry-equivalent: price once
                    continue
                seen.add(sig)
                cost = schedule_cost(sched)
                stats = residency_stats(sched)
                val = float(getattr(cost, attr))
                rows_out.append({
                    "rows": rows, "cols": cols, "min_compute": mcb,
                    "placement": placement,
                    "n_compute": sched.n_compute,
                    "n_storage": sched.n_storage,
                    "rounds": len(sched.rounds), "kt": sched.kt,
                    "objective": round(val, 3),
                    "serial_cycles": round(cost.serial_cycles_, 1),
                    "overlapped_cycles": round(cost.overlapped_cycles_, 1),
                    "energy_pj": round(cost.energy_pj, 3),
                    "fetches": stats["fetches"],
                    "hits": stats["hits"],
                    "hit_rate": round(stats["hit_rate"], 3),
                    "fetch_reduction": round(stats["fetch_reduction"], 3),
                })
                if best_val is None or val < best_val:
                    best, best_val = (sched, cost), val
    if best is None:
        shapes = "+".join(f"{g.M}x{g.K}x{g.N}" for g in specs)
        raise ValueError(
            f"no candidate geometry can schedule {shapes} int{nbits}")
    return _SEARCH_MEMO.put(key, SearchResult(
        schedule=best[0], cost=best[1], objective=objective,
        candidates=tuple(rows_out)))


def search_schedule(M: int, K: int, N: int, nbits: int, *,
                    base: FabricConfig = FabricConfig(),
                    signed: bool = False,
                    geometries: Optional[Tuple[Tuple[int, int], ...]] = None,
                    splits: Optional[Tuple[int, ...]] = None,
                    placements: Optional[Tuple[str, ...]] = None,
                    objective: str = "overlapped_cycles") -> SearchResult:
    """Single-GEMM wrapper of :func:`search_program` (legacy surface)."""
    return search_program((GemmSpec("gemm", M, K, N),), nbits, base=base,
                          signed=signed, geometries=geometries,
                          splits=splits, placements=placements,
                          objective=objective)


# ---------------------------------------------------------------------------
# Attention on the fabric (the paper's DL workload, via models/attention
# shapes: q/k are (B, S, H, hd) exactly as produced by ``_qkv``)
# ---------------------------------------------------------------------------
def _quantize_sym(x: np.ndarray, bits: int):
    """Symmetric per-tensor quantization to signed ``bits`` ints."""
    qmax = (1 << (bits - 1)) - 1
    amax = max(float(np.abs(x).max()), 1e-8)
    scale = amax / qmax
    q = np.clip(np.round(x / scale), -qmax - 1, qmax).astype(np.int64)
    return q, scale


def fabric_attention_scores(q: np.ndarray, k: np.ndarray,
                            cfg: FabricConfig = FabricConfig(),
                            bits: int = 8):
    """Attention score matmul ``q @ k^T`` per (batch, head) on the fabric.

    q: ``(B, Sq, H, hd)``, k: ``(B, Sk, H, hd)`` floats (the
    ``models.attention._qkv`` layout).  Each (batch, head) score tile is
    one fabric GEMM of the *quantized* operands; scores come back
    dequantized and pre-scaled by ``hd ** -0.5`` -- ready for the
    softmax of :func:`repro.models.attention.chunked_attention`.

    Returns ``(scores (B, Sq, H, Sk) float32, int_scores int64,
    costs list[ScheduleCost])``.
    """
    q = np.asarray(q, np.float32)
    k = np.asarray(k, np.float32)
    B, Sq, H, hd = q.shape
    Bk, Sk, Hk, hdk = k.shape
    if (B, H, hd) != (Bk, Hk, hdk):
        raise ValueError(f"q {q.shape} vs k {k.shape}")

    qq, sq = _quantize_sym(q, bits)
    qk, sk = _quantize_sym(k, bits)
    scores = np.zeros((B, Sq, H, Sk), np.float32)
    int_scores = np.zeros((B, Sq, H, Sk), np.int64)
    costs = []
    for b in range(B):
        for h in range(H):
            res = fabric_matmul(qq[b, :, h, :], qk[b, :, h, :].T,
                                nbits=bits, cfg=cfg, signed=True)
            int_scores[b, :, h, :] = res.out
            scores[b, :, h, :] = res.out * (sq * sk * hd ** -0.5)
            costs.append(res.cost)
    return scores, int_scores, costs


class FabricAttentionBlock:
    """A full single-head attention block decoding on ONE fabric session.

    Per decode step, four chained programs run on one grid allocation
    (the session pins the mode map at step 1):

    1. fused **QKV** projection -- ``x (1, d) @ wq/wk/wv (d, hd)``;
       weight tiles go resident at step 1 and emit NO loads afterwards;
    2. **scores** ``q (1, hd) @ K^T (hd, t)`` -- K^T is a session KV
       cache (``GemmSpec(kv="k", kv_axis="n")``): this step's column
       was *appended* in place, so the schedule charges only the delta;
    3. host softmax + **AV** ``p (1, t) @ V (t, hd)`` -- V is the
       second KV cache, growing along the K axis (``kv_axis="k"``);
    4. **output projection** ``a (1, hd) @ wo (hd, d)``.

    Quantization scales are FIXED after step-1 calibration (``sp`` is
    analytic: softmax outputs live in [0, 1]): an append-only cache
    cannot rescale history, so every step quantizes onto the same grid
    and the whole trajectory is replayable bit-exactly by a host int
    oracle applying the same scales (see tests).  Execution re-packs the
    host-side mirrors every launch, so outputs are bit-identical with or
    without the session -- the session changes the *accounting*
    (steady-state steps fetch ~nothing).
    """

    def __init__(self, wq, wk, wv, wo, cfg: FabricConfig = FabricConfig(),
                 bits: int = 8, window: int = 64,
                 session: Optional[FabricSession] = None):
        self.wq, self.wk, self.wv, self.wo = (
            np.asarray(w, np.float32) for w in (wq, wk, wv, wo))
        d, hd = self.wq.shape
        for name, w, shape in (("wk", self.wk, (d, hd)),
                               ("wv", self.wv, (d, hd)),
                               ("wo", self.wo, (hd, d))):
            if w.shape != shape:
                raise ValueError(f"{name} {w.shape}, expected {shape} "
                                 f"(wq is {self.wq.shape})")
        self.d, self.hd = d, hd
        self.cfg = cfg
        self.bits = bits
        self.window = window
        self.qmax = (1 << (bits - 1)) - 1
        # stationary weights: quantize ONCE (the session contract -- a
        # stable name must mean a stable weight)
        (self._qwq, self.swq), (self._qwk, self.swk), \
            (self._qwv, self.swv), (self._qwo, self.swo) = (
                _quantize_sym(w, bits)
                for w in (self.wq, self.wk, self.wv, self.wo))
        self.session = session if session is not None else FabricSession(cfg)
        self.session.reserve_kv("k", pos_bits=hd * bits, window=window)
        self.session.reserve_kv("v", pos_bits=hd * bits, window=window)
        # activation scales: calibrated at step 1, then FIXED
        self.sx = self.sq = self.sk = self.sv = self.so = None
        self.sp = 1.0 / self.qmax          # softmax probs: analytic scale
        # host-side mirrors of the on-fabric caches (execution packs
        # operands from the host; residency/kv is the cost-model view)
        self.k_cache = np.zeros((hd, 0), np.int64)     # K^T: (hd, t)
        self.v_cache = np.zeros((0, hd), np.int64)     # V:   (t, hd)

    @property
    def t(self) -> int:
        """Positions decoded so far (== both KV cache lengths)."""
        return self.v_cache.shape[0]

    def _qfix(self, x: np.ndarray, scale: float) -> np.ndarray:
        q = np.round(np.asarray(x, np.float32) / scale)
        return np.clip(q, -self.qmax - 1, self.qmax).astype(np.int64)

    def _cal(self, attr: str, x: np.ndarray) -> float:
        """First step: calibrate the scale; later steps: reuse it."""
        if getattr(self, attr) is None:
            amax = max(float(np.abs(x).max()), 1e-8)
            setattr(self, attr, amax / self.qmax)
        return getattr(self, attr)

    def decode_step(self, x_t):
        """One decode position: x_t ``(d,)`` or ``(1, d)`` float.

        Returns ``(y (1, d) float32, step stats dict)`` -- the stats
        are this step's session bucket (fetches, kv appends, costs).
        """
        if self.t >= self.window:
            raise ValueError(f"KV window exhausted ({self.window})")
        x = np.asarray(x_t, np.float32).reshape(1, self.d)
        step = self.session.begin_step()
        qx = self._qfix(x, self._cal("sx", x))

        qkv = fabric_fused_matmul(
            qx, (self._qwq, self._qwk, self._qwv), nbits=self.bits,
            cfg=self.cfg, signed=True, names=("wq", "wk", "wv"),
            session=self.session)
        q_f = qkv.outs[0] * (self.sx * self.swq)
        k_f = qkv.outs[1] * (self.sx * self.swk)
        v_f = qkv.outs[2] * (self.sx * self.swv)

        qq = self._qfix(q_f, self._cal("sq", q_f))
        qk = self._qfix(k_f, self._cal("sk", k_f))
        qv = self._qfix(v_f, self._cal("sv", v_f))
        # append this position's K column / V row -- grows IN PLACE on
        # the fabric (the host mirror grows for the next launch's pack)
        self.k_cache = np.hstack([self.k_cache, qk.T])
        self.v_cache = np.vstack([self.v_cache, qv])
        self.session.kv_append("k")
        self.session.kv_append("v")
        t = self.t

        scores = fabric_fused_matmul(
            qq, (self.k_cache,), nbits=self.bits, cfg=self.cfg,
            signed=True,
            specs=(GemmSpec("scores", 1, self.hd, t,
                            kv="k", kv_axis="n"),),
            session=self.session)
        s_f = scores.outs[0] * (self.sq * self.sk * self.hd ** -0.5)
        e = np.exp(s_f - s_f.max(axis=-1, keepdims=True))
        p = e / e.sum(axis=-1, keepdims=True)
        qp = self._qfix(p, self.sp)

        av = fabric_fused_matmul(
            qp, (self.v_cache,), nbits=self.bits, cfg=self.cfg,
            signed=True,
            specs=(GemmSpec("av", 1, t, self.hd, kv="v", kv_axis="k"),),
            session=self.session)
        a_f = av.outs[0] * (self.sp * self.sv)

        qa = self._qfix(a_f, self._cal("so", a_f))
        proj = fabric_fused_matmul(
            qa, (self._qwo,), nbits=self.bits, cfg=self.cfg,
            signed=True, names=("wo",), session=self.session)
        y = (proj.outs[0] * (self.so * self.swo)).astype(np.float32)
        return y, step

    def report(self) -> dict:
        """Session stats + trajectory (cold vs steady-state)."""
        return self.session.stats()


class FabricLinearProbe:
    """Run one decode step's linear projection(s) on the simulated fabric.

    Attached to :class:`repro.serve.engine.ServeEngine`, the probe takes
    the engine's *live* per-step activations (the token embeddings of
    the batch being decoded), quantizes activations and weights to
    ``bits``, and runs the projection as a fabric-scheduled GEMM --
    i.e. a small slice of a real decode step executes on the
    cycle-accurate block grid, with a cost report per step.

    ``w`` may be a single ``(d_in, d_out)`` weight or a *sequence* of
    them sharing ``d_in`` (the Q/K/V/... projections of one layer): a
    multi-weight probe runs the whole decode step's projections as ONE
    fused :class:`FabricProgram` -- shared activation residency, one
    grid allocation, one batched launch -- and ``observe`` returns a
    tuple of outputs.

    The fabric simulator is an oracle, not a serving fast path, so the
    probe only samples the first ``max_steps`` decode steps.

    ``autotune=True`` runs :func:`search_program` on the first observed
    activation shape and serves every sampled step from the argmin
    program -- serving picks its grid split and placement
    automatically.  The search is restricted to the probe's own block
    geometry by default (split/placement sweep only: executing a new
    geometry would compile a new program mid-serve); pass
    ``search_geometries`` to widen it.

    ``session=True`` gives the probe its own :class:`FabricSession`
    spanning the whole serve loop (pass an existing session to share
    one): each ``observe`` becomes a session *step*, so the probe's
    stationary weights go resident at step 1 and steps 2..N schedule
    warm -- ``report()`` then carries the cold-vs-steady trajectory.
    Outputs stay bit-identical to the sessionless probe.
    """

    def __init__(self, w, cfg: FabricConfig = FabricConfig(),
                 bits: int = 8, max_steps: int = 1,
                 autotune: bool = False,
                 search_geometries: Optional[tuple] = None,
                 faults: Optional[faults_core.FaultModel] = None,
                 session=None):
        ws = list(w) if isinstance(w, (list, tuple)) else [w]
        self.ws = tuple(np.asarray(wi, np.float32) for wi in ws)
        self.fused = isinstance(w, (list, tuple))
        for wi in self.ws:
            if wi.ndim != 2 or wi.shape[0] != self.ws[0].shape[0]:
                raise ValueError(
                    f"probe weights must be 2-D and share d_in, got "
                    f"{[tuple(x.shape) for x in self.ws]}")
        self.cfg = cfg
        self.bits = bits
        self.max_steps = max_steps
        self.autotune = autotune
        self.search_geometries = search_geometries
        self.search: Optional[SearchResult] = None
        self.costs: list = []
        self.outputs: list = []
        # per-step observed batch rows (the GEMM's M): under continuous
        # batching the engine feeds only ACTIVE lanes, so this traces
        # the live-batch size as slots recycle (docs/serve.md)
        self.observed_m: list = []
        # stationary weights quantize ONCE -- the session residency
        # contract (stable name = stable weight) and less per-step host
        # work for sessionless probes too
        self._qws, self._sws = zip(
            *(_quantize_sym(wi, self.bits) for wi in self.ws))
        self.session: Optional[FabricSession] = (
            FabricSession(cfg) if session is True else session)
        # fault path: inject via `faults` and cross-check every fabric
        # output against the cheap host int matmul of the SAME quantized
        # operands -- an exact oracle, so any escaped corruption is
        # caught at the serving boundary and raised as FabricFaultError
        # (the ServeEngine's retry/fallback cue) instead of silently
        # wrong tokens.
        self.faults = faults
        self.escaped_outputs = 0

    @property
    def w(self) -> np.ndarray:
        """Legacy single-weight accessor."""
        return self.ws[0]

    @property
    def done(self) -> bool:
        return len(self.costs) >= self.max_steps

    def _program_for(self, M: int, K: int) -> Optional[FabricProgram]:
        if not self.autotune:
            return None
        specs = tuple(GemmSpec(f"proj{g}", M, K, wi.shape[1])
                      for g, wi in enumerate(self.ws))
        if self.search is None or self.search.schedule.gemms != specs:
            geoms = self.search_geometries if self.search_geometries \
                is not None else ((self.cfg.rows, self.cfg.cols),)
            self.search = search_program(specs, self.bits, base=self.cfg,
                                         signed=True, geometries=geoms)
        return self.search.schedule

    def observe(self, x):
        """x: (B, d_in) float activation of the current decode step.

        Returns the probe's dequantized projection output: one array for
        a single-weight probe, a tuple (one per projection) for a fused
        probe; ``None`` once ``max_steps`` steps have been sampled.
        """
        if self.done:
            return None
        x = np.asarray(x, np.float32)
        qx, sx = _quantize_sym(x, self.bits)
        qws, sws = self._qws, self._sws
        prog = self._program_for(qx.shape[0], qx.shape[1])
        fm = self.faults if (self.faults is not None
                             and self.faults.active) else None
        if self.session is not None:
            self.session.begin_step()
        res = fabric_fused_matmul(qx, qws, nbits=self.bits, cfg=self.cfg,
                                  signed=True, program=prog, faults=fm,
                                  names=tuple(f"proj{g}" for g
                                              in range(len(self.ws))),
                                  session=self.session)
        if fm is not None:
            for g, (qw, out) in enumerate(zip(qws, res.outs)):
                expect = qx.astype(np.int64) @ np.asarray(qw, np.int64)
                if not np.array_equal(np.asarray(out, np.int64), expect):
                    fm.escaped += 1
                    self.escaped_outputs += 1
                    raise FabricFaultError(
                        f"escaped corruption: fabric projection {g} "
                        f"disagrees with the host oracle")
        ys = tuple(out.astype(np.float32) * (sx * sw)
                   for out, sw in zip(res.outs, sws))
        y = ys if self.fused else ys[0]
        self.costs.append(res.cost)
        self.outputs.append(y)
        self.observed_m.append(int(qx.shape[0]))
        return y

    def observe_ref(self, x):
        """The probe's projections on the host (``mode="ref"``): the
        graceful-degradation fallback when the fabric keeps faulting.
        Same quantization, no fabric execution, no cost sample."""
        x = np.asarray(x, np.float32)
        qx, sx = _quantize_sym(x, self.bits)
        ys = []
        for qw, sw in zip(self._qws, self._sws):
            ys.append((qx.astype(np.int64) @ qw).astype(np.float32)
                      * (sx * sw))
        return tuple(ys) if self.fused else ys[0]

    def config_summary(self) -> dict:
        """The grid the probe actually serves from (autotuned or not)."""
        cfg = self.search.schedule.cfg if self.search is not None else self.cfg
        return {
            "geometry": f"{cfg.rows}x{cfg.cols}",
            "n_blocks": cfg.n_blocks,
            "min_compute": cfg.min_compute_blocks,
            "placement": cfg.placement,
            "projections": len(self.ws),
            "autotuned": self.search is not None,
        }

    def report(self) -> Optional[dict]:
        if not self.costs:
            return None
        rep = combine_costs("fabric/decode_step", self.costs).report()
        rep.update(self.config_summary())
        rep["observed_m"] = list(self.observed_m)
        if self.session is not None and self.session.steps:
            rep["session"] = self.session.trajectory().report()
        if self.faults is not None:
            rep["faults"] = self.faults.stats()
            rep["escaped_outputs"] = self.escaped_outputs
        return rep


def combine_costs(name: str, costs) -> costmodel.ScheduleCost:
    """Sum a list of :class:`ScheduleCost` (sequential launches)."""
    if not costs:
        raise ValueError("no costs to combine")
    c0 = costs[0]
    return costmodel.ScheduleCost(
        name=name, n_blocks=c0.n_blocks,
        n_compute=max(c.n_compute for c in costs),
        n_storage=max(c.n_storage for c in costs),
        rounds=sum(c.rounds for c in costs),
        compute_block_cycles=sum(c.compute_block_cycles for c in costs),
        round_cycles=sum(c.round_cycles for c in costs),
        storage_rows_touched=sum(c.storage_rows_touched for c in costs),
        fabric_bits_moved=sum(c.fabric_bits_moved for c in costs),
        spill_bits_moved=sum(c.spill_bits_moved for c in costs),
        ops=sum(c.ops for c in costs),
        energy_compute_pj=sum(c.energy_compute_pj for c in costs),
        energy_storage_pj=sum(c.energy_storage_pj for c in costs),
        energy_wire_pj=sum(c.energy_wire_pj for c in costs),
        # sequential launches: serial latencies add; overlap only exists
        # within each schedule, so the pipelined latencies add too
        serial_cycles=sum(c.serial_cycles_ for c in costs),
        overlapped_cycles=sum(c.overlapped_cycles_ for c in costs),
        fabric_bit_mm=sum(c.fabric_bit_mm for c in costs),
        spill_bit_mm=sum(c.spill_bit_mm for c in costs))
