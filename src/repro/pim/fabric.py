"""Fabric scheduler: tile GEMM/attention across a Compute RAM block grid.

The paper's fabric-level claim (§IV, §V): an FPGA carries hundreds of
Compute RAM sites, each *dynamically* allocated to storage mode (a plain
BRAM holding operands) or compute mode (executing an instruction
sequence), and a DL workload is tiled across the grid.  This module is
that layer for the simulator: it turns "one block runs one program"
(:mod:`repro.pim.cram`) into "a simulated FPGA runs a matmul".

Pipeline
--------
1. :func:`schedule_gemm` builds an explicit :class:`Schedule` IR:

   * **mode map** -- each of the grid's ``n_blocks`` blocks is assigned
     ``storage`` (operand residency) or ``compute`` (paper §II dual-mode
     allocation).  Storage demand is sized from the operand footprint;
     whatever does not fit on-fabric is marked *spilled* (off-fabric
     memory, longer wires).
   * **tiling** -- K is tiled to the ``idot`` tuple capacity of the
     block geometry (:func:`repro.pim.cram.idot_geometry`, clamped so
     the int32 accumulator provably cannot overflow), N to the block's
     columns, and each output row ``m`` is one tile task.  Ragged edge
     tiles are zero-padded to the fixed tile geometry so **every round
     replays one compiled program**.
   * **rounds** -- tile tasks are packed ``n_compute`` at a time into
     :class:`Round`\\ s; one round is one ``engine.execute_blocks``
     launch.  Blocks without a task in a partial round are *not
     started* (each block has its own start line from the host FSM, so
     idle blocks burn no compute energy); the simulator still steps
     them on zeros purely as a wide-batch convenience, and their
     results are discarded.

2. :func:`execute_schedule` runs the rounds **exactly** on the block
   simulator and accumulates per-tile accumulators into the output.

3. :func:`schedule_cost` walks the same IR and prices it with
   :mod:`repro.core.costmodel` (compute-mode cycles, storage-mode row
   traffic, and block-to-block / spill wire energy for every operand
   move), returning a :class:`repro.core.costmodel.ScheduleCost`.

Signed operands use the same zero-point offset algebra as
:func:`repro.pim.cram.cram_matmul` (the blocks are unsigned-only
hardware); corrections are host-side sums.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core import costmodel, engine, harness, programs
from repro.pim import cram

ACC_BITS = 32


# ---------------------------------------------------------------------------
# Config + IR
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FabricConfig:
    """A grid of Compute RAM blocks (one simulated FPGA)."""
    n_blocks: int = 8
    rows: int = 512
    cols: int = 40
    executor: str = "compiled"
    min_compute_blocks: int = 1    # never storage-starve the grid

    @property
    def block_bits(self) -> int:
        return self.rows * self.cols

    def __post_init__(self):
        if self.n_blocks < 1:
            raise ValueError("fabric needs at least one block")
        if not 1 <= self.min_compute_blocks <= self.n_blocks:
            raise ValueError("min_compute_blocks out of range")


@dataclasses.dataclass(frozen=True)
class TileTask:
    """One (output-row, K-tile, N-tile) unit of work on one compute block."""
    block: int                 # compute-block slot executing this tile
    m: int                     # output row
    k0: int
    k1: int
    n0: int
    n1: int
    x_src: int                 # storage block holding x[m, :] (-1 = spill)
    w_src: int                 # storage block holding w tile (-1 = spill)


@dataclasses.dataclass(frozen=True)
class Round:
    """One lockstep ``execute_blocks`` launch over the compute blocks."""
    tasks: Tuple[TileTask, ...]


@dataclasses.dataclass(frozen=True)
class Schedule:
    """Explicit fabric schedule for one quantized GEMM (the IR every
    later scaling PR -- sharding, async rounds, multi-backend -- builds
    on)."""
    cfg: FabricConfig
    nbits: int
    signed: bool
    M: int
    K: int
    N: int
    kt: int                              # K-tile (idot tuples per launch)
    modes: Tuple[str, ...]               # per block: "compute" | "storage"
    x_home: Tuple[int, ...]              # per output row m -> block | -1
    w_home: Dict[Tuple[int, int], int]   # (k-tile, n-tile) -> block | -1
    rounds: Tuple[Round, ...]

    @property
    def n_compute(self) -> int:
        return self.modes.count("compute")

    @property
    def n_storage(self) -> int:
        return self.modes.count("storage")

    @property
    def program(self):
        """The single idot program every round replays."""
        prog, _ = programs.idot(self.nbits, rows=self.cfg.rows,
                                tuples=self.kt)
        return prog

    @property
    def ops(self) -> int:
        """Useful MACs (zero-padding excluded)."""
        return sum((t.k1 - t.k0) * (t.n1 - t.n0)
                   for r in self.rounds for t in r.tasks)

    def describe(self) -> str:
        lines = [
            f"Schedule {self.M}x{self.K}@{self.K}x{self.N} "
            f"int{self.nbits}{'s' if self.signed else 'u'} on "
            f"{self.cfg.n_blocks} blocks "
            f"({self.n_compute} compute / {self.n_storage} storage)",
            f"  K-tile={self.kt} tuples, N-tile={self.cfg.cols} cols, "
            f"{len(self.rounds)} round(s), "
            f"{sum(len(r.tasks) for r in self.rounds)} tile task(s)",
        ]
        spills = sum(1 for t_ in self.w_home.values() if t_ < 0) \
            + sum(1 for t_ in self.x_home if t_ < 0)
        if spills:
            lines.append(f"  {spills} operand(s) spilled off-fabric")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Scheduling
# ---------------------------------------------------------------------------
def schedule_gemm(M: int, K: int, N: int, nbits: int,
                  cfg: FabricConfig = FabricConfig(),
                  signed: bool = False) -> Schedule:
    """Plan ``(M, K) @ (K, N)`` onto the block grid (no execution)."""
    if min(M, K, N) < 1:
        raise ValueError(f"degenerate GEMM {M}x{K}x{N}")
    kt = cram.idot_tile(nbits, cfg.rows, ACC_BITS)
    k_tiles = math.ceil(K / kt)
    n_tiles = math.ceil(N / cfg.cols)

    # --- mode map: size storage demand, keep >= min_compute_blocks ----------
    w_tile_bits = {}
    for ki in range(k_tiles):
        for ni in range(n_tiles):
            kw = min(K, (ki + 1) * kt) - ki * kt
            nw = min(N, (ni + 1) * cfg.cols) - ni * cfg.cols
            w_tile_bits[(ki, ni)] = kw * nw * nbits
    x_row_bits = K * nbits
    total_bits = sum(w_tile_bits.values()) + M * x_row_bits
    n_storage = min(math.ceil(total_bits / cfg.block_bits),
                    cfg.n_blocks - cfg.min_compute_blocks)
    n_storage = max(n_storage, 0)
    n_compute = cfg.n_blocks - n_storage
    modes = tuple(["storage"] * n_storage + ["compute"] * n_compute)

    # --- operand residency: first-fit into the storage blocks ---------------
    free = [cfg.block_bits] * n_storage

    def place(bits: int) -> int:
        for b in range(n_storage):
            if free[b] >= bits:
                free[b] -= bits
                return b
        return -1                                  # spill off-fabric

    w_home = {key: place(bits) for key, bits in sorted(w_tile_bits.items())}
    x_home = tuple(place(x_row_bits) for _ in range(M))

    # --- tile tasks -> lockstep rounds of n_compute ------------------------
    # (ki, ni, m) order: consecutive tasks share a weight tile, so a
    # future broadcast optimization can coalesce their fetches.
    units = [(m, ki, ni) for ki in range(k_tiles) for ni in range(n_tiles)
             for m in range(M)]
    rounds = []
    for r0 in range(0, len(units), n_compute):
        tasks = []
        for slot, (m, ki, ni) in enumerate(units[r0:r0 + n_compute]):
            tasks.append(TileTask(
                block=n_storage + slot, m=m,
                k0=ki * kt, k1=min(K, (ki + 1) * kt),
                n0=ni * cfg.cols, n1=min(N, (ni + 1) * cfg.cols),
                x_src=x_home[m], w_src=w_home[(ki, ni)]))
        rounds.append(Round(tasks=tuple(tasks)))

    return Schedule(cfg=cfg, nbits=nbits, signed=signed, M=M, K=K, N=N,
                    kt=kt, modes=modes, x_home=x_home, w_home=w_home,
                    rounds=tuple(rounds))


# ---------------------------------------------------------------------------
# Exact execution on the block simulator
# ---------------------------------------------------------------------------
def execute_schedule(sched: Schedule, x_u: np.ndarray, w_u: np.ndarray,
                     executor: Optional[str] = None) -> np.ndarray:
    """Run the schedule's rounds exactly; operands already unsigned.

    x_u ``(M, K)``, w_u ``(K, N)`` unsigned ``< 2^nbits``.  Returns the
    raw uint64 accumulator image ``(M, N)`` (callers apply the signed
    zero-point correction; see :func:`fabric_matmul`).
    """
    import jax.numpy as jnp

    cfg = sched.cfg
    executor = executor or cfg.executor
    x_u = np.asarray(x_u, np.uint64)
    w_u = np.asarray(w_u, np.uint64)
    if x_u.shape != (sched.M, sched.K) or w_u.shape != (sched.K, sched.N):
        raise ValueError(f"operands {x_u.shape} @ {w_u.shape} do not match "
                         f"schedule {sched.M}x{sched.K}x{sched.N}")
    if np.any(x_u >= (1 << sched.nbits)) or np.any(w_u >= (1 << sched.nbits)):
        raise ValueError(f"operands must be < 2^{sched.nbits}")

    prog, lay = programs.idot(sched.nbits, rows=cfg.rows, tuples=sched.kt)
    n_compute = sched.n_compute
    out = np.zeros((sched.M, sched.N), np.uint64)
    zero = np.zeros((sched.kt, cfg.cols), np.uint64)

    for rnd in sched.rounds:
        arrs = np.zeros((n_compute, cfg.rows, cfg.cols), bool)
        for t in rnd.tasks:
            a = zero.copy()
            b = zero.copy()
            kw, nw = t.k1 - t.k0, t.n1 - t.n0
            a[:kw, :] = x_u[t.m, t.k0:t.k1][:, None]   # broadcast to cols
            b[:kw, :nw] = w_u[t.k0:t.k1, t.n0:t.n1]
            arrs[t.block - sched.n_storage] = harness.pack_state(
                lay, {"a": a, "b": b}, cfg.cols)
        states = engine.CRState(
            array=jnp.asarray(arrs),
            carry=jnp.zeros((n_compute, cfg.cols), bool),
            tag=jnp.ones((n_compute, cfg.cols), bool))
        res = np.asarray(
            engine.execute_blocks(prog, states, executor=executor).array)
        for t in rnd.tasks:
            acc = harness.unpack_acc(res[t.block - sched.n_storage], lay)
            out[t.m, t.n0:t.n1] += acc[: t.n1 - t.n0]
    return out


@dataclasses.dataclass(frozen=True)
class FabricResult:
    out: np.ndarray
    schedule: Schedule
    cost: costmodel.ScheduleCost


def fabric_matmul(x, w, nbits: int = 4,
                  cfg: FabricConfig = FabricConfig(),
                  signed: bool = False) -> FabricResult:
    """Schedule, execute, and account ``(M, K) @ (K, N)`` on the fabric.

    Bit-exact vs ``x @ w`` in int64 for any operand in range; the cost
    report prices the *executed* schedule (same IR), so correctness and
    accounting can never drift apart.
    """
    x = np.asarray(x)
    w = np.asarray(w)
    sched = schedule_gemm(x.shape[0], x.shape[1], w.shape[1], nbits,
                          cfg=cfg, signed=signed)
    if signed:
        cram._check_range((x, w), nbits, signed=True)
        xu, off = cram._bias_signed(x, nbits)
        wu, _ = cram._bias_signed(w, nbits)
        raw = execute_schedule(sched, xu, wu)
        out = cram._unbias(raw, off,
                           xu.sum(axis=1, dtype=np.int64)[:, None],
                           wu.sum(axis=0, dtype=np.int64)[None, :],
                           x.shape[1])
    else:
        out = execute_schedule(sched, x, w)
    return FabricResult(out=out, schedule=sched, cost=schedule_cost(sched))


# ---------------------------------------------------------------------------
# Cost accounting (walks the IR, prices with core.costmodel)
# ---------------------------------------------------------------------------
def schedule_cost(sched: Schedule) -> costmodel.ScheduleCost:
    """Roll one schedule up into energy (pJ) / time (us).

    Event counts per tile task (transposed bit-serial layout):

    * operand load: ``a`` moves ``kw * nbits`` bits once (broadcast
      across columns happens inside the destination block), ``w`` moves
      ``kw * nw * nbits`` bits; each travels a fabric hop when its home
      is a storage-mode block, the spill path when off-fabric.
    * storage-mode traffic: source rows read (``ceil(bits / row width)``
      at the home block) plus destination rows written (the tile spans
      ``kt * 2n`` rows of the compute block while it is still in storage
      mode), plus ``ACC_BITS`` accumulator rows read back.
    * compute: every *started* block burns ``program.cycles()``
      compute-mode cycles; idle blocks in a partial round are never
      started (per-block start lines) and burn nothing.  Rounds
      serialize (lockstep launches), so the critical path still spans
      every round regardless of occupancy.
    """
    cfg = sched.cfg
    cycles = sched.program.cycles()
    row_bits = cfg.cols

    n_active = sum(len(r.tasks) for r in sched.rounds)
    rows_touched = 0.0
    fabric_bits = 0.0
    spill_bits = 0.0
    for rnd in sched.rounds:
        for t in rnd.tasks:
            kw, nw = t.k1 - t.k0, t.n1 - t.n0
            a_bits = kw * sched.nbits
            w_bits = kw * nw * sched.nbits
            res_bits = ACC_BITS * nw
            for bits, src in ((a_bits, t.x_src), (w_bits, t.w_src)):
                if src >= 0:
                    fabric_bits += bits
                    rows_touched += math.ceil(bits / row_bits)  # src reads
                else:
                    spill_bits += bits
            # result readback always crosses the fabric to the host edge
            fabric_bits += res_bits
            # dst writes while in storage mode + acc rows read back
            rows_touched += sched.kt * 2 * sched.nbits + ACC_BITS

    return costmodel.schedule_cost_rollup(
        f"fabric/gemm{sched.M}x{sched.K}x{sched.N}/int{sched.nbits}",
        n_blocks=cfg.n_blocks, n_compute=sched.n_compute,
        n_storage=sched.n_storage, rounds=len(sched.rounds),
        compute_block_cycles=float(n_active * cycles),
        round_cycles=float(len(sched.rounds) * cycles),
        storage_rows_touched=rows_touched,
        fabric_bits_moved=fabric_bits, spill_bits_moved=spill_bits,
        ops=sched.ops)


# ---------------------------------------------------------------------------
# Attention on the fabric (the paper's DL workload, via models/attention
# shapes: q/k are (B, S, H, hd) exactly as produced by ``_qkv``)
# ---------------------------------------------------------------------------
def _quantize_sym(x: np.ndarray, bits: int):
    """Symmetric per-tensor quantization to signed ``bits`` ints."""
    qmax = (1 << (bits - 1)) - 1
    amax = max(float(np.abs(x).max()), 1e-8)
    scale = amax / qmax
    q = np.clip(np.round(x / scale), -qmax - 1, qmax).astype(np.int64)
    return q, scale


def fabric_attention_scores(q: np.ndarray, k: np.ndarray,
                            cfg: FabricConfig = FabricConfig(),
                            bits: int = 8):
    """Attention score matmul ``q @ k^T`` per (batch, head) on the fabric.

    q: ``(B, Sq, H, hd)``, k: ``(B, Sk, H, hd)`` floats (the
    ``models.attention._qkv`` layout).  Each (batch, head) score tile is
    one fabric GEMM of the *quantized* operands; scores come back
    dequantized and pre-scaled by ``hd ** -0.5`` -- ready for the
    softmax of :func:`repro.models.attention.chunked_attention`.

    Returns ``(scores (B, Sq, H, Sk) float32, int_scores int64,
    costs list[ScheduleCost])``.
    """
    q = np.asarray(q, np.float32)
    k = np.asarray(k, np.float32)
    B, Sq, H, hd = q.shape
    Bk, Sk, Hk, hdk = k.shape
    if (B, H, hd) != (Bk, Hk, hdk):
        raise ValueError(f"q {q.shape} vs k {k.shape}")

    qq, sq = _quantize_sym(q, bits)
    qk, sk = _quantize_sym(k, bits)
    scores = np.zeros((B, Sq, H, Sk), np.float32)
    int_scores = np.zeros((B, Sq, H, Sk), np.int64)
    costs = []
    for b in range(B):
        for h in range(H):
            res = fabric_matmul(qq[b, :, h, :], qk[b, :, h, :].T,
                                nbits=bits, cfg=cfg, signed=True)
            int_scores[b, :, h, :] = res.out
            scores[b, :, h, :] = res.out * (sq * sk * hd ** -0.5)
            costs.append(res.cost)
    return scores, int_scores, costs


class FabricLinearProbe:
    """Run one decode step's linear projection on the simulated fabric.

    Attached to :class:`repro.serve.engine.ServeEngine`, the probe takes
    the engine's *live* per-step activations (the token embeddings of
    the batch being decoded), quantizes activation and weight to
    ``bits``, and runs the projection as a fabric-scheduled GEMM --
    i.e. a small slice of a real decode step executes on the
    cycle-accurate block grid, with a cost report per step.

    The fabric simulator is an oracle, not a serving fast path, so the
    probe only samples the first ``max_steps`` decode steps.
    """

    def __init__(self, w, cfg: FabricConfig = FabricConfig(),
                 bits: int = 8, max_steps: int = 1):
        self.w = np.asarray(w, np.float32)       # (d_in, d_out)
        if self.w.ndim != 2:
            raise ValueError(f"probe weight must be 2-D, got {self.w.shape}")
        self.cfg = cfg
        self.bits = bits
        self.max_steps = max_steps
        self.costs: list = []
        self.outputs: list = []

    @property
    def done(self) -> bool:
        return len(self.costs) >= self.max_steps

    def observe(self, x) -> Optional[np.ndarray]:
        """x: (B, d_in) float activation of the current decode step."""
        if self.done:
            return None
        x = np.asarray(x, np.float32)
        qx, sx = _quantize_sym(x, self.bits)
        qw, sw = _quantize_sym(self.w, self.bits)
        res = fabric_matmul(qx, qw, nbits=self.bits, cfg=self.cfg,
                            signed=True)
        y = res.out.astype(np.float32) * (sx * sw)
        self.costs.append(res.cost)
        self.outputs.append(y)
        return y

    def report(self) -> Optional[dict]:
        if not self.costs:
            return None
        return combine_costs("fabric/decode_linear", self.costs).report()


def combine_costs(name: str, costs) -> costmodel.ScheduleCost:
    """Sum a list of :class:`ScheduleCost` (sequential launches)."""
    if not costs:
        raise ValueError("no costs to combine")
    c0 = costs[0]
    return costmodel.ScheduleCost(
        name=name, n_blocks=c0.n_blocks,
        n_compute=max(c.n_compute for c in costs),
        n_storage=max(c.n_storage for c in costs),
        rounds=sum(c.rounds for c in costs),
        compute_block_cycles=sum(c.compute_block_cycles for c in costs),
        round_cycles=sum(c.round_cycles for c in costs),
        storage_rows_touched=sum(c.storage_rows_touched for c in costs),
        fabric_bits_moved=sum(c.fabric_bits_moved for c in costs),
        spill_bits_moved=sum(c.spill_bits_moved for c in costs),
        ops=sum(c.ops for c in costs),
        energy_compute_pj=sum(c.energy_compute_pj for c in costs),
        energy_storage_pj=sum(c.energy_storage_pj for c in costs),
        energy_wire_pj=sum(c.energy_wire_pj for c in costs))
