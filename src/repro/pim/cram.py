"""Compute-RAM-backed matmul: run integer GEMMs on the engine itself.

The other pim backends (``pallas`` / ``popcount`` / ``ref``) re-express
the paper's bit-plane arithmetic with TPU-native ops.  This module closes
the loop the other way: it maps a quantized matmul onto the *actual*
Compute RAM block simulator -- operands transposed into bit-serial
columns, one ``idot`` program per block, blocks batched with
``engine.execute_blocks``.  With the compiled executor this is fast
enough to use in tests as a cross-layer oracle: the same numbers must
fall out of the Pallas popcount kernel and the cycle-accurate block.

Mapping for ``cram_matmul(x, w)`` with x ``(M, K)`` and w ``(K, N)``
unsigned ints: output column ``n`` lives in CR column ``n`` (paper's
40-column block => N <= cols per block), K is the serial tuple axis,
and each output row m is one CR block (vmap axis).
"""

from __future__ import annotations

import numpy as np

from repro.core import engine, harness, programs


def idot_geometry(n: int, rows: int = 512, acc_bits: int = 32):
    """Max dot-product length (tuples) an ``idot`` program supports."""
    _, lay = programs.idot(n, rows=rows, acc_bits=acc_bits)
    return lay.tuples


def cram_dot(a, b, n: int, rows: int = 512,
             executor: str = "compiled") -> np.ndarray:
    """Per-column dot products on one Compute RAM block.

    a, b: ``(T, cols)`` unsigned ints (< 2^n).  Returns ``(cols,)``
    ``sum_t a[t] * b[t]`` as uint64 (exact; int32 accumulator).
    """
    a = np.asarray(a, np.uint64)
    b = np.asarray(b, np.uint64)
    if np.any(a >= (1 << n)) or np.any(b >= (1 << n)):
        raise ValueError(f"operands must be < 2^{n}")
    prog, lay = programs.idot(n, rows=rows, tuples=a.shape[0])
    arr = harness.run_program(prog, lay, {"a": a, "b": b}, a.shape[1],
                              executor=executor)
    return harness.unpack_acc(arr, lay)


def cram_matmul(x, w, n: int = 4, rows: int = 512, cols: int = 40,
                executor: str = "compiled") -> np.ndarray:
    """``(M, K) @ (K, N)`` unsigned integer matmul on CR blocks.

    Tiles N over the block's columns and K over idot tuple capacity;
    M runs as parallel blocks via :func:`engine.execute_blocks`.  All
    tiles share ONE compiled idot program (same geometry), so the
    compile cost is paid once per (n, rows, K-tile) shape.
    """
    import jax.numpy as jnp

    x = np.asarray(x, np.uint64)
    w = np.asarray(w, np.uint64)
    M, K = x.shape
    K2, N = w.shape
    if K != K2:
        raise ValueError(f"shape mismatch {x.shape} @ {w.shape}")
    if np.any(x >= (1 << n)) or np.any(w >= (1 << n)):
        raise ValueError(f"operands must be < 2^{n}")

    kt = idot_geometry(n, rows)
    out = np.zeros((M, N), np.uint64)
    for k0 in range(0, K, kt):
        ksl = slice(k0, min(K, k0 + kt))
        t = ksl.stop - k0
        prog, lay = programs.idot(n, rows=rows, tuples=t)
        for n0 in range(0, N, cols):
            nsl = slice(n0, min(N, n0 + cols))
            c = nsl.stop - n0
            # one block per output row: (M, rows, c) batched state
            arrs = np.stack([
                harness.pack_state(lay, {
                    "a": np.repeat(x[m, ksl][:, None], c, axis=1),
                    "b": w[ksl, nsl],
                }, c) for m in range(M)])
            states = engine.CRState(
                array=jnp.asarray(arrs),
                carry=jnp.zeros((M, c), bool),
                tag=jnp.ones((M, c), bool))
            res = engine.execute_blocks(prog, states, executor=executor)
            res = np.asarray(res.array)
            out[:, nsl] += np.stack([
                harness.unpack_acc(res[m], lay) for m in range(M)])
    return out
