"""Compute-RAM-backed matmul: run integer GEMMs on the engine itself.

The other pim backends (``pallas`` / ``popcount`` / ``ref``) re-express
the paper's bit-plane arithmetic with TPU-native ops.  This module closes
the loop the other way: it maps a quantized matmul onto the *actual*
Compute RAM block simulator -- operands transposed into bit-serial
columns, one ``idot`` program per block, blocks batched with
``engine.execute_blocks``.  With the compiled executor this is fast
enough to use in tests as a cross-layer oracle: the same numbers must
fall out of the Pallas popcount kernel and the cycle-accurate block.

Mapping for ``cram_matmul(x, w)`` with x ``(M, K)`` and w ``(K, N)``
unsigned ints: output column ``n`` lives in CR column ``n`` (paper's
40-column block => N <= cols per block), K is the serial tuple axis,
and each output row m is one CR block (vmap axis).

Signed operands (``signed=True``) use the standard zero-point offset:
the ``idot`` program is unsigned-only hardware (the paper handles sign
"one level up" via bit-plane weighting), so signed values in
``[-2^(n-1), 2^(n-1))`` are biased by ``off = 2^(n-1)`` into unsigned
range, run exactly, and corrected on readback:

    x @ w = (u_x - off) @ (u_w - off)
          = u_x @ u_w - off*rowsum(u_x) - off*colsum(u_w) + K*off^2

The correction terms are host-side sums of values the host loaded into
storage mode anyway -- no extra block cycles.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import engine, floatprog, harness, programs


# ---------------------------------------------------------------------------
# Element dtypes the PIM stack schedules (per-GEMM asymmetric precision)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DType:
    """One schedulable element type: integer or FTZ+RTZ float."""
    name: str
    kind: str                    # "int" | "float"
    bits: int                    # storage bits per element
    fmt: Optional[floatprog.FloatFormat] = None   # floats only

    @property
    def is_float(self) -> bool:
        return self.kind == "float"


DTYPES = {
    "int4": DType("int4", "int", 4),
    "int8": DType("int8", "int", 8),
    "int16": DType("int16", "int", 16),
    "bf16": DType("bf16", "float", 16, floatprog.BF16),
    "fp16": DType("fp16", "float", 16, floatprog.FP16),
    "fp8": DType("fp8", "float", 8, floatprog.FP8_E4M3),
}

#: numpy/jax dtype names -> DTYPES keys (``np.dtype(jnp.bfloat16).name``
#: is "bfloat16" via ml_dtypes).
_DTYPE_ALIASES = {
    "bfloat16": "bf16", "float16": "fp16", "float8_e4m3fn": "fp8",
    "float8_e4m3": "fp8", "uint8": "int8", "uint16": "int16",
}


def resolve_dtype(dtype) -> Optional[DType]:
    """Map a dtype spec (DType | str | numpy/jax dtype) to a DType.

    ``None`` passes through (callers substitute their int default).
    Accepts ``jnp.bfloat16`` / ``np.float16`` style dtype objects, the
    DTYPES keys, and numpy dtype names.
    """
    if dtype is None or isinstance(dtype, DType):
        return dtype
    if isinstance(dtype, str):
        key = dtype
    else:
        try:
            key = np.dtype(dtype).name
        except TypeError:
            key = getattr(dtype, "__name__", str(dtype))
    key = _DTYPE_ALIASES.get(key, key)
    if key not in DTYPES:
        raise ValueError(
            f"unsupported dtype {dtype!r}; expected one of "
            f"{sorted(DTYPES)} (or a numpy/jax dtype mapping to one)")
    return DTYPES[key]


def idot_geometry(n: int, rows: int = 512, acc_bits: int = 32):
    """Max dot-product length (tuples) an ``idot`` program supports."""
    _, lay = programs.idot(n, rows=rows, acc_bits=acc_bits)
    return lay.tuples


def idot_tile(n: int, rows: int = 512, acc_bits: int = 32) -> int:
    """K-tile for exact accumulation: :func:`idot_geometry` clamped so
    ``tuples * (2^n - 1)^2`` provably fits the accumulator (the wide
    precisions -- int16 -- would otherwise wrap mod ``2^acc_bits``)."""
    acc_limit = ((1 << acc_bits) - 1) // max((1 << n) - 1, 1) ** 2
    return max(1, min(idot_geometry(n, rows, acc_bits), acc_limit))


def _bias_signed(x, n: int):
    """Two's-complement -> biased-unsigned (``u = x + 2^(n-1)``)."""
    off = np.int64(1 << (n - 1))
    return (np.asarray(x, np.int64) + off).astype(np.uint64), off


def _unbias(raw, off, a_sums, b_sums, T: int) -> np.ndarray:
    """Invert the offset on a raw biased-unsigned accumulator:

        x @ w = u_x @ u_w - off*sum(u_x) - off*sum(u_w) + T*off^2

    ``a_sums`` / ``b_sums`` are the biased operands' reduction sums,
    already broadcast to ``raw``'s shape; ``T`` is the reduction length.
    Shared by cram_dot / cram_matmul / the fabric scheduler so the
    algebra can never diverge between layers.
    """
    corr = off * a_sums + off * b_sums - np.int64(T) * off * off
    return np.asarray(raw).astype(np.int64) - corr


def _check_range(arrs, n: int, signed: bool):
    if signed:
        lo, hi = -(1 << (n - 1)), 1 << (n - 1)
        for a in arrs:
            ai = np.asarray(a, np.int64)
            if np.any(ai < lo) or np.any(ai >= hi):
                raise ValueError(
                    f"signed operands must be in [{lo}, {hi})")
    else:
        for a in arrs:
            ai = np.asarray(a, np.int64)
            if np.any(ai < 0) or np.any(ai >= (1 << n)):
                raise ValueError(f"operands must be < 2^{n}")


def cram_dot(a, b, n: int, rows: int = 512,
             executor: str = "compiled", signed: bool = False) -> np.ndarray:
    """Per-column dot products on one Compute RAM block.

    a, b: ``(T, cols)`` ints (unsigned ``< 2^n``, or two's-complement
    signed with ``signed=True``).  Returns ``(cols,)`` ``sum_t
    a[t] * b[t]`` -- uint64 for unsigned, int64 for signed (exact).

    ``T`` may exceed one program's tuple capacity (partial-tile
    support): the dot is K-tiled over multiple program launches and
    accumulated host-side, mirroring how the fabric scheduler streams
    a long reduction through one block.
    """
    _check_range((a, b), n, signed)
    if signed:
        au, off = _bias_signed(a, n)
        bu, _ = _bias_signed(b, n)
        raw = cram_dot(au, bu, n, rows=rows, executor=executor)
        return _unbias(raw, off, au.sum(axis=0, dtype=np.int64),
                       bu.sum(axis=0, dtype=np.int64), a.shape[0])
    a = np.asarray(a, np.uint64)
    b = np.asarray(b, np.uint64)
    kt = idot_tile(n, rows)
    out = np.zeros((a.shape[1],), np.uint64)
    for k0 in range(0, a.shape[0], kt):
        ksl = slice(k0, min(a.shape[0], k0 + kt))
        prog, lay = programs.idot(n, rows=rows, tuples=ksl.stop - k0)
        arr = harness.run_program(prog, lay, {"a": a[ksl], "b": b[ksl]},
                                  a.shape[1], executor=executor)
        out += harness.unpack_acc(arr, lay)
    return out


def fdot_geometry(fmt, rows: int = 512,
                  guard: int = floatprog.ACC_GUARD) -> int:
    """Max dot length (tuples) a ``float_dot`` program supports; 0 when
    the geometry cannot host the format's scratch + accumulator."""
    if isinstance(fmt, DType):
        fmt = fmt.fmt
    try:
        _, lay = floatprog.float_dot(fmt, rows=rows, guard=guard)
    except ValueError:
        return 0
    return lay.tuples


def _resolve_fmt(fmt) -> floatprog.FloatFormat:
    if isinstance(fmt, floatprog.FloatFormat):
        return fmt
    info = resolve_dtype(fmt)
    if info is None or info.fmt is None:
        raise ValueError(f"{fmt!r} is not a float dtype")
    return info.fmt


def cram_fdot(a_bits, b_bits, fmt, rows: int = 512,
              executor: str = "compiled",
              guard: int = floatprog.ACC_GUARD) -> np.ndarray:
    """Per-column float fused-MAC dot products on one Compute RAM block.

    a_bits, b_bits: ``(T, cols)`` fmt bit patterns (``ref.to_bits``).
    Returns ``(cols,)`` fmt bit patterns with the documented FTZ+RTZ
    fused-MAC semantics (:func:`repro.core.ref.float_dot`).  ``T`` may
    exceed one program's tuple capacity: the reduction is K-tiled over
    multiple launches with the *wide accumulator image carried between
    them*, so the result is bit-identical to a single sequential pass
    regardless of tiling.
    """
    fmt = _resolve_fmt(fmt)
    a = np.asarray(a_bits, np.uint64)
    b = np.asarray(b_bits, np.uint64)
    if np.any(a >= (1 << fmt.width)) or np.any(b >= (1 << fmt.width)):
        raise ValueError(f"operands must be {fmt.width}-bit patterns")
    kt = fdot_geometry(fmt, rows, guard)
    if kt < 1:
        raise ValueError(
            f"geometry {rows} rows cannot host a float_dot[{fmt.name}] "
            f"program (too few rows)")
    K = a.shape[0]
    res = np.zeros((a.shape[1],), np.uint64)     # empty reduction: +0
    acc = None
    cache = {}                                   # tuples -> (prog, lay)
    for k0 in range(0, K, kt):
        t = min(K, k0 + kt) - k0
        if t not in cache:
            cache[t] = floatprog.float_dot(fmt, rows=rows, tuples=t,
                                           guard=guard)
        prog, lay = cache[t]
        img = harness.pack_state(lay, {"a": a[k0:k0 + t], "b": b[k0:k0 + t]},
                                 a.shape[1])
        if acc is not None:
            floatprog.fdot_set_acc(img, fmt, acc, guard)
        arr = np.asarray(engine.run(prog, harness.make_jax_state(img),
                                    executor=executor).array)
        acc = floatprog.fdot_acc(arr, fmt, guard)
        res = floatprog.fdot_result(arr, fmt)
    return res


def cram_fmatmul(x_bits, w_bits, fmt, rows: int = 512, cols: int = 40,
                 executor: str = "compiled",
                 guard: int = floatprog.ACC_GUARD) -> np.ndarray:
    """``(M, K) @ (K, N)`` float matmul on CR blocks (bit patterns).

    The float face of :func:`cram_matmul`: N tiles over block columns,
    K tiles over ``float_dot`` capacity with the accumulator image
    chained across launches, M runs as parallel blocks.  Bit-exact vs
    :func:`repro.core.ref.float_matmul` for any operands -- the result
    does not depend on the tiling.
    """
    import jax.numpy as jnp

    fmt = _resolve_fmt(fmt)
    x = np.asarray(x_bits, np.uint64)
    w = np.asarray(w_bits, np.uint64)
    M, K = x.shape
    K2, N = w.shape
    if K != K2:
        raise ValueError(f"shape mismatch {x.shape} @ {w.shape}")
    kt = fdot_geometry(fmt, rows, guard)
    if kt < 1:
        raise ValueError(
            f"geometry {rows} rows cannot host a float_dot[{fmt.name}] "
            f"program (too few rows)")
    out = np.zeros((M, N), np.uint64)
    # only two distinct programs exist: the full K-tile and the final
    # ragged one -- build each once, not per (N-tile, K-tile) pair
    cache = {}
    for n0 in range(0, N, cols):
        nsl = slice(n0, min(N, n0 + cols))
        c = nsl.stop - n0
        accs = None                       # (M, c) wide images, chained
        for k0 in range(0, K, kt):
            ksl = slice(k0, min(K, k0 + kt))
            t = ksl.stop - k0
            if t not in cache:
                cache[t] = floatprog.float_dot(fmt, rows=rows, tuples=t,
                                               guard=guard)
            prog, lay = cache[t]
            imgs = []
            for m in range(M):
                img = harness.pack_state(lay, {
                    "a": np.repeat(x[m, ksl][:, None], c, axis=1),
                    "b": w[ksl, nsl],
                }, c)
                if accs is not None:
                    floatprog.fdot_set_acc(img, fmt, accs[m], guard)
                imgs.append(img)
            states = engine.CRState(
                array=jnp.asarray(np.stack(imgs)),
                carry=jnp.zeros((M, c), bool),
                tag=jnp.ones((M, c), bool))
            res = np.asarray(engine.execute_blocks(
                prog, states, executor=executor).array)
            accs = [floatprog.fdot_acc(res[m], fmt, guard)
                    for m in range(M)]
            out[:, nsl] = np.stack([floatprog.fdot_result(res[m], fmt)
                                    for m in range(M)])
    return out


def cram_matmul(x, w, n: int = 4, rows: int = 512, cols: int = 40,
                executor: str = "compiled",
                signed: bool = False) -> np.ndarray:
    """``(M, K) @ (K, N)`` integer matmul on CR blocks.

    Tiles N over the block's columns and K over idot tuple capacity
    (ragged/partial edge tiles supported); M runs as parallel blocks via
    :func:`engine.execute_blocks`.  All full tiles share ONE compiled
    idot program (same geometry), so the compile cost is paid once per
    (n, rows, K-tile) shape.

    ``signed=True`` accepts two's-complement operands in
    ``[-2^(n-1), 2^(n-1))`` and returns exact int64 (see module
    docstring for the offset algebra) -- this is what lets
    ``pim/linear.py`` quantized weights run without manual re-biasing.
    """
    import jax.numpy as jnp

    _check_range((x, w), n, signed)
    if signed:
        xu, off = _bias_signed(x, n)
        wu, _ = _bias_signed(w, n)
        raw = cram_matmul(xu, wu, n=n, rows=rows, cols=cols,
                          executor=executor)
        return _unbias(raw, off,
                       xu.sum(axis=1, dtype=np.int64)[:, None],
                       wu.sum(axis=0, dtype=np.int64)[None, :],
                       xu.shape[1])

    x = np.asarray(x, np.uint64)
    w = np.asarray(w, np.uint64)
    M, K = x.shape
    K2, N = w.shape
    if K != K2:
        raise ValueError(f"shape mismatch {x.shape} @ {w.shape}")

    kt = idot_tile(n, rows)
    out = np.zeros((M, N), np.uint64)
    for k0 in range(0, K, kt):
        ksl = slice(k0, min(K, k0 + kt))
        t = ksl.stop - k0
        prog, lay = programs.idot(n, rows=rows, tuples=t)
        for n0 in range(0, N, cols):
            nsl = slice(n0, min(N, n0 + cols))
            c = nsl.stop - n0
            # one block per output row: (M, rows, c) batched state
            arrs = np.stack([
                harness.pack_state(lay, {
                    "a": np.repeat(x[m, ksl][:, None], c, axis=1),
                    "b": w[ksl, nsl],
                }, c) for m in range(M)])
            states = engine.CRState(
                array=jnp.asarray(arrs),
                carry=jnp.zeros((M, c), bool),
                tag=jnp.ones((M, c), bool))
            res = engine.execute_blocks(prog, states, executor=executor)
            res = np.asarray(res.array)
            out[:, nsl] += np.stack([
                harness.unpack_acc(res[m], lay) for m in range(M)])
    return out
