"""Compute-RAM-backed matmul: run integer GEMMs on the engine itself.

The other pim backends (``pallas`` / ``popcount`` / ``ref``) re-express
the paper's bit-plane arithmetic with TPU-native ops.  This module closes
the loop the other way: it maps a quantized matmul onto the *actual*
Compute RAM block simulator -- operands transposed into bit-serial
columns, one ``idot`` program per block, blocks batched with
``engine.execute_blocks``.  With the compiled executor this is fast
enough to use in tests as a cross-layer oracle: the same numbers must
fall out of the Pallas popcount kernel and the cycle-accurate block.

Mapping for ``cram_matmul(x, w)`` with x ``(M, K)`` and w ``(K, N)``
unsigned ints: output column ``n`` lives in CR column ``n`` (paper's
40-column block => N <= cols per block), K is the serial tuple axis,
and each output row m is one CR block (vmap axis).

Signed operands (``signed=True``) use the standard zero-point offset:
the ``idot`` program is unsigned-only hardware (the paper handles sign
"one level up" via bit-plane weighting), so signed values in
``[-2^(n-1), 2^(n-1))`` are biased by ``off = 2^(n-1)`` into unsigned
range, run exactly, and corrected on readback:

    x @ w = (u_x - off) @ (u_w - off)
          = u_x @ u_w - off*rowsum(u_x) - off*colsum(u_w) + K*off^2

The correction terms are host-side sums of values the host loaded into
storage mode anyway -- no extra block cycles.
"""

from __future__ import annotations

import numpy as np

from repro.core import engine, harness, programs


def idot_geometry(n: int, rows: int = 512, acc_bits: int = 32):
    """Max dot-product length (tuples) an ``idot`` program supports."""
    _, lay = programs.idot(n, rows=rows, acc_bits=acc_bits)
    return lay.tuples


def idot_tile(n: int, rows: int = 512, acc_bits: int = 32) -> int:
    """K-tile for exact accumulation: :func:`idot_geometry` clamped so
    ``tuples * (2^n - 1)^2`` provably fits the accumulator (the wide
    precisions -- int16 -- would otherwise wrap mod ``2^acc_bits``)."""
    acc_limit = ((1 << acc_bits) - 1) // max((1 << n) - 1, 1) ** 2
    return max(1, min(idot_geometry(n, rows, acc_bits), acc_limit))


def _bias_signed(x, n: int):
    """Two's-complement -> biased-unsigned (``u = x + 2^(n-1)``)."""
    off = np.int64(1 << (n - 1))
    return (np.asarray(x, np.int64) + off).astype(np.uint64), off


def _unbias(raw, off, a_sums, b_sums, T: int) -> np.ndarray:
    """Invert the offset on a raw biased-unsigned accumulator:

        x @ w = u_x @ u_w - off*sum(u_x) - off*sum(u_w) + T*off^2

    ``a_sums`` / ``b_sums`` are the biased operands' reduction sums,
    already broadcast to ``raw``'s shape; ``T`` is the reduction length.
    Shared by cram_dot / cram_matmul / the fabric scheduler so the
    algebra can never diverge between layers.
    """
    corr = off * a_sums + off * b_sums - np.int64(T) * off * off
    return np.asarray(raw).astype(np.int64) - corr


def _check_range(arrs, n: int, signed: bool):
    if signed:
        lo, hi = -(1 << (n - 1)), 1 << (n - 1)
        for a in arrs:
            ai = np.asarray(a, np.int64)
            if np.any(ai < lo) or np.any(ai >= hi):
                raise ValueError(
                    f"signed operands must be in [{lo}, {hi})")
    else:
        for a in arrs:
            ai = np.asarray(a, np.int64)
            if np.any(ai < 0) or np.any(ai >= (1 << n)):
                raise ValueError(f"operands must be < 2^{n}")


def cram_dot(a, b, n: int, rows: int = 512,
             executor: str = "compiled", signed: bool = False) -> np.ndarray:
    """Per-column dot products on one Compute RAM block.

    a, b: ``(T, cols)`` ints (unsigned ``< 2^n``, or two's-complement
    signed with ``signed=True``).  Returns ``(cols,)`` ``sum_t
    a[t] * b[t]`` -- uint64 for unsigned, int64 for signed (exact).

    ``T`` may exceed one program's tuple capacity (partial-tile
    support): the dot is K-tiled over multiple program launches and
    accumulated host-side, mirroring how the fabric scheduler streams
    a long reduction through one block.
    """
    _check_range((a, b), n, signed)
    if signed:
        au, off = _bias_signed(a, n)
        bu, _ = _bias_signed(b, n)
        raw = cram_dot(au, bu, n, rows=rows, executor=executor)
        return _unbias(raw, off, au.sum(axis=0, dtype=np.int64),
                       bu.sum(axis=0, dtype=np.int64), a.shape[0])
    a = np.asarray(a, np.uint64)
    b = np.asarray(b, np.uint64)
    kt = idot_tile(n, rows)
    out = np.zeros((a.shape[1],), np.uint64)
    for k0 in range(0, a.shape[0], kt):
        ksl = slice(k0, min(a.shape[0], k0 + kt))
        prog, lay = programs.idot(n, rows=rows, tuples=ksl.stop - k0)
        arr = harness.run_program(prog, lay, {"a": a[ksl], "b": b[ksl]},
                                  a.shape[1], executor=executor)
        out += harness.unpack_acc(arr, lay)
    return out


def cram_matmul(x, w, n: int = 4, rows: int = 512, cols: int = 40,
                executor: str = "compiled",
                signed: bool = False) -> np.ndarray:
    """``(M, K) @ (K, N)`` integer matmul on CR blocks.

    Tiles N over the block's columns and K over idot tuple capacity
    (ragged/partial edge tiles supported); M runs as parallel blocks via
    :func:`engine.execute_blocks`.  All full tiles share ONE compiled
    idot program (same geometry), so the compile cost is paid once per
    (n, rows, K-tile) shape.

    ``signed=True`` accepts two's-complement operands in
    ``[-2^(n-1), 2^(n-1))`` and returns exact int64 (see module
    docstring for the offset algebra) -- this is what lets
    ``pim/linear.py`` quantized weights run without manual re-biasing.
    """
    import jax.numpy as jnp

    _check_range((x, w), n, signed)
    if signed:
        xu, off = _bias_signed(x, n)
        wu, _ = _bias_signed(w, n)
        raw = cram_matmul(xu, wu, n=n, rows=rows, cols=cols,
                          executor=executor)
        return _unbias(raw, off,
                       xu.sum(axis=1, dtype=np.int64)[:, None],
                       wu.sum(axis=0, dtype=np.int64)[None, :],
                       xu.shape[1])

    x = np.asarray(x, np.uint64)
    w = np.asarray(w, np.uint64)
    M, K = x.shape
    K2, N = w.shape
    if K != K2:
        raise ValueError(f"shape mismatch {x.shape} @ {w.shape}")

    kt = idot_tile(n, rows)
    out = np.zeros((M, N), np.uint64)
    for k0 in range(0, K, kt):
        ksl = slice(k0, min(K, k0 + kt))
        t = ksl.stop - k0
        prog, lay = programs.idot(n, rows=rows, tuples=t)
        for n0 in range(0, N, cols):
            nsl = slice(n0, min(N, n0 + cols))
            c = nsl.stop - n0
            # one block per output row: (M, rows, c) batched state
            arrs = np.stack([
                harness.pack_state(lay, {
                    "a": np.repeat(x[m, ksl][:, None], c, axis=1),
                    "b": w[ksl, nsl],
                }, c) for m in range(M)])
            states = engine.CRState(
                array=jnp.asarray(arrs),
                carry=jnp.zeros((M, c), bool),
                tag=jnp.ones((M, c), bool))
            res = engine.execute_blocks(prog, states, executor=executor)
            res = np.asarray(res.array)
            out[:, nsl] += np.stack([
                harness.unpack_acc(res[m], lay) for m in range(M)])
    return out
