"""PIM-backed linear layers: the paper's technique as a framework feature.

A Compute RAM is a *dual-mode* block: the same bits serve storage and
compute.  The framework analogue: a linear layer whose weights are
*stored* bit-plane packed (``uint32`` planes, the storage mode) and
*consumed* directly by the bit-serial matmul kernels (the compute mode)
-- no dequantized copy ever exists in HBM.

Backends (``PimConfig.mode``):

* ``off``      -- ordinary dense bf16 matmul (the "baseline FPGA": data
                  moves to the MXU as-is).  Used for training.
* ``pallas``   -- packed weights + VMEM unpack + MXU (performance path).
* ``popcount`` -- packed weights + AND/popcount bit-serial arithmetic
                  (PIM-faithful path).
* ``ref``      -- pure-jnp oracle of the packed path (tests, CPU).
* ``fabric``   -- the whole GEMM scheduled across a simulated Compute RAM
                  block grid (``repro.pim.fabric``): storage/compute mode
                  allocation, per-round block launches, exact integer
                  arithmetic on the cycle-accurate simulator.  Host-side
                  (numpy) -- an oracle/accounting path, not a jit path.

Activations are dynamically quantized to int8 per call in packed modes
(standard W4A8/W8A8 serving).  ``linear_apply`` is differentiable only
in ``off`` mode; packed modes are inference paths.

``fused_linear_apply`` applies several linears sharing one input (the
QKV projections); in ``fabric`` mode they run as ONE multi-GEMM
``FabricProgram`` with shared activation residency.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class PimConfig:
    mode: str = "off"            # off | ref | pallas | popcount | fabric
    weight_bits: int = 4
    act_bits: int = 8
    # fabric mode only: the block grid to schedule onto (a
    # repro.pim.fabric.FabricConfig; None = that module's default grid)
    fabric: Optional[object] = None
    # fabric mode only: pick the grid split per GEMM shape with
    # repro.pim.fabric.search_schedule (costmodel argmin; memoized per
    # shape).  The search stays on the grid's own block geometry so no
    # extra program compiles are triggered by tuning.
    fabric_autotune: bool = False
    # fabric mode only: a repro.pim.fabric.FabricSession carrying warm
    # resident-tile state across sequential fused_linear_apply calls
    # (the weight-stationary decode loop).  The session is mutable and
    # compares/hashes by identity, so the config stays frozen/hashable.
    fabric_session: Optional[object] = None

    @property
    def packed(self) -> bool:
        return self.mode != "off"


def linear_init(key, d_in: int, d_out: int, cfg: PimConfig,
                dtype=jnp.bfloat16, scale: Optional[float] = None) -> dict:
    """Init a linear layer's params (dense; pack separately if desired)."""
    std = scale if scale is not None else d_in ** -0.5
    w = jax.random.normal(key, (d_in, d_out), jnp.float32) * std
    return {"w": w.astype(dtype)}


def pack_linear(params: dict, cfg: PimConfig) -> dict:
    """Convert a dense layer to packed storage (offline weight prep)."""
    w = params["w"].astype(jnp.float32)
    q, scale = kops.quantize(w, bits=cfg.weight_bits, axis=1)
    packed = kops.pack_bitplanes(q, cfg.weight_bits, axis=0)
    return {"w_packed": packed, "w_scale": scale}


def linear_apply(params: dict, x: jnp.ndarray, cfg: PimConfig) -> jnp.ndarray:
    """y = x @ W with the configured backend.  x: (..., d_in)."""
    if not cfg.packed:
        return x @ params["w"]
    if cfg.mode == "fabric":
        # one pipeline for single and fused fabric GEMMs: the fused path
        # with a single weight IS the single-GEMM schedule
        return fused_linear_apply((params,), x, cfg)[0]

    orig_shape = x.shape
    d_in = orig_shape[-1]
    xf = x.reshape(-1, d_in)
    qx, sx = kops.quantize(xf.astype(jnp.float32), bits=cfg.act_bits, axis=0)

    wp, ws = params["w_packed"], params["w_scale"]
    if cfg.mode == "ref":
        acc = kref.quant_matmul(qx, wp, ws, bits=cfg.weight_bits)
    elif cfg.mode == "pallas":
        acc = kops.quant_matmul(qx, wp, ws, bits=cfg.weight_bits)
    elif cfg.mode == "popcount":
        ap = kops.pack_bitplanes(qx, cfg.act_bits, axis=1)
        raw = kops.popcount_matmul(ap, wp)
        acc = raw.astype(jnp.float32) * ws[None, :]
    else:
        raise ValueError(cfg.mode)

    y = acc.astype(jnp.float32) * sx[:, None]
    return y.reshape(orig_shape[:-1] + (y.shape[-1],)).astype(x.dtype)


def fused_linear_apply(params_list, x: jnp.ndarray, cfg: PimConfig):
    """Apply several linears sharing the input (the QKV projections).

    Returns a tuple ``(x @ W_0, x @ W_1, ...)``, one per entry of
    ``params_list``.  In ``fabric`` mode the projections are fused into
    ONE :class:`repro.pim.fabric.FabricProgram`: one grid allocation,
    shared activation residency (the activation tiles are fetched once
    and reused by every projection), one batched wide-block launch.
    Bit-identical to calling :func:`linear_apply` per layer -- the
    activation quantization is per call and deterministic, so the fused
    path shares it exactly.  Other modes simply loop
    :func:`linear_apply` (the MXU paths have no cross-GEMM state to
    share).
    """
    params_list = list(params_list)
    if cfg.mode != "fabric":
        return tuple(linear_apply(p, x, cfg) for p in params_list)

    import numpy as np

    from repro.pim import fabric as fabric_mod

    orig_shape = x.shape
    d_in = orig_shape[-1]
    xf = x.reshape(-1, d_in)
    qx, sx = kops.quantize(xf.astype(jnp.float32), bits=cfg.act_bits, axis=0)
    qws = [kref.unpack_bitplanes(p["w_packed"], axis=0, signed=True)
           for p in params_list]
    fcfg = cfg.fabric if cfg.fabric is not None \
        else fabric_mod.FabricConfig()
    nbits = max(cfg.act_bits, cfg.weight_bits)
    prog = None
    if cfg.fabric_autotune:
        specs = tuple(fabric_mod.GemmSpec(f"proj{g}", qx.shape[0],
                                          qx.shape[1], qw.shape[1])
                      for g, qw in enumerate(qws))
        prog = fabric_mod.search_program(
            specs, nbits, base=fcfg, signed=True,
            geometries=((fcfg.rows, fcfg.cols),)).schedule
    res = fabric_mod.fabric_fused_matmul(
        np.asarray(qx, np.int64), [np.asarray(qw, np.int64) for qw in qws],
        nbits=nbits, cfg=fcfg, signed=True, program=prog,
        names=tuple(f"proj{g}" for g in range(len(qws))),
        session=cfg.fabric_session)
    outs = []
    for raw, p in zip(res.outs, params_list):
        acc = jnp.asarray(raw.astype(np.float32)) * p["w_scale"][None, :]
        y = acc.astype(jnp.float32) * sx[:, None]
        outs.append(
            y.reshape(orig_shape[:-1] + (y.shape[-1],)).astype(x.dtype))
    return tuple(outs)
