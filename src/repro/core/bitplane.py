"""Transposed bit-plane layout helpers (paper §II-B / Fig 2).

Bit-serial arithmetic stores operands *transposed*: the bits of one
operand live in one column across consecutive rows (LSB in the lowest
row).  These helpers convert between integer/bfloat16 vectors and the
``(rows, cols)`` boolean main array of the engine.

Convention: for an n-bit operand at row base ``r``, row ``r + i`` holds
bit ``i`` (LSB first).  bfloat16 uses its uint16 bit pattern, so rows
``r+0..r+6`` = mantissa, ``r+7..r+14`` = exponent, ``r+15`` = sign.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def int_to_planes(x, nbits: int):
    """(cols,) unsigned ints -> (nbits, cols) bool planes, LSB first."""
    x = jnp.asarray(x, jnp.uint32)
    shifts = jnp.arange(nbits, dtype=jnp.uint32)[:, None]
    return ((x[None, :] >> shifts) & 1).astype(jnp.bool_)


def planes_to_int(planes, dtype=jnp.uint32):
    """(nbits, cols) bool planes -> (cols,) unsigned ints."""
    planes = jnp.asarray(planes)
    nbits = planes.shape[0]
    weights = (jnp.uint32(1) << jnp.arange(nbits, dtype=jnp.uint32))[:, None]
    return jnp.sum(planes.astype(jnp.uint32) * weights, axis=0).astype(dtype)


def bf16_to_planes(x):
    """(cols,) bfloat16 -> (16, cols) bool planes of the bit pattern."""
    u = jnp.asarray(x, jnp.bfloat16).view(jnp.uint16).astype(jnp.uint32)
    return int_to_planes(u, 16)


def planes_to_bf16(planes):
    """(16, cols) bool planes -> (cols,) bfloat16."""
    u = planes_to_int(planes, jnp.uint32).astype(jnp.uint16)
    return u.view(jnp.bfloat16)


def store(state_array, base: int, planes):
    """Write bit planes into rows [base, base+n) of the main array."""
    return state_array.at[base:base + planes.shape[0]].set(planes)


def load(state_array, base: int, nbits: int):
    """Read rows [base, base+nbits) as bit planes."""
    return state_array[base:base + nbits]


# numpy mirrors (test convenience, no tracing) ------------------------------
def np_int_to_planes(x, nbits: int) -> np.ndarray:
    x = np.asarray(x, np.uint64)
    return ((x[None, :] >> np.arange(nbits, dtype=np.uint64)[:, None]) & 1
            ).astype(bool)


def np_planes_to_int(planes: np.ndarray) -> np.ndarray:
    nbits = planes.shape[0]
    w = (np.uint64(1) << np.arange(nbits, dtype=np.uint64))[:, None]
    return (planes.astype(np.uint64) * w).sum(axis=0)
