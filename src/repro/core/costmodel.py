"""Area / frequency / energy / timing model (paper §IV-B, §IV-C, §V).

The paper evaluates Compute RAMs with VTR + COFFE + OpenRAM + Synopsys DC
at 22 nm.  None of those tools run here, so this module encodes their
*measured outputs* (Table II) as hardware constants and reimplements the
paper's energy/timing methodology on top:

* transistor (dynamic) energy: activity factor 0.1, energy proportional
  to transistor count derived from block area (§IV-C);
* wire energy: fJ/mm/bit numbers in the style of Keckler et al. [30]
  scaled to 22 nm, times bits moved, times VTR-style average net length;
* baseline-FPGA circuit composition: 1 BRAM + enough LB/DSP compute units
  to saturate the BRAM's 40-bit row bandwidth + LB control (§IV-C);
* Compute RAM circuit: a single block; cycle counts come from *executing
  the actual instruction sequences* (``repro.core.programs``).

Every constant is named and documented so the derivation chain from the
paper is auditable.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Block-level constants (Table II, 22 nm)
# ---------------------------------------------------------------------------
AREA_LB_UM2 = 1938.0
AREA_DSP_UM2 = 12433.0
AREA_BRAM_UM2 = 8311.0

# Compute RAM component breakdown (§IV-B: BRAM + OpenRAM 4Kb imem +
# DC-synthesized controller & peripherals + 15% place&route overhead).
AREA_IMEM_UM2 = 1200.0
AREA_CTRL_UM2 = 700.0
AREA_PERIPH_UM2 = 501.3
PNR_OVERHEAD = 1.15
AREA_CR_UM2 = AREA_BRAM_UM2 + PNR_OVERHEAD * (
    AREA_IMEM_UM2 + AREA_CTRL_UM2 + AREA_PERIPH_UM2)   # = 11072.5

FREQ_BRAM_MHZ = 922.9
# compute mode: ~33% slower (lowered word-line voltage + same-cycle
# read/write, from the Jeloka prototype; §IV-B), ~3% peripherals included.
CR_COMPUTE_SLOWDOWN = 0.66
FREQ_CR_MHZ = FREQ_BRAM_MHZ * CR_COMPUTE_SLOWDOWN      # = 609.1
FREQ_DSP_FIXED_MHZ = 391.8
FREQ_DSP_FLOAT_MHZ = 336.4

# VTR-reported *circuit* frequencies (paper §V-B: Compute RAM circuits run
# 60-65% faster because few long interconnect paths remain).
FREQ_CIRCUIT_CR_MHZ = 606.0          # short paths outside the block only
FREQ_CIRCUIT_BASE_FIXED_MHZ = 374.0  # LB/DSP/BRAM paths through the fabric
FREQ_CIRCUIT_BASE_FLOAT_MHZ = 325.0

# Paper-reported per-block throughput constants for baseline blocks
# (Table II; vendor/VTR-derived, not re-derivable here).
GOPS_DSP = {"int4": 0.7, "int8": 0.5, "bf16": 0.2}
GOPS_LB = {"int4": 1.4, "int8": 0.6}

# ---------------------------------------------------------------------------
# Energy constants (22 nm)
# ---------------------------------------------------------------------------
ACTIVITY = 0.1                       # §IV-C
# Compute mode activates two word lines + a write-back every cycle plus
# all column peripherals; its effective switching activity is higher than
# the storage-mode 0.1.  Calibrated so the int-add energy ratio (where our
# cycle counts match the paper's exactly) lands on the paper's ~20%.
COMPUTE_MODE_ACTIVITY_FACTOR = 2.5
TR_PER_UM2_SRAM = 40.0               # 6T bit cells dominate
TR_PER_UM2_LOGIC = 8.0
E_PER_TR_FJ = 0.05                   # C_eff ~0.08 fF at V=0.8 V
# Keckler et al. [30]-style wire energy scaled to 22 nm; FPGA interconnect
# multiplies by a switch factor (pass transistors + buffers per segment).
WIRE_FJ_PER_BIT_MM = 34.0
FPGA_SWITCH_FACTOR = 4.0
NET_LENGTH_BASE_MM = 0.60            # VTR-style average net length, baseline
NET_LENGTH_CR_MM = 0.08              # only mode/start/done + host control
# Fabric-level operand movement (schedule roll-up): a storage-mode block
# feeding a compute-mode block is a short block-to-block hop; operands
# spilled to off-fabric memory ride the long I/O column nets.
NET_LENGTH_FABRIC_MM = 0.30
NET_LENGTH_SPILL_MM = 1.20
# Topology-aware wire model: blocks sit at (row, col) sites on the grid
# (FabricConfig.site) and every operand move is priced by the Manhattan
# hop count between the actual sites -- NET_LENGTH_HOP_MM is the wire
# length of ONE hop between adjacent sites.  Two hops equal the old
# average fabric net (NET_LENGTH_FABRIC_MM), so flat and hop-based
# pricing agree for a typical small grid and diverge as the grid --
# and therefore its diameter -- grows.
NET_LENGTH_HOP_MM = 0.15

GEOMETRIES = {(512, 40): "512x40", (1024, 20): "1024x20",
              (2048, 10): "2048x10"}
BRAM_BITS = 20 * 1024
BRAM_ROW_BITS = 40
BRAM_ROWS = 512


def _transistors(area_um2: float, sram_fraction: float) -> float:
    return area_um2 * (sram_fraction * TR_PER_UM2_SRAM
                       + (1 - sram_fraction) * TR_PER_UM2_LOGIC)


def block_energy_per_cycle_fj(area_um2: float, sram_fraction: float) -> float:
    """Dynamic transistor energy of one block for one active cycle."""
    return ACTIVITY * _transistors(area_um2, sram_fraction) * E_PER_TR_FJ


def wire_energy_fj(bits: float, net_length_mm: float) -> float:
    return bits * net_length_mm * WIRE_FJ_PER_BIT_MM * FPGA_SWITCH_FACTOR


def hop_net_length_mm(hops: float) -> float:
    """Wire length of one fabric net spanning ``hops`` Manhattan hops.

    Monotone (non-decreasing) in the hop count, and never shorter than
    one hop: even adjacent blocks pay one switch-box crossing.  The
    schedule roll-up uses this to price each load/broadcast/drain by the
    *actual* distance between the block sites involved, instead of one
    average net length -- the topology-aware half of the paper's
    data-movement claim (wires, not arithmetic, are the expensive
    resource at the fabric level).
    """
    return max(1.0, float(hops)) * NET_LENGTH_HOP_MM


def wire_energy_bit_mm_fj(bit_mm: float) -> float:
    """Wire energy of an arbitrary bits-times-millimetres total.

    Same Keckler-style constants as :func:`wire_energy_fj`; callers that
    price every net by its own length (hop-based schedules) accumulate
    ``bits * mm`` per move and convert once here.
    """
    return bit_mm * WIRE_FJ_PER_BIT_MM * FPGA_SWITCH_FACTOR


# ---------------------------------------------------------------------------
# Circuit designs (paper §IV-C): what gets instantiated on each FPGA
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CircuitCost:
    """Area/energy/time of one mapped circuit."""
    name: str
    area_um2: float
    cycles: float
    freq_mhz: float
    energy_pj: float
    ops: int

    @property
    def time_us(self) -> float:
        return self.cycles / self.freq_mhz

    @property
    def energy_per_op_pj(self) -> float:
        return self.energy_pj / max(self.ops, 1)

    @property
    def time_per_op_ns(self) -> float:
        return 1e3 * self.time_us / max(self.ops, 1)


# bits per tuple stored in the BRAM for each op/precision (operands+result)
def tuple_bits(op: str, precision: str) -> int:
    n = {"int4": 4, "int8": 8, "bf16": 16}[precision]
    if op == "add":
        return 3 * n
    if op == "mul":
        return 2 * n + (2 * n if precision != "bf16" else n)
    if op == "dot":
        return 2 * n          # accumulator lives in registers / acc rows
    raise ValueError(op)


@dataclasses.dataclass(frozen=True)
class BaselineDesign:
    """Baseline FPGA circuit: 1 BRAM + compute + control (paper §IV-C)."""
    op: str
    precision: str
    n_dsp: int
    n_lb_compute: int
    n_lb_control: int = 4
    pipeline_depth: int = 4

    def cost(self) -> CircuitCost:
        tb = tuple_bits(self.op, self.precision)
        tuples_per_row = max(1, BRAM_ROW_BITS // tb)
        rows_per_tuple = max(1, math.ceil(tb / BRAM_ROW_BITS))
        if tuples_per_row >= 1 and tb <= BRAM_ROW_BITS:
            n_ops = tuples_per_row * BRAM_ROWS
            rows_touched = BRAM_ROWS
        else:
            n_ops = BRAM_ROWS // rows_per_tuple
            rows_touched = BRAM_ROWS
        # dual-ported BRAM: read stream and write-back stream overlap
        cycles = rows_touched + self.pipeline_depth
        if self.op == "dot":
            # operands only (results accumulate in registers): the paper's
            # int4 example reads 480 operand rows and takes ~480 cycles.
            rows_touched = math.ceil(n_ops * tb / BRAM_ROW_BITS)
            cycles = rows_touched + self.pipeline_depth

        freq = (FREQ_CIRCUIT_BASE_FLOAT_MHZ if self.precision == "bf16"
                else FREQ_CIRCUIT_BASE_FIXED_MHZ)
        area = (AREA_BRAM_UM2 + self.n_dsp * AREA_DSP_UM2
                + (self.n_lb_compute + self.n_lb_control) * AREA_LB_UM2)

        # energy: every active cycle, all blocks toggle at ACTIVITY and a
        # full row (+ result writeback) moves through the interconnect.
        e_blocks = (block_energy_per_cycle_fj(AREA_BRAM_UM2, 0.9)
                    + self.n_dsp * block_energy_per_cycle_fj(AREA_DSP_UM2, 0.0)
                    + (self.n_lb_compute + self.n_lb_control)
                    * block_energy_per_cycle_fj(AREA_LB_UM2, 0.0))
        bits_moved = BRAM_ROW_BITS * 2        # operands out + results back
        e_wire = wire_energy_fj(bits_moved, NET_LENGTH_BASE_MM)
        energy_pj = cycles * (e_blocks + e_wire) / 1e3
        return CircuitCost(
            f"baseline/{self.op}/{self.precision}", area, cycles, freq,
            energy_pj, n_ops)


@dataclasses.dataclass(frozen=True)
class ComputeRamDesign:
    """One Compute RAM block running a generated instruction sequence.

    ``cols`` other than 40 model the paper's §V-D exploration of wider,
    shallower geometries (72 columns, Xilinx-style) for the *same* 20 Kb
    capacity: rows shrink accordingly, the block area/energy change only
    marginally (more sense amps / peripherals), parallelism grows.
    """
    op: str
    precision: str
    cols: int = 40
    rows: int | None = None
    n_lb_control: int = 1      # small host FSM asserting mode/start

    def cost(self) -> CircuitCost:
        from . import programs
        rows = self.rows if self.rows is not None else BRAM_BITS // self.cols
        gen = programs.GENERATORS[(self.op, self.precision)]
        prog, layout = gen(rows=rows)
        cycles = prog.cycles()
        n_ops = layout.tuples * self.cols
        periph_scale = 1.0 + 0.06 * (self.cols / 40.0 - 1.0)
        area = AREA_CR_UM2 * periph_scale + self.n_lb_control * AREA_LB_UM2
        e_block = COMPUTE_MODE_ACTIVITY_FACTOR * \
            block_energy_per_cycle_fj(AREA_CR_UM2 * periph_scale, 0.75)
        e_wire = wire_energy_fj(4, NET_LENGTH_CR_MM)   # mode/start/done only
        energy_pj = cycles * (e_block + e_wire) / 1e3
        return CircuitCost(
            f"compute_ram/{self.op}/{self.precision}/{self.cols}col",
            area, cycles, FREQ_CIRCUIT_CR_MHZ, energy_pj, n_ops)


# canonical baseline compositions per paper §IV-C --------------------------
BASELINES = {
    ("add", "int4"): BaselineDesign("add", "int4", n_dsp=0, n_lb_compute=3),
    ("add", "int8"): BaselineDesign("add", "int8", n_dsp=0, n_lb_compute=1),
    ("add", "bf16"): BaselineDesign("add", "bf16", n_dsp=1, n_lb_compute=0),
    ("mul", "int4"): BaselineDesign("mul", "int4", n_dsp=2, n_lb_compute=0),
    ("mul", "int8"): BaselineDesign("mul", "int8", n_dsp=1, n_lb_compute=0),
    ("mul", "bf16"): BaselineDesign("mul", "bf16", n_dsp=1, n_lb_compute=0),
    # dot: 5 int4 multipliers + 4-deep int32 adder tree (paper §V-D)
    ("dot", "int4"): BaselineDesign("dot", "int4", n_dsp=5, n_lb_compute=8),
    ("dot", "int8"): BaselineDesign("dot", "int8", n_dsp=2, n_lb_compute=8),
    # bf16 dot: 2 float DSP slices (mul + acc) + adder-tree glue -- the
    # paper's float column; the CR side runs floatprog.float_dot
    ("dot", "bf16"): BaselineDesign("dot", "bf16", n_dsp=2, n_lb_compute=8),
}


def compare(op: str, precision: str, cr_cols: int = 40) -> dict:
    """Baseline vs Compute RAM for one operation (one paper figure bar)."""
    base = BASELINES[(op, precision)].cost()
    cr = ComputeRamDesign(op, precision, cols=cr_cols).cost()
    return {
        "op": op, "precision": precision, "cols": cr_cols,
        "baseline": base, "compute_ram": cr,
        "area_ratio": cr.area_um2 / base.area_um2,
        "energy_ratio": (cr.energy_per_op_pj / base.energy_per_op_pj),
        "time_ratio": cr.time_per_op_ns / base.time_per_op_ns,
        "freq_gain": cr.freq_mhz / base.freq_mhz - 1.0,
    }


# ---------------------------------------------------------------------------
# Schedule-level roll-up (fabric scheduler, paper §IV/§V): many blocks,
# some in storage mode holding operands, some in compute mode executing
# instruction sequences, cooperating on one workload.  ``repro.pim.fabric``
# counts *events* (cycles, rows touched, bits moved); this section turns
# the counts into energy/time with the same constants as the per-block
# model, so per-block and fabric numbers are directly comparable.
# ---------------------------------------------------------------------------
# Storage-mode row accesses run at the (faster) BRAM frequency; one row
# access therefore costs this many CR-circuit-frequency cycle
# equivalents.  Having one cycle unit lets serial and overlapped latency
# be compared directly.
STORAGE_ROW_CR_CYCLES = FREQ_CIRCUIT_CR_MHZ / FREQ_BRAM_MHZ


@dataclasses.dataclass(frozen=True)
class ScheduleCost:
    """Energy/time roll-up of one executed fabric schedule."""
    name: str
    n_blocks: int                 # grid size
    n_compute: int                # blocks in compute mode
    n_storage: int                # blocks in storage mode
    rounds: int                   # serialized execute_blocks launches
    compute_block_cycles: float   # sum over (active block, cycle) pairs
    round_cycles: float           # critical-path compute cycles (per round
    #                               blocks run in parallel -> max, summed)
    storage_rows_touched: float   # storage-mode row reads/writes (loads +
    #                               readback), across all blocks
    fabric_bits_moved: float      # operand/result bits on block-to-block nets
    spill_bits_moved: float       # bits to/from off-fabric memory
    ops: int                      # useful MACs (padding excluded)
    energy_compute_pj: float
    energy_storage_pj: float
    energy_wire_pj: float
    # Latency model (CR-circuit-frequency cycle units; storage rows are
    # converted via STORAGE_ROW_CR_CYCLES).  ``serial_cycles`` is every
    # round's load + compute + drain laid end to end -- identical to the
    # legacy ``time_us`` roll-up by construction.  ``overlapped_cycles``
    # is the double-buffered pipeline: round i+1's operand loads (and
    # round i's accumulator drain) hide behind round i's compute.  0.0
    # means "not modeled" (roll-ups that never saw per-round structure);
    # accessors fall back to the serial number.
    serial_cycles: float = 0.0
    overlapped_cycles: float = 0.0
    # Hop-priced wire totals (bits x mm, summed per net over the actual
    # Manhattan distances between block sites).  0.0 means "not modeled"
    # (roll-ups without placement information); the wire-energy term then
    # uses bits x the flat average net lengths above.
    fabric_bit_mm: float = 0.0
    spill_bit_mm: float = 0.0

    @property
    def energy_pj(self) -> float:
        return (self.energy_compute_pj + self.energy_storage_pj
                + self.energy_wire_pj)

    @property
    def time_us(self) -> float:
        """Compute rounds serialize at the compute-mode frequency; data
        movement overlaps row-by-row with storage-mode accesses at the
        (faster) storage frequency."""
        return (self.round_cycles / FREQ_CIRCUIT_CR_MHZ
                + self.storage_rows_touched / FREQ_BRAM_MHZ)

    @property
    def serial_cycles_(self) -> float:
        """serial_cycles, falling back to the legacy roll-up when the
        schedule walk did not provide per-round structure."""
        if self.serial_cycles > 0:
            return self.serial_cycles
        return (self.round_cycles
                + self.storage_rows_touched * STORAGE_ROW_CR_CYCLES)

    @property
    def overlapped_cycles_(self) -> float:
        return (self.overlapped_cycles if self.overlapped_cycles > 0
                else self.serial_cycles_)

    @property
    def time_us_overlapped(self) -> float:
        return self.overlapped_cycles_ / FREQ_CIRCUIT_CR_MHZ

    @property
    def overlap_speedup(self) -> float:
        return self.serial_cycles_ / max(self.overlapped_cycles_, 1e-12)

    @property
    def energy_per_op_pj(self) -> float:
        return self.energy_pj / max(self.ops, 1)

    @property
    def gops(self) -> float:
        return self.ops / max(self.time_us, 1e-12) / 1e3

    def report(self) -> dict:
        """Flat summary (benchmarks / examples / JSON artifacts)."""
        return {
            "name": self.name, "blocks": self.n_blocks,
            "compute": self.n_compute, "storage": self.n_storage,
            "rounds": self.rounds, "ops": self.ops,
            "energy_pj": round(self.energy_pj, 3),
            "energy_compute_pj": round(self.energy_compute_pj, 3),
            "energy_storage_pj": round(self.energy_storage_pj, 3),
            "energy_wire_pj": round(self.energy_wire_pj, 3),
            "time_us": round(self.time_us, 4),
            "serial_cycles": round(self.serial_cycles_, 1),
            "overlapped_cycles": round(self.overlapped_cycles_, 1),
            "time_us_overlapped": round(self.time_us_overlapped, 4),
            "overlap_speedup": round(self.overlap_speedup, 3),
            "energy_per_op_pj": round(self.energy_per_op_pj, 4),
            "gops": round(self.gops, 3),
            "fabric_bit_mm": round(self.fabric_bit_mm, 3),
            "spill_bit_mm": round(self.spill_bit_mm, 3),
            "avg_hop_mm": round(
                self.fabric_bit_mm / self.fabric_bits_moved, 4)
            if self.fabric_bit_mm > 0 and self.fabric_bits_moved > 0 else 0.0,
        }


def schedule_cost_rollup(name: str, *, n_blocks: int, n_compute: int,
                         n_storage: int, rounds: int,
                         compute_block_cycles: float, round_cycles: float,
                         storage_rows_touched: float,
                         fabric_bits_moved: float, spill_bits_moved: float,
                         ops: int, serial_cycles: float = 0.0,
                         overlapped_cycles: float = 0.0,
                         fabric_bit_mm: float = 0.0,
                         spill_bit_mm: float = 0.0) -> ScheduleCost:
    """Price a fabric schedule's event counts (see :class:`ScheduleCost`).

    * compute energy: every (active compute block, cycle) pair burns the
      compute-mode block energy (elevated activity factor, §IV-C);
    * storage energy: each storage-mode row access costs one cycle of a
      block at storage activity (0.1) -- the BRAM-like half of the
      dual-mode claim;
    * wire energy: operand/result bits times the wire length they cross,
      Keckler-style.  When the caller prices every net by its actual
      Manhattan distance (``fabric_bit_mm`` / ``spill_bit_mm`` > 0,
      bits x mm accumulated per move -- the topology-aware wire model),
      those totals are used directly; otherwise bits times the flat
      average net lengths (``NET_LENGTH_FABRIC_MM`` /
      ``NET_LENGTH_SPILL_MM``) -- the pre-placement fallback.

    ``serial_cycles`` / ``overlapped_cycles`` carry the per-round
    pipeline latency model when the caller walked the round structure
    (:func:`repro.pim.fabric.schedule_cost`); left at 0.0, the
    :class:`ScheduleCost` accessors fall back to the serial roll-up.
    """
    e_cr_compute = COMPUTE_MODE_ACTIVITY_FACTOR * \
        block_energy_per_cycle_fj(AREA_CR_UM2, 0.75)
    e_cr_storage = block_energy_per_cycle_fj(AREA_CR_UM2, 0.9)
    e_wire_fabric = (wire_energy_bit_mm_fj(fabric_bit_mm)
                     if fabric_bit_mm > 0 else
                     wire_energy_fj(fabric_bits_moved, NET_LENGTH_FABRIC_MM))
    e_wire_spill = (wire_energy_bit_mm_fj(spill_bit_mm)
                    if spill_bit_mm > 0 else
                    wire_energy_fj(spill_bits_moved, NET_LENGTH_SPILL_MM))
    return ScheduleCost(
        name=name, n_blocks=n_blocks, n_compute=n_compute,
        n_storage=n_storage, rounds=rounds,
        compute_block_cycles=compute_block_cycles,
        round_cycles=round_cycles,
        storage_rows_touched=storage_rows_touched,
        fabric_bits_moved=fabric_bits_moved,
        spill_bits_moved=spill_bits_moved, ops=ops,
        energy_compute_pj=compute_block_cycles * e_cr_compute / 1e3,
        energy_storage_pj=storage_rows_touched * e_cr_storage / 1e3,
        energy_wire_pj=(e_wire_fabric + e_wire_spill) / 1e3,
        serial_cycles=serial_cycles, overlapped_cycles=overlapped_cycles,
        fabric_bit_mm=fabric_bit_mm, spill_bit_mm=spill_bit_mm,
    )


def fault_cost(name: str, *, n_blocks: int, cols: int, parity_bits: float,
               scrub_rows: float, refetch_bits: float,
               edge_hops: float = 1.0) -> ScheduleCost:
    """Price fault-tolerance overhead as a :class:`ScheduleCost`.

    The fault subsystem (``repro.core.faults``, docs/faults.md) adds
    three kinds of honest overhead on top of a schedule's own roll-up:

    * **parity storage**: the 2-D parity signature of every protected
      block (``rows + cols`` bits each) is written once at load time --
      ``ceil(parity_bits / cols)`` storage-mode row writes plus the bits
      crossing the fabric to the parity words;
    * **scrub reads**: every scrub pass re-reads the rows it verifies
      (``scrub_rows`` storage-mode row reads, at BRAM frequency);
    * **re-fetch traffic**: a dirty tile is evicted and re-fetched from
      its backing store -- ``refetch_bits`` moved across the fabric
      (priced at ``edge_hops`` Manhattan hops, the conservative
      worst-case span) plus the row writes to land them.

    All three are storage/wire costs -- detection and repair burn no
    compute-mode cycles.  Combine with the schedule's own cost via
    :func:`repro.pim.fabric.combine_costs` (sequential: the scrub stage
    serializes with the rounds it protects).
    """
    row_bits = max(int(cols), 1)
    rows_touched = (float(scrub_rows)
                    + math.ceil(parity_bits / row_bits)
                    + math.ceil(refetch_bits / row_bits))
    moved = float(parity_bits + refetch_bits)
    serial = rows_touched * STORAGE_ROW_CR_CYCLES
    return schedule_cost_rollup(
        name, n_blocks=n_blocks, n_compute=0, n_storage=0, rounds=0,
        compute_block_cycles=0.0, round_cycles=0.0,
        storage_rows_touched=rows_touched,
        fabric_bits_moved=moved, spill_bits_moved=0.0, ops=0,
        serial_cycles=serial, overlapped_cycles=serial,
        fabric_bit_mm=moved * hop_net_length_mm(edge_hops))


def kv_append_cost(name: str, *, n_blocks: int, cols: int, bits: float,
                   edge_hops: float = 1.0,
                   spilled: bool = False) -> ScheduleCost:
    """Price appending ``bits`` of new KV-cache entries into a storage
    block (the on-fabric KV cache of a :class:`repro.pim.fabric`
    session).

    An append is the *write half* of a fetch: the new entries cross the
    fabric from the host edge to the cache's home block (``edge_hops``
    Manhattan hops; the spill path when the cache did not fit on-fabric)
    and land as ``ceil(bits / cols)`` storage-mode row writes.  Nothing
    is re-read and no compute-mode cycles burn -- which is exactly the
    session's append-not-refetch claim: the K/V history already resident
    on the grid is never moved again.
    """
    row_bits = max(int(cols), 1)
    rows_touched = float(math.ceil(bits / row_bits))
    serial = rows_touched * STORAGE_ROW_CR_CYCLES
    fabric_bits = 0.0 if spilled else float(bits)
    spill_bits = float(bits) if spilled else 0.0
    return schedule_cost_rollup(
        name, n_blocks=n_blocks, n_compute=0, n_storage=1, rounds=0,
        compute_block_cycles=0.0, round_cycles=0.0,
        storage_rows_touched=rows_touched,
        fabric_bits_moved=fabric_bits, spill_bits_moved=spill_bits, ops=0,
        serial_cycles=serial, overlapped_cycles=serial,
        fabric_bit_mm=fabric_bits * hop_net_length_mm(edge_hops),
        spill_bit_mm=spill_bits * (NET_LENGTH_SPILL_MM
                                   + hop_net_length_mm(edge_hops)))


def _mean(vals) -> float:
    vals = list(vals)
    return sum(vals) / len(vals) if vals else 0.0


@dataclasses.dataclass(frozen=True)
class CostTrajectory:
    """Per-step cost/fetch trajectory of a persistent fabric session.

    One entry per *decode step* (a :meth:`FabricSession.begin_step`
    bucket): the combined :class:`ScheduleCost` of every program the
    step executed, plus the step's operand-fetch counters from the
    schedule IR.  Step 0 is the **cold** step (every weight tile
    fetched); steps 1.. are **steady state** (warm residency), and the
    cold/steady split is the session win the fabric benchmark gates:
    ``steady_fetch_reduction = cold fetches / mean(steady fetches)``.

    ``costs`` entries may be ``None`` for steps that were scheduled but
    never executed (cost samples come from the execution layer).
    """
    name: str
    costs: Tuple[Optional[ScheduleCost], ...]
    fetches: Tuple[int, ...]
    fetch_bits: Tuple[float, ...]
    w_fetches: Tuple[int, ...] = ()
    kv_fetch_bits: Tuple[float, ...] = ()

    @property
    def steps(self) -> int:
        return len(self.fetches)

    @property
    def cold_fetches(self) -> int:
        return self.fetches[0] if self.fetches else 0

    @property
    def steady_fetches(self) -> float:
        return _mean(self.fetches[1:])

    @property
    def steady_fetch_reduction(self) -> float:
        """Cold step-1 fetch count over the steady-state mean (>= 1 when
        residency carries across programs; 1.0 for a single step)."""
        if self.steps < 2:
            return 1.0
        return self.cold_fetches / max(self.steady_fetches, 1e-12)

    @property
    def steady_w_fetch_reduction(self) -> float:
        """Like :attr:`steady_fetch_reduction` for weight fetches only.
        A fully weight-stationary steady state fetches ZERO weights;
        report the cold count then (the reduction is 'all of them')
        so the number stays finite/JSON-able."""
        if self.steps < 2 or not self.w_fetches:
            return 1.0
        steady = _mean(self.w_fetches[1:])
        if steady == 0:
            return float(max(self.w_fetches[0], 1))
        return self.w_fetches[0] / steady

    def _cost_attr(self, idx: int, attr: str) -> float:
        c = self.costs[idx] if idx < len(self.costs) else None
        return float(getattr(c, attr)) if c is not None else 0.0

    @property
    def cold_energy_pj(self) -> float:
        return self._cost_attr(0, "energy_pj")

    @property
    def steady_energy_pj(self) -> float:
        return _mean(self._cost_attr(i, "energy_pj")
                     for i in range(1, self.steps))

    @property
    def cold_overlapped_cycles(self) -> float:
        return self._cost_attr(0, "overlapped_cycles_")

    @property
    def steady_overlapped_cycles(self) -> float:
        return _mean(self._cost_attr(i, "overlapped_cycles_")
                     for i in range(1, self.steps))

    def report(self) -> dict:
        """Flat JSON-able summary (benchmarks / serve artifacts)."""
        rep = {
            "name": self.name,
            "steps": self.steps,
            "per_step_fetches": list(self.fetches),
            "per_step_fetch_bits": [round(b, 1) for b in self.fetch_bits],
            "cold_fetches": self.cold_fetches,
            "steady_fetches": round(self.steady_fetches, 3),
            "steady_fetch_reduction": round(self.steady_fetch_reduction, 3),
        }
        if self.w_fetches:
            rep["per_step_w_fetches"] = list(self.w_fetches)
            rep["steady_w_fetch_reduction"] = round(
                self.steady_w_fetch_reduction, 3)
        if self.kv_fetch_bits:
            rep["per_step_kv_fetch_bits"] = [round(b, 1)
                                             for b in self.kv_fetch_bits]
        if any(c is not None for c in self.costs):
            rep.update({
                "cold_energy_pj": round(self.cold_energy_pj, 3),
                "steady_energy_pj": round(self.steady_energy_pj, 3),
                "cold_overlapped_cycles": round(
                    self.cold_overlapped_cycles, 1),
                "steady_overlapped_cycles": round(
                    self.steady_overlapped_cycles, 1),
            })
        return rep


def cr_throughput_gops(op: str, precision: str, cols: int = 40,
                       rows: int = 512) -> float:
    """Compute RAM throughput from executed instruction sequences."""
    from . import programs
    prog, layout = programs.GENERATORS[(op, precision)](rows=rows)
    ops_per_pass = layout.tuples * cols
    seconds = prog.cycles() / (FREQ_CR_MHZ * 1e6)
    return ops_per_pass / seconds / 1e9
