"""JAX execution engine for Compute RAM blocks.

A Compute RAM's main array is modeled as a boolean tensor ``(rows, cols)``
plus per-column ``carry`` and ``tag`` latches (the logic peripherals of
paper §III-A4).  Every micro-op operates on *all columns simultaneously* --
the bit-line-computing parallelism axis.

Three executors are provided (``run(..., executor=...)`` dispatches):

* :func:`execute` (``"unroll"``) -- unrolls the micro-op stream eagerly,
  one host op per cycle.  The simplest oracle.
* :func:`execute_scan` (``"scan"``) -- the faithful "controller": the
  program is assembled into opcode/operand arrays and executed with
  ``jax.lax.scan`` + ``jax.lax.switch`` (compact HLO, cycle-per-step),
  mirroring the fetch/decode/execute pipeline of the in-block controller.
* :func:`execute_compiled` (``"compiled"``) -- lowers the expanded
  stream into a statically-specialized fused jnp function (constant
  opcodes, batched row writes, optional uint32 bit-packing of the column
  axis) and jits it once per (program, geometry).  Bit-exact with the
  other two; orders of magnitude faster to replay.  See ``docs/engine.md``.

``jax.vmap`` over a leading block axis models many Compute RAM blocks
operating in parallel (an FPGA has hundreds of BRAM sites); see
:func:`execute_blocks`.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import compiler, isa


class CRState(NamedTuple):
    """State of one Compute RAM block in compute mode."""
    array: jax.Array   # (rows, cols) bool -- the main array
    carry: jax.Array   # (cols,) bool -- per-column carry latch
    tag: jax.Array     # (cols,) bool -- per-column predication latch


def make_state(rows: int = 512, cols: int = 40) -> CRState:
    """Fresh block state (paper default geometry 512x40 = 20 Kb)."""
    return CRState(
        array=jnp.zeros((rows, cols), dtype=jnp.bool_),
        carry=jnp.zeros((cols,), dtype=jnp.bool_),
        tag=jnp.ones((cols,), dtype=jnp.bool_),
    )


# ---------------------------------------------------------------------------
# Single micro-op semantics
# ---------------------------------------------------------------------------
def _apply(state: CRState, op: int, dst, a, b, pred: bool) -> CRState:
    arr, carry, tag = state
    ra = arr[a]
    rb = arr[b]
    O = isa

    if op == O.OP_NOP:
        return state
    # tag / carry latch ops -------------------------------------------------
    if op == O.OP_C0:
        new_c = jnp.zeros_like(carry)
        return state._replace(carry=jnp.where(tag, new_c, carry) if pred else new_c)
    if op == O.OP_C1:
        new_c = jnp.ones_like(carry)
        return state._replace(carry=jnp.where(tag, new_c, carry) if pred else new_c)
    if op == O.OP_CROW:
        return state._replace(carry=jnp.where(tag, ra, carry) if pred else ra)
    if op == O.OP_TC:
        return state._replace(tag=carry)
    if op == O.OP_TNC:
        return state._replace(tag=~carry)
    if op == O.OP_TROW:
        return state._replace(tag=ra)
    if op == O.OP_TNROW:
        return state._replace(tag=~ra)
    if op == O.OP_T1:
        return state._replace(tag=jnp.ones_like(tag))
    if op == O.OP_TAND:
        return state._replace(tag=tag & ra)
    if op == O.OP_TOR:
        return state._replace(tag=tag | ra)
    if op == O.OP_TNOT:
        return state._replace(tag=~tag)

    # row-writing ops ---------------------------------------------------------
    new_carry = carry
    if op == O.OP_COPY:
        val = ra
    elif op == O.OP_NOT:
        val = ~ra
    elif op == O.OP_AND:
        val = ra & rb
    elif op == O.OP_OR:
        val = ra | rb
    elif op == O.OP_XOR:
        val = ra ^ rb
    elif op == O.OP_NOR:
        val = ~(ra | rb)
    elif op == O.OP_FA:
        val = ra ^ rb ^ carry
        new_carry = (ra & rb) | (carry & (ra ^ rb))
    elif op == O.OP_FS:   # dst = a - b - borrow (carry latch holds borrow)
        val = ra ^ rb ^ carry
        new_carry = ((~ra) & rb) | (carry & (~(ra ^ rb)))
    elif op == O.OP_W0:
        val = jnp.zeros_like(ra)
    elif op == O.OP_W1:
        val = jnp.ones_like(ra)
    elif op == O.OP_CSTORE:
        val = carry
        new_carry = jnp.zeros_like(carry)
    elif op == O.OP_TSTORE:
        val = tag
    else:
        raise ValueError(f"unknown opcode {op}")

    if pred:
        val = jnp.where(tag, val, arr[dst])
        new_carry = jnp.where(tag, new_carry, carry)
    return CRState(arr.at[dst].set(val), new_carry, tag)


# ---------------------------------------------------------------------------
# Executor 1: trace-time unroll
# ---------------------------------------------------------------------------
def execute(program: isa.Program, state: CRState) -> CRState:
    """Run ``program`` on ``state`` by unrolling its micro-op stream."""
    for ins in program.expand():
        state = _apply(state, ins.op, ins.dst, ins.a, ins.b, ins.pred)
    return state


# ---------------------------------------------------------------------------
# Executor 2: lax.scan "controller"
# ---------------------------------------------------------------------------
def assemble(program: isa.Program):
    """Assemble the executed stream into dense operand arrays."""
    stream = program.expand()
    ops = np.array([i.op for i in stream], np.int32)
    dst = np.array([i.dst for i in stream], np.int32)
    a = np.array([i.a for i in stream], np.int32)
    b = np.array([i.b for i in stream], np.int32)
    pred = np.array([i.pred for i in stream], np.bool_)
    return ops, dst, a, b, pred


def _switch_apply(state: CRState, op, dst, a, b, pred) -> CRState:
    """Dynamic-opcode micro-op (for lax.switch): all ops as branches."""
    arr, carry, tag = state
    ra = jnp.take(arr, a, axis=0)
    rb = jnp.take(arr, b, axis=0)
    rd = jnp.take(arr, dst, axis=0)
    zeros = jnp.zeros_like(ra)
    ones = jnp.ones_like(ra)

    # (row_value, new_carry, new_tag, writes_row)
    def mk(val, c, t, w):
        return val, c, t, w

    O = isa
    branches = [None] * O.N_ARRAY_OPS
    branches[O.OP_NOP] = lambda: mk(rd, carry, tag, False)
    branches[O.OP_COPY] = lambda: mk(ra, carry, tag, True)
    branches[O.OP_NOT] = lambda: mk(~ra, carry, tag, True)
    branches[O.OP_AND] = lambda: mk(ra & rb, carry, tag, True)
    branches[O.OP_OR] = lambda: mk(ra | rb, carry, tag, True)
    branches[O.OP_XOR] = lambda: mk(ra ^ rb, carry, tag, True)
    branches[O.OP_NOR] = lambda: mk(~(ra | rb), carry, tag, True)
    branches[O.OP_FA] = lambda: mk(ra ^ rb ^ carry,
                                   (ra & rb) | (carry & (ra ^ rb)), tag, True)
    branches[O.OP_FS] = lambda: mk(ra ^ rb ^ carry,
                                   ((~ra) & rb) | (carry & (~(ra ^ rb))),
                                   tag, True)
    branches[O.OP_W0] = lambda: mk(zeros, carry, tag, True)
    branches[O.OP_W1] = lambda: mk(ones, carry, tag, True)
    branches[O.OP_C0] = lambda: mk(rd, jnp.zeros_like(carry), tag, False)
    branches[O.OP_C1] = lambda: mk(rd, jnp.ones_like(carry), tag, False)
    branches[O.OP_CROW] = lambda: mk(rd, ra, tag, False)
    branches[O.OP_CSTORE] = lambda: mk(carry, jnp.zeros_like(carry), tag, True)
    branches[O.OP_TC] = lambda: mk(rd, carry, carry, False)
    branches[O.OP_TNC] = lambda: mk(rd, carry, ~carry, False)
    branches[O.OP_TROW] = lambda: mk(rd, carry, ra, False)
    branches[O.OP_TNROW] = lambda: mk(rd, carry, ~ra, False)
    branches[O.OP_T1] = lambda: mk(rd, carry, jnp.ones_like(tag), False)
    branches[O.OP_TAND] = lambda: mk(rd, carry, tag & ra, False)
    branches[O.OP_TOR] = lambda: mk(rd, carry, tag | ra, False)
    branches[O.OP_TSTORE] = lambda: mk(tag, carry, tag, True)
    branches[O.OP_TNOT] = lambda: mk(rd, carry, ~tag, False)

    val, new_carry, new_tag, writes = jax.lax.switch(
        op, [lambda i=i: branches[i]() for i in range(O.N_ARRAY_OPS)])

    # predication: suppress row write / carry update where tag is 0
    eff = jnp.where(pred, tag, jnp.ones_like(tag))
    val = jnp.where(eff & writes, val, rd)
    new_carry = jnp.where(eff, new_carry, carry)
    new_arr = jax.lax.dynamic_update_index_in_dim(arr, val, dst, axis=0)
    return CRState(new_arr, new_carry, new_tag)


def execute_scan(program: isa.Program, state: CRState) -> CRState:
    """Run ``program`` with a lax.scan controller (compact HLO)."""
    ops, dst, a, b, pred = assemble(program)

    def step(st, ins):
        op_i, d_i, a_i, b_i, p_i = ins
        return _switch_apply(st, op_i, d_i, a_i, b_i, p_i), None

    xs = (jnp.asarray(ops), jnp.asarray(dst), jnp.asarray(a),
          jnp.asarray(b), jnp.asarray(pred))
    final, _ = jax.lax.scan(step, state, xs)
    return final


# ---------------------------------------------------------------------------
# Executor 3: compiled fast path
#
# The expanded micro-op stream has *constant* opcodes and row operands,
# so instead of a cycle-per-step interpreter (scan + 24-way switch) the
# whole program lowers to one statically-specialized fused jnp function;
# see :mod:`repro.core.compiler` for the two lowering strategies (lane
# vectorization over the tuple loop, flat specialization) and the
# ripple-chain -> integer-add folding shared by both.  With
# ``packed=True`` the bool column axis is bit-packed into uint32 words
# (:func:`repro.core.compiler.pack_cols`) so one host op covers 32
# columns.
# ---------------------------------------------------------------------------
pack_cols = compiler.pack_cols
unpack_cols = compiler.unpack_cols


class _LRUCache:
    """Bounded mapping with LRU eviction (insertion + touch order).

    The compiled-program cache used to be an unbounded dict; a
    long-running serve process sweeping many (program, geometry, blocks)
    shapes -- e.g. the fabric autotuner probing grids -- would grow it
    without limit, each entry pinning a jitted executable.  Eviction
    only drops the *host* handle; re-compiling an evicted program is
    always correct, just slower.
    """

    def __init__(self, limit: int):
        from collections import OrderedDict
        self._d: "OrderedDict" = OrderedDict()
        self.limit = limit
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key):
        v = self._d.get(key)
        if v is None:
            self.misses += 1
            return None
        self.hits += 1
        self._d.move_to_end(key)
        return v

    def put(self, key, value):
        self._d[key] = value
        self._d.move_to_end(key)
        while len(self._d) > self.limit:
            self._d.popitem(last=False)
            self.evictions += 1
        return value

    def clear(self):
        self._d.clear()

    def __len__(self):
        return len(self._d)

    def __contains__(self, key):
        return key in self._d


# Module-level compiled-program cache: repeated replays (the dominant
# test cost) compile once per (program content, geometry, representation).
COMPILE_CACHE_LIMIT = 64
_COMPILE_CACHE = _LRUCache(COMPILE_CACHE_LIMIT)

# Programs whose expanded stream is at least this many micro-ops go
# through the jaxpr-level CSE pass before jit (the float sequences; see
# compiler.apply_cse).  Small programs skip it -- the extra abstract
# trace would cost more than it saves.
CSE_MIN_CYCLES = 1500

# Packed-by-default policy: programs up to this many expanded micro-ops
# resolve ``packed=None`` to the uint32 bit-plane interior.  Above it
# the bool interior stays the default: the long flat float sequences
# trace to very deep elementwise chains in the plane domain, which XLA's
# CPU scheduling passes handle pathologically (minutes, vs seconds for
# the int32 interior).  Every integer/fabric program sits far below the
# threshold; callers can always force either representation explicitly.
PACKED_DEFAULT_MAX_CYCLES = 2500

#: canonical wide-block compile budgets: `execute_blocks` rounds the
#: block count up to the next budget (zero-padding the batch) so ONE
#: compiled fn serves every count in (prev, budget] -- autotuner sweeps
#: and ragged last chunks stop churning the compile cache.
BLOCK_BUDGETS = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512)


def default_packed(program: isa.Program) -> bool:
    """Resolve the ``packed=None`` default for ``program`` (see
    :data:`PACKED_DEFAULT_MAX_CYCLES`)."""
    return len(program.expand()) <= PACKED_DEFAULT_MAX_CYCLES


def canonical_block_budget(blocks: int) -> int:
    """Smallest canonical budget >= ``blocks`` (identity above the
    largest budget -- the fabric already chunks its batches there)."""
    for b in BLOCK_BUDGETS:
        if blocks <= b:
            return b
    return blocks

#: stats of the most recent CSE run ({"eqns_before", "eqns_after",
#: "removed"}) -- benchmark introspection, None until a pass runs.
last_cse_stats = None


def set_compile_cache_limit(limit: int) -> None:
    """Re-bound the compiled-program cache (evicts LRU down to fit)."""
    if limit < 1:
        raise ValueError("cache limit must be >= 1")
    _COMPILE_CACHE.limit = limit
    while len(_COMPILE_CACHE._d) > limit:
        _COMPILE_CACHE._d.popitem(last=False)
        _COMPILE_CACHE.evictions += 1


def compile_cache_stats() -> dict:
    return {"size": len(_COMPILE_CACHE), "limit": _COMPILE_CACHE.limit,
            "hits": _COMPILE_CACHE.hits, "misses": _COMPILE_CACHE.misses,
            "evictions": _COMPILE_CACHE.evictions}


def _use_cse(program: isa.Program, cse) -> bool:
    """Resolve the cse flag (None = auto by expanded-stream size).

    ``expand()`` is memoized on the Program, so this is O(1) on the hot
    cache-lookup path.
    """
    if cse is not None:
        return bool(cse)
    return len(program.expand()) >= CSE_MIN_CYCLES


def _cse_pass(fn, blocks: int, rows: int, cols: int) -> "callable":
    """Run the jaxpr CSE pass over a lowered fn (see compiler.apply_cse)."""
    global last_cse_stats
    shape = (rows, cols) if blocks == 0 else (blocks, rows, cols)
    csh = shape[:-2] + shape[-1:]
    example = CRState(
        array=jax.ShapeDtypeStruct(shape, jnp.bool_),
        carry=jax.ShapeDtypeStruct(csh, jnp.bool_),
        tag=jax.ShapeDtypeStruct(csh, jnp.bool_))
    out = compiler.apply_cse(fn, example)
    last_cse_stats = getattr(out, "_cse_stats", None)
    return out


def compile_program(program: isa.Program, rows: int = 512, cols: int = 40,
                    *, packed: bool | None = None, cse: bool | None = None):
    """Compile ``program`` for a fixed geometry into a jitted fn.

    Returns ``fn(CRState) -> CRState``.  Results are cached module-wide
    in a bounded LRU (see :data:`COMPILE_CACHE_LIMIT` /
    :func:`set_compile_cache_limit`); the key includes
    :meth:`Program.fingerprint` so same-named programs with different
    nodes never collide.  ``packed=None`` resolves via
    :func:`default_packed` (uint32 interior for everything below the
    float-sequence size threshold).  ``cse=None`` auto-enables the
    jaxpr-level CSE pass for programs of >= :data:`CSE_MIN_CYCLES`
    micro-ops; both resolved flags are part of the cache key (forced
    variants never alias).
    """
    use_cse = _use_cse(program, cse)
    if packed is None:
        packed = default_packed(program)
    key = (program.name, rows, cols, bool(packed), use_cse,
           program.fingerprint())
    fn = _COMPILE_CACHE.get(key)
    if fn is None:
        fn = compiler.lower(program, rows, cols, packed)
        if use_cse:
            fn = _cse_pass(fn, 0, rows, cols)
        fn = _COMPILE_CACHE.put(key, jax.jit(fn))
    return fn


def clear_compile_cache() -> None:
    """Drop all cached compiled programs (tests / memory pressure)."""
    _COMPILE_CACHE.clear()


def execute_compiled(program: isa.Program, state: CRState,
                     *, packed: bool | None = None) -> CRState:
    """Run ``program`` through the statically-specialized compiled path."""
    rows, cols = state.array.shape
    return compile_program(program, rows, cols, packed=packed)(state)


# ---------------------------------------------------------------------------
# Executor dispatch
# ---------------------------------------------------------------------------
EXECUTORS = ("unroll", "scan", "compiled")


def run(program: isa.Program, state: CRState, executor: str = "compiled",
        *, packed: bool | None = None) -> CRState:
    """Run ``program`` with the chosen executor (see module docstring)."""
    if executor == "unroll":
        return execute(program, state)
    if executor == "scan":
        return execute_scan(program, state)
    if executor == "compiled":
        return execute_compiled(program, state, packed=packed)
    raise ValueError(
        f"unknown executor {executor!r}; expected one of {EXECUTORS}")


# multi-block execution -----------------------------------------------------
def execute_blocks(program: isa.Program, states: CRState,
                   executor: str = "compiled",
                   *, packed: bool | None = None,
                   faults=None) -> CRState:
    """Run the same program on many blocks: states have a leading block dim.

    The compiled path exploits that every micro-op is column-parallel:
    B blocks of C columns are exactly one block of B*C columns, so the
    fabric is simulated by reshaping into a single wide block (no vmap,
    no per-block overhead).  The block count is rounded up to the next
    canonical budget (:func:`canonical_block_budget`) and the batch
    zero-padded, so one compiled fn serves a whole range of ragged
    counts instead of recompiling per distinct count; columns are fully
    independent, so the pad columns cannot perturb the live ones and are
    sliced off on return.  The scan/unroll paths vmap per block.

    ``faults`` (a :class:`repro.core.faults.FaultModel`, default None =
    pristine SRAM) injects seeded bit flips / dead-block garbage into
    the row-states before dispatch and parity-scrubs on the model's
    cadence; injection happens host-side before lowering, so packed and
    bool interiors see identical corruption (docs/faults.md).
    """
    if faults is not None and faults.active:
        from . import faults as faults_mod
        return faults_mod.apply_block_faults(
            program, states, faults, executor=executor, packed=packed)
    if executor == "compiled":
        blocks, rows, cols = states.array.shape
        if packed is None:
            packed = default_packed(program)
        budget = canonical_block_budget(blocks)
        use_cse = _use_cse(program, None)
        key = ("blocks", program.name, budget, rows, cols, bool(packed),
               use_cse, program.fingerprint())
        fn = _COMPILE_CACHE.get(key)
        if fn is None:
            inner = compiler.lower(program, rows, budget * cols, packed)

            def wide_fn(st: CRState, blocks=budget, rows=rows, cols=cols):
                wide = CRState(
                    array=jnp.moveaxis(st.array, 0, 1).reshape(
                        rows, blocks * cols),
                    carry=st.carry.reshape(blocks * cols),
                    tag=st.tag.reshape(blocks * cols))
                out = inner(wide)
                return CRState(
                    array=jnp.moveaxis(
                        out.array.reshape(rows, blocks, cols), 1, 0),
                    carry=out.carry.reshape(blocks, cols),
                    tag=out.tag.reshape(blocks, cols))

            if use_cse:
                wide_fn = _cse_pass(wide_fn, budget, rows, cols)
            fn = _COMPILE_CACHE.put(key, jax.jit(wide_fn))
        if budget != blocks:
            pad = budget - blocks
            padded = CRState(
                array=jnp.concatenate(
                    [states.array,
                     jnp.zeros((pad, rows, cols), jnp.bool_)]),
                carry=jnp.concatenate(
                    [states.carry, jnp.zeros((pad, cols), jnp.bool_)]),
                tag=jnp.concatenate(
                    [states.tag, jnp.zeros((pad, cols), jnp.bool_)]))
            out = fn(padded)
            return CRState(out.array[:blocks], out.carry[:blocks],
                           out.tag[:blocks])
        return fn(states)
    if executor not in ("unroll", "scan"):
        raise ValueError(
            f"unknown executor {executor!r}; expected one of {EXECUTORS}")
    inner = execute if executor == "unroll" else execute_scan
    return jax.vmap(lambda s: inner(program, s))(states)


# packed-resident execution -------------------------------------------------
#
# `execute_blocks` round-trips the bool planes through the pack/unpack
# ladder on every launch; at 64 blocks that ladder costs ~3x the packed
# inner compute.  Replay loops (fabric rounds, chained small programs)
# should instead keep the state *packed-resident*: pack once, replay any
# number of launches on uint32 words, unpack once at the end.
def pack_state(state: CRState) -> CRState:
    """Column-pack every field of a state (bool -> uint32 words)."""
    return CRState(pack_cols(state.array), pack_cols(state.carry),
                   pack_cols(state.tag))


def unpack_state(state: CRState, cols: int) -> CRState:
    """Invert :func:`pack_state` back to ``cols`` bool columns."""
    return CRState(unpack_cols(state.array, cols),
                   unpack_cols(state.carry, cols),
                   unpack_cols(state.tag, cols))


def pack_block_states(states: CRState) -> CRState:
    """Fuse a ``(blocks, rows, cols)`` batch into one packed wide state.

    Returns a packed single-block state of ``blocks * cols`` columns
    (``array`` is ``(rows, n_words)`` uint32) -- the resident form the
    :func:`compile_packed` fns operate on.
    """
    blocks, rows, cols = states.array.shape
    wide = CRState(
        array=jnp.moveaxis(states.array, 0, 1).reshape(rows, blocks * cols),
        carry=states.carry.reshape(blocks * cols),
        tag=states.tag.reshape(blocks * cols))
    return pack_state(wide)


def unpack_block_states(wide: CRState, blocks: int, cols: int) -> CRState:
    """Invert :func:`pack_block_states` back to a block batch."""
    rows = wide.array.shape[0]
    st = unpack_state(wide, blocks * cols)
    return CRState(
        array=jnp.moveaxis(st.array.reshape(rows, blocks, cols), 1, 0),
        carry=st.carry.reshape(blocks, cols),
        tag=st.tag.reshape(blocks, cols))


def compile_packed(program: isa.Program, rows: int, cols: int,
                   *, cse: bool | None = None):
    """Compile ``program`` into a jitted fn over *packed* states.

    The returned fn maps a packed state of ``cols`` total columns (see
    :func:`pack_state` / :func:`pack_block_states`) to a packed state:
    no per-launch pack/unpack ladder at all.  Bit-identical to the other
    executors after :func:`unpack_state`.  Cached like
    :func:`compile_program`.
    """
    use_cse = _use_cse(program, cse)
    key = ("pio", program.name, rows, cols, use_cse, program.fingerprint())
    fn = _COMPILE_CACHE.get(key)
    if fn is None:
        inner = compiler.lower(program, rows, cols, True, packed_io=True)
        if use_cse:
            global last_cse_stats
            w = compiler.n_words(cols)
            example = CRState(
                array=jax.ShapeDtypeStruct((rows, w), jnp.uint32),
                carry=jax.ShapeDtypeStruct((w,), jnp.uint32),
                tag=jax.ShapeDtypeStruct((w,), jnp.uint32))
            inner = compiler.apply_cse(inner, example)
            last_cse_stats = getattr(inner, "_cse_stats", None)
        fn = _COMPILE_CACHE.put(key, jax.jit(inner))
    return fn


def run_chain(programs, state: CRState, *, cse: bool | None = None,
              faults=None) -> CRState:
    """Run several programs back-to-back, state packed across launches.

    The whole chain is fused into ONE jitted function: pack once, run
    every program's packed-io body, unpack once.  This is the fix for
    small-program replay barely beating the scan executor -- a chain of
    K short programs pays one launch + one pack/unpack ladder instead of
    K of each.  Bit-identical to ``for p in programs: state = run(p,
    state)``.  Cached per chain fingerprint.

    An active ``faults`` model (:class:`repro.core.faults.FaultModel`)
    injects flips *between* chained programs, which requires host
    visibility of the intermediate states -- the chain falls back to a
    sequential per-program replay (each leg still compiled + cached);
    the fused single-jit path is untouched when faults are off.
    """
    programs = tuple(programs)
    if faults is not None and faults.active:
        from . import faults as faults_mod
        return faults_mod.apply_chain_faults(programs, state, faults, cse=cse)
    if not programs:
        return state
    rows, cols = state.array.shape
    if cse is None:
        cse = sum(len(p.expand()) for p in programs) >= CSE_MIN_CYCLES
    key = ("chain", rows, cols, bool(cse),
           tuple(p.fingerprint() for p in programs))
    fn = _COMPILE_CACHE.get(key)
    if fn is None:
        bodies = [compiler.lower(p, rows, cols, True, packed_io=True)
                  for p in programs]

        def chain_fn(st: CRState):
            pst = pack_state(st)
            for body in bodies:
                pst = body(pst)
            return unpack_state(pst, cols)

        if cse:
            chain_fn = _cse_pass(chain_fn, 0, rows, cols)
        fn = _COMPILE_CACHE.put(key, jax.jit(chain_fn))
    return fn(state)
