"""The paper's contribution: Compute RAM ISA, instruction-sequence
generators (any precision), bit-plane execution engine, and the
Table II-calibrated area/energy/frequency cost model."""
