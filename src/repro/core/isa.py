"""Compute RAM instruction set (paper §III).

The Compute RAM block executes 16-bit instructions from a 4 Kb instruction
memory (256 instructions).  Instructions are of two kinds (paper §III-A3):

  * array micro-ops -- sent to the main array / per-column logic
    peripherals.  One micro-op per cycle; every column executes it
    simultaneously (bit-line computing + bit-serial arithmetic).
  * controller ops -- executed by the in-block controller (8 registers,
    adder/comparator/logical unit, zero-overhead hardware loops).

We model both levels explicitly:

  * ``Program`` is what sits in the instruction memory: a list of
    ``Instr`` and ``Loop`` nodes.  ``Program.footprint()`` is the number of
    instruction-memory slots used (a hardware loop costs 1 slot for the
    LOOP marker + its body once) -- this validates the paper's claim that
    common operations fit in <= 200 of the 256 slots.
  * ``Program.expand()`` resolves loops and register-relative row
    addressing into the *executed micro-op stream*.  Its length is the
    cycle count (hardware loops have zero branch overhead, so loop
    management contributes no cycles; controller ALU instructions placed
    inside the stream cost 1 cycle each, like in the paper's simple
    pipelined controller).

Row operands may be absolute ints or ``R(reg, offset)`` register-relative
references; registers are maintained by the expansion (the controller).
"""

from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import List, Sequence, Union

# ---------------------------------------------------------------------------
# Array micro-op opcodes (per-column logic peripherals; 1 cycle each).
# The underlying bit-line primitive senses A.B on BL and ~A.~B on BLB
# (Jeloka et al.); the peripherals derive XOR/OR/full-add from these plus
# the carry and tag latches (Neural Cache-style).
# ---------------------------------------------------------------------------
OP_NOP = 0
OP_COPY = 1    # dst <- row[a]
OP_NOT = 2     # dst <- ~row[a]
OP_AND = 3     # dst <- row[a] & row[b]
OP_OR = 4      # dst <- row[a] | row[b]
OP_XOR = 5     # dst <- row[a] ^ row[b]
OP_NOR = 6     # dst <- ~(row[a] | row[b])
OP_FA = 7      # full add: dst <- a ^ b ^ carry ; carry <- maj(a, b, carry)
OP_FS = 8      # full sub: dst <- a ^ b ^ borrow; borrow <- ~a&b | borrow&~(a^b)
OP_W0 = 9      # dst <- 0
OP_W1 = 10     # dst <- 1
OP_C0 = 11     # carry <- 0
OP_C1 = 12     # carry <- 1
OP_CROW = 13   # carry <- row[a]
OP_CSTORE = 14 # dst <- carry (then carry <- 0)
OP_TC = 15     # tag <- carry
OP_TNC = 16    # tag <- ~carry
OP_TROW = 17   # tag <- row[a]
OP_TNROW = 18  # tag <- ~row[a]
OP_T1 = 19     # tag <- 1
OP_TAND = 20   # tag <- tag & row[a]
OP_TOR = 21    # tag <- tag | row[a]
OP_TSTORE = 22 # dst <- tag
OP_TNOT = 23   # tag <- ~tag

N_ARRAY_OPS = 24

ARRAY_OP_NAMES = {
    OP_NOP: "nop", OP_COPY: "copy", OP_NOT: "not", OP_AND: "and",
    OP_OR: "or", OP_XOR: "xor", OP_NOR: "nor", OP_FA: "fa", OP_FS: "fs",
    OP_W0: "w0", OP_W1: "w1", OP_C0: "c0", OP_C1: "c1", OP_CROW: "crow",
    OP_CSTORE: "cstore", OP_TC: "tc", OP_TNC: "tnc", OP_TROW: "trow",
    OP_TNROW: "tnrow", OP_T1: "t1", OP_TAND: "tand", OP_TOR: "tor",
    OP_TSTORE: "tstore", OP_TNOT: "tnot",
}

#: inverse of :data:`ARRAY_OP_NAMES` -- the corpus text format and the
#: fuzzer's program parser address opcodes by mnemonic.
OP_BY_NAME = {name: op for op, name in ARRAY_OP_NAMES.items()}

# Ops that write an array row (predication masks this write with tag).
_WRITES_ROW = {OP_COPY, OP_NOT, OP_AND, OP_OR, OP_XOR, OP_NOR, OP_FA,
               OP_FS, OP_W0, OP_W1, OP_CSTORE, OP_TSTORE}
# Ops that read row operand ``a`` / ``b``
_READS_A = {OP_COPY, OP_NOT, OP_AND, OP_OR, OP_XOR, OP_NOR, OP_FA, OP_FS,
            OP_CROW, OP_TROW, OP_TNROW, OP_TAND, OP_TOR}
_READS_B = {OP_AND, OP_OR, OP_XOR, OP_NOR, OP_FA, OP_FS}

NUM_REGS = 8       # paper §III-A3: register file of 8 (flip-flop based)
IMEM_SLOTS = 256   # paper §III-A2: 4 Kb / 16-bit = 256 instructions


@dataclasses.dataclass(frozen=True)
class R:
    """Register-relative row reference: row = regs[reg] + offset."""
    reg: int
    offset: int = 0

    def __post_init__(self):
        if not (0 <= self.reg < NUM_REGS):
            raise ValueError(f"register {self.reg} out of range")


RowRef = Union[int, R]


@dataclasses.dataclass(frozen=True)
class Instr:
    """One array micro-op (possibly tag-predicated).

    ``inc`` is a tuple of ``(reg, delta)`` post-increments applied after
    the micro-op executes -- the controller's address-generation unit
    (like DSP AGUs, paper §III-A3 cites DSP processor fundamentals), so
    pointer walks inside hardware loops cost zero extra cycles.
    """
    op: int
    dst: RowRef = 0
    a: RowRef = 0
    b: RowRef = 0
    pred: bool = False
    inc: tuple = ()

    def __repr__(self):
        name = ARRAY_OP_NAMES.get(self.op, f"op{self.op}")
        p = "?t " if self.pred else ""
        return f"<{p}{name} d={self.dst} a={self.a} b={self.b}>"


@dataclasses.dataclass(frozen=True)
class SetReg:
    """Controller op: regs[reg] <- value (1 cycle)."""
    reg: int
    value: int


@dataclasses.dataclass(frozen=True)
class AddReg:
    """Controller op: regs[reg] += delta (1 cycle)."""
    reg: int
    delta: int


@dataclasses.dataclass(frozen=True)
class MovReg:
    """Controller op: regs[dst] <- regs[src] + offset (1 cycle)."""
    dst: int
    src: int
    offset: int = 0


@dataclasses.dataclass(frozen=True)
class Loop:
    """Zero-overhead hardware loop: repeat body ``count`` times.

    Occupies 1 instruction-memory slot (the loop marker) plus the body;
    the repetition itself costs no extra cycles (paper §III-A3, DSP-style
    dedicated hardware loop control).
    """
    count: int
    body: List["Node"]


Node = Union[Instr, SetReg, AddReg, MovReg, Loop]


@dataclasses.dataclass(frozen=True)
class StreamMeta:
    """Static metadata of an expanded micro-op stream.

    This is what the compiled executor (``engine.compile_program``)
    consumes: it bounds the rows a program touches (so geometry
    mismatches fail loudly at compile time instead of silently indexing
    out of range) and summarizes the op mix for diagnostics.
    """
    n_cycles: int                 # array micro-ops executed
    rows_read: frozenset          # absolute rows read as operands
    rows_written: frozenset       # absolute rows written
    max_row: int                  # highest row touched (-1: none)
    uses_pred: bool               # any tag-predicated micro-op?
    op_histogram: tuple           # ((opcode, count), ...) sorted by opcode


def stream_meta(stream: Sequence["Instr"]) -> StreamMeta:
    """Compute :class:`StreamMeta` for an expanded micro-op stream."""
    reads, writes = set(), set()
    hist: dict = {}
    uses_pred = False
    for ins in stream:
        hist[ins.op] = hist.get(ins.op, 0) + 1
        uses_pred = uses_pred or ins.pred
        if ins.op in _READS_A:
            reads.add(ins.a)
        if ins.op in _READS_B:
            reads.add(ins.b)
        if ins.op in _WRITES_ROW:
            writes.add(ins.dst)
            if ins.pred:          # predicated writes read back dst
                reads.add(ins.dst)
    max_row = max(reads | writes, default=-1)
    return StreamMeta(len(stream), frozenset(reads), frozenset(writes),
                      max_row, uses_pred, tuple(sorted(hist.items())))


@dataclasses.dataclass
class Program:
    """A Compute RAM program (contents of the instruction memory)."""
    name: str
    nodes: List[Node]
    # rows the program assumes are scratch (for capacity accounting)
    temp_rows: int = 0

    # -- instruction-memory footprint (slots) -------------------------------
    def footprint(self) -> int:
        def count(nodes: Sequence[Node]) -> int:
            n = 0
            for nd in nodes:
                if isinstance(nd, Loop):
                    n += 1 + count(nd.body)   # LOOP marker + body
                else:
                    n += 1
            return n
        return count(self.nodes) + 1          # +1 for END

    def fits_imem(self) -> bool:
        return self.footprint() <= IMEM_SLOTS

    def imem_images(self) -> int:
        """Instruction-memory images needed to stream this program.

        Every integer program (and the float add/mul sequences) fits the
        paper's single 4 Kb image; the fused float MAC is the first
        library program that does not -- the host FSM would reload the
        imem between segments (a storage-mode row-write burst, amortized
        over every column x tuple of the pass).
        """
        return max(1, math.ceil(self.footprint() / IMEM_SLOTS))

    # -- expansion to the executed micro-op stream --------------------------
    def expand(self) -> List[Instr]:
        """Resolve loops + registers into absolute-row micro-ops.

        The returned list length == cycle count of the array portion;
        controller ALU ops (SetReg/AddReg) each cost 1 cycle and are
        accounted in ``cycles()``.  Memoized: like ``fingerprint()``,
        a Program is frozen once executed -- don't mutate ``nodes``.
        """
        cached = self.__dict__.get("_expanded")
        if cached is None:
            regs = [0] * NUM_REGS
            ctrl = [0]
            cached = self._expand_with(regs, ctrl)
            self._ctrl_cycles = ctrl[0]
            self.__dict__["_expanded"] = cached
        return cached

    def cycles(self) -> int:
        """Total cycles = array micro-ops + controller ALU ops executed."""
        stream = self.expand()
        return len(stream) + self._ctrl_cycles

    def meta(self) -> StreamMeta:
        """Metadata of the expanded stream (compiled-executor input)."""
        return stream_meta(self.expand())

    def expand_grouped(self):
        """Expand, split at the dominant top-level hardware loop.

        Returns ``(pre, iters, post)`` where ``iters`` is one micro-op
        stream per iteration of the top-level :class:`Loop` contributing
        the most cycles, and ``pre``/``post`` are the surrounding
        streams; or ``None`` when there is no top-level loop with at
        least 2 iterations.  ``pre + sum(iters) + post`` is always
        identical to :meth:`expand` -- the grouping only adds boundaries,
        so compilers can fall back to the flat stream at any point.
        """
        best, best_cycles = None, 0
        for idx, nd in enumerate(self.nodes):
            if isinstance(nd, Loop) and nd.count >= 2:
                body_cycles = Program("_", nd.body).cycles()
                if nd.count * body_cycles > best_cycles:
                    best, best_cycles = idx, nd.count * body_cycles
        if best is None:
            return None
        loop = self.nodes[best]
        regs = [0] * NUM_REGS
        ctrl = [0]

        def expand_nodes(nodes):
            sub = Program("_", list(nodes))
            stream = sub._expand_with(regs, ctrl)
            return stream

        pre = expand_nodes(self.nodes[:best])
        iters = [expand_nodes(loop.body) for _ in range(loop.count)]
        post = expand_nodes(self.nodes[best + 1:])
        return pre, iters, post

    def expand_segments(self):
        """Expand, splitting at EVERY top-level hardware loop.

        Returns a list of ``("flat", stream)`` and ``("loop", iters)``
        segments in program order, where ``iters`` is one micro-op
        stream per iteration of a top-level :class:`Loop` with at least
        2 iterations.  Register state threads through the segments in
        order, so the concatenation of all streams is always identical
        to :meth:`expand` -- like :meth:`expand_grouped` this only adds
        boundaries.  Programs built by concatenation (``__add__``) keep
        one segment per constituent loop, which is what lets the
        compiled executor lane-vectorize each dominant loop of a chained
        program instead of only the single biggest one.
        """
        regs = [0] * NUM_REGS
        ctrl = [0]
        segs = []
        flat: List[Node] = []

        def expand_nodes(nodes):
            return Program("_", list(nodes))._expand_with(regs, ctrl)

        def flush():
            if flat:
                stream = expand_nodes(flat)
                if stream:
                    segs.append(("flat", stream))
                del flat[:]

        for nd in self.nodes:
            if isinstance(nd, Loop) and nd.count >= 2:
                flush()
                segs.append(("loop",
                             [expand_nodes(nd.body)
                              for _ in range(nd.count)]))
            else:
                flat.append(nd)
        flush()
        return segs

    def _expand_with(self, regs, ctrl):
        """Like :meth:`expand` but threading caller-owned register state
        (``regs``) and a 1-element controller-cycle accumulator."""
        stream: List[Instr] = []

        def resolve(ref: RowRef) -> int:
            if isinstance(ref, R):
                return regs[ref.reg] + ref.offset
            return int(ref)

        def run(nodes: Sequence[Node]):
            for nd in nodes:
                if isinstance(nd, Loop):
                    for _ in range(nd.count):
                        run(nd.body)
                elif isinstance(nd, SetReg):
                    regs[nd.reg] = nd.value
                    ctrl[0] += 1
                elif isinstance(nd, AddReg):
                    regs[nd.reg] += nd.delta
                    ctrl[0] += 1
                elif isinstance(nd, MovReg):
                    regs[nd.dst] = regs[nd.src] + nd.offset
                    ctrl[0] += 1
                else:
                    stream.append(Instr(nd.op, resolve(nd.dst),
                                        resolve(nd.a), resolve(nd.b),
                                        nd.pred))
                    for reg, delta in nd.inc:
                        regs[reg] += delta
        run(self.nodes)
        return stream

    def fingerprint(self) -> str:
        """Stable content hash of the program.

        Covers both the 16-bit encoded instruction words (structure) and
        the expanded micro-op stream (absolute row operands, which the
        16-bit encoding carries in registers and therefore does not pin
        down by itself).  Two programs sharing a name but differing in
        nodes hash differently, so compiled-executor caches keyed on
        this never cross-contaminate.

        Memoized on first use (it feeds every compiled-executor cache
        lookup): treat a Program as frozen once it has been executed --
        mutating ``nodes`` in place afterwards is not supported (build
        a new Program instead, as ``__add__`` does).
        """
        fp = self.__dict__.get("_fingerprint")
        if fp is None:
            h = hashlib.sha256()
            for w in encode(self):
                h.update(w.to_bytes(2, "little"))
            for ins in self.expand():
                h.update(f"{ins.op},{ins.dst},{ins.a},{ins.b},"
                         f"{int(ins.pred)};".encode())
            fp = self.__dict__["_fingerprint"] = h.hexdigest()[:16]
        return fp

    def __add__(self, other: "Program") -> "Program":
        return Program(f"{self.name}+{other.name}", self.nodes + other.nodes,
                       max(self.temp_rows, other.temp_rows))


# ---------------------------------------------------------------------------
# Program validity (the fuzzer's well-formed-by-construction contract)
# ---------------------------------------------------------------------------
def validate_program(program: Program, rows: int,
                     max_cycles: int | None = None) -> List[str]:
    """Check that ``program`` is well-formed for a ``rows``-row geometry.

    Returns a list of human-readable violations (empty = valid).  This
    is the contract the constrained-random fuzzer guarantees *by
    construction* and re-checks before every differential replay: a
    stream that indexes outside the array is not a program the hardware
    could run, so executor divergence on it would be noise, not signal.

    Checks, on the *expanded* stream (register-relative addressing
    resolved, exactly what the executors consume):

    * every row operand a micro-op actually reads/writes is in
      ``[0, rows)`` -- negative rows wrap in the unroll executor but
      clamp in the scan executor's gathers, so an out-of-range row is
      not merely invalid, it is a false differential;
    * opcodes are known array micro-ops;
    * structural checks on the node tree: loop trip counts >= 1,
      post-increment register indices in range;
    * optionally, the expanded stream stays under ``max_cycles``.
    """
    bad: List[str] = []

    def check_nodes(nodes: Sequence[Node], depth: int = 0):
        for nd in nodes:
            if isinstance(nd, Loop):
                if nd.count < 1:
                    bad.append(f"loop count {nd.count} < 1")
                if depth >= 8:
                    bad.append("loop nesting deeper than 8")
                check_nodes(nd.body, depth + 1)
            elif isinstance(nd, Instr):
                if not (0 <= nd.op < N_ARRAY_OPS):
                    bad.append(f"unknown opcode {nd.op}")
                for reg, _delta in nd.inc:
                    if not (0 <= reg < NUM_REGS):
                        bad.append(f"inc register {reg} out of range")
            elif isinstance(nd, (SetReg, AddReg, MovReg)):
                pass      # register indices enforced by the dataclasses
            else:
                bad.append(f"unknown node type {type(nd).__name__}")

    check_nodes(program.nodes)
    if bad:
        return bad                     # expansion may not be meaningful
    stream = program.expand()
    for i, ins in enumerate(stream):
        used = []
        if ins.op in _READS_A:
            used.append(("a", ins.a))
        if ins.op in _READS_B:
            used.append(("b", ins.b))
        if ins.op in _WRITES_ROW:
            used.append(("dst", ins.dst))
        for field, row in used:
            if not (0 <= row < rows):
                bad.append(f"cycle {i} ({ARRAY_OP_NAMES[ins.op]}): "
                           f"{field}={row} outside [0, {rows})")
    if max_cycles is not None and len(stream) > max_cycles:
        bad.append(f"{len(stream)} micro-ops > cap {max_cycles}")
    return bad


def describe_stream(program: Program) -> str:
    """One-line op-mix summary of the expanded stream (diagnostics)."""
    meta = program.meta()
    mix = " ".join(f"{ARRAY_OP_NAMES[op]}:{n}"
                   for op, n in meta.op_histogram)
    return (f"{program.name}: {meta.n_cycles} cycles, rows<= {meta.max_row},"
            f" pred={meta.uses_pred} [{mix}]")


# ---------------------------------------------------------------------------
# 16-bit encoding (paper: each instruction is 16 bits wide).
#
# Array micro-op:  [15] = 0 | [14:10] opcode(5) | [9] pred |
#                  [8:6] dst reg | [5:3] a reg | [2:0] b reg
# Controller op:   [15] = 1 | [14] kind (0=set,1=add) | [13:11] reg |
#                  [10:0] signed immediate
# Loop marker:     encoded as a controller op on a dedicated loop register.
#
# Row *offsets* are carried in registers (SetReg/AddReg), matching the
# register-relative addressing a 16-bit encoding forces; ``encode`` is a
# structural check that the program is representable, used by tests.
# ---------------------------------------------------------------------------
def encode(program: Program) -> List[int]:
    words: List[int] = []

    def enc(nodes: Sequence[Node]):
        for nd in nodes:
            if isinstance(nd, Loop):
                words.append(0x8000 | (0x7FF & min(nd.count, 0x7FF)))
                enc(nd.body)
            elif isinstance(nd, SetReg):
                words.append(0xC000 | (nd.reg << 11) | (nd.value & 0x7FF))
            elif isinstance(nd, AddReg):
                words.append(0xE000 | (nd.reg << 11) | (nd.delta & 0x7FF))
            elif isinstance(nd, MovReg):
                words.append(0xA000 | (nd.dst << 11) | (nd.src << 8)
                             | (nd.offset & 0xFF))
            else:
                def regof(ref):
                    return ref.reg if isinstance(ref, R) else 0
                words.append((nd.op << 10) | (int(nd.pred) << 9)
                             | (regof(nd.dst) << 6) | (regof(nd.a) << 3)
                             | regof(nd.b))
    enc(program.nodes)
    words.append(0xFFFF)   # END
    return words
