"""Static compiler: Compute RAM programs -> fused jnp functions.

``engine.compile_program`` lowers the *expanded* micro-op stream of a
:class:`repro.core.isa.Program` into a statically-specialized jnp
function: opcodes are compile-time constants (no ``lax.switch``), row
values live in trace-time dictionaries so runs of row writes become one
batched ``arr.at[rows].set(vals)``, and the bool column axis is
optionally bit-packed into ``uint32`` words so one host op covers 32
columns.  Two lowering strategies, tried in order:

1. **Lane vectorization** (`_analyze` / `_lower_lanes`).  Programs from
   :mod:`repro.core.programs` process T tuples with a dominant top-level
   hardware loop whose iterations touch disjoint ("affine") row windows
   plus shared scratch rows that every iteration overwrites before
   reading.  Such loops execute all T iterations as *lanes* of one
   vectorized body -- the compiled graph contains ONE copy of the body
   on ``(T, ...)``-shaped values instead of T copies.  Rows carrying a
   loop-serial dependence (e.g. the ``idot`` accumulator) force the
   minimal suffix of the body containing them to run serially per lane;
   everything before it still vectorizes.

2. **Flat lowering** (`_lower_flat`): straight-line specialization of
   the whole stream, used when the loop analysis bails.  Correctness
   never depends on the analysis succeeding.

Both strategies fold maximal OP_FA/OP_FS runs ("ripple chains") into
per-column integer adds/subtracts: an n-cycle carry ripple is one
``a + b + carry_in`` on bit-plane-packed ints (exact, including the
final carry latch and tag predication).

The paper's own framing (§III-C) is that the ISA is the contract and
the substrate may change freely; this module is that idea applied to
the simulator itself.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import isa
from ..kernels import bitplane_ops
from .isa import (Instr, _READS_A, _READS_B, _WRITES_ROW,
                  OP_NOP, OP_COPY, OP_NOT, OP_AND, OP_OR, OP_XOR, OP_NOR,
                  OP_FA, OP_FS, OP_W0, OP_W1, OP_C0, OP_C1, OP_CROW,
                  OP_CSTORE, OP_TC, OP_TNC, OP_TROW, OP_TNROW, OP_T1,
                  OP_TAND, OP_TOR, OP_TSTORE, OP_TNOT)

WORD = 32

# carry / tag access classification (predication adds tag reads and, for
# the carry-latch writes, a read of the old carry)
_CARRY_READ = {OP_FA, OP_FS, OP_CSTORE, OP_TC, OP_TNC}
_CARRY_WRITE = {OP_C0, OP_C1, OP_CROW, OP_FA, OP_FS, OP_CSTORE}
_CARRY_KILL = {OP_C0, OP_C1, OP_CROW}          # unpredicated only
_TAG_READ = {OP_TAND, OP_TOR, OP_TNOT, OP_TSTORE}
_TAG_WRITE = {OP_TC, OP_TNC, OP_TROW, OP_TNROW, OP_T1, OP_TAND, OP_TOR,
              OP_TNOT}
_TAG_KILL = {OP_T1, OP_TROW, OP_TNROW, OP_TC, OP_TNC}

# Longest FA/FS run folded into one integer add: keeps the per-column
# integers comfortably inside int32 (sum < 2^25).
MAX_CHAIN = 24
# Minimum run length worth the pack/unpack overhead of the integer form.
MIN_CHAIN = 4

# With the packed (uint32-word) interior, run folds stay in the *bit
# plane* domain: integers are lists of packed planes and a ripple chain
# is 5 bitwise word-ops per bit (kernels/bitplane_ops.py) instead of an
# unpack -> int32 weighted-sum -> repack ladder.  Bitwise plane ops are
# pure elementwise, so XLA fuses whole chains into a few memory passes;
# at fabric widths (64 blocks x 40 cols) this is the difference between
# memory-traffic-bound and compute-trivial.  The flag exists only as a
# debugging escape hatch.
PLANE_DOMAIN = True


def n_words(cols: int) -> int:
    return (cols + WORD - 1) // WORD


def pack_cols(x: jax.Array) -> jax.Array:
    """Bit-pack the trailing (column) axis of a bool array into uint32."""
    cols = x.shape[-1]
    pad = n_words(cols) * WORD - cols
    if pad:
        x = jnp.concatenate(
            [x, jnp.zeros(x.shape[:-1] + (pad,), x.dtype)], axis=-1)
    x = x.reshape(x.shape[:-1] + (n_words(cols), WORD))
    weights = jnp.uint32(1) << jnp.arange(WORD, dtype=jnp.uint32)
    return jnp.sum(x.astype(jnp.uint32) * weights, axis=-1, dtype=jnp.uint32)


def unpack_cols(xw: jax.Array, cols: int) -> jax.Array:
    """Inverse of :func:`pack_cols`: uint32 words -> (..., cols) bool."""
    bits = (xw[..., None] >> jnp.arange(WORD, dtype=jnp.uint32)) & 1
    return bits.reshape(xw.shape[:-1] + (-1,))[..., :cols].astype(jnp.bool_)


# ---------------------------------------------------------------------------
# References.  The machine below is generic over *where* a row lives:
#   ("k", row)  -- a concrete array row (flat lowering, shared scratch)
#   ("l", c)    -- the lane-relative row c + t*stride of lane t
# Unused operand slots are None so they never pollute the analysis.
# ---------------------------------------------------------------------------
def _to_refs(stream: Sequence[Instr], slotfn) -> List[Instr]:
    out = []
    for p, ins in enumerate(stream):
        dst = slotfn(p, "dst") if ins.op in _WRITES_ROW else None
        a = slotfn(p, "a") if ins.op in _READS_A else None
        b = slotfn(p, "b") if ins.op in _READS_B else None
        out.append(Instr(ins.op, dst, a, b, ins.pred))
    return out


def _flat_refs(stream: Sequence[Instr]) -> List[Instr]:
    return _to_refs(stream,
                    lambda p, slot: ("k", getattr(stream[p], slot)))


def _ref_delta(a, b):
    """Row distance between two refs of the same kind (None: unrelated)."""
    if isinstance(a, tuple) and isinstance(b, tuple) and a[0] == b[0]:
        return b[1] - a[1]
    return None


def _segment(stream: Sequence[Instr]):
    """Split a ref-stream into ('op', ins) and ('chain', [ins...]) items.

    A chain is a maximal run of same-opcode, same-predication OP_FA or
    OP_FS micro-ops in which no cycle reads a row written by an earlier
    cycle of the run (read-before-write within one cycle is fine: the
    bit-lines sense operands before write-back).  Such a run is a
    ripple-carry add/sub over bit-planes and folds into ONE per-column
    integer op; any run violating the conditions simply splits, so
    correctness never depends on the matcher being clever.

    Runs of OP_COPY with a uniform +/-1 row stride on dst and src
    ("copyrun"), and of predicated OP_W0/OP_W1 ("fillrun"), fold the
    same way: the whole run is one integer-domain move/mux instead of a
    per-row select -- the float programs' big/small builds, align
    shifts, flushes, and accumulator writebacks are made of exactly
    these.
    """
    items = []
    i, n = 0, len(stream)
    while i < n:
        ins = stream[i]
        if ins.op in (OP_FA, OP_FS):
            run = [ins]
            written = {ins.dst}
            j = i + 1
            while (j < n and len(run) < MAX_CHAIN
                   and stream[j].op == ins.op
                   and stream[j].pred == ins.pred
                   and stream[j].a not in written
                   and stream[j].b not in written):
                run.append(stream[j])
                written.add(stream[j].dst)
                j += 1
            if len(run) >= MIN_CHAIN:
                items.append(("chain", run))
            else:
                items.extend(("op", r) for r in run)
            i = j
        elif ins.op == OP_AND and not ins.pred:
            # partial-product idiom: a run of ANDs against one shared
            # operand row (the multiplier bit) is the bit-plane product
            # a_int * bit -- one integer multiply
            run = [ins]
            written = {ins.dst}
            j = i + 1
            while (j < n and len(run) < MAX_CHAIN
                   and stream[j].op == OP_AND
                   and not stream[j].pred
                   and stream[j].b == ins.b
                   and stream[j].a not in written
                   and stream[j].b not in written
                   and stream[j].dst not in written):
                run.append(stream[j])
                written.add(stream[j].dst)
                j += 1
            if len(run) >= MIN_CHAIN:
                items.append(("andrun", run))
            else:
                items.extend(("op", r) for r in run)
            i = j
        elif ins.op in (OP_OR, OP_XOR):
            # bitwise runs: OR/XOR over uniform-stride row windows (b
            # may also be one shared row) fold to a single integer-
            # domain bitwise op -- | and ^ act bit-plane-wise on the
            # packed integers, so no carry structure is needed at all
            run = [ins]
            written = {ins.dst}
            d = db = None
            j = i + 1
            while (j < n and len(run) < MAX_CHAIN
                   and stream[j].op == ins.op
                   and stream[j].pred == ins.pred):
                prev, nxt = run[-1], stream[j]
                dd = _ref_delta(prev.dst, nxt.dst)
                if dd not in (1, -1) or (d is not None and dd != d):
                    break
                if _ref_delta(prev.a, nxt.a) != dd or nxt.a in written:
                    break
                dbd = _ref_delta(prev.b, nxt.b)
                if dbd not in (0, dd) or (db is not None and dbd != db):
                    break
                if nxt.b in written or nxt.dst in written:
                    break
                d, db = dd, dbd
                run.append(nxt)
                written.add(nxt.dst)
                j += 1
            if len(run) >= MIN_CHAIN:
                items.append(("bitrun", run))
            else:
                items.extend(("op", r) for r in run)
            i = j
        elif (ins.op == OP_COPY
              or (ins.pred and ins.op in (OP_W0, OP_W1))):
            run = [ins]
            written = {ins.dst}
            d = None
            j = i + 1
            while (j < n and len(run) < MAX_CHAIN
                   and stream[j].op == ins.op
                   and stream[j].pred == ins.pred):
                prev, nxt = run[-1], stream[j]
                dd = _ref_delta(prev.dst, nxt.dst)
                if dd not in (1, -1) or (d is not None and dd != d):
                    break
                if ins.op == OP_COPY and (
                        _ref_delta(prev.a, nxt.a) != dd
                        or nxt.a in written):
                    break
                if nxt.dst in written:
                    break
                d = dd
                run.append(nxt)
                written.add(nxt.dst)
                j += 1
            if len(run) >= MIN_CHAIN:
                items.append(("copyrun" if ins.op == OP_COPY
                              else "fillrun", run))
            else:
                items.extend(("op", r) for r in run)
            i = j
        else:
            items.append(("op", ins))
            i += 1
    return items


# ---------------------------------------------------------------------------
# The abstract machine: executes a segmented ref-stream with pluggable
# row storage.  Values are (cols,) bool or (W,) uint32 vectors, with an
# optional leading lane axis; &, |, ^, ~ mean the same thing column-wise
# in every case, which is why one op-semantics body serves all stages.
# ---------------------------------------------------------------------------
class _Ctx:
    def __init__(self, cols: int, packed: bool):
        self.cols = cols
        self.packed = packed
        # packed interiors keep folded integers in the bit-plane domain
        # (see PLANE_DOMAIN): each plane IS a row's repr value, so
        # building/extracting integers is free and every arithmetic step
        # is a fusable bitwise op on uint32 words.
        self.planes = packed and PLANE_DOMAIN
        if packed:
            self.empty = jnp.zeros((n_words(cols),), jnp.uint32)
            self.full = jnp.full((n_words(cols),), 0xFFFFFFFF, jnp.uint32)
        else:
            self.empty = jnp.zeros((cols,), jnp.bool_)
            self.full = jnp.ones((cols,), jnp.bool_)

    def to_bits(self, v):
        """repr value(s) -> (..., cols) int32 of 0/1 bits."""
        if self.packed:
            return unpack_cols(v, self.cols).astype(jnp.int32)
        return v.astype(jnp.int32)

    def from_bools(self, bits):
        """(..., cols) bool -> repr value(s)."""
        return pack_cols(bits) if self.packed else bits


def _select(mask, x, y):
    # column-wise mux; 3 ops instead of 4 for (m & x) | (~m & y)
    return y ^ ((x ^ y) & mask)


def _stack(vals):
    """jnp.stack with broadcasting of base-shaped values to lane shape."""
    nd = max(v.ndim for v in vals)
    if any(v.ndim != nd for v in vals):
        shp = next(v.shape for v in vals if v.ndim == nd)
        vals = [v if v.ndim == nd else jnp.broadcast_to(v, shp)
                for v in vals]
    return jnp.stack(vals)


class _Lazy:
    """A row value defined as bit ``k`` of a per-column integer.

    Ripple chains compute whole integers; each written row is one bit of
    that integer.  Deferring the bit extraction keeps dependent chains in
    the integer domain (the next chain reads ``(s >> k) & mask`` instead
    of restacking bit-planes) and lets XLA skip rows nobody reads.
    """
    __slots__ = ("src", "bit", "_mat")

    def __init__(self, src, bit: int):
        self.src = src            # (..., cols) int32
        self.bit = bit
        self._mat = None

    def materialize(self, ctx: "_Ctx"):
        if self._mat is None:
            bit = ((self.src >> self.bit) & 1).astype(jnp.bool_)
            self._mat = ctx.from_bools(bit)
        return self._mat


def _mat(ctx, v):
    return v.materialize(ctx) if isinstance(v, _Lazy) else v


def _mat_many(ctx, vals):
    """Materialize a batch of values, extracting bits of a shared source
    integer together (one shift/pack for the whole group)."""
    groups: Dict[int, list] = {}
    for v in vals:
        if isinstance(v, _Lazy) and v._mat is None:
            groups.setdefault(id(v.src), []).append(v)
    for lazies in groups.values():
        if len(lazies) < 2:
            continue
        src = lazies[0].src
        ks = jnp.asarray([v.bit for v in lazies], jnp.int32)
        ks = ks.reshape((len(lazies),) + (1,) * src.ndim)
        bits = ((src[None] >> ks) & 1).astype(jnp.bool_)
        reprs = ctx.from_bools(bits)
        for j, v in enumerate(lazies):
            v._mat = reprs[j]
    return [_mat(ctx, v) for v in vals]


class _Machine:
    """Runs segmented micro-ops against read/write callbacks.

    ``prov`` maps row refs to ``(src_int, bit)`` -- the provenance of a
    row as one bit of a chain's integer result.  Chains whose operands
    are consecutive bits of one source skip bit-plane restacking
    entirely: ``a_int = (src >> k) & mask``.  The dict may be shared
    across machines (prefix -> serial suffix); ``lane_view`` then maps a
    lane-shaped (T, cols) source into this machine's frame.
    """

    def __init__(self, ctx: _Ctx, read, write, carry, tag,
                 prov=None, lane_view=None, peek=None, planes=None):
        self.ctx = ctx
        self._read_cb = read
        self._write_cb = write
        self.carry = carry        # repr array, _Lazy bit, or None (poison)
        self.tag = tag
        self.prov = {} if prov is None else prov
        self.lane_view = lane_view or (lambda v: v)
        self.peek = peek or (lambda ref: None)
        self._int_cache: Dict[tuple, jax.Array] = {}
        self._int_deps: Dict[tuple, set] = {}
        self._tagb = None
        # per-machine domain choice: serial per-lane suffix machines
        # force the int32 domain (their deep scalar carry chains make
        # XLA's scheduling blow up in the plane domain) while flat and
        # vectorized-prefix machines default to ctx.planes
        self.planes = ctx.planes if planes is None else planes

    # -- value access -------------------------------------------------------
    def read(self, ref):
        return _mat(self.ctx, self._read_cb(ref))

    def write(self, ref, v):
        self.prov.pop(ref, None)
        for key in self._int_deps.pop(ref, ()):
            self._int_cache.pop(key, None)
        self._write_cb(ref, v)

    def carry_repr(self):
        assert self.carry is not None, "read of uninitialized carry latch"
        return _mat(self.ctx, self.carry)

    def _carry_bits(self):
        c = self.carry
        assert c is not None, "read of uninitialized carry latch"
        if c is self.ctx.empty:
            return 0
        if isinstance(c, _Lazy):
            return (self.lane_view(c.src) >> c.bit) & 1
        return self.ctx.to_bits(c)

    def _tag_bits(self):
        if self._tagb is None or self._tagb[0] is not self.tag:
            self._tagb = (self.tag,
                          self.ctx.to_bits(_mat(self.ctx, self.tag)))
        return self._tagb[1]

    # -- integers -----------------------------------------------------------
    def _int_prov(self, refs, m):
        """(src >> k) & mask when refs are consecutive bits of one
        source int, optionally tailed by known-zero rows."""
        p0 = self.prov.get(refs[0])
        if p0 is None:
            return None
        src0, k0 = p0
        n = 1
        for r in refs[1:]:
            p = self.prov.get(r)
            if p is not None and p[0] is src0 and p[1] == k0 + n:
                n += 1
            else:
                break
        for r in refs[n:]:
            if self.peek(r) is not self.ctx.empty:
                return None
        src = self.lane_view(src0)
        out = (src >> k0) if k0 else src
        return out & ((1 << n) - 1)

    def _int_of(self, refs, m):
        key = tuple(refs)
        v = self._int_cache.get(key)
        if v is not None:
            return v
        v = self._int_prov(refs, m)
        if v is None:
            bits = self.ctx.to_bits(_stack(
                _mat_many(self.ctx, [self._read_cb(r) for r in refs])))
            w = (jnp.int32(1) << jnp.arange(m, dtype=jnp.int32))
            w = w.reshape((m,) + (1,) * (bits.ndim - 1))
            v = jnp.sum(bits * w, axis=0, dtype=jnp.int32)
        self._int_cache[key] = v
        for r in refs:
            self._int_deps.setdefault(r, set()).add(key)
        return v

    # -- bit-plane domain (packed interior) ---------------------------------
    def _plane_tag(self):
        return _mat(self.ctx, self.tag)

    def _plane_zero(self, v):
        """None (known zero) <-> repr sentinel conversion helpers."""
        return None if v is self.ctx.empty else v

    def _plane_val(self, v):
        return self.ctx.empty if v is None else v

    def _chain_planes(self, run):
        """FA/FS chain in the plane domain: one bitwise ripple
        (kernels.bitplane_ops.planes_add) whose planes are written back
        directly -- no int32 build, no bit extraction, exact carry."""
        ctx = self.ctx
        a = [self._plane_zero(self.read(c.a)) for c in run]
        b = [self._plane_zero(self.read(c.b)) for c in run]
        cin = self.carry
        assert cin is not None, "read of uninitialized carry latch"
        s, cout = bitplane_ops.planes_add(
            a, b, self._plane_zero(_mat(ctx, cin)),
            sub=run[0].op == OP_FS)
        if run[0].pred:
            # tag=0 columns keep their old rows and old carry -- the
            # same end-of-chain mux the int32 fold applies
            t = self._plane_tag()
            s = [_select(t, self._plane_val(x), self.read(c.dst))
                 for x, c in zip(s, run)]
            cout = _select(t, self._plane_val(cout), _mat(ctx, cin))
        for c, x in zip(run, s):
            self.write(c.dst, self._plane_val(x))
        self.carry = self._plane_val(cout)

    def _and_run_planes(self, run):
        b_bit = self.read(run[0].b)
        vals = [self.read(c.a) & b_bit for c in run]
        for c, v in zip(run, vals):
            self.write(c.dst, v)

    def _copy_run_planes(self, run):
        vals = [self.read(c.a) for c in run]
        if run[0].pred:
            t = self._plane_tag()
            vals = [_select(t, v, self.read(c.dst))
                    for v, c in zip(vals, run)]
        for c, v in zip(run, vals):
            self.write(c.dst, v)

    def _fill_run_planes(self, run):
        t = self._plane_tag()
        if run[0].op == OP_W0:
            vals = [self.read(c.dst) & ~t for c in run]
        else:
            vals = [self.read(c.dst) | t for c in run]
        for c, v in zip(run, vals):
            self.write(c.dst, v)

    def _bit_run_planes(self, run):
        op = run[0].op
        a = [self.read(c.a) for c in run]
        b = [self.read(c.b) for c in run]
        vals = [(x | y) if op == OP_OR else (x ^ y) for x, y in zip(a, b)]
        if run[0].pred:
            t = self._plane_tag()
            vals = [_select(t, v, self.read(c.dst))
                    for v, c in zip(vals, run)]
        for c, v in zip(run, vals):
            self.write(c.dst, v)

    # -- int32 domain (bool interior) ---------------------------------------
    def _chain(self, run):
        """One FA/FS ripple chain == one per-column integer add/sub,
        computed and kept in the integer domain (writes become lazy
        bit extractions; the carry latch becomes a lazy bit)."""
        if self.planes:
            return self._chain_planes(run)
        m = len(run)
        a_refs = [c.a for c in run]
        b_refs = [c.b for c in run]
        a_int = self._int_of(a_refs, m)
        b_int = self._int_of(b_refs, m)
        c_in = self._carry_bits()
        is_fa = run[0].op == OP_FA
        if is_fa:
            s = a_int + b_int + c_in
            c_out = None                # bit m of s (kept implicit)
        else:                           # OP_FS: d = a - b - borrow
            s = a_int - b_int - c_in
            c_out = (s < 0).astype(jnp.int32)
        if run[0].pred:
            # integer-domain mux: tag=0 columns keep old rows and carry
            tb = self._tag_bits()
            dst_refs = [c.dst for c in run]
            old = (a_int if dst_refs == a_refs
                   else self._int_of(dst_refs, m))
            zero_cin = isinstance(c_in, int) and c_in == 0
            if is_fa and not zero_cin:
                c_out = (s >> m) & 1
            s = old + (s - old) * tb
            if is_fa and zero_cin:
                # _int_of masks old to m bits, so bit m of the muxed sum
                # is tag & carry-out == select(tag, carry_out, c_in=0)
                c_out = None
            elif c_out is not None:
                c_out = c_in + (c_out - c_in) * tb
        # arithmetic >> keeps the low bits of s mod 2^m correct even for
        # a negative FS difference (two's complement)
        for i, c in enumerate(run):
            self.write(c.dst, _Lazy(s, i))
            self.prov[c.dst] = (s, i)
        # FA carry-out is bit m of the same sum: keeping that provenance
        # lets the next chain read [rows..., CSTORE row] as one integer
        self.carry = _Lazy(s, m) if c_out is None else _Lazy(c_out, 0)

    def _and_run(self, run):
        """Partial-product AND run == integer multiply by the shared bit."""
        if self.planes:
            return self._and_run_planes(run)
        m = len(run)
        a_int = self._int_of([c.a for c in run], m)
        b_bit = self.ctx.to_bits(self.read(run[0].b))
        s = a_int * b_bit
        for i, c in enumerate(run):
            self.write(c.dst, _Lazy(s, i))
            self.prov[c.dst] = (s, i)

    def _copy_run(self, run):
        """Uniform-stride COPY run == one integer-domain move (mux)."""
        if self.planes:
            return self._copy_run_planes(run)
        m = len(run)
        s = self._int_of([c.a for c in run], m)
        if run[0].pred:
            old = self._int_of([c.dst for c in run], m)
            s = old + (s - old) * self._tag_bits()
        for i, c in enumerate(run):
            self.write(c.dst, _Lazy(s, i))
            self.prov[c.dst] = (s, i)

    def _fill_run(self, run):
        """Predicated W0/W1 run == one integer-domain mask merge."""
        if self.planes:
            return self._fill_run_planes(run)
        m = len(run)
        old = self._int_of([c.dst for c in run], m)
        tb = self._tag_bits()
        if run[0].op == OP_W0:
            s = old - old * tb
        else:
            s = old + (((1 << m) - 1) - old) * tb
        for i, c in enumerate(run):
            self.write(c.dst, _Lazy(s, i))
            self.prov[c.dst] = (s, i)

    def _bit_run(self, run):
        """OR/XOR run over strided windows == one integer bitwise op
        (| and ^ distribute over bit planes of the packed integers)."""
        if self.planes:
            return self._bit_run_planes(run)
        m = len(run)
        a_int = self._int_of([c.a for c in run], m)
        b_int = self._int_of([c.b for c in run], m)
        s = (a_int | b_int) if run[0].op == OP_OR else (a_int ^ b_int)
        if run[0].pred:
            old = self._int_of([c.dst for c in run], m)
            s = old + (s - old) * self._tag_bits()
        for i, c in enumerate(run):
            self.write(c.dst, _Lazy(s, i))
            self.prov[c.dst] = (s, i)

    # -- main loop ----------------------------------------------------------
    def run(self, items):
        ctx = self.ctx
        empty, full = ctx.empty, ctx.full
        for kind, ins in items:
            if kind == "chain":
                self._chain(ins)
                continue
            if kind == "andrun":
                self._and_run(ins)
                continue
            if kind == "copyrun":
                self._copy_run(ins)
                continue
            if kind == "fillrun":
                self._fill_run(ins)
                continue
            if kind == "bitrun":
                self._bit_run(ins)
                continue
            op = ins.op
            if op == OP_NOP:
                continue
            # carry / tag latch ops ----------------------------------------
            if op == OP_C0:
                self.carry = (_select(self.tag, empty, self.carry_repr())
                              if ins.pred else empty)
            elif op == OP_C1:
                self.carry = (_select(self.tag, full, self.carry_repr())
                              if ins.pred else full)
            elif op == OP_CROW:
                ra = self.read(ins.a)
                self.carry = (_select(self.tag, ra, self.carry_repr())
                              if ins.pred else ra)
            elif op == OP_TC:
                self.tag = self.carry_repr()
            elif op == OP_TNC:
                self.tag = ~self.carry_repr()
            elif op == OP_TROW:
                self.tag = self.read(ins.a)
            elif op == OP_TNROW:
                self.tag = ~self.read(ins.a)
            elif op == OP_T1:
                self.tag = full
            elif op == OP_TAND:
                self.tag = self.tag & self.read(ins.a)
            elif op == OP_TOR:
                self.tag = self.tag | self.read(ins.a)
            elif op == OP_TNOT:
                self.tag = ~self.tag
            # row-writing ops ----------------------------------------------
            else:
                new_carry = self.carry
                if op == OP_COPY:
                    val = self.read(ins.a)
                elif op == OP_NOT:
                    val = ~self.read(ins.a)
                elif op == OP_AND:
                    val = self.read(ins.a) & self.read(ins.b)
                elif op == OP_OR:
                    val = self.read(ins.a) | self.read(ins.b)
                elif op == OP_XOR:
                    val = self.read(ins.a) ^ self.read(ins.b)
                elif op == OP_NOR:
                    val = ~(self.read(ins.a) | self.read(ins.b))
                elif op == OP_FA:
                    ra, rb = self.read(ins.a), self.read(ins.b)
                    carry = self.carry_repr()
                    axb = ra ^ rb
                    val = axb ^ carry
                    new_carry = (ra & rb) | (carry & axb)
                elif op == OP_FS:
                    ra, rb = self.read(ins.a), self.read(ins.b)
                    carry = self.carry_repr()
                    axb = ra ^ rb
                    val = axb ^ carry
                    new_carry = (~ra & rb) | (carry & ~axb)
                elif op == OP_W0:
                    val = empty
                elif op == OP_W1:
                    val = full
                elif op == OP_CSTORE:
                    val = self.carry   # may stay lazy on the unpred path
                    new_carry = empty
                elif op == OP_TSTORE:
                    val = self.tag
                else:
                    raise ValueError(f"unknown opcode {op}")
                if ins.pred:
                    val = _select(self.tag, _mat(ctx, val),
                                  self.read(ins.dst))
                    if new_carry is not self.carry:   # op touched carry
                        new_carry = _select(self.tag, _mat(ctx, new_carry),
                                            self.carry_repr())
                keep_prov = (op == OP_CSTORE and not ins.pred
                             and isinstance(val, _Lazy))
                self.write(ins.dst, val)
                if keep_prov:     # CSTORE forwards the carry bit's source
                    self.prov[ins.dst] = (val.src, val.bit)
                self.carry = new_carry


# ---------------------------------------------------------------------------
# Lane analysis
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class LanePlan:
    lanes: int                  # T
    stride: int                 # row offset between consecutive lanes
    serial_start: int           # body position where the serial suffix begins
    pre: List[Instr]            # flat streams around the lane loop
    post: List[Instr]
    body: List[Instr]           # ref-stream of one iteration (lane 0 rows)
    const_kind: Dict[int, str]  # const row -> "kill" | "ro" | "red"
    carry_in_prefix: bool       # prefix writes the carry latch
    tag_in_prefix: bool
    carry_in_body: bool
    tag_in_body: bool


def _used_slots(ins: Instr):
    reads, writes = [], []
    if ins.op in _READS_A:
        reads.append("a")
    if ins.op in _READS_B:
        reads.append("b")
    if ins.op in _WRITES_ROW:
        writes.append("dst")
        if ins.pred:
            reads.append("dst")   # predicated writes read back dst
    return reads, writes


def _coverage_kills(stream: Sequence[Instr]) -> set:
    """Rows fully written before any exposed read, counting predicated
    complementary pairs as one full write.

    The float programs build scratch values with two predicated passes:

        trow g ; ?t copy r, ...     # columns where g
        tnrow g ; ?t copy r, ...    # columns where ~g

    Together the pair overwrites every column of ``r``, so ``r`` is
    lane-private scratch exactly like an unpredicated ("kill") write --
    but the per-position classification in :func:`analyze` only sees a
    predicated first write and pins it "red", forcing the serial suffix
    to start there.  This pass walks one iteration tracking the tag
    latch as an abstract value and returns the rows proven *covered*:

    * an unpredicated write (or one under ``t1``) covers immediately;
    * a predicated write under ``tag <- row[g]`` (or its negation)
      records a *half*; the complementary half -- same guard row ``g``,
      opposite polarity, ``g`` unwritten between the two tag latches --
      completes the cover;
    * any exposed read before the cover completes (operand reads and
      guard reads; a predicated write's read-back of its own dst is the
      mux being modeled, not an exposed read) disqualifies the row --
      EXCEPT *masked* reads, which only observe columns the pending
      half-write already covered:

      - ``tand r`` (and operand reads of predicated ops) observe ``r``
        only where the tag is 1: safe when the half was written under
        the exact current tag ``(g, neg)``;
      - ``tor r`` observes ``r`` only where the tag is 0: safe when the
        half was written under the *complementary* ``(g, ~neg)``.

      This is what unseals the float adder's carry-out idiom
      (``?t cstore COUT`` under ``tag<-row[SUB]`` followed by
      ``trow SUB; tand COUT``): the tand reads exactly the half-written
      columns, the later unpredicated ``tstore COUT`` completes the
      cover, so COUT is lane-private scratch and no longer pins a
      serial suffix.

    Rows never pair-written are simply absent -- the default
    classification applies, so this only ever *upgrades* red to kill.
    """
    ver: Dict[int, int] = {}
    tag = None                    # ("row", g, neg, ver) | ("one",) | None
    halves: Dict[int, tuple] = {}
    covered: set = set()
    dead: set = set()

    def spoil(r):
        dead.add(r)
        halves.pop(r, None)

    for ins in stream:
        reads, writes = _used_slots(ins)
        for slot in reads:
            if slot == "dst":
                continue          # predicated write read-back: the mux
            r = getattr(ins, slot)
            if r in covered:
                continue
            half = halves.get(r)
            if half is not None and tag is not None and tag[0] == "row":
                g, neg, gv = tag[1], tag[2], tag[3]
                masked_by_tag = (ins.op == OP_TAND
                                 or (ins.pred and ins.op in _WRITES_ROW))
                if masked_by_tag and half == (g, neg, gv):
                    continue      # observes only half-written columns
                if ins.op == OP_TOR and half == (g, not neg, gv):
                    continue      # tor reads where tag=0: the other half
            spoil(r)
        if ins.op in (OP_TROW, OP_TNROW):
            tag = ("row", ins.a, ins.op == OP_TNROW, ver.get(ins.a, 0))
        elif ins.op == OP_T1:
            tag = ("one",)
        elif ins.op in _TAG_WRITE:
            tag = None            # TC/TNC/TAND/TOR/TNOT: unknown mask
        if not writes:
            continue
        r = ins.dst
        ver[r] = ver.get(r, 0) + 1
        if r in covered or r in dead:
            continue
        if not ins.pred or tag == ("one",):
            covered.add(r)
            halves.pop(r, None)
        elif tag is None:
            spoil(r)
        else:
            _, g, neg, gv = tag
            prev = halves.get(r)
            if prev is None:
                halves[r] = (g, neg, gv)
            elif prev == (g, not neg, gv):
                covered.add(r)
                halves.pop(r, None)
            elif prev != (g, neg, gv):
                spoil(r)
    return covered


def analyze(program: isa.Program) -> Optional[LanePlan]:
    """Plan for the single dominant top-level loop; None = fall back.

    Kept as the introspection API (tests/benchmarks assert on it); the
    lowering itself goes through :func:`analyze_multi`, which plans
    EVERY top-level loop so chained/concatenated programs with two or
    more dominant loops vectorize each of them.
    """
    grouped = program.expand_grouped()
    if grouped is None:
        return None
    pre, iters, post = grouped
    return _plan_loop(pre, iters, post)


def analyze_multi(program: isa.Program):
    """Segment the program at every top-level loop and plan each.

    Returns a list of ``("flat", stream)`` / ``("loop", LanePlan)``
    segments (plans carry empty pre/post), or None when no loop admits
    a plan -- the caller then flat-lowers the whole stream.  Loops whose
    plan fails degrade to flat segments, so correctness never depends
    on any individual loop vectorizing.
    """
    out, any_plan = [], False
    for kind, payload in program.expand_segments():
        if kind == "loop":
            plan = _plan_loop([], payload, [])
            if plan is not None:
                out.append(("loop", plan))
                any_plan = True
                continue
            payload = [i for it in payload for i in it]
        if out and out[-1][0] == "flat":
            out[-1] = ("flat", out[-1][1] + list(payload))
        else:
            out.append(("flat", list(payload)))
    return out if any_plan else None


def _plan_loop(pre, iters, post) -> Optional[LanePlan]:
    """Lane-vectorization analysis of one loop's iteration streams."""
    T = len(iters)
    L = len(iters[0])
    if T < 2 or L == 0:
        return None
    sig = [(i.op, i.pred) for i in iters[0]]
    if any([(i.op, i.pred) for i in it] != sig for it in iters[1:]):
        return None

    # per-position operand rows across lanes -> const or affine refs
    stride = None
    refs: List[Dict[str, tuple]] = []
    for p in range(L):
        slots = {}
        reads, writes = _used_slots(iters[0][p])
        for slot in set(reads + writes):
            rows = [getattr(iters[t][p], slot) for t in range(T)]
            d = rows[1] - rows[0]
            if any(rows[t] != rows[0] + t * d for t in range(T)):
                return None
            if d == 0:
                slots[slot] = ("k", rows[0])
            else:
                if stride is None:
                    stride = d
                elif d != stride:
                    return None
                slots[slot] = ("l", rows[0])
        refs.append(slots)
    if stride is None:
        return None               # nothing varies; vectorizing buys nothing

    # lanes must occupy disjoint row windows
    residues = [ref[1] for slots in refs for ref in slots.values()
                if ref[0] == "l"]
    if not residues or max(residues) - min(residues) >= abs(stride):
        return None
    affine_rows = {c + t * stride for c in residues for t in range(T)}
    const_rows = {ref[1] for slots in refs for ref in slots.values()
                  if ref[0] == "k"}
    if affine_rows & const_rows:
        return None

    # classify const rows by their first access within an iteration.
    # Rows whose first access is a predicated write may still be lane-
    # private scratch when complementary predicated passes are proven to
    # fully overwrite them (the float-program idiom) -- _coverage_kills
    # upgrades exactly those from "red" to "kill".
    const_written = set()
    for p in range(L):
        _, writes = _used_slots(iters[0][p])
        for slot in writes:
            if refs[p].get(slot, (None,))[0] == "k":
                const_written.add(refs[p][slot][1])
    covered = _coverage_kills(iters[0])
    const_kind: Dict[int, str] = {}
    for p in range(L):
        ins = iters[0][p]
        reads, writes = _used_slots(ins)
        for slot in reads:
            r = refs[p].get(slot)
            if not (r and r[0] == "k") or r[1] in const_kind:
                continue
            if slot == "dst" and r[1] in covered:
                continue      # covered row's own predicated-write mux
            const_kind[r[1]] = ("ro" if r[1] not in const_written
                                else "red")
        for slot in writes:
            r = refs[p].get(slot)
            if r and r[0] == "k" and r[1] not in const_kind:
                const_kind[r[1]] = ("kill" if not ins.pred
                                    or r[1] in covered else "red")

    # find where the serial suffix must begin: the first position that
    # touches a reduction row, or reads a carry/tag value inherited from
    # the previous iteration
    carry_in_body = any(i.op in _CARRY_WRITE for i in iters[0])
    tag_in_body = any(i.op in _TAG_WRITE for i in iters[0])
    carry_ok = not carry_in_body
    tag_ok = not tag_in_body
    serial_start = L
    for p, ins in enumerate(iters[0]):
        reads_carry = (ins.op in _CARRY_READ
                       or (ins.pred and ins.op in (OP_C0, OP_C1, OP_CROW)))
        reads_tag = ins.pred or ins.op in _TAG_READ
        touches_red = any(
            ref[0] == "k" and const_kind.get(ref[1]) == "red"
            for ref in refs[p].values())
        if ((reads_carry and not carry_ok) or (reads_tag and not tag_ok)
                or touches_red):
            serial_start = p
            break
        if not ins.pred and ins.op in _CARRY_KILL:
            carry_ok = True
        if ins.op in _TAG_KILL:
            tag_ok = True         # TC/TNC read carry: checked above
    if serial_start == 0:
        return None

    body = _to_refs(iters[0], lambda p, s: refs[p][s])
    prefix_ins = iters[0][:serial_start]
    return LanePlan(
        lanes=T, stride=stride, serial_start=serial_start,
        pre=pre, post=post, body=body, const_kind=const_kind,
        carry_in_prefix=any(i.op in _CARRY_WRITE for i in prefix_ins),
        tag_in_prefix=any(i.op in _TAG_WRITE for i in prefix_ins),
        carry_in_body=carry_in_body, tag_in_body=tag_in_body)


# ---------------------------------------------------------------------------
# Lowerings
# ---------------------------------------------------------------------------
def _row(arr, r: int):
    """Static single-row read (slice+squeeze: no bounds clamping)."""
    return jax.lax.squeeze(jax.lax.slice_in_dim(arr, r, r + 1, axis=0), (0,))


def _rows(arr, idx: np.ndarray):
    """Gather of statically-known in-bounds row indices."""
    return arr.at[idx].get(mode="promise_in_bounds", unique_indices=True)


def _lane_last(v):
    """Final (lane T-1) view of a possibly lane-shaped value."""
    if isinstance(v, _Lazy):
        return _Lazy(v.src[-1], v.bit) if v.src.ndim == 2 else v
    return v if v.ndim == 1 else v[-1]


def _lane_at(v, t):
    if isinstance(v, _Lazy):
        return _Lazy(v.src[t], v.bit) if v.src.ndim == 2 else v
    return v if v.ndim == 1 else v[t]


def _scatter(ctx, arr, updates: Dict[int, jax.Array]):
    """One batched row update from a {row: value} dict."""
    if not updates:
        return arr
    rows = sorted(updates)
    idx = np.asarray(rows, np.int32)
    vals = jnp.stack(_mat_many(ctx, [updates[r] for r in rows]))
    return arr.at[idx].set(vals, mode="promise_in_bounds",
                           unique_indices=True)


def _run_flat(ctx, items, arr, store, carry, tag):
    """Run a flat ('k'-ref) segmented stream over a row store."""
    def read(ref):
        v = store.get(ref[1])
        if v is None:
            v = store[ref[1]] = _row(arr, ref[1])
        return v

    written = {}

    def write(ref, v):
        store[ref[1]] = written[ref[1]] = v

    m = _Machine(ctx, read, write, carry, tag,
                 peek=lambda ref: store.get(ref[1]))
    m.run(items)
    return written, m.carry, m.tag


def _lower_flat(program: isa.Program, rows: int, cols: int, packed: bool,
                packed_io: bool = False):
    items = _segment(_flat_refs(program.expand()))

    def fn(state):
        ctx = _Ctx(cols, packed)
        if packed and not packed_io:
            arr = pack_cols(state.array)
            carry, tag = pack_cols(state.carry), pack_cols(state.tag)
        else:
            arr, carry, tag = state.array, state.carry, state.tag
        written, carry, tag = _run_flat(ctx, items, arr, {}, carry, tag)
        arr = _scatter(ctx, arr, written)
        if packed and not packed_io:
            return type(state)(unpack_cols(arr, cols),
                               unpack_cols(_mat(ctx, carry), cols),
                               unpack_cols(_mat(ctx, tag), cols))
        return type(state)(arr, _mat(ctx, carry), _mat(ctx, tag))

    return fn


@dataclasses.dataclass
class _LoopLow:
    """Per-loop static lowering data (shared by every trace)."""
    plan: LanePlan
    prefix_items: list
    suffix_items: list
    suffix: list                 # raw suffix ref-stream
    suffix_affine_writes: set
    prefetch: list
    written_rows: set            # absolute rows the loop writes
    fold: Optional[list]         # foldable accumulate chain, or None


def _loop_static(plan: LanePlan) -> _LoopLow:
    T, s = plan.lanes, plan.stride
    prefix = plan.body[:plan.serial_start]
    suffix = plan.body[plan.serial_start:]
    suffix_affine_writes = {ins.dst[1] for ins in suffix
                            if ins.op in _WRITES_ROW and ins.dst[0] == "l"}

    # affine rows whose first body access is a read come straight from
    # the array: fetch them all in ONE gather instead of one per residue
    written_refs, prefetch = set(), []
    for ins in plan.body:
        reads, writes = _used_slots(ins)
        for slot in reads:
            ref = getattr(ins, slot)
            if (ref is not None and ref[0] == "l"
                    and ref not in written_refs
                    and ref[1] not in prefetch):
                prefetch.append(ref[1])
        if writes:
            written_refs.add(ins.dst)
    prefetch = sorted(prefetch)

    written_rows = set()
    for ins in plan.body:
        if ins.op in _WRITES_ROW:
            if ins.dst[0] == "k":
                written_rows.add(ins.dst[1])
            else:
                written_rows.update(ins.dst[1] + t * s for t in range(T))

    # the serial-suffix ACCUMULATION FOLD: a suffix that is exactly one
    # unpredicated in-place FA chain over shared reduction rows
    # (``acc += lane_value``, carry killed in the prefix) is T modular
    # adds -- associative, so the per-lane serial loop collapses into a
    # log-depth lane fold (kernels.bitplane_ops.lane_fold) plus one
    # carry-exact final add with the last lane.  This is what lets dot-
    # product programs scale with block count instead of serializing.
    suffix_items = _segment(suffix)
    fold = None
    if len(suffix_items) == 1 and suffix_items[0][0] == "chain":
        run = suffix_items[0][1]
        a_refs = [c.a for c in run]
        prefix_writes = {ins.dst for ins in prefix if ins.op in _WRITES_ROW}
        if (run[0].op == OP_FA and not run[0].pred
                and all(c.dst == c.a for c in run)
                and all(r[0] == "k" for r in a_refs)
                and not ({c.b for c in run} & set(a_refs))
                and not (set(a_refs) & prefix_writes)
                and plan.carry_in_prefix):
            fold = run
    return _LoopLow(plan, _segment(prefix), suffix_items, suffix,
                    suffix_affine_writes, prefetch, written_rows, fold)


def _run_loop(ctx, ll: _LoopLow, arr, carry, tag, store):
    """Execute one planned loop against (arr, carry, tag).

    ``store`` caches const-row values across segments (reads reuse it;
    rows this loop writes are refreshed/invalidated on exit).
    """
    plan = ll.plan
    T, s = plan.lanes, plan.stride
    suffix = ll.suffix

    # ---- vectorized prefix: all lanes at once ----------------------------
    lane_store: Dict[tuple, jax.Array] = {}
    lane_written: Dict[tuple, bool] = {}
    if ll.prefetch:
        idx = np.asarray([[c + t * s for t in range(T)]
                          for c in ll.prefetch], np.int32)
        block = _rows(arr, idx)            # (n_prefetch, T, cols|W)
        for i, c in enumerate(ll.prefetch):
            lane_store[("l", c)] = block[i]

    def lane_read(ref):
        v = lane_store.get(ref)
        if v is None:
            if ref[0] == "k":
                v = store.get(ref[1])
                if v is None:
                    v = _row(arr, ref[1])
            else:
                idx = np.asarray(
                    [ref[1] + t * s for t in range(T)], np.int32)
                v = _rows(arr, idx)
            lane_store[ref] = v
        return v

    def lane_write(ref, v):
        lane_store[ref] = v
        lane_written[ref] = True

    def lane_peek(ref):
        v = lane_store.get(ref)
        if v is None and ref[0] == "k":
            v = store.get(ref[1])
        return v

    # a poisoned latch would mean the analysis mis-ordered a kill;
    # reading it raises at trace time rather than miscomputing
    pm = _Machine(ctx, lane_read, lane_write,
                  None if plan.carry_in_prefix else carry,
                  None if plan.tag_in_prefix else tag,
                  peek=lane_peek)
    pm.run(ll.prefix_items)

    # ---- suffix ----------------------------------------------------------
    suffix_store: Dict[int, jax.Array] = {}
    suffix_lane_vals: Dict[int, list] = {c: [] for c
                                         in ll.suffix_affine_writes}
    if suffix and ll.fold is not None and pm.carry is ctx.empty:
        run = ll.fold
        m = len(run)

        def as_planes(vals):
            return [None if v is ctx.empty else v for v in vals]

        bplanes = []
        for c in run:
            v = lane_read(c.b)
            if v is ctx.empty:
                bplanes.append(None)
                continue
            v = _mat(ctx, v)
            if v.ndim == 1:        # shared row: same addend every lane
                v = jnp.broadcast_to(v, (T,) + v.shape)
            bplanes.append(v)
        acc0 = []
        for c in run:
            v = store.get(c.a[1])
            v = _row(arr, c.a[1]) if v is None else _mat(ctx, v)
            acc0.append(v)
        acc0 = as_planes(acc0)
        if T > 1:
            main = [None if p is None else p[:T - 1] for p in bplanes]
            red = bitplane_ops.lane_fold(main, m, packed=ctx.packed)
            accm, _ = bitplane_ops.planes_add(acc0, red, None, width=m)
        else:
            accm = acc0
        last = [None if p is None else p[T - 1] for p in bplanes]
        # the final add runs carry-exact: its carry-out IS the latch the
        # last serial lane would have left (bit m of acc_{T-1} + b_{T-1})
        final, cout = bitplane_ops.planes_add(accm, last, None, width=m)
        for c, x in zip(run, final):
            suffix_store[c.a[1]] = ctx.empty if x is None else x
        carry = ctx.empty if cout is None else cout
        if plan.tag_in_prefix:
            tag = _lane_last(pm.tag)
    elif suffix:
        # chain operands produced by the prefix (e.g. idot's product
        # rows) are integer-summarized ONCE across all lanes here,
        # instead of once per lane inside the serial loop
        suffix_written = {ins.dst for ins in suffix
                          if ins.op in _WRITES_ROW}
        shared_ints: Dict[tuple, jax.Array] = {}
        for kind, run in ll.suffix_items:
            if kind not in ("chain", "andrun", "copyrun"):
                continue
            ref_lists = [[c.a for c in run]]
            if kind == "chain":
                ref_lists.append([c.b for c in run])
            for refs in ref_lists:
                key = tuple(refs)
                if key in shared_ints or (set(refs) & suffix_written):
                    continue
                shared_ints[key] = pm._int_of(refs, len(run))
        ser_carry = carry if not plan.carry_in_prefix else None
        ser_tag = tag if not plan.tag_in_prefix else None
        kill_scoped: Dict[int, jax.Array] = {}
        for t in range(T):
            # "kill" rows are lane-private scratch: every lane
            # overwrites them before reading, so suffix writes to
            # them must not leak into the next lane (which still
            # sees its own prefix value)
            kill_scoped = {}
            if t:
                # provenance written by the previous lane's suffix
                # (1-D sources) is stale for this lane on exactly
                # the lane-private refs: kill consts and affine
                # rows.  Prefix provenance (lane-shaped 2-D
                # sources, mapped by lane_view) and shared
                # reduction rows stay valid.
                for ref, (src, _b) in list(pm.prov.items()):
                    if getattr(src, "ndim", 1) == 2:
                        continue
                    if (ref[0] == "l"
                            or plan.const_kind.get(ref[1]) == "kill"):
                        del pm.prov[ref]

            def ser_read(ref, t=t, ks=kill_scoped):
                if ref[0] == "k":
                    r = ref[1]
                    if plan.const_kind.get(r) == "kill":
                        v = ks.get(r)
                        if v is None:
                            v = lane_store.get(ref)
                            return (_row(arr, r) if v is None
                                    else _lane_at(v, t))
                        return v
                    v = suffix_store.get(r)
                    if v is not None:
                        return v
                    v = lane_store.get(ref)
                    if v is not None:
                        return _lane_at(v, t)
                    v = store.get(r)
                    return _row(arr, r) if v is None else v
                lst = suffix_lane_vals.get(ref[1])
                if lst is not None and len(lst) > t:
                    return lst[t]
                v = lane_store.get(ref)
                if v is not None:
                    return _lane_at(v, t)
                return _row(arr, ref[1] + t * s)

            def ser_peek(ref, t=t, ks=kill_scoped):
                if ref[0] == "k":
                    r = ref[1]
                    for d in (ks, suffix_store, store):
                        if r in d:
                            return d[r]
                    return None
                lst = suffix_lane_vals.get(ref[1])
                if lst is not None and len(lst) > t:
                    return lst[t]
                return None

            def ser_write(ref, v, t=t, ks=kill_scoped):
                if ref[0] == "k":
                    if plan.const_kind.get(ref[1]) == "kill":
                        ks[ref[1]] = v
                    else:
                        suffix_store[ref[1]] = v
                else:
                    lst = suffix_lane_vals[ref[1]]
                    if len(lst) == t:      # first write this lane
                        lst.append(v)
                    else:                  # rewrite: last value wins
                        lst[t] = v

            sm = _Machine(
                ctx, ser_read, ser_write,
                _lane_at(pm.carry, t) if plan.carry_in_prefix
                else ser_carry,
                _lane_at(pm.tag, t) if plan.tag_in_prefix else ser_tag,
                prov=pm.prov, peek=ser_peek,
                lane_view=lambda v, t=t: v[t] if v.ndim == 2 else v,
                planes=False)
            for key, v in shared_ints.items():
                sm._int_cache[key] = v[t] if v.ndim == 2 else v
            sm.run(ll.suffix_items)
            ser_carry, ser_tag = sm.carry, sm.tag
        carry, tag = ser_carry, ser_tag
        # final values of lane-private rows rewritten by the last
        # lane's suffix override its prefix values
        suffix_store.update(kill_scoped)
    else:
        if plan.carry_in_body:
            carry = _lane_last(pm.carry)
        if plan.tag_in_body:
            tag = _lane_last(pm.tag)

    # ---- materialize final rows ------------------------------------------
    const_updates: Dict[int, jax.Array] = {}
    for ref in lane_written:
        if ref[0] == "k":
            const_updates[ref[1]] = _lane_last(lane_store[ref])
    const_updates.update(suffix_store)
    arr = _scatter(ctx, arr, const_updates)

    # all affine row groups land in one batched scatter
    aff_idx, aff_vals = [], []
    for ref in lane_written:            # prefix affine writes
        if ref[0] == "l" and ref[1] not in ll.suffix_affine_writes:
            aff_idx.append(np.asarray(
                [ref[1] + t * s for t in range(T)], np.int32))
            v = _mat(ctx, lane_store[ref])
            if v.ndim == 1:
                v = jnp.broadcast_to(v, (T,) + v.shape)
            aff_vals.append(v)
    for c, lst in suffix_lane_vals.items():
        aff_idx.append(np.asarray(
            [c + t * s for t in range(T)], np.int32))
        aff_vals.append(_stack(_mat_many(ctx, lst)))
    if aff_idx:
        arr = arr.at[np.concatenate(aff_idx)].set(
            jnp.concatenate(aff_vals), mode="promise_in_bounds",
            unique_indices=True)

    # keep the cross-segment row store coherent: rows this loop wrote
    # are refreshed (const rows) or dropped (affine rows); everything
    # the loop left alone stays resident for the next segment
    for r in ll.written_rows:
        store.pop(r, None)
    for r, v in const_updates.items():
        store[r] = v
    return arr, carry, tag


def _lower_multi(program: isa.Program, rows: int, cols: int, packed: bool,
                 segs, packed_io: bool = False):
    """Lower a segmented program: flat runs + one `_run_loop` per plan.

    ``segs`` comes from :func:`analyze_multi`.  A shared row store keeps
    const rows resident across segment boundaries so chained loops (two
    dominant loops, fabric-composed programs) don't re-gather rows the
    previous segment just computed.
    """
    lowered = []
    for kind, payload in segs:
        if kind == "loop":
            lowered.append(("loop", _loop_static(payload)))
        else:
            lowered.append(("flat", _segment(_flat_refs(payload))))

    def fn(state):
        ctx = _Ctx(cols, packed)
        if packed and not packed_io:
            arr = pack_cols(state.array)
            carry, tag = pack_cols(state.carry), pack_cols(state.tag)
        else:
            arr, carry, tag = state.array, state.carry, state.tag
        store: Dict[int, jax.Array] = {}
        for kind, payload in lowered:
            if kind == "flat":
                written, carry, tag = _run_flat(ctx, payload, arr, store,
                                                carry, tag)
                arr = _scatter(ctx, arr, written)
            else:
                arr, carry, tag = _run_loop(ctx, payload, arr, carry, tag,
                                            store)
        carry, tag = _mat(ctx, carry), _mat(ctx, tag)
        if packed and not packed_io:
            return type(state)(unpack_cols(arr, cols),
                               unpack_cols(carry, cols),
                               unpack_cols(tag, cols))
        return type(state)(arr, carry, tag)

    return fn


def lower(program: isa.Program, rows: int, cols: int, packed: bool, *,
          packed_io: bool = False):
    """Lower ``program`` to a pure fn(CRState) -> CRState (un-jitted).

    ``packed_io`` (implies ``packed``) makes the fn take and return a
    state whose fields are already column-packed uint32 words; callers
    that chain launches keep state packed end-to-end and skip the
    per-launch pack/unpack ladders entirely.

    Prefix-affine reads (``lane_read``) only appear when a lane plan
    validates; otherwise the whole stream goes through `_lower_flat`.
    """
    if packed_io:
        packed = True
    meta = program.meta()
    if meta.max_row >= rows:
        raise ValueError(
            f"program {program.name!r} touches row {meta.max_row} but the "
            f"geometry has only {rows} rows")
    segs = analyze_multi(program)
    if segs is not None:
        return _lower_multi(program, rows, cols, packed, segs, packed_io)
    return _lower_flat(program, rows, cols, packed, packed_io)


# ---------------------------------------------------------------------------
# Jaxpr-level CSE.  Big flat-lowered programs (the float sequences: no
# lane plan, thousands of micro-ops) trace to jaxprs with many repeated
# pure equations -- identical selects, mask extractions, pack/unpack
# ladders.  XLA eventually CSEs them too, but only after ingesting the
# full graph; deduplicating *before* jit hands XLA a smaller program and
# cuts compile time.  The pass is a single forward walk: equations are
# keyed on (primitive, canonicalized invars, params) and replayed
# through ``eval_jaxpr``; anything it cannot prove safe to key (effects,
# sub-jaxpr params, exotic literals) is simply kept, so correctness
# never depends on coverage.
# ---------------------------------------------------------------------------
def _freeze(v):
    """Hashable snapshot of an eqn param value; None = give up."""
    if isinstance(v, (bool, int, float, str, bytes, type(None), type)):
        return v
    if isinstance(v, (tuple, list)):
        parts = tuple(_freeze(x) for x in v)
        return None if any(p is None for p in parts) else (type(v).__name__,
                                                           parts)
    if isinstance(v, dict):
        items = tuple(sorted((k, _freeze(x)) for k, x in v.items()))
        return None if any(p is None for _, p in items) else ("dict", items)
    if isinstance(v, np.dtype):
        return ("dtype", v.str)
    if isinstance(v, np.ndarray):
        if v.size > 256:
            return None
        return ("ndarray", v.dtype.str, v.shape, v.tobytes())
    try:
        hash(v)
    except TypeError:
        return None
    # jaxprs / closures / trackers: identity is the only safe equality
    return ("id", id(v))


def _literal_key(lit):
    val = lit.val
    if isinstance(val, (bool, int, float, complex)):
        return ("lit", str(lit.aval), val)
    arr = np.asarray(val)
    if arr.size > 256:
        return None
    return ("lit", str(lit.aval), arr.dtype.str, arr.shape, arr.tobytes())


def cse_jaxpr(closed):
    """Common-subexpression-eliminate a ClosedJaxpr (pure eqns only).

    Returns ``(new_closed_jaxpr, n_removed)``.
    """
    import jax.core as jcore

    jaxpr = closed.jaxpr
    subst: Dict = {}

    def canon(v):
        if isinstance(v, jcore.Literal):
            return v
        return subst.get(v, v)

    table: Dict = {}
    new_eqns = []
    removed = 0
    for eqn in jaxpr.eqns:
        invars = [canon(v) for v in eqn.invars]
        key = None
        if not eqn.effects:
            parts = [_freeze(dict(eqn.params))]
            for v in invars:
                parts.append(_literal_key(v) if isinstance(v, jcore.Literal)
                             else v)
            if all(p is not None for p in parts):
                key = (eqn.primitive, tuple(parts))
        if key is not None:
            hit = table.get(key)
            # every output the duplicate defines must exist on the kept
            # eqn (a DropVar there has no value to forward)
            if hit is not None and all(
                    isinstance(old, jcore.DropVar)
                    or not isinstance(new, jcore.DropVar)
                    for old, new in zip(eqn.outvars, hit)):
                for old, new in zip(eqn.outvars, hit):
                    if not isinstance(old, jcore.DropVar):
                        subst[old] = new
                removed += 1
                continue
        eqn = eqn.replace(invars=invars)
        new_eqns.append(eqn)
        if key is not None:
            table[key] = eqn.outvars
    new_jaxpr = jaxpr.replace(
        eqns=new_eqns, outvars=[canon(v) for v in jaxpr.outvars])
    return jcore.ClosedJaxpr(new_jaxpr, closed.consts), removed


def apply_cse(fn, *example_args):
    """Wrap ``fn`` so it evaluates through a CSE'd jaxpr (un-jitted).

    ``example_args`` are pytrees of arrays or ``jax.ShapeDtypeStruct``
    giving the call signature to trace.  On ANY failure the original
    ``fn`` is returned untouched -- the pass is an optimization, never a
    correctness dependency.  The returned callable carries a
    ``_cse_stats`` dict (eqn counts) for benchmarks.
    """
    import jax.core as jcore

    try:
        closed, out_shape = jax.make_jaxpr(
            fn, return_shape=True)(*example_args)
        n_before = len(closed.jaxpr.eqns)
        new_closed, removed = cse_jaxpr(closed)
        out_tree = jax.tree_util.tree_structure(out_shape)

        def cse_fn(*args):
            flat = jax.tree_util.tree_leaves(args)
            outs = jcore.eval_jaxpr(new_closed.jaxpr, new_closed.consts,
                                    *flat)
            return jax.tree_util.tree_unflatten(out_tree, outs)

        cse_fn._cse_stats = {"eqns_before": n_before,
                             "eqns_after": n_before - removed,
                             "removed": removed}
        return cse_fn
    except Exception:                                   # pragma: no cover
        return fn
