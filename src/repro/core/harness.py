"""Host-side load/readback for Compute RAM layouts.

In a real deployment the FPGA-side state machine writes operands into the
block in storage mode (paper §III-B); here, numpy plays that role.  Data
is laid out transposed per :class:`repro.core.programs.TupleLayout`.

:func:`run_program` is the one-call harness used by tests and examples:
pack operands, execute with a chosen executor (``unroll`` / ``scan`` /
``compiled``), and return the final main-array image.
"""

from __future__ import annotations

import numpy as np

from .programs import TupleLayout


def pack_state(layout: TupleLayout, data: dict, cols: int) -> np.ndarray:
    """Build the (rows, cols) bool main-array image.

    ``data[name]`` is a ``(tuples, cols)`` array of unsigned ints (or
    uint16 bf16 bit patterns) for each layout field being loaded.
    """
    arr = np.zeros((layout.rows, cols), dtype=bool)
    for name, vals in data.items():
        off, width = layout.fields[name]
        vals = np.asarray(vals, np.uint64)
        if vals.shape != (layout.tuples, cols):
            raise ValueError(
                f"{name}: expected {(layout.tuples, cols)}, got {vals.shape}")
        bases = np.array([layout.base(t) for t in range(layout.tuples)])
        for i in range(width):
            bit = (vals >> np.uint64(i)) & np.uint64(1)
            arr[bases + off + i, :] = bit.astype(bool)
    return arr


def unpack_field(arr: np.ndarray, layout: TupleLayout, name: str) -> np.ndarray:
    """Read a layout field back as ``(tuples, cols)`` unsigned ints."""
    arr = np.asarray(arr)
    off, width = layout.fields[name]
    out = np.zeros((layout.tuples, arr.shape[1]), np.uint64)
    bases = np.array([layout.base(t) for t in range(layout.tuples)])
    for i in range(width):
        out |= arr[bases + off + i, :].astype(np.uint64) << np.uint64(i)
    return out


def unpack_acc(arr: np.ndarray, layout: TupleLayout) -> np.ndarray:
    """Read the dot-product accumulator: (cols,) unsigned ints."""
    out = np.zeros((arr.shape[1],), np.uint64)
    for i in range(layout.acc_bits):
        out |= arr[i, :].astype(np.uint64) << np.uint64(i)
    return out


def make_jax_state(arr: np.ndarray):
    """Wrap a packed main-array image into a fresh CRState."""
    import jax.numpy as jnp

    from . import engine

    cols = arr.shape[1]
    return engine.CRState(jnp.asarray(arr), jnp.zeros((cols,), bool),
                          jnp.ones((cols,), bool))


def run_program(program, layout: TupleLayout, data: dict, cols: int,
                executor: str = "compiled") -> np.ndarray:
    """Pack ``data``, run ``program`` with ``executor``, return the array.

    The default ``compiled`` executor caches its jitted program per
    (program, geometry), so repeated calls -- the dominant test cost --
    replay in fractions of a millisecond.
    """
    from . import engine

    state = make_jax_state(pack_state(layout, data, cols))
    return np.asarray(engine.run(program, state, executor=executor).array)
