"""Host-side load/readback for Compute RAM layouts.

In a real deployment the FPGA-side state machine writes operands into the
block in storage mode (paper §III-B); here, numpy plays that role.  Data
is laid out transposed per :class:`repro.core.programs.TupleLayout`.
"""

from __future__ import annotations

import numpy as np

from .programs import TupleLayout


def pack_state(layout: TupleLayout, data: dict, cols: int) -> np.ndarray:
    """Build the (rows, cols) bool main-array image.

    ``data[name]`` is a ``(tuples, cols)`` array of unsigned ints (or
    uint16 bf16 bit patterns) for each layout field being loaded.
    """
    arr = np.zeros((layout.rows, cols), dtype=bool)
    for name, vals in data.items():
        off, width = layout.fields[name]
        vals = np.asarray(vals, np.uint64)
        if vals.shape != (layout.tuples, cols):
            raise ValueError(
                f"{name}: expected {(layout.tuples, cols)}, got {vals.shape}")
        bases = np.array([layout.base(t) for t in range(layout.tuples)])
        for i in range(width):
            bit = (vals >> np.uint64(i)) & np.uint64(1)
            arr[bases + off + i, :] = bit.astype(bool)
    return arr


def unpack_field(arr: np.ndarray, layout: TupleLayout, name: str) -> np.ndarray:
    """Read a layout field back as ``(tuples, cols)`` unsigned ints."""
    arr = np.asarray(arr)
    off, width = layout.fields[name]
    out = np.zeros((layout.tuples, arr.shape[1]), np.uint64)
    bases = np.array([layout.base(t) for t in range(layout.tuples)])
    for i in range(width):
        out |= arr[bases + off + i, :].astype(np.uint64) << np.uint64(i)
    return out


def unpack_acc(arr: np.ndarray, layout: TupleLayout) -> np.ndarray:
    """Read the dot-product accumulator: (cols,) unsigned ints."""
    out = np.zeros((arr.shape[1],), np.uint64)
    for i in range(layout.acc_bits):
        out |= arr[i, :].astype(np.uint64) << np.uint64(i)
    return out
