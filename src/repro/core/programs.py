"""Instruction-sequence generators for Compute RAM operations.

These are the "libraries of common operation sequences" the paper (§III-C)
anticipates shipping with Compute RAM-equipped FPGAs: given a precision
and an array geometry, each generator emits a :class:`~repro.core.isa.Program`
that processes **every column in parallel** and **T tuples per column
serially** (bit-serial arithmetic, transposed layout).

Layouts
-------
Each generator returns ``(program, layout)``.  The layout tells the host
(or :mod:`repro.core.bitplane`) where operands/results live:

* ``iadd``/``isub``: tuple ``t`` occupies rows ``[t*3n, (t+1)*3n)`` as
  ``{a: n, b: n, d: n}`` (the paper's packing: int4 -> 12 bits/tuple,
  3 tuples per 40-bit BRAM row when untransposed).
* ``imul``: stride ``4n``: ``{a: n, b: n, d: 2n}``.
* ``idot``: int32 accumulator in rows ``[0, acc_bits)``; tuple ``t`` at
  ``acc_bits + t*2n`` as ``{a: n, b: n}``; result = sum_t a_t*b_t.
* bf16 ops: stride 48 (a, b, d as 16-bit patterns), scratch block at the
  top of the array.

All integer programs are unsigned (two's-complement addition behaves
identically; signed multiply is handled one level up by bit-plane
weighting -- see ``repro.pim``).  bfloat16 programs implement
**FTZ (flush-to-zero subnormals) + RTZ (truncate) finite-only** semantics;
the matching oracle lives in ``repro.core.ref`` and tests validate
bit-exactness against it.

Register conventions: r4 = tuple base pointer; r1..r3, r5..r7 scratch.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

from .isa import (AddReg, Instr, Loop, MovReg, Program, R, SetReg,
                  OP_AND, OP_C0, OP_C1, OP_COPY, OP_CROW, OP_CSTORE, OP_FA,
                  OP_FS, OP_NOR, OP_NOT, OP_OR, OP_T1, OP_TAND, OP_TC,
                  OP_TNC, OP_TNOT, OP_TNROW, OP_TOR, OP_TROW, OP_TSTORE,
                  OP_W0, OP_W1, OP_XOR)

DEFAULT_ROWS = 512
DEFAULT_COLS = 40


# ---------------------------------------------------------------------------
# Layouts
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TupleLayout:
    """T tuples per column; field offsets are relative to tuple base."""
    nbits: int
    rows: int
    stride: int
    tuples: int
    fields: dict            # name -> (offset, width)
    acc_bits: int = 0       # for dot product: accumulator rows [0, acc_bits)
    scratch_base: int = 0   # first scratch row (0 = none)
    tuple_base: int = -1    # first tuple row (-1 => acc_bits)

    def base(self, t: int) -> int:
        off = self.tuple_base if self.tuple_base >= 0 else self.acc_bits
        return off + t * self.stride

    def row(self, t: int, field: str) -> int:
        off, _ = self.fields[field]
        return self.base(t) + off


def _tuples_for(rows: int, stride: int, reserved_top: int,
                reserved_bottom: int = 0) -> int:
    return (rows - reserved_top - reserved_bottom) // stride


# ---------------------------------------------------------------------------
# Integer add / sub:  d = a +/- b   (n-bit, wrapping; paper Fig 4)
# per-tuple steady state: 1 (carry clear) + n (full adds) cycles
# ---------------------------------------------------------------------------
def iadd(n: int, rows: int = DEFAULT_ROWS, sub: bool = False,
         tuples: int | None = None) -> Tuple[Program, TupleLayout]:
    stride = 3 * n
    T = tuples if tuples is not None else _tuples_for(rows, stride, 1)
    op = OP_FS if sub else OP_FA
    nodes = [
        SetReg(4, -2 * n),
        Loop(T, [
            Instr(OP_C0, inc=((4, 2 * n),)),
            Loop(n, [Instr(op, R(4, 2 * n), R(4, 0), R(4, n),
                           inc=((4, 1),))]),
        ]),
    ]
    layout = TupleLayout(n, rows, stride, T,
                         {"a": (0, n), "b": (n, n), "d": (2 * n, n)})
    return Program(f"{'isub' if sub else 'iadd'}{n}x{T}", nodes), layout


def isub(n: int, rows: int = DEFAULT_ROWS,
         tuples: int | None = None) -> Tuple[Program, TupleLayout]:
    return iadd(n, rows, sub=True, tuples=tuples)


# ---------------------------------------------------------------------------
# Integer multiply:  d(2n bits) = a * b  (unsigned shift-and-add)
# ---------------------------------------------------------------------------
def _mul_body(n: int, prod_nodes_abs: int | None = None) -> List:
    """Shift-and-add multiply of one tuple: d(2n) = a(n) * b(n).

    Assumes r4 = tuple base (a at +0, b at +n); product rows are either
    tuple-relative at +2n or absolute at ``prod_nodes_abs``.

    No explicit zeroing is needed: iteration 0 writes rows d..d+n-1
    directly as AND partial products, the carry-out of iteration i is
    CSTOREd into row d+i+n *before* iteration i+1 ever reads it, and no
    row above d+i+n is read at iteration i.  This is the optimized
    sequence recorded in EXPERIMENTS.md (program-level perf iteration).
    """
    if prod_nodes_abs is None:
        set_prod = MovReg(6, 4, 2 * n)
    else:
        set_prod = SetReg(6, prod_nodes_abs)
    return [
        MovReg(5, 4, n),          # r5 = multiplier-bit ptr
        set_prod,                 # r6 = product row ptr
        MovReg(7, 4, 0),          # r7 = multiplicand ptr
        # i = 0: direct AND partial products (no zeroing, no carry)
        Loop(n, [Instr(OP_AND, R(6), R(7), R(5),
                       inc=((6, 1), (7, 1)))]),
        # zero row d+n (read as top operand at i = 1); rewind pointers
        Instr(OP_W0, R(6), inc=((6, 1 - n), (7, -n), (5, 1))),
        # i = 1 .. n-1.  The CSTORE is *unpredicated*: where the
        # multiplier bit is 0, the (unpredicated) C0 left carry = 0, so
        # storing it both writes the correct 0 carry-out and scrubs any
        # stale value when product rows are reused across tuples (idot).
        Loop(n - 1, [
            Instr(OP_TROW, a=R(5), inc=((5, 1),)),
            Instr(OP_C0),
            Loop(n, [Instr(OP_FA, R(6), R(6), R(7), pred=True,
                           inc=((6, 1), (7, 1)))]),
            Instr(OP_CSTORE, R(6), inc=((6, 1 - n), (7, -n))),
        ]),
    ]


def imul(n: int, rows: int = DEFAULT_ROWS,
         tuples: int | None = None) -> Tuple[Program, TupleLayout]:
    stride = 4 * n
    T = tuples if tuples is not None else _tuples_for(rows, stride, 1)
    tuple_body = _mul_body(n) + [AddReg(4, stride)]
    nodes = [SetReg(4, 0), Loop(T, tuple_body)]
    layout = TupleLayout(n, rows, stride, T,
                         {"a": (0, n), "b": (n, n), "d": (2 * n, 2 * n)})
    return Program(f"imul{n}x{T}", nodes), layout


# ---------------------------------------------------------------------------
# Dot product: acc(32) = sum_t a_t * b_t  (paper Fig 6; int4 + int32 acc)
#
# Fused multiply-accumulate directly into the accumulator.  After the
# n partial-product adds at bit position i, the carry must ripple upward;
# the ripple span is bounded because after t tuples acc < t * (2^n - 1)^2,
# so bits >= 2n + ceil(log2(t)) are provably zero.  We use the worst-case
# (final-tuple) bound as a fixed hardware-loop trip count.
# ---------------------------------------------------------------------------
def idot(n: int, rows: int = DEFAULT_ROWS, acc_bits: int = 32,
         tuples: int | None = None) -> Tuple[Program, TupleLayout]:
    stride = 2 * n
    zero_row = rows - 1
    prod = acc_bits                               # 2n scratch product rows
    T = tuples if tuples is not None else \
        _tuples_for(rows, stride, 1 + 2 * n, acc_bits)
    # acc < T * (2^n - 1)^2  =>  bits >= 2n + ceil(log2 T) provably zero;
    # carry ripple after the product add never needs to pass `top`.
    top = min(acc_bits, 2 * n + max(1, T).bit_length() + 1)

    tuple_body: List = _mul_body(n, prod_nodes_abs=prod) + [
        # acc += product (2n bits), then bounded carry ripple to `top`
        Instr(OP_C0),
        SetReg(6, 0),
        SetReg(7, prod),
        Loop(2 * n, [Instr(OP_FA, R(6), R(6), R(7),
                           inc=((6, 1), (7, 1)))]),
        Loop(top - 2 * n, [Instr(OP_FA, R(6), R(6), zero_row,
                                 inc=((6, 1),))]),
        AddReg(4, stride),
    ]

    nodes = [
        SetReg(6, 0),
        Loop(acc_bits, [Instr(OP_W0, R(6), inc=((6, 1),))]),   # zero acc
        Instr(OP_W0, zero_row),
        Instr(OP_T1),
        SetReg(4, acc_bits + 2 * n),
        Loop(T, tuple_body),
    ]
    layout = TupleLayout(n, rows, stride, T,
                         {"a": (0, n), "b": (n, n)},
                         acc_bits=acc_bits, tuple_base=acc_bits + 2 * n)
    return Program(f"idot{n}x{T}", nodes), layout


# ===========================================================================
# bfloat16 (FTZ + RTZ, finite-only)
# ===========================================================================
# Operand bit pattern (LSB-first rows): m[0:7], e[7:15], s[15].
#
# Scratch block (absolute rows at the top of the array); per-program setup
# cost is amortized over the tuples in the column.

_BF = 16


class _Emit:
    """Helper for emitting bf16 programs with loop-compressed blocks."""

    def __init__(self):
        self.nodes: List = []

    # raw ops --------------------------------------------------------------
    def op(self, *a, **k):
        self.nodes.append(Instr(*a, **k))

    def ctrl(self, nd):
        self.nodes.append(nd)

    # vector op over `count` rows with per-operand strides ------------------
    def vec(self, op, dst, a=0, b=0, count=1, sd=1, sa=1, sb=0, pred=False):
        """for i in count: op(dst+i*sd, a+i*sa, b+i*sb) -- loop-compressed.

        Registers are only allocated for operands the opcode actually
        uses *and* that walk (stride != 0) -- keeps the instruction-memory
        footprint small (imem is only 256 slots).
        """
        from .isa import _READS_A, _READS_B, _WRITES_ROW
        use = {"d": op in _WRITES_ROW, "a": op in _READS_A,
               "b": op in _READS_B}
        if count <= 3:
            for i in range(count):
                self.op(op, dst + i * sd, a + i * sa, b + i * sb, pred=pred)
            return
        refs, inc = {}, []
        for name, reg, base, stride in (("d", 1, dst, sd), ("a", 2, a, sa),
                                        ("b", 3, b, sb)):
            if use[name] and stride:
                self.ctrl(SetReg(reg, base))
                refs[name] = R(reg)
                inc.append((reg, stride))
            else:
                refs[name] = base if use[name] else 0
        self.nodes.append(Loop(count, [
            Instr(op, refs["d"], refs["a"], refs["b"], pred=pred,
                  inc=tuple(inc))]))

    def vec_rel(self, op, dst, a, count, dst_rel=False, a_rel=False,
                pred=False):
        """vector copy where one side is tuple-relative (base reg 4)."""
        d = R(1)
        s = R(2)
        self.ctrl(MovReg(1, 4, dst) if dst_rel else SetReg(1, dst))
        self.ctrl(MovReg(2, 4, a) if a_rel else SetReg(2, a))
        self.nodes.append(Loop(count, [
            Instr(op, d, s, pred=pred, inc=((1, 1), (2, 1)))]))

    # tag = OR of rows [base, base+count) -----------------------------------
    def tag_or(self, base, count, invert=False):
        self.op(OP_TROW, a=base)
        if count > 1:
            self.ctrl(SetReg(2, base + 1))
            self.nodes.append(Loop(count - 1, [
                Instr(OP_TOR, a=R(2), inc=((2, 1),))]))
        if invert:
            self.op(OP_TNOT)


def bf16_add(rows: int = DEFAULT_ROWS,
             tuples: int | None = None):
    """d = a + b in bfloat16 (delegates to the parameterized generator)."""
    from .floatprog import BF16, float_add
    return float_add(BF16, rows=rows, tuples=tuples)


def bf16_mul(rows: int = DEFAULT_ROWS,
             tuples: int | None = None):
    """d = a * b in bfloat16 (delegates to the parameterized generator)."""
    from .floatprog import BF16, float_mul
    return float_mul(BF16, rows=rows, tuples=tuples)


def fp16_add(rows: int = DEFAULT_ROWS, tuples: int | None = None):
    from .floatprog import FP16, float_add
    return float_add(FP16, rows=rows, tuples=tuples)


def fp16_mul(rows: int = DEFAULT_ROWS, tuples: int | None = None):
    from .floatprog import FP16, float_mul
    return float_mul(FP16, rows=rows, tuples=tuples)


def fp8_add(rows: int = DEFAULT_ROWS, tuples: int | None = None):
    from .floatprog import FP8_E4M3, float_add
    return float_add(FP8_E4M3, rows=rows, tuples=tuples)


def fp8_mul(rows: int = DEFAULT_ROWS, tuples: int | None = None):
    from .floatprog import FP8_E4M3, float_mul
    return float_mul(FP8_E4M3, rows=rows, tuples=tuples)


def bf16_dot(rows: int = DEFAULT_ROWS, tuples: int | None = None):
    """Fused MAC: acc += sum_t a_t * b_t in bfloat16 (see floatprog)."""
    from .floatprog import BF16, float_dot
    return float_dot(BF16, rows=rows, tuples=tuples)


def fp16_dot(rows: int = DEFAULT_ROWS, tuples: int | None = None):
    from .floatprog import FP16, float_dot
    return float_dot(FP16, rows=rows, tuples=tuples)


def fp8_dot(rows: int = DEFAULT_ROWS, tuples: int | None = None):
    from .floatprog import FP8_E4M3, float_dot
    return float_dot(FP8_E4M3, rows=rows, tuples=tuples)


# ---------------------------------------------------------------------------
# Registry used by benchmarks / the pim layer
# ---------------------------------------------------------------------------
GENERATORS = {
    ("add", "int4"): lambda **kw: iadd(4, **kw),
    ("add", "int8"): lambda **kw: iadd(8, **kw),
    ("add", "bf16"): lambda **kw: bf16_add(**kw),
    ("mul", "int4"): lambda **kw: imul(4, **kw),
    ("mul", "int8"): lambda **kw: imul(8, **kw),
    ("mul", "bf16"): lambda **kw: bf16_mul(**kw),
    ("dot", "int4"): lambda **kw: idot(4, **kw),
    ("dot", "int8"): lambda **kw: idot(8, **kw),
    ("add", "fp16"): lambda **kw: fp16_add(**kw),
    ("mul", "fp16"): lambda **kw: fp16_mul(**kw),
    ("add", "fp8"): lambda **kw: fp8_add(**kw),
    ("mul", "fp8"): lambda **kw: fp8_mul(**kw),
    ("add", "int16"): lambda **kw: iadd(16, **kw),
    ("mul", "int16"): lambda **kw: imul(16, **kw),
    ("dot", "int16"): lambda **kw: idot(16, **kw),
    ("dot", "bf16"): lambda **kw: bf16_dot(**kw),
    ("dot", "fp16"): lambda **kw: fp16_dot(**kw),
    ("dot", "fp8"): lambda **kw: fp8_dot(**kw),
}


# ---------------------------------------------------------------------------
# Content-addressable ops (the Jeloka prototype's TCAM/BCAM modes and
# Compute Caches' compare/search, paper §II-B): match a broadcast query
# against every column's stored word in O(nbits) cycles.
# ---------------------------------------------------------------------------
def vsearch(n: int, rows: int = DEFAULT_ROWS,
            tuples: int | None = None) -> Tuple[Program, TupleLayout]:
    """Per-tuple equality search: match[t] = (a_t == q).

    Layout per tuple: a (n rows), q (n rows, the broadcast query -- the
    host writes the same value to every column), m (1 row: match flag).
    tag-chain: start with tag=1, AND in XNOR(a_i, q_i) per bit via
    (a AND q) OR (~a AND ~q) = NOR(XOR) -- realized as two ops per bit
    using the XOR + TNROW trick: tag &= ~(a_i ^ q_i).
    """
    stride = 2 * n + 1
    T = tuples if tuples is not None else _tuples_for(rows, stride, 2)
    scratch = rows - 1                   # XOR scratch row
    scratch2 = rows - 2                  # inverted-XOR scratch row
    tuple_body = [
        Instr(OP_T1),
        MovReg(5, 4, 0),
        MovReg(6, 4, n),
        Loop(n, [
            Instr(OP_XOR, scratch, R(5), R(6), inc=((5, 1), (6, 1))),
            Instr(OP_NOT, scratch2, scratch),
            Instr(OP_TAND, a=scratch2),
        ]),
        Instr(OP_TSTORE, R(4, 2 * n)),
        AddReg(4, stride),
    ]
    nodes = [SetReg(4, 0), Loop(T, tuple_body)]
    layout = TupleLayout(n, rows, stride, T,
                         {"a": (0, n), "q": (n, n), "m": (2 * n, 1)})
    return Program(f"vsearch{n}x{T}", nodes), layout


def vcmp_gt(n: int, rows: int = DEFAULT_ROWS,
            tuples: int | None = None) -> Tuple[Program, TupleLayout]:
    """Per-tuple unsigned compare: m[t] = (a_t > b_t), via the borrow of
    b - a (borrow set <=> a > b)."""
    stride = 2 * n + 1
    T = tuples if tuples is not None else _tuples_for(rows, stride, 1)
    scratch = rows - 1
    tuple_body = [
        Instr(OP_C0),
        MovReg(5, 4, 0),
        MovReg(6, 4, n),
        Loop(n, [Instr(OP_FS, scratch, R(6), R(5),
                       inc=((5, 1), (6, 1)))]),
        Instr(OP_CSTORE, R(4, 2 * n)),
        AddReg(4, stride),
    ]
    nodes = [SetReg(4, 0), Loop(T, tuple_body)]
    layout = TupleLayout(n, rows, stride, T,
                         {"a": (0, n), "b": (n, n), "m": (2 * n, 1)})
    return Program(f"vcmp_gt{n}x{T}", nodes), layout
