"""Constrained-random microcode fuzzing with differential replay.

The compiler (:mod:`repro.core.compiler`) carries five generations of
semantic passes -- lane vectorization, complementary-predication
coverage, copyrun/fillrun batching, jaxpr CSE, packed bit-plane lowering
with None-elision, multi-loop segmentation, the log-depth ``lane_fold``
carry-save fold -- and two latent cross-lane/borrow-asymmetry bugs were
already found *by hand* (PR 5, PR 6).  This module industrializes that
hunt the way constrained-random verification does for RISC-V cores:

* **Sequences** (:data:`SEQUENCES`) are reusable generators of
  random-but-valid node runs, each aimed at one compiler surface:
  predicated trow/tnrow write pairs stress ``_coverage_kills``, FA/FS
  ripple and in-place reduction chains stress ``planes_add`` elision and
  the lane-fold carry-dead proof, copy/fill runs with uniform and
  non-uniform strides stress run batching, hazard loops read rows the
  previous iteration wrote, and multi-loop emissions exercise
  ``analyze_multi`` segmentation.
* A **funnel** (:func:`gen_program`) draws a weighted mix of sequences,
  assigns each a row window inside the block (windows may deliberately
  overlap, for cross-sequence hazards), and concatenates them into one
  :class:`~repro.core.isa.Program` that is well-formed **by
  construction** -- re-checked by :func:`isa.validate_program` before
  every replay.
* **Differential replay** (:func:`replay`) runs every generated program
  across the full executor x packing matrix -- ``unroll`` (oracle),
  ``scan``, ``compiled`` x ``packed in {False, True, None}`` -- plus
  ``execute_blocks`` at a ragged block count, a two-program
  ``run_chain``, and a **fault family** (``"faults"``): the same block
  batch replayed through the protected
  :func:`repro.core.engine.execute_blocks` path with a seeded
  :class:`repro.core.faults.FaultModel` flipping bits between load and
  launch.  With scrub on (the default) the parity scrub must repair
  every flip -- the variant asserts bit-identity with the clean oracle
  AND that the injected flips were actually *detected*; with
  ``FuzzConfig.fault_scrub=False`` the same flips escape into the
  outputs, which is the forced bug the shrinking pipeline reduces to
  the committed ``tests/corpus/fuzz_faults.txt`` repro.
* On mismatch, **delta-debugging shrinking** (:func:`shrink`) reduces
  the repro -- drop sequences, then drop/halve op runs, then narrow the
  column width -- and the minimal program is serialized to a corpus
  file (:func:`save_repro` / :func:`load_corpus`) replayable via
  ``benchmarks/fuzz_run.py --replay FILE``.

Seed discipline: everything derives from one integer seed.
``gen_program(seed, cfg)`` is a pure function, the initial state derives
from ``(seed, "state")``, so a corpus file's seed alone reproduces the
whole scenario; the shrunken node list is stored too, because shrinking
is what seeds cannot reproduce.

See ``docs/fuzzing.md`` for the workflow (CI budget, soak mode, corpus
promotion).
"""

from __future__ import annotations

import dataclasses
import pathlib
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import engine, isa
from . import faults as faults_core
from .isa import (AddReg, Instr, Loop, MovReg, Program, R, SetReg,
                  OP_AND, OP_C0, OP_C1, OP_COPY, OP_CROW, OP_CSTORE,
                  OP_FA, OP_FS, OP_NOP, OP_NOR, OP_NOT, OP_OR, OP_T1,
                  OP_TAND, OP_TC, OP_TNC, OP_TNOT, OP_TNROW, OP_TOR,
                  OP_TROW, OP_TSTORE, OP_W0, OP_W1, OP_XOR)

__all__ = [
    "FuzzConfig", "FuzzProgram", "Mismatch", "ReplayReport", "SEQUENCES",
    "gen_program", "gen_state", "replay", "shrink", "save_repro",
    "load_corpus", "program_to_text", "program_from_text", "run_budget",
    "MUTATIONS",
]


# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FuzzConfig:
    """Geometry + budget constraints every generated program honours.

    ``rows``/``cols`` are the single-block geometry; ``blocks`` is the
    (deliberately ragged -- not a canonical budget) block count of the
    ``execute_blocks`` leg; ``max_ops`` caps the expanded stream so a
    CI budget's wall-clock stays bounded; ``min_seqs``/``max_seqs``
    bound the funnel draw; ``weights`` overrides the per-sequence
    default weights (unknown names are an error, weight 0 disables).

    The fault-family knobs drive the ``"faults"`` replay variant:
    ``fault_rate`` is the per-bit flip probability at the pre-launch
    injection point (the default expects a couple of flips per replay
    on the default 3x48x8 batch -- enough that detection is exercised
    on nearly every program); the per-program fault seed is
    ``seed ^ fault_seed``; ``fault_scrub=False`` disables the parity
    scrub so the same flips escape into the outputs -- the forced-bug
    mode the shrinking pipeline and the committed fault corpus use.
    """
    rows: int = 48
    cols: int = 8
    blocks: int = 3
    max_ops: int = 320
    min_seqs: int = 2
    max_seqs: int = 5
    weights: Tuple[Tuple[str, float], ...] = ()
    fault_rate: float = 2e-3
    fault_seed: int = 0xFA17
    fault_scrub: bool = True

    def __post_init__(self):
        if self.rows < 24:
            raise ValueError("fuzz geometry needs >= 24 rows")
        if self.cols < 1 or self.blocks < 1:
            raise ValueError("cols and blocks must be >= 1")
        if not 0.0 <= self.fault_rate <= 1.0:
            raise ValueError(f"fault_rate must be in [0, 1]: "
                             f"{self.fault_rate}")
        for name, _w in self.weights:
            if name not in SEQUENCES:
                raise ValueError(f"unknown sequence {name!r}; "
                                 f"known: {sorted(SEQUENCES)}")


# ---------------------------------------------------------------------------
# Sequences: each returns a list of Nodes touching only rows inside its
# window [base, base + h).  Registers are always Set before use, so a
# sequence never depends on (or leaks) register state across groups.
# ---------------------------------------------------------------------------
_ROW_WRITE_OPS = sorted(isa._WRITES_ROW)
_LATCH_OPS = sorted(set(range(isa.N_ARRAY_OPS)) - isa._WRITES_ROW
                    - {OP_NOP})
_MIN_WINDOW = 12      # every sequence can work inside 12 rows


def _row(rng, base, h):
    return int(base + rng.integers(0, h))


def seq_ops(rng, base, h):
    """Random run of flat micro-ops over the whole opcode space.

    Latch ops are mixed in so carry/tag provenance threads through the
    row writes; ~1/3 of ops are predicated.
    """
    nodes = []
    for _ in range(int(rng.integers(3, 13))):
        if rng.random() < 0.35:
            op = int(rng.choice(_LATCH_OPS))
        else:
            op = int(rng.choice(_ROW_WRITE_OPS))
        nodes.append(Instr(op, dst=_row(rng, base, h),
                           a=_row(rng, base, h), b=_row(rng, base, h),
                           pred=bool(rng.random() < 0.3)))
    return nodes


def seq_predpair(rng, base, h):
    """Complementary trow/tnrow predicated write pairs.

    The canonical ``_coverage_kills`` stressor: tag <- row t, predicated
    write to dst, tag <- ~row t, predicated write to the SAME dst -- the
    pair fully covers dst, so the compiler may (and does) prove the
    pre-pair value dead.  Variants flip which half comes first, write
    different values per half, and sometimes only *almost* cover (a
    different dst in one half) so the kill must NOT fire.
    """
    nodes = []
    for _ in range(int(rng.integers(1, 4))):
        t = _row(rng, base, h)
        d = _row(rng, base, h)
        d2 = d if rng.random() < 0.7 else _row(rng, base, h)   # near-miss
        src1, src2 = _row(rng, base, h), _row(rng, base, h)
        op1 = int(rng.choice([OP_COPY, OP_NOT, OP_W0, OP_W1]))
        op2 = int(rng.choice([OP_COPY, OP_NOT, OP_W0, OP_W1]))
        first, second = ((OP_TROW, OP_TNROW) if rng.random() < 0.5
                         else (OP_TNROW, OP_TROW))
        nodes += [Instr(first, a=t),
                  Instr(op1, dst=d, a=src1, pred=True),
                  Instr(second, a=t),
                  Instr(op2, dst=d2, a=src2, pred=True)]
        if rng.random() < 0.3:           # carry-latch flavored coverage
            nodes += [Instr(OP_CROW, a=t, pred=bool(rng.random() < 0.5)),
                      Instr(OP_TC if rng.random() < 0.5 else OP_TNC),
                      Instr(OP_CSTORE, dst=_row(rng, base, h), pred=True)]
    return nodes


def seq_ripple(rng, base, h):
    """FA/FS ripple chains and in-place reduction chains.

    Three flavors, all register-walked hardware loops (the lane-plan
    idiom):

    * three-address ripple ``d <- a (+/-) b`` over ``n`` bit rows;
    * in-place ``d <- d (+/-) a`` accumulation over shared rows -- the
      ``planes_add`` / lane-fold carry-dead surface, including the
      a-0 / 0-b borrow-asymmetry class fixed in PR 6;
    * a bounded carry-ripple suffix against a constant row (the idot
      idiom): ``W0 z; loop(FA d, d, z)``.
    """
    n = int(rng.integers(2, max(3, h // 3)))
    d0, a0, b0 = (int(base + o) for o in
                  rng.choice(h - n + 1, size=3, replace=True))
    op = OP_FS if rng.random() < 0.5 else OP_FA
    carry = int(rng.choice([OP_C0, OP_C1]))
    nodes: List = [Instr(carry)]
    flavor = rng.random()
    if flavor < 0.4:                                  # three-address
        nodes += [SetReg(1, d0), SetReg(2, a0), SetReg(3, b0),
                  Loop(n, [Instr(op, R(1), R(2), R(3),
                                 inc=((1, 1), (2, 1), (3, 1)))])]
    elif flavor < 0.8:                                # in-place
        nodes += [SetReg(1, d0), SetReg(2, a0),
                  Loop(n, [Instr(op, R(1), R(1), R(2),
                                 inc=((1, 1), (2, 1)))])]
    else:                                             # a-0 / 0-b elision
        z = int(base + h - 1)
        nodes += [Instr(OP_W0, dst=z), SetReg(1, d0),
                  Loop(n, [Instr(op, R(1), R(1), z, inc=((1, 1),))])]
    if rng.random() < 0.5:
        nodes.append(Instr(OP_CSTORE, dst=_row(rng, base, h)))
    if rng.random() < 0.3:                            # bounded suffix
        z = int(base + h - 1)
        k = int(rng.integers(1, 4))
        top = min(d0 + n + k, base + h - 1)
        if top > d0 + n:
            nodes += [Instr(OP_W0, dst=z), SetReg(1, d0 + n),
                      Loop(top - (d0 + n),
                           [Instr(OP_FA, R(1), R(1), z, inc=((1, 1),))])]
    return nodes


def seq_copyfill(rng, base, h):
    """Copy/fill runs with uniform and non-uniform strides.

    The copyrun/fillrun batching surface: loop-compressed COPY/NOT/W0/W1
    walks where dst and src advance at the same rate (uniform -- the
    batchable case) or different rates (non-uniform -- must NOT batch),
    optionally predicated.
    """
    nodes = []
    for _ in range(int(rng.integers(1, 4))):
        op = int(rng.choice([OP_COPY, OP_NOT, OP_W0, OP_W1]))
        sd = int(rng.choice([1, 1, 2, 3]))
        sa = int(rng.choice([0, 1, 1, 2])) if op in (OP_COPY, OP_NOT) \
            else 0
        span = max(sd, sa, 1)
        n = int(rng.integers(2, max(3, (h - 1) // span + 1)))
        n = min(n, (h - 1) // span) or 1
        d0 = int(base + rng.integers(0, h - (n - 1) * sd))
        a0 = int(base + rng.integers(0, h - max(1, (n - 1) * sa)))
        pred = bool(rng.random() < 0.25)
        if pred:
            nodes.append(Instr(OP_TROW, a=_row(rng, base, h)))
        inc = ((1, sd),) + (((2, sa),) if sa else ())
        body = Instr(op, R(1), R(2) if sa else a0, pred=pred, inc=inc)
        nodes += [SetReg(1, d0)] + ([SetReg(2, a0)] if sa else []) \
            + [Loop(n, [body])]
    return nodes


def seq_hazard(rng, base, h):
    """Loops whose iterations read rows written in the same loop.

    Iteration ``i`` writes row ``w + i`` and reads row ``w + i - 1``
    (written by iteration ``i - 1``) plus a fixed shared row that the
    loop itself keeps overwriting -- the read-after-write-in-loop
    pattern that cross-lane provenance staleness (the PR 5 bug class)
    gets wrong when lanes are vectorized.
    """
    n = int(rng.integers(2, max(3, h // 2)))
    w0 = int(base + rng.integers(1, h - n + 1))
    shared = int(base + rng.integers(0, h))
    op = int(rng.choice([OP_XOR, OP_AND, OP_OR, OP_FA, OP_FS]))
    nodes: List = []
    if op in (OP_FA, OP_FS):
        nodes.append(Instr(int(rng.choice([OP_C0, OP_C1]))))
    nodes += [SetReg(1, w0), SetReg(2, w0 - 1),
              Loop(n, [Instr(op, R(1), R(2), shared,
                             inc=((1, 1), (2, 1))),
                       Instr(OP_COPY, shared, R(2))])]
    return nodes


def seq_latch(rng, base, h):
    """Carry/tag latch torture: dense latch-op interleavings.

    Random walks over the full latch-op set (tc/tnc/tag algebra,
    predicated carry loads, cstore's carry clear) with just enough row
    writes in between that latch provenance must thread through the
    compiled executor's state tracking.
    """
    nodes = []
    for _ in range(int(rng.integers(4, 10))):
        r = rng.random()
        if r < 0.55:
            op = int(rng.choice(_LATCH_OPS))
            nodes.append(Instr(op, a=_row(rng, base, h),
                               pred=bool(rng.random() < 0.3)))
        elif r < 0.8:
            nodes.append(Instr(int(rng.choice([OP_CSTORE, OP_TSTORE])),
                               dst=_row(rng, base, h),
                               pred=bool(rng.random() < 0.4)))
        else:
            nodes.append(Instr(int(rng.choice([OP_FA, OP_FS, OP_XOR])),
                               dst=_row(rng, base, h),
                               a=_row(rng, base, h),
                               b=_row(rng, base, h),
                               pred=bool(rng.random() < 0.3)))
    return nodes


def seq_multiloop(rng, base, h):
    """TWO top-level hardware loops back to back.

    Guarantees the program has at least two dominant loops, so
    ``analyze_multi`` segmentation (and the chained lane plans over a
    shared row store) is exercised even when the funnel drew only this
    sequence.  The second loop reads rows the first loop wrote.
    """
    half = h // 2
    n1 = int(rng.integers(2, max(3, half)))
    n2 = int(rng.integers(2, max(3, half)))
    d1 = int(base + rng.integers(0, half - n1 + 1)) if half > n1 else base
    d2 = int(base + half)
    op1 = int(rng.choice([OP_COPY, OP_XOR, OP_FA]))
    op2 = int(rng.choice([OP_FA, OP_FS, OP_AND]))
    nodes: List = []
    if op1 == OP_FA:
        nodes.append(Instr(OP_C0))
    src = int(base + rng.integers(0, h))
    nodes += [SetReg(1, d1),
              Loop(n1, [Instr(op1, R(1), R(1), src, inc=((1, 1),))])]
    if op2 in (OP_FA, OP_FS):
        nodes.append(Instr(int(rng.choice([OP_C0, OP_C1]))))
    n2 = min(n2, base + h - d2)
    if n2 >= 1:
        nodes += [SetReg(1, d2), SetReg(2, d1),
                  Loop(n2, [Instr(op2, R(1), R(2), d1,
                                  inc=((1, 1), (2, 1)))])]
    return nodes


#: name -> (generator, default weight).  Weights shape the funnel draw;
#: override per run via FuzzConfig.weights.
SEQUENCES: Dict[str, Tuple[Callable, float]] = {
    "ops": (seq_ops, 1.0),
    "predpair": (seq_predpair, 1.2),
    "ripple": (seq_ripple, 1.4),
    "copyfill": (seq_copyfill, 1.0),
    "hazard": (seq_hazard, 1.2),
    "latch": (seq_latch, 1.0),
    "multiloop": (seq_multiloop, 0.8),
}


# ---------------------------------------------------------------------------
# The funnel: weighted sequence mix -> one valid Program
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FuzzProgram:
    """One generated scenario: seed, geometry, and the grouped nodes.

    ``groups`` keeps the sequence boundaries (name, nodes) -- the first
    shrinking level drops whole groups.  ``shrunk`` marks instances
    whose nodes no longer derive from the seed (corpus files store the
    node text for exactly this reason).
    """
    seed: int
    cfg: FuzzConfig
    groups: Tuple[Tuple[str, Tuple], ...]
    shrunk: bool = False

    @property
    def program(self) -> Program:
        p = self.__dict__.get("_program")
        if p is None:
            nodes = [nd for _name, nds in self.groups for nd in nds]
            tag = "min" if self.shrunk else "gen"
            p = Program(f"fuzz_s{self.seed}_{tag}", nodes)
            object.__setattr__(self, "_program", p)
        return p

    def with_groups(self, groups, cfg=None) -> "FuzzProgram":
        return FuzzProgram(self.seed, cfg or self.cfg,
                           tuple((n, tuple(g)) for n, g in groups),
                           shrunk=True)

    def describe(self) -> str:
        names = ",".join(n for n, _ in self.groups)
        return (f"seed={self.seed} [{names}] "
                + isa.describe_stream(self.program))


def _weights(cfg: FuzzConfig):
    w = {name: wt for name, (_fn, wt) in SEQUENCES.items()}
    w.update(dict(cfg.weights))
    names = [n for n, wt in w.items() if wt > 0]
    probs = np.array([w[n] for n in names], float)
    return names, probs / probs.sum()


def gen_program(seed: int, cfg: FuzzConfig = FuzzConfig()) -> FuzzProgram:
    """Generate one random-but-valid program (pure in ``seed``/``cfg``).

    Draws ``min_seqs..max_seqs`` sequences by weight, gives each a row
    window (>= 12 rows, sometimes overlapping a neighbour's window for
    cross-sequence hazards), and concatenates until :attr:`max_ops`
    would be exceeded.  The result always passes
    :func:`isa.validate_program`.
    """
    rng = np.random.default_rng([int(seed), 0xF0225])
    names, probs = _weights(cfg)
    k = int(rng.integers(cfg.min_seqs, cfg.max_seqs + 1))
    picks = [str(rng.choice(names, p=probs)) for _ in range(k)]
    groups: List[Tuple[str, Tuple]] = []
    total = 0
    for name in picks:
        fn, _w = SEQUENCES[name]
        h = int(rng.integers(_MIN_WINDOW, min(cfg.rows, 2 * _MIN_WINDOW) + 1))
        base = int(rng.integers(0, cfg.rows - h + 1))
        nodes = fn(rng, base, h)
        cost = Program("_", list(nodes)).cycles()
        if groups and total + cost > cfg.max_ops:
            break
        groups.append((name, tuple(nodes)))
        total += cost
    fp = FuzzProgram(int(seed), cfg, tuple(groups))
    bad = isa.validate_program(fp.program, cfg.rows)
    if bad:     # a sequence generator broke its window contract
        raise AssertionError(
            f"generator emitted an invalid program (seed {seed}): {bad}")
    return fp


def gen_state(seed: int, cfg: FuzzConfig, blocks: int = 0):
    """Random initial CRState for ``seed`` (array, carry AND tag random).

    ``blocks=0`` gives a single-block ``(rows, cols)`` state; otherwise
    a ``(blocks, rows, cols)`` batch.  Derived from the seed alone so a
    corpus file's seed reproduces the exact scenario.
    """
    import jax.numpy as jnp

    rng = np.random.default_rng([int(seed), 0x57A7E])
    shape = (cfg.rows, cfg.cols) if blocks == 0 \
        else (blocks, cfg.rows, cfg.cols)
    cshape = shape[:-2] + shape[-1:]
    return engine.CRState(
        array=jnp.asarray(rng.integers(0, 2, shape).astype(bool)),
        carry=jnp.asarray(rng.integers(0, 2, cshape).astype(bool)),
        tag=jnp.asarray(rng.integers(0, 2, cshape).astype(bool)))


# ---------------------------------------------------------------------------
# Differential replay
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Mismatch:
    variant: str        # e.g. "compiled:packed=True", "blocks", "chain"
    field: str          # array | carry | tag | cycles | footprint
    detail: str


@dataclasses.dataclass
class ReplayReport:
    fp: FuzzProgram
    mismatches: List[Mismatch]
    variants: Tuple[str, ...]
    cycles: int = 0
    footprint: int = 0

    @property
    def ok(self) -> bool:
        return not self.mismatches


#: the full differential matrix.  unroll is the oracle, not a variant.
#: "faults" replays the block batch through the protected engine path
#: with seeded bit flips injected pre-launch (scrub-on => bit-exact).
VARIANTS = ("scan", "compiled:packed=False", "compiled:packed=True",
            "compiled:packed=None", "blocks", "chain", "faults")

#: known-bad mutations (test hooks for the shrinking pipeline): name ->
#: fn(variant, program, CRState) -> CRState applied to a variant's
#: output.  "fa-flip" corrupts the packed compiled path's first array
#: bit whenever the program contains an OP_FA -- a stand-in for a real
#: lowering bug, used by tests and `fuzz_run.py --force-bug`.
def _mut_fa_flip(variant: str, program: Program, state):
    if variant != "compiled:packed=True":
        return state
    if not any(i.op == OP_FA for i in program.expand()):
        return state
    arr = state.array
    return state._replace(array=arr.at[0, 0].set(~arr[0, 0]))


def _mut_pred_carry(variant: str, program: Program, state):
    if variant != "scan" or not program.meta().uses_pred:
        return state
    return state._replace(carry=~state.carry)


MUTATIONS: Dict[str, Callable] = {
    "fa-flip": _mut_fa_flip,
    "pred-carry": _mut_pred_carry,
}


def _diff_state(variant: str, got, want, out: List[Mismatch]):
    for field in ("array", "carry", "tag"):
        g = np.asarray(getattr(got, field))
        w = np.asarray(getattr(want, field))
        if not np.array_equal(g, w):
            n = int((g != w).sum())
            idx = tuple(int(x[0]) for x in np.nonzero(g != w))
            out.append(Mismatch(variant, field,
                                f"{n} bit(s) differ, first at {idx}"))


def replay(fp: FuzzProgram, variants: Sequence[str] = VARIANTS,
           mutate: Optional[Callable] = None) -> ReplayReport:
    """Differentially replay ``fp`` across ``variants`` vs the unroll
    oracle; returns the mismatch report (empty = bit-identical).

    Also re-checks validity and, for unshrunk programs, regenerates from
    the seed and pins fingerprint/cycles/footprint -- the seed
    discipline that makes every corpus line reproducible.

    ``mutate`` is the test seam for the shrinking pipeline: it is
    applied to every variant's final state (see :data:`MUTATIONS`).
    """
    prog, cfg = fp.program, fp.cfg
    mismatches: List[Mismatch] = []
    bad = isa.validate_program(prog, cfg.rows)
    if bad:
        return ReplayReport(fp, [Mismatch("validate", "program", "; ".join(bad))],
                            tuple(variants))

    cycles, footprint = prog.cycles(), prog.footprint()
    if not fp.shrunk:
        regen = gen_program(fp.seed, cfg)
        if regen.program.fingerprint() != prog.fingerprint():
            mismatches.append(Mismatch("regen", "fingerprint",
                                       "generator is not seed-deterministic"))
        if regen.program.cycles() != cycles:
            mismatches.append(Mismatch("regen", "cycles",
                                       f"{regen.program.cycles()} != {cycles}"))
        if regen.program.footprint() != footprint:
            mismatches.append(
                Mismatch("regen", "footprint",
                         f"{regen.program.footprint()} != {footprint}"))
    # the cycle accounting must agree with the stream metadata
    meta = prog.meta()
    if cycles != meta.n_cycles + prog._ctrl_cycles:
        mismatches.append(Mismatch("meta", "cycles",
                                   f"cycles()={cycles} != stream "
                                   f"{meta.n_cycles}+{prog._ctrl_cycles}"))

    state = gen_state(fp.seed, cfg)
    want = engine.execute(prog, state)                      # oracle
    if mutate is not None:
        want = mutate("unroll", prog, want)

    def check(variant, got):
        if mutate is not None:
            got = mutate(variant, prog, got)
        _diff_state(variant, got, want, mismatches)

    # "blocks" and "faults" share one block batch + unroll oracle, so
    # running both costs a single extra (compile-cached) executable run
    _blocks_oracle = {}

    def blocks_oracle():
        if not _blocks_oracle:
            bstates = gen_state(fp.seed, cfg, blocks=cfg.blocks)
            _blocks_oracle["v"] = (
                bstates, engine.execute_blocks(prog, bstates, "unroll"))
        return _blocks_oracle["v"]

    for variant in variants:
        if variant == "scan":
            check(variant, engine.execute_scan(prog, state))
        elif variant.startswith("compiled:"):
            pk = {"False": False, "True": True,
                  "None": None}[variant.split("=", 1)[1]]
            check(variant, engine.execute_compiled(prog, state, packed=pk))
        elif variant == "blocks":
            bstates, bwant = blocks_oracle()
            bgot = engine.execute_blocks(prog, bstates, "compiled")
            if mutate is not None:
                bgot = mutate(variant, prog, bgot)
            _diff_state(variant, bgot, bwant, mismatches)
        elif variant == "faults":
            bstates, bwant = blocks_oracle()
            fm = faults_core.FaultModel(
                bit_rate=cfg.fault_rate, seed=fp.seed ^ cfg.fault_seed,
                scrub=cfg.fault_scrub)
            fgot = engine.execute_blocks(prog, bstates, "compiled",
                                         faults=fm)
            if mutate is not None:
                fgot = mutate(variant, prog, fgot)
            _diff_state(variant, fgot, bwant, mismatches)
            if cfg.fault_scrub and fm.injected_flips and not fm.detected:
                mismatches.append(Mismatch(
                    variant, "detection",
                    f"{fm.injected_flips} flip(s) injected but parity "
                    f"scrub detected none"))
        elif variant == "chain":
            cwant = engine.execute(prog, want)     # 2nd sequential run
            cgot = engine.run_chain([prog, prog], state)
            if mutate is not None:
                cgot = mutate(variant, prog, cgot)
            _diff_state(variant, cgot, cwant, mismatches)
        else:
            raise ValueError(f"unknown replay variant {variant!r}")
    return ReplayReport(fp, mismatches, tuple(variants),
                        cycles=cycles, footprint=footprint)


# ---------------------------------------------------------------------------
# Delta-debugging shrinking
# ---------------------------------------------------------------------------
def _map_loops(nodes, edit):
    """All single-loop edits of a node tuple (used by the loop pass)."""
    out = []
    for i, nd in enumerate(nodes):
        if isinstance(nd, Loop):
            for repl in edit(nd):
                cand = list(nodes)
                if repl is None:
                    cand[i:i + 1] = list(nd.body)      # unwrap
                else:
                    cand[i] = repl
                out.append(tuple(cand))
            for sub in _map_loops(tuple(nd.body), edit):
                cand = list(nodes)
                cand[i] = Loop(nd.count, list(sub))
                out.append(tuple(cand))
    return out


def shrink(fp: FuzzProgram, fails: Callable[[FuzzProgram], bool],
           max_evals: int = 250) -> FuzzProgram:
    """Reduce ``fp`` to a (locally) minimal program with ``fails`` true.

    Classic greedy delta debugging in three levels, exactly the order
    the issue prescribes: (1) drop whole sequences (groups), (2) drop /
    halve op runs inside the survivors (top-level nodes, loop trip
    counts, loop bodies, loop unwrapping), (3) narrow the column width.
    ``fails`` is typically a one-variant :func:`replay` closure -- the
    caller restricts to the variant that originally mismatched, so each
    probe costs one compile, not six.  Bounded by ``max_evals`` probes.
    """
    evals = [0]

    def try_cand(cand: FuzzProgram):
        if not any(nds for _n, nds in cand.groups):
            return None
        if evals[0] >= max_evals:
            return None
        evals[0] += 1
        try:
            return cand if fails(cand) else None
        except Exception:
            return None       # a candidate that errors is not a repro

    cur = fp
    # -- level 1: drop whole groups ----------------------------------------
    changed = True
    while changed and len(cur.groups) > 1:
        changed = False
        for i in range(len(cur.groups) - 1, -1, -1):
            cand = cur.with_groups(
                [g for j, g in enumerate(cur.groups) if j != i])
            got = try_cand(cand)
            if got is not None:
                cur, changed = got, True
                break

    # -- level 2: drop / halve op runs inside groups -----------------------
    def node_edits(cur):
        """Candidate programs from one structural edit anywhere."""
        for gi, (name, nodes) in enumerate(cur.groups):
            # drop contiguous chunks (halves first, then singles)
            n = len(nodes)
            for size in (max(1, n // 2), 1):
                for s in range(0, n, size):
                    rest = nodes[:s] + nodes[s + size:]
                    if not rest and len(cur.groups) == 1:
                        continue
                    yield cur.with_groups(
                        [(nm, rest if j == gi else nds)
                         for j, (nm, nds) in enumerate(cur.groups)])
            # halve loop counts / unwrap loops / shrink loop bodies
            def loop_edit(lp):
                reps = []
                if lp.count > 1:
                    reps.append(Loop(max(1, lp.count // 2), lp.body))
                    reps.append(Loop(1, lp.body))
                reps.append(None)                      # unwrap once
                if len(lp.body) > 1:
                    for k in range(len(lp.body)):
                        reps.append(Loop(lp.count,
                                         lp.body[:k] + lp.body[k + 1:]))
                return reps
            for edited in _map_loops(nodes, loop_edit):
                yield cur.with_groups(
                    [(nm, edited if j == gi else nds)
                     for j, (nm, nds) in enumerate(cur.groups)])

    changed = True
    while changed and evals[0] < max_evals:
        changed = False
        for cand in node_edits(cur):
            got = try_cand(cand)
            if got is not None:
                cur, changed = got, True
                break

    # -- level 3: narrow the width -----------------------------------------
    cols = cur.cfg.cols
    while cols > 1 and evals[0] < max_evals:
        cols = max(1, cols // 2)
        cand = cur.with_groups(cur.groups,
                               cfg=dataclasses.replace(cur.cfg, cols=cols))
        got = try_cand(cand)
        if got is None:
            break
        cur = got
    return cur


# ---------------------------------------------------------------------------
# Corpus serialization: a small line-based text format, parseable back
# into a FuzzProgram (shrunken nodes cannot be re-derived from the seed)
# ---------------------------------------------------------------------------
def _ref_to_text(ref) -> str:
    if isinstance(ref, R):
        return f"R{ref.reg}{ref.offset:+d}" if ref.offset else f"R{ref.reg}"
    return str(int(ref))


def _ref_from_text(s: str):
    if s.startswith("R"):
        body = s[1:]
        for i, c in enumerate(body):
            if c in "+-":
                return R(int(body[:i]), int(body[i:]))
        return R(int(body))
    return int(s)


def _nodes_to_lines(nodes, indent: int, out: List[str]):
    pad = "  " * indent
    for nd in nodes:
        if isinstance(nd, Loop):
            out.append(f"{pad}loop {nd.count}")
            _nodes_to_lines(nd.body, indent + 1, out)
            out.append(f"{pad}endloop")
        elif isinstance(nd, SetReg):
            out.append(f"{pad}setreg {nd.reg} {nd.value}")
        elif isinstance(nd, AddReg):
            out.append(f"{pad}addreg {nd.reg} {nd.delta}")
        elif isinstance(nd, MovReg):
            out.append(f"{pad}movreg {nd.dst} {nd.src} {nd.offset}")
        else:
            # serialize every operand (even ones the op ignores) so a
            # parsed program's expanded stream is byte-identical
            parts = [f"instr {isa.ARRAY_OP_NAMES[nd.op]}"]
            for field in ("dst", "a", "b"):
                ref = getattr(nd, field)
                if ref != 0:
                    parts.append(f"{field}={_ref_to_text(ref)}")
            if nd.pred:
                parts.append("pred")
            if nd.inc:
                parts.append("inc=" + ",".join(f"{r}:{d}"
                                               for r, d in nd.inc))
            out.append(pad + " ".join(parts))


def program_to_text(fp: FuzzProgram, header: Dict[str, str] = ()) -> str:
    """Serialize ``fp`` (geometry, seed, grouped nodes) to corpus text."""
    lines = ["# repro fuzz corpus v1"]
    for k, v in dict(header).items():
        lines.append(f"# {k}: {v}")
    c = fp.cfg
    lines.append(f"seed {fp.seed}")
    lines.append(f"geometry rows={c.rows} cols={c.cols} blocks={c.blocks}")
    dflt = FuzzConfig()
    if (c.fault_rate, c.fault_seed, c.fault_scrub) != \
            (dflt.fault_rate, dflt.fault_seed, dflt.fault_scrub):
        lines.append(f"faults rate={c.fault_rate!r} seed={c.fault_seed} "
                     f"scrub={int(c.fault_scrub)}")
    lines.append(f"shrunk {int(fp.shrunk)}")
    lines.append(f"cycles {fp.program.cycles()}")
    lines.append(f"footprint {fp.program.footprint()}")
    for name, nodes in fp.groups:
        lines.append(f"group {name}")
        _nodes_to_lines(nodes, 1, lines)
    return "\n".join(lines) + "\n"


def program_from_text(text: str) -> Tuple[FuzzProgram, Dict[str, int]]:
    """Parse corpus text back into ``(FuzzProgram, pins)``.

    ``pins`` carries the recorded ``cycles``/``footprint`` so corpus
    regression tests can assert the ISA-level accounting has not
    drifted since the repro was captured.

    The node text is always the source of truth: the text format does
    not record the full generator config (sequence weights etc.), so
    parsed programs are marked ``shrunk=True`` -- replay checks the
    nodes as-is and skips seed regeneration.  The ``shrunk`` header
    line is informational only.
    """
    seed, cfg_kw = 0, {}
    fault_kw: Dict = {}
    pins: Dict[str, int] = {}
    groups: List[Tuple[str, List]] = []
    stack: List[List] = []       # innermost-last loop bodies

    def target() -> List:
        if stack:
            return stack[-1]
        if not groups:
            groups.append(("corpus", []))
        return groups[-1][1]

    for raw in text.splitlines():
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        toks = line.split()
        kw = toks[0]
        if kw == "seed":
            seed = int(toks[1])
        elif kw == "geometry":
            cfg_kw = {k: int(v) for k, v in
                      (t.split("=") for t in toks[1:])}
        elif kw == "faults":
            kv = dict(t.split("=") for t in toks[1:])
            fault_kw = {"fault_rate": float(kv.get("rate", 0.0)),
                        "fault_seed": int(kv.get("seed", 0)),
                        "fault_scrub": bool(int(kv.get("scrub", 1)))}
        elif kw == "shrunk":
            pass                       # informational (see docstring)
        elif kw in ("cycles", "footprint"):
            pins[kw] = int(toks[1])
        elif kw == "group":
            if stack:
                raise ValueError("group inside a loop")
            groups.append((toks[1], []))
        elif kw == "loop":
            body: List = []
            target().append(Loop(int(toks[1]), body))
            stack.append(body)
        elif kw == "endloop":
            stack.pop()
        elif kw == "setreg":
            target().append(SetReg(int(toks[1]), int(toks[2])))
        elif kw == "addreg":
            target().append(AddReg(int(toks[1]), int(toks[2])))
        elif kw == "movreg":
            target().append(MovReg(int(toks[1]), int(toks[2]),
                                   int(toks[3])))
        elif kw == "instr":
            op = isa.OP_BY_NAME[toks[1]]
            kws: Dict = {"dst": 0, "a": 0, "b": 0, "pred": False,
                         "inc": ()}
            for t in toks[2:]:
                if t == "pred":
                    kws["pred"] = True
                elif t.startswith("inc="):
                    kws["inc"] = tuple(
                        (int(r), int(d)) for r, d in
                        (p.split(":") for p in t[4:].split(",")))
                else:
                    k, v = t.split("=", 1)
                    kws[k] = _ref_from_text(v)
            target().append(Instr(op, kws["dst"], kws["a"], kws["b"],
                                  kws["pred"], kws["inc"]))
        else:
            raise ValueError(f"unparseable corpus line: {raw!r}")
    if stack:
        raise ValueError("unterminated loop")
    cfg = FuzzConfig(rows=cfg_kw.get("rows", 48),
                     cols=cfg_kw.get("cols", 8),
                     blocks=cfg_kw.get("blocks", 3), **fault_kw)
    fp = FuzzProgram(seed, cfg,
                     tuple((n, tuple(nds)) for n, nds in groups),
                     shrunk=True)
    return fp, pins


def save_repro(fp: FuzzProgram, report: ReplayReport,
               corpus_dir) -> pathlib.Path:
    """Write a shrunken repro to ``corpus_dir`` (named by fingerprint)."""
    corpus_dir = pathlib.Path(corpus_dir)
    corpus_dir.mkdir(parents=True, exist_ok=True)
    path = corpus_dir / f"fuzz_{fp.program.fingerprint()}.txt"
    mm = "; ".join(f"{m.variant}/{m.field}: {m.detail}"
                   for m in report.mismatches) or "captured-without-mismatch"
    header = {
        "mismatch": mm,
        "replay": f"PYTHONPATH=src python benchmarks/fuzz_run.py "
                  f"--replay {path}",
        "reseed": f"PYTHONPATH=src python benchmarks/fuzz_run.py "
                  f"--seed {fp.seed} --budget 1",
    }
    path.write_text(program_to_text(fp, header))
    return path


def load_corpus(path) -> Tuple[FuzzProgram, Dict[str, int]]:
    """Load one corpus file back into a replayable FuzzProgram."""
    return program_from_text(pathlib.Path(path).read_text())


# ---------------------------------------------------------------------------
# Budgeted campaign driver (the CI entry point; CLI in benchmarks/)
# ---------------------------------------------------------------------------
def run_budget(budget: int, seed: int = 0,
               cfg: FuzzConfig = FuzzConfig(),
               variants: Sequence[str] = VARIANTS,
               mutate: Optional[Callable] = None,
               corpus_dir=None,
               do_shrink: bool = True,
               max_minutes: Optional[float] = None,
               clear_cache_every: int = 40,
               log: Optional[Callable[[str], None]] = None) -> dict:
    """Fuzz ``budget`` seeds (``seed..seed+budget-1``); stop on mismatch.

    On the first mismatch the repro is shrunk against the cheapest
    failing variant and written to ``corpus_dir`` (when given).  Returns
    a stats dict: ``{"programs", "ops", "mismatch": report|None,
    "repro_path", "shrunk_ops", "seq_histogram", "seconds"}``.
    ``max_minutes`` bounds soak-style runs by wall clock instead of
    budget.  The engine compile cache is cleared every
    ``clear_cache_every`` programs -- fuzzing sweeps distinct programs,
    so the cache only pins dead executables.
    """
    t0 = time.time()
    log = log or (lambda s: None)
    stats = {"programs": 0, "ops": 0, "mismatch": None, "repro_path": None,
             "shrunk_ops": None, "seq_histogram": {}, "seconds": 0.0,
             "last_seed": None}
    for i in range(budget):
        if max_minutes is not None and (time.time() - t0) / 60 > max_minutes:
            log(f"fuzz: wall-clock budget {max_minutes} min reached")
            break
        s = seed + i
        fp = gen_program(s, cfg)
        report = replay(fp, variants=variants, mutate=mutate)
        stats["programs"] += 1
        stats["ops"] += report.cycles
        stats["last_seed"] = s
        for name, _ in fp.groups:
            stats["seq_histogram"][name] = \
                stats["seq_histogram"].get(name, 0) + 1
        if stats["programs"] % 20 == 0:
            log(f"fuzz: {stats['programs']} programs clean "
                f"({stats['ops']} micro-ops replayed, "
                f"{time.time() - t0:.0f}s)")
        if clear_cache_every and stats["programs"] % clear_cache_every == 0:
            engine.clear_compile_cache()
        if not report.ok:
            log(f"fuzz: MISMATCH at seed {s}: " + "; ".join(
                f"{m.variant}/{m.field}" for m in report.mismatches))
            min_fp = fp
            if do_shrink:
                bad_variants = [m.variant for m in report.mismatches
                                if m.variant in VARIANTS]
                probe = tuple(bad_variants[:1]) or tuple(variants)

                def fails(cand):
                    return not replay(cand, variants=probe,
                                      mutate=mutate).ok

                min_fp = shrink(fp, fails)
                log(f"fuzz: shrunk {len(fp.program.expand())} -> "
                    f"{len(min_fp.program.expand())} micro-ops")
            final = replay(min_fp, variants=variants, mutate=mutate)
            stats["mismatch"] = final if not final.ok else report
            stats["shrunk_ops"] = len(min_fp.program.expand())
            if corpus_dir is not None:
                stats["repro_path"] = str(save_repro(
                    min_fp, stats["mismatch"], corpus_dir))
            break
    stats["seconds"] = time.time() - t0
    return stats
