"""Pure-numpy oracles for Compute RAM programs.

Integer oracles are exact unsigned arithmetic.  The bfloat16 oracles
replicate the engine's documented semantics **bit-exactly**:

* FTZ: subnormal inputs are treated as zero; outputs whose packed
  exponent would be 0 are flushed to +0.
* RTZ: right-shifts truncate (no guard/round/sticky bits).
* finite-only: exponent 255 is treated as an ordinary value; tests
  avoid overflow regions (documented limitation, matches the paper's
  scope which evaluates throughput, not IEEE edge cases).
"""

from __future__ import annotations

import numpy as np


# -- integers ---------------------------------------------------------------
def iadd(a, b, n):
    return (np.asarray(a, np.uint64) + np.asarray(b, np.uint64)) % (1 << n)


def isub(a, b, n):
    return (np.asarray(a, np.int64) - np.asarray(b, np.int64)) % (1 << n)


def imul(a, b, n):
    return (np.asarray(a, np.uint64) * np.asarray(b, np.uint64)) % (1 << (2 * n))


def idot(a, b, acc_bits=32):
    """a, b: (T, cols) -> (cols,) accumulated dot product."""
    s = (np.asarray(a, np.uint64) * np.asarray(b, np.uint64)).sum(axis=0)
    return s % (1 << acc_bits)


# -- parameterized floats (bit-pattern in/out as unsigned ints) -------------
def _parts(u, e_bits=8, m_bits=7):
    u = np.asarray(u, np.uint32)
    emask = (1 << e_bits) - 1
    mmask = (1 << m_bits) - 1
    s = (u >> (e_bits + m_bits)) & 1
    e = (u >> m_bits) & emask
    m = u & mmask
    hidden = (e != 0).astype(np.uint32)
    m = np.where(hidden == 1, m, 0)          # FTZ inputs
    mant = m | (hidden << m_bits)            # mantissa with hidden bit
    return s, e, mant, hidden


def _pack(s, e, m, e_bits=8, m_bits=7):
    emask = (1 << e_bits) - 1
    mmask = (1 << m_bits) - 1
    return ((s.astype(np.uint32) << (e_bits + m_bits))
            | ((e & emask) << m_bits) | (m & mmask))


def float_add(a_bits, b_bits, e_bits=8, m_bits=7):
    """Matches the engine's float_add sequence bit-exactly."""
    import math
    sa, ea, ma, _ = _parts(a_bits, e_bits, m_bits)
    sb, eb, mb, _ = _parts(b_bits, e_bits, m_bits)
    emod = 1 << e_bits
    mm = m_bits + 3
    L = max(1, math.ceil(math.log2(mm)))
    wmask = (1 << (m_bits + 1)) - 1          # normalize window

    swap = ea > eb                           # engine SW: 1 -> BIG = a
    sbig = np.where(swap, sa, sb)
    ssml = np.where(swap, sb, sa)
    ebig = np.where(swap, ea, eb)
    esml = np.where(swap, eb, ea)
    mbig = np.where(swap, ma, mb)
    msml = np.where(swap, mb, ma)

    ediff = ebig - esml
    msml = np.where(ediff >= (1 << L), 0,
                    msml >> np.minimum(ediff, (1 << L) - 1))   # RTZ

    mod = 1 << mm
    sub = (sbig ^ ssml) == 1
    rr = np.where(sub, (mbig - msml) % mod, mbig + msml)
    neg = sub & (msml > mbig)
    rr = np.where(neg, (msml - mbig) % mod, rr)
    sgn = np.where(neg, 1 - sbig, sbig)

    ee = ebig.copy()
    ovf = (~sub) & ((rr >> (m_bits + 1)) & 1 == 1)
    rr = np.where(ovf, rr >> 1, rr)          # RTZ drop
    ee = np.where(ovf, (ee + 1) % emod, ee)

    sc = np.zeros_like(rr)
    k = 1
    shifts = []
    while k <= m_bits:
        shifts.append(k)
        k <<= 1
    for k in reversed(shifts):
        cond = (rr >> (m_bits - k + 1)) & ((1 << k) - 1) == 0
        rr = np.where(cond, (rr << k) & wmask, rr)
        sc = sc + k * cond

    und = sc > ee
    ee = (ee - sc) % emod

    flush = (rr == 0) | und | (ee == 0)
    return np.where(flush, 0,
                    _pack(sgn, ee, rr, e_bits, m_bits)).astype(np.uint32)


def float_mul(a_bits, b_bits, e_bits=8, m_bits=7):
    sa, ea, ma, ha = _parts(a_bits, e_bits, m_bits)
    sb, eb, mb, hb = _parts(b_bits, e_bits, m_bits)
    bias = (1 << (e_bits - 1)) - 1
    e2mod = 1 << (e_bits + 1)
    emask = (1 << e_bits) - 1
    mmask = (1 << m_bits) - 1

    sgn = sa ^ sb
    esum = (ea + eb) % e2mod
    und = esum < bias
    ee = (esum - bias) % e2mod

    p = (ma * mb) & ((1 << (2 * m_bits + 2)) - 1)
    top = (p >> (2 * m_bits + 1)) & 1 == 1
    mm = np.where(top, (p >> (m_bits + 1)) & mmask, (p >> m_bits) & mmask)
    ee = np.where(top, (ee + 1) % e2mod, ee)

    flush = und | (ha == 0) | (hb == 0) | ((ee & emask) == 0)
    return np.where(flush, 0,
                    _pack(sgn, ee & emask, mm, e_bits, m_bits)
                    ).astype(np.uint32)


def bf16_add(a_bits, b_bits):
    return float_add(a_bits, b_bits, 8, 7).astype(np.uint16)


def bf16_mul(a_bits, b_bits):
    return float_mul(a_bits, b_bits, 8, 7).astype(np.uint16)


# -- fused MAC (dot product) ------------------------------------------------
#: Extra low-order mantissa bits of the float_dot accumulator (the
#: "widened accumulator": same exponent field, m_bits + ACC_GUARD
#: mantissa bits, RTZ).  Matches repro.core.floatprog.ACC_GUARD.
ACC_GUARD = 8


def float_dot_acc(a_bits, b_bits, e_bits=8, m_bits=7, guard=ACC_GUARD,
                  acc=None):
    """Sequential fused-MAC reference: ``acc += sum_t a_t * b_t``.

    a_bits, b_bits: ``(T, cols)`` fmt bit patterns.  ``acc`` is an
    optional ``(cols,)`` *wide-format* accumulator image (exponent
    ``e_bits``, mantissa ``m_bits + guard``) carried from a previous
    K-tile; None starts from +0.  Returns ``(result_bits, acc_bits)``:
    the fmt result (guard bits RTZ-truncated, zero exponent flushed)
    and the wide accumulator for chaining.  Tuples accumulate **in
    order** -- float addition does not associate, so this, not a
    tree-sum, is the contract the engine program reproduces bit-exactly.
    """
    a = np.asarray(a_bits, np.uint32)
    b = np.asarray(b_bits, np.uint32)
    mw = m_bits + guard
    emask = (1 << e_bits) - 1
    mmask = (1 << m_bits) - 1
    acc = (np.zeros(a.shape[1:], np.uint32) if acc is None
           else np.asarray(acc, np.uint32))
    for t in range(a.shape[0]):
        p = float_mul(a[t], b[t], e_bits, m_bits)
        s = p >> (e_bits + m_bits)
        e = (p >> m_bits) & emask
        m = p & mmask
        pw = _pack(s, e, m << guard, e_bits, mw)     # widen: guard zeros
        acc = float_add(acc, pw, e_bits, mw)
    return float_dot_round(acc, e_bits, m_bits, guard), acc


def float_dot_round(acc_bits, e_bits=8, m_bits=7, guard=ACC_GUARD):
    """Final normalize/round of a wide accumulator: RTZ-truncate the
    guard bits and flush a zero exponent to +0."""
    mw = m_bits + guard
    acc = np.asarray(acc_bits, np.uint32)
    emask = (1 << e_bits) - 1
    s = acc >> (e_bits + mw)
    e = (acc >> mw) & emask
    m = (acc & ((1 << mw) - 1)) >> guard
    return np.where(e == 0, 0,
                    _pack(s, e, m, e_bits, m_bits)).astype(np.uint32)


def float_dot(a_bits, b_bits, e_bits=8, m_bits=7, guard=ACC_GUARD):
    """Fused-MAC dot product reference (see :func:`float_dot_acc`)."""
    return float_dot_acc(a_bits, b_bits, e_bits, m_bits, guard)[0]


def float_matmul(x_bits, w_bits, e_bits=8, m_bits=7, guard=ACC_GUARD):
    """``(M, K) @ (K, N)`` with :func:`float_dot` semantics per output
    element (K accumulated in order).  Bit patterns in / out."""
    x = np.asarray(x_bits, np.uint32)
    w = np.asarray(w_bits, np.uint32)
    M, K = x.shape
    out = np.zeros((M, w.shape[1]), np.uint32)
    for m in range(M):
        out[m] = float_dot(np.broadcast_to(x[m][:, None], w.shape), w,
                           e_bits, m_bits, guard)
    return out


def bf16_dot(a_bits, b_bits):
    return float_dot(a_bits, b_bits, 8, 7).astype(np.uint16)


# -- float <-> bit-pattern conversion (FTZ + RTZ, finite-only) --------------
def to_bits(x, e_bits=8, m_bits=7):
    """float32 array -> packed fmt bit patterns.

    RTZ (mantissa truncation), FTZ (anything below the smallest normal
    becomes +0), finite-only (overflow -- and inf/nan inputs -- clamp
    to the largest finite magnitude).  For bf16 this is exactly the
    truncating float32 >> 16 conversion.
    """
    x = np.ascontiguousarray(x, np.float32)
    u = x.view(np.uint32)
    s = (u >> 31).astype(np.uint32)
    e32 = ((u >> 23) & 0xFF).astype(np.int64)
    m32 = (u & 0x7FFFFF).astype(np.uint32)
    bias = (1 << (e_bits - 1)) - 1
    emax = (1 << e_bits) - 1
    e = e32 - 127 + bias
    m = m32 >> (23 - m_bits)
    m = np.where((e > emax) | (e32 == 255), (1 << m_bits) - 1, m)
    e = np.clip(e, 0, emax)
    out = _pack(s, e.astype(np.uint32), m, e_bits, m_bits)
    return np.where(e == 0, 0, out).astype(np.uint32)   # FTZ


def from_bits(u, e_bits=8, m_bits=7):
    """Packed fmt bit patterns -> float32 (exact: FTZ values are
    integer-mantissa scaled powers of two; only bf16's very top
    exponent codes exceed float32 range and map to +/-inf)."""
    s, e, mant, _ = _parts(u, e_bits, m_bits)
    bias = (1 << (e_bits - 1)) - 1
    val = mant.astype(np.float64) * np.exp2(
        e.astype(np.float64) - bias - m_bits)
    return np.where(s == 1, -val, val).astype(np.float32)
