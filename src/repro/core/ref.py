"""Pure-numpy oracles for Compute RAM programs.

Integer oracles are exact unsigned arithmetic.  The bfloat16 oracles
replicate the engine's documented semantics **bit-exactly**:

* FTZ: subnormal inputs are treated as zero; outputs whose packed
  exponent would be 0 are flushed to +0.
* RTZ: right-shifts truncate (no guard/round/sticky bits).
* finite-only: exponent 255 is treated as an ordinary value; tests
  avoid overflow regions (documented limitation, matches the paper's
  scope which evaluates throughput, not IEEE edge cases).
"""

from __future__ import annotations

import numpy as np


# -- integers ---------------------------------------------------------------
def iadd(a, b, n):
    return (np.asarray(a, np.uint64) + np.asarray(b, np.uint64)) % (1 << n)


def isub(a, b, n):
    return (np.asarray(a, np.int64) - np.asarray(b, np.int64)) % (1 << n)


def imul(a, b, n):
    return (np.asarray(a, np.uint64) * np.asarray(b, np.uint64)) % (1 << (2 * n))


def idot(a, b, acc_bits=32):
    """a, b: (T, cols) -> (cols,) accumulated dot product."""
    s = (np.asarray(a, np.uint64) * np.asarray(b, np.uint64)).sum(axis=0)
    return s % (1 << acc_bits)


# -- parameterized floats (bit-pattern in/out as unsigned ints) -------------
def _parts(u, e_bits=8, m_bits=7):
    u = np.asarray(u, np.uint32)
    emask = (1 << e_bits) - 1
    mmask = (1 << m_bits) - 1
    s = (u >> (e_bits + m_bits)) & 1
    e = (u >> m_bits) & emask
    m = u & mmask
    hidden = (e != 0).astype(np.uint32)
    m = np.where(hidden == 1, m, 0)          # FTZ inputs
    mant = m | (hidden << m_bits)            # mantissa with hidden bit
    return s, e, mant, hidden


def _pack(s, e, m, e_bits=8, m_bits=7):
    emask = (1 << e_bits) - 1
    mmask = (1 << m_bits) - 1
    return ((s.astype(np.uint32) << (e_bits + m_bits))
            | ((e & emask) << m_bits) | (m & mmask))


def float_add(a_bits, b_bits, e_bits=8, m_bits=7):
    """Matches the engine's float_add sequence bit-exactly."""
    import math
    sa, ea, ma, _ = _parts(a_bits, e_bits, m_bits)
    sb, eb, mb, _ = _parts(b_bits, e_bits, m_bits)
    emod = 1 << e_bits
    mm = m_bits + 3
    L = max(1, math.ceil(math.log2(mm)))
    wmask = (1 << (m_bits + 1)) - 1          # normalize window

    swap = ea > eb                           # engine SW: 1 -> BIG = a
    sbig = np.where(swap, sa, sb)
    ssml = np.where(swap, sb, sa)
    ebig = np.where(swap, ea, eb)
    esml = np.where(swap, eb, ea)
    mbig = np.where(swap, ma, mb)
    msml = np.where(swap, mb, ma)

    ediff = ebig - esml
    msml = np.where(ediff >= (1 << L), 0,
                    msml >> np.minimum(ediff, (1 << L) - 1))   # RTZ

    mod = 1 << mm
    sub = (sbig ^ ssml) == 1
    rr = np.where(sub, (mbig - msml) % mod, mbig + msml)
    neg = sub & (msml > mbig)
    rr = np.where(neg, (msml - mbig) % mod, rr)
    sgn = np.where(neg, 1 - sbig, sbig)

    ee = ebig.copy()
    ovf = (~sub) & ((rr >> (m_bits + 1)) & 1 == 1)
    rr = np.where(ovf, rr >> 1, rr)          # RTZ drop
    ee = np.where(ovf, (ee + 1) % emod, ee)

    sc = np.zeros_like(rr)
    k = 1
    shifts = []
    while k <= m_bits:
        shifts.append(k)
        k <<= 1
    for k in reversed(shifts):
        cond = (rr >> (m_bits - k + 1)) & ((1 << k) - 1) == 0
        rr = np.where(cond, (rr << k) & wmask, rr)
        sc = sc + k * cond

    und = sc > ee
    ee = (ee - sc) % emod

    flush = (rr == 0) | und | (ee == 0)
    return np.where(flush, 0,
                    _pack(sgn, ee, rr, e_bits, m_bits)).astype(np.uint32)


def float_mul(a_bits, b_bits, e_bits=8, m_bits=7):
    sa, ea, ma, ha = _parts(a_bits, e_bits, m_bits)
    sb, eb, mb, hb = _parts(b_bits, e_bits, m_bits)
    bias = (1 << (e_bits - 1)) - 1
    e2mod = 1 << (e_bits + 1)
    emask = (1 << e_bits) - 1
    mmask = (1 << m_bits) - 1

    sgn = sa ^ sb
    esum = (ea + eb) % e2mod
    und = esum < bias
    ee = (esum - bias) % e2mod

    p = (ma * mb) & ((1 << (2 * m_bits + 2)) - 1)
    top = (p >> (2 * m_bits + 1)) & 1 == 1
    mm = np.where(top, (p >> (m_bits + 1)) & mmask, (p >> m_bits) & mmask)
    ee = np.where(top, (ee + 1) % e2mod, ee)

    flush = und | (ha == 0) | (hb == 0) | ((ee & emask) == 0)
    return np.where(flush, 0,
                    _pack(sgn, ee & emask, mm, e_bits, m_bits)
                    ).astype(np.uint32)


def bf16_add(a_bits, b_bits):
    return float_add(a_bits, b_bits, 8, 7).astype(np.uint16)


def bf16_mul(a_bits, b_bits):
    return float_mul(a_bits, b_bits, 8, 7).astype(np.uint16)
