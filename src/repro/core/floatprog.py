"""Parameterized floating-point instruction sequences — the paper's
"any custom precision" claim (§III-C advantage 2), literally.

One generator pair covers every (exp_bits, man_bits) format: bfloat16
(8,7), IEEE half (5,10), fp8-e4m3 (4,3), or anything else — switching
precision is *loading a different instruction sequence*, no hardware
change.  Semantics: FTZ + RTZ, finite-only (same as the bf16 oracles;
generalized oracles live in ``repro.core.ref``).

Bit layout per operand (LSB-first rows): m mantissa, e exponent, 1 sign.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Tuple

from .isa import (AddReg, Instr, Loop, Program, R, SetReg,
                  OP_C0, OP_C1, OP_COPY, OP_CSTORE, OP_FA, OP_FS, OP_NOT,
                  OP_T1, OP_TAND, OP_TC, OP_TNOT, OP_TNROW, OP_TOR,
                  OP_TROW, OP_TSTORE, OP_W0, OP_W1, OP_XOR)


@dataclasses.dataclass(frozen=True)
class FloatFormat:
    ebits: int
    mbits: int
    name: str = ""

    @property
    def width(self) -> int:
        return 1 + self.ebits + self.mbits

    @property
    def bias(self) -> int:
        return (1 << (self.ebits - 1)) - 1

    @property
    def mm(self) -> int:                    # working mantissa reg width
        return self.mbits + 3

    @property
    def align_levels(self) -> int:          # shift bits for alignment
        return max(1, math.ceil(math.log2(self.mm)))

    @property
    def lz_shifts(self):                    # leading-zero normalize steps
        out = []
        k = 1
        while k <= self.mbits:
            out.append(k)
            k <<= 1
        return list(reversed(out))

    @property
    def sc_bits(self) -> int:
        return len(self.lz_shifts)


BF16 = FloatFormat(8, 7, "bf16")
FP16 = FloatFormat(5, 10, "fp16")
FP8_E4M3 = FloatFormat(4, 3, "fp8")


@dataclasses.dataclass(frozen=True)
class FloatScratch:
    """Absolute scratch-row map (sized per format)."""
    base: int
    fmt: FloatFormat

    def _sizes(self):
        f = self.fmt
        rr = max(f.mm, 2 * f.mbits + 2)
        return [("WA", f.width), ("WB", f.width), ("SW", 1), ("SBIG", 1),
                ("ED", f.ebits), ("MB", f.mm), ("MS", f.mm), ("RR", rr),
                ("EE", f.ebits + 1), ("MM", f.mbits), ("SC", f.sc_bits),
                ("CB", f.ebits + 1), ("HA", 1), ("HB", 1), ("SUB", 1),
                ("NEG", 1), ("COUT", 1), ("SGN", 1), ("UND", 1), ("Z", 1)]

    def __getattr__(self, name):
        off = object.__getattribute__(self, "base")
        for k, sz in object.__getattribute__(self, "_sizes")():
            if k == name:
                return off
            off += sz
        raise AttributeError(name)

    def size(self) -> int:
        return sum(sz for _, sz in self._sizes())


def _layout(fmt: FloatFormat, rows: int, tuples):
    from .programs import TupleLayout
    scratch = FloatScratch(0, fmt)
    scratch = FloatScratch(rows - scratch.size(), fmt)
    w = fmt.width
    stride = 3 * w
    T = tuples if tuples is not None else (rows - scratch.size()) // stride
    layout = TupleLayout(w, rows, stride, T,
                         {"a": (0, w), "b": (w, w), "d": (2 * w, w)},
                         scratch_base=scratch.base)
    return layout, scratch


def _ftz_hidden(e, s, fmt):
    """Extract hidden bits + flush subnormal inputs in WA/WB."""
    m, eb = fmt.mbits, fmt.ebits
    for W, H in ((s.WA, s.HA), (s.WB, s.HB)):
        e.tag_or(W + m, eb)
        e.op(OP_TSTORE, H)                  # hidden bit = (exp != 0)
        e.op(OP_TNOT)
        e.vec(OP_W0, W, count=m, pred=True)   # FTZ inputs


def _load_and_ftz(e, s, fmt):
    w = fmt.width
    e.vec_rel(OP_COPY, s.WA, 0, w, a_rel=True)
    e.vec_rel(OP_COPY, s.WB, w, w, a_rel=True)
    _ftz_hidden(e, s, fmt)


def float_add(fmt: FloatFormat, rows: int = 512,
              tuples=None) -> Tuple[Program, "TupleLayout"]:
    """d = a + b in the given format (FTZ, RTZ, finite-only)."""
    from .programs import _Emit
    layout, s = _layout(fmt, rows, tuples)
    m, eb, w = fmt.mbits, fmt.ebits, fmt.width

    e = _Emit()
    e.op(OP_W0, s.Z)
    e.op(OP_T1)
    e.ctrl(SetReg(4, 0))

    body = _Emit()
    body.op(OP_T1)
    _load_and_ftz(body, s, fmt)
    _add_core(body, s, fmt)

    # pack
    body.vec_rel(OP_COPY, 2 * w, s.RR, m, dst_rel=True)
    body.vec_rel(OP_COPY, 2 * w + m, s.EE, eb, dst_rel=True)
    body.nodes.append(Instr(OP_COPY, R(4, 2 * w + m + eb), s.SGN))
    body.nodes.append(AddReg(4, 3 * w))

    e.nodes.append(Loop(layout.tuples, body.nodes))
    return Program(f"{fmt.name or 'float'}_add x{layout.tuples}",
                   e.nodes), layout


def _add_core(body, s, fmt):
    """WA + WB -> (s.SGN, s.EE[:eb], s.RR[:m]), FTZ+RTZ.

    Everything of the float adder between operand load and result pack;
    shared verbatim by :func:`float_add` (operands = the tuple's a/b)
    and :func:`float_dot` (operands = running accumulator + product, in
    the widened accumulator format).  Expects WA/WB loaded and HA/HB
    set (:func:`_ftz_hidden`).
    """
    m, eb = fmt.mbits, fmt.ebits
    mm, L = fmt.mm, fmt.align_levels

    # swap flag + |ediff| + big/small register build (two predicated passes)
    body.op(OP_C0)
    body.vec(OP_FS, s.ED, s.WB + m, s.WA + m, count=eb, sa=1, sb=1)
    body.op(OP_TC)
    body.op(OP_TSTORE, s.SW)               # 1 -> BIG = WA
    body.op(OP_C0)
    body.vec(OP_FS, s.ED, s.Z, s.ED, count=eb, sa=0, sb=1, pred=True)

    for tagop, WBIG, WSML, HBIG, HSML in (
            (OP_TROW, s.WA, s.WB, s.HA, s.HB),
            (OP_TNROW, s.WB, s.WA, s.HB, s.HA)):
        body.op(tagop, a=s.SW)
        body.vec(OP_COPY, s.EE, WBIG + m, count=eb, pred=True)
        body.vec(OP_COPY, s.MB, WBIG, count=m, pred=True)
        body.op(OP_COPY, s.MB + m, HBIG, pred=True)
        body.vec(OP_COPY, s.MS, WSML, count=m, pred=True)
        body.op(OP_COPY, s.MS + m, HSML, pred=True)
        body.op(OP_COPY, s.SBIG, WBIG + m + eb, pred=True)
    body.op(OP_T1)
    for M in (s.MB, s.MS):
        body.op(OP_W0, M + m + 1)
        body.op(OP_W0, M + m + 2)

    # align: saturating right shift of MS by |ediff|
    if eb > L:
        body.tag_or(s.ED + L, eb - L)       # ediff >= 2^L -> zero
        body.vec(OP_W0, s.MS, count=mm, pred=True)
    for bit in range(L - 1, -1, -1):
        k = 1 << bit
        body.op(OP_TROW, a=s.ED + bit)
        keep = mm - k
        if keep > 0:
            body.vec(OP_COPY, s.MS, s.MS + k, count=keep, pred=True)
            body.vec(OP_W0, s.MS + keep, count=k, pred=True)
        else:
            body.vec(OP_W0, s.MS, count=mm, pred=True)

    # effective add/sub
    body.op(OP_XOR, s.SUB, s.WA + m + eb, s.WB + m + eb)
    body.op(OP_TROW, a=s.SUB)
    body.op(OP_C0)
    body.vec(OP_FS, s.RR, s.MB, s.MS, count=mm, sa=1, sb=1, pred=True)
    body.op(OP_CSTORE, s.COUT, pred=True)
    body.op(OP_TNROW, a=s.SUB)
    body.op(OP_C0)
    body.vec(OP_FA, s.RR, s.MB, s.MS, count=mm, sa=1, sb=1, pred=True)
    body.op(OP_T1)

    # negative subtraction result
    body.op(OP_TROW, a=s.SUB)
    body.op(OP_TAND, a=s.COUT)
    body.op(OP_TSTORE, s.NEG)
    body.op(OP_C0)
    body.vec(OP_FS, s.RR, s.Z, s.RR, count=mm, sa=0, sb=1, pred=True)
    body.op(OP_XOR, s.SGN, s.SBIG, s.NEG)
    body.op(OP_T1)

    # add-overflow normalize: bit m+1
    body.op(OP_TNROW, a=s.SUB)
    body.op(OP_TAND, a=s.RR + m + 1)
    body.vec(OP_COPY, s.RR, s.RR + 1, count=m + 1, pred=True)
    body.op(OP_W0, s.RR + m + 1, pred=True)
    body.op(OP_C1)
    body.vec(OP_FA, s.EE, s.EE, s.Z, count=eb, sa=1, sb=0, pred=True)
    body.op(OP_T1)
    body.op(OP_C0)

    # leading-zero normalize with shift-count accumulation
    body.vec(OP_W0, s.SC, count=fmt.sc_bits)
    for k in fmt.lz_shifts:
        if k > 1:
            body.tag_or(s.RR + m - k + 1, k, invert=True)
        else:
            body.op(OP_TNROW, a=s.RR + m)
        body.op(OP_TSTORE, s.SC + int(math.log2(k)))
        # left-shift by k: descending copy (loop-compressed)
        body.vec(OP_COPY, s.RR + m, s.RR + m - k, count=m - k + 1,
                 sd=-1, sa=-1, pred=True)
        body.vec(OP_W0, s.RR, count=k, pred=True)
    body.op(OP_T1)

    # EE -= SC
    body.op(OP_C0)
    scw = min(fmt.sc_bits, eb)
    body.vec(OP_FS, s.EE, s.EE, s.SC, count=scw, sa=1, sb=1)
    if eb > scw:
        body.vec(OP_FS, s.EE + scw, s.EE + scw, s.Z, count=eb - scw,
                 sa=1, sb=0)
    body.op(OP_CSTORE, s.UND)

    # flush: zero mantissa / underflow / exp==0
    body.tag_or(s.RR, mm, invert=True)
    body.op(OP_TSTORE, s.COUT)
    body.tag_or(s.EE, eb, invert=True)
    body.op(OP_TOR, a=s.COUT)
    body.op(OP_TOR, a=s.UND)
    body.vec(OP_W0, s.EE, count=eb, pred=True)
    body.vec(OP_W0, s.RR, count=m + 1, pred=True)
    body.op(OP_W0, s.SGN, pred=True)
    body.op(OP_T1)


def _mul_bias(e, s, fmt):
    """Write the exponent bias constant 2^(e-1) - 1 into s.CB."""
    eb = fmt.ebits
    for i in range(eb - 1):
        e.op(OP_W1, s.CB + i)
    e.op(OP_W0, s.CB + eb - 1)
    e.op(OP_W0, s.CB + eb)


def float_mul(fmt: FloatFormat, rows: int = 512,
              tuples=None) -> Tuple[Program, "TupleLayout"]:
    """d = a * b (FTZ, RTZ, finite-only, overflow wraps)."""
    from .programs import _Emit
    layout, s = _layout(fmt, rows, tuples)
    m, eb, w = fmt.mbits, fmt.ebits, fmt.width

    e = _Emit()
    e.op(OP_W0, s.Z)
    e.op(OP_T1)
    _mul_bias(e, s, fmt)
    e.ctrl(SetReg(4, 0))

    body = _Emit()
    body.op(OP_T1)
    _load_and_ftz(body, s, fmt)
    _mul_core(body, s, fmt)

    # pack
    body.vec_rel(OP_COPY, 2 * w, s.MM, m, dst_rel=True)
    body.vec_rel(OP_COPY, 2 * w + m, s.EE, eb, dst_rel=True)
    body.nodes.append(Instr(OP_COPY, R(4, 2 * w + m + eb), s.SGN))
    body.nodes.append(AddReg(4, 3 * w))

    e.nodes.append(Loop(layout.tuples, body.nodes))
    return Program(f"{fmt.name or 'float'}_mul x{layout.tuples}",
                   e.nodes), layout


def _mul_core(body, s, fmt):
    """WA * WB -> (s.SGN, s.EE[:eb] flushed, s.MM[:m]), FTZ+RTZ.

    The float multiplier between operand load and result pack, shared
    by :func:`float_mul` and the fused-MAC :func:`float_dot`.  Expects
    WA/WB loaded, HA/HB set, and the bias constant in s.CB
    (:func:`_mul_bias`, emitted once in the prelude).
    """
    m, eb = fmt.mbits, fmt.ebits

    body.op(OP_XOR, s.SGN, s.WA + m + eb, s.WB + m + eb)

    # exponent: EE = ea + eb - bias
    body.op(OP_C0)
    body.vec(OP_FA, s.EE, s.WA + m, s.WB + m, count=eb, sa=1, sb=1)
    body.op(OP_CSTORE, s.EE + eb)
    body.op(OP_C0)
    body.vec(OP_FS, s.EE, s.EE, s.CB, count=eb + 1, sa=1, sb=1)
    body.op(OP_CSTORE, s.UND)

    # hidden bits into position m (over exp LSB row, already consumed)
    body.op(OP_COPY, s.WA + m, s.HA)
    body.op(OP_COPY, s.WB + m, s.HB)

    # (m+1) x (m+1) -> 2m+2 bit product
    pw = 2 * m + 2
    body.vec(OP_W0, s.RR, count=pw)
    for i in range(m + 1):
        body.op(OP_TROW, a=s.WB + i)
        body.op(OP_C0)
        body.vec(OP_FA, s.RR + i, s.RR + i, s.WA, count=m + 1, sa=1, sb=1,
                 pred=True)
        body.op(OP_CSTORE, s.RR + i + m + 1, pred=True)
    body.op(OP_T1)

    # normalize: top bit 2m+1 set -> MM = RR[m+1 .. 2m], EE += 1
    body.op(OP_TROW, a=s.RR + 2 * m + 1)
    body.vec(OP_COPY, s.MM, s.RR + m + 1, count=m, pred=True)
    body.op(OP_C1)
    body.vec(OP_FA, s.EE, s.EE, s.Z, count=eb + 1, sa=1, sb=0, pred=True)
    body.op(OP_TNROW, a=s.RR + 2 * m + 1)
    body.vec(OP_COPY, s.MM, s.RR + m, count=m, pred=True)
    body.op(OP_T1)

    # flush: underflow / zero input / packed exp == 0
    body.op(OP_NOT, s.COUT, s.HA)
    body.op(OP_NOT, s.NEG, s.HB)
    body.op(OP_TROW, a=s.UND)
    body.op(OP_TOR, a=s.COUT)
    body.op(OP_TOR, a=s.NEG)
    body.op(OP_TSTORE, s.SUB)
    body.tag_or(s.EE, eb, invert=True)
    body.op(OP_TOR, a=s.SUB)
    body.vec(OP_W0, s.MM, count=m, pred=True)
    body.vec(OP_W0, s.EE, count=eb + 1, pred=True)
    body.op(OP_W0, s.SGN, pred=True)
    body.op(OP_T1)


# ---------------------------------------------------------------------------
# Fused multiply-accumulate: the paper's dot-product column at float
# precision.  acc rows hold a running accumulator in a *widened* format
# (same exponent field, mantissa + ACC_GUARD extra RTZ guard bits); each
# tuple multiplies exactly as float_mul, widens the product, and runs
# the float_add pipeline against the accumulator -- align, add/sub,
# normalize -- all in the wide format.  The final normalize/round (RTZ
# truncation of the guard bits + exp==0 flush) packs the result rows.
# ---------------------------------------------------------------------------
#: Extra low-order accumulator mantissa bits (the widened-accumulator
#: guard).  Matches repro.core.ref.ACC_GUARD -- the numpy oracle.
ACC_GUARD = 8


def wide_format(fmt: FloatFormat, guard: int = ACC_GUARD) -> FloatFormat:
    """The widened accumulator format of :func:`float_dot`."""
    return FloatFormat(fmt.ebits, fmt.mbits + guard,
                       f"{fmt.name}w" if fmt.name else "")


def float_dot(fmt: FloatFormat, rows: int = 512, tuples=None,
              guard: int = ACC_GUARD) -> Tuple[Program, "TupleLayout"]:
    """acc += sum_t a_t * b_t, FTZ + RTZ, widened accumulator.

    Layout: result rows ``[0, w)`` (fmt bit pattern, valid after every
    pass), accumulator rows ``[w, w + wide.width)`` (wide-format bit
    pattern: mantissa, exponent, sign -- host-initialized, so a fresh
    run starts from +0 and a K-tiled reduction *chains* by carrying the
    acc image between launches), tuples of ``{a, b}`` above.  Semantics
    (bit-exact oracle: :func:`repro.core.ref.float_dot`): per tuple the
    product is rounded to fmt exactly as :func:`float_mul`, widened by
    ``guard`` zero guard bits, and added to the accumulator with the
    :func:`float_add` pipeline at the wide format; the final
    normalize/round truncates the guard bits (RTZ) and flushes a zero
    exponent.
    """
    from .programs import TupleLayout, _Emit
    m, eb, w = fmt.mbits, fmt.ebits, fmt.width
    wide = wide_format(fmt, guard)
    mw = wide.mbits
    acc_w = wide.width                       # mantissa + exponent + sign
    ACC = w                                  # result at [0, w), acc above

    sw_base = rows - FloatScratch(0, wide).size()
    s_base = sw_base - FloatScratch(0, fmt).size()
    s = FloatScratch(s_base, fmt)
    sw = FloatScratch(sw_base, wide)
    stride = 2 * w
    tuple_base = w + acc_w
    cap = (s_base - tuple_base) // stride
    T = tuples if tuples is not None else cap
    if T < 1 or T > cap:
        raise ValueError(
            f"geometry {rows} rows cannot host float_dot[{fmt.name}] "
            f"with {T if tuples is not None else 1} tuple(s) "
            f"(capacity {max(cap, 0)})")
    layout = TupleLayout(w, rows, stride, T, {"a": (0, w), "b": (w, w)},
                         acc_bits=tuple_base, scratch_base=s_base,
                         tuple_base=tuple_base)

    e = _Emit()
    e.op(OP_W0, s.Z)
    e.op(OP_W0, sw.Z)
    e.op(OP_T1)
    _mul_bias(e, s, fmt)
    e.ctrl(SetReg(4, tuple_base))

    body = _Emit()
    body.op(OP_T1)
    _load_and_ftz(body, s, fmt)
    _mul_core(body, s, fmt)
    # widen the product into a wide-format operand (guard zeros low)
    body.vec(OP_W0, sw.WA, count=guard)
    body.vec(OP_COPY, sw.WA + guard, s.MM, count=m)
    body.vec(OP_COPY, sw.WA + mw, s.EE, count=eb)
    body.op(OP_COPY, sw.WA + mw + eb, s.SGN)
    # fetch the running accumulator (the loop-carried rows: everything
    # from here on is the serial suffix of the lane plan)
    body.vec(OP_COPY, sw.WB, ACC, count=acc_w)
    _ftz_hidden(body, sw, wide)
    _add_core(body, sw, wide)
    # write the accumulator back
    body.vec(OP_COPY, ACC, sw.RR, count=mw)
    body.vec(OP_COPY, ACC + mw, sw.EE, count=eb)
    body.op(OP_COPY, ACC + mw + eb, sw.SGN)
    body.ctrl(AddReg(4, stride))
    e.nodes.append(Loop(T, body.nodes))

    # final normalize/round: RTZ-drop the guard bits into the result
    e.vec(OP_COPY, 0, ACC + guard, count=m)
    e.vec(OP_COPY, m, ACC + mw, count=eb)
    e.op(OP_COPY, m + eb, ACC + mw + eb)
    e.tag_or(ACC + mw, eb, invert=True)
    e.vec(OP_W0, 0, count=w, pred=True)      # exp == 0 -> flush to +0
    e.op(OP_T1)
    return Program(f"{fmt.name or 'float'}_dot x{T}", e.nodes), layout


def _read_rows(arr, base: int, width: int):
    import numpy as np
    out = np.zeros((arr.shape[1],), np.uint64)
    for i in range(width):
        out |= arr[base + i, :].astype(np.uint64) << np.uint64(i)
    return out


def fdot_result(arr, fmt: FloatFormat):
    """Read the packed fmt result of a float_dot pass: (cols,) bits."""
    return _read_rows(arr, 0, fmt.width)


def fdot_acc(arr, fmt: FloatFormat, guard: int = ACC_GUARD):
    """Read the wide-format accumulator image: (cols,) bits."""
    return _read_rows(arr, fmt.width, wide_format(fmt, guard).width)


def fdot_set_acc(arr, fmt: FloatFormat, acc_bits,
                 guard: int = ACC_GUARD) -> None:
    """Write a wide-format accumulator image into a packed state array
    (in place) -- how a K-tiled reduction chains across launches."""
    import numpy as np
    acc_bits = np.asarray(acc_bits, np.uint64)
    w = fmt.width
    for i in range(wide_format(fmt, guard).width):
        arr[w + i, :] = ((acc_bits >> np.uint64(i)) & np.uint64(1)) \
            .astype(arr.dtype)
