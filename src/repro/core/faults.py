"""Seeded bit-fault injection, parity detection, and scrub/repair for
Compute RAM blocks.

A Compute RAM block is an SRAM array, and SRAM on a real FPGA suffers
soft errors (SEU bit flips in stored rows) and, rarely, whole-block
(hard) faults.  The simulator's default assumption -- every bit read
back is the bit written -- hides both, and the weight-stationary
residency of the fabric scheduler makes a flipped resident weight tile
*persistently* wrong, corrupting every later launch that reads it.

This module provides the three pieces the stack hooks together:

* :class:`FaultModel` -- a seeded, deterministic fault process.  It
  draws per-bit flips at rate ``bit_rate`` each time an execution layer
  offers it a state (an *injection point*: between chained programs,
  before a block launch, per fabric round), and can mark whole blocks
  dead.  All draws come from one ``numpy`` Generator seeded at
  construction, so a given (seed, call sequence) replays exactly --
  the property the fuzzer's differential fault family relies on.
* **2-D parity signatures** -- per-block column parity over rows plus
  row parity over columns (:func:`parity_signature`).  Any odd number
  of flips in some row or column is detected; the smallest undetectable
  pattern is a 4-flip rectangle, vanishingly unlikely at the rates the
  bench gates (<= 1e-4).  Storage is ``rows + cols`` bits per block
  (:func:`parity_bits`), priced by ``core.costmodel.fault_cost``.
* **Scrub + repair** (:func:`scrub_states`) -- verify current state
  against the signature taken at load time; a dirty block is restored
  from its pristine image (the analog of evicting the resident tile and
  re-fetching it from the backing store), with the re-fetch traffic
  charged to the model's counters.

Everything defaults OFF: a ``FaultModel`` with ``bit_rate == 0`` and no
dead blocks is inert (``active`` is False), and every hook treats
``faults=None`` as the pre-fault bit-exact path.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


class FabricFaultError(RuntimeError):
    """A fault the fabric could not mask: a dead block with no spare
    capacity left, or corruption detected with repair disabled.  The
    serve layer catches this to retry / fall back (docs/faults.md)."""


@dataclasses.dataclass
class FaultModel:
    """Deterministic fault process + detection/repair accounting.

    Parameters
    ----------
    bit_rate:
        Per-bit flip probability applied at each injection point.
    dead_blocks:
        Block ids (grid positions) whose contents are garbage every
        launch -- the hard-fault model.  Repair remaps them to spares.
    seed:
        Seeds the private numpy Generator; same seed => same fault
        sequence.
    scrub:
        Enable parity verification + repair at the hooks.  With scrub
        off, injected flips propagate into outputs (the fuzzer's forced
        escape path).
    scrub_every:
        Verify parity every N-th injection point (cadence >= 1).  Flips
        injected between scrubs are still caught at the next scrub
        *before* the state is consumed, because hooks scrub-then-execute.
    heal_after:
        Stop injecting after this many injection *events* (not bits).
        Lets a retry deterministically succeed in serve degradation
        tests.  ``None`` = never heal.
    """

    bit_rate: float = 0.0
    dead_blocks: Tuple[int, ...] = ()
    seed: int = 0
    scrub: bool = True
    scrub_every: int = 1
    heal_after: Optional[int] = None

    # mutable accounting (reset with .reset())
    injected_flips: int = 0
    injection_events: int = 0
    detected: int = 0
    repaired: int = 0
    escaped: int = 0
    refetch_bits: int = 0
    scrub_rows: int = 0
    parity_bits: int = 0
    remaps: int = 0

    def __post_init__(self):
        if self.bit_rate < 0 or self.bit_rate > 1:
            raise ValueError(f"bit_rate must be in [0, 1]: {self.bit_rate}")
        if self.scrub_every < 1:
            raise ValueError(f"scrub_every must be >= 1: {self.scrub_every}")
        self.dead_blocks = tuple(sorted(set(int(b) for b in self.dead_blocks)))
        self._rng = np.random.default_rng(self.seed)

    # -- process ----------------------------------------------------------
    @property
    def active(self) -> bool:
        """True when the model can actually perturb an execution."""
        return self.bit_rate > 0 or bool(self.dead_blocks)

    @property
    def healed(self) -> bool:
        return (self.heal_after is not None
                and self.injection_events >= self.heal_after)

    def flip_mask(self, shape) -> np.ndarray:
        """Draw a boolean flip mask for one injection point.

        Always advances the RNG by one draw (so scrub on/off replays the
        same flip sequence); returns an all-False mask once healed.
        """
        was_healed = self.healed      # before counting THIS event:
        mask = self._rng.random(shape) < self.bit_rate
        self.injection_events += 1    # heal_after=N injects events 1..N
        if was_healed or self.bit_rate <= 0:
            return np.zeros(shape, np.bool_)
        self.injected_flips += int(mask.sum())
        return mask

    def should_scrub(self, point: int) -> bool:
        """Whether injection point ``point`` (0-based) falls on the
        scrub cadence."""
        return self.scrub and point % self.scrub_every == 0

    # -- accounting -------------------------------------------------------
    def reset(self) -> None:
        for f in ("injected_flips", "injection_events", "detected",
                  "repaired", "escaped", "refetch_bits", "scrub_rows",
                  "parity_bits", "remaps"):
            setattr(self, f, 0)
        self._rng = np.random.default_rng(self.seed)

    def stats(self) -> dict:
        return {
            "bit_rate": self.bit_rate,
            "dead_blocks": list(self.dead_blocks),
            "scrub": self.scrub,
            "scrub_every": self.scrub_every,
            "injected_flips": self.injected_flips,
            "injection_events": self.injection_events,
            "detected": self.detected,
            "repaired": self.repaired,
            "escaped": self.escaped,
            "refetch_bits": self.refetch_bits,
            "scrub_rows": self.scrub_rows,
            "parity_bits": self.parity_bits,
            "remaps": self.remaps,
        }


# ---------------------------------------------------------------------------
# 2-D parity signatures
# ---------------------------------------------------------------------------
def parity_bits(rows: int, cols: int) -> int:
    """Parity storage per block: one column-parity word (``cols`` bits,
    XOR over rows) + one row-parity word (``rows`` bits, XOR over
    columns)."""
    return rows + cols


def parity_signature(arrays: np.ndarray):
    """2-D parity of a block batch ``(blocks, rows, cols)`` (bool).

    Returns ``(col_parity (blocks, cols), row_parity (blocks, rows))``.
    """
    a = np.asarray(arrays, np.bool_)
    return (np.logical_xor.reduce(a, axis=-2),
            np.logical_xor.reduce(a, axis=-1))


def dirty_blocks(arrays: np.ndarray, signature) -> np.ndarray:
    """Blocks whose current parity disagrees with ``signature``.

    Returns a ``(blocks,)`` bool mask.  A block is dirty when *any* of
    its column- or row-parity bits mismatch.
    """
    col, row = parity_signature(arrays)
    ref_col, ref_row = signature
    return (np.any(col != ref_col, axis=-1)
            | np.any(row != ref_row, axis=-1))


def scrub_states(arrays: np.ndarray, pristine: np.ndarray, signature,
                 fm: FaultModel) -> np.ndarray:
    """Parity-verify ``arrays`` and restore dirty blocks from
    ``pristine`` (the load-time image == re-fetch from backing store).

    Charges detection/repair/re-fetch to ``fm``'s counters and returns
    the repaired batch.  A scrub *reads* every row of every block it
    verifies (the cost model prices that), but only dirty blocks pay
    re-fetch traffic.
    """
    blocks, rows, cols = arrays.shape
    fm.scrub_rows += blocks * rows
    dirty = dirty_blocks(arrays, signature)
    n_dirty = int(dirty.sum())
    if n_dirty:
        fm.detected += n_dirty
        fm.repaired += n_dirty
        fm.refetch_bits += n_dirty * rows * cols
        arrays = np.where(dirty[:, None, None], pristine, arrays)
    return arrays


# ---------------------------------------------------------------------------
# Engine-level protected execution (imported lazily by core.engine)
# ---------------------------------------------------------------------------
def inject(arrays: np.ndarray, fm: FaultModel,
           dead_slots=None) -> np.ndarray:
    """One injection point over a block batch: bit flips + dead blocks.

    ``dead_slots`` names the batch indices that read back garbage; the
    default ``None`` uses ``fm.dead_blocks`` directly (the engine-level
    convention, where batch index == block id).  The fabric passes an
    explicit (usually empty) list because grid block ids map to launch
    *slots* there, and dead blocks are handled by
    :func:`repro.pim.fabric.repair_program` before any launch.
    """
    was_healed = fm.healed            # flip_mask counts this event
    mask = fm.flip_mask(arrays.shape)
    out = np.logical_xor(arrays, mask)
    dead = fm.dead_blocks if dead_slots is None else dead_slots
    if dead and not was_healed:
        blocks, rows, cols = arrays.shape
        for b in dead:
            if 0 <= b < blocks:
                # a dead block reads back seeded garbage, not zeros --
                # zeros could masquerade as a valid cleared tile
                out[b] = fm._rng.random((rows, cols)) < 0.5
                fm.injected_flips += int(np.sum(out[b] != arrays[b]))
    return out


def apply_block_faults(program, states, fm: FaultModel, *,
                       executor: str = "compiled", packed=None):
    """Faulted :func:`repro.core.engine.execute_blocks`.

    Load-time parity is taken over the incoming row-states; flips (and
    dead-block garbage) are injected host-side *before* lowering, so the
    packed and bool interiors see identical corruption; a scrub on the
    model's cadence detects dirty blocks by parity and restores them
    from the pristine image before dispatching to the normal executor.
    """
    from . import engine  # local import: engine lazily imports us too
    import jax.numpy as jnp

    pristine = np.asarray(states.array, np.bool_)
    blocks, rows, cols = pristine.shape
    fm.parity_bits = max(fm.parity_bits, blocks * parity_bits(rows, cols))
    sig = parity_signature(pristine)
    arrays = inject(pristine.copy(), fm)
    if fm.should_scrub(fm.injection_events - 1):
        arrays = scrub_states(arrays, pristine, sig, fm)
    states = states._replace(array=jnp.asarray(arrays))
    return engine.execute_blocks(program, states, executor, packed=packed)


def apply_chain_faults(programs, state, fm: FaultModel, *, cse=None):
    """Faulted :func:`repro.core.engine.run_chain`: flips are injected
    between chained programs, so the fused single-jit chain gives way to
    a sequential per-program replay (each leg still compiled+cached).
    The state is treated as a 1-block batch for parity purposes.
    """
    from . import engine
    import jax.numpy as jnp

    programs = tuple(programs)
    for point, prog in enumerate(programs):
        pristine = np.asarray(state.array, np.bool_)[None]
        rows, cols = pristine.shape[1:]
        fm.parity_bits = max(fm.parity_bits, parity_bits(rows, cols))
        sig = parity_signature(pristine)
        arrays = inject(pristine.copy(), fm)
        if fm.should_scrub(fm.injection_events - 1):
            arrays = scrub_states(arrays, pristine, sig, fm)
        state = state._replace(array=jnp.asarray(arrays[0]))
        state = engine.run(prog, state, "compiled", packed=None)
    return state
