"""Serving example: batched requests through the slot-based engine,
optionally with PIM-packed (W4A8 bit-plane) weights.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import numpy as np

import jax

from repro import configs
from repro.models.model import LM
from repro.serve.engine import Request, ServeEngine


def main():
    cfg = configs.get_config("llama3.2-1b", smoke=True)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))

    eng = ServeEngine(model, params, batch_slots=4, capacity=64)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, rng.integers(3, 9)).astype(
        np.int32) for _ in range(6)]
    for i, p in enumerate(prompts):
        eng.add(Request(rid=i, prompt=p, max_new=8))

    done = eng.run()
    for r in sorted(done, key=lambda r: r.rid):
        print(f"req {r.rid}: prompt={[int(t) for t in r.prompt]} -> {r.out}")
    print(f"{len(done)} requests served through {eng.B} slots "
          f"(continuous batching)")

    # --- same engine, PIM storage-mode weights (int8 "compute RAM" style)
    from repro.models.qweight import quantize_tree, tree_bytes
    qparams = quantize_tree(params, bits=8)
    print(f"\nstorage-mode weights: {tree_bytes(params):,} -> "
          f"{tree_bytes(qparams):,} bytes")
    eng_q = ServeEngine(model, qparams, batch_slots=4, capacity=64)
    for i, p in enumerate(prompts[:3]):
        eng_q.add(Request(rid=i, prompt=p, max_new=8))
    done_q = {r.rid: r.out for r in eng_q.run()}
    ref = {r.rid: r.out for r in done}
    agree = sum(sum(a == b for a, b in zip(done_q[i], ref[i]))
                for i in done_q)
    total = sum(len(done_q[i]) for i in done_q)
    print(f"w8-served tokens matching bf16: {agree}/{total} "
          f"(greedy decode is sensitive on a random-init model)")


if __name__ == "__main__":
    main()
