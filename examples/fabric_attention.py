"""An attention score matmul scheduled across a Compute RAM block grid.

The paper's fabric-level story (§IV/§V) end-to-end: quantized q/k from
the attention layer layout, tiled over a grid of blocks (storage vs
compute mode allocation), executed exactly on the cycle-accurate block
simulator, and accounted with the paper's energy/timing methodology.

Run:  PYTHONPATH=src python examples/fabric_attention.py
"""

import numpy as np

from repro.pim import FabricConfig, fabric_matmul
from repro.pim.fabric import combine_costs, fabric_attention_scores


def main():
    rng = np.random.default_rng(0)

    # -- a quantized GEMM on a 16-block grid --------------------------------
    cfg = FabricConfig(n_blocks=16)
    x = rng.integers(-8, 8, (4, 96)).astype(np.int64)      # int4 activations
    w = rng.integers(-8, 8, (96, 64)).astype(np.int64)     # int4 weights
    res = fabric_matmul(x, w, nbits=4, cfg=cfg, signed=True)
    assert (res.out == x @ w).all()
    print(res.schedule.describe())
    rep = res.cost.report()
    print(f"  exact int4 GEMM: {rep['energy_pj']:.0f} pJ "
          f"({rep['energy_compute_pj']:.0f} compute / "
          f"{rep['energy_storage_pj']:.0f} storage / "
          f"{rep['energy_wire_pj']:.0f} wire), "
          f"{rep['time_us']:.1f} us, {rep['gops']:.3f} GOPS")
    # the wire split is hop-priced: every load/broadcast/drain is billed
    # by the Manhattan distance between its actual block sites
    print(f"  hop-priced wires: {rep['fabric_bit_mm']:.0f} bit*mm fabric "
          f"+ {rep['spill_bit_mm']:.0f} bit*mm spill "
          f"(avg net {rep['avg_hop_mm']:.2f} mm on the "
          f"{cfg.grid_rows}x{cfg.grid_cols} grid) "
          f"-> {rep['energy_wire_pj']:.0f} pJ")
    # serial vs overlapped: round i+1's loads double-buffer against
    # round i's compute (docs/fabric.md, "Overlapped rounds")
    print(f"  latency: serial {rep['serial_cycles']:.0f} cyc "
          f"({rep['time_us']:.1f} us) -> overlapped "
          f"{rep['overlapped_cycles']:.0f} cyc "
          f"({rep['time_us_overlapped']:.1f} us), "
          f"{rep['overlap_speedup']:.2f}x\n")

    # -- the schedule autotuner picks the grid split + placement ------------
    from repro.pim import search_schedule
    sr = search_schedule(x.shape[0], x.shape[1], w.shape[1], 4,
                         base=cfg, signed=True)
    print(sr.describe())
    print(sr.candidate_table())
    tuned = sr.cost.report()
    print(f"  autotuned: {tuned['overlapped_cycles']:.0f} overlapped cyc "
          f"vs default {rep['overlapped_cycles']:.0f} "
          f"({rep['overlapped_cycles'] / tuned['overlapped_cycles']:.2f}x)"
          "\n")

    # -- fused QKV: one FabricProgram, shared activation residency ----------
    from repro.pim import fabric_fused_matmul, residency_stats
    wq = rng.integers(-8, 8, (96, 32)).astype(np.int64)
    wk = rng.integers(-8, 8, (96, 32)).astype(np.int64)
    wv = rng.integers(-8, 8, (96, 32)).astype(np.int64)
    fused = fabric_fused_matmul(x, (wq, wk, wv), nbits=4, cfg=cfg,
                                signed=True, names=("q", "k", "v"))
    for out, wi in zip(fused.outs, (wq, wk, wv)):
        assert (out == x @ wi).all()
    print(fused.schedule.describe())
    st = residency_stats(fused.schedule)
    frep = fused.cost.report()
    print(f"  fused QKV: {st['fetches']} fetches for {st['reads']} tile "
          f"reads ({st['fetch_reduction']:.2f}x fewer than reload), "
          f"{frep['energy_wire_pj']:.0f} pJ wire\n")

    # -- attention scores: q @ k^T per (batch, head) ------------------------
    B, Sq, Sk, H, hd = 1, 8, 8, 2, 32
    q = rng.normal(size=(B, Sq, H, hd)).astype(np.float32)
    k = rng.normal(size=(B, Sk, H, hd)).astype(np.float32)
    scores, _, costs = fabric_attention_scores(q, k, cfg=cfg, bits=8)
    ref = np.einsum("bqhd,bchd->bqhc", q, k) * hd ** -0.5
    err = np.abs(scores - ref).max()
    total = combine_costs("attention_scores", costs)
    rep = total.report()
    print(f"attention scores {q.shape} x {k.shape} on "
          f"{cfg.n_blocks} blocks: max |err| {err:.4f} (int8 quant)")
    print(f"  {rep['rounds']} rounds, {rep['ops']} MACs, "
          f"{rep['energy_pj']:.0f} pJ, {rep['time_us']:.1f} us, "
          f"{rep['energy_per_op_pj']:.2f} pJ/MAC")


if __name__ == "__main__":
    main()
