"""Quickstart: program a Compute RAM block and run it (paper's Fig 2 flow).

1. storage mode: load operands (transposed bit-plane layout)
2. load an instruction sequence into the instruction memory
3. compute mode: the controller executes the sequence; every column
   computes in parallel
4. storage mode: read results back

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

import jax.numpy as jnp

from repro.core import costmodel, engine, harness, isa, programs


def main():
    rng = np.random.default_rng(0)

    # --- int8 addition on a 512x40 block -------------------------------
    prog, layout = programs.iadd(8, rows=512)
    print(f"program: {prog.name}")
    print(f"  instruction-memory footprint: {prog.footprint()} / "
          f"{isa.IMEM_SLOTS} slots")
    print(f"  cycles: {prog.cycles()} for {layout.tuples} adds/column "
          f"x 40 columns = {layout.tuples * 40} ops")

    a = rng.integers(0, 256, (layout.tuples, 40), dtype=np.uint64)
    b = rng.integers(0, 256, (layout.tuples, 40), dtype=np.uint64)

    arr = harness.pack_state(layout, {"a": a, "b": b}, cols=40)  # storage
    state = engine.CRState(jnp.asarray(arr), jnp.zeros((40,), bool),
                           jnp.ones((40,), bool))
    out = engine.execute_scan(prog, state)                       # compute
    d = harness.unpack_field(np.asarray(out.array), layout, "d")  # readback

    assert (d == (a + b) % 256).all()
    print(f"  all {layout.tuples * 40} results correct "
          f"(e.g. {a[0, 0]} + {b[0, 0]} = {d[0, 0]})")

    # --- adaptable precision: same block, new program -> bfloat16 -------
    prog16, lay16 = programs.bf16_mul(rows=512, tuples=2)
    fa = np.asarray([1.5, -2.25], np.float32)
    fb = np.asarray([3.0, 0.5], np.float32)
    bits_a = np.tile((fa.view(np.uint32) >> 16).astype(np.uint16)[:, None],
                     (1, 8))
    bits_b = np.tile((fb.view(np.uint32) >> 16).astype(np.uint16)[:, None],
                     (1, 8))
    arr = harness.pack_state(lay16, {"a": bits_a, "b": bits_b}, cols=8)
    st = engine.CRState(jnp.asarray(arr), jnp.zeros((8,), bool),
                        jnp.ones((8,), bool))
    out = engine.execute_scan(prog16, st)
    dd = harness.unpack_field(np.asarray(out.array), lay16, "d")
    vals = (dd.astype(np.uint32) << 16).view(np.float32)[:, 0]
    print(f"\nbfloat16 via new instruction sequence (no new hardware):")
    print(f"  {fa[0]} * {fb[0]} = {vals[0]},  {fa[1]} * {fb[1]} = {vals[1]}")

    # --- the paper's headline comparison --------------------------------
    print("\nbaseline FPGA vs Compute RAM (paper Fig 4, int8 add):")
    r = costmodel.compare("add", "int8")
    print(f"  energy: {r['energy_ratio']:.0%} of baseline")
    print(f"  time:   {r['time_ratio']:.0%} of baseline")
    print(f"  circuit frequency: +{r['freq_gain']:.0%}")


if __name__ == "__main__":
    main()
