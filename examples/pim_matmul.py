"""PIM matmul as a framework feature: store weights bit-plane packed
(storage mode), compute directly on the packed planes (compute mode).

Run:  PYTHONPATH=src python examples/pim_matmul.py
"""

import numpy as np

import jax
import jax.numpy as jnp

from repro.pim import PimConfig, linear_apply, linear_init, pack_linear


def main():
    d_in, d_out = 512, 256
    key = jax.random.PRNGKey(0)
    dense = linear_init(key, d_in, d_out, PimConfig())
    x = jax.random.normal(jax.random.PRNGKey(1), (16, d_in), jnp.bfloat16)

    y_ref = linear_apply(dense, x, PimConfig(mode="off"))
    print(f"dense bf16 weights: {d_in * d_out * 2:,} bytes in HBM")

    for bits in (8, 4):
        cfg = PimConfig(mode="pallas", weight_bits=bits)
        packed = pack_linear(dense, cfg)
        nbytes = packed["w_packed"].size * 4
        y = linear_apply(packed, x, cfg)
        err = float(jnp.mean(jnp.abs(
            y.astype(jnp.float32) - y_ref.astype(jnp.float32))))
        mag = float(jnp.mean(jnp.abs(y_ref.astype(jnp.float32))))
        print(f"W{bits}A8 bit-plane packed: {nbytes:,} bytes "
              f"({d_in * d_out * 2 / nbytes:.1f}x less traffic), "
              f"rel.err {err / mag:.4f}")

    # PIM-faithful popcount path == same math
    cfg = PimConfig(mode="popcount", weight_bits=4)
    packed = pack_linear(dense, cfg)
    y_pc = linear_apply(packed, x, cfg)
    cfg_ref = PimConfig(mode="ref", weight_bits=4)
    y_rf = linear_apply(packed, x, cfg_ref)
    diff = float(jnp.max(jnp.abs(y_pc.astype(jnp.float32)
                                 - y_rf.astype(jnp.float32))))
    print(f"popcount (AND/popcount bit-serial) vs ref path: "
          f"max diff {diff:.2e} (exact integer arithmetic)")

    # ... and the same arithmetic on the cycle-accurate Compute RAM
    # block simulator itself (idot programs, compiled executor)
    from repro.pim import cram_matmul
    rng = np.random.default_rng(0)
    xi = rng.integers(0, 16, (4, 24), dtype=np.uint64)
    wi = rng.integers(0, 16, (24, 40), dtype=np.uint64)
    yi = cram_matmul(xi, wi, n=4)
    assert (yi == xi @ wi).all()
    print(f"cram_matmul: {xi.shape} @ {wi.shape} int4 GEMM executed "
          f"cycle-accurately on simulated Compute RAM blocks -- exact")


if __name__ == "__main__":
    main()
