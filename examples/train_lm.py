"""End-to-end training driver example: train a small llama-family model
on synthetic data with checkpointing, kill it mid-run, and watch it
resume from the latest checkpoint.

Run:  PYTHONPATH=src python examples/train_lm.py           (~2 min, CPU)
      PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
"""

import argparse
import shutil

import jax

from repro.configs.base import ModelConfig
from repro.models.model import LM
from repro.train import data as data_mod
from repro.train import optimizer as opt_mod
from repro.train.runner import RunnerConfig, Trainer
from repro.train.step import jit_train_step

PRESETS = {
    # ~8M params: fast on CPU
    "tiny": ModelConfig(name="tiny-lm", family="dense", n_layers=4,
                        d_model=256, n_heads=8, n_kv_heads=4, d_ff=1024,
                        vocab=2048, tie_embeddings=True),
    # ~100M params: the paper-scale end-to-end target (use on real HW)
    "100m": ModelConfig(name="lm-100m", family="dense", n_layers=12,
                        d_model=768, n_heads=12, n_kv_heads=4, d_ff=3072,
                        vocab=32000, tie_embeddings=True),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=PRESETS)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_example_ckpt")
    ap.add_argument("--simulate-failure", action="store_true",
                    default=True)
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    model = LM(cfg)
    print(f"{cfg.name}: {cfg.param_count()/1e6:.1f}M params")
    shutil.rmtree(args.ckpt_dir, ignore_errors=True)

    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = opt_mod.OptConfig(lr=3e-3, warmup_steps=10,
                                total_steps=args.steps)
    opt_state = opt_mod.init(params, opt_cfg)
    pipe = data_mod.Pipeline(data_mod.DataConfig(
        global_batch=args.batch, seq_len=args.seq, vocab=cfg.vocab))
    step_fn = jit_train_step(model, opt_cfg, donate=False)

    # inject one simulated node failure at 60% of the run
    fail_at = int(args.steps * 0.6)
    armed = {"on": args.simulate_failure}

    def fail_hook(step):
        if step == fail_at and armed["on"]:
            armed["on"] = False
            raise RuntimeError("simulated node failure (example)")

    trainer = Trainer(
        RunnerConfig(total_steps=args.steps, ckpt_every=20,
                     ckpt_dir=args.ckpt_dir, log_every=10),
        step_fn, params, opt_state, pipe, fail_hook=fail_hook)
    end, metrics = trainer.run()
    print(f"done at step {end}; final loss {metrics['loss']:.4f}; "
          f"restarts={trainer.restarts}")


if __name__ == "__main__":
    main()
