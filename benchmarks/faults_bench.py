"""Fault-injection sweep: escape rates, repair overhead, graceful serve.

Writes ``BENCH_faults.json`` (ROADMAP "fault tolerance" -- JSON
artifact + CI gate, mirroring the engine/fabric/serve benches).  Three
legs, all seeded and therefore deterministic:

* **GEMM sweep** -- a signed int4 fabric GEMM replayed under bit-flip
  rates ``{0, 1e-5, 1e-4}`` (plus ``1e-3`` in full mode) x scrub
  {on, off}.  Escapes are counted the only way that matters: the
  fabric output is compared element-wise against the exact host
  ``x @ w`` in int64.  The hard gate is the paper-level claim of the
  fault stack: **zero escaped corruptions at rates <= 1e-4 with the
  parity scrub on**.  The scrub-off row of the same sweep must escape
  at the top rate -- proving the sweep actually injects and the gate
  is not vacuously green.
* **Repair** -- a dead block remapped to a spare (bit-exact, >= 1
  remap charged) and a dead block on a spare-less grid absorbed by the
  degraded-grid reschedule (bit-exact on fewer blocks).
* **Serve** -- the smoke LM served end to end with a fabric probe
  carrying a live fault model at the gated rate (1e-4, scrub on):
  every request must complete with its full token budget and zero
  escaped probe outputs -- graceful degradation never drops traffic.

A failing gate writes a ``BENCH_faults_repro.json`` repro artifact
(the exact sweep + failure list) via the shared ``bench_util`` abort
path; CI uploads it so the failure is preserved even though no real
artifact is written.

CLI: ``python benchmarks/faults_bench.py [--quick] [--json PATH]
[--gate]``.
"""

import argparse
import pathlib
import sys

import numpy as np

import jax

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
import bench_util  # noqa: E402

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.core.faults import FaultModel  # noqa: E402
from repro.pim import fabric  # noqa: E402

BENCH_JSON = "BENCH_faults.json"
REPRO_JSON = "BENCH_faults_repro.json"

#: the gate line from docs/faults.md: scrub-on serving must be clean
#: at (and below) this rate
GATED_RATE = 1e-4


def _grid(n_blocks=8, spare_blocks=0):
    return fabric.FabricConfig(n_blocks=n_blocks, rows=256, cols=32,
                               spare_blocks=spare_blocks)


def _gemm_cell(rate, scrub, repeats, rng_ops):
    """One sweep cell: ``repeats`` seeded fabric GEMMs at one
    (rate, scrub) point; escapes counted vs the int64 host oracle."""
    cell = {"rate": rate, "scrub": scrub, "runs": repeats,
            "injected_flips": 0, "detected": 0, "repaired": 0,
            "escaped_runs": 0, "escaped_elems": 0, "energy_pj": 0.0}
    for seed in range(repeats):
        x = rng_ops.integers(-8, 8, (8, 48)).astype(np.int64)
        w = rng_ops.integers(-8, 8, (48, 8)).astype(np.int64)
        fm = FaultModel(bit_rate=rate, scrub=scrub, seed=seed)
        res = fabric.fabric_matmul(x, w, nbits=4, signed=True,
                                   cfg=_grid(), faults=fm)
        wrong = int(np.sum(np.asarray(res.out, np.int64) != x @ w))
        cell["injected_flips"] += fm.injected_flips
        cell["detected"] += fm.detected
        cell["repaired"] += fm.repaired
        cell["escaped_elems"] += wrong
        cell["escaped_runs"] += int(wrong > 0)
        cell["energy_pj"] += float(res.cost.energy_pj)
    cell["energy_pj"] = round(cell["energy_pj"], 3)
    return cell


def _repair_leg(rng_ops):
    """Dead-block repair: spare remap + spare-less degraded reschedule."""
    x = rng_ops.integers(-8, 8, (8, 48)).astype(np.int64)
    w = rng_ops.integers(-8, 8, (48, 8)).astype(np.int64)
    out = {}
    fm = FaultModel(dead_blocks=(2,), seed=0)
    res = fabric.fabric_matmul(x, w, nbits=4, signed=True,
                               cfg=_grid(8, spare_blocks=2), faults=fm)
    out["spare"] = {"dead_blocks": [2], "spare_blocks": 2,
                    "remaps": fm.remaps,
                    "exact": bool(np.array_equal(
                        np.asarray(res.out, np.int64), x @ w))}
    fm2 = FaultModel(dead_blocks=(1, 3), seed=0)
    res2 = fabric.fabric_matmul(x, w, nbits=4, signed=True,
                                cfg=_grid(8), faults=fm2)
    out["degraded"] = {"dead_blocks": [1, 3], "spare_blocks": 0,
                       "alive_blocks": 6, "remaps": fm2.remaps,
                       "exact": bool(np.array_equal(
                           np.asarray(res2.out, np.int64), x @ w))}
    return out


def _serve_leg(quick):
    """Smoke-LM serving with a faulted fabric probe at the gated rate."""
    from repro import configs
    from repro.models.model import LM
    from repro.serve.engine import Request, ServeEngine

    cfg = configs.get_config("qwen2-0.5b", smoke=True)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    slots = 2
    n_req, max_new = (3, 3) if quick else (4, 6)
    fm = FaultModel(bit_rate=GATED_RATE, scrub=True, seed=0)
    probe = fabric.FabricLinearProbe(
        np.linspace(-1, 1, cfg.d_model * 16).reshape(cfg.d_model, 16)
        .astype(np.float32),
        cfg=_grid(4), bits=8, max_steps=n_req * max_new, faults=fm)
    eng = ServeEngine(model, params, batch_slots=slots, capacity=32,
                      fabric_probe=probe, probe_retries=2)
    rng = np.random.default_rng(0)
    for rid in range(n_req):
        eng.add(Request(
            rid=rid, prompt=rng.integers(0, cfg.vocab, 5).astype(np.int32),
            max_new=max_new))
    done = eng.run()
    rep = eng.fault_report()
    return {
        "rate": GATED_RATE,
        "requests": len(done),
        "expected_requests": n_req,
        "tokens": sum(len(r.out) for r in done),
        "expected_tokens": n_req * max_new,
        "probe_steps_observed": len(probe.costs),
        "probe_retries": rep["probe_retries"],
        "probe_fallbacks": rep["probe_fallbacks"],
        "escaped_outputs": rep["probe_escaped_outputs"],
        "injected_flips": fm.injected_flips,
        "repaired": fm.repaired,
    }


def run(print_fn=print, json_path=BENCH_JSON, quick=False):
    rates = [0.0, 1e-5, 1e-4] + ([] if quick else [1e-3])
    repeats = 2 if quick else 4
    rng_ops = np.random.default_rng(42)
    sweep = [_gemm_cell(rate, scrub, repeats, rng_ops)
             for rate in rates for scrub in (True, False)]
    for cell in sweep:
        print_fn(f"faults/gemm_sweep,rate={cell['rate']:g},"
                 f"scrub={int(cell['scrub'])};"
                 f"flips={cell['injected_flips']};"
                 f"repaired={cell['repaired']};"
                 f"escaped_runs={cell['escaped_runs']}")
    repair = _repair_leg(rng_ops)
    print_fn(f"faults/repair,spare_exact={int(repair['spare']['exact'])},"
             f"remaps={repair['spare']['remaps']};"
             f"degraded_exact={int(repair['degraded']['exact'])}")
    serve = _serve_leg(quick)
    print_fn(f"faults/serve,{serve['tokens']},tokens;"
             f"requests={serve['requests']};"
             f"retries={serve['probe_retries']};"
             f"fallbacks={serve['probe_fallbacks']};"
             f"escaped={serve['escaped_outputs']}")
    top_rate = max(rates)
    payload = {
        "quick": quick,
        "gated_rate": GATED_RATE,
        "rates": rates,
        "sweep": sweep,
        "repair": repair,
        "serve": serve,
        "escape_demo_rate": top_rate,
        "scrub_off_escaped": any(
            c["escaped_runs"] for c in sweep
            if not c["scrub"] and c["rate"] == top_rate),
    }
    if json_path:
        bench_util.atomic_write_json(json_path, payload, print_fn,
                                     tag="faults")
    return payload


def check_gates(payload: dict):
    """Failure strings for the fault-tolerance gates (docs/faults.md)."""
    bad = []
    for c in payload["sweep"]:
        if c["scrub"] and c["rate"] <= payload["gated_rate"] \
                and c["escaped_runs"]:
            bad.append(f"{c['escaped_runs']} run(s) escaped at rate "
                       f"{c['rate']:g} with scrub ON")
    if not payload["scrub_off_escaped"]:
        bad.append(f"scrub-off sweep never escaped at rate "
                   f"{payload['escape_demo_rate']:g} -- injection is "
                   f"not exercising the outputs")
    for leg in ("spare", "degraded"):
        if not payload["repair"][leg]["exact"]:
            bad.append(f"{leg} repair output is not bit-exact")
    if payload["repair"]["spare"]["remaps"] < 1:
        bad.append("spare repair charged no remaps")
    sv = payload["serve"]
    if sv["requests"] != sv["expected_requests"] \
            or sv["tokens"] != sv["expected_tokens"]:
        bad.append(f"serve dropped traffic: {sv['requests']}/"
                   f"{sv['expected_requests']} requests, {sv['tokens']}/"
                   f"{sv['expected_tokens']} tokens")
    if sv["escaped_outputs"]:
        bad.append(f"{sv['escaped_outputs']} serve probe output(s) "
                   f"escaped at the gated rate with scrub on")
    return bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="smaller sweep (CI tier-1)")
    ap.add_argument("--json", default=BENCH_JSON,
                    help=f"output path (default {BENCH_JSON})")
    ap.add_argument("--repro-json", default=REPRO_JSON,
                    help="repro artifact written on gate failure "
                    f"(default {REPRO_JSON})")
    ap.add_argument("--gate", action="store_true",
                    help="enforce the fault gates (exit 1 on failure)")
    args = ap.parse_args(argv)
    # gates run BEFORE the artifact exists (see bench_util)
    payload = run(json_path=None, quick=args.quick)
    bad = check_gates(payload) if args.gate else []
    if bench_util.gate_and_write(payload, bad, args.json, "faults",
                                 repro_path=args.repro_json):
        return 1
    if args.gate:
        print(f"zero escapes at rate <= {payload['gated_rate']:g} with "
              f"scrub on; repair bit-exact; serve complete: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
