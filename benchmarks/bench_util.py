"""Shared bench-main plumbing: atomic artifacts, gates before writes.

Two invariants every ``BENCH_*.json`` producer must keep (CI's artifact
validation step trusts them):

* **Artifacts are atomic.**  The JSON is written to a ``.tmp`` sibling
  and ``os.replace``d into place -- a crashed or killed bench can never
  leave a torn/partial artifact for CI to "validate".
* **Gates run before the artifact exists.**  A bench whose gate fails
  exits non-zero with a one-line ``BENCH ABORT`` reason and writes NO
  artifact (and never clobbers a previous good one), so a failing run
  cannot smuggle a green-looking artifact past the gate step.
"""
import json
import os
import pathlib


def atomic_write_json(path, payload: dict, print_fn=print,
                      tag: str = "bench") -> None:
    """Atomically write ``payload`` as JSON to ``path`` (tmp + rename)."""
    path = pathlib.Path(path)
    tmp = path.with_name(path.name + ".tmp")
    tmp.write_text(json.dumps(payload, indent=2))
    os.replace(tmp, path)
    print_fn(f"{tag}/bench_json,{path},written")


def gate_and_write(payload: dict, bad: list, json_path, tag: str,
                   print_fn=print, repro_path=None) -> int:
    """Shared bench-main epilogue: abort (no artifact) or write + pass.

    ``bad`` is the concatenated gate-failure list.  Non-empty: print a
    single ``BENCH ABORT`` line naming every failure and return 1
    WITHOUT touching the artifact.  Empty: atomically write the
    artifact and return 0.

    ``repro_path``: when given, an aborting run atomically writes the
    (gate-failing) payload plus the failure list THERE -- a repro
    artifact CI uploads on failure so the exact sweep that tripped the
    gate is preserved, while the real artifact path stays untouched.
    """
    if bad:
        print_fn(f"BENCH ABORT ({tag}): " + "; ".join(bad)
                 + " -- no artifact written")
        if repro_path is not None:
            atomic_write_json(repro_path,
                              {"gate_failures": bad, "payload": payload},
                              print_fn, tag=f"{tag}/repro")
        return 1
    atomic_write_json(json_path, payload, print_fn, tag=tag)
    return 0
