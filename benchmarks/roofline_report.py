"""Emit the EXPERIMENTS.md roofline table (markdown) from dry-run JSONs.

Usage: PYTHONPATH=src python -m benchmarks.roofline_report [results/dryrun]
"""

import glob
import json
import sys

from repro.launch import analysis


def rows(res_dir: str, mesh: str = "single"):
    out = []
    for f in sorted(glob.glob(f"{res_dir}/*__{mesh}.json")):
        d = json.load(open(f))
        if d["status"] != "ok":
            out.append((d["arch"], d["shape"], None, d))
            continue
        r = analysis.roofline(d["analytic_flops"], d["analytic_bytes"],
                              d["collective_bytes"], d["chips"])
        out.append((d["arch"], d["shape"], r, d))
    return out


def main():
    res = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    mesh = sys.argv[2] if len(sys.argv) > 2 else "single"
    print("| arch | shape | compute (ms) | memory (ms) | collective (ms) |"
          " dominant | MODEL/HLO flops | what moves the dominant term |")
    print("|---|---|---|---|---|---|---|---|")
    hints = {
        ("compute", "train"): "larger per-chip batch or lower-precision "
                              "matmuls (MXU int8) raise the roof",
        ("compute", "prefill"): "attention-window + chunk-size tuning; "
                                "PIM W4 weights don't help (compute-bound)",
        ("memory", "decode"): "quantized (PIM bit-plane) KV cache + weights"
                              " cut HBM bytes directly",
        ("collective", "train"): "two-stage (hierarchical) MoE dispatch; "
                                 "overlap via async collectives",
        ("collective", "prefill"): "expert-parallel all-to-all batching",
        ("memory", "train"): "remat policy / activation dtype",
        ("memory", "prefill"): "KV layout",
        ("memory", "long"): "state is tiny; already at the HBM floor",
    }
    for arch, shape, r, d in rows(res, mesh):
        if r is None:
            print(f"| {arch} | {shape} | -- | -- | -- | skipped |"
                  f" -- | {d.get('reason','')[:60]} |")
            continue
        kind = shape.split("_")[0]
        hint = hints.get((r["dominant"], kind), "")
        mf = d["model_flops_6nd"] / max(d["analytic_flops"], 1)
        print(f"| {arch} | {shape} | {r['t_compute_s']*1e3:.2f} |"
              f" {r['t_memory_s']*1e3:.2f} | {r['t_collective_s']*1e3:.2f} |"
              f" **{r['dominant']}** | {mf:.2f} | {hint} |")


if __name__ == "__main__":
    main()
