"""Benchmark harness: one section per paper table/figure.

Prints ``name,value,derived`` CSV lines.  Sections:
  table2  -- paper Table II (block properties)
  fig4/5/6 -- paper Figures 4-6 (add / mul / dot-product comparisons)
  engine  -- instruction-sequence cycle counts + footprints
  kernel  -- Pallas kernel micro-benchmarks
  app     -- application-level MLP projection (paper §VI future work)
  serve   -- serving-engine throughput (continuous batching)
  dryrun  -- roofline terms per dry-run cell (if results/ exists)
"""

import json
import pathlib


def main() -> None:
    from . import (app_projection, engine_bench, figures, kernel_bench,
                   serve_bench, table2_blocks)
    print("name,value,derived")
    table2_blocks.run()
    figures.run()
    engine_bench.run()
    kernel_bench.run()
    app_projection.run()
    serve_bench.run()

    res = pathlib.Path("results/dryrun")
    if res.exists():
        from repro.launch import analysis
        ok = skip = err = 0
        for f in sorted(res.glob("*.json")):
            d = json.loads(f.read_text())
            if d["status"] == "ok":
                ok += 1
                r = analysis.roofline(
                    max(d["hlo_flops"], d["analytic_flops"]),
                    max(d["hlo_bytes"], d["analytic_bytes"]),
                    d["collective_bytes"], d["chips"])
                print(f"dryrun/{f.stem},{r['roofline_s']*1e3:.2f},"
                      f"dominant={r['dominant']}"
                      f";compute_ms={r['t_compute_s']*1e3:.2f}"
                      f";memory_ms={r['t_memory_s']*1e3:.2f}"
                      f";collective_ms={r['t_collective_s']*1e3:.2f}")
            elif d["status"] == "skipped":
                skip += 1
            else:
                err += 1
        print(f"dryrun/summary,{ok},skipped={skip};errors={err}")


if __name__ == "__main__":
    main()
