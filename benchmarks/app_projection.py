"""Application-level projection (the paper's §VI future work: "evaluate
the performance boost at the application level (neural networks)").

Maps an MLP inference layer (int4 weights, int32 accumulate) onto a
fleet of Compute RAM blocks vs the baseline-FPGA dot-product design of
Fig 6, using the measured per-block cycle counts of our generated
sequences and the Table II-calibrated area/energy model.

An Agilex-class mid-range FPGA carries ~7,000 BRAM sites (all become
Compute RAMs per the paper's drop-in claim) but only ~4,500 DSPs; the
baseline dot-product engine consumes 5 DSPs + 8 LBs + 1 BRAM per
instance, the Compute RAM engine 1 block per instance -- the *compute
density* argument (GOPS/mm^2) is the paper's advantage #4.
"""

from repro.core import costmodel as cm

FPGA_BRAM_SITES = 7_000
FPGA_DSP_SITES = 4_500
FPGA_LB_SITES = 100_000


def run(print_fn=print):
    layer_macs = 784 * 512 + 512 * 512 + 512 * 10   # small MLP, per sample
    batch = 1024

    base = cm.BASELINES[("dot", "int4")].cost()
    cr40 = cm.ComputeRamDesign("dot", "int4", cols=40).cost()
    cr72 = cm.ComputeRamDesign("dot", "int4", cols=72).cost()

    for name, unit, sites in (
            ("baseline_dsp_engine", base,
             min(FPGA_DSP_SITES // 5, FPGA_BRAM_SITES, FPGA_LB_SITES // 12)),
            ("compute_ram_40col", cr40, FPGA_BRAM_SITES),
            ("compute_ram_72col", cr72, FPGA_BRAM_SITES)):
        total_macs = layer_macs * batch
        macs_per_pass = unit.ops
        passes = -(-total_macs // (macs_per_pass * sites))
        t_us = passes * unit.cycles / unit.freq_mhz
        e_uj = total_macs * unit.energy_per_op_pj / 1e6
        area_mm2 = sites * unit.area_um2 / 1e6
        gops = total_macs / t_us / 1e3
        print_fn(f"app/mlp_int4/{name},{t_us:.0f},"
                 f"us_for_{batch}_samples;engines={sites}"
                 f";energy_uJ={e_uj:.0f};GOPS={gops:.0f}"
                 f";GOPS_per_mm2={gops/area_mm2:.2f}")

    # headline: compute density ratio (paper advantage #4)
    d_base = (cm.BASELINES[('dot', 'int4')].cost().ops
              / cm.BASELINES[('dot', 'int4')].cost().cycles
              * cm.FREQ_CIRCUIT_BASE_FIXED_MHZ
              / cm.BASELINES[('dot', 'int4')].cost().area_um2)
    d_cr = (cr40.ops / cr40.cycles * cm.FREQ_CIRCUIT_CR_MHZ
            / cr40.area_um2)
    print_fn(f"app/compute_density_ratio,{d_cr/d_base:.2f},"
             f"GOPS_per_um2_CR_vs_baseline_engine")
