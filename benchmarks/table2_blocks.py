"""Table II: Compute RAM vs DSP vs BRAM vs LB (area/frequency/GOPS).

Areas/frequencies are model constants (COFFE/OpenRAM/DC outputs encoded
in costmodel.py); Compute RAM throughput is *computed from executing our
generated instruction sequences* -- the reproduction check is that it
lands on the paper's reported GOPS.
"""

from repro.core import costmodel as cm

PAPER = {
    "area": {"compute_ram": 11072.5, "dsp": 12433.0, "bram": 8311.0,
             "lb": 1938.0},
    "freq": {"compute_ram": 609.1, "dsp_fixed": 391.8, "dsp_float": 336.4,
             "bram": 922.9},
    "cr_gops": {"int4": 4.8, "int8": 2.7, "bf16": 0.3},
}


def run(print_fn=print):
    rows = []
    area = {"compute_ram": cm.AREA_CR_UM2, "dsp": cm.AREA_DSP_UM2,
            "bram": cm.AREA_BRAM_UM2, "lb": cm.AREA_LB_UM2}
    freq = {"compute_ram": cm.FREQ_CR_MHZ, "dsp_fixed": cm.FREQ_DSP_FIXED_MHZ,
            "dsp_float": cm.FREQ_DSP_FLOAT_MHZ, "bram": cm.FREQ_BRAM_MHZ}
    for k, v in area.items():
        rows.append(("table2/area_um2/" + k, v, PAPER["area"][k]))
    for k, v in freq.items():
        rows.append(("table2/freq_mhz/" + k, v, PAPER["freq"][k]))
    for prec in ("int4", "int8", "bf16"):
        ours = max(cm.cr_throughput_gops(op, prec) for op in ("add", "mul"))
        rows.append((f"table2/cr_gops/{prec}", ours,
                     PAPER["cr_gops"][prec]))
        rows.append((f"table2/dsp_gops/{prec}", cm.GOPS_DSP[prec],
                     cm.GOPS_DSP[prec]))
    for name, ours, paper in rows:
        print_fn(f"{name},{ours:.3f},paper={paper}")
    return rows
