"""Compute RAM engine benchmarks: cycle counts per op + executor
replay comparison (scan controller vs compiled fast path) + multi-block
scaling (one FPGA = hundreds of Compute RAM sites executing in
parallel), plus instruction-memory footprints (paper §III-A2).

Writes the executor numbers to ``BENCH_engine.json`` so regressions in
the compiled path show up as a diff, not just a log line.

CLI: ``python benchmarks/engine_bench.py [--quick] [--json PATH]
[--min-idot-speedup X] [--max-compile-s S] [--min-blocks-scaling X]``.
``--quick`` runs a reduced program set with fewer replays (CI tier-1
budget) but still covers the full 1/16/64 blocks sweep;
``--min-idot-speedup`` exits non-zero if any ``idot`` compiled-vs-scan
speedup falls below the floor, which is how CI fails loudly on executor
regressions (ROADMAP "benchmark hygiene"); ``--max-compile-s`` exits
non-zero if the float-program compile (bf16 add through the jaxpr-level
CSE pass) exceeds the ceiling -- the compile-time regression guard;
``--min-blocks-scaling`` exits non-zero when the 64-block packed-
resident replay stops scaling over the 1-block one (the multi-block
replay wall this sweep exists to catch).
"""

import argparse
import json
import pathlib
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
import bench_util  # noqa: E402

from repro.core import costmodel as cm, engine, harness, programs  # noqa: E402

BENCH_JSON = "BENCH_engine.json"


def _replay_pair(f1, f2, n=25):
    """Interleaved min-of-n for two functions (load-noise resistant)."""
    f1(), f2(), f1(), f2()
    b1 = b2 = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        f1()
        b1 = min(b1, time.perf_counter() - t0)
        t0 = time.perf_counter()
        f2()
        b2 = min(b2, time.perf_counter() - t0)
    return b1, b2


def bench_executors(print_fn=print, rows=512, cols=40, quick=False):
    """Replay scan vs compiled on the paper geometry; return results."""
    rng = np.random.default_rng(0)
    results = {}
    cases = [
        ("idot4", programs.idot(4, rows=rows)),
        ("idot8", programs.idot(8, rows=rows)),
        ("iadd8", programs.iadd(8, rows=rows)),
    ] if quick else [
        ("imul4", programs.imul(4, rows=rows)),
        ("imul8", programs.imul(8, rows=rows)),
        ("imul16", programs.imul(16, rows=rows)),
        ("idot4", programs.idot(4, rows=rows)),
        ("idot8", programs.idot(8, rows=rows)),
        ("idot16", programs.idot(16, rows=rows)),
        ("iadd8", programs.iadd(8, rows=rows)),
    ]
    for name, (prog, lay) in cases:
        a = rng.integers(0, 1 << lay.nbits, (lay.tuples, cols),
                         dtype=np.uint64)
        b = rng.integers(0, 1 << lay.nbits, (lay.tuples, cols),
                         dtype=np.uint64)
        state = harness.make_jax_state(
            harness.pack_state(lay, {"a": a, "b": b}, cols))

        scan_fn = jax.jit(lambda s, p=prog: engine.execute_scan(p, s))

        t0 = time.perf_counter()
        fn = engine.compile_program(prog, rows, cols)
        jax.block_until_ready(fn(state).array)
        t_compile = time.perf_counter() - t0

        t_scan, t_compiled = _replay_pair(
            lambda: jax.block_until_ready(scan_fn(state).array),
            lambda: jax.block_until_ready(fn(state).array),
            n=8 if quick else 25)

        speedup = t_scan / t_compiled
        results[name] = {
            "cycles": prog.cycles(),
            "scan_replay_ms": round(t_scan * 1e3, 4),
            "compiled_replay_ms": round(t_compiled * 1e3, 4),
            "compile_s": round(t_compile, 2),
            "speedup": round(speedup, 2),
        }
        print_fn(f"engine/executor_{name}/speedup,{speedup:.1f},"
                 f"scan_ms={t_scan*1e3:.2f};compiled_ms="
                 f"{t_compiled*1e3:.2f};compile_s={t_compile:.1f}")
    return results


def bench_blocks(print_fn=print, rows=512, cols=40, quick=False):
    """Multi-block fabric simulation (int4 dot product per block).

    The compiled replay is measured in its *packed-resident* form: the
    block batch is packed once (``engine.pack_block_states``), replayed
    as one wide uint32 launch per round, and unpacked once at the end --
    which is how replay loops (fabric rounds, :func:`engine.run_chain`)
    actually run the program.  Measuring the single-shot
    ``execute_blocks`` launch instead would time the per-launch bool
    pack/unpack ladder (recorded separately as ``launch_ms``), which is
    amortized over a replay loop and at 64 blocks costs ~3x the inner
    compute.  The vmapped scan controller is the baseline.
    ``--min-blocks-scaling`` gates blocks64/blocks1 throughput.
    """
    prog, lay = programs.idot(4, rows=rows)
    results = {}
    for blocks in (1, 16, 64):
        states = engine.CRState(
            array=jnp.zeros((blocks, rows, cols), jnp.bool_),
            carry=jnp.zeros((blocks, cols), jnp.bool_),
            tag=jnp.ones((blocks, cols), jnp.bool_),
        )
        f_scan = jax.jit(
            lambda s: engine.execute_blocks(prog, s, executor="scan"))
        wide = jax.block_until_ready(engine.pack_block_states(states))
        fn = engine.compile_packed(prog, rows, blocks * cols)
        jax.block_until_ready(fn(wide).array)               # compile
        jax.block_until_ready(
            engine.execute_blocks(prog, states).array)      # compile e2e
        t_scan, t_comp = _replay_pair(
            lambda: jax.block_until_ready(f_scan(states).array),
            lambda: jax.block_until_ready(fn(wide).array),
            n=4 if quick else 8)
        t_launch = float("inf")                  # single-shot, with ladder
        for _ in range(3):
            t0 = time.perf_counter()
            jax.block_until_ready(engine.execute_blocks(prog, states).array)
            t_launch = min(t_launch, time.perf_counter() - t0)
        ops_total = lay.tuples * cols * blocks   # int4 MACs simulated
        results[f"blocks{blocks}"] = {
            "scan_replay_ms": round(t_scan * 1e3, 4),
            "compiled_replay_ms": round(t_comp * 1e3, 4),
            "launch_ms": round(t_launch * 1e3, 4),
            "speedup": round(t_scan / t_comp, 2),
            "sim_mops_compiled": round(ops_total / (t_comp * 1e6), 1),
        }
        print_fn(f"engine/multiblock_idot4/{blocks}blk,"
                 f"{t_comp*1e6:.0f},ops={ops_total};"
                 f"sim_mops={ops_total/(t_comp*1e6):.1f};"
                 f"speedup_vs_scan={t_scan/t_comp:.1f};"
                 f"launch_ms={t_launch*1e3:.2f}")
    scaling = (results["blocks64"]["sim_mops_compiled"]
               / results["blocks1"]["sim_mops_compiled"])
    results["scaling_64v1"] = round(scaling, 2)
    print_fn(f"engine/multiblock_idot4/scaling_64v1,{scaling:.2f},"
             f"resident_replay")
    return results


def bench_float_compile(print_fn=print, quick=False):
    """Compile-time regression guard for float programs.

    Times one cold ``compile_program`` of the bf16 adder (the heaviest
    flat-lowered program family, ~5-10 s each on a fast host) with the
    jaxpr-level CSE pass forced on, and records the pass's equation
    counts.  ``--max-compile-s`` gates on the seconds.
    """
    rows = 256 if quick else 512
    prog, lay = programs.bf16_add(rows=rows)
    engine.clear_compile_cache()              # force a cold compile
    state = harness.make_jax_state(np.zeros((rows, 40), bool))
    t0 = time.perf_counter()
    fn = engine.compile_program(prog, rows, 40, cse=True)
    jax.block_until_ready(fn(state).array)
    t_compile = time.perf_counter() - t0
    stats = engine.last_cse_stats or {}
    print_fn(f"engine/float_compile_bf16add/s,{t_compile:.2f},"
             f"rows={rows};cycles={prog.cycles()};"
             f"cse_removed={stats.get('removed', 0)}")
    return {
        "program": f"bf16_add@{rows}", "cycles": prog.cycles(),
        "compile_s": round(t_compile, 2),
        "cse_eqns_before": stats.get("eqns_before", 0),
        "cse_eqns_after": stats.get("eqns_after", 0),
        "cse_removed": stats.get("removed", 0),
    }


def bench_float_dot(print_fn=print, quick=False):
    """Scan-vs-compiled replay + compile time for the bf16 fused MAC.

    The float tuple loops now get a lane plan (complementary-predication
    coverage) and the copy/fill-run batcher, so the compiled path must
    beat the scan controller -- ``--min-fdot-speedup`` gates the ratio
    and ``--max-compile-s`` covers this compile alongside the bf16-add
    one.  ``lane_plan``/``serial_start`` are recorded so a silent fall
    back to flat lowering shows up in the artifact.
    """
    from repro.core import compiler, floatprog

    rows, cols = 512, 40
    tuples = 2 if quick else None
    prog, lay = floatprog.float_dot(floatprog.BF16, rows=rows,
                                    tuples=tuples)
    plan = compiler.analyze(prog)
    rng = np.random.default_rng(0)

    def bits(shape):
        s = rng.integers(0, 2, shape).astype(np.uint64)
        e = rng.integers(100, 150, shape).astype(np.uint64)
        m = rng.integers(0, 128, shape).astype(np.uint64)
        return (s << 15) | (e << 7) | m

    state = harness.make_jax_state(harness.pack_state(
        lay, {"a": bits((lay.tuples, cols)), "b": bits((lay.tuples, cols))},
        cols))
    engine.clear_compile_cache()              # force a cold compile
    t0 = time.perf_counter()
    fn = engine.compile_program(prog, rows, cols)
    jax.block_until_ready(fn(state).array)
    t_compile = time.perf_counter() - t0
    scan_fn = jax.jit(lambda s, p=prog: engine.execute_scan(p, s))
    jax.block_until_ready(scan_fn(state).array)
    t_scan, t_comp = _replay_pair(
        lambda: jax.block_until_ready(scan_fn(state).array),
        lambda: jax.block_until_ready(fn(state).array),
        n=5 if quick else 15)
    speedup = t_scan / t_comp
    print_fn(f"engine/float_dot_bf16/speedup,{speedup:.2f},"
             f"tuples={lay.tuples};scan_ms={t_scan*1e3:.2f};"
             f"compiled_ms={t_comp*1e3:.2f};compile_s={t_compile:.1f};"
             f"serial_start={plan.serial_start if plan else -1}")
    return {
        "program": f"bf16_dot@{rows}x{lay.tuples}",
        "cycles": prog.cycles(),
        "compile_s": round(t_compile, 2),
        "scan_replay_ms": round(t_scan * 1e3, 4),
        "compiled_replay_ms": round(t_comp * 1e3, 4),
        "speedup": round(speedup, 2),
        "lane_plan": plan is not None,
        "serial_start": plan.serial_start if plan else -1,
        "body_len": len(plan.body) if plan else 0,
    }


def run(print_fn=print, json_path=BENCH_JSON, quick=False):
    if not quick:
        for (op, prec), gen in programs.GENERATORS.items():
            prog, lay = gen(rows=512)
            cyc = prog.cycles()
            per_op = cyc / lay.tuples
            us = cyc / cm.FREQ_CR_MHZ
            print_fn(f"engine/{op}_{prec}/cycles,{cyc},"
                     f"per_op={per_op:.1f};imem_slots={prog.footprint()}"
                     f";time_us={us:.2f}@{cm.FREQ_CR_MHZ:.0f}MHz")

    payload = {
        "geometry": {"rows": 512, "cols": 40},
        "quick": quick,
        "executors": bench_executors(print_fn, quick=quick),
        "blocks": bench_blocks(print_fn, quick=quick),
        "float_compile": bench_float_compile(print_fn, quick=quick),
        "float_dot": bench_float_dot(print_fn, quick=quick),
    }
    if json_path:
        bench_util.atomic_write_json(json_path, payload, print_fn,
                                     tag="engine")
    return payload


def check_idot_speedup(payload: dict, floor: float) -> list:
    """Return the idot entries whose compiled-vs-scan speedup < floor."""
    return [f"{k}: {v['speedup']:.2f}x < {floor}x"
            for k, v in sorted(payload["executors"].items())
            if k.startswith("idot") and v["speedup"] < floor]


def check_compile_time(payload: dict, ceiling: float) -> list:
    """Return failure strings when a float compile exceeds the cap.

    Covers both the bf16 adder (``float_compile``) and the fused MAC
    (``float_dot``).  A payload with no measurement is a FAILURE, not a
    pass -- the gate must not silently disarm if the bench stops
    measuring."""
    bad = []
    for section in ("float_compile", "float_dot"):
        fc = payload.get(section, {})
        s = fc.get("compile_s")
        if s is None:
            bad.append(f"{section}/compile_s missing from payload "
                       "(gate has nothing to check)")
        elif s > ceiling:
            bad.append(f"{fc.get('program', section)}: "
                       f"compile {s:.1f}s > {ceiling}s")
    return bad


def check_blocks_scaling(payload: dict, floor: float) -> list:
    """Fail when 64-block packed-resident throughput doesn't scale.

    The whole point of the wide-block lowering is that B blocks cost one
    launch, so simulated MACs/s must GROW with the block count; this
    gate pins blocks64/blocks1 >= ``floor``.  A payload missing either
    endpoint is a FAILURE (the gate must not silently disarm)."""
    bl = payload.get("blocks", {})
    lo = bl.get("blocks1", {}).get("sim_mops_compiled")
    hi = bl.get("blocks64", {}).get("sim_mops_compiled")
    if not lo or hi is None:
        return ["blocks sweep missing blocks1/blocks64 sim_mops_compiled "
                "(gate has nothing to check)"]
    if hi / lo < floor:
        return [f"blocks scaling: {hi / lo:.2f}x < {floor}x "
                f"(blocks64 {hi} vs blocks1 {lo} sim_mops)"]
    return []


def check_fdot_speedup(payload: dict, floor: float) -> list:
    """Fail when the compiled fused-MAC replay drops below the floor or
    the lane plan silently fell back to flat lowering."""
    fd = payload.get("float_dot", {})
    s = fd.get("speedup")
    if s is None:
        return ["float_dot/speedup missing from payload "
                "(gate has nothing to check)"]
    bad = []
    if s < floor:
        bad.append(f"float_dot: {s:.2f}x < {floor}x")
    if not fd.get("lane_plan", False):
        bad.append("float_dot: lane analysis fell back to flat lowering")
    return bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="reduced program set + fewer replays (CI tier-1)")
    ap.add_argument("--json", default=BENCH_JSON,
                    help=f"output path (default {BENCH_JSON})")
    ap.add_argument("--min-idot-speedup", type=float, default=None,
                    metavar="X",
                    help="fail (exit 1) if any idot compiled-vs-scan "
                    "speedup drops below X")
    ap.add_argument("--min-fdot-speedup", type=float, default=None,
                    metavar="X",
                    help="fail (exit 1) if the bf16 float_dot compiled-"
                    "vs-scan speedup drops below X (or the lane plan "
                    "falls back to flat lowering)")
    ap.add_argument("--max-compile-s", type=float, default=None,
                    metavar="S",
                    help="fail (exit 1) if a float-program compile "
                    "(bf16 add or bf16 dot) takes longer than S seconds")
    ap.add_argument("--min-blocks-scaling", type=float, default=None,
                    metavar="X",
                    help="fail (exit 1) if blocks64/blocks1 packed-"
                    "resident throughput (sim_mops_compiled) is below X")
    args = ap.parse_args(argv)
    # gates run BEFORE the artifact exists: a failing gate exits 1 with
    # one line and writes nothing for CI to "validate"
    payload = run(json_path=None, quick=args.quick)
    bad = []
    if args.min_idot_speedup is not None:
        bad += check_idot_speedup(payload, args.min_idot_speedup)
    if args.min_fdot_speedup is not None:
        bad += check_fdot_speedup(payload, args.min_fdot_speedup)
    if args.max_compile_s is not None:
        bad += check_compile_time(payload, args.max_compile_s)
    if args.min_blocks_scaling is not None:
        bad += check_blocks_scaling(payload, args.min_blocks_scaling)
    if bench_util.gate_and_write(payload, bad, args.json, "engine"):
        return 1
    if args.min_idot_speedup is not None:
        print(f"idot speedups >= {args.min_idot_speedup}x: OK")
    if args.min_fdot_speedup is not None:
        print(f"float_dot speedup >= {args.min_fdot_speedup}x: OK")
    if args.max_compile_s is not None:
        print(f"float compiles <= {args.max_compile_s}s: OK")
    if args.min_blocks_scaling is not None:
        print(f"blocks scaling >= {args.min_blocks_scaling}x: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
