"""Compute RAM engine benchmarks: cycle counts per op + multi-block
scaling (one FPGA = hundreds of Compute RAM sites executing in
parallel), plus instruction-memory footprints (paper §III-A2)."""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costmodel as cm, engine, programs


def run(print_fn=print):
    for (op, prec), gen in programs.GENERATORS.items():
        prog, lay = gen(rows=512)
        cyc = prog.cycles()
        per_op = cyc / lay.tuples
        us = cyc / cm.FREQ_CR_MHZ
        print_fn(f"engine/{op}_{prec}/cycles,{cyc},"
                 f"per_op={per_op:.1f};imem_slots={prog.footprint()}"
                 f";time_us={us:.2f}@{cm.FREQ_CR_MHZ:.0f}MHz")

    # multi-block vmap scaling (simulation throughput, informational)
    prog, lay = programs.iadd(8, rows=512)
    for blocks in (1, 16, 64):
        states = engine.CRState(
            array=jnp.zeros((blocks, 512, 40), jnp.bool_),
            carry=jnp.zeros((blocks, 40), jnp.bool_),
            tag=jnp.ones((blocks, 40), jnp.bool_),
        )
        f = jax.jit(lambda s: engine.execute_blocks(prog, s))
        jax.block_until_ready(f(states).array)
        t0 = time.perf_counter()
        jax.block_until_ready(f(states).array)
        us = (time.perf_counter() - t0) * 1e6
        ops_total = lay.tuples * 40 * blocks
        print_fn(f"engine/multiblock_iadd8/{blocks}blk,{us:.0f},"
                 f"ops={ops_total};sim_mops={ops_total/us:.1f}")
