"""Constrained-random microcode fuzzing CLI (differential replay).

Drives :mod:`repro.core.fuzz`: generates seeded random-but-valid CR
programs and differentially replays each across the full executor x
packing matrix (unroll oracle vs scan / compiled x {packed False, True,
None} / ragged execute_blocks / two-program run_chain).  On a mismatch
the repro is delta-debug shrunk and written to the corpus directory,
and the process exits non-zero printing the exact reproduce command.

Modes::

    # bounded CI budget: N programs, fail loudly on any mismatch
    PYTHONPATH=src python benchmarks/fuzz_run.py --budget 200 --seed 0

    # unbounded soak (nightly): run until wall clock expires
    PYTHONPATH=src python benchmarks/fuzz_run.py --soak --max-minutes 20

    # replay one corpus file (regression / triage)
    PYTHONPATH=src python benchmarks/fuzz_run.py --replay tests/corpus/fuzz_X.txt

    # demonstrate the shrinking pipeline against a known-bad mutation
    PYTHONPATH=src python benchmarks/fuzz_run.py --force-bug fa-flip --budget 50

    # force the fault-escape bug: disable the parity scrub so injected
    # bit flips reach the outputs and the "faults" variant mismatches
    PYTHONPATH=src python benchmarks/fuzz_run.py --no-fault-scrub --budget 5

Seed discipline: ``--seed N --budget B`` fuzzes seeds ``N..N+B-1``; the
soak derives its base seed from the clock and prints it, so any soak
finding is reproducible from the log line alone.
"""
import argparse
import json
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.core import fuzz  # noqa: E402

DEFAULT_CORPUS = pathlib.Path(__file__).resolve().parents[1] / "tests" / "corpus"
BENCH_JSON = "BENCH_fuzz.json"


def _fail_banner(stats: dict) -> None:
    rep = stats["mismatch"]
    print("=" * 72)
    print(f"FUZZ MISMATCH at seed {rep.fp.seed} "
          f"(shrunk to {stats['shrunk_ops']} micro-ops):")
    for m in rep.mismatches:
        print(f"  {m.variant} / {m.field}: {m.detail}")
    if stats["repro_path"]:
        print(f"repro written: {stats['repro_path']}")
        print("reproduce with:")
        print(f"  PYTHONPATH=src python benchmarks/fuzz_run.py "
              f"--replay {stats['repro_path']}")
    print(f"or regenerate the unshrunk scenario:")
    print(f"  PYTHONPATH=src python benchmarks/fuzz_run.py "
          f"--seed {rep.fp.seed} --budget 1")
    print("=" * 72)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--budget", type=int, default=200, metavar="N",
                    help="number of programs to fuzz (default 200)")
    ap.add_argument("--seed", type=int, default=0,
                    help="base seed; seeds seed..seed+budget-1 are fuzzed")
    ap.add_argument("--soak", action="store_true",
                    help="unbounded mode: ignore --budget, run until "
                    "--max-minutes expires (base seed from the clock)")
    ap.add_argument("--max-minutes", type=float, default=20.0,
                    help="wall-clock cap for --soak (default 20)")
    ap.add_argument("--replay", metavar="FILE", default=None,
                    help="replay one corpus file instead of fuzzing")
    ap.add_argument("--corpus", default=str(DEFAULT_CORPUS), metavar="DIR",
                    help=f"directory for shrunken repros "
                    f"(default {DEFAULT_CORPUS})")
    ap.add_argument("--force-bug", choices=sorted(fuzz.MUTATIONS),
                    default=None, metavar="NAME",
                    help="apply a known-bad mutation to one replay leg "
                    "(tests the mismatch->shrink->corpus pipeline; "
                    f"choices: {', '.join(sorted(fuzz.MUTATIONS))})")
    ap.add_argument("--rows", type=int, default=48)
    ap.add_argument("--cols", type=int, default=8)
    ap.add_argument("--max-ops", type=int, default=320)
    ap.add_argument("--fault-rate", type=float,
                    default=fuzz.FuzzConfig().fault_rate,
                    help="per-bit flip rate of the 'faults' replay "
                    "variant (0 disables injection)")
    ap.add_argument("--no-fault-scrub", action="store_true",
                    help="disable the parity scrub in the 'faults' "
                    "variant: injected flips escape into outputs, the "
                    "mismatch is shrunk and written to the corpus")
    ap.add_argument("--no-shrink", action="store_true",
                    help="skip delta-debugging on mismatch (fast triage)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help=f"also write campaign stats JSON (e.g. "
                    f"{BENCH_JSON})")
    args = ap.parse_args(argv)

    cfg = fuzz.FuzzConfig(rows=args.rows, cols=args.cols,
                          max_ops=args.max_ops,
                          fault_rate=args.fault_rate,
                          fault_scrub=not args.no_fault_scrub)
    mutate = fuzz.MUTATIONS[args.force_bug] if args.force_bug else None

    # -- replay mode --------------------------------------------------------
    if args.replay:
        fp, pins = fuzz.load_corpus(args.replay)
        print(f"replaying {args.replay}: {fp.describe()}")
        for k, v in pins.items():
            got = getattr(fp.program, k)()
            if got != v:
                print(f"FUZZ REPLAY: {k} drifted: recorded {v}, now {got}")
                return 1
        rep = fuzz.replay(fp, mutate=mutate)
        if rep.ok:
            print(f"replay OK: bit-identical across {len(rep.variants)} "
                  f"variants ({rep.cycles} cycles)")
            return 0
        print("FUZZ REPLAY MISMATCH:")
        for m in rep.mismatches:
            print(f"  {m.variant} / {m.field}: {m.detail}")
        return 1

    # -- budget / soak mode -------------------------------------------------
    if args.soak:
        base_seed = int(time.time()) % 1_000_000_000
        budget = 10 ** 9                      # wall clock is the bound
        max_minutes = args.max_minutes
        print(f"soak: base seed {base_seed}, max {max_minutes} min "
              f"(reproduce any finding with --seed <seed> --budget 1)")
    else:
        base_seed, budget, max_minutes = args.seed, args.budget, None

    stats = fuzz.run_budget(
        budget, seed=base_seed, cfg=cfg, mutate=mutate,
        corpus_dir=args.corpus, do_shrink=not args.no_shrink,
        max_minutes=max_minutes, log=print)

    print(f"fuzz: {stats['programs']} programs, {stats['ops']} micro-ops "
          f"replayed across {len(fuzz.VARIANTS)} variants in "
          f"{stats['seconds']:.0f}s; sequence mix {stats['seq_histogram']}")
    if args.json:
        payload = {k: v for k, v in stats.items() if k != "mismatch"}
        payload["clean"] = stats["mismatch"] is None
        payload["base_seed"] = base_seed
        tmp = pathlib.Path(args.json + ".tmp")
        tmp.write_text(json.dumps(payload, indent=2))
        tmp.replace(args.json)
    if stats["mismatch"] is not None:
        _fail_banner(stats)
        return 1
    print("fuzz: all programs bit-identical across the replay matrix")
    return 0


if __name__ == "__main__":
    sys.exit(main())
