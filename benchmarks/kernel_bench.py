"""Pallas kernel micro-benchmarks (wall time is CPU-interpret, so the
derived columns carry the architectural quantities: packed-weight HBM
traffic reduction and arithmetic intensity).

Writes ``BENCH_kernels.json`` (ROADMAP "benchmark hygiene" -- JSON
artifact + CI floor, mirroring ``engine_bench.py`` / ``fabric_bench.py``):
per-precision quant-matmul interpret times with the packed-vs-bf16
weight-traffic reduction, the popcount kernel's arithmetic intensity,
and the flash-attention working set.  The traffic reduction is exact
arithmetic (``16 / bits``), so ``--min-traffic-reduction X`` is a
deterministic CI gate on the packed-storage claim -- it fails loudly if
a layout change silently grows the weight bytes the serving path moves.

CLI: ``python benchmarks/kernel_bench.py [--quick] [--json PATH]
[--min-traffic-reduction X]``.
"""

import argparse
import json
import pathlib
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
import bench_util  # noqa: E402

from repro.kernels import ops, ref  # noqa: E402

BENCH_JSON = "BENCH_kernels.json"


def _time(fn, *args, iters=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run(print_fn=print, json_path=BENCH_JSON, quick=False):
    rng = np.random.default_rng(0)
    m, k, n = (64, 512, 256) if quick else (128, 1024, 512)
    iters = 2 if quick else 3
    a = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
    w = jnp.asarray(rng.integers(-8, 8, (k, n)), jnp.int8)
    scale = jnp.ones((n,), jnp.float32)

    payload = {"quick": quick, "shape": f"{m}x{k}x{n}",
               "quant_matmul": {}}
    for bits in (4, 8):
        wp = ref.pack_bitplanes(w, bits, axis=0)
        us = _time(lambda: ops.quant_matmul(a, wp, scale, bits=bits,
                                            interpret=True), iters=iters)
        dense_bytes = k * n * 2                       # bf16 weights
        # measured from the ACTUAL packed array, not the closed-form
        # `bits * (k // 32) * n * 4`: a layout change that pads planes
        # or stores extra words shows up here and trips the CI gate
        packed_bytes = int(wp.size) * wp.dtype.itemsize
        reduction = dense_bytes / packed_bytes
        payload["quant_matmul"][f"w{bits}"] = {
            "interp_us": round(us),
            "hbm_weight_bytes": packed_bytes,
            "bf16_bytes": dense_bytes,
            "traffic_reduction": round(reduction, 3),
        }
        print_fn(f"kernel/quant_matmul_w{bits}/interp,{us:.0f},"
                 f"hbm_weight_bytes={packed_bytes}"
                 f";bf16_bytes={dense_bytes}"
                 f";traffic_reduction={reduction:.2f}x")

    ap = ref.pack_bitplanes(a, 8, axis=1)
    wp4 = ref.pack_bitplanes(w, 4, axis=0)
    us = _time(lambda: ops.popcount_matmul(
        ap, wp4, interpret=True, block_m=32, block_n=128,
        block_k=min(k, 256)), iters=iters)
    ai = (2.0 * m * k * n * 32) / ((m * k + k * n) * 4 / 8 * 32)
    payload["popcount"] = {"interp_us": round(us), "plane_pairs": 8 * 4,
                           "arith_intensity": round(ai)}
    print_fn(f"kernel/popcount_matmul_a8w4/interp,{us:.0f},"
             f"plane_pairs={8*4};arith_intensity~{ai:.0f}")

    # dense reference for scale
    af = a.astype(jnp.bfloat16)
    wf = w.astype(jnp.bfloat16)
    us = _time(lambda: af @ wf, iters=iters)
    payload["dense_bf16"] = {"us": round(us)}
    print_fn(f"kernel/dense_bf16_matmul,{us:.0f},reference")

    # flash attention kernel (interpret mode)
    from repro.kernels.flash_attention import flash_attention
    bh, s_, hd = (2, 128, 64) if quick else (4, 256, 64)
    q = jnp.asarray(rng.normal(0, 1, (bh, s_, hd)), jnp.float32)
    kk = jnp.asarray(rng.normal(0, 1, (bh, s_, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (bh, s_, hd)), jnp.float32)
    us = _time(lambda: flash_attention(q, kk, v, interpret=True,
                                       block_q=128, block_k=128),
               iters=iters)
    vmem = (128 * hd * 3 + 128 * 128 + 128 * (hd + 2)) * 4
    payload["flash_attention"] = {
        "interp_us": round(us), "shape": f"{bh}x{s_}x{hd}",
        "vmem_working_set_bytes": vmem,
    }
    print_fn(f"kernel/flash_attention_{s_},{us:.0f},"
             f"vmem_working_set_bytes={vmem};never_materializes_SxS")

    if json_path:
        bench_util.atomic_write_json(json_path, payload, print_fn,
                                     tag="kernel")
    return payload


def check_traffic_reduction(payload: dict, floor: float):
    """Failure strings when any packed path misses the traffic floor.

    ``floor`` is expressed for the int4 path (ideal 4x vs bf16); wider
    precisions gate at the precision-scaled equivalent (w8 ideal is 2x,
    so its floor is ``floor / 2``) -- one flag covers every packed
    layout without under-gating the headline w4 claim.
    """
    bad = []
    for name, rec in payload["quant_matmul"].items():
        bits = int(name.lstrip("w"))
        required = floor * 4 / bits
        r = rec["traffic_reduction"]
        if r < required:
            bad.append(f"quant_matmul/{name}: {r:.2f}x < {required:.2f}x")
    return bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="smaller shapes + fewer replays (CI tier-1)")
    ap.add_argument("--json", default=BENCH_JSON,
                    help=f"output path (default {BENCH_JSON})")
    ap.add_argument("--min-traffic-reduction", type=float, default=None,
                    metavar="X",
                    help="fail (exit 1) if the packed-weight HBM traffic "
                    "reduction (vs bf16) drops below X for any precision")
    args = ap.parse_args(argv)
    # gates run BEFORE the artifact exists (see bench_util)
    payload = run(json_path=None, quick=args.quick)
    bad = []
    if args.min_traffic_reduction is not None:
        bad = check_traffic_reduction(payload, args.min_traffic_reduction)
    if bench_util.gate_and_write(payload, bad, args.json, "kernel"):
        return 1
    if args.min_traffic_reduction is not None:
        print(f"packed-weight traffic reduction >= "
              f"{args.min_traffic_reduction}x: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
