"""Pallas kernel micro-benchmarks (wall time is CPU-interpret, so the
derived column carries the architectural quantities: packed-weight HBM
traffic reduction and arithmetic intensity)."""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, iters=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run(print_fn=print):
    rng = np.random.default_rng(0)
    m, k, n = 128, 1024, 512
    a = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
    w = jnp.asarray(rng.integers(-8, 8, (k, n)), jnp.int8)
    scale = jnp.ones((n,), jnp.float32)

    for bits in (4, 8):
        wp = ref.pack_bitplanes(w, bits, axis=0)
        us = _time(lambda: ops.quant_matmul(a, wp, scale, bits=bits,
                                            interpret=True))
        dense_bytes = k * n * 2                       # bf16 weights
        packed_bytes = bits * (k // 32) * n * 4       # uint32 planes
        print_fn(f"kernel/quant_matmul_w{bits}/interp,{us:.0f},"
                 f"hbm_weight_bytes={packed_bytes}"
                 f";bf16_bytes={dense_bytes}"
                 f";traffic_reduction={dense_bytes/packed_bytes:.2f}x")

    ap = ref.pack_bitplanes(a, 8, axis=1)
    wp4 = ref.pack_bitplanes(w, 4, axis=0)
    us = _time(lambda: ops.popcount_matmul(
        ap, wp4, interpret=True, block_m=32, block_n=128, block_k=256))
    ai = (2.0 * m * k * n * 32) / ((m * k + k * n) * 4 / 8 * 32)
    print_fn(f"kernel/popcount_matmul_a8w4/interp,{us:.0f},"
             f"plane_pairs={8*4};arith_intensity~{ai:.0f}")

    # dense reference for scale
    af = a.astype(jnp.bfloat16)
    wf = w.astype(jnp.bfloat16)
    us = _time(lambda: af @ wf)
    print_fn(f"kernel/dense_bf16_matmul,{us:.0f},reference")

    # flash attention kernel (interpret mode)
    from repro.kernels.flash_attention import flash_attention
    bh, s_, hd = 4, 256, 64
    q = jnp.asarray(rng.normal(0, 1, (bh, s_, hd)), jnp.float32)
    kk = jnp.asarray(rng.normal(0, 1, (bh, s_, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (bh, s_, hd)), jnp.float32)
    us = _time(lambda: flash_attention(q, kk, v, interpret=True,
                                       block_q=128, block_k=128))
    vmem = (128 * hd * 3 + 128 * 128 + 128 * (hd + 2)) * 4
    print_fn(f"kernel/flash_attention_256,{us:.0f},"
             f"vmem_working_set_bytes={vmem};never_materializes_SxS")
