"""Figures 4/5/6: baseline FPGA vs Compute RAM for add / mul / dot.

Reports area / energy / time ratios (CR / baseline) per precision,
mirroring the paper's bar charts, plus the paper's qualitative claims as
pass/fail annotations.  Fig 6 adds the 72-column wide-geometry variant
and a "paper-cycles" row that plugs the paper's own reported cycle
counts (1470 CR / 480 baseline) into our energy/area model -- isolating
sequence-level optimization differences from the architecture model.
"""

from repro.core import costmodel as cm

PAPER_DOT_CYCLES = {"cr_40col": 1470.0, "baseline": 480.0}


def _emit(print_fn, tag, r):
    b, c = r["baseline"], r["compute_ram"]
    print_fn(f"{tag}/area_ratio,{r['area_ratio']:.3f},baseline_um2="
             f"{b.area_um2:.0f};cr_um2={c.area_um2:.0f}")
    print_fn(f"{tag}/energy_ratio,{r['energy_ratio']:.3f},baseline_pj_op="
             f"{b.energy_per_op_pj:.2f};cr_pj_op={c.energy_per_op_pj:.2f}")
    print_fn(f"{tag}/time_ratio,{r['time_ratio']:.3f},baseline_ns_op="
             f"{b.time_per_op_ns:.3f};cr_ns_op={c.time_per_op_ns:.3f}")
    print_fn(f"{tag}/freq_gain,{r['freq_gain']:.3f},paper=0.60-0.65")


def fig4_addition(print_fn=print):
    for prec in ("int4", "int8", "bf16"):
        r = cm.compare("add", prec)
        _emit(print_fn, f"fig4/add/{prec}", r)


def fig5_multiplication(print_fn=print):
    for prec in ("int4", "int8", "bf16"):
        r = cm.compare("mul", prec)
        _emit(print_fn, f"fig5/mul/{prec}", r)


def fig6_dotproduct(print_fn=print):
    for cols in (40, 72):
        r = cm.compare("dot", "int4", cr_cols=cols)
        _emit(print_fn, f"fig6/dot/int4/{cols}col", r)
        print_fn(f"fig6/dot/int4/{cols}col/cycles,"
                 f"{r['compute_ram'].cycles:.0f},"
                 f"baseline={r['baseline'].cycles:.0f}")
    # paper-faithful cycle counts through the same energy/time model
    base = cm.BASELINES[("dot", "int4")].cost()
    cr = cm.ComputeRamDesign("dot", "int4", cols=40).cost()
    t_base = PAPER_DOT_CYCLES["baseline"] / base.freq_mhz / base.ops
    t_cr = PAPER_DOT_CYCLES["cr_40col"] / cr.freq_mhz / cr.ops
    print_fn(f"fig6/dot/int4/paper_cycles_time_ratio,"
             f"{t_cr / t_base:.3f},paper_claims_40col_slower")
    t_cr72 = (PAPER_DOT_CYCLES["cr_40col"] * (40 / 72)) / cr.freq_mhz \
        / cr.ops
    print_fn(f"fig6/dot/int4/paper_cycles_72col_time_ratio,"
             f"{t_cr72 / t_base:.3f},paper=~0.8")
    # the paper's future-work geometry (40 rows x 512 cols): a 40-row
    # column cannot hold a 32-bit accumulator + int4 operand tuples, so
    # dot products would need cross-column reduction through the FPGA
    # interconnect -- exactly the I/O-port cost the paper defers.
    print_fn("fig6/dot/int4/512col,n/a,"
             "40-row_column_cannot_hold_acc32+operands;"
             "needs_cross-column_reduction(paper_future_work)")


def run(print_fn=print):
    fig4_addition(print_fn)
    fig5_multiplication(print_fn)
    fig6_dotproduct(print_fn)
